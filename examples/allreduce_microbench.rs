//! Allreduce micro-benchmark sweep (Figures 4 and 6) with configurable
//! ranks/cluster, printing the same series the paper plots plus the
//! headline-ratio checks (H1/H2).
//!
//! Run: `cargo run --release --example allreduce_microbench -- \
//!       [--ranks 16] [--cluster ri2] [--max 256MB] [--json]`

use mpi_dnn_train::bench;
use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::nccl::NcclWorld;
use mpi_dnn_train::comm::{MpiFlavor, MpiWorld};
use mpi_dnn_train::util::bytes::{fmt_bytes, msg_size_sweep, parse_bytes};
use mpi_dnn_train::util::cli::Args;
use mpi_dnn_train::util::stats::geomean;

fn main() -> mpi_dnn_train::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(mpi_dnn_train::util::error::Error::msg)?;
    let ranks = args.get_usize("ranks", 16).map_err(mpi_dnn_train::util::error::Error::msg)?;
    let cluster = presets::by_name(&args.get_or("cluster", "ri2"))?;
    let max = parse_bytes(&args.get_or("max", "256MB")).map_err(mpi_dnn_train::util::error::Error::msg)?;
    let json = args.get_bool("json");
    args.reject_unknown().map_err(mpi_dnn_train::util::error::Error::msg)?;

    // the canonical Figure 6 table
    let t = bench::fig6()?;
    if json {
        println!("{}", t.to_json());
    } else {
        println!("{t}");
    }

    // per-rank/cluster custom sweep + aggregate ratios
    let stock = MpiWorld::new(MpiFlavor::Mvapich2, cluster.clone());
    let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, cluster.clone());
    let nccl = NcclWorld::new(cluster.clone()).ok();
    let mut small_ratios = Vec::new();
    let mut all_rows = Vec::new();
    for bytes in msg_size_sweep(max) {
        let s = stock.allreduce_latency(ranks, bytes).time.as_us();
        let o = opt.allreduce_latency(ranks, bytes).time.as_us();
        let n = nccl.as_ref().map(|w| w.allreduce_latency(ranks, bytes).time.as_us());
        if bytes <= 128 * 1024 {
            if let Some(n) = n {
                small_ratios.push(n / o);
            }
        }
        all_rows.push((bytes, s, o, n));
    }
    println!("custom sweep: {} ranks on {}", ranks, cluster.name);
    for (bytes, s, o, n) in &all_rows {
        println!(
            "  {:>6}  stock {:>12.1}us  opt {:>12.1}us  nccl {}",
            fmt_bytes(*bytes),
            s,
            o,
            n.map(|v| format!("{v:>12.1}us")).unwrap_or_else(|| "n/a".into())
        );
    }
    if !small_ratios.is_empty() {
        println!(
            "geomean NCCL2/MPI-Opt over small/medium sizes: {:.1}x (paper: 5-17x band)",
            geomean(&small_ratios)
        );
    }
    Ok(())
}
