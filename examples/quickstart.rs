//! Quickstart: the whole stack in ~40 lines.
//!
//! 1. loads the AOT-compiled JAX/Pallas artifacts (L2/L1),
//! 2. trains a tiny transformer for 20 data-parallel steps where the
//!    gradient aggregation runs through the paper's optimized Allreduce,
//! 3. prints one Allreduce micro-benchmark row (the §V-C comparison).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::nccl::NcclWorld;
use mpi_dnn_train::comm::{MpiFlavor, MpiWorld};
use mpi_dnn_train::trainer::{TrainConfig, Trainer};

fn main() -> mpi_dnn_train::util::error::Result<()> {
    // --- real training through PJRT + the real Allreduce ---
    let client = mpi_dnn_train::runtime::client::shared()?;
    let cfg = TrainConfig {
        model_config: "tiny".into(),
        world: 4,
        steps: 20,
        log_every: 5,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&client, cfg)?;
    let result = trainer.train()?;
    println!(
        "trained {} params x {} steps on {} simulated workers: loss {:.3} -> {:.3}",
        result.param_count,
        result.steps,
        result.world,
        result.initial_loss(),
        result.final_loss()
    );
    println!("simulated RI2 time {}, wall {:.1}s", result.sim_time, result.wall_secs);

    // --- the paper's headline micro-benchmark, one size ---
    let ri2 = presets::ri2();
    let stock = MpiWorld::new(MpiFlavor::Mvapich2, ri2.clone());
    let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, ri2.clone());
    let nccl = NcclWorld::new(ri2)?;
    let bytes = 8;
    println!(
        "\nAllreduce(8B, 16 ranks): stock MVAPICH2 {:.0}us | NCCL2 {:.0}us | MPI-Opt {:.0}us",
        stock.allreduce_latency(16, bytes).time.as_us(),
        nccl.allreduce_latency(16, bytes).time.as_us(),
        opt.allreduce_latency(16, bytes).time.as_us(),
    );
    println!("(paper §V-C: MPI-Opt is 17x faster than NCCL2 at 8 bytes)");
    Ok(())
}
