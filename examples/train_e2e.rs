//! End-to-end validation run (DESIGN.md §4 "e2e"): train the `medium`
//! transformer — 26.8M parameters, deliberately sized to ResNet-50's
//! 25.6M — for a few hundred steps of real data-parallel training:
//!
//!   * every worker's fwd/bwd is the REAL AOT-compiled JAX graph on PJRT,
//!   * gradients are aggregated by the REAL recursive-halving/doubling
//!     Allreduce (the paper's MPI-Opt configuration),
//!   * the update is the REAL fused Pallas SGD kernel,
//!   * the virtual clock reports what the run would cost on RI2.
//!
//! The loss curve is written to `e2e_loss.csv` and summarized on stdout;
//! EXPERIMENTS.md records a reference run.
//!
//! Run: `cargo run --release --example train_e2e -- [--config medium]
//!       [--world 4] [--steps 200] [--pjrt-reduce]`

use std::io::Write;

use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::MpiFlavor;
use mpi_dnn_train::trainer::{TrainConfig, Trainer};
use mpi_dnn_train::util::cli::Args;

fn main() -> mpi_dnn_train::util::error::Result<()> {
    mpi_dnn_train::util::logger::init_from_env();
    let args = Args::parse(std::env::args().skip(1)).map_err(mpi_dnn_train::util::error::Error::msg)?;
    let cfg = TrainConfig {
        model_config: args.get_or("config", "medium"),
        world: args.get_usize("world", 4).map_err(mpi_dnn_train::util::error::Error::msg)?,
        steps: args.get_usize("steps", 200).map_err(mpi_dnn_train::util::error::Error::msg)?,
        seed: 42,
        flavor: MpiFlavor::Mvapich2GdrOpt,
        cluster: presets::ri2(),
        pjrt_reduce: args.get_bool("pjrt-reduce"),
        log_every: args.get_usize("log-every", 10).map_err(mpi_dnn_train::util::error::Error::msg)?,
        checkpoint_every: args.get_usize("checkpoint-every", 0).map_err(mpi_dnn_train::util::error::Error::msg)?,
        checkpoint_path: args.get("checkpoint").map(std::path::PathBuf::from),
    };
    args.reject_unknown().map_err(mpi_dnn_train::util::error::Error::msg)?;

    let client = mpi_dnn_train::runtime::client::shared()?;
    let mut trainer = Trainer::new(&client, cfg.clone())?;
    let meta = trainer.meta().clone();
    println!(
        "e2e: config={} ({} params ≈ ResNet-50 scale), world={}, steps={}, \
         batch/worker={}, seq={}",
        meta.config, meta.param_count, cfg.world, cfg.steps, meta.batch, meta.seq
    );

    let r = trainer.train()?;

    let mut f = std::fs::File::create("e2e_loss.csv")?;
    writeln!(f, "step,loss")?;
    for (i, l) in r.losses.iter().enumerate() {
        writeln!(f, "{i},{l}")?;
    }
    let min = r.losses.iter().cloned().fold(f32::INFINITY, f32::min);
    println!("\nloss curve (every 10th step):");
    for (i, l) in r.losses.iter().enumerate().step_by(10) {
        let bar = "#".repeat(((l / r.losses[0]) * 40.0) as usize);
        println!("  {i:>4} {l:7.4} {bar}");
    }
    println!(
        "\nsummary: loss {:.4} -> {:.4} (min {:.4}) over {} steps",
        r.initial_loss(),
        r.final_loss(),
        min,
        r.steps
    );
    println!(
        "simulated {} cluster time: {}   wall: {:.1}s   ({} tokens/step/world)",
        cfg.cluster.name,
        r.sim_time,
        r.wall_secs,
        cfg.world * meta.batch * meta.seq
    );
    println!("wrote e2e_loss.csv");
    mpi_dnn_train::ensure!(
        r.final_loss() < r.initial_loss(),
        "training failed to reduce loss"
    );
    Ok(())
}
