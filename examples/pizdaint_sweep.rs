//! Figure-9 style application-level sweep on the simulated Piz Daint:
//! all four approaches × three DNNs × 1..128 GPUs, plus the H4 throughput
//! ratios and the config-file launcher path (writes + runs a TOML config,
//! demonstrating the `experiment` machinery end-to-end).
//!
//! Run: `cargo run --release --example pizdaint_sweep`

use mpi_dnn_train::bench;
use mpi_dnn_train::config::ExperimentConfig;
use mpi_dnn_train::models;
use mpi_dnn_train::strategies::{self, Strategy as _, WorldSpec};

fn main() -> mpi_dnn_train::util::error::Result<()> {
    for m in ["nasnet", "resnet50", "mobilenet"] {
        println!("{}", bench::fig9(m)?);
    }

    // H4 headline: Horovod-MPI vs gRPC at 128 GPUs
    let cluster = mpi_dnn_train::cluster::presets::piz_daint();
    for (model_name, paper_ratio) in [("resnet50", 1.8), ("mobilenet", 3.2)] {
        let model = models::by_name(model_name)?;
        let ws = WorldSpec::new(cluster.clone(), model, 128);
        let h = strategies::by_name("horovod-cray")?.iteration(&ws)?;
        let g = strategies::by_name("grpc")?.iteration(&ws)?;
        println!(
            "H4 {model_name}: Horovod-MPI/gRPC = {:.2}x (paper: {paper_ratio}x)",
            h.imgs_per_sec / g.imgs_per_sec
        );
    }

    // the launcher path: a TOML experiment config, parsed and executed
    let cfg_text = r#"
name = "pizdaint-resnet50-readme"

[workload]
cluster = "pizdaint"
model = "resnet50"
gpus = [1, 8, 64, 128]

[comm]
strategies = ["grpc", "baidu", "horovod-cray"]
"#;
    let path = std::env::temp_dir().join("pizdaint_sweep_example.toml");
    std::fs::write(&path, cfg_text)?;
    let cfg = ExperimentConfig::from_file(&path)?;
    println!(
        "\nlauncher demo: experiment `{}` on {} ({} strategies, {} world sizes) parsed OK",
        cfg.name,
        cfg.cluster.name,
        cfg.strategies.len(),
        cfg.gpus.len()
    );
    for &gpus in &cfg.gpus {
        let ws = WorldSpec::new(cfg.cluster.clone(), cfg.model.clone(), gpus);
        let mut line = format!("  {gpus:>4} GPUs:");
        for name in &cfg.strategies {
            let r = strategies::by_name(name)?.iteration(&ws)?;
            line += &format!("  {name} {:.0} img/s", r.imgs_per_sec);
        }
        println!("{line}");
    }
    std::fs::remove_file(&path).ok();
    Ok(())
}
