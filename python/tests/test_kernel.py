"""Kernel vs. pure-jnp oracle — the CORE L1 correctness signal.

hypothesis sweeps shapes (including non-tile-multiple lengths), dtypes, op
types, and part counts; every case asserts allclose between the Pallas
kernel (interpret=True) and kernels.ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.reduce import OPS, reduce_pairwise, reduce_parts
from compile.kernels.sgd import sgd_momentum

jax.config.update("jax_enable_x64", False)

# Cover: below one tile, exactly one tile, crossing tiles, odd lengths.
LENGTHS = st.sampled_from([1, 3, 255, 4096, 4097, 10000, 65536])
SMALL_BLOCKS = st.sampled_from([8, 64, 4096])


def _vec(rng, n, dtype):
    v = rng.standard_normal(n).astype(dtype)
    if dtype == np.int32:
        v = (v * 100).astype(np.int32)
    return v


@settings(max_examples=40, deadline=None)
@given(n=LENGTHS, op=st.sampled_from(OPS), seed=st.integers(0, 2**31 - 1),
       block=SMALL_BLOCKS)
def test_reduce_pairwise_matches_ref(n, op, seed, block):
    rng = np.random.default_rng(seed)
    x = _vec(rng, n, np.float32)
    y = _vec(rng, n, np.float32)
    got = reduce_pairwise(jnp.asarray(x), jnp.asarray(y), op=op, block=block)
    want = ref.reduce_pairwise_ref(jnp.asarray(x), jnp.asarray(y), op=op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([1, 255, 4096, 5001]),
       p=st.integers(2, 8), seed=st.integers(0, 2**31 - 1))
def test_reduce_parts_matches_ref(n, p, seed):
    rng = np.random.default_rng(seed)
    parts = rng.standard_normal((p, n)).astype(np.float32)
    got = reduce_parts(jnp.asarray(parts), block=4096)
    want = ref.reduce_parts_ref(jnp.asarray(parts))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_reduce_pairwise_int32_sum():
    x = jnp.arange(5000, dtype=jnp.int32)
    y = jnp.ones(5000, dtype=jnp.int32)
    got = reduce_pairwise(x, y, op="sum")
    np.testing.assert_array_equal(np.asarray(got), np.arange(5000) + 1)


def test_reduce_pairwise_rejects_bad_shapes():
    with pytest.raises(ValueError):
        reduce_pairwise(jnp.zeros((4, 4)), jnp.zeros((4, 4)))
    with pytest.raises(ValueError):
        reduce_pairwise(jnp.zeros(4), jnp.zeros(5))


def test_reduce_pairwise_associativity_chain():
    """Chained pairwise reductions == one fused parts reduction (the RSA
    invariant the rust allreduce relies on)."""
    rng = np.random.default_rng(7)
    parts = rng.standard_normal((6, 3000)).astype(np.float32)
    acc = jnp.asarray(parts[0])
    for i in range(1, 6):
        acc = reduce_pairwise(acc, jnp.asarray(parts[i]), op="sum")
    fused = reduce_parts(jnp.asarray(parts))
    np.testing.assert_allclose(np.asarray(acc), np.asarray(fused), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.sampled_from([1, 100, 4096, 9999]),
       seed=st.integers(0, 2**31 - 1),
       lr=st.sampled_from([0.01, 0.05, 0.5]),
       mu=st.sampled_from([0.0, 0.9, 0.99]),
       scale=st.sampled_from([1.0, 0.25, 0.0078125]))
def test_sgd_momentum_matches_ref(n, seed, lr, mu, scale):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(n).astype(np.float32)
    v = rng.standard_normal(n).astype(np.float32)
    g = rng.standard_normal(n).astype(np.float32)
    w2, v2 = sgd_momentum(jnp.asarray(w), jnp.asarray(v), jnp.asarray(g),
                          scale, lr=lr, mu=mu, block=4096)
    w2r, v2r = ref.sgd_momentum_ref(jnp.asarray(w), jnp.asarray(v), jnp.asarray(g),
                                    scale, lr=lr, mu=mu)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v2r), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w2r), rtol=1e-6, atol=1e-6)


def test_sgd_zero_grad_pure_momentum_decay():
    w = jnp.ones(100)
    v = jnp.full((100,), 2.0)
    g = jnp.zeros(100)
    w2, v2 = sgd_momentum(w, v, g, 1.0, lr=0.1, mu=0.5)
    np.testing.assert_allclose(np.asarray(v2), 1.0)
    np.testing.assert_allclose(np.asarray(w2), 1.0 - 0.1)
