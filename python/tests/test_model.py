"""L2 model correctness: shapes, layout round-trip, loss/grad sanity,
and the data-parallel equivalence invariant the whole paper rests on:
allreduce-of-shard-gradients == gradient-of-full-batch.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.CONFIGS["tiny"]


@pytest.fixture(scope="module")
def flat():
    return M.init_params(CFG, seed=0)


def _tokens(seed, batch=None):
    rng = np.random.default_rng(seed)
    b = batch or CFG.batch
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(b, CFG.seq + 1)), jnp.int32)


def test_param_count_matches_layout(flat):
    assert flat.shape == (M.param_count(CFG),)


def test_unflatten_roundtrip(flat):
    p = M.unflatten(flat, CFG)
    names = [n for n, _ in M.param_specs(CFG)]
    assert set(p) == set(names)
    reflat = jnp.concatenate([p[n].reshape(-1) for n in names])
    np.testing.assert_array_equal(np.asarray(reflat), np.asarray(flat))


def test_forward_shape(flat):
    toks = _tokens(1)[:, :-1]
    logits = M.forward(flat, toks, CFG)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform(flat):
    """Random init ⇒ loss ≈ ln(vocab)."""
    loss = M.loss_fn(flat, _tokens(2), CFG)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_grads_finite_and_nonzero(flat):
    loss, grads = M.train_step(flat, _tokens(3), CFG)
    assert grads.shape == flat.shape
    assert bool(jnp.all(jnp.isfinite(grads)))
    assert float(jnp.linalg.norm(grads)) > 1e-4


def test_loss_decreases_under_sgd(flat):
    """A few full-batch steps on a fixed batch must reduce loss."""
    toks = _tokens(4)
    w = flat
    losses = []
    for _ in range(5):
        loss, g = M.train_step(w, toks, CFG)
        losses.append(float(loss))
        w = w - 0.5 * g
    assert losses[-1] < losses[0]


def test_data_parallel_gradient_equivalence(flat):
    """sum_k grad(shard_k)/K == grad(full batch) — the invariant that makes
    allreduce-based data parallelism (the paper's subject) correct."""
    b = 4
    toks = _tokens(5, batch=b)
    cfg = M.ModelConfig(**{**CFG.__dict__, "batch": b})
    _, g_full = M.train_step(flat, toks, cfg)
    cfg1 = M.ModelConfig(**{**CFG.__dict__, "batch": 1})
    shard_grads = []
    for k in range(b):
        _, gk = M.train_step(flat, toks[k : k + 1], cfg1)
        shard_grads.append(gk)
    g_avg = sum(shard_grads) / b
    np.testing.assert_allclose(np.asarray(g_avg), np.asarray(g_full), rtol=2e-4, atol=2e-5)


def test_pallas_add_custom_vjp_matches_plain_add(flat):
    """The L1 kernel embedded in the L2 graph must be AD-transparent."""
    x = jnp.arange(12.0)
    y = jnp.ones(12)

    def f_pallas(x, y):
        return jnp.sum(M._pallas_add(x, y) ** 2)

    def f_plain(x, y):
        return jnp.sum((x + y) ** 2)

    gx_p, gy_p = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gx, gy = jax.grad(f_plain, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gy_p), np.asarray(gy), rtol=1e-6)


def test_configs_param_counts_sane():
    counts = {name: M.param_count(c) for name, c in M.CONFIGS.items()}
    assert counts["tiny"] < 1_000_000
    assert 5_000_000 < counts["small"] < 15_000_000
    # `medium` mirrors ResNet-50's 25.6M parameters (paper's main workload).
    assert 20_000_000 < counts["medium"] < 35_000_000
    assert counts["large"] > 70_000_000
