"""L2: JAX model fwd/bwd — the real DNN workload behind the simulator.

The paper trains convnets (ResNet-50 / MobileNet / NASNet) through
tf_cnn_benchmarks with synthetic input data, measuring pure
(GPU compute) + (gradient communication).  Our end-to-end real workload is
a decoder-only transformer LM trained on synthetic token data: the same
"synthetic data ⇒ measure compute+comm only" methodology, sized to mirror
the paper's models (the `medium` config ≈ ResNet-50's 25.6M parameters).

Interface contract with the rust coordinator (runtime/step.rs):

    train_step : (params f32[N], tokens i32[B, S+1]) -> (loss f32[], grads f32[N])

Parameters live in ONE flat f32 vector.  This makes the rust side of
data-parallel training trivial and faithful to the paper: the gradient
Allreduce operates on a flat buffer exactly like Horovod's fusion buffer,
and the optimizer is a single fused Pallas kernel over the flat vector
(kernels/sgd.py).

The L1 Pallas reduction kernel (kernels/reduce.py) is called INSIDE the
model (embedding + positional-encoding add) so it lowers into the same HLO
artifact — proving the L1→L2 composition — wrapped in a custom_vjp since
pallas_call is not auto-differentiable.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.reduce import reduce_pairwise


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer hyperparameters (static ⇒ baked into HLO)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int
    batch: int

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


#: Artifact presets.  `tiny` drives unit tests; `small` the CI-speed demos;
#: `medium` ≈ ResNet-50's 25.6M params for the end-to-end run; `large`
#: ≈100M-class for users with more compute (compiled by `make artifacts-large`).
CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=4, d_ff=128, seq=32, batch=4),
    "small": ModelConfig("small", vocab=8192, d_model=256, n_layers=4, n_heads=8, d_ff=1024, seq=64, batch=4),
    "medium": ModelConfig("medium", vocab=16384, d_model=384, n_layers=8, n_heads=8, d_ff=1536, seq=64, batch=2),
    "large": ModelConfig("large", vocab=32768, d_model=512, n_layers=16, n_heads=8, d_ff=2048, seq=128, batch=2),
}


# --------------------------------------------------------------------------
# Flat parameter layout
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig):
    """Ordered (name, shape) layout of the flat parameter vector."""
    specs = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.ln1_g", (cfg.d_model,)),
            (f"l{i}.ln1_b", (cfg.d_model,)),
            (f"l{i}.wq", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wk", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wv", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln2_g", (cfg.d_model,)),
            (f"l{i}.ln2_b", (cfg.d_model,)),
            (f"l{i}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.b1", (cfg.d_ff,)),
            (f"l{i}.w2", (cfg.d_ff, cfg.d_model)),
            (f"l{i}.b2", (cfg.d_model,)),
        ]
    specs += [
        ("lnf_g", (cfg.d_model,)),
        ("lnf_b", (cfg.d_model,)),
        ("head", (cfg.d_model, cfg.vocab)),
    ]
    return specs


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


def unflatten(flat, cfg: ModelConfig):
    """Static-slice the flat vector into a {name: array} dict."""
    params = {}
    off = 0
    for name, shape in param_specs(cfg):
        size = 1
        for d in shape:
            size *= d
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    assert off == flat.shape[0], f"flat vector has {flat.shape[0]} elems, layout wants {off}"
    return params


def init_params(cfg: ModelConfig, seed: int = 0):
    """Initialize the flat parameter vector (scaled-normal / zeros layout)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        size = 1
        for d in shape:
            size *= d
        if name.endswith((".b1", ".b2", "_b")):
            chunks.append(jnp.zeros((size,), jnp.float32))
        elif name.endswith("_g"):
            chunks.append(jnp.ones((size,), jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = (1.0 / fan_in) ** 0.5
            chunks.append(std * jax.random.normal(sub, (size,), jnp.float32))
    return jnp.concatenate(chunks)


# --------------------------------------------------------------------------
# Pallas-add with custom VJP (pallas_call is not auto-differentiable)
# --------------------------------------------------------------------------

@jax.custom_vjp
def _pallas_add(x, y):
    return reduce_pairwise(x, y, op="sum")


def _pallas_add_fwd(x, y):
    return _pallas_add(x, y), None


def _pallas_add_bwd(_, g):
    return g, g


_pallas_add.defvjp(_pallas_add_fwd, _pallas_add_bwd)


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(x, p, prefix, cfg: ModelConfig):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = (x @ p[f"{prefix}.wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (x @ p[f"{prefix}.wk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = (x @ p[f"{prefix}.wv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / (dh**0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ p[f"{prefix}.wo"]


def _block(x, p, i, cfg: ModelConfig):
    h = _layernorm(x, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
    x = x + _attention(h, p, f"l{i}", cfg)
    h = _layernorm(x, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
    ff = jax.nn.gelu(h @ p[f"l{i}.w1"] + p[f"l{i}.b1"]) @ p[f"l{i}.w2"] + p[f"l{i}.b2"]
    return x + ff


def forward(flat, tokens, cfg: ModelConfig):
    """Logits for next-token prediction.  tokens: i32[B, S]."""
    p = unflatten(flat, cfg)
    b, s = tokens.shape
    x = p["tok_emb"][tokens]  # [B, S, D]
    pos = jnp.broadcast_to(p["pos_emb"][:s], (b, s, cfg.d_model))
    # L1 Pallas kernel on the L2 path: embedding + positional add.
    x = _pallas_add(x.reshape(-1), pos.reshape(-1)).reshape(b, s, cfg.d_model)
    for i in range(cfg.n_layers):
        x = _block(x, p, i, cfg)
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["head"]


def loss_fn(flat, tokens, cfg: ModelConfig):
    """Mean cross-entropy of next-token prediction.  tokens: i32[B, S+1]."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(flat, inp, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def train_step(flat, tokens, cfg: ModelConfig):
    """(loss, flat_grads) — the artifact the rust workers execute."""
    loss, grads = jax.value_and_grad(loss_fn)(flat, tokens, cfg)
    return loss, grads


def make_train_step(cfg: ModelConfig):
    """Jittable closure with the config baked in (for lowering/AOT)."""

    @functools.wraps(train_step)
    def step(flat, tokens):
        return train_step(flat, tokens, cfg)

    return step
