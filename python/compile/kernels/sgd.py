"""L1 Pallas kernel: fused SGD-with-momentum parameter update.

After the Allreduce aggregates gradients, every worker applies the same
optimizer step.  tf_cnn_benchmarks uses stock momentum-SGD; we fuse the
whole update (grad scale + momentum accumulate + param axpy) into a single
bandwidth-bound Pallas kernel so params/velocity stream through VMEM once:

    v' = mu * v + g / world_size
    w' = w - lr * v'

Same VMEM-tiling scheme as kernels.reduce — see that module and DESIGN.md
§Hardware-Adaptation for the CUDA→TPU mapping rationale.  interpret=True
for CPU-PJRT executability.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .reduce import BLOCK, _pad_to_block


def _sgd_kernel(w_ref, v_ref, g_ref, scale_ref, w_out_ref, v_out_ref, *, lr, mu):
    """One tile of the fused momentum update (scale is 1/world_size)."""
    g = g_ref[...] * scale_ref[0]
    v = mu * v_ref[...] + g
    v_out_ref[...] = v
    w_out_ref[...] = w_ref[...] - lr * v


@functools.partial(jax.jit, static_argnames=("lr", "mu", "block"))
def sgd_momentum(w, v, g, scale, lr: float = 0.01, mu: float = 0.9, block: int = BLOCK):
    """Fused momentum-SGD over flat 1-D params; returns (w', v').

    `scale` is a scalar array (1/world_size) kept as a runtime input so the
    same AOT artifact serves any world size.  lr/mu are compile-time
    constants (they select the artifact variant, mirroring how the paper's
    training scripts fix hyperparameters per run).
    """
    if not (w.shape == v.shape == g.shape) or w.ndim != 1:
        raise ValueError(f"expect equal 1-D shapes, got {w.shape}/{v.shape}/{g.shape}")
    n = w.shape[0]
    wp = _pad_to_block(w, block)
    vp = _pad_to_block(v, block)
    gp = _pad_to_block(g, block)
    scale = jnp.asarray(scale, w.dtype).reshape((1,))
    grid = (wp.shape[0] // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    w2, v2 = pl.pallas_call(
        functools.partial(_sgd_kernel, lr=lr, mu=mu),
        out_shape=(
            jax.ShapeDtypeStruct(wp.shape, w.dtype),
            jax.ShapeDtypeStruct(vp.shape, v.dtype),
        ),
        grid=grid,
        in_specs=[spec, spec, spec, scalar_spec],
        out_specs=(spec, spec),
        interpret=True,
    )(wp, vp, gp, scale)
    return w2[:n], v2[:n]
