"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package has a reference implementation here written
with nothing but jax.numpy; pytest (python/tests/test_kernel.py) asserts
allclose between kernel and oracle across a hypothesis-driven sweep of
shapes, dtypes, and op types.  The rust-side reduction backends are in turn
pinned to the same semantics through the AOT artifacts.
"""

import jax.numpy as jnp


def reduce_pairwise_ref(x, y, op: str = "sum"):
    if op == "sum":
        return x + y
    if op == "prod":
        return x * y
    if op == "max":
        return jnp.maximum(x, y)
    if op == "min":
        return jnp.minimum(x, y)
    raise ValueError(f"unsupported op {op}")


def reduce_parts_ref(parts):
    return jnp.sum(parts, axis=0)


def sgd_momentum_ref(w, v, g, scale, lr: float = 0.01, mu: float = 0.9):
    g = g * jnp.asarray(scale, w.dtype)
    v2 = mu * v + g
    w2 = w - lr * v2
    return w2, v2
