"""L1 Pallas kernel: GPU-kernel-enabled reduction (paper §V-A, TPU-adapted).

The paper's contribution offloads the reduction step of the recursive
vector-halving/doubling reduce-scatter-allgather (RSA) Allreduce from the
host CPU to a CUDA grid-stride vector-add kernel.  The core insight is "do
the reduction where the bandwidth is" — on the accelerator's high-bandwidth
memory, avoiding the D2H/H2D staging copies.

TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of a CUDA
grid-stride loop over HBM, we tile the operand vectors into VMEM-resident
blocks with a BlockSpec grid.  Each grid step streams one (BLOCK,)-sized
tile of `x` and `y` from HBM into VMEM, the VPU performs the elementwise
add (or min/max/prod for the other MPI_Op reductions), and Pallas's
automatic pipelining double-buffers the HBM→VMEM stream against compute.
The kernel is bandwidth-bound; its roofline metric is achieved fraction of
memory bandwidth (see DESIGN.md §Perf).

Run with interpret=True everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls.  Correctness is pinned to kernels.ref via pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size in elements.  16 KiB of f32 per operand tile keeps three
# operands (x, y, o) well under VMEM (~16 MiB) even with double-buffering,
# while being long enough to amortize the per-tile control overhead.
BLOCK = 4096

#: MPI_Op reduction operators supported by the kernel (paper's Allreduce
#: carries MPI_SUM for gradient aggregation; the others exist because the
#: MPI runtime we model must implement the full predefined-op set).
OPS = ("sum", "prod", "max", "min")


def _reduce_kernel(x_ref, y_ref, o_ref, *, op: str):
    """One VMEM-tile step: o = x ⊕ y elementwise on the VPU."""
    x = x_ref[...]
    y = y_ref[...]
    if op == "sum":
        o_ref[...] = x + y
    elif op == "prod":
        o_ref[...] = x * y
    elif op == "max":
        o_ref[...] = jnp.maximum(x, y)
    elif op == "min":
        o_ref[...] = jnp.minimum(x, y)
    else:  # pragma: no cover - guarded by OPS
        raise ValueError(f"unsupported op {op}")


def _pad_to_block(v, block):
    n = v.shape[0]
    pad = (-n) % block
    if pad:
        v = jnp.pad(v, (0, pad))
    return v


@functools.partial(jax.jit, static_argnames=("op", "block"))
def reduce_pairwise(x, y, op: str = "sum", block: int = BLOCK):
    """Elementwise reduction of two 1-D vectors via the Pallas kernel.

    This is the accelerator-side reduction primitive used by the RSA
    Allreduce: each RSA step reduces the received chunk into the local
    chunk.  Handles arbitrary lengths by padding to the tile size; the
    padding lanes are sliced off before returning (pad values are the op's
    identity so they never pollute real lanes even if fused downstream).
    """
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError(f"expect equal 1-D shapes, got {x.shape} vs {y.shape}")
    n = x.shape[0]
    xp = _pad_to_block(x, block)
    yp = _pad_to_block(y, block)
    grid = (xp.shape[0] // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_reduce_kernel, op=op),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        interpret=True,
    )(xp, yp)
    return out[:n]


def _segsum_kernel(parts_ref, o_ref):
    """Tree-reduce P already-resident part vectors for one VMEM tile.

    Used by the fused "reduce a whole fusion buffer of P peers" path —
    the Horovod tensor-fusion + MPI-Opt combination reduces P staged
    contributions in one kernel launch instead of P-1 launches.
    """
    acc = parts_ref[0, ...]
    for p in range(1, parts_ref.shape[0]):
        acc = acc + parts_ref[p, ...]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block",))
def reduce_parts(parts, block: int = BLOCK):
    """Sum P part-vectors (shape [P, N]) into one [N] vector in one pass.

    Single kernel launch regardless of P: the per-tile loop unrolls the P
    accumulations while the tile streams through VMEM once.  This is the
    fused analogue of NCCL's multi-peer reduction and is what the
    `GpuKernelFused` reduction backend in the rust simulator models.
    """
    if parts.ndim != 2:
        raise ValueError(f"expect [P, N], got {parts.shape}")
    p, n = parts.shape
    pad = (-n) % block
    if pad:
        parts = jnp.pad(parts, ((0, 0), (0, pad)))
    grid = (parts.shape[1] // block,)
    out = pl.pallas_call(
        _segsum_kernel,
        out_shape=jax.ShapeDtypeStruct((parts.shape[1],), parts.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((p, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(parts)
    return out[:n]
