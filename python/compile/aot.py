"""AOT bridge: lower JAX/Pallas computations to HLO TEXT for the rust runtime.

HLO *text* (not `.serialize()`d HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (behind the published `xla` crate) rejects (`proto.id() <= INT_MAX`).
The text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/gen_hlo.py, which this module generalizes.

Artifacts produced per model config <cfg> (all consumed by rust/src/runtime):

  artifacts/train_step_<cfg>.hlo.txt   (params[N], tokens[B,S+1]) -> (loss, grads[N])
  artifacts/sgd_<cfg>.hlo.txt          (w[N], v[N], g[N], scale[1]) -> (w', v')
  artifacts/params_<cfg>.bin           little-endian f32 initial parameters
  artifacts/meta_<cfg>.json            shapes + hyperparameters for the rust side

plus model-independent reduction kernels (the paper's §V-A contribution):

  artifacts/reduce_sum_<n>.hlo.txt     (x[n], y[n]) -> (x ⊕ y)  for n in CHUNKS

Run: `cd python && python -m compile.aot --config small` (see Makefile).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import reduce as K_reduce
from .kernels import sgd as K_sgd

#: Chunk sizes (f32 elements) for which a standalone reduction-kernel
#: artifact is emitted.  The rust GPU-kernel reduction backend picks the
#: largest chunk that fits and loops; 4 KiB .. 4 MiB spans the RSA chunk
#: sizes that occur for the paper's message range (4B .. 256MB, 2..128 ranks).
REDUCE_CHUNKS = (4096, 65536, 1048576)

#: Optimizer constants baked into the SGD artifact (tf_cnn_benchmarks
#: defaults: momentum SGD, lr tuned per model; scale=1/world_size stays a
#: runtime input so one artifact serves every world size).
SGD_LR = 0.05
SGD_MU = 0.9


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def emit_train_step(cfg: M.ModelConfig, out_dir: str) -> int:
    n = M.param_count(cfg)
    step = M.make_train_step(cfg)
    params_spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    tokens_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)
    lowered = jax.jit(step).lower(params_spec, tokens_spec)
    _write(os.path.join(out_dir, f"train_step_{cfg.name}.hlo.txt"), to_hlo_text(lowered))
    return n


def emit_sgd(cfg: M.ModelConfig, n: int, out_dir: str) -> None:
    def update(w, v, g, scale):
        return K_sgd.sgd_momentum(w, v, g, scale, lr=SGD_LR, mu=SGD_MU)

    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    scal = jax.ShapeDtypeStruct((1,), jnp.float32)
    lowered = jax.jit(update).lower(vec, vec, vec, scal)
    _write(os.path.join(out_dir, f"sgd_{cfg.name}.hlo.txt"), to_hlo_text(lowered))


def emit_reduce_kernels(out_dir: str) -> None:
    for n in REDUCE_CHUNKS:
        # §Perf (EXPERIMENTS.md): interpret-mode pallas pays per-grid-step
        # overhead, so the AOT artifact uses the largest tile that stays
        # within a VMEM budget (256K f32 × 3 operands = 3 MB of ~16 MB)
        # instead of the default BLOCK — 16× fewer grid steps at 1M elems.
        block = min(n, 256 * 1024)

        def red(x, y):
            return K_reduce.reduce_pairwise(x, y, op="sum", block=block)

        vec = jax.ShapeDtypeStruct((n,), jnp.float32)
        lowered = jax.jit(red).lower(vec, vec)
        _write(os.path.join(out_dir, f"reduce_sum_{n}.hlo.txt"), to_hlo_text(lowered))


def emit_params(cfg: M.ModelConfig, n: int, out_dir: str, seed: int) -> None:
    import numpy as np

    flat = np.asarray(M.init_params(cfg, seed=seed), dtype="<f4")
    assert flat.shape == (n,)
    path = os.path.join(out_dir, f"params_{cfg.name}.bin")
    flat.tofile(path)
    print(f"  wrote {path} ({flat.nbytes} bytes)")


def emit_meta(cfg: M.ModelConfig, n: int, out_dir: str) -> None:
    meta = {
        "config": cfg.name,
        "param_count": n,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "seq": cfg.seq,
        "batch": cfg.batch,
        "tokens_shape": [cfg.batch, cfg.seq + 1],
        "sgd_lr": SGD_LR,
        "sgd_mu": SGD_MU,
        "reduce_chunks": list(REDUCE_CHUNKS),
    }
    path = os.path.join(out_dir, f"meta_{cfg.name}.json")
    with open(path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"  wrote {path}")


def build(config: str, out_dir: str, seed: int = 0, skip_reduce: bool = False) -> None:
    cfg = M.CONFIGS[config]
    os.makedirs(out_dir, exist_ok=True)
    print(f"[aot] lowering config={cfg.name} ...")
    n = emit_train_step(cfg, out_dir)
    emit_sgd(cfg, n, out_dir)
    emit_params(cfg, n, out_dir, seed)
    emit_meta(cfg, n, out_dir)
    if not skip_reduce:
        emit_reduce_kernels(out_dir)
    print(f"[aot] done: config={cfg.name} param_count={n}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="small", choices=sorted(M.CONFIGS))
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-reduce", action="store_true",
                    help="skip the model-independent reduction kernels")
    args = ap.parse_args()
    build(args.config, args.out_dir, args.seed, args.skip_reduce)


if __name__ == "__main__":
    main()
