//! Bench: regenerate Figure 9 (Piz Daint, 3 models × 4 approaches) +
//! the H4/H6 headline guards.
use mpi_dnn_train::bench;
use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::models;
use mpi_dnn_train::strategies::{self, Strategy as _, WorldSpec};
use mpi_dnn_train::util::bench::{black_box, Bencher};

fn main() {
    for m in ["nasnet", "resnet50", "mobilenet"] {
        println!("{}", bench::fig9(m).expect("fig9"));
    }
    let eff = |name: &str| {
        let ws = WorldSpec::new(presets::piz_daint(), models::by_name(name).unwrap(), 128);
        strategies::by_name("horovod-cray").unwrap().iteration(&ws).unwrap().scaling_efficiency
    };
    let (n, r, m) = (eff("nasnet"), eff("resnet50"), eff("mobilenet"));
    assert!(n > r && r > m, "H6 regression");
    println!("H6 efficiency @128: nasnet {:.0}% > resnet {:.0}% > mobilenet {:.0}% (paper 92/71/16)",
        n * 100.0, r * 100.0, m * 100.0);
    let mut b = Bencher::new("fig9");
    b.bench("generate_mobilenet", || {
        black_box(bench::fig9("mobilenet").unwrap());
    });
}
