//! Bench: regenerate Figure 3 (six approaches on RI2) and time it.
use mpi_dnn_train::bench;
use mpi_dnn_train::util::bench::{black_box, Bencher};

fn main() {
    let table = bench::fig3().expect("fig3");
    println!("{table}");
    let mut b = Bencher::new("fig3");
    b.bench("generate", || {
        black_box(bench::fig3().unwrap());
    });
}
