//! Bench: regenerate Figure 7 (Horovod variants on RI2).
use mpi_dnn_train::bench;
use mpi_dnn_train::util::bench::{black_box, Bencher};

fn main() {
    let table = bench::fig7().expect("fig7");
    println!("{table}");
    let mut b = Bencher::new("fig7");
    b.bench("generate", || {
        black_box(bench::fig7().unwrap());
    });
}
