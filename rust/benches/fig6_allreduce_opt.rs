//! Bench: regenerate Figure 6 (the paper's §V MPI-Opt comparison) and
//! verify the headline ratios stay in band on every run.
use mpi_dnn_train::bench;
use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::nccl::NcclWorld;
use mpi_dnn_train::comm::{MpiFlavor, MpiWorld};
use mpi_dnn_train::util::bench::{black_box, Bencher};

fn main() {
    let table = bench::fig6().expect("fig6");
    println!("{table}");

    // headline guards (H1/H2) — fail loudly if a regression breaks them
    let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());
    let nccl = NcclWorld::new(presets::ri2()).unwrap();
    let r8 = nccl.allreduce_latency(16, 8).time.as_us() / opt.allreduce_latency(16, 8).time.as_us();
    assert!(r8 > 5.0, "H1 regression: 8B ratio {r8:.1}x");
    let big = 256 << 20;
    let rl = nccl.allreduce_latency(16, big).time.as_us() / opt.allreduce_latency(16, big).time.as_us();
    assert!(rl > 1.15, "H2 regression: 256MB ratio {rl:.2}x");
    println!("H1 8B NCCL2/Opt = {r8:.1}x (paper 17x)   H2 256MB = {rl:.2}x (paper ~1.4x)");

    let mut b = Bencher::new("fig6");
    b.bench("generate", || {
        black_box(bench::fig6().unwrap());
    });
    b.bench("allreduce_latency_256MB_16r", || {
        black_box(opt.allreduce_latency(16, big));
    });
}
