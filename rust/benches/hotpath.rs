//! Hot-path micro-benchmarks for the §Perf pass (EXPERIMENTS.md):
//!  * real-buffer allreduce inner loops (ring/RHD reductions)
//!  * the event-engine throughput (events/s)
//!  * pointer-cache resolve latency
//!  * PS fan-in simulation cost
//!  * PJRT train_step + reduce-kernel execution (when artifacts exist)
//!
//! Run: `cargo bench --offline` (or this target alone via
//! `cargo bench --bench hotpath`).

use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::allreduce::{rhd_allreduce, ring_allreduce, AllreduceCtx, ReducePlace, TransportMode};
use mpi_dnn_train::comm::ptrcache::{BufKind, CacheMode, CudaDriverSim, PointerCache};
use mpi_dnn_train::comm::{MpiFlavor, MpiWorld};
use mpi_dnn_train::models;
use mpi_dnn_train::sim::{Engine, SimTime};
use mpi_dnn_train::strategies::{PsStrategy, Strategy, WorldSpec};
use mpi_dnn_train::util::bench::{black_box, Bencher};
use mpi_dnn_train::util::prng::Rng;

fn ctx() -> AllreduceCtx {
    let c = presets::ri2();
    AllreduceCtx::new(
        c.fabric.clone(),
        c.gpu.clone(),
        TransportMode::Gdr,
        ReducePlace::Gpu,
        CacheMode::Intercept,
        c.driver_query_us,
    )
}

fn main() {
    let mut b = Bencher::new("hotpath");
    let mut rng = Rng::new(7);

    // --- L3 hot loop 1: real-data allreduce (16 ranks × 1M f32 = 64MB) ---
    let base: Vec<Vec<f32>> = (0..16).map(|_| rng.f32_vec(1 << 20)).collect();
    b.bench("rhd_allreduce_16r_4MB_each", || {
        let mut bufs = base.clone();
        let mut c = ctx();
        black_box(rhd_allreduce(&mut bufs, &mut c));
    });
    b.bench("ring_allreduce_16r_4MB_each", || {
        let mut bufs = base.clone();
        let mut c = ctx();
        black_box(ring_allreduce(&mut bufs, &mut c));
    });

    // --- shadow-path latency model (the strategies' inner call) ---
    let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());
    b.bench("shadow_latency_128r_256MB", || {
        black_box(opt.allreduce_latency(128, 256 << 20));
    });

    // --- event engine throughput ---
    b.bench("engine_100k_events", || {
        let mut e = Engine::new();
        let r = e.resource(10.0, SimTime::ZERO);
        for i in 0..100_000u64 {
            e.at(SimTime(i * 10), move |e| {
                e.serve(r, 64.0, |_| {});
            });
        }
        black_box(e.run());
    });

    // --- pointer cache resolve (the §V-B critical-path op) ---
    let mut driver = CudaDriverSim::new(1.0);
    let mut cache = PointerCache::new(CacheMode::Intercept);
    let ptrs: Vec<u64> = (0..1024).map(|_| driver.cu_malloc(4096)).collect();
    for &p in &ptrs {
        cache.on_malloc(p, BufKind::Device);
    }
    b.bench("ptrcache_resolve_x1024", || {
        for &p in &ptrs {
            black_box(cache.resolve(p, &mut driver));
        }
    });

    // --- PS fan-in DES (gRPC, ResNet-50, 16 workers) ---
    let model = models::by_name("resnet50").unwrap();
    b.bench("ps_grpc_iteration_16w", || {
        let ws = WorldSpec::new(presets::ri2(), model.clone(), 16);
        black_box(PsStrategy::grpc().iteration(&ws).unwrap());
    });

    // --- PJRT execution (L1/L2 artifacts), when built ---
    if let Ok(dir) = mpi_dnn_train::runtime::artifacts_dir() {
        if mpi_dnn_train::runtime::config_available(&dir, "tiny") {
            let client = mpi_dnn_train::runtime::client::shared().unwrap();
            let step =
                mpi_dnn_train::runtime::TrainStep::load(&client, &dir, "tiny").unwrap();
            let params = step.meta.load_params(&dir).unwrap();
            let tokens = rng.tokens(step.meta.tokens_len(), step.meta.vocab as u32);
            b.bench("pjrt_train_step_tiny", || {
                black_box(step.run(&params, &tokens).unwrap());
            });
            if dir.join("reduce_sum_1048576.hlo.txt").is_file() {
                let k = mpi_dnn_train::runtime::ReduceKernel::load(
                    &client,
                    &dir,
                    &[1048576],
                )
                .unwrap();
                let mut acc = rng.f32_vec(1 << 20);
                let x = rng.f32_vec(1 << 20);
                b.bench("pjrt_pallas_reduce_1M", || {
                    k.accumulate(&mut acc, &x).unwrap();
                    black_box(acc[0]);
                });
            }
        }
    }
}
