//! Bench: regenerate Figure 4 (MPI vs NCCL2 allreduce baseline).
use mpi_dnn_train::bench;
use mpi_dnn_train::util::bench::{black_box, Bencher};

fn main() {
    let table = bench::fig4().expect("fig4");
    println!("{table}");
    let mut b = Bencher::new("fig4");
    b.bench("generate", || {
        black_box(bench::fig4().unwrap());
    });
}
