//! Bench: regenerate Figure 8 (Owens, 64 P100s) + H3 efficiency guard.
use mpi_dnn_train::bench;
use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::models;
use mpi_dnn_train::strategies::{self, Strategy as _, WorldSpec};
use mpi_dnn_train::util::bench::{black_box, Bencher};

fn main() {
    let table = bench::fig8().expect("fig8");
    println!("{table}");
    let ws = WorldSpec::new(presets::owens(), models::by_name("resnet50").unwrap(), 64);
    let eff = strategies::by_name("horovod-mpi-opt")
        .unwrap()
        .iteration(&ws)
        .unwrap()
        .scaling_efficiency;
    assert!(eff > 0.8, "H3 regression: Owens@64 eff {eff:.2}");
    println!("H3 Owens@64 MPI-Opt efficiency = {:.0}% (paper ~90%)", eff * 100.0);
    let mut b = Bencher::new("fig8");
    b.bench("generate", || {
        black_box(bench::fig8().unwrap());
    });
}
