//! Bench: regenerate Figure 2 (batch-size sweep) and time the generator.
use mpi_dnn_train::bench;
use mpi_dnn_train::util::bench::{black_box, Bencher};

fn main() {
    let table = bench::fig2();
    println!("{table}");
    let mut b = Bencher::new("fig2");
    b.bench("generate", || {
        black_box(bench::fig2());
    });
}
