//! mpi-dnn-train CLI — the L3 leader entrypoint.
//!
//! ```text
//! mpi-dnn-train figure 6               # regenerate a paper figure
//! mpi-dnn-train figure all --json      # all figures, points in parallel
//! mpi-dnn-train microbench --ranks 16 --max 256MB
//! mpi-dnn-train train --config small --world 4 --steps 100
//! mpi-dnn-train experiment cfgs/fig9.toml
//! mpi-dnn-train ablation --cluster owens --world 64 [--sweep fusion|cycle-grid]
//! mpi-dnn-train scenario straggler --cluster owens --world 64 --factor 1.5 [--streams 2]
//! mpi-dnn-train scenario two-jobs --cluster pizdaint --world 64 --model mobilenet --family ps
//! mpi-dnn-train scenario placement --cluster owens --world 16 --gpus-per-node 4 --rails 2
//! mpi-dnn-train scenario overlap --cluster pizdaint --world 64 --model mobilenet --streams 8
//! mpi-dnn-train scenario fault --world 8 --fault "crash@1500:r3" --trace recovery.json
//! mpi-dnn-train scenario faults --cluster owens --world 16 --seed 7   # rate × world sweep
//! mpi-dnn-train scenario campaign --world 8 --campaign-iters 50 --campaign-mtbf-us 60000 \
//!     --campaign-ckpt young-daly --campaign-ckpt-cost-us 500 --campaign-repair-us 8000 \
//!     [--strategy horovod-mpi-opt --trace c.json --report c-report.json]
//! mpi-dnn-train scenario campaigns --cluster ri2 --world 8 --seed 7   # policy × rate sweep
//! mpi-dnn-train graph --algo ring --ranks 8 --size 4MB --straggler 1 --factor 2
//! mpi-dnn-train graph --ranks 8 --gpus-per-node 2 --rails 2   # dense-node timeline
//! mpi-dnn-train trace --strategy horovod-mpi-opt --world 8 --streams 2 --out trace.json
//! mpi-dnn-train trace validate trace.json
//! mpi-dnn-train perf [--quick] [--out BENCH_engine.json] [--check BASE --band 0.25]
//! mpi-dnn-train perf scale-sweep [--quick]   # §Scale 256→16k-rank fleet sweep
//! mpi-dnn-train validate               # artifacts + numerics smoke
//! mpi-dnn-train list
//! ```

use mpi_dnn_train::util::error::{Context, Error, Result};

use mpi_dnn_train::bench::{self, Table};
use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::nccl::NcclWorld;
use mpi_dnn_train::comm::{MpiFlavor, MpiWorld};
use mpi_dnn_train::config::ExperimentConfig;
use mpi_dnn_train::runtime;
use mpi_dnn_train::strategies::{self, Strategy as _, WorldSpec};
use mpi_dnn_train::trainer::{TrainConfig, Trainer};
use mpi_dnn_train::util::bytes::{fmt_bytes, parse_bytes};
use mpi_dnn_train::util::cli::Args;
use mpi_dnn_train::util::par::par_map_ordered;

fn main() {
    mpi_dnn_train::util::logger::init_from_env();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn emit(t: &Table, json: bool) {
    if json {
        println!("{}", t.to_json());
    } else {
        println!("{t}");
    }
}

fn run(args: Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("figure") => cmd_figure(&args),
        Some("microbench") => cmd_microbench(&args),
        Some("train") => cmd_train(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("graph") => cmd_graph(&args),
        Some("trace") => cmd_trace(&args),
        Some("perf") => cmd_perf(&args),
        Some("validate") => cmd_validate(&args),
        Some("list") => cmd_list(&args),
        Some(other) => mpi_dnn_train::bail!("unknown subcommand `{other}` (see README)"),
        None => {
            println!(
                "usage: mpi-dnn-train <figure|microbench|train|experiment|ablation|scenario|graph|trace|perf|validate|list> [flags]"
            );
            Ok(())
        }
    }
}

fn cmd_figure(args: &Args) -> Result<()> {
    let json = args.get_bool("json");
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    args.reject_unknown().map_err(Error::msg)?;
    let mut tables: Vec<Table> = Vec::new();
    match which {
        "2" => tables.push(bench::fig2()),
        "3" => tables.push(bench::fig3()?),
        "4" => tables.push(bench::fig4()?),
        "6" => tables.push(bench::fig6()?),
        "7" => tables.push(bench::fig7()?),
        "8" => tables.push(bench::fig8()?),
        "9" => {
            for m in ["nasnet", "resnet50", "mobilenet"] {
                tables.push(bench::fig9(m)?);
            }
        }
        "all" => {
            // every figure is an independent sweep: generate them in
            // parallel, join in publication order
            type Job = Box<dyn FnOnce() -> Result<Vec<Table>> + Send>;
            let jobs: Vec<Job> = vec![
                Box::new(|| Ok(vec![bench::fig2()])),
                Box::new(|| Ok(vec![bench::fig3()?])),
                Box::new(|| Ok(vec![bench::fig4()?])),
                Box::new(|| Ok(vec![bench::fig6()?])),
                Box::new(|| Ok(vec![bench::fig7()?])),
                Box::new(|| Ok(vec![bench::fig8()?])),
                Box::new(|| {
                    let mut v = Vec::new();
                    for m in ["nasnet", "resnet50", "mobilenet"] {
                        v.push(bench::fig9(m)?);
                    }
                    Ok(v)
                }),
            ];
            for g in par_map_ordered(jobs, |j| j()) {
                tables.extend(g?);
            }
        }
        other => mpi_dnn_train::bail!("unknown figure `{other}` (2|3|4|6|7|8|9|all)"),
    }
    for t in &tables {
        emit(t, json);
    }
    Ok(())
}

fn cmd_microbench(args: &Args) -> Result<()> {
    let ranks = args.get_usize("ranks", 16).map_err(Error::msg)?;
    let max = parse_bytes(&args.get_or("max", "256MB")).map_err(Error::msg)?;
    let cluster = presets::by_name(&args.get_or("cluster", "ri2"))?;
    let json = args.get_bool("json");
    args.reject_unknown().map_err(Error::msg)?;

    let mpi = MpiWorld::new(MpiFlavor::Mvapich2, cluster.clone());
    let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, cluster.clone());
    let nccl = NcclWorld::new(cluster.clone()).ok();
    let mut t = Table::new(
        &format!("Allreduce microbenchmark, {} ranks on {}", ranks, cluster.name),
        &["size", "MPI (us)", "MPI-Opt (us)", "NCCL2 (us)"],
    );
    for bytes in mpi_dnn_train::util::bytes::msg_size_sweep(max) {
        t.row([
            fmt_bytes(bytes),
            format!("{:.1}", mpi.allreduce_latency(ranks, bytes).time.as_us()),
            format!("{:.1}", opt.allreduce_latency(ranks, bytes).time.as_us()),
            match &nccl {
                Some(n) => format!("{:.1}", n.allreduce_latency(ranks, bytes).time.as_us()),
                None => "n/a".into(),
            },
        ]);
    }
    emit(&t, json);
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = TrainConfig {
        model_config: args.get_or("config", "small"),
        world: args.get_usize("world", 4).map_err(Error::msg)?,
        steps: args.get_usize("steps", 100).map_err(Error::msg)?,
        seed: args.get_usize("seed", 0).map_err(Error::msg)? as u64,
        flavor: parse_flavor(&args.get_or("flavor", "mvapich2-gdr-opt"))?,
        cluster: presets::by_name(&args.get_or("cluster", "ri2"))?,
        pjrt_reduce: args.get_bool("pjrt-reduce"),
        log_every: args.get_usize("log-every", 10).map_err(Error::msg)?,
        checkpoint_every: args.get_usize("checkpoint-every", 0).map_err(Error::msg)?,
        checkpoint_path: args.get("checkpoint").map(std::path::PathBuf::from),
        trace_path: args.get("trace").map(std::path::PathBuf::from),
    };
    // §Robustness rehearsal: any --campaign-* flag reroutes `train` into
    // an engine-level sustained-failure campaign over the configured
    // cluster/world/flavor — same seeds, crash stream and checkpoint
    // policy the real run would face, no PJRT artifacts needed
    let campaign_given = [
        "campaign-iters",
        "campaign-mtbf-us",
        "campaign-ckpt",
        "campaign-ckpt-period-us",
        "campaign-ckpt-cost-us",
        "campaign-repair-us",
        "campaign-model",
    ]
    .iter()
    .any(|k| args.get(k).is_some());
    let campaign_iters = args.get_usize("campaign-iters", cfg.steps).map_err(Error::msg)?;
    let campaign_mtbf = args.get_f64("campaign-mtbf-us", 0.0).map_err(Error::msg)?;
    let campaign_ckpt = args.get_or("campaign-ckpt", "off");
    let campaign_ckpt_period =
        args.get_f64("campaign-ckpt-period-us", 0.0).map_err(Error::msg)?;
    let campaign_ckpt_cost = args.get_f64("campaign-ckpt-cost-us", 0.0).map_err(Error::msg)?;
    let campaign_repair = args.get_f64("campaign-repair-us", 0.0).map_err(Error::msg)?;
    let campaign_model = args.get_or("campaign-model", "resnet50");
    args.reject_unknown().map_err(Error::msg)?;
    if campaign_given {
        use mpi_dnn_train::sim::{run_campaign, CampaignSpec, CheckpointPolicy, TraceGuard};
        let model = mpi_dnn_train::models::by_name(&campaign_model)?;
        let model_name = model.name.clone();
        let ws = WorldSpec::new(cfg.cluster.clone(), model, cfg.world);
        let sc = mpi_dnn_train::strategies::Scenario {
            campaign: CampaignSpec {
                iters: campaign_iters,
                mtbf_us: campaign_mtbf,
                seed: cfg.seed,
                policy: CheckpointPolicy::parse(&campaign_ckpt, campaign_ckpt_period)?,
                ckpt_cost_us: campaign_ckpt_cost,
                repair_us: campaign_repair,
            },
            ..mpi_dnn_train::strategies::Scenario::default()
        };
        sc.validate()?;
        let strat = mpi_dnn_train::strategies::Horovod::mpi(cfg.flavor);
        println!(
            "campaign rehearsal: {} × {} iters on simulated {} ({model_name}, world {})",
            strat.name(),
            campaign_iters,
            cfg.cluster.name,
            cfg.world
        );
        let report = {
            let _t = cfg.trace_path.as_ref().map(|_| TraceGuard::new());
            run_campaign(&strat, &ws, &sc)?
        };
        println!(
            "done: {} committed ({} attempted, {} discarded), {} crashes / {} rejoins / {} \
             checkpoints, makespan {}, goodput {:.0} img/s (fault-free {:.0})",
            report.committed,
            report.attempted,
            report.discarded,
            report.crashes,
            report.rejoins,
            report.checkpoints,
            report.makespan,
            report.goodput_imgs_per_sec,
            report.fault_free_imgs_per_sec
        );
        if let Some(path) = &cfg.trace_path {
            let trace = report
                .trace
                .as_ref()
                .context("traced campaign attached no trace (tracer detached?)")?;
            std::fs::write(path, &trace.chrome_json)
                .context(format!("writing {}", path.display()))?;
            println!("wrote {} (representative campaign iteration)", path.display());
        }
        return Ok(());
    }

    let client = mpi_dnn_train::runtime::client::shared()?;
    println!(
        "training config={} world={} steps={} on simulated {} (PJRT platform: {})",
        cfg.model_config,
        cfg.world,
        cfg.steps,
        cfg.cluster.name,
        client.platform()
    );
    let mut trainer = Trainer::new(&client, cfg)?;
    let r = trainer.train()?;
    println!(
        "done: {} params, loss {:.4} -> {:.4}, simulated cluster time {}, wall {:.1}s",
        r.param_count,
        r.initial_loss(),
        r.final_loss(),
        r.sim_time,
        r.wall_secs
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let path = args.positional.first().context("usage: experiment <config.toml>")?;
    args.reject_unknown().map_err(Error::msg)?;
    let cfg = ExperimentConfig::from_file(std::path::Path::new(path))?;
    let mut headers = vec!["gpus".to_string(), "ideal".to_string()];
    headers.extend(cfg.strategies.iter().cloned());
    let mut t = Table::new(
        &format!("experiment `{}`: {} on {}", cfg.name, cfg.model.name, cfg.cluster.name),
        &headers.iter().map(|h| h.as_str()).collect::<Vec<_>>(),
    );
    // resolve once (names were validated at config parse; this keeps any
    // future lookup failure loud instead of an "n/a" cell), then one
    // thread per sweep point, rows joined in sweep order
    let strats = cfg
        .strategies
        .iter()
        .map(|n| strategies::by_name(n))
        .collect::<Result<Vec<_>>>()?;
    let rows = par_map_ordered(cfg.gpus.iter().copied(), |gpus| {
        let mut ws = WorldSpec::new(cfg.cluster.clone(), cfg.model.clone(), gpus);
        ws.batch_per_gpu = cfg.batch_per_gpu;
        let ideal = gpus as f64 * ws.throughput_1gpu();
        let mut row = vec![gpus.to_string(), format!("{ideal:.0}")];
        for s in &strats {
            row.push(match s.iteration_in(&ws, &cfg.scenario) {
                Ok(r) => format!("{:.0}", r.imgs_per_sec),
                Err(_) => "n/a".into(),
            });
        }
        row
    });
    for row in rows {
        t.row(row);
    }
    if !cfg.scenario.is_neutral() {
        t.note(format!("scenario: {:?}", cfg.scenario));
    }
    emit(&t, cfg.json_output);
    // `[scenario] second_job = true`: run the link-sharing co-tenant
    // tables on the sweep's largest point, one per configured strategy
    // that has a runner (Horovod variants and Baidu share the wire, PS
    // transports the per-server NICs).
    if cfg.scenario.second_job {
        let world = *cfg.gpus.iter().max().unwrap();
        let offset = cfg.scenario.second_job_offset_us;
        for name in &cfg.strategies {
            let lower = name.to_ascii_lowercase();
            if !(lower.starts_with("horovod")
                || lower.starts_with("grpc")
                || lower.starts_with("rdma")
                || lower.starts_with("baidu"))
            {
                println!("(two-jobs: no link-share runner for `{name}`, skipped)");
                continue;
            }
            match bench::scenario_two_jobs(
                cfg.cluster.clone(),
                cfg.model.clone(),
                world,
                offset,
                &lower,
            ) {
                Ok(t) => emit(&t, cfg.json_output),
                // e.g. horovod-nccl on a verbs-less fabric: keep the rest
                Err(e) => println!("(two-jobs `{name}` unavailable: {e})"),
            }
        }
    }
    // `[scenario.campaign]`: the main sweep rows above are fault-free
    // iterations; the campaign runs on the sweep's largest point, one
    // row per configured strategy under the same seeded crash stream
    if !cfg.scenario.campaign.is_off() {
        let world = *cfg.gpus.iter().max().unwrap();
        let spec = &cfg.scenario.campaign;
        let mut ct = Table::new(
            &format!(
                "experiment `{}`: {}-iter campaign @ {world} gpus (MTBF {:.0}us/rank, \
                 ckpt {})",
                cfg.name,
                spec.iters,
                spec.mtbf_us,
                spec.policy.name()
            ),
            &["strategy", "goodput", "iters/s", "crashes", "rejoins", "ckpts", "makespan"],
        );
        let rows = par_map_ordered(strats.iter(), |s| {
            let mut ws = WorldSpec::new(cfg.cluster.clone(), cfg.model.clone(), world);
            ws.batch_per_gpu = cfg.batch_per_gpu;
            match mpi_dnn_train::sim::run_campaign(s.as_ref(), &ws, &cfg.scenario) {
                Ok(r) => vec![
                    s.name(),
                    format!("{:.0}", r.goodput_imgs_per_sec),
                    format!("{:.2}", r.effective_iters_per_sec),
                    r.crashes.to_string(),
                    r.rejoins.to_string(),
                    r.checkpoints.to_string(),
                    format!("{}", r.makespan),
                ],
                Err(_) => {
                    let mut row = vec![s.name(), "n/a".into(), "n/a".into()];
                    row.extend(["-", "-", "-", "-"].map(String::from));
                    row
                }
            }
        });
        for row in rows {
            ct.row(row);
        }
        emit(&ct, cfg.json_output);
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let cluster = args.get_or("cluster", "owens");
    let world = args.get_usize("world", 64).map_err(Error::msg)?;
    let sweep = args.get_or("sweep", "fusion");
    let json = args.get_bool("json");
    args.reject_unknown().map_err(Error::msg)?;
    let table = match sweep.as_str() {
        "fusion" => bench::ablation_fusion(&cluster, world)?,
        "cycle-grid" | "cycle-scenario" => bench::ablation_cycle_grid(&cluster, world)?,
        other => mpi_dnn_train::bail!("--sweep must be fusion|cycle-grid, got `{other}`"),
    };
    emit(&table, json);
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    use mpi_dnn_train::sim::FaultPlan;
    use mpi_dnn_train::strategies::Scenario;
    let kind = args.positional.first().map(String::as_str).unwrap_or("straggler");
    let mut cluster = presets::by_name(&args.get_or("cluster", "owens"))?;
    let world = args.get_usize("world", 16).map_err(Error::msg)?;
    let model = mpi_dnn_train::models::by_name(&args.get_or("model", "resnet50"))?;
    let json = args.get_bool("json");
    let factor = args.get_f64("factor", 1.5).map_err(Error::msg)?;
    let ranks = args.get_usize("ranks", 1).map_err(Error::msg)?;
    let jitter = args.get_f64("jitter-us", 0.0).map_err(Error::msg)?;
    let load = args.get_f64("load", 0.5).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 0).map_err(Error::msg)? as u64;
    let offset = args.get_f64("offset-us", 0.0).map_err(Error::msg)?;
    let family = args.get_or("family", "horovod");
    // §Overlap knobs: comm streams (1 = the classic serialized comm
    // thread) and the in-flight depth cap (0 = as deep as the streams).
    // They compose with every scenario kind; the `overlap` kind sweeps
    // the stream count instead (--streams then sets the sweep ceiling).
    let streams = args.get_usize("streams", 1).map_err(Error::msg)?;
    let depth = args.get_usize("depth", 0).map_err(Error::msg)?;
    // §Transports knob: cap the PS family's in-flight shard RPCs per
    // worker (0 = unbounded — the serialized reference schedule)
    let rpc_window = args.get_usize("rpc-window", 0).map_err(Error::msg)?;
    // placement overrides: dense nodes / multi-rail NICs reshape the
    // cluster every scenario runs on (the `placement` kind sweeps them
    // instead, defaulting to a 2-GPU / 2-rail comparison)
    let gpn_flag = match args.get("gpus-per-node") {
        Some(_) => Some(args.get_usize("gpus-per-node", 1).map_err(Error::msg)?),
        None => None,
    };
    let rails_flag = match args.get("rails") {
        Some(_) => Some(args.get_usize("rails", 1).map_err(Error::msg)?),
        None => None,
    };
    // §Robustness knobs: `--fault SPEC` schedules injected failures for
    // the `fault` kind; the recovery-cost flags ride both fault kinds
    // (`faults` seeds its own crash draws but honors the shared knobs).
    let fault_spec = args.get("fault").map(String::from);
    let fault_knob_given = [
        "fault-timeout-us",
        "fault-backoff-us",
        "fault-backoff-factor",
        "fault-retries",
        "rebuild-us",
        "checkpoint-us",
    ]
    .iter()
    .any(|k| args.get(k).is_some());
    let knobs = {
        let d = FaultPlan::default();
        FaultPlan {
            events: Vec::new(),
            detect_timeout_us: args
                .get_f64("fault-timeout-us", d.detect_timeout_us)
                .map_err(Error::msg)?,
            backoff_base_us: args
                .get_f64("fault-backoff-us", d.backoff_base_us)
                .map_err(Error::msg)?,
            backoff_factor: args
                .get_f64("fault-backoff-factor", d.backoff_factor)
                .map_err(Error::msg)?,
            max_retries: args
                .get_usize("fault-retries", d.max_retries as usize)
                .map_err(Error::msg)? as u32,
            rebuild_us: args.get_f64("rebuild-us", d.rebuild_us).map_err(Error::msg)?,
            checkpoint_period_us: args
                .get_f64("checkpoint-us", d.checkpoint_period_us)
                .map_err(Error::msg)?,
        }
    };
    // §Robustness campaign knobs (the `campaign` kind): a sustained
    // seeded crash stream over many iterations with checkpoint rollback
    // and elastic rejoin; `campaigns` sweeps policy × rate instead.
    let campaign_knob_given = [
        "campaign-iters",
        "campaign-mtbf-us",
        "campaign-ckpt",
        "campaign-ckpt-period-us",
        "campaign-ckpt-cost-us",
        "campaign-repair-us",
    ]
    .iter()
    .any(|k| args.get(k).is_some());
    let campaign_iters = args.get_usize("campaign-iters", 50).map_err(Error::msg)?;
    let campaign_mtbf = args.get_f64("campaign-mtbf-us", 0.0).map_err(Error::msg)?;
    let campaign_ckpt = args.get_or("campaign-ckpt", "off");
    let campaign_ckpt_period =
        args.get_f64("campaign-ckpt-period-us", 0.0).map_err(Error::msg)?;
    let campaign_ckpt_cost = args.get_f64("campaign-ckpt-cost-us", 0.0).map_err(Error::msg)?;
    let campaign_repair = args.get_f64("campaign-repair-us", 0.0).map_err(Error::msg)?;
    let strategy_flag = args.get("strategy").map(String::from);
    let report_flag = args.get("report").map(String::from);
    // §Observability: after the comparison table, re-run the scenario's
    // horovod-mpi-opt point with the span tracer attached and write the
    // Chrome timeline here (the table itself runs untraced, as always).
    let trace_flag = args.get("trace").map(String::from);
    args.reject_unknown().map_err(Error::msg)?;
    if trace_flag.is_some() {
        mpi_dnn_train::ensure!(
            !matches!(kind, "two-jobs" | "placement" | "faults" | "campaigns"),
            "--trace works with straggler | hetero | jitter | link-load | overlap | fault | \
             campaign (the {kind} comparison has no single traced run)"
        );
    }
    // same inert-knob policy as --streams/--depth below: fault flags on a
    // kind that never reads them would silently report fault-free numbers
    // (`campaign` honors the shared recovery knobs on its crash draws)
    if !matches!(kind, "fault" | "faults" | "campaign") {
        mpi_dnn_train::ensure!(
            fault_spec.is_none() && !fault_knob_given,
            "--fault and the fault knobs are only consumed by `scenario fault` / \
             `scenario faults` / `scenario campaign`"
        );
    }
    if matches!(kind, "faults" | "campaign") {
        mpi_dnn_train::ensure!(
            fault_spec.is_none(),
            "`scenario {kind}` draws its own seeded crashes — use `scenario fault` to \
             inject an explicit --fault schedule"
        );
    }
    if kind != "campaign" {
        mpi_dnn_train::ensure!(
            !campaign_knob_given && strategy_flag.is_none() && report_flag.is_none(),
            "--campaign-* / --strategy / --report are only consumed by `scenario campaign` \
             (`scenario campaigns` derives its grid from the measured iteration and --seed)"
        );
    }
    for (name, v) in [("--gpus-per-node", gpn_flag), ("--rails", rails_flag)] {
        if let Some(v) = v {
            mpi_dnn_train::ensure!(v >= 1, "{name} must be >= 1, got {v}");
        }
    }
    // the two-jobs / placement / faults kinds run their own fixed
    // comparisons and do not consume the overlap knobs — accepting them
    // silently would report serialized-baseline numbers under an overlap
    // label (the same inert-knob policy the `[scenario]` table enforces)
    if matches!(kind, "two-jobs" | "placement" | "faults" | "campaigns") {
        mpi_dnn_train::ensure!(
            streams == 1 && depth == 0 && rpc_window == 0,
            "--streams/--depth/--rpc-window are not consumed by `scenario {kind}` — use \
             them with straggler | hetero | jitter | link-load | fault | campaign, or sweep \
             streams via `scenario overlap`"
        );
    }
    // `overlap` sweeps the allreduce stream count; the PS window knob
    // would ride along inert (the overlap table runs the Horovod family)
    if kind == "overlap" {
        mpi_dnn_train::ensure!(
            rpc_window == 0,
            "--rpc-window is not consumed by `scenario overlap` — the PS RPC window rides \
             straggler | hetero | jitter | link-load | fault"
        );
    }
    if kind == "placement" {
        let table = bench::placement_sweep(
            cluster,
            model,
            world,
            gpn_flag.unwrap_or(2),
            rails_flag.unwrap_or(2),
        )?;
        emit(&table, json);
        return Ok(());
    }
    if let Some(g) = gpn_flag {
        cluster.gpus_per_node = g;
    }
    if let Some(r) = rails_flag {
        cluster.nic_rails = r;
    }
    // each rank occupies one rail: more rails than ranks per node would
    // sit idle and silently change nothing but the execution path
    mpi_dnn_train::ensure!(
        cluster.nic_rails <= cluster.gpus_per_node,
        "--rails {} exceeds --gpus-per-node {}: the extra rails would be idle",
        cluster.nic_rails,
        cluster.gpus_per_node
    );

    // the campaign kinds run whole training campaigns, not single
    // iterations, and own their --trace/--report handling — they return
    // before the generic single-iteration trace trailer below
    if kind == "campaigns" {
        let table = bench::campaign_sweep(cluster, model, world, seed)?;
        emit(&table, json);
        return Ok(());
    }
    if kind == "campaign" {
        use mpi_dnn_train::sim::{run_campaign, CampaignSpec, CheckpointPolicy, TraceGuard};
        let cluster_name = cluster.name;
        let model_name = model.name.clone();
        let spec = CampaignSpec {
            iters: campaign_iters,
            mtbf_us: campaign_mtbf,
            seed,
            policy: CheckpointPolicy::parse(&campaign_ckpt, campaign_ckpt_period)?,
            ckpt_cost_us: campaign_ckpt_cost,
            repair_us: campaign_repair,
        };
        let sc = Scenario {
            streams,
            depth,
            rpc_window,
            fault: knobs.clone(),
            campaign: spec.clone(),
            ..Scenario::default()
        };
        sc.validate()?;
        let Some(name) = strategy_flag else {
            // no strategy picked: the all-strategies comparison table
            mpi_dnn_train::ensure!(
                trace_flag.is_none() && report_flag.is_none(),
                "--trace/--report need --strategy NAME (the campaign comparison table has \
                 no single run to export)"
            );
            let table = bench::campaign_compare(cluster, model, world, &sc)?;
            emit(&table, json);
            return Ok(());
        };
        let strat = strategies::by_name(&name)?;
        let ws = WorldSpec::new(cluster, model, world);
        let report = {
            let _t = trace_flag.as_ref().map(|_| TraceGuard::new());
            run_campaign(strat.as_ref(), &ws, &sc)?
        };
        let mut t = Table::new(
            &format!(
                "Campaign: {name} × {} iters ({model_name}, {cluster_name}@{world})",
                report.committed
            ),
            &["metric", "value"],
        );
        t.row(["iters committed / attempted / discarded".into(), format!(
            "{} / {} / {}",
            report.committed, report.attempted, report.discarded
        )]);
        t.row(["crashes / rejoins / suppressed".into(), format!(
            "{} / {} / {}",
            report.crashes, report.rejoins, report.suppressed
        )]);
        t.row(["checkpoints".into(), format!(
            "{} ({})",
            report.checkpoints,
            if report.checkpoint_interval_us > 0.0 {
                format!("every {:.0}us, {}", report.checkpoint_interval_us, spec.policy.name())
            } else {
                "off".to_string()
            }
        )]);
        t.row(["makespan".into(), format!("{}", report.makespan)]);
        t.row(["productive".into(), format!("{}", report.productive)]);
        t.row(["rollback lost".into(), format!("{}", report.rollback_lost)]);
        t.row(["recovery".into(), format!("{}", report.recovery)]);
        t.row(["rejoin rebuild".into(), format!("{}", report.rejoin_rebuild)]);
        t.row(["checkpoint overhead".into(), format!("{}", report.checkpoint_overhead)]);
        t.row(["goodput".into(), format!("{:.0} img/s", report.goodput_imgs_per_sec)]);
        t.row(["effective iters/s".into(), format!("{:.2}", report.effective_iters_per_sec)]);
        t.row(["fault-free".into(), format!("{:.0} img/s", report.fault_free_imgs_per_sec)]);
        t.row(["world min / changes".into(), format!(
            "{} / {}",
            report.min_world,
            report.world_timeline.len().saturating_sub(1)
        )]);
        emit(&t, json);
        if let Some(path) = trace_flag {
            let trace = report
                .trace
                .as_ref()
                .context("traced campaign attached no trace (tracer detached?)")?;
            std::fs::write(&path, &trace.chrome_json).context(format!("writing {path}"))?;
            println!("wrote {path} (representative campaign iteration)");
        }
        if let Some(path) = report_flag {
            let text = report.to_json().to_string() + "\n";
            std::fs::write(&path, text).context(format!("writing {path}"))?;
            println!("wrote {path} (CampaignReport JSON)");
        }
        return Ok(());
    }

    // cloned only when a traced re-run follows the table (the bench
    // calls consume `cluster`/`model`); the Scenario each arm records is
    // exactly the one its table ran
    let trace_env = trace_flag.as_ref().map(|_| (cluster.clone(), model.clone()));
    let mut traced_sc: Option<Scenario> = None;
    let table = match kind {
        "overlap" => {
            // sweep the stream-count knob itself (--streams = ceiling)
            mpi_dnn_train::ensure!(
                depth == 0,
                "--depth is not a sweep axis of `scenario overlap` (each point runs depth = \
                 streams)"
            );
            // trace the sweep's widest point — the one the table's last
            // row reports
            traced_sc = Some(Scenario { streams: streams.max(4), ..Scenario::default() });
            bench::overlap_sweep(cluster, model, world, streams.max(4))?
        }
        "straggler" => {
            let sc = Scenario {
                jitter_us: jitter,
                seed,
                streams,
                depth,
                rpc_window,
                ..Scenario::straggler(ranks, factor)
            };
            sc.validate()?;
            traced_sc = Some(sc.clone());
            bench::scenario_compare(
                &format!(
                    "Scenario: {ranks} straggler rank(s) × {factor}x ({}, {}@{world})",
                    model.name, cluster.name
                ),
                cluster,
                model,
                world,
                &sc,
            )?
        }
        "hetero" => {
            let sc = Scenario {
                jitter_us: jitter,
                seed,
                streams,
                depth,
                rpc_window,
                ..Scenario::hetero(ranks, factor)
            };
            sc.validate()?;
            traced_sc = Some(sc.clone());
            bench::scenario_compare(
                &format!(
                    "Scenario: {ranks} rank(s) on a {factor}x-slower GPU ({}, {}@{world})",
                    model.name, cluster.name
                ),
                cluster,
                model,
                world,
                &sc,
            )?
        }
        "jitter" => {
            // --jitter-us is the knob; default to a visible 250us bound
            let sc = Scenario {
                jitter_us: if jitter > 0.0 { jitter } else { 250.0 },
                seed,
                streams,
                depth,
                rpc_window,
                ..Scenario::default()
            };
            sc.validate()?;
            traced_sc = Some(sc.clone());
            bench::scenario_compare(
                &format!(
                    "Scenario: per-rank sync jitter ≤ {:.0}us ({}, {}@{world})",
                    sc.jitter_us, model.name, cluster.name
                ),
                cluster,
                model,
                world,
                &sc,
            )?
        }
        "link-load" => {
            let sc = Scenario { streams, depth, rpc_window, ..Scenario::link_loaded(load) };
            sc.validate()?;
            traced_sc = Some(sc.clone());
            bench::scenario_compare(
                &format!(
                    "Scenario: {:.0}% of the fabric taken by background traffic ({}, {}@{world})",
                    100.0 * load, model.name, cluster.name
                ),
                cluster,
                model,
                world,
                &sc,
            )?
        }
        "fault" => {
            let spec = fault_spec.as_deref().context(
                "scenario fault needs --fault \"crash@T:rN; flap@T:nN.lR+D; ...\" (see `list`)",
            )?;
            let fault = FaultPlan { events: FaultPlan::parse_spec(spec)?.events, ..knobs.clone() };
            let sc = Scenario { streams, depth, rpc_window, fault, ..Scenario::default() };
            sc.validate()?;
            traced_sc = Some(sc.clone());
            bench::fault_compare(cluster, model, world, &sc)?
        }
        "faults" => bench::fault_sweep(cluster, model, world, seed, &knobs)?,
        "two-jobs" => bench::scenario_two_jobs(cluster, model, world, offset, &family)?,
        other => mpi_dnn_train::bail!(
            "unknown scenario `{other}` (straggler | hetero | jitter | link-load | two-jobs | \
             placement | overlap | fault | faults | campaign | campaigns)"
        ),
    };
    emit(&table, json);
    if let Some(path) = trace_flag {
        let (tc, tm) = trace_env.expect("trace env cloned alongside --trace");
        let sc = traced_sc.expect("every traceable kind records its scenario");
        let ws = WorldSpec::new(tc, tm, world);
        let strat = strategies::by_name("horovod-mpi-opt")?;
        let report = {
            let _t = mpi_dnn_train::sim::TraceGuard::new();
            strat.iteration_in(&ws, &sc)?
        };
        let trace =
            report.trace.context("traced iteration attached no trace (tracer detached?)")?;
        std::fs::write(&path, &trace.chrome_json).context(format!("writing {path}"))?;
        println!("{}", trace.render());
        if let Some(f) = report.fault {
            println!(
                "fault: failed at {}, detected +{}, recovered +{} ({} retries), lost work {}, \
                 surviving world {}, goodput {:.0} img/s",
                f.failed_at,
                f.detect,
                f.recover,
                f.retries,
                f.lost_work,
                f.surviving_world,
                f.goodput_imgs_per_sec
            );
        }
        println!("wrote {path} (horovod-mpi-opt, the traced point of this scenario)");
    }
    Ok(())
}

/// Dump the per-rank execution timeline of one collective's `CommGraph`:
/// when each node of each rank started and finished, with optional
/// straggler/jitter perturbation to watch the skew cone propagate.  Runs
/// through the §Perf cached-template path (an immutable `GraphTemplate`
/// replayed under the scenario's overlay — the same code the strategies
/// execute), and rows sort by (rank, start, step) so dumps are
/// diff-stable across runs and display modes.
fn cmd_graph(args: &Args) -> Result<()> {
    use mpi_dnn_train::comm::allreduce::{shadow_steps, Algo};
    use mpi_dnn_train::comm::graph::{allreduce_graph_placed, GraphResources, GraphTemplate};
    use mpi_dnn_train::comm::CommSchedule;
    use mpi_dnn_train::sim::Engine;
    use mpi_dnn_train::strategies::Scenario;

    let ranks = args.get_usize("ranks", 8).map_err(Error::msg)?;
    let bytes = parse_bytes(&args.get_or("size", "4MB")).map_err(Error::msg)?;
    let mut cluster = presets::by_name(&args.get_or("cluster", "ri2"))?;
    let flavor = parse_flavor(&args.get_or("flavor", "mvapich2"))?;
    let algo_flag = args.get_or("algo", "auto");
    let straggler = args.get_usize("straggler", 0).map_err(Error::msg)?;
    let factor = args.get_f64("factor", 1.5).map_err(Error::msg)?;
    let jitter = args.get_f64("jitter-us", 0.0).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 0).map_err(Error::msg)? as u64;
    let gpus_per_node =
        args.get_usize("gpus-per-node", cluster.gpus_per_node).map_err(Error::msg)?;
    let rails = args.get_usize("rails", cluster.nic_rails).map_err(Error::msg)?;
    let json = args.get_bool("json");
    let trace_path = args.get("trace").map(String::from);
    args.reject_unknown().map_err(Error::msg)?;
    mpi_dnn_train::ensure!(ranks >= 2, "--ranks must be at least 2");
    mpi_dnn_train::ensure!(gpus_per_node >= 1, "--gpus-per-node must be >= 1");
    mpi_dnn_train::ensure!(rails >= 1, "--rails must be >= 1");
    mpi_dnn_train::ensure!(
        rails <= gpus_per_node,
        "--rails {rails} exceeds --gpus-per-node {gpus_per_node}: the extra rails would be idle"
    );
    mpi_dnn_train::ensure!(
        straggler == 0 || (factor.is_finite() && factor > 1.0),
        "--factor must be > 1.0 when --straggler is set, got {factor}"
    );
    cluster.gpus_per_node = gpus_per_node;
    cluster.nic_rails = rails;
    let place = cluster.placement();

    let w = MpiWorld::new(flavor, cluster.clone());
    let (planned, mut ctx) = w.plan(bytes);
    let algo = match algo_flag.as_str() {
        "auto" => planned,
        "ring" => Algo::Ring,
        "rhd" => Algo::Rhd,
        "tree" => Algo::Tree,
        other => mpi_dnn_train::bail!("--algo must be auto|ring|rhd|tree, got `{other}`"),
    };
    ctx.wire.beta_gbs /= cluster.fabric.contention_factor(ranks);
    let (report, steps) = shadow_steps(algo, ranks, (bytes / 4).max(1), &mut ctx);
    let serial_us = CommSchedule::from_steps(&steps).total_us();

    let template = GraphTemplate::new(allreduce_graph_placed(
        algo,
        ranks,
        &steps,
        place,
        cluster.fabric.local_hop_factor(),
    ));
    let sc = Scenario {
        straggler_ranks: straggler,
        straggler_factor: factor,
        jitter_us: jitter,
        seed,
        ..Scenario::default()
    };
    let overlay = sc.overlay(ranks, 0);

    // enabling must precede `Engine::new` — that is where the tracer
    // attaches; the guard stays alive for the whole (single-engine) run
    let _trace_guard = trace_path.as_ref().map(|_| mpi_dnn_train::sim::TraceGuard::new());
    let mut e = Engine::new();
    let res = GraphResources::install_placed(&mut e, ranks, place);
    let run = template.execute(&mut e, res.mapper(), &overlay, Box::new(|_| {}));
    let end = e.run();
    let run = run.borrow();
    let g = template.graph();

    let title = format!(
        "CommGraph timeline: {:?} allreduce of {} across {ranks} ranks ({}, {})",
        algo,
        fmt_bytes(bytes),
        cluster.name,
        flavor.name()
    );
    // per-rank timelines, one row per node, sorted by (rank, start, step)
    // — stable under both perturbation and display width
    let mut order: Vec<usize> = (0..g.len()).collect();
    order.sort_by_key(|&i| (g.nodes[i].rank, run.start[i], g.nodes[i].step));
    let mut table = if ranks <= 16 {
        let mut t = Table::new(&title, &["rank", "step", "start", "finish"]);
        for &i in &order {
            t.row([
                format!("r{}", g.nodes[i].rank),
                g.nodes[i].step.to_string(),
                format!("{:.1}", run.start[i].as_us()),
                format!("{:.1}", run.finish[i].as_us()),
            ]);
        }
        t
    } else {
        // wide worlds: per-rank summary (one row per rank, rank-sorted)
        let mut t = Table::new(&title, &["rank", "nodes", "first start", "last finish"]);
        for r in 0..ranks {
            let ids: Vec<usize> = (0..g.len()).filter(|&i| g.nodes[i].rank == r).collect();
            let first = ids.iter().map(|&i| run.start[i]).min().unwrap_or_default();
            let last = ids.iter().map(|&i| run.finish[i]).max().unwrap_or_default();
            t.row([
                r.to_string(),
                ids.len().to_string(),
                format!("{:.1}", first.as_us()),
                format!("{:.1}", last.as_us()),
            ]);
        }
        t
    };
    table.note(format!(
        "{} nodes, {} algorithm steps; completion {:.1}us vs serialized critical-path {serial_us:.1}us \
         (equal when unperturbed); cost-model total {:.1}us",
        g.len(),
        report.steps,
        end.as_us(),
        report.time.as_us()
    ));
    if sc.per_rank_skew() {
        table.note(format!(
            "perturbed: {straggler} straggler rank(s) ×{factor}, jitter ≤{jitter}us (seed {seed}) — \
             deterministic, same seed ⇒ same timeline (cached-template replay)"
        ));
    }
    if !place.is_trivial() {
        table.note(format!(
            "placement: {gpus_per_node} GPU(s)/node × {rails} NIC rail(s) — co-located ranks \
             share their node's NIC port(s) and PCIe link; intra-node hops ride PCIe at \
             {:.2}x the wire time",
            cluster.fabric.local_hop_factor()
        ));
    }
    emit(&table, json);
    if let Some(path) = &trace_path {
        use mpi_dnn_train::sim::IterationParts;
        let t = e.take_trace().context("tracer detached despite --trace")?;
        let report = t.into_report(&e, IterationParts::comm_only(end));
        std::fs::write(path, &report.chrome_json).context(format!("writing {path}"))?;
        println!("{}", report.render());
        println!("wrote {path}");
    }
    Ok(())
}

/// §Observability driver: run ONE traced iteration of a strategy —
/// span tracer attached, everything else identical to the untraced run —
/// and print the attribution report (per-resource busy/queue-wait,
/// critical-path buckets, exposed-vs-overlapped comm); `--out FILE`
/// additionally writes the Chrome trace-event JSON.  `trace validate
/// FILE` re-reads an exported file and checks it against the schema the
/// importers rely on (sorted timestamps, non-overlapping serialized
/// spans, well-formed events).
fn cmd_trace(args: &Args) -> Result<()> {
    use mpi_dnn_train::sim::{trace::validate_chrome_json, TraceGuard};
    use mpi_dnn_train::strategies::Scenario;

    if args.positional.first().map(String::as_str) == Some("validate") {
        let path = args.positional.get(1).context("usage: trace validate <FILE>")?.clone();
        args.reject_unknown().map_err(Error::msg)?;
        let text = std::fs::read_to_string(&path).context(format!("reading {path}"))?;
        let events = validate_chrome_json(&text)?;
        println!(
            "{path}: valid {} trace, {events} events",
            mpi_dnn_train::sim::trace::TRACE_SCHEMA
        );
        return Ok(());
    }

    let strat_name = args.get_or("strategy", "horovod-mpi-opt");
    let mut cluster = presets::by_name(&args.get_or("cluster", "ri2"))?;
    let world = args.get_usize("world", 8).map_err(Error::msg)?;
    let model = mpi_dnn_train::models::by_name(&args.get_or("model", "resnet50"))?;
    let streams = args.get_usize("streams", 2).map_err(Error::msg)?;
    let depth = args.get_usize("depth", 0).map_err(Error::msg)?;
    let gpus_per_node =
        args.get_usize("gpus-per-node", cluster.gpus_per_node).map_err(Error::msg)?;
    let rails = args.get_usize("rails", cluster.nic_rails).map_err(Error::msg)?;
    let straggler = args.get_usize("straggler", 0).map_err(Error::msg)?;
    let factor = args.get_f64("factor", 1.5).map_err(Error::msg)?;
    let jitter = args.get_f64("jitter-us", 0.0).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 0).map_err(Error::msg)? as u64;
    let rpc_window = args.get_usize("rpc-window", 0).map_err(Error::msg)?;
    let out = args.get("out").map(String::from);
    args.reject_unknown().map_err(Error::msg)?;
    mpi_dnn_train::ensure!(world >= 2, "--world must be at least 2");
    mpi_dnn_train::ensure!(streams >= 1, "--streams must be >= 1, got {streams}");
    mpi_dnn_train::ensure!(gpus_per_node >= 1, "--gpus-per-node must be >= 1");
    mpi_dnn_train::ensure!(
        rails >= 1 && rails <= gpus_per_node,
        "--rails must be in 1..=--gpus-per-node, got {rails}"
    );
    mpi_dnn_train::ensure!(
        straggler == 0 || (factor.is_finite() && factor > 1.0),
        "--factor must be > 1.0 when --straggler is set, got {factor}"
    );
    if depth > 0 {
        mpi_dnn_train::ensure!(
            streams > 1 && depth <= streams,
            "--depth requires --streams > 1 and depth <= streams"
        );
    }
    cluster.gpus_per_node = gpus_per_node;
    cluster.nic_rails = rails;
    let sc = Scenario {
        straggler_ranks: straggler,
        straggler_factor: factor,
        jitter_us: jitter,
        seed,
        streams,
        depth,
        rpc_window,
        ..Scenario::default()
    };
    let ws = WorldSpec::new(cluster, model, world);
    let strat = strategies::by_name(&strat_name)?;
    let report = {
        let _t = TraceGuard::new();
        strat.iteration_in(&ws, &sc)?
    };
    let trace = report.trace.context(format!("strategy `{strat_name}` attached no trace"))?;
    println!("{}", trace.render());
    if let Some(out) = out {
        std::fs::write(&out, &trace.chrome_json).context(format!("writing {out}"))?;
        println!("wrote {out} (load in chrome://tracing or ui.perfetto.dev)");
    }
    Ok(())
}

/// §Perf harness: time representative simulator workloads and write
/// `BENCH_engine.json` (events/s + wall-ms + §Scale peak-memory per
/// workload) — the repo's engine-throughput trajectory.  The positional
/// `scale-sweep` runs the 256 → 16k-rank fleet sweep instead of the
/// standard workload set.  The v2 document keeps one section per
/// (workload set × sizing) mode and `--out` merges into an existing
/// file, so a quick smoke run never clobbers a full or scale baseline.
/// `--check BASELINE` reports deterministic event-count drift and gates
/// on events/s regression *bands* (`--band`, default 0.25 × baseline);
/// refresh a baseline by re-running in the same mode and committing.
fn cmd_perf(args: &Args) -> Result<()> {
    let quick = args.get_bool("quick");
    let json = args.get_bool("json");
    let out = args.get_or("out", "BENCH_engine.json");
    let check = args.get("check").map(String::from);
    let band = args.get_f64("band", bench::perf::DEFAULT_BAND).map_err(Error::msg)?;
    let which = args.positional.first().map(String::as_str).unwrap_or("standard");
    args.reject_unknown().map_err(Error::msg)?;

    let (workloads, scale) = match which {
        "standard" => (bench::perf::run_perf(quick)?, false),
        "scale-sweep" => (bench::perf::run_scale_sweep(quick)?, true),
        other => {
            mpi_dnn_train::bail!("unknown perf workload set `{other}` (standard|scale-sweep)")
        }
    };
    let mode = bench::perf::bench_mode(scale, quick);
    let table = bench::perf::perf_table(&workloads, quick);
    emit(&table, json);
    let existing = std::fs::read_to_string(&out)
        .ok()
        .and_then(|t| mpi_dnn_train::util::json::Json::parse(&t).ok());
    let payload =
        bench::perf::merge_bench(existing.as_ref(), &workloads, mode).to_string() + "\n";
    std::fs::write(&out, payload).context(format!("writing {out}"))?;
    println!("wrote {out} ({mode} section)");
    if let Some(baseline) = check {
        let report =
            bench::perf::check_against(&workloads, mode, std::path::Path::new(&baseline), band)?;
        println!("{report}");
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    args.reject_unknown().map_err(Error::msg)?;
    // 1. artifacts present?
    let dir = runtime::artifacts_dir()?;
    println!("artifacts dir: {}", dir.display());
    for cfg in ["tiny", "small", "medium", "large"] {
        println!(
            "  config {cfg:<7} {}",
            if runtime::config_available(&dir, cfg) { "present" } else { "missing" }
        );
    }
    // 2. allreduce numerics vs serial oracle across every flavor
    use mpi_dnn_train::comm::allreduce::{max_abs_err, serial_oracle};
    let mut rng = mpi_dnn_train::util::prng::Rng::new(1);
    for flavor in [
        MpiFlavor::Mvapich2,
        MpiFlavor::Mvapich2GdrOpt,
        MpiFlavor::CrayMpich,
        MpiFlavor::Mpich,
    ] {
        let w = MpiWorld::new(flavor, presets::ri2());
        let mut bufs: Vec<Vec<f32>> = (0..16).map(|_| rng.f32_vec(10_000)).collect();
        let oracle = serial_oracle(&bufs);
        w.allreduce(&mut bufs);
        let err = max_abs_err(&bufs, &oracle);
        println!("  allreduce {:<18} max err {err:.2e}", w.flavor.name());
        mpi_dnn_train::ensure!(err < 1e-3, "{} numerics off", w.flavor.name());
    }
    // 3. PJRT round trip on the tiny model
    if runtime::config_available(&dir, "tiny") {
        let client = mpi_dnn_train::runtime::client::shared()?;
        let step = runtime::TrainStep::load(&client, &dir, "tiny")?;
        let params = step.meta.load_params(&dir)?;
        let tokens = rng.tokens(step.meta.tokens_len(), step.meta.vocab as u32);
        let (loss, grads) = step.run(&params, &tokens)?;
        println!("  pjrt train_step(tiny): loss {loss:.3}, |g| {} elems", grads.len());
        mpi_dnn_train::ensure!(loss.is_finite());
    } else {
        println!("  (tiny artifacts missing — PJRT smoke skipped; run `make artifacts`)");
    }
    println!("validate: OK");
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    args.reject_unknown().map_err(Error::msg)?;
    println!("clusters:");
    for c in presets::all() {
        println!(
            "  {:<10} {} × {}  fabric {} (verbs: {}, gdr: {})",
            c.name,
            c.nodes,
            c.gpu.name,
            c.fabric.inter.name,
            c.fabric.ib_verbs,
            c.fabric.gdr
        );
    }
    println!("models: resnet50, mobilenet, nasnet (+ transformer via train --config)");
    println!(
        "strategies: grpc, grpc+mpi, grpc+verbs, rdma, baidu, horovod-mpi, horovod-nccl, \
         horovod-mpi-opt, horovod-cray"
    );
    println!("mpi flavors: mvapich2, mvapich2-gdr-opt, cray-mpich, mpich");
    println!(
        "scenarios: straggler, hetero, jitter, link-load, two-jobs [--family horovod|baidu|ps], \
         placement, overlap, fault, faults, campaign, campaigns (see `scenario --help` flags)"
    );
    println!(
        "faults: `scenario fault --fault SPEC` injects a schedule — SPEC is `;`-separated \
         events: crash@T:rN (rank N dies at T us), die@T:rNxF (straggler ×F then dies), \
         flap@T:nN.lR+D (port node N rail R dark D us), raildown@T:nN.lR (rail failover); \
         knobs: --fault-timeout-us --fault-backoff-us --fault-backoff-factor --fault-retries \
         --rebuild-us --checkpoint-us; `scenario faults` sweeps seeded crashes over rate × world"
    );
    println!(
        "campaigns: `scenario campaign` runs a sustained-failure training campaign — knobs: \
         --campaign-iters N --campaign-mtbf-us M (per-rank MTBF, Poisson crash stream) \
         --campaign-ckpt off|fixed|young-daly --campaign-ckpt-period-us P (fixed) \
         --campaign-ckpt-cost-us C --campaign-repair-us R; add --strategy S for one run \
         (--trace/--report export it), omit for the all-strategies table; `scenario \
         campaigns` sweeps policy × failure rate from --seed; `train --campaign-*` rehearses \
         the campaign on the training cluster; experiment tomls take [scenario.campaign]"
    );
    println!(
        "overlap: every scenario accepts --streams N --depth D (N > 1 interleaves fusion \
         buffers across comm streams, NCCL-stream semantics; `scenario overlap` sweeps N)"
    );
    println!(
        "transports: the PS family (grpc, grpc+mpi, grpc+verbs, rdma) accepts --rpc-window W \
         on scenario straggler|hetero|jitter|link-load|fault and on trace — cap in-flight \
         shard RPCs per worker (0 = unbounded, the serialized reference)"
    );
    println!(
        "placement: every scenario/graph accepts --gpus-per-node N --rails R (dense nodes \
         share a NIC/PCIe bundle; rails split the node NIC; intra-node hops ride PCIe)"
    );
    println!("graph: per-rank CommGraph timelines (--algo auto|ring|rhd|tree, --straggler, --jitter-us)");
    println!(
        "trace: deterministic span tracing — `trace [--strategy S] [--out FILE]` runs one \
         traced iteration (attribution report + Chrome JSON); `trace validate FILE` checks an \
         export; scenario/graph/train accept --trace FILE"
    );
    println!(
        "perf: engine/graph-replay/sweep throughput harness (--quick; writes BENCH_engine.json; \
         `perf scale-sweep` runs the §Scale 256→16k-rank fleet sweep)"
    );
    Ok(())
}

fn parse_flavor(s: &str) -> Result<MpiFlavor> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "mvapich2" => MpiFlavor::Mvapich2,
        "mvapich2-gdr-opt" | "opt" | "mpi-opt" => MpiFlavor::Mvapich2GdrOpt,
        "cray-mpich" | "cray" => MpiFlavor::CrayMpich,
        "mpich" => MpiFlavor::Mpich,
        other => mpi_dnn_train::bail!("unknown flavor `{other}`"),
    })
}
