//! Minimal TOML parser: tables, key = value with strings / integers /
//! floats / booleans / flat arrays, `#` comments.  Covers everything the
//! experiment configs use; nested tables-of-tables and datetimes are out
//! of scope (and rejected loudly rather than misparsed).

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// table name ("" for the root) → key → value.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

pub fn parse_toml(src: &str) -> Result<TomlDoc, String> {
    let mut doc: TomlDoc = BTreeMap::new();
    doc.insert(String::new(), BTreeMap::new());
    let mut table = String::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated table header", lineno + 1))?;
            if name.starts_with('[') {
                return Err(format!("line {}: array-of-tables not supported", lineno + 1));
            }
            table = name.trim().to_string();
            doc.entry(table.clone()).or_default();
        } else if let Some((k, v)) = line.split_once('=') {
            let value = parse_value(v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.get_mut(&table).unwrap().insert(k.trim().to_string(), value);
        } else {
            return Err(format!("line {}: expected `key = value` or `[table]`", lineno + 1));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

/// Split on commas that are not inside quotes (flat arrays only).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_experiment_config_shape() {
        let doc = parse_toml(
            r#"
# figure 9 sweep
name = "pizdaint"

[workload]
model = "resnet50"      # batch from model default
gpus = [1, 2, 4, 8]
batch = 64

[comm]
fusion_mb = 64.5
nccl = false
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"], TomlValue::Str("pizdaint".into()));
        assert_eq!(doc["workload"]["model"].as_str(), Some("resnet50"));
        assert_eq!(doc["workload"]["gpus"].as_array().unwrap().len(), 4);
        assert_eq!(doc["workload"]["batch"].as_int(), Some(64));
        assert!((doc["comm"]["fusion_mb"].as_float().unwrap() - 64.5).abs() < 1e-9);
        assert_eq!(doc["comm"]["nccl"].as_bool(), Some(false));
    }

    #[test]
    fn strings_with_hash_and_escapes() {
        let doc = parse_toml(r#"k = "a # not comment \" quote""#).unwrap();
        assert_eq!(doc[""]["k"].as_str(), Some(r#"a # not comment " quote"#));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_toml("just words").is_err());
        assert!(parse_toml("[unterminated").is_err());
        assert!(parse_toml("k = ").is_err());
        assert!(parse_toml("[[aot]]").is_err());
    }

    #[test]
    fn numbers_with_underscores() {
        let doc = parse_toml("n = 1_000_000").unwrap();
        assert_eq!(doc[""]["n"].as_int(), Some(1_000_000));
    }

    #[test]
    fn empty_array() {
        let doc = parse_toml("a = []").unwrap();
        assert_eq!(doc[""]["a"].as_array().unwrap().len(), 0);
    }
}
