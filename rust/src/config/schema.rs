//! Typed experiment configuration loaded from a TOML file — the "config
//! system + launcher" surface of the framework (README quickstart).

use std::path::Path;

use anyhow::{Context, Result};

use super::toml_lite::{parse_toml, TomlDoc};
use crate::cluster::{presets, ClusterSpec};
use crate::models::{self, ModelProfile};

/// One experiment: a cluster, a workload, a strategy set and a GPU sweep.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub cluster: ClusterSpec,
    pub model: ModelProfile,
    pub gpus: Vec<usize>,
    pub batch_per_gpu: usize,
    pub strategies: Vec<String>,
    /// Horovod fusion threshold override, bytes (0 = default).
    pub fusion_bytes: usize,
    pub json_output: bool,
}

impl ExperimentConfig {
    pub fn from_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = parse_toml(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        ExperimentConfig::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<ExperimentConfig> {
        let root = doc.get("").context("missing root table")?;
        let name = root
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("experiment")
            .to_string();

        let wl = doc.get("workload").context("missing [workload] table")?;
        let cluster = presets::by_name(
            wl.get("cluster").and_then(|v| v.as_str()).unwrap_or("ri2"),
        )?;
        let model =
            models::by_name(wl.get("model").and_then(|v| v.as_str()).unwrap_or("resnet50"))?;
        let gpus: Vec<usize> = wl
            .get("gpus")
            .and_then(|v| v.as_array())
            .map(|a| a.iter().filter_map(|x| x.as_int()).map(|i| i as usize).collect())
            .unwrap_or_else(|| vec![1, 2, 4, 8, 16]);
        anyhow::ensure!(!gpus.is_empty(), "empty gpu sweep");
        for &g in &gpus {
            cluster.check_world(g)?;
        }
        let batch_per_gpu = wl
            .get("batch")
            .and_then(|v| v.as_int())
            .map(|b| b as usize)
            .unwrap_or(model.default_batch);

        let comm = doc.get("comm").cloned().unwrap_or_default();
        let strategies = comm
            .get("strategies")
            .and_then(|v| v.as_array())
            .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
            .unwrap_or_else(|| vec!["horovod-mpi".into(), "horovod-mpi-opt".into()]);
        for s in &strategies {
            crate::strategies::by_name(s)?; // validate early
        }
        let fusion_bytes = comm
            .get("fusion_mb")
            .and_then(|v| v.as_float())
            .map(|mb| (mb * 1024.0 * 1024.0) as usize)
            .unwrap_or(0);

        Ok(ExperimentConfig {
            name,
            cluster,
            model,
            gpus,
            batch_per_gpu,
            strategies,
            fusion_bytes,
            json_output: root.get("json").and_then(|v| v.as_bool()).unwrap_or(false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<ExperimentConfig> {
        ExperimentConfig::from_doc(&parse_toml(s).unwrap())
    }

    #[test]
    fn full_config_roundtrip() {
        let c = parse(
            r#"
name = "fig9-resnet"
json = true

[workload]
cluster = "pizdaint"
model = "resnet50"
gpus = [1, 32, 128]
batch = 64

[comm]
strategies = ["grpc", "horovod-cray"]
fusion_mb = 32.0
"#,
        )
        .unwrap();
        assert_eq!(c.name, "fig9-resnet");
        assert_eq!(c.cluster.name, "PizDaint");
        assert_eq!(c.gpus, vec![1, 32, 128]);
        assert_eq!(c.batch_per_gpu, 64);
        assert_eq!(c.strategies.len(), 2);
        assert_eq!(c.fusion_bytes, 32 << 20);
        assert!(c.json_output);
    }

    #[test]
    fn defaults_fill_in() {
        let c = parse("[workload]\nmodel = \"mobilenet\"").unwrap();
        assert_eq!(c.cluster.name, "RI2");
        assert_eq!(c.batch_per_gpu, 64);
        assert!(!c.strategies.is_empty());
    }

    #[test]
    fn rejects_bad_strategy_and_oversized_world() {
        assert!(parse("[workload]\ngpus = [100000]").is_err());
        assert!(
            parse("[workload]\nmodel=\"resnet50\"\n[comm]\nstrategies=[\"bogus\"]").is_err()
        );
    }
}
