//! Typed experiment configuration loaded from a TOML file — the "config
//! system + launcher" surface of the framework (README quickstart).

use std::path::Path;

use crate::util::error::{Context, Result};

use super::toml_lite::{parse_toml, TomlDoc};
use crate::cluster::{presets, ClusterSpec};
use crate::models::{self, ModelProfile};
use crate::sim::{CampaignSpec, CheckpointPolicy, FaultPlan};
use crate::strategies::Scenario;

/// One experiment: a cluster, a workload, a strategy set and a GPU sweep.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub cluster: ClusterSpec,
    pub model: ModelProfile,
    pub gpus: Vec<usize>,
    pub batch_per_gpu: usize,
    pub strategies: Vec<String>,
    /// Horovod fusion threshold override, bytes (0 = default).
    pub fusion_bytes: usize,
    /// Optional `[scenario]` perturbations (stragglers, hetero mixes,
    /// jitter, fabric load) applied to every sweep point.
    pub scenario: Scenario,
    pub json_output: bool,
}

impl ExperimentConfig {
    pub fn from_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = parse_toml(&text).map_err(|e| crate::anyhow!("{}: {e}", path.display()))?;
        ExperimentConfig::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<ExperimentConfig> {
        let root = doc.get("").context("missing root table")?;
        let name = root
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("experiment")
            .to_string();

        let wl = doc.get("workload").context("missing [workload] table")?;
        let mut cluster = presets::by_name(
            wl.get("cluster").and_then(|v| v.as_str()).unwrap_or("ri2"),
        )?;
        let model =
            models::by_name(wl.get("model").and_then(|v| v.as_str()).unwrap_or("resnet50"))?;
        let gpus: Vec<usize> = wl
            .get("gpus")
            .and_then(|v| v.as_array())
            .map(|a| a.iter().filter_map(|x| x.as_int()).map(|i| i as usize).collect())
            .unwrap_or_else(|| vec![1, 2, 4, 8, 16]);
        crate::ensure!(!gpus.is_empty(), "empty gpu sweep");
        let batch_per_gpu = wl
            .get("batch")
            .and_then(|v| v.as_int())
            .map(|b| b as usize)
            .unwrap_or(model.default_batch);

        let comm = doc.get("comm").cloned().unwrap_or_default();
        let strategies = comm
            .get("strategies")
            .and_then(|v| v.as_array())
            .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
            .unwrap_or_else(|| vec!["horovod-mpi".into(), "horovod-mpi-opt".into()]);
        for s in &strategies {
            crate::strategies::by_name(s)?; // validate early
        }
        let fusion_bytes = comm
            .get("fusion_mb")
            .and_then(|v| v.as_float())
            .map(|mb| (mb * 1024.0 * 1024.0) as usize)
            .unwrap_or(0);

        let mut scenario = Scenario::default();
        if let Some(sc) = doc.get("scenario") {
            let f = |key: &str, default: f64| {
                sc.get(key).and_then(|v| v.as_float()).unwrap_or(default)
            };
            let n = |key: &str| {
                sc.get(key).and_then(|v| v.as_int()).map(|i| i as usize).unwrap_or(0)
            };
            scenario = Scenario {
                straggler_ranks: n("straggler_ranks"),
                straggler_factor: f("straggler_factor", 1.0),
                hetero_ranks: n("hetero_ranks"),
                hetero_factor: f("hetero_factor", 1.0),
                jitter_us: f("jitter_us", 0.0),
                seed: sc.get("seed").and_then(|v| v.as_int()).unwrap_or(0) as u64,
                link_load: f("link_load", 0.0),
                second_job: sc.get("second_job").and_then(|v| v.as_bool()).unwrap_or(false),
                second_job_offset_us: f("second_job_offset_us", 0.0),
                // validated below BEFORE the usize cast: a negative TOML
                // int must be a friendly config error, not a wrapped
                // 2^64-lane allocation
                streams: 1,
                depth: 0,
                rpc_window: 0,
                fault: FaultPlan::default(),
                campaign: CampaignSpec::default(),
                rejoin_rebuild_us: 0.0,
            };
            // §Overlap knobs — raw negative-int checks must run BEFORE
            // the usize casts; every shared range/consistency rule runs
            // once in `Scenario::validate` below
            let streams_raw = sc.get("streams").and_then(|v| v.as_int()).unwrap_or(1);
            crate::ensure!(
                streams_raw >= 1,
                "[scenario] streams must be >= 1, got {streams_raw}"
            );
            scenario.streams = streams_raw as usize;
            let depth_raw = sc.get("depth").and_then(|v| v.as_int()).unwrap_or(0);
            crate::ensure!(depth_raw >= 0, "[scenario] depth must be >= 0, got {depth_raw}");
            scenario.depth = depth_raw as usize;
            // §Transports knob: the PS family's per-worker RPC window
            // (0 = unbounded — the serialized reference schedule)
            let window_raw = sc.get("rpc_window").and_then(|v| v.as_int()).unwrap_or(0);
            crate::ensure!(
                window_raw >= 0,
                "[scenario] rpc_window must be >= 0, got {window_raw}"
            );
            scenario.rpc_window = window_raw as usize;
            // placement keys ride the [scenario] table: they reshape the
            // cluster the whole sweep runs on — dense nodes colocate
            // ranks on shared NIC/PCIe bundles, rails split the node NIC
            // (graph-path execution; serialized replay cannot express it)
            for (key, slot) in [
                ("gpus_per_node", &mut cluster.gpus_per_node),
                ("rails", &mut cluster.nic_rails),
            ] {
                if let Some(v) = sc.get(key).and_then(|v| v.as_int()) {
                    crate::ensure!(v >= 1, "[scenario] {key} must be >= 1, got {v}");
                    *slot = v as usize;
                }
            }
            // each rank occupies one rail: more rails than ranks per
            // node would sit idle — an inert knob is a config mistake
            crate::ensure!(
                cluster.nic_rails <= cluster.gpus_per_node,
                "[scenario] rails = {} exceeds gpus_per_node = {}: the extra rails would be idle",
                cluster.nic_rails,
                cluster.gpus_per_node
            );
        }
        // optional [scenario.fault] table (§Robustness): the injected
        // failure schedule (CLI spec grammar) plus detection/recovery
        // knobs — parse before the shared validation pass
        if let Some(ft) = doc.get("scenario.fault") {
            if let Some(events) = ft.get("events").and_then(|v| v.as_array()) {
                let specs: Vec<&str> = events.iter().filter_map(|x| x.as_str()).collect();
                crate::ensure!(
                    specs.len() == events.len(),
                    "[scenario.fault] events must be spec strings \
                     (crash@T:rN | die@T:rNxF | flap@T:nN.lR+D | raildown@T:nN.lR)"
                );
                if !specs.is_empty() {
                    scenario.fault = FaultPlan::parse_spec(&specs.join(";"))?;
                }
            }
            let f = |key: &str, or: f64| ft.get(key).and_then(|v| v.as_float()).unwrap_or(or);
            scenario.fault.detect_timeout_us =
                f("detect_timeout_us", scenario.fault.detect_timeout_us);
            scenario.fault.backoff_base_us = f("backoff_base_us", scenario.fault.backoff_base_us);
            scenario.fault.backoff_factor = f("backoff_factor", scenario.fault.backoff_factor);
            scenario.fault.rebuild_us = f("rebuild_us", scenario.fault.rebuild_us);
            scenario.fault.checkpoint_period_us =
                f("checkpoint_period_us", scenario.fault.checkpoint_period_us);
            if let Some(r) = ft.get("max_retries").and_then(|v| v.as_int()) {
                crate::ensure!(r >= 0, "[scenario.fault] max_retries must be >= 0, got {r}");
                scenario.fault.max_retries = r as u32;
            }
        }
        // optional [scenario.campaign] table (§Robustness campaign): a
        // sustained-failure training campaign — N iterations under a
        // seeded MTBF crash stream with a checkpoint policy and elastic
        // rejoin.  Raw negative-int checks run before the casts; the
        // shared range/consistency rules run in `Scenario::validate`.
        if let Some(ct) = doc.get("scenario.campaign") {
            let f = |key: &str, or: f64| ct.get(key).and_then(|v| v.as_float()).unwrap_or(or);
            let iters_raw = ct.get("iters").and_then(|v| v.as_int()).unwrap_or(0);
            crate::ensure!(
                iters_raw >= 0,
                "[scenario.campaign] iters must be >= 0, got {iters_raw}"
            );
            scenario.campaign.iters = iters_raw as usize;
            scenario.campaign.mtbf_us = f("mtbf_us", 0.0);
            scenario.campaign.seed =
                ct.get("seed").and_then(|v| v.as_int()).unwrap_or(0) as u64;
            scenario.campaign.ckpt_cost_us = f("ckpt_cost_us", 0.0);
            scenario.campaign.repair_us = f("repair_us", 0.0);
            let policy =
                ct.get("ckpt").and_then(|v| v.as_str()).unwrap_or("off").to_string();
            scenario.campaign.policy =
                CheckpointPolicy::parse(&policy, f("ckpt_period_us", 0.0))?;
        }
        // one shared validation pass — the same `Scenario::validate` the
        // CLI flags and the bench sweeps run (§Robustness satellite)
        scenario.validate()?;
        // worlds validate against the (possibly densified) machine
        for &g in &gpus {
            cluster.check_world(g)?;
        }

        Ok(ExperimentConfig {
            name,
            cluster,
            model,
            gpus,
            batch_per_gpu,
            strategies,
            fusion_bytes,
            scenario,
            json_output: root.get("json").and_then(|v| v.as_bool()).unwrap_or(false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<ExperimentConfig> {
        ExperimentConfig::from_doc(&parse_toml(s).unwrap())
    }

    #[test]
    fn full_config_roundtrip() {
        let c = parse(
            r#"
name = "fig9-resnet"
json = true

[workload]
cluster = "pizdaint"
model = "resnet50"
gpus = [1, 32, 128]
batch = 64

[comm]
strategies = ["grpc", "horovod-cray"]
fusion_mb = 32.0
"#,
        )
        .unwrap();
        assert_eq!(c.name, "fig9-resnet");
        assert_eq!(c.cluster.name, "PizDaint");
        assert_eq!(c.gpus, vec![1, 32, 128]);
        assert_eq!(c.batch_per_gpu, 64);
        assert_eq!(c.strategies.len(), 2);
        assert_eq!(c.fusion_bytes, 32 << 20);
        assert!(c.json_output);
        assert!(c.scenario.is_neutral());
    }

    #[test]
    fn scenario_table_parses() {
        let c = parse(
            r#"
[workload]
model = "resnet50"

[scenario]
straggler_ranks = 2
straggler_factor = 1.8
jitter_us = 250.0
link_load = 0.25
seed = 9
"#,
        )
        .unwrap();
        assert_eq!(c.scenario.straggler_ranks, 2);
        assert!((c.scenario.straggler_factor - 1.8).abs() < 1e-12);
        assert!((c.scenario.jitter_us - 250.0).abs() < 1e-12);
        assert!((c.scenario.link_load - 0.25).abs() < 1e-12);
        assert_eq!(c.scenario.seed, 9);
        assert!(!c.scenario.is_neutral());
        assert!(parse("[workload]\n[scenario]\nlink_load = 1.5").is_err());
    }

    #[test]
    fn scenario_second_job_parses_and_validates() {
        let c = parse(
            r#"
[workload]
model = "resnet50"

[scenario]
second_job = true
second_job_offset_us = 500.0
"#,
        )
        .unwrap();
        assert!(c.scenario.second_job);
        assert!((c.scenario.second_job_offset_us - 500.0).abs() < 1e-12);
        assert!(!c.scenario.is_neutral());
        // an offset without the job is a config mistake, not a no-op
        assert!(parse("[workload]\n[scenario]\nsecond_job_offset_us = 10.0").is_err());
    }

    #[test]
    fn scenario_placement_keys_reshape_the_cluster() {
        let c = parse(
            r#"
[workload]
model = "resnet50"
gpus = [4, 16]

[scenario]
gpus_per_node = 4
rails = 2
"#,
        )
        .unwrap();
        assert_eq!(c.cluster.gpus_per_node, 4);
        assert_eq!(c.cluster.nic_rails, 2);
        assert_eq!(c.cluster.placement().key(), (4, 2));
        // the scenario knobs themselves stay neutral — placement is a
        // cluster reshape, not a per-rank perturbation
        assert!(c.scenario.is_neutral());
        assert!(parse("[workload]\n[scenario]\ngpus_per_node = 0").is_err());
        assert!(parse("[workload]\n[scenario]\nrails = 0").is_err());
        // rails beyond the ranks per node would sit idle — rejected
        assert!(parse("[workload]\n[scenario]\nrails = 2").is_err());
        assert!(
            parse("[workload]\n[scenario]\ngpus_per_node = 2\nrails = 4").is_err()
        );
        // worlds validate against the densified machine
        let big = parse(
            "[workload]\ncluster = \"ri2\"\ngpus = [40]\n[scenario]\ngpus_per_node = 2",
        )
        .unwrap();
        assert_eq!(big.cluster.max_gpus(), 40);
        assert!(parse("[workload]\ncluster = \"ri2\"\ngpus = [40]").is_err());
    }

    #[test]
    fn scenario_streams_and_depth_parse_and_validate() {
        let c = parse(
            r#"
[workload]
model = "mobilenet"

[scenario]
streams = 4
depth = 2
"#,
        )
        .unwrap();
        assert_eq!(c.scenario.streams, 4);
        assert_eq!(c.scenario.depth, 2);
        assert_eq!(c.scenario.lanes(), (4, 2));
        assert!(c.scenario.overlapped());
        // defaults: one serialized stream, uncapped depth sentinel
        let d = parse("[workload]\nmodel = \"resnet50\"\n[scenario]\nseed = 1").unwrap();
        assert_eq!((d.scenario.streams, d.scenario.depth), (1, 0));
        assert!(!d.scenario.overlapped());
        // inert / invalid combinations are config mistakes
        assert!(parse("[workload]\n[scenario]\nstreams = 0").is_err());
        assert!(parse("[workload]\n[scenario]\ndepth = 2").is_err());
        assert!(parse("[workload]\n[scenario]\nstreams = 2\ndepth = 4").is_err());
        // negative ints must be friendly errors, not usize wraps into
        // a 2^64-lane allocation
        assert!(parse("[workload]\n[scenario]\nstreams = -1").is_err());
        assert!(parse("[workload]\n[scenario]\nstreams = 2\ndepth = -3").is_err());
        // the two-job runners don't consume the overlap knobs — the
        // combination would silently print serialized numbers
        assert!(parse("[workload]\n[scenario]\nsecond_job = true\nstreams = 2").is_err());
    }

    #[test]
    fn scenario_rpc_window_parses_and_validates() {
        let c = parse(
            r#"
[workload]
model = "mobilenet"

[scenario]
rpc_window = 4
"#,
        )
        .unwrap();
        assert_eq!(c.scenario.rpc_window, 4);
        assert!(!c.scenario.is_neutral());
        // default: unbounded (the serialized reference schedule)
        let d = parse("[workload]\nmodel = \"resnet50\"\n[scenario]\nseed = 1").unwrap();
        assert_eq!(d.scenario.rpc_window, 0);
        // negative ints must be friendly errors, not usize wraps
        assert!(parse("[workload]\n[scenario]\nrpc_window = -2").is_err());
        // the two-job runners don't consume the PS window knob
        assert!(
            parse("[workload]\n[scenario]\nsecond_job = true\nrpc_window = 2").is_err()
        );
    }

    #[test]
    fn scenario_rejects_inert_factors() {
        // a sub-1.0 straggler would silently report pristine numbers
        // under a scenario label
        assert!(
            parse("[workload]\n[scenario]\nstraggler_ranks = 2\nstraggler_factor = 0.5").is_err()
        );
        assert!(parse("[workload]\n[scenario]\nhetero_ranks = 1\nhetero_factor = 1.0").is_err());
        assert!(parse("[workload]\n[scenario]\nstraggler_factor = 1.5").is_err());
    }

    #[test]
    fn scenario_fault_table_parses_and_validates() {
        let c = parse(
            r#"
[workload]
model = "resnet50"
gpus = [8]

[scenario.fault]
events = ["crash@1500:r3", "flap@200:n0.l0+350"]
detect_timeout_us = 500.0
backoff_base_us = 100.0
backoff_factor = 2.0
max_retries = 4
rebuild_us = 1000.0
checkpoint_period_us = 2000.0
"#,
        )
        .unwrap();
        let fp = &c.scenario.fault;
        assert_eq!(fp.events.len(), 2);
        assert!((fp.detect_timeout_us - 500.0).abs() < 1e-12);
        assert_eq!(fp.max_retries, 4);
        assert!((fp.checkpoint_period_us - 2000.0).abs() < 1e-12);
        // knobs without events leave the plan empty (inert knobs are
        // allowed here — the sweep surfaces inject their own events)
        let d = parse("[workload]\n[scenario.fault]\ndetect_timeout_us = 50.0").unwrap();
        assert!(d.scenario.fault.is_empty());
        assert!((d.scenario.fault.detect_timeout_us - 50.0).abs() < 1e-12);
        // bad specs and degenerate knobs are config errors
        assert!(parse("[workload]\n[scenario.fault]\nevents = [\"reboot@1:r0\"]").is_err());
        assert!(parse("[workload]\n[scenario.fault]\nbackoff_factor = 0.5").is_err());
        assert!(parse("[workload]\n[scenario.fault]\nmax_retries = -1").is_err());
        assert!(parse("[workload]\n[scenario.fault]\nmax_retries = 99").is_err());
    }

    #[test]
    fn defaults_fill_in() {
        let c = parse("[workload]\nmodel = \"mobilenet\"").unwrap();
        assert_eq!(c.cluster.name, "RI2");
        assert_eq!(c.batch_per_gpu, 64);
        assert!(!c.strategies.is_empty());
    }

    #[test]
    fn rejects_bad_strategy_and_oversized_world() {
        assert!(parse("[workload]\ngpus = [100000]").is_err());
        assert!(
            parse("[workload]\nmodel=\"resnet50\"\n[comm]\nstrategies=[\"bogus\"]").is_err()
        );
    }
}
