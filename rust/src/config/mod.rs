//! Experiment configuration: a TOML-subset parser (offline environment —
//! DESIGN.md §7) plus the typed experiment schema the CLI and launcher
//! consume.

pub mod schema;
pub mod toml_lite;

pub use schema::ExperimentConfig;
pub use toml_lite::{TomlValue, parse_toml};
