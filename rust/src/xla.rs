//! Stub of the `xla` (PJRT) FFI surface used by `runtime/`.
//!
//! The build environment has no vendored `xla_extension` closure, so the
//! crate ships dependency-free: this module mirrors the exact API shape
//! `runtime::{client, artifact}` consume and fails **at runtime** from the
//! single entry point (`PjRtClient::cpu`) with an actionable message.
//! Every PJRT-dependent test and CLI path already skips gracefully when no
//! client/artifacts are available, so the simulator, comm, strategy and
//! bench layers are unaffected.  Re-linking the real bindings is a
//! one-file change: delete this module and add the `xla` dependency back
//! (see ARCHITECTURE.md §Runtime).

use std::fmt;

/// Error type standing in for `xla::Error`; implements `std::error::Error`
/// so `?` and `.context(...)` convert it like the real one.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT backend not linked in this build (xla_extension closure not vendored); \
         the simulator/bench/strategy layers run without it"
            .to_string(),
    )
}

/// Stub of `xla::PjRtClient`.  `cpu()` always fails, which is the only
/// constructor — so the unreachable methods below never execute.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

/// Inert literal: constructible (so `lit_f32` & friends stay total
/// functions) but never executable.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }

    pub fn get_first_element<T>(&self) -> Result<T, XlaError> {
        Err(unavailable())
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not linked"));
    }

    #[test]
    fn literals_construct_inertly() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
