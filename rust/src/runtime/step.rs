//! Typed wrappers over the three artifact families: train_step, sgd, and
//! the standalone Pallas reduction kernels (the paper's "CUDA kernel-
//! enabled reduction", §V-A).

use std::path::Path;
use std::rc::Rc;

use crate::util::error::{Context, Result};

use super::artifact::{lit_f32, lit_i32_2d, to_f32, to_scalar_f32, Artifact};
use super::client::RuntimeClient;
use super::meta::ModelMeta;

/// `(params[N], tokens[B,S+1]) -> (loss, grads[N])`
pub struct TrainStep {
    artifact: Rc<Artifact>,
    pub meta: ModelMeta,
}

impl TrainStep {
    pub fn load(client: &RuntimeClient, dir: &Path, config: &str) -> Result<TrainStep> {
        let meta = ModelMeta::load(dir, config)?;
        let artifact = client.load(&dir.join(format!("train_step_{config}.hlo.txt")))?;
        Ok(TrainStep { artifact, meta })
    }

    /// Execute one fwd/bwd step; returns (loss, flat gradient).
    pub fn run(&self, params: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        crate::ensure!(
            params.len() == self.meta.param_count,
            "params len {} != {}",
            params.len(),
            self.meta.param_count
        );
        crate::ensure!(
            tokens.len() == self.meta.tokens_len(),
            "tokens len {} != {}",
            tokens.len(),
            self.meta.tokens_len()
        );
        let p = lit_f32(params);
        let t = lit_i32_2d(tokens, self.meta.batch, self.meta.seq + 1)?;
        let outs = self.artifact.run(&[p, t])?;
        crate::ensure!(outs.len() == 2, "train_step returned {} outputs", outs.len());
        let loss = to_scalar_f32(&outs[0]).context("loss output")?;
        let grads = to_f32(&outs[1]).context("grads output")?;
        Ok((loss, grads))
    }
}

/// `(w[N], v[N], g[N], scale[1]) -> (w', v')` — fused Pallas SGD-momentum.
pub struct SgdUpdate {
    artifact: Rc<Artifact>,
    pub n: usize,
}

impl SgdUpdate {
    pub fn load(client: &RuntimeClient, dir: &Path, config: &str, n: usize) -> Result<SgdUpdate> {
        let artifact = client.load(&dir.join(format!("sgd_{config}.hlo.txt")))?;
        Ok(SgdUpdate { artifact, n })
    }

    /// In-place momentum update; `scale` is 1/world_size.
    pub fn run(&self, w: &mut Vec<f32>, v: &mut Vec<f32>, g: &[f32], scale: f32) -> Result<()> {
        crate::ensure!(w.len() == self.n && v.len() == self.n && g.len() == self.n);
        let outs = self
            .artifact
            .run(&[lit_f32(w), lit_f32(v), lit_f32(g), lit_f32(&[scale])])?;
        crate::ensure!(outs.len() == 2, "sgd returned {} outputs", outs.len());
        *w = to_f32(&outs[0])?;
        *v = to_f32(&outs[1])?;
        Ok(())
    }
}

/// `(x[n], y[n]) -> x + y` — the standalone Pallas reduction kernel, used
/// by the GPU-kernel reduction backend of the Allreduce implementations.
pub struct ReduceKernel {
    /// (chunk_len, executable) sorted ascending by chunk length.
    kernels: Vec<(usize, Rc<Artifact>)>,
}

impl ReduceKernel {
    pub fn load(client: &RuntimeClient, dir: &Path, chunks: &[usize]) -> Result<ReduceKernel> {
        let mut kernels = Vec::new();
        for &n in chunks {
            let a = client.load(&dir.join(format!("reduce_sum_{n}.hlo.txt")))?;
            kernels.push((n, a));
        }
        kernels.sort_by_key(|(n, _)| *n);
        crate::ensure!(!kernels.is_empty(), "no reduce kernels found");
        Ok(ReduceKernel { kernels })
    }

    /// `acc += x`, chunked over the fixed-size kernels (largest first,
    /// smallest kernel padded for the tail).
    pub fn accumulate(&self, acc: &mut [f32], x: &[f32]) -> Result<()> {
        crate::ensure!(acc.len() == x.len(), "length mismatch");
        let mut off = 0;
        while off < acc.len() {
            let remaining = acc.len() - off;
            // largest kernel that fits, else the smallest one (padded tail)
            let (n, artifact) = self
                .kernels
                .iter()
                .rev()
                .find(|(n, _)| *n <= remaining)
                .unwrap_or(&self.kernels[0])
                .clone();
            let take = remaining.min(n);
            let (xa, ya);
            if take == n {
                xa = lit_f32(&acc[off..off + n]);
                ya = lit_f32(&x[off..off + n]);
            } else {
                // tail: pad with zeros (identity of sum)
                let mut xb = vec![0.0f32; n];
                let mut yb = vec![0.0f32; n];
                xb[..take].copy_from_slice(&acc[off..off + take]);
                yb[..take].copy_from_slice(&x[off..off + take]);
                xa = lit_f32(&xb);
                ya = lit_f32(&yb);
            }
            let outs = artifact.run(&[xa, ya])?;
            let z = to_f32(&outs[0])?;
            acc[off..off + take].copy_from_slice(&z[..take]);
            off += take;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_dir, config_available};

    fn client() -> Option<Rc<RuntimeClient>> {
        super::super::client::shared().ok()
    }

    #[test]
    fn reduce_kernel_matches_scalar_sum() {
        let Ok(dir) = artifacts_dir() else { return };
        if !dir.join("reduce_sum_4096.hlo.txt").is_file() {
            return;
        }
        let c = client().unwrap();
        let k = ReduceKernel::load(&c, &dir, &[4096]).unwrap();
        let mut rng = crate::util::prng::Rng::new(1);
        for n in [1usize, 100, 4096, 5000] {
            let mut acc = rng.f32_vec(n);
            let x = rng.f32_vec(n);
            let want: Vec<f32> = acc.iter().zip(&x).map(|(a, b)| a + b).collect();
            k.accumulate(&mut acc, &x).unwrap();
            for (g, w) in acc.iter().zip(&want) {
                assert!((g - w).abs() < 1e-6, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn train_step_tiny_runs_and_loss_sane() {
        let Ok(dir) = artifacts_dir() else { return };
        if !config_available(&dir, "tiny") {
            return;
        }
        let c = client().unwrap();
        let step = TrainStep::load(&c, &dir, "tiny").unwrap();
        let params = step.meta.load_params(&dir).unwrap();
        let mut rng = crate::util::prng::Rng::new(2);
        let tokens = rng.tokens(step.meta.tokens_len(), step.meta.vocab as u32);
        let (loss, grads) = step.run(&params, &tokens).unwrap();
        // random init ⇒ loss ≈ ln(vocab)
        let expect = (step.meta.vocab as f32).ln();
        assert!((loss - expect).abs() < 1.0, "loss={loss} expect≈{expect}");
        assert_eq!(grads.len(), step.meta.param_count);
        assert!(grads.iter().all(|g| g.is_finite()));
        let norm: f32 = grads.iter().map(|g| g * g).sum::<f32>().sqrt();
        assert!(norm > 1e-4, "gradient should be nonzero, norm={norm}");
    }

    #[test]
    fn sgd_matches_scalar_reference() {
        let Ok(dir) = artifacts_dir() else { return };
        if !config_available(&dir, "tiny") {
            return;
        }
        let c = client().unwrap();
        let meta = ModelMeta::load(&dir, "tiny").unwrap();
        let sgd = SgdUpdate::load(&c, &dir, "tiny", meta.param_count).unwrap();
        let mut rng = crate::util::prng::Rng::new(3);
        let n = meta.param_count;
        let mut w = rng.f32_vec(n);
        let mut v = rng.f32_vec(n);
        let g = rng.f32_vec(n);
        let (w0, v0) = (w.clone(), v.clone());
        let scale = 0.25f32;
        sgd.run(&mut w, &mut v, &g, scale).unwrap();
        let (lr, mu) = (meta.sgd_lr as f32, meta.sgd_mu as f32);
        for i in (0..n).step_by(997) {
            let ve = mu * v0[i] + g[i] * scale;
            let we = w0[i] - lr * ve;
            assert!((v[i] - ve).abs() < 1e-5, "v[{i}]: {} vs {ve}", v[i]);
            assert!((w[i] - we).abs() < 1e-5, "w[{i}]: {} vs {we}", w[i]);
        }
    }
}
