//! Model metadata sidecar (`artifacts/meta_<cfg>.json`) parsed with the
//! in-tree JSON parser — the contract between aot.py and the rust trainer.

use std::path::Path;

use crate::util::error::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    pub config: String,
    pub param_count: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub sgd_lr: f64,
    pub sgd_mu: f64,
    pub reduce_chunks: Vec<usize>,
}

impl ModelMeta {
    pub fn load(dir: &Path, config: &str) -> Result<ModelMeta> {
        let path = dir.join(format!("meta_{config}.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Ok(ModelMeta {
            config: j
                .get("config")
                .and_then(Json::as_str)
                .context("missing `config`")?
                .to_string(),
            param_count: j.req_usize("param_count")?,
            vocab: j.req_usize("vocab")?,
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            d_ff: j.req_usize("d_ff")?,
            seq: j.req_usize("seq")?,
            batch: j.req_usize("batch")?,
            sgd_lr: j.req_f64("sgd_lr")?,
            sgd_mu: j.req_f64("sgd_mu")?,
            reduce_chunks: j
                .get("reduce_chunks")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
        })
    }

    /// Tokens-per-step shape the train_step artifact expects: [batch, seq+1].
    pub fn tokens_len(&self) -> usize {
        self.batch * (self.seq + 1)
    }

    /// Gradient payload in bytes (f32) — what the Allreduce carries.
    pub fn grad_bytes(&self) -> usize {
        self.param_count * 4
    }

    /// Load the initial flat parameter vector (little-endian f32 .bin).
    pub fn load_params(&self, dir: &Path) -> Result<Vec<f32>> {
        let path = dir.join(format!("params_{}.bin", self.config));
        let bytes = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        crate::ensure!(
            bytes.len() == self.param_count * 4,
            "param file {} has {} bytes, expected {}",
            path.display(),
            bytes.len(),
            self.param_count * 4
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generated_meta_when_present() {
        let Ok(dir) = crate::runtime::artifacts_dir() else { return };
        if !crate::runtime::config_available(&dir, "tiny") {
            return;
        }
        let m = ModelMeta::load(&dir, "tiny").unwrap();
        assert_eq!(m.config, "tiny");
        assert!(m.param_count > 0);
        assert_eq!(m.tokens_len(), m.batch * (m.seq + 1));
        let params = m.load_params(&dir).unwrap();
        assert_eq!(params.len(), m.param_count);
        assert!(params.iter().all(|x| x.is_finite()));
    }
}
