//! PJRT client wrapper.  One process-wide CPU client; compiling an HLO
//! module is expensive, so executables are cached per artifact path.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::util::error::{Context, Result};
use crate::xla;

use super::artifact::Artifact;

/// Per-thread PJRT client + executable cache.
///
/// `xla::PjRtClient` is `Rc`-based (not `Send`/`Sync`), which suits the
/// deterministic single-threaded simulator: every simulated worker shares
/// one compilation of each artifact (matches the paper's setup where every
/// rank runs the same compiled graph).
pub struct RuntimeClient {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<Artifact>>>,
}

impl RuntimeClient {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient { client, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an HLO-text artifact (cached by absolute path).
    pub fn load(&self, path: &Path) -> Result<Rc<Artifact>> {
        let key = path
            .canonicalize()
            .with_context(|| format!("artifact not found: {}", path.display()))?;
        if let Some(a) = self.cache.borrow().get(&key) {
            return Ok(a.clone());
        }
        let artifact = Rc::new(Artifact::compile(&self.client, &key)?);
        self.cache.borrow_mut().insert(key, artifact.clone());
        Ok(artifact)
    }
}

thread_local! {
    static SHARED: RefCell<Option<Rc<RuntimeClient>>> = const { RefCell::new(None) };
}

/// Per-thread shared client for the common case (tests, examples, benches).
pub fn shared() -> Result<Rc<RuntimeClient>> {
    SHARED.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            *slot = Some(Rc::new(RuntimeClient::cpu()?));
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}
