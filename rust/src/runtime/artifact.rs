//! One compiled HLO artifact: load text, compile once, execute many.

use std::path::Path;

use crate::util::error::{Context, Result};
use crate::xla;

/// A compiled PJRT executable plus its provenance.
pub struct Artifact {
    pub path: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Parse HLO text and compile it on `client`.
    pub fn compile(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Artifact { path: path.display().to_string(), exe })
    }

    /// Execute with literal inputs; returns the flattened tuple of outputs.
    ///
    /// jax lowering uses `return_tuple=True`, so the single device output
    /// is always a tuple literal — we decompose it for callers.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.path))?;
        let mut lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.path))?;
        lit.decompose_tuple().map_err(Into::into)
    }
}

/// Build an f32 vector literal of the given length.
pub fn lit_f32(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Build a rank-2 i32 literal `[rows, cols]` from row-major data.
pub fn lit_i32_2d(v: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    crate::ensure!(v.len() == rows * cols, "shape mismatch: {} != {rows}x{cols}", v.len());
    xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64]).map_err(Into::into)
}

/// Extract an f32 vector from a literal.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(Into::into)
}

/// Extract the single f32 scalar from a literal.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(Into::into)
}
