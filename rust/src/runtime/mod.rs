//! Runtime layer: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them on the PJRT CPU client from the rust hot path.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  HLO *text* is the interchange format —
//! xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit ids).
//!
//! Python never runs here; after `make artifacts` the binary is
//! self-contained.

pub mod artifact;
pub mod client;
pub mod meta;
pub mod step;

pub use artifact::Artifact;
pub use client::RuntimeClient;
pub use meta::ModelMeta;
pub use step::{ReduceKernel, SgdUpdate, TrainStep};

use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$MPI_DNN_ARTIFACTS`, else `./artifacts`
/// walking up from cwd (so tests/benches work from any target dir).
pub fn artifacts_dir() -> crate::util::error::Result<PathBuf> {
    if let Ok(p) = std::env::var("MPI_DNN_ARTIFACTS") {
        let p = PathBuf::from(p);
        crate::ensure!(p.is_dir(), "MPI_DNN_ARTIFACTS={} is not a directory", p.display());
        return Ok(p);
    }
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return Ok(cand);
        }
        if !dir.pop() {
            crate::bail!(
                "artifacts/ not found (run `make artifacts` or set MPI_DNN_ARTIFACTS)"
            );
        }
    }
}

/// True when the artifact set for `config` exists (lets tests skip
/// gracefully rather than fail when only some configs were built).
pub fn config_available(dir: &Path, config: &str) -> bool {
    dir.join(format!("train_step_{config}.hlo.txt")).is_file()
        && dir.join(format!("meta_{config}.json")).is_file()
        && dir.join(format!("params_{config}.bin")).is_file()
}
