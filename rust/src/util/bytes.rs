//! Byte-size formatting/parsing for message-size sweeps ("4B".."256MB"),
//! matching the axis labels of the paper's Allreduce figures.

/// Format a byte count the way the paper's figures label their x-axis
/// (power-of-two units: 1024 bytes = 1KB there).
pub fn fmt_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if v.fract() == 0.0 {
        format!("{}{}", v as u64, UNITS[u])
    } else {
        format!("{v:.1}{}", UNITS[u])
    }
}

/// Parse "8", "8B", "128K", "128KB", "64M", "1G" (case-insensitive).
pub fn parse_bytes(s: &str) -> Result<usize, String> {
    let t = s.trim().to_ascii_uppercase();
    let digits_end = t.find(|c: char| !c.is_ascii_digit() && c != '.').unwrap_or(t.len());
    let (num, suffix) = t.split_at(digits_end);
    let base: f64 = num.parse().map_err(|_| format!("bad size `{s}`"))?;
    let mult = match suffix.trim_end_matches('B') {
        "" => 1.0,
        "K" => 1024.0,
        "M" => 1024.0 * 1024.0,
        "G" => 1024.0_f64.powi(3),
        "T" => 1024.0_f64.powi(4),
        _ => return Err(format!("bad size suffix in `{s}`")),
    };
    Ok((base * mult) as usize)
}

/// Duration in microseconds → human string (the paper reports Allreduce
/// latency in µs/ms).
pub fn fmt_us(us: f64) -> String {
    if us < 1e3 {
        format!("{us:.1}us")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// The standard message-size sweep used by Figures 4 and 6: powers of two
/// from 4B to `max` bytes.
pub fn msg_size_sweep(max: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut n = 4usize;
    while n <= max {
        v.push(n);
        n *= 2;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_roundtrip_common() {
        for (n, s) in [(4, "4B"), (1024, "1KB"), (128 * 1024, "128KB"), (256 * 1024 * 1024, "256MB")] {
            assert_eq!(fmt_bytes(n), s);
            assert_eq!(parse_bytes(s).unwrap(), n);
        }
    }

    #[test]
    fn parse_variants() {
        assert_eq!(parse_bytes("8").unwrap(), 8);
        assert_eq!(parse_bytes("128k").unwrap(), 131072);
        assert_eq!(parse_bytes(" 2MB ").unwrap(), 2 * 1024 * 1024);
        assert!(parse_bytes("12X").is_err());
        assert!(parse_bytes("").is_err());
    }

    #[test]
    fn fractional_fmt() {
        assert_eq!(fmt_bytes(1536), "1.5KB");
    }

    #[test]
    fn us_formatting() {
        assert_eq!(fmt_us(12.34), "12.3us");
        assert_eq!(fmt_us(12_345.0), "12.35ms");
        assert_eq!(fmt_us(2_000_000.0), "2.000s");
    }

    #[test]
    fn sweep_is_pow2_4b_up() {
        let s = msg_size_sweep(64);
        assert_eq!(s, vec![4, 8, 16, 32, 64]);
        let big = msg_size_sweep(256 * 1024 * 1024);
        assert_eq!(*big.first().unwrap(), 4);
        assert_eq!(*big.last().unwrap(), 256 * 1024 * 1024);
        assert_eq!(big.len(), 27);
    }
}
