//! Ordered parallel map over independent work items (scoped threads).
//!
//! The sweep drivers fan independent points (each owning its private
//! discrete-event engine) across threads and join results **in input
//! order**, so parallel output is byte-identical to a sequential run.
//! One item per thread: sweeps are small (≤ a few dozen points) and each
//! point is compute-heavy, so scheduling granularity is a non-issue.

/// Run `f` over `items` on scoped threads; results come back in input
/// order.  Panics in a worker propagate to the caller.
pub fn par_map_ordered<I, T, F>(items: I, f: F) -> Vec<T>
where
    I: IntoIterator,
    I::Item: Send,
    T: Send,
    F: Fn(I::Item) -> T + Sync,
{
    let items: Vec<I::Item> = items.into_iter().collect();
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = items.into_iter().map(|it| s.spawn(move || f(it))).collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel sweep worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let out = par_map_ordered(0..32usize, |i| i * i);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn borrows_captured_state() {
        let base = vec![10, 20, 30];
        let out = par_map_ordered([0usize, 1, 2], |i| base[i] + 1);
        assert_eq!(out, vec![11, 21, 31]);
    }

    #[test]
    #[should_panic(expected = "parallel sweep worker panicked")]
    fn worker_panic_propagates() {
        par_map_ordered([0u32, 1], |i| {
            if i == 1 {
                panic!("boom");
            }
            i
        });
    }
}
