//! Minimal CLI argument parser (no clap offline; DESIGN.md §7).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value | --key=value] [pos..]`.
//! Unknown flags are errors (catches typos in experiment scripts).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse raw argv (without program name).  The first token not starting
    /// with `--` becomes the subcommand; later bare tokens are positional.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    // boolean flag
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got `{v}`")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected number, got `{v}`")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true" | "1" | "yes"))
    }

    /// Comma-separated list flag, e.g. `--gpus 1,2,4,8`.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
    }

    /// Error on flags that no `get_*` consulted — typo protection.
    pub fn reject_unknown(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        let unknown: Vec<_> =
            self.flags.keys().filter(|k| !seen.contains(k)).cloned().collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown flag(s): {}", unknown.join(", ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --steps 100 --config=small --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert_eq!(a.get("config"), Some("small"));
        assert!(a.get_bool("verbose"));
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn positional_args() {
        let a = parse("figure 4 6");
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.positional, vec!["4", "6"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert!((a.get_f64("f", 1.5).unwrap() - 1.5).abs() < 1e-12);
        assert!(!a.get_bool("nope"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse("x --typo 3");
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn list_flag() {
        let a = parse("x --gpus 1,2,4, 8");
        assert_eq!(a.get_list("gpus").unwrap(), vec!["1", "2", "4"]);
    }
}
