//! Minimal error-context substrate (no `anyhow` offline — DESIGN.md §7).
//!
//! The API surface mirrors the subset of `anyhow` the crate uses: an
//! opaque [`Error`] holding a context chain, a defaulted [`Result`]
//! alias, a [`Context`] extension trait for `Result`/`Option`, and the
//! `bail!` / `ensure!` / `anyhow!` macros (exported at the crate root,
//! re-exported here).  `{:#}` formatting prints the full cause chain the
//! way the CLI's `error: {e:#}` expects.

use std::fmt;

/// An error: a stack of human-readable messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message (the `anyhow::Error::msg`
    /// entry point — also what `Args`'s `Result<_, String>` maps through).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Prepend a layer of context.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> + '_ {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, `context: cause: root` style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion (io::Error, the xla stub error, ParseError, …)
// cannot overlap the reflexive `From<T> for T` impl — same trick anyhow
// itself relies on.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `.context(...)` / `.with_context(...)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: no such file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing flag").unwrap_err();
        assert_eq!(e.to_string(), "missing flag");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<()> = Err(io_err()).with_context(|| format!("step {}", 3));
        assert_eq!(format!("{:#}", r.unwrap_err()), "step 3: no such file");
    }

    #[test]
    fn macros_produce_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert!(f(5).unwrap_err().to_string().contains("condition failed"));
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let e = crate::anyhow!("v={}", 4);
        assert_eq!(e.to_string(), "v=4");
    }

    #[test]
    fn error_msg_from_string_and_chain_iter() {
        let e = Error::msg(String::from("boom")).context("outer");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "boom"]);
    }
}
