//! Leveled stderr logger (no `log`/`tracing` offline; DESIGN.md §7).
//!
//! Level comes from `MPI_DNN_LOG` (error|warn|info|debug|trace) or
//! `set_level`.  Macros mirror the `log` crate so call sites read
//! idiomatically.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static INIT: std::sync::Once = std::sync::Once::new();

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    init_from_env();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn init_from_env() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("MPI_DNN_LOG") {
            let l = match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            };
            LEVEL.store(l as u8, Ordering::Relaxed);
        }
    });
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, module_path!(), format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
