//! Self-written substrates.
//!
//! The build environment is offline (crates.io unreachable; only the `xla`
//! crate's closure is vendored), so everything a production system would
//! normally pull from the ecosystem — PRNG, statistics, JSON, CLI parsing,
//! byte-size formatting, logging, a micro-benchmark harness — is
//! implemented here from scratch (DESIGN.md §7).

pub mod bench;
pub mod bytes;
pub mod cli;
pub mod error;
pub mod json;
pub mod logger;
pub mod par;
pub mod prng;
pub mod stats;
