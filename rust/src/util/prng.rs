//! Deterministic PRNG: splitmix64 seeding + xoshiro256++ core.
//!
//! Every stochastic component in the stack (synthetic data, workload
//! jitter, property tests) takes an explicit seed so simulator runs are
//! bit-reproducible — the same property the paper gets from synthetic
//! input data in tf_cnn_benchmarks.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm),
/// seeded via splitmix64 so any u64 seed yields a well-mixed state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Independent child stream (for per-rank / per-worker generators).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Vector of uniform f32 in [-1, 1) (synthetic gradient payloads).
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_f32() * 2.0 - 1.0).collect()
    }

    /// Vector of i32 token ids in [0, vocab).
    pub fn tokens(&mut self, n: usize, vocab: u32) -> Vec<i32> {
        (0..n).map(|_| self.next_below(vocab as u64) as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "100 elems should move");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn tokens_within_vocab() {
        let mut r = Rng::new(13);
        assert!(r.tokens(1000, 50).iter().all(|&t| (0..50).contains(&t)));
    }
}
