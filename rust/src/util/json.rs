//! Minimal JSON: parser + writer (no serde offline; DESIGN.md §7).
//!
//! Parses the `artifacts/meta_*.json` files emitted by the python AOT path
//! and serializes bench/figure reports.  Supports the full JSON grammar
//! except `\u` surrogate pairs beyond the BMP (not needed for our data).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: required numeric field or descriptive error.
    pub fn req_usize(&self, key: &str) -> crate::util::error::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| crate::anyhow!("missing/invalid numeric field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> crate::util::error::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| crate::anyhow!("missing/invalid numeric field `{key}`"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad \\u digit"))?;
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the sequence through
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("truncated utf8"))?;
                    }
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    /// Compact serialization (stable key order via BTreeMap).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Builder helpers for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(xs: I) -> Json {
    Json::Arr(xs.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_meta_like() {
        let j = Json::parse(
            r#"{"config":"tiny","param_count":101376,"reduce_chunks":[4096,65536],"sgd_lr":0.05}"#,
        )
        .unwrap();
        assert_eq!(j.get("config").unwrap().as_str(), Some("tiny"));
        assert_eq!(j.req_usize("param_count").unwrap(), 101376);
        assert_eq!(j.get("reduce_chunks").unwrap().as_arr().unwrap().len(), 2);
        assert!((j.req_f64("sgd_lr").unwrap() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3e2],"b":{"c":null,"d":true},"e":"x\"\n"}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n \"k\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(j.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let j = Json::parse(r#""éé""#).unwrap();
        assert_eq!(j.as_str(), Some("éé"));
    }

    #[test]
    fn builders_emit_valid() {
        let j = obj(vec![("x", num(1.0)), ("y", arr([s("a"), s("b")]))]);
        assert_eq!(j.to_string(), r#"{"x":1,"y":["a","b"]}"#);
    }
}
