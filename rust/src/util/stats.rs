//! Streaming statistics (Welford) and percentile summaries for the bench
//! harness and the simulator's metric collectors.

/// Online mean/variance accumulator (Welford's algorithm), numerically
/// stable for long runs of the event loop.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Sample reservoir with exact percentiles (bench harness scale: ≤10⁶
/// samples, so we just keep them all and sort on demand).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Samples { xs: Vec::new(), sorted: true }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Percentile by linear interpolation, q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = rank - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn summary(&mut self) -> Summary {
        Summary {
            n: self.len(),
            mean: self.mean(),
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
            min: self.percentile(0.0),
            max: self.percentile(100.0),
        }
    }
}

/// One-line distribution summary (what the mini-bench prints per case).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

/// Geometric mean — used for reporting speedup ratios across message sizes
/// the way the paper aggregates "5–17× for small and medium messages".
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of the data set is 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Samples::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 4.0);
        assert!((s.median() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_sane() {
        let mut s = Samples::new();
        for i in 0..101 {
            s.push(i as f64);
        }
        let sum = s.summary();
        assert_eq!(sum.n, 101);
        assert!((sum.mean - 50.0).abs() < 1e-9);
        assert!((sum.p50 - 50.0).abs() < 1e-9);
        assert!((sum.p95 - 95.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0, 16.0]) - 4.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn empty_samples_nan() {
        let mut s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }
}
