//! Criterion-like micro-benchmark harness (no criterion offline;
//! DESIGN.md §7): warmup, timed samples, distribution summary, and an
//! opaque `black_box` to defeat const-folding.
//!
//! Used by `rust/benches/*` (all `harness = false`) and the `hotpath`
//! profiling pass (EXPERIMENTS.md §Perf).

use std::time::Instant;

use crate::util::stats::{Samples, Summary};

/// Prevent the optimizer from deleting the benchmarked computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Iterations batched per sample (amortizes the Instant overhead for
    /// nanosecond-scale bodies).
    pub iters_per_sample: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, samples: 20, iters_per_sample: 1 }
    }
}

impl BenchConfig {
    /// Scale sample counts down for CI-speed runs (set `MPI_DNN_BENCH_FAST=1`).
    pub fn from_env() -> Self {
        if std::env::var("MPI_DNN_BENCH_FAST").is_ok() {
            BenchConfig { warmup_iters: 1, samples: 5, iters_per_sample: 1 }
        } else {
            BenchConfig::default()
        }
    }
}

/// One benchmark result (times are in microseconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.summary.mean
    }
}

pub struct Bencher {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
    group: String,
}

impl Bencher {
    pub fn new(group: &str) -> Self {
        println!("== bench group: {group} ==");
        Bencher { cfg: BenchConfig::from_env(), results: Vec::new(), group: group.to_string() }
    }

    pub fn with_config(group: &str, cfg: BenchConfig) -> Self {
        let mut b = Bencher::new(group);
        b.cfg = cfg;
        b
    }

    /// Time `f` and record under `name`.  `f` is a full iteration body.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Samples::new();
        for _ in 0..self.cfg.samples {
            let t0 = Instant::now();
            for _ in 0..self.cfg.iters_per_sample {
                f();
            }
            let dt = t0.elapsed().as_secs_f64() * 1e6 / self.cfg.iters_per_sample as f64;
            samples.push(dt);
        }
        let summary = samples.summary();
        println!(
            "{:<44} mean {:>10.2}us  p50 {:>10.2}us  p95 {:>10.2}us  (n={})",
            format!("{}/{}", self.group, name),
            summary.mean,
            summary.p50,
            summary.p95,
            summary.n
        );
        self.results.push(BenchResult { name: name.to_string(), summary });
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let mut b = Bencher::with_config(
            "test",
            BenchConfig { warmup_iters: 1, samples: 5, iters_per_sample: 2 },
        );
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.mean_us() > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn summary_fields_consistent() {
        let mut b = Bencher::with_config(
            "test2",
            BenchConfig { warmup_iters: 0, samples: 8, iters_per_sample: 1 },
        );
        let r = b.bench("noop", || {
            black_box(1 + 1);
        });
        let s = r.summary;
        assert!(s.min <= s.p50 && s.p50 <= s.max);
        assert_eq!(s.n, 8);
    }
}
