//! RDMA-verbs tensor transport (§III-B2, the gRPC+Verbs contrib path):
//! direct verbs RDMA for tensor payloads with pinned staging buffers,
//! while setup/administration stays on gRPC.  No GDR here (that is the
//! separate gRPC+GDR contrib, which the paper could not run either).

use crate::cluster::{Fabric, Link};
use crate::comm::CostBreakdown;
use crate::sim::SimTime;

#[derive(Debug, Clone)]
pub struct VerbsTransport {
    pub link: Link,
    pub pcie: Link,
    /// Pinned (registered) staging buffers double PCIe efficiency vs
    /// pageable copies and skip per-transfer registration.
    pub pinned: bool,
    /// Per-transfer software overhead, µs (QP work-request posting).
    pub post_us: f64,
}

impl VerbsTransport {
    pub fn new(fabric: &Fabric) -> Self {
        VerbsTransport { link: fabric.inter, pcie: fabric.pcie, pinned: true, post_us: 3.0 }
    }

    /// One tensor moved GPU→GPU via RDMA write with host staging.
    pub fn tensor_cost(&self, bytes: usize) -> CostBreakdown {
        let mut c = CostBreakdown { sw_us: self.post_us, ..Default::default() };
        let pcie_eff = if self.pinned { 1.0 } else { 0.55 };
        c.staging_us =
            2.0 * (self.pcie.alpha_us + self.pcie.wire_us(bytes) / pcie_eff);
        c.wire_us = self.link.alpha_us + self.link.wire_us(bytes);
        c
    }

    pub fn tensor_time(&self, bytes: usize) -> SimTime {
        self.tensor_cost(bytes).total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fabric;
    use crate::comm::grpc::GrpcTransport;

    #[test]
    fn verbs_beats_grpc() {
        // §III's whole premise: verbs tensor path ≫ gRPC tensor path.
        let f = Fabric::ib_edr_gdr();
        let v = VerbsTransport::new(&f);
        let g = GrpcTransport::new(f.tcp, f.pcie);
        for bytes in [1 << 12, 1 << 20, 16 << 20] {
            assert!(
                v.tensor_time(bytes).as_us() < g.tensor_pull_time(bytes).as_us(),
                "verbs should beat gRPC at {bytes}B"
            );
        }
    }

    #[test]
    fn pinned_buffers_matter() {
        let f = Fabric::ib_edr_gdr();
        let mut v = VerbsTransport::new(&f);
        let fast = v.tensor_time(16 << 20);
        v.pinned = false;
        let slow = v.tensor_time(16 << 20);
        assert!(slow.as_us() > 1.2 * fast.as_us());
    }

    #[test]
    fn staging_always_present_without_gdr() {
        let f = Fabric::ib_edr_gdr();
        let v = VerbsTransport::new(&f);
        assert!(v.tensor_cost(1 << 20).staging_us > 0.0);
    }
}
