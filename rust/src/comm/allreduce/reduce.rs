//! Reduction backends: where the elementwise sum happens and what it
//! costs.  This is the axis the paper's §V-A contribution moves: stock
//! MVAPICH2 reduces on the **CPU** (wasting the GPU and paying PCIe
//! staging); the optimized design reduces **on the GPU** with a CUDA
//! kernel — here, the Pallas kernel artifact when one is loaded.

use std::rc::Rc;

use crate::comm::CostBreakdown;
use crate::runtime::ReduceKernel;

use super::AllreduceCtx;

/// Largest payload serviced via the GDRCopy (BAR-mapped) path instead of
/// DMA staging — mirrors MVAPICH2-GDR's eager threshold.
pub const GDRCOPY_MAX_BYTES: usize = 32 * 1024;

/// GDRCopy effective bandwidth (BAR reads are slow, but latency-free).
pub const GDRCOPY_GBS: f64 = 0.8;

/// How buffers travel between ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportMode {
    /// GPUDirect RDMA: NIC ↔ GPU memory directly.
    Gdr,
    /// Host-staged: D2H → wire → H2D on every hop.
    Staged,
}

/// Where the reduction executes.
#[derive(Clone)]
pub enum ReducePlace {
    /// CPU loop at `gbs` effective GB/s of reduced data.  If the transport
    /// is GDR the operands must additionally be staged to the host and the
    /// result staged back (that combination is what stock MVAPICH2's
    /// recursive-halving path effectively pays — §V-A).
    Cpu { gbs: f64 },
    /// GPU kernel: launch overhead + 3·bytes/HBM-bandwidth (2 reads + 1
    /// write).  Executes the AOT Pallas artifact when provided, otherwise
    /// a scalar loop with identical semantics.
    Gpu,
    /// Like `Gpu` but runs the real PJRT-compiled Pallas kernel.
    GpuPjrt(Rc<ReduceKernel>),
}

impl std::fmt::Debug for ReducePlace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReducePlace::Cpu { gbs } => write!(f, "Cpu{{{gbs}GB/s}}"),
            ReducePlace::Gpu => write!(f, "Gpu"),
            ReducePlace::GpuPjrt(_) => write!(f, "GpuPjrt"),
        }
    }
}

impl ReducePlace {
    /// Modeled cost of reducing `bytes` (no data movement) — the shadow
    /// path used by latency models over huge message sizes.
    pub fn cost(&self, ctx: &AllreduceCtx, bytes: usize) -> CostBreakdown {
        let mut cost = CostBreakdown::default();
        match self {
            ReducePlace::Cpu { gbs } => {
                cost.reduce_us = bytes as f64 / (gbs * 1e3);
                if ctx.transport == TransportMode::Gdr {
                    if bytes <= GDRCOPY_MAX_BYTES {
                        // GDRCopy window: the CPU reads/writes GPU memory
                        // through the BAR — low bandwidth but no DMA setup
                        // latency, which is what wins for tiny payloads.
                        cost.staging_us = 3.0 * bytes as f64 / (GDRCOPY_GBS * 1e3);
                    } else {
                        // operands live on the GPU: stage both down, result up
                        cost.staging_us =
                            3.0 * (ctx.fabric.pcie.alpha_us + ctx.fabric.pcie.wire_us(bytes));
                    }
                }
            }
            ReducePlace::Gpu | ReducePlace::GpuPjrt(_) => {
                let t = ctx.gpu.reduce_time(bytes);
                cost.launch_us = ctx.gpu.launch_us;
                cost.reduce_us = t.as_us() - ctx.gpu.launch_us;
            }
        }
        cost
    }

    /// Perform `acc += x` for real and return the modeled cost.
    pub fn reduce_into(&self, ctx: &AllreduceCtx, acc: &mut [f32], x: &[f32]) -> CostBreakdown {
        debug_assert_eq!(acc.len(), x.len());
        match self {
            ReducePlace::Cpu { .. } | ReducePlace::Gpu => scalar_sum(acc, x),
            ReducePlace::GpuPjrt(kernel) => {
                kernel.accumulate(acc, x).expect("pjrt reduce kernel failed")
            }
        }
        self.cost(ctx, acc.len() * 4)
    }
}

/// The semantics every backend implements (and the paper's MPI_SUM).
#[inline]
pub fn scalar_sum(acc: &mut [f32], x: &[f32]) {
    for (a, b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::ctx_gdr;
    use super::*;
    use crate::cluster::presets;
    use crate::comm::ptrcache::CacheMode;

    #[test]
    fn all_backends_same_semantics() {
        let ctx = ctx_gdr();
        let mut rng = crate::util::prng::Rng::new(1);
        let x = rng.f32_vec(1000);
        let base = rng.f32_vec(1000);

        let mut a_cpu = base.clone();
        let mut a_gpu = base.clone();
        ReducePlace::Cpu { gbs: 3.0 }.reduce_into(&ctx, &mut a_cpu, &x);
        ReducePlace::Gpu.reduce_into(&ctx, &mut a_gpu, &x);
        assert_eq!(a_cpu, a_gpu);
        for i in 0..1000 {
            assert!((a_cpu[i] - (base[i] + x[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn cpu_reduce_on_gdr_pays_staging() {
        let ctx = ctx_gdr(); // Gdr transport
        let mut acc = vec![0.0f32; 1 << 20];
        let x = vec![1.0f32; 1 << 20];
        let c = ReducePlace::Cpu { gbs: 3.0 }.reduce_into(&ctx, &mut acc, &x);
        assert!(c.staging_us > 0.0, "GDR + CPU reduce must stage");
        assert!(c.reduce_us > 0.0);
    }

    #[test]
    fn gpu_reduce_much_faster_for_large() {
        let ctx = ctx_gdr();
        let n = 1 << 22; // 16 MB
        let mut acc = vec![0.0f32; n];
        let x = vec![1.0f32; n];
        let cpu = ReducePlace::Cpu { gbs: 3.0 }.reduce_into(&ctx, &mut acc.clone(), &x);
        let gpu = ReducePlace::Gpu.reduce_into(&ctx, &mut acc, &x);
        assert!(
            cpu.total_us() > 5.0 * gpu.total_us(),
            "cpu {} vs gpu {}",
            cpu.total_us(),
            gpu.total_us()
        );
    }

    #[test]
    fn gpu_reduce_launch_dominated_small() {
        let ctx = ctx_gdr();
        let mut acc = vec![0.0f32; 2];
        let c = ReducePlace::Gpu.reduce_into(&ctx, &mut acc, &[1.0, 2.0]);
        assert!((c.launch_us - ctx.gpu.launch_us).abs() < 1e-9);
        assert!(c.reduce_us < 0.1);
    }

    #[test]
    fn staged_transport_cpu_reduce_no_extra_staging() {
        let c = presets::ri2();
        let ctx = super::super::AllreduceCtx::new(
            c.fabric.clone(),
            c.gpu.clone(),
            TransportMode::Staged,
            ReducePlace::Cpu { gbs: 3.0 },
            CacheMode::None,
            1.0,
        );
        let mut acc = vec![0.0f32; 1024];
        let cost = ReducePlace::Cpu { gbs: 3.0 }.reduce_into(&ctx, &mut acc, &vec![1.0; 1024]);
        // data already on host because the transport staged it
        assert_eq!(cost.staging_us, 0.0);
    }
}
