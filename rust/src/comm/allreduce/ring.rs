//! Ring reduce-scatter-allgather (Patarasuk & Yuan) — the algorithm behind
//! NCCL and Baidu's mpi_collectives.  Bandwidth-optimal (each rank moves
//! 2·n·(p−1)/p bytes) but pays 2(p−1) α-steps, which is what sinks it for
//! small messages at scale (Figure 4/6's small-message regime).

use super::{AllreduceCtx, AllreduceReport};
use crate::sim::SimTime;

/// Split `n` elements into `p` nearly-equal contiguous chunks.
fn chunk_ranges(n: usize, p: usize) -> Vec<(usize, usize)> {
    let base = n / p;
    let rem = n % p;
    let mut out = Vec::with_capacity(p);
    let mut lo = 0;
    for i in 0..p {
        let len = base + usize::from(i < rem);
        out.push((lo, lo + len));
        lo += len;
    }
    debug_assert_eq!(lo, n);
    out
}

/// In-place ring allreduce over `bufs[p][n]` (sum).
pub fn ring_allreduce(bufs: &mut [Vec<f32>], ctx: &mut AllreduceCtx) -> AllreduceReport {
    let p = bufs.len();
    assert!(p >= 1);
    let n = bufs[0].len();
    let mut report = AllreduceReport { algo: "ring", ..Default::default() };

    if p == 1 || n == 0 {
        return report;
    }
    ctx.register_ranks(p, (n * 4) as u64);

    let chunks = chunk_ranges(n, p);
    let max_chunk_bytes = chunks.iter().map(|(a, b)| (b - a) * 4).max().unwrap();

    // ---- reduce-scatter: p−1 steps ----
    // At step s, rank r sends chunk (r − s) mod p to its right neighbour
    // (r+1) mod p and reduces the chunk it receives from the left.
    for s in 0..p - 1 {
        // snapshot the outgoing chunk of every rank (synchronous step)
        let outgoing: Vec<Vec<f32>> = (0..p)
            .map(|r| {
                let c = (r + p - s) % p;
                let (lo, hi) = chunks[c];
                bufs[r][lo..hi].to_vec()
            })
            .collect();
        let mut step_cost = ctx.sendrecv_cost(max_chunk_bytes);
        step_cost.driver_us = ctx.driver_cost_us(0);
        // every rank reduces its received chunk; identical work, charge once
        let mut reduce_cost = Default::default();
        for r in 0..p {
            let left = (r + p - 1) % p;
            let c = (left + p - s) % p;
            let (lo, hi) = chunks[c];
            let incoming = &outgoing[left];
            let mut acc = std::mem::take(&mut bufs[r]);
            let rc = ctx.reduce_into(&mut acc[lo..hi], incoming);
            bufs[r] = acc;
            reduce_cost = rc; // same every rank
        }
        step_cost.add(&reduce_cost);
        report.cost.add(&step_cost);
        report.steps += 1;
        report.wire_bytes_per_rank += max_chunk_bytes;
    }

    // ---- allgather: p−1 steps ----
    // After reduce-scatter, rank r owns fully-reduced chunk (r+1) mod p.
    for s in 0..p - 1 {
        let outgoing: Vec<Vec<f32>> = (0..p)
            .map(|r| {
                let c = (r + 1 + p - s) % p;
                let (lo, hi) = chunks[c];
                bufs[r][lo..hi].to_vec()
            })
            .collect();
        let mut step_cost = ctx.sendrecv_cost(max_chunk_bytes);
        step_cost.driver_us = ctx.driver_cost_us(0);
        for r in 0..p {
            let left = (r + p - 1) % p;
            let c = (left + 1 + p - s) % p;
            let (lo, hi) = chunks[c];
            bufs[r][lo..hi].copy_from_slice(&outgoing[left]);
        }
        report.cost.add(&step_cost);
        report.steps += 1;
        report.wire_bytes_per_rank += max_chunk_bytes;
    }

    report.time = SimTime::from_us(report.cost.total_us());
    report
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_allreduced, ctx_gdr, make_bufs};
    use super::super::serial_oracle;
    use super::*;

    #[test]
    fn correct_for_various_p_and_n() {
        for p in [1, 2, 3, 4, 5, 8, 16] {
            for n in [0, 1, 7, 64, 1000] {
                let mut bufs = make_bufs(p, n, (p * 1000 + n) as u64);
                let oracle = serial_oracle(&bufs);
                let mut ctx = ctx_gdr();
                ring_allreduce(&mut bufs, &mut ctx);
                assert_allreduced(&bufs, &oracle, 1e-4);
            }
        }
    }

    #[test]
    fn step_count_is_2_p_minus_1() {
        let mut bufs = make_bufs(8, 64, 1);
        let mut ctx = ctx_gdr();
        let r = ring_allreduce(&mut bufs, &mut ctx);
        assert_eq!(r.steps, 14);
    }

    #[test]
    fn bandwidth_optimal_wire_bytes() {
        // each rank moves ~2·n·(p−1)/p bytes
        let (p, n) = (8, 8000);
        let mut bufs = make_bufs(p, n, 2);
        let mut ctx = ctx_gdr();
        let r = ring_allreduce(&mut bufs, &mut ctx);
        let ideal = 2 * n * 4 * (p - 1) / p;
        let rel = (r.wire_bytes_per_rank as f64 - ideal as f64).abs() / ideal as f64;
        assert!(rel < 0.01, "{} vs ideal {ideal}", r.wire_bytes_per_rank);
    }

    #[test]
    fn single_rank_is_noop() {
        let mut bufs = vec![vec![1.0, 2.0, 3.0]];
        let mut ctx = ctx_gdr();
        let r = ring_allreduce(&mut bufs, &mut ctx);
        assert_eq!(bufs[0], vec![1.0, 2.0, 3.0]);
        assert_eq!(r.steps, 0);
        assert_eq!(r.time, crate::sim::SimTime::ZERO);
    }

    #[test]
    fn latency_grows_linearly_with_p_for_small_msgs() {
        let mut ctx = ctx_gdr();
        let t = |p: usize, ctx: &mut super::AllreduceCtx| {
            let mut bufs = make_bufs(p, 2, 3);
            ring_allreduce(&mut bufs, ctx).time.as_us()
        };
        let t4 = t(4, &mut ctx);
        let t16 = t(16, &mut ctx);
        // 2(p−1) steps: 30/6 = 5× more steps
        let ratio = t16 / t4;
        assert!(ratio > 3.5 && ratio < 6.5, "ratio {ratio}");
    }

    #[test]
    fn chunks_cover_everything() {
        for (n, p) in [(10, 3), (7, 7), (5, 8), (100, 16)] {
            let c = chunk_ranges(n, p);
            assert_eq!(c.len(), p);
            assert_eq!(c[0].0, 0);
            assert_eq!(c[p - 1].1, n);
            for w in c.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }
}
