//! Binomial-tree reduce + broadcast — the small-message algorithm in
//! MPICH-family runtimes (latency-optimal: 2·⌈log₂p⌉ α-steps, but each
//! step carries the FULL vector, so it loses to RSA once n/β matters).

use super::{AllreduceCtx, AllreduceReport};
use crate::sim::SimTime;

/// In-place binomial-tree allreduce over `bufs[p][n]` (sum, root 0).
pub fn tree_allreduce(bufs: &mut [Vec<f32>], ctx: &mut AllreduceCtx) -> AllreduceReport {
    let p = bufs.len();
    assert!(p >= 1);
    let n = bufs[0].len();
    let mut report = AllreduceReport { algo: "tree", ..Default::default() };
    if p == 1 || n == 0 {
        return report;
    }
    ctx.register_ranks(p, (n * 4) as u64);
    let bytes = n * 4;

    // ---- reduce to root (rank 0) ----
    // round k: ranks where bit k is the lowest set bit send to r − 2^k.
    let mut dist = 1;
    while dist < p {
        let mut any = false;
        let mut step = ctx.sendrecv_cost(bytes);
        step.driver_us = ctx.driver_cost_us(0);
        let mut red = Default::default();
        let senders: Vec<usize> = (0..p)
            .filter(|r| r % (2 * dist) == dist)
            .collect();
        for &src in &senders {
            let dst = src - dist;
            let incoming = bufs[src].clone();
            let mut acc = std::mem::take(&mut bufs[dst]);
            red = ctx.reduce_into(&mut acc, &incoming);
            bufs[dst] = acc;
            any = true;
        }
        if any {
            step.add(&red);
            report.cost.add(&step);
            report.steps += 1;
            report.wire_bytes_per_rank += bytes;
        }
        dist *= 2;
    }

    // ---- broadcast from root ----
    let mut dist = p.next_power_of_two() / 2;
    while dist >= 1 {
        let mut any = false;
        let mut step = ctx.sendrecv_cost(bytes);
        step.driver_us = ctx.driver_cost_us(0);
        for src in (0..p).step_by(2 * dist) {
            let dst = src + dist;
            if dst < p {
                let data = bufs[src].clone();
                bufs[dst].copy_from_slice(&data);
                any = true;
            }
        }
        if any {
            report.cost.add(&step);
            report.steps += 1;
            report.wire_bytes_per_rank += bytes;
        }
        dist /= 2;
    }

    report.time = SimTime::from_us(report.cost.total_us());
    report
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_allreduced, ctx_gdr, make_bufs};
    use super::super::{rhd_allreduce, serial_oracle};
    use super::*;

    #[test]
    fn correct_for_various_p() {
        for p in [1, 2, 3, 4, 5, 7, 8, 13, 16] {
            for n in [0, 1, 33, 500] {
                let mut bufs = make_bufs(p, n, (p * 13 + n) as u64);
                let oracle = serial_oracle(&bufs);
                let mut ctx = ctx_gdr();
                tree_allreduce(&mut bufs, &mut ctx);
                assert_allreduced(&bufs, &oracle, 1e-4);
            }
        }
    }

    #[test]
    fn step_count_2_ceil_log2() {
        let mut ctx = ctx_gdr();
        for (p, want) in [(2, 2), (4, 4), (8, 6), (16, 8), (5, 6)] {
            let mut bufs = make_bufs(p, 16, 3);
            let r = tree_allreduce(&mut bufs, &mut ctx);
            assert_eq!(r.steps, want, "p={p}");
        }
    }

    #[test]
    fn beats_rsa_only_on_small_messages() {
        let p = 16;
        // tiny message: tree (log steps, full-but-tiny vector) ≈ RHD —
        // both are α-bound; large message: tree must lose (full vector
        // every step).
        let t = |algo: fn(&mut [Vec<f32>], &mut super::AllreduceCtx) -> AllreduceReport,
                 n: usize| {
            let mut bufs = make_bufs(p, n, 4);
            let mut ctx = ctx_gdr();
            algo(&mut bufs, &mut ctx).time.as_us()
        };
        let large = 1 << 20;
        assert!(t(tree_allreduce, large) > 2.0 * t(rhd_allreduce, large));
    }
}
