//! Recursive vector halving/doubling reduce-scatter-allgather
//! (Thakur, Rabenseifner & Gropp) — the algorithm inside MPICH/MVAPICH2
//! and the carrier of the paper's §V-A optimization: same 2·log₂p step
//! structure, but with the reduction offloaded to the GPU kernel and the
//! pointer cache killing the per-step driver queries.
//!
//! The same function therefore serves three library personalities, chosen
//! purely by `AllreduceCtx`:
//!   stock MVAPICH2  = Staged transport + Cpu reduce + no pointer cache
//!   Cray-MPICH      = Staged + Cpu (no GDR on Aries)
//!   MVAPICH2-GDR-Opt= Gdr + Gpu kernel + Intercept cache   ← the paper
//!
//! Non-power-of-two worlds use the standard MPICH pre/post phase: the
//! first `rem` odd ranks fold into their even neighbour, the power-of-two
//! core runs RHD, and the result is mirrored back.

use super::{AllreduceCtx, AllreduceReport};
use crate::sim::SimTime;

/// In-place recursive halving/doubling allreduce over `bufs[p][n]` (sum).
pub fn rhd_allreduce(bufs: &mut [Vec<f32>], ctx: &mut AllreduceCtx) -> AllreduceReport {
    let p = bufs.len();
    assert!(p >= 1);
    let n = bufs[0].len();
    let mut report = AllreduceReport { algo: "rhd", ..Default::default() };
    if p == 1 || n == 0 {
        return report;
    }
    ctx.register_ranks(p, (n * 4) as u64);

    let p2 = super::flp2(p);
    let rem = p - p2;
    let full_bytes = n * 4;

    // ---- pre-phase: fold the `rem` extra ranks in (ranks 2i+1 → 2i) ----
    if rem > 0 {
        let mut step = ctx.sendrecv_cost(full_bytes);
        step.driver_us = ctx.driver_cost_us(0);
        let mut red = Default::default();
        for i in 0..rem {
            let (dst, src) = (2 * i, 2 * i + 1);
            let incoming = bufs[src].clone();
            let mut acc = std::mem::take(&mut bufs[dst]);
            red = ctx.reduce_into(&mut acc, &incoming);
            bufs[dst] = acc;
        }
        step.add(&red);
        report.cost.add(&step);
        report.steps += 1;
        report.wire_bytes_per_rank += full_bytes;
    }

    // active set: evens among the first 2·rem ranks, then the tail
    let active: Vec<usize> =
        (0..rem).map(|i| 2 * i).chain(2 * rem..p).collect();
    debug_assert_eq!(active.len(), p2);

    // ---- reduce-scatter by recursive halving ----
    // range[a] = current [lo, hi) of active rank a; pre[a] = stack of
    // pre-step ranges for the doubling phase.
    let mut range = vec![(0usize, n); p2];
    let mut pre: Vec<Vec<(usize, usize)>> = vec![Vec::new(); p2];
    let mut masks = Vec::new();
    let mut mask = p2 >> 1;
    while mask > 0 {
        masks.push(mask);
        // Pairs (a, a^mask) share the same current range; the keeper of
        // the lower half reduces the partner's UNMODIFIED lower half while
        // the partner reduces the keeper's UNMODIFIED upper half — the
        // reads and writes are disjoint, so the exchange runs zero-copy
        // over two mutable borrows (§Perf: this removed the per-step
        // half-vector snapshots, ~2.3× on the 16×4MB hot path).
        let mut max_half = 0usize;
        let mut step_driver = 0.0;
        let mut red = Default::default();
        for a in 0..p2 {
            let partner = a ^ mask;
            if a > partner {
                continue; // each pair processed once
            }
            let (lo, hi) = range[a];
            debug_assert_eq!(range[partner], (lo, hi));
            let mid = lo + (hi - lo) / 2;
            pre[a].push((lo, hi));
            pre[partner].push((lo, hi));
            max_half = max_half.max((mid - lo).max(hi - mid));
            // a & mask == 0 ⇒ a keeps the lower half, partner the upper
            let (ra, rp) = (active[a], active[partner]);
            let (first, second) = if ra < rp {
                let (x, y) = bufs.split_at_mut(rp);
                (&mut x[ra], &mut y[0])
            } else {
                let (x, y) = bufs.split_at_mut(ra);
                (&mut y[0], &mut x[rp])
            };
            // `first` is a's buffer, `second` is partner's
            let incoming_lower = &second[lo..mid];
            let _ = ctx.reduce_into(&mut first[lo..mid], incoming_lower);
            let incoming_upper = &first[mid..hi];
            red = ctx.reduce_into(&mut second[mid..hi], incoming_upper);
            range[a] = (lo, mid);
            range[partner] = (mid, hi);
        }
        step_driver += ctx.driver_cost_us(0);
        let mut step = ctx.sendrecv_cost(max_half * 4);
        step.driver_us = step_driver;
        step.add(&red);
        report.cost.add(&step);
        report.steps += 1;
        report.wire_bytes_per_rank += max_half * 4;
        mask >>= 1;
    }

    // ---- allgather by recursive doubling (reverse order) ----
    for &mask in masks.iter().rev() {
        // snapshot everyone's currently-owned (fully reduced) segment
        // Pairwise zero-copy exchange: a and a^mask own complementary,
        // disjoint segments, so both directions copy straight between the
        // two buffers (§Perf: replaced the per-step whole-segment
        // snapshots).
        let max_seg = range.iter().map(|&(lo, hi)| hi - lo).max().unwrap_or(0);
        let mut step = ctx.sendrecv_cost(max_seg * 4);
        step.driver_us = ctx.driver_cost_us(0);
        for a in 0..p2 {
            let partner = a ^ mask;
            if a > partner {
                continue;
            }
            let (alo, ahi) = range[a];
            let (plo, phi) = range[partner];
            let (ra, rp) = (active[a], active[partner]);
            let (first, second) = if ra < rp {
                let (x, y) = bufs.split_at_mut(rp);
                (&mut x[ra], &mut y[0])
            } else {
                let (x, y) = bufs.split_at_mut(ra);
                (&mut y[0], &mut x[rp])
            };
            first[plo..phi].copy_from_slice(&second[plo..phi]);
            second[alo..ahi].copy_from_slice(&first[alo..ahi]);
            range[a] = pre[a].pop().expect("range history underflow");
            range[partner] = pre[partner].pop().expect("range history underflow");
        }
        report.cost.add(&step);
        report.steps += 1;
        report.wire_bytes_per_rank += max_seg * 4;
    }
    debug_assert!(range.iter().all(|&(lo, hi)| (lo, hi) == (0, n)));

    // ---- post-phase: mirror results back to the folded ranks ----
    if rem > 0 {
        let mut step = ctx.sendrecv_cost(full_bytes);
        step.driver_us = ctx.driver_cost_us(0);
        for i in 0..rem {
            let (src, dst) = (2 * i, 2 * i + 1);
            let data = bufs[src].clone();
            bufs[dst].copy_from_slice(&data);
        }
        report.cost.add(&step);
        report.steps += 1;
        report.wire_bytes_per_rank += full_bytes;
    }

    report.time = SimTime::from_us(report.cost.total_us());
    report
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_allreduced, ctx_gdr, make_bufs};
    use super::super::{ring_allreduce, serial_oracle};
    use super::*;

    #[test]
    fn correct_for_pow2_worlds() {
        for p in [2, 4, 8, 16, 32] {
            for n in [1, 2, 7, 64, 1000] {
                let mut bufs = make_bufs(p, n, (p * 7 + n) as u64);
                let oracle = serial_oracle(&bufs);
                let mut ctx = ctx_gdr();
                rhd_allreduce(&mut bufs, &mut ctx);
                assert_allreduced(&bufs, &oracle, 1e-4);
            }
        }
    }

    #[test]
    fn correct_for_non_pow2_worlds() {
        for p in [3, 5, 6, 7, 9, 12, 13] {
            for n in [1, 17, 256, 999] {
                let mut bufs = make_bufs(p, n, (p * 31 + n) as u64);
                let oracle = serial_oracle(&bufs);
                let mut ctx = ctx_gdr();
                rhd_allreduce(&mut bufs, &mut ctx);
                assert_allreduced(&bufs, &oracle, 1e-4);
            }
        }
    }

    #[test]
    fn step_count_logarithmic() {
        let mut ctx = ctx_gdr();
        let mut bufs = make_bufs(16, 64, 1);
        let r = rhd_allreduce(&mut bufs, &mut ctx);
        assert_eq!(r.steps, 8); // 2·log₂16

        let mut bufs = make_bufs(6, 64, 1);
        let r = rhd_allreduce(&mut bufs, &mut ctx);
        assert_eq!(r.steps, 2 * 2 + 2); // pre + 2·log₂4 + post
    }

    #[test]
    fn fewer_alpha_steps_than_ring_at_scale_small_msgs() {
        let mut ctx = ctx_gdr();
        let p = 16;
        let mut b1 = make_bufs(p, 2, 5);
        let t_rhd = rhd_allreduce(&mut b1, &mut ctx).time.as_us();
        let mut ctx2 = ctx_gdr();
        let mut b2 = make_bufs(p, 2, 5);
        let t_ring = ring_allreduce(&mut b2, &mut ctx2).time.as_us();
        assert!(
            t_rhd < 0.5 * t_ring,
            "RHD ({t_rhd}us) should beat ring ({t_ring}us) on small messages at p=16"
        );
    }

    #[test]
    fn wire_bytes_near_optimal_pow2() {
        let (p, n) = (16, 16384);
        let mut bufs = make_bufs(p, n, 6);
        let mut ctx = ctx_gdr();
        let r = rhd_allreduce(&mut bufs, &mut ctx);
        let ideal = 2 * n * 4 * (p - 1) / p;
        let ratio = r.wire_bytes_per_rank as f64 / ideal as f64;
        assert!(ratio < 1.1, "wire bytes {} vs ideal {ideal}", r.wire_bytes_per_rank);
    }

    #[test]
    fn matches_ring_numerics() {
        // both algorithms must produce identical results up to fp
        // reassociation on the same inputs
        let mut a = make_bufs(8, 500, 9);
        let mut b = a.clone();
        let mut ctx1 = ctx_gdr();
        let mut ctx2 = ctx_gdr();
        rhd_allreduce(&mut a, &mut ctx1);
        ring_allreduce(&mut b, &mut ctx2);
        for (x, y) in a[0].iter().zip(b[0].iter()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }
}
