//! Shadow (data-free) cost evaluation of the allreduce algorithms.
//!
//! The figure harness sweeps message sizes up to 256 MB across up to 128
//! ranks; materializing real per-rank buffers there would mean tens of
//! gigabytes per point.  These functions replay the *exact* step/cost
//! structure of ring.rs / rhd.rs / tree.rs without touching data.
//! `tests::shadow_matches_real` pins them to the real implementations
//! bit-for-bit on the virtual clock, so they cannot drift silently.
//!
//! Since the `CommOp` refactor the shadow pass is also the **schedule
//! generator**: [`shadow_steps`] emits one [`StepCost`] per algorithm
//! step, from which both the serialized [`CommSchedule`]
//! ([`shadow_schedule`]) and the per-rank dependency graphs
//! (`comm::graph::allreduce_graph`) are derived, and [`shadow_cost`] is
//! the aggregate — so everything the strategies replay onto the engine is
//! pinned to the real-data implementations by the same tests.

use super::{Algo, AllreduceCtx, AllreduceReport, ReducePlace};
use crate::comm::commop::{CommSchedule, StepCost};
use crate::comm::CostBreakdown;
use crate::sim::SimTime;

/// Cost of an `Algo` allreduce of `n` f32 elements across `p` ranks.
pub fn shadow_cost(algo: Algo, p: usize, n: usize, ctx: &mut AllreduceCtx) -> AllreduceReport {
    shadow_steps(algo, p, n, ctx).0
}

/// Cost *and* the per-algorithm-step cost sequence of the allreduce — the
/// single source both the serialized `CommSchedule` and the per-rank
/// `CommGraph` builders consume.
pub fn shadow_steps(
    algo: Algo,
    p: usize,
    n: usize,
    ctx: &mut AllreduceCtx,
) -> (AllreduceReport, Vec<StepCost>) {
    let mut steps = Vec::new();
    let report = match algo {
        Algo::Ring => ring_shadow(p, n, ctx, &mut steps),
        Algo::Rhd => rhd_shadow(p, n, ctx, &mut steps),
        Algo::Tree => tree_shadow(p, n, ctx, &mut steps),
    };
    (report, steps)
}

/// Cost *and* the per-step `CommOp` schedule of the allreduce.
pub fn shadow_schedule(
    algo: Algo,
    p: usize,
    n: usize,
    ctx: &mut AllreduceCtx,
) -> (AllreduceReport, CommSchedule) {
    let (report, steps) = shadow_steps(algo, p, n, ctx);
    let sched = CommSchedule::from_steps(&steps);
    debug_assert!(
        (report.cost.total_us() - sched.total_us()).abs() < 1e-6,
        "schedule/cost divergence: {} vs {}",
        report.cost.total_us(),
        sched.total_us()
    );
    (report, sched)
}

fn gpu_reduce(ctx: &AllreduceCtx) -> bool {
    matches!(ctx.reduce, ReducePlace::Gpu | ReducePlace::GpuPjrt(_))
}

/// Account one algorithm step: fold it into the aggregate report and
/// append it to the step sequence.
fn account(
    report: &mut AllreduceReport,
    steps: &mut Vec<StepCost>,
    step: &CostBreakdown,
    wire_bytes: usize,
    gpu: bool,
) {
    report.cost.add(step);
    report.steps += 1;
    report.wire_bytes_per_rank += wire_bytes;
    steps.push(StepCost { cost: *step, gpu_reduce: gpu });
}

fn chunk_len(n: usize, p: usize, i: usize) -> usize {
    n / p + usize::from(i < n % p)
}

fn ring_shadow(
    p: usize,
    n: usize,
    ctx: &mut AllreduceCtx,
    steps: &mut Vec<StepCost>,
) -> AllreduceReport {
    let mut report = AllreduceReport { algo: "ring", ..Default::default() };
    if p == 1 || n == 0 {
        return report;
    }
    let gpu = gpu_reduce(ctx);
    ctx.register_ranks(p, (n * 4) as u64);
    let max_chunk_bytes = 4 * chunk_len(n, p, 0);
    for s in 0..p - 1 {
        let mut step = ctx.sendrecv_cost(max_chunk_bytes);
        step.driver_us = ctx.driver_cost_us(0);
        // real code keeps the reduce cost of the LAST rank (r = p−1)
        let left = p - 2;
        let c = (left + p - s) % p;
        step.add(&ctx.reduce.clone().cost(ctx, 4 * chunk_len(n, p, c)));
        account(&mut report, steps, &step, max_chunk_bytes, gpu);
    }
    for _s in 0..p - 1 {
        let mut step = ctx.sendrecv_cost(max_chunk_bytes);
        step.driver_us = ctx.driver_cost_us(0);
        account(&mut report, steps, &step, max_chunk_bytes, gpu);
    }
    report.time = SimTime::from_us(report.cost.total_us());
    report
}

fn rhd_shadow(
    p: usize,
    n: usize,
    ctx: &mut AllreduceCtx,
    steps: &mut Vec<StepCost>,
) -> AllreduceReport {
    let mut report = AllreduceReport { algo: "rhd", ..Default::default() };
    if p == 1 || n == 0 {
        return report;
    }
    let gpu = gpu_reduce(ctx);
    ctx.register_ranks(p, (n * 4) as u64);
    let p2 = super::flp2(p);
    let rem = p - p2;
    let full_bytes = n * 4;

    if rem > 0 {
        let mut step = ctx.sendrecv_cost(full_bytes);
        step.driver_us = ctx.driver_cost_us(0);
        step.add(&ctx.reduce.clone().cost(ctx, full_bytes));
        account(&mut report, steps, &step, full_bytes, gpu);
    }

    let mut range = vec![(0usize, n); p2];
    let mut pre: Vec<Vec<(usize, usize)>> = vec![Vec::new(); p2];
    let mut masks = Vec::new();
    let mut mask = p2 >> 1;
    while mask > 0 {
        masks.push(mask);
        // max over ranks of the larger half (mirrors the real snapshot)
        let mut max_half = 0usize;
        let mut last_red_bytes = 0usize;
        for (a, &(lo, hi)) in range.iter().enumerate() {
            let mid = lo + (hi - lo) / 2;
            let send = if a & mask == 0 { hi - mid } else { mid - lo };
            max_half = max_half.max(send.max((hi - lo) - send));
            last_red_bytes = 4 * if a & mask == 0 { mid - lo } else { hi - mid };
        }
        let mut step = ctx.sendrecv_cost(max_half * 4);
        step.driver_us = ctx.driver_cost_us(0);
        step.add(&ctx.reduce.clone().cost(ctx, last_red_bytes));
        for a in 0..p2 {
            let (lo, hi) = range[a];
            let mid = lo + (hi - lo) / 2;
            pre[a].push((lo, hi));
            range[a] = if a & mask == 0 { (lo, mid) } else { (mid, hi) };
        }
        account(&mut report, steps, &step, max_half * 4, gpu);
        mask >>= 1;
    }

    for &_mask in masks.iter().rev() {
        let max_seg = range.iter().map(|&(lo, hi)| hi - lo).max().unwrap_or(0);
        let mut step = ctx.sendrecv_cost(max_seg * 4);
        step.driver_us = ctx.driver_cost_us(0);
        for a in 0..p2 {
            range[a] = pre[a].pop().expect("range history underflow");
        }
        account(&mut report, steps, &step, max_seg * 4, gpu);
    }

    if rem > 0 {
        let mut step = ctx.sendrecv_cost(full_bytes);
        step.driver_us = ctx.driver_cost_us(0);
        account(&mut report, steps, &step, full_bytes, gpu);
    }
    report.time = SimTime::from_us(report.cost.total_us());
    report
}

fn tree_shadow(
    p: usize,
    n: usize,
    ctx: &mut AllreduceCtx,
    steps: &mut Vec<StepCost>,
) -> AllreduceReport {
    let mut report = AllreduceReport { algo: "tree", ..Default::default() };
    if p == 1 || n == 0 {
        return report;
    }
    let gpu = gpu_reduce(ctx);
    ctx.register_ranks(p, (n * 4) as u64);
    let bytes = n * 4;
    let mut dist = 1;
    while dist < p {
        let any = (0..p).any(|r| r % (2 * dist) == dist);
        if any {
            let mut step = ctx.sendrecv_cost(bytes);
            step.driver_us = ctx.driver_cost_us(0);
            step.add(&ctx.reduce.clone().cost(ctx, bytes));
            account(&mut report, steps, &step, bytes, gpu);
        }
        dist *= 2;
    }
    let mut dist = p.next_power_of_two() / 2;
    while dist >= 1 {
        let any = (0..p).step_by(2 * dist).any(|src| src + dist < p);
        if any {
            let mut step = ctx.sendrecv_cost(bytes);
            step.driver_us = ctx.driver_cost_us(0);
            account(&mut report, steps, &step, bytes, gpu);
        }
        dist /= 2;
    }
    report.time = SimTime::from_us(report.cost.total_us());
    report
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{ctx_gdr, make_bufs};
    use super::super::{rhd_allreduce, ring_allreduce, tree_allreduce};
    use super::*;

    /// THE pin: shadow cost == real-data cost on the virtual clock.
    #[test]
    fn shadow_matches_real() {
        for p in [2usize, 3, 4, 5, 8, 13, 16] {
            for n in [1usize, 7, 255, 4096, 100_000] {
                for algo in [Algo::Ring, Algo::Rhd, Algo::Tree] {
                    let mut bufs = make_bufs(p, n, (p * 31 + n) as u64);
                    let mut ctx_real = ctx_gdr();
                    let real = match algo {
                        Algo::Ring => ring_allreduce(&mut bufs, &mut ctx_real),
                        Algo::Rhd => rhd_allreduce(&mut bufs, &mut ctx_real),
                        Algo::Tree => tree_allreduce(&mut bufs, &mut ctx_real),
                    };
                    let mut ctx_shadow = ctx_gdr();
                    let shadow = shadow_cost(algo, p, n, &mut ctx_shadow);
                    assert_eq!(real.steps, shadow.steps, "{algo:?} p={p} n={n} steps");
                    assert_eq!(
                        real.wire_bytes_per_rank, shadow.wire_bytes_per_rank,
                        "{algo:?} p={p} n={n} wire bytes"
                    );
                    let d = (real.cost.total_us() - shadow.cost.total_us()).abs();
                    assert!(d < 1e-6, "{algo:?} p={p} n={n}: real {} vs shadow {}",
                        real.cost.total_us(), shadow.cost.total_us());
                }
            }
        }
    }

    /// Shadow also matches under the stock (staged + CPU + no-cache) ctx,
    /// where driver-query state evolves per step.
    #[test]
    fn shadow_matches_real_stock_ctx() {
        use crate::cluster::presets;
        use crate::comm::allreduce::{ReducePlace, TransportMode};
        use crate::comm::ptrcache::CacheMode;
        let mk = || {
            let c = presets::ri2();
            AllreduceCtx::new(
                c.fabric.clone(),
                c.gpu.clone(),
                TransportMode::Staged,
                ReducePlace::Cpu { gbs: 2.0 },
                CacheMode::None,
                c.driver_query_us,
            )
        };
        for p in [4usize, 6, 16] {
            for n in [16usize, 9999] {
                let mut bufs = make_bufs(p, n, 3);
                let mut c1 = mk();
                let real = rhd_allreduce(&mut bufs, &mut c1);
                let mut c2 = mk();
                let shadow = shadow_cost(Algo::Rhd, p, n, &mut c2);
                let d = (real.cost.total_us() - shadow.cost.total_us()).abs();
                assert!(d < 1e-6, "p={p} n={n}: {d}");
            }
        }
    }

    #[test]
    fn shadow_huge_sizes_cheap() {
        // 256MB × 128 ranks — must run in microseconds of wall time.
        let mut ctx = ctx_gdr();
        let r = shadow_cost(Algo::Rhd, 128, 64 << 20, &mut ctx);
        assert!(r.time.as_ms() > 1.0);
        assert_eq!(r.steps, 14);
    }

    /// The schedule is the cost: per-component totals must agree with the
    /// aggregate breakdown for every algorithm and context.
    #[test]
    fn schedule_breakdown_matches_report() {
        for algo in [Algo::Ring, Algo::Rhd, Algo::Tree] {
            for (p, n) in [(2usize, 64usize), (5, 1000), (16, 100_000)] {
                let mut ctx = ctx_gdr();
                let (report, sched) = shadow_schedule(algo, p, n, &mut ctx);
                let derived = sched.breakdown();
                for (a, b) in [
                    (report.cost.wire_us, derived.wire_us),
                    (report.cost.staging_us, derived.staging_us),
                    (report.cost.reduce_us, derived.reduce_us),
                    (report.cost.driver_us, derived.driver_us),
                    (report.cost.launch_us, derived.launch_us),
                    (report.cost.sw_us, derived.sw_us),
                ] {
                    assert!((a - b).abs() < 1e-6, "{algo:?} p={p} n={n}: {a} vs {b}");
                }
                assert!((sched.total_us() - report.time.as_us()).abs() < 1e-6);
            }
        }
    }
}
