//! Allreduce algorithms over real buffers with modeled time.
//!
//! Three algorithm families from the paper (§V-A):
//!  * `ring`  — ring reduce-scatter-allgather (NCCL, Baidu): 2(p−1) steps,
//!    bandwidth-optimal, latency-heavy at scale.
//!  * `rhd`   — recursive vector halving/doubling RSA (MPICH, MVAPICH2,
//!    and the paper's optimized design): 2·log₂p steps.
//!  * `tree`  — binomial reduce+broadcast for small messages.
//!
//! Every implementation moves **real f32 data** between the per-rank
//! buffers and is pinned to `serial_oracle` by tests; the returned
//! `AllreduceReport` carries the virtual-clock cost on the configured
//! fabric (DESIGN.md §5's cost model).

pub mod reduce;
pub mod rhd;
pub mod ring;
pub mod shadow;
pub mod tree;

pub use reduce::{ReducePlace, TransportMode};
pub use rhd::rhd_allreduce;
pub use ring::ring_allreduce;
pub use shadow::{shadow_cost, shadow_schedule, shadow_steps};
pub use tree::tree_allreduce;

use crate::cluster::{Fabric, GpuModel, Link};
use crate::comm::ptrcache::{BufKind, CacheMode, CudaDriverSim, PointerCache};
use crate::comm::CostBreakdown;
use crate::sim::SimTime;

/// Everything an allreduce needs to know about the machine + runtime
/// configuration.  Owns the *real* simulated-driver + pointer-cache state
/// so query counts and staleness behaviour are exercised, not assumed.
pub struct AllreduceCtx {
    pub fabric: Fabric,
    pub gpu: GpuModel,
    /// Link used for the collective's inter-node hops (usually
    /// `fabric.inter`; NCCL substitutes its own effective link).
    pub wire: Link,
    pub transport: TransportMode,
    pub reduce: ReducePlace,
    /// Pointer-attribute resolves per buffer per p2p operation (paper
    /// Fig 5 shows several driver-module hops; stock MVAPICH2 re-queries
    /// each time).  NCCL-style implementations set 0.
    pub attrs_per_buffer: usize,
    /// Fixed per-p2p-op software overhead, µs (matching, tag lookup).
    pub p2p_sw_us: f64,
    pub driver: CudaDriverSim,
    pub cache: PointerCache,
    /// Registered (send, recv) device pointers, one pair per rank.
    bufs: Vec<(u64, u64)>,
}

impl AllreduceCtx {
    pub fn new(
        fabric: Fabric,
        gpu: GpuModel,
        transport: TransportMode,
        reduce: ReducePlace,
        cache_mode: CacheMode,
        driver_query_us: f64,
    ) -> Self {
        let wire = fabric.inter;
        AllreduceCtx {
            fabric,
            gpu,
            wire,
            transport,
            reduce,
            attrs_per_buffer: 4,
            p2p_sw_us: 0.5,
            driver: CudaDriverSim::new(driver_query_us),
            cache: PointerCache::new(cache_mode),
            bufs: Vec::new(),
        }
    }

    /// Register per-rank send/recv buffers with the simulated driver (what
    /// the application's cudaMalloc would have done).  In `Intercept` mode
    /// the pointer cache learns them here — off the critical path.
    pub fn register_ranks(&mut self, p: usize, bytes: u64) {
        self.bufs.clear();
        for _ in 0..p {
            let s = self.driver.cu_malloc(bytes.max(4));
            let r = self.driver.cu_malloc(bytes.max(4));
            self.cache.on_malloc(s, BufKind::Device);
            self.cache.on_malloc(r, BufKind::Device);
            self.bufs.push((s, r));
        }
    }

    /// Charge the driver-query cost a rank pays for one p2p operation
    /// (resolving both its send and recv buffer `attrs_per_buffer` times,
    /// as the stock runtime does on every MPI call).
    pub fn driver_cost_us(&mut self, rank: usize) -> f64 {
        if self.attrs_per_buffer == 0 || self.bufs.is_empty() {
            return 0.0;
        }
        let (s, r) = self.bufs[rank % self.bufs.len()];
        let mut us = 0.0;
        for _ in 0..self.attrs_per_buffer {
            us += self.cache.resolve(s, &mut self.driver).1;
            us += self.cache.resolve(r, &mut self.driver).1;
        }
        us
    }

    /// Cost of one synchronous sendrecv of `bytes` between two ranks
    /// (symmetric, so charged once per step): wire + optional staging.
    pub fn sendrecv_cost(&self, bytes: usize) -> CostBreakdown {
        let mut c = CostBreakdown { sw_us: self.p2p_sw_us, ..Default::default() };
        c.wire_us = self.wire.alpha_us + self.wire.wire_us(bytes);
        if self.transport == TransportMode::Staged {
            // D2H before send + H2D after recv
            c.staging_us = 2.0 * (self.fabric.pcie.alpha_us + self.fabric.pcie.wire_us(bytes));
        }
        c
    }

    /// Reduce `x` into `acc` (REAL data) and account its cost.
    pub fn reduce_into(&mut self, acc: &mut [f32], x: &[f32]) -> CostBreakdown {
        self.reduce.clone().reduce_into(self, acc, x)
    }
}

/// Result of one allreduce call.
#[derive(Debug, Clone, Default)]
pub struct AllreduceReport {
    pub algo: &'static str,
    pub time: SimTime,
    pub cost: CostBreakdown,
    pub steps: usize,
    /// Bytes each rank put on the wire (for BW-optimality checks).
    pub wire_bytes_per_rank: usize,
}

/// Nearest power of two ≤ `p` — the RHD "power-of-two core" (extra ranks
/// fold into it pre-collective and unfold post).  The real implementation
/// (rhd.rs), the shadow accounting (shadow.rs) and the graph builder
/// (comm/graph.rs) must all agree on this, so it lives in one place.
pub fn flp2(p: usize) -> usize {
    p.next_power_of_two() >> usize::from(!p.is_power_of_two())
}

/// Ground truth: elementwise sum across ranks.
pub fn serial_oracle(bufs: &[Vec<f32>]) -> Vec<f32> {
    let n = bufs[0].len();
    let mut out = vec![0.0f32; n];
    for b in bufs {
        assert_eq!(b.len(), n);
        for (o, x) in out.iter_mut().zip(b) {
            *o += x;
        }
    }
    out
}

/// Max |a−b| against the oracle — used by tests and the `validate` CLI.
pub fn max_abs_err(bufs: &[Vec<f32>], oracle: &[f32]) -> f32 {
    bufs.iter()
        .flat_map(|b| b.iter().zip(oracle).map(|(x, o)| (x - o).abs()))
        .fold(0.0, f32::max)
}

/// Algorithm choice, in the shape MVAPICH2-like runtimes select by size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    Tree,
    Ring,
    Rhd,
}

pub fn run_algo(
    algo: Algo,
    bufs: &mut [Vec<f32>],
    ctx: &mut AllreduceCtx,
) -> AllreduceReport {
    match algo {
        Algo::Tree => tree_allreduce(bufs, ctx),
        Algo::Ring => ring_allreduce(bufs, ctx),
        Algo::Rhd => rhd_allreduce(bufs, ctx),
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::cluster::presets;

    /// A default CUDA-aware GDR context on RI2 hardware.
    pub fn ctx_gdr() -> AllreduceCtx {
        let c = presets::ri2();
        AllreduceCtx::new(
            c.fabric.clone(),
            c.gpu.clone(),
            TransportMode::Gdr,
            ReducePlace::Gpu,
            CacheMode::Intercept,
            c.driver_query_us,
        )
    }

    /// Random per-rank buffers.
    pub fn make_bufs(p: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::prng::Rng::new(seed);
        (0..p).map(|_| rng.f32_vec(n)).collect()
    }

    pub fn assert_allreduced(bufs: &[Vec<f32>], oracle: &[f32], tol: f32) {
        let err = max_abs_err(bufs, oracle);
        assert!(err <= tol, "allreduce mismatch: max err {err} > {tol}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_sums_ranks() {
        let bufs = vec![vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        assert_eq!(serial_oracle(&bufs), vec![111.0, 222.0]);
    }

    #[test]
    fn max_err_detects_mismatch() {
        let oracle = vec![1.0, 1.0];
        let good = vec![vec![1.0, 1.0]];
        let bad = vec![vec![1.0, 1.5]];
        assert_eq!(max_abs_err(&good, &oracle), 0.0);
        assert!((max_abs_err(&bad, &oracle) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn ctx_registers_and_charges_queries() {
        let mut ctx = testutil::ctx_gdr();
        ctx.register_ranks(4, 1024);
        // Intercept mode: resolves are hash probes
        let us = ctx.driver_cost_us(0);
        assert!(us < 0.5, "intercepted resolve should be cheap, got {us}");
        assert_eq!(ctx.driver.queries, 0);
    }

    #[test]
    fn no_cache_charges_driver() {
        let c = crate::cluster::presets::ri2();
        let mut ctx = AllreduceCtx::new(
            c.fabric.clone(),
            c.gpu.clone(),
            TransportMode::Staged,
            ReducePlace::Cpu { gbs: 3.0 },
            CacheMode::None,
            c.driver_query_us,
        );
        ctx.register_ranks(2, 64);
        let us = ctx.driver_cost_us(0);
        // 4 attrs × 2 buffers × 1.0us
        assert!((us - 8.0).abs() < 1e-9, "got {us}");
        assert_eq!(ctx.driver.queries, 8);
    }
}
