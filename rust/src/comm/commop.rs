//! The `CommOp` schedule layer: collectives described as ordered
//! resource-occupancy steps, replayed onto the discrete-event engine.
//!
//! Before this layer, a collective call collapsed into one scalar
//! (`CostBreakdown::total()`), which a strategy could only add to a
//! hand-maintained float timeline — contention between jobs, stragglers,
//! and overlap were inexpressible.  Now a collective *emits* its step
//! structure — inter-node wire occupancy, PCIe staging, the GPU reduce
//! kernel, driver queries, per-step launches, software overhead — and the
//! strategy replays those ops onto shared `Engine` resources.  Durations
//! still come from the validated α–β cost models (pinned to the real-data
//! allreduce implementations by `shadow::tests`); *queueing* comes from
//! the engine's FIFO resources, so two schedules sharing a wire contend
//! the way two jobs on one fabric do.
//!
//! `CostBreakdown` is now **derived** from a schedule
//! ([`CommSchedule::breakdown`]) instead of being the primary artifact.

use std::rc::Rc;

use crate::comm::CostBreakdown;
use crate::sim::{Action, Engine, ProgStep, ResourceId, SimTime};

/// Which resource class a [`CommOp`] occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ResKind {
    /// Inter-node link (IB EDR / Aries / NCCL's effective ring link).
    Wire,
    /// Host↔device staging path (PCIe), shared with the training stream.
    Pcie,
    /// GPU reduction kernel occupancy (HBM-bandwidth bound).
    GpuReduce,
    /// CPU reduction loop occupancy.
    CpuReduce,
    /// CUDA driver pointer-attribute queries (serialized in the driver).
    Driver,
    /// Kernel-launch overhead.
    Launch,
    /// Software overhead: matching, negotiation, RPC dispatch, protobuf.
    Sw,
}

impl ResKind {
    pub const ALL: [ResKind; 7] = [
        ResKind::Wire,
        ResKind::Pcie,
        ResKind::GpuReduce,
        ResKind::CpuReduce,
        ResKind::Driver,
        ResKind::Launch,
        ResKind::Sw,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ResKind::Wire => "wire",
            ResKind::Pcie => "pcie",
            ResKind::GpuReduce => "gpu-reduce",
            ResKind::CpuReduce => "cpu-reduce",
            ResKind::Driver => "driver",
            ResKind::Launch => "launch",
            ResKind::Sw => "sw",
        }
    }

    /// The trace-span bucket of this resource class (§Observability) —
    /// the first seven [`SpanKind`](crate::sim::SpanKind)s mirror
    /// `ResKind` one-to-one.
    pub fn span_kind(self) -> crate::sim::SpanKind {
        use crate::sim::SpanKind;
        match self {
            ResKind::Wire => SpanKind::Wire,
            ResKind::Pcie => SpanKind::Pcie,
            ResKind::GpuReduce => SpanKind::GpuReduce,
            ResKind::CpuReduce => SpanKind::CpuReduce,
            ResKind::Driver => SpanKind::Driver,
            ResKind::Launch => SpanKind::Launch,
            ResKind::Sw => SpanKind::Sw,
        }
    }
}

/// A template-relative resource pin: *names* the resource an op needs
/// ("server 3's ingress NIC") instead of baking a concrete engine
/// [`ResourceId`] into the op.  The execution map resolves the name onto
/// that engine run's physical resources at replay time, which is what
/// lets PS fan-in templates live in the strategy-level
/// [`TemplateCache`](crate::comm::graph::TemplateCache) and replay
/// across calls and engines (the old engine-id pins made them
/// call-local).  Graph-path only: serialized replays keep `on`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RelPin {
    /// Ingress NIC queue of parameter server `s` (gradient pushes).
    PsIn(u32),
    /// Egress NIC queue of parameter server `s` (pull payloads).
    PsOut(u32),
    /// The single MPI service thread of worker `w` (gRPC+MPI §III-B1).
    WorkerTx(u32),
}

/// One resource-occupancy step of a communication operation.
///
/// `us` is the modeled duration (computed by the cost models at schedule
/// build time).  `on` optionally pins the op to a concrete engine
/// resource; `rel` pins it to a *named* resource the execution map
/// resolves at replay time ([`RelPin`]); otherwise the map resolves the
/// kind — and a kind the map does not back simply elapses as a pure
/// delay (per-rank private work that contends with nothing).
#[derive(Debug, Clone, Copy)]
pub struct CommOp {
    pub kind: ResKind,
    pub us: f64,
    pub on: Option<ResourceId>,
    /// Template-relative pin; consulted when `on` is `None`.
    pub rel: Option<RelPin>,
}

impl CommOp {
    pub fn fixed(kind: ResKind, us: f64) -> CommOp {
        CommOp { kind, us, on: None, rel: None }
    }

    pub fn pinned(self, r: ResourceId) -> CommOp {
        CommOp { on: Some(r), ..self }
    }

    /// Pin to a template-relative resource resolved at execute time.
    pub fn rel_pinned(self, pin: RelPin) -> CommOp {
        CommOp { rel: Some(pin), ..self }
    }
}

/// One algorithm step of a collective, still in cost-model form: the §V
/// component breakdown plus where its reduction runs.  This is the common
/// currency of the serialized [`CommSchedule`] (steps concatenated) and
/// the per-rank [`CommGraph`](crate::comm::graph::CommGraph) (one node per
/// rank per step) — both decompose a step into ops via
/// [`CommSchedule::push_step`], so they cannot drift from each other.
#[derive(Debug, Clone, Copy)]
pub struct StepCost {
    pub cost: CostBreakdown,
    pub gpu_reduce: bool,
}

impl StepCost {
    /// The step decomposed into causal-order ops (zero components drop).
    pub fn ops(&self) -> Vec<CommOp> {
        let mut s = CommSchedule::default();
        s.push_step(&self.cost, self.gpu_reduce);
        s.ops
    }
}

/// The exact bit pattern of a step sequence — the "step-cost signature"
/// part of a graph-template cache key (§Perf).  Two step sequences with
/// the same signature produce byte-identical graphs, and any change to
/// cluster, message size, derate or backend perturbs at least one f64
/// bit, so a cache keyed on this can never serve a stale template.
pub fn steps_sig(steps: &[StepCost]) -> Vec<u64> {
    let mut sig = Vec::with_capacity(steps.len() * 7);
    for st in steps {
        sig.push(st.cost.wire_us.to_bits());
        sig.push(st.cost.staging_us.to_bits());
        sig.push(st.cost.reduce_us.to_bits());
        sig.push(st.cost.driver_us.to_bits());
        sig.push(st.cost.launch_us.to_bits());
        sig.push(st.cost.sw_us.to_bits());
        sig.push(st.gpu_reduce as u64);
    }
    sig
}

/// An ordered list of [`CommOp`]s — the schedule of one collective (or
/// one PS transfer leg).  Ops execute strictly in order; concurrency
/// arises from *different* schedules contending on shared resources.
#[derive(Debug, Clone, Default)]
pub struct CommSchedule {
    pub ops: Vec<CommOp>,
}

impl CommSchedule {
    /// The serialized (critical-path) schedule of a step sequence.
    pub fn from_steps(steps: &[StepCost]) -> CommSchedule {
        let mut s = CommSchedule::default();
        for st in steps {
            s.push_step(&st.cost, st.gpu_reduce);
        }
        s
    }

    /// Append an op, dropping zero-duration ops (they would only bloat
    /// the event heap).
    pub fn push(&mut self, op: CommOp) {
        if op.us > 0.0 {
            self.ops.push(op);
        }
    }

    /// Append one cost-model step, decomposed by component in causal
    /// order: software overhead → driver queries → D2H staging → wire →
    /// H2D staging → kernel launch → reduction.
    pub fn push_step(&mut self, step: &CostBreakdown, gpu_reduce: bool) {
        self.push(CommOp::fixed(ResKind::Sw, step.sw_us));
        self.push(CommOp::fixed(ResKind::Driver, step.driver_us));
        self.push(CommOp::fixed(ResKind::Pcie, step.staging_us / 2.0));
        self.push(CommOp::fixed(ResKind::Wire, step.wire_us));
        self.push(CommOp::fixed(ResKind::Pcie, step.staging_us / 2.0));
        self.push(CommOp::fixed(ResKind::Launch, step.launch_us));
        let reduce = if gpu_reduce { ResKind::GpuReduce } else { ResKind::CpuReduce };
        self.push(CommOp::fixed(reduce, step.reduce_us));
    }

    pub fn extend(&mut self, other: &CommSchedule) {
        self.ops.extend_from_slice(&other.ops);
    }

    pub fn total_us(&self) -> f64 {
        self.ops.iter().map(|o| o.us).sum()
    }

    /// Scale every op duration by `s` (the Baidu per-tensor pipeline
    /// amortization uses this to spread fixed costs).
    pub fn scale(&mut self, s: f64) {
        for op in &mut self.ops {
            op.us *= s;
        }
    }

    /// Re-derive the paper's §V cost breakdown from the schedule.
    pub fn breakdown(&self) -> CostBreakdown {
        let mut c = CostBreakdown::default();
        for op in &self.ops {
            match op.kind {
                ResKind::Wire => c.wire_us += op.us,
                ResKind::Pcie => c.staging_us += op.us,
                ResKind::GpuReduce | ResKind::CpuReduce => c.reduce_us += op.us,
                ResKind::Driver => c.driver_us += op.us,
                ResKind::Launch => c.launch_us += op.us,
                ResKind::Sw => c.sw_us += op.us,
            }
        }
        c
    }
}

/// Resolves a [`ResKind`] to the engine resource backing it (or `None`
/// for per-rank work that elapses without contention).
pub type ResMap = Rc<dyn Fn(ResKind) -> Option<ResourceId>>;

/// The standard per-job resource bundle: one FIFO resource per kind.
/// Scenario runs share selected members across jobs (two jobs on one
/// fabric share `wire` but keep private PCIe/GPU/host resources).
#[derive(Debug, Clone, Copy)]
pub struct CommResources {
    pub wire: ResourceId,
    pub pcie: ResourceId,
    pub gpu: ResourceId,
    pub cpu: ResourceId,
    pub driver: ResourceId,
    pub launch: ResourceId,
    pub sw: ResourceId,
}

impl CommResources {
    pub fn install(e: &mut Engine) -> CommResources {
        let res = CommResources {
            wire: e.unit_resource(),
            pcie: e.unit_resource(),
            gpu: e.unit_resource(),
            cpu: e.unit_resource(),
            driver: e.unit_resource(),
            launch: e.unit_resource(),
            sw: e.unit_resource(),
        };
        if e.tracing() {
            for k in ResKind::ALL {
                e.trace_resource(
                    res.get(k),
                    k.span_kind(),
                    crate::sim::trace::PID_ENGINE,
                    0,
                    k.name(),
                );
            }
        }
        res
    }

    /// A second job's bundle that contends on an existing wire resource
    /// but owns every node-local resource.
    pub fn sharing_wire(e: &mut Engine, wire: ResourceId) -> CommResources {
        CommResources { wire, ..CommResources::install(e) }
    }

    pub fn get(&self, k: ResKind) -> ResourceId {
        match k {
            ResKind::Wire => self.wire,
            ResKind::Pcie => self.pcie,
            ResKind::GpuReduce => self.gpu,
            ResKind::CpuReduce => self.cpu,
            ResKind::Driver => self.driver,
            ResKind::Launch => self.launch,
            ResKind::Sw => self.sw,
        }
    }

    pub fn mapper(self) -> ResMap {
        Rc::new(move |k| Some(self.get(k)))
    }

    /// Per-resource (served, busy) snapshot for `IterationReport`.
    pub fn utilization(&self, e: &Engine) -> Vec<ResourceUse> {
        ResKind::ALL
            .iter()
            .map(|&k| {
                let s = e.resource_stats(self.get(k));
                ResourceUse { name: k.name().to_string(), served: s.served, busy: s.busy }
            })
            .filter(|u| u.served > 0)
            .collect()
    }
}

/// One row of the per-resource utilization report.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceUse {
    pub name: String,
    pub served: u64,
    pub busy: SimTime,
}

impl ResourceUse {
    /// Aggregate (served, busy) of a set of engine resources under one
    /// row name — NIC groups, per-rank bundles.
    pub fn aggregate<I>(e: &Engine, name: &str, ids: I) -> ResourceUse
    where
        I: IntoIterator<Item = ResourceId>,
    {
        let (mut served, mut busy) = (0u64, SimTime::ZERO);
        for r in ids {
            let s = e.resource_stats(r);
            served += s.served;
            busy += s.busy;
        }
        ResourceUse { name: name.to_string(), served, busy }
    }
}

/// Resolve a schedule against a resource map into an engine program:
/// each op becomes one [`ProgStep`] with its backing resource decided up
/// front (maps are pure, so eager resolution equals the old lazy per-op
/// lookup bit-for-bit).
pub fn resolve_ops(ops: &[CommOp], map: &ResMap) -> Rc<[ProgStep]> {
    ops.iter()
        .map(|op| {
            // rel pins are a graph-path concept: a kind-only map cannot
            // name resources, so a rel-pinned op here is a wiring bug
            debug_assert!(op.rel.is_none(), "rel-pinned op in a serialized replay");
            ProgStep { us: op.us, on: op.on.or_else(|| map(op.kind)) }
        })
        .collect::<Vec<_>>()
        .into()
}

/// Replay a schedule onto the engine: op *i+1* starts when op *i*
/// finishes service; each op queues FIFO on its backing resource.
/// `done` fires when the last op completes.  §Perf: this is a typed
/// engine program — one `Copy` event per op — where the old
/// implementation boxed a fresh continuation closure per op.
pub fn replay(e: &mut Engine, map: ResMap, ops: Rc<Vec<CommOp>>, done: Action) {
    let steps = resolve_ops(&ops, &map);
    e.run_program(steps, done);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;

    fn sched(ops: &[(ResKind, f64)]) -> Rc<Vec<CommOp>> {
        Rc::new(ops.iter().map(|&(k, us)| CommOp::fixed(k, us)).collect())
    }

    #[test]
    fn push_step_roundtrips_breakdown() {
        let step = CostBreakdown {
            wire_us: 10.0,
            staging_us: 6.0,
            reduce_us: 3.0,
            driver_us: 2.0,
            launch_us: 1.0,
            sw_us: 0.5,
        };
        let mut s = CommSchedule::default();
        s.push_step(&step, true);
        assert!((s.total_us() - step.total_us()).abs() < 1e-12);
        let back = s.breakdown();
        assert!((back.wire_us - 10.0).abs() < 1e-12);
        assert!((back.staging_us - 6.0).abs() < 1e-12);
        assert!((back.reduce_us - 3.0).abs() < 1e-12);
        // zero components must not create ops
        let mut s2 = CommSchedule::default();
        s2.push_step(&CostBreakdown { wire_us: 1.0, ..Default::default() }, false);
        assert_eq!(s2.ops.len(), 1);
    }

    #[test]
    fn replay_uncontended_is_serial_sum() {
        let mut e = Engine::new();
        let res = CommResources::install(&mut e);
        let end = Rc::new(RefCell::new(0.0));
        let end2 = end.clone();
        let ops = sched(&[(ResKind::Sw, 1.0), (ResKind::Wire, 10.0), (ResKind::GpuReduce, 2.0)]);
        replay(&mut e, res.mapper(), ops, Box::new(move |e| *end2.borrow_mut() = e.now().as_us()));
        e.run();
        assert!((*end.borrow() - 13.0).abs() < 1e-9);
        let util = res.utilization(&e);
        assert_eq!(util.len(), 3);
        assert!(util.iter().any(|u| u.name == "wire" && u.busy == SimTime::from_us(10.0)));
    }

    #[test]
    fn shared_wire_contends_private_resources_overlap() {
        // Two identical schedules: wire 10us then private gpu 5us.
        // Shared wire serializes (A: 0–10, B: 10–20); the GPU phases
        // overlap with the other job's wire time.
        let mut e = Engine::new();
        let a = CommResources::install(&mut e);
        let b = CommResources::sharing_wire(&mut e, a.wire);
        let ends = Rc::new(RefCell::new(Vec::new()));
        for res in [a, b] {
            let ends = ends.clone();
            let ops = sched(&[(ResKind::Wire, 10.0), (ResKind::GpuReduce, 5.0)]);
            replay(
                &mut e,
                res.mapper(),
                ops,
                Box::new(move |e| ends.borrow_mut().push(e.now().as_us())),
            );
        }
        e.run();
        assert_eq!(*ends.borrow(), vec![15.0, 25.0]);
        assert_eq!(e.resource_stats(a.wire).busy, SimTime::from_us(20.0));
    }

    #[test]
    fn unmapped_kinds_elapse_without_contention() {
        // Map backs nothing: two 10us delays run fully in parallel.
        let mut e = Engine::new();
        let map: ResMap = Rc::new(|_| None);
        let ends = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..2 {
            let ends = ends.clone();
            replay(
                &mut e,
                map.clone(),
                sched(&[(ResKind::Sw, 10.0)]),
                Box::new(move |e| ends.borrow_mut().push(e.now().as_us())),
            );
        }
        let end = e.run();
        assert_eq!(end, SimTime::from_us(10.0));
        assert_eq!(*ends.borrow(), vec![10.0, 10.0]);
    }

    #[test]
    fn pinned_ops_override_the_map() {
        let mut e = Engine::new();
        let nic = e.unit_resource();
        let map: ResMap = Rc::new(|_| None);
        for _ in 0..2 {
            let ops = Rc::new(vec![CommOp::fixed(ResKind::Wire, 7.0).pinned(nic)]);
            replay(&mut e, map.clone(), ops, Box::new(|_| {}));
        }
        let end = e.run();
        assert_eq!(end, SimTime::from_us(14.0));
        let s = e.resource_stats(nic);
        assert_eq!((s.served, s.busy), (2, SimTime::from_us(14.0)));
    }

    #[test]
    fn scale_preserves_structure() {
        let mut s = CommSchedule::default();
        s.push(CommOp::fixed(ResKind::Wire, 8.0));
        s.push(CommOp::fixed(ResKind::Sw, 2.0));
        s.scale(0.5);
        assert!((s.total_us() - 5.0).abs() < 1e-12);
        assert_eq!(s.ops.len(), 2);
    }
}
