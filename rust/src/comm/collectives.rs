//! The rest of the MPI/NCCL collective set (§II-B: "broadcast, all-gather,
//! reduce, reduce-scatter, and all-reduce").  Allreduce — the paper's
//! focus — lives in `allreduce/`; these are the remaining primitives the
//! substrate needs to be a credible MPI runtime (Horovod itself uses
//! broadcast for initial parameter sync, which the trainer exercises).
//!
//! Same contract as the allreduce family: REAL data movement over the
//! per-rank buffers, modeled time on the configured fabric.

use crate::comm::allreduce::{AllreduceCtx, AllreduceReport};
use crate::sim::SimTime;

/// Binomial-tree broadcast from `root`: ⌈log₂p⌉ full-vector steps.
/// After the call every rank holds root's data (Horovod's parameter
/// broadcast at initialization).
pub fn bcast(bufs: &mut [Vec<f32>], root: usize, ctx: &mut AllreduceCtx) -> AllreduceReport {
    let p = bufs.len();
    assert!(root < p, "root {root} out of range for {p} ranks");
    let n = bufs[0].len();
    let mut report = AllreduceReport { algo: "bcast", ..Default::default() };
    if p == 1 || n == 0 {
        return report;
    }
    ctx.register_ranks(p, (n * 4) as u64);
    let bytes = n * 4;
    // relabel so the root acts as rank 0
    let rel = |v: usize| (v + root) % p;
    let mut dist = p.next_power_of_two() / 2;
    while dist >= 1 {
        let mut any = false;
        for src in (0..p).step_by(2 * dist) {
            let dst = src + dist;
            if dst < p {
                let data = bufs[rel(src)].clone();
                bufs[rel(dst)].copy_from_slice(&data);
                any = true;
            }
        }
        if any {
            let mut step = ctx.sendrecv_cost(bytes);
            step.driver_us = ctx.driver_cost_us(0);
            report.cost.add(&step);
            report.steps += 1;
            report.wire_bytes_per_rank += bytes;
        }
        dist /= 2;
    }
    report.time = SimTime::from_us(report.cost.total_us());
    report
}

/// Binomial-tree reduce to `root` (sum): ⌈log₂p⌉ steps with a reduction
/// each.  Non-root buffers are left in an unspecified partial state, as
/// with MPI_Reduce.
pub fn reduce(bufs: &mut [Vec<f32>], root: usize, ctx: &mut AllreduceCtx) -> AllreduceReport {
    let p = bufs.len();
    assert!(root < p);
    let n = bufs[0].len();
    let mut report = AllreduceReport { algo: "reduce", ..Default::default() };
    if p == 1 || n == 0 {
        return report;
    }
    ctx.register_ranks(p, (n * 4) as u64);
    let bytes = n * 4;
    let rel = |v: usize| (v + root) % p;
    let mut dist = 1;
    while dist < p {
        let mut any = false;
        let mut red = Default::default();
        for r in (0..p).filter(|r| r % (2 * dist) == dist) {
            let dst = rel(r - dist);
            let src = rel(r);
            let incoming = bufs[src].clone();
            let mut acc = std::mem::take(&mut bufs[dst]);
            red = ctx.reduce_into(&mut acc, &incoming);
            bufs[dst] = acc;
            any = true;
        }
        if any {
            let mut step = ctx.sendrecv_cost(bytes);
            step.driver_us = ctx.driver_cost_us(0);
            step.add(&red);
            report.cost.add(&step);
            report.steps += 1;
            report.wire_bytes_per_rank += bytes;
        }
        dist *= 2;
    }
    report.time = SimTime::from_us(report.cost.total_us());
    report
}

/// Ring allgather: every rank contributes its own vector; all ranks end
/// with the p·n concatenation (rank-major).  p−1 steps of n elements.
pub fn allgather(contribs: &[Vec<f32>], ctx: &mut AllreduceCtx) -> (Vec<Vec<f32>>, AllreduceReport) {
    let p = contribs.len();
    let n = contribs.first().map(Vec::len).unwrap_or(0);
    let mut report = AllreduceReport { algo: "allgather", ..Default::default() };
    let mut out = vec![vec![0.0f32; p * n]; p];
    for (r, c) in contribs.iter().enumerate() {
        assert_eq!(c.len(), n, "ragged allgather contribution");
        out[r][r * n..(r + 1) * n].copy_from_slice(c);
    }
    if p == 1 || n == 0 {
        report.time = SimTime::ZERO;
        return (out, report);
    }
    ctx.register_ranks(p, (n * 4) as u64);
    let bytes = n * 4;
    // step s: rank r forwards block (r − s) mod p to its right neighbour
    for s in 0..p - 1 {
        let outgoing: Vec<Vec<f32>> = (0..p)
            .map(|r| {
                let b = (r + p - s) % p;
                out[r][b * n..(b + 1) * n].to_vec()
            })
            .collect();
        for r in 0..p {
            let left = (r + p - 1) % p;
            let b = (left + p - s) % p;
            out[r][b * n..(b + 1) * n].copy_from_slice(&outgoing[left]);
        }
        let mut step = ctx.sendrecv_cost(bytes);
        step.driver_us = ctx.driver_cost_us(0);
        report.cost.add(&step);
        report.steps += 1;
        report.wire_bytes_per_rank += bytes;
    }
    report.time = SimTime::from_us(report.cost.total_us());
    (out, report)
}

/// Ring reduce-scatter (sum): rank r ends with the fully-reduced r-th
/// block of the input vectors.  p−1 steps of n/p elements.
pub fn reduce_scatter(
    bufs: &mut [Vec<f32>],
    ctx: &mut AllreduceCtx,
) -> (Vec<Vec<f32>>, AllreduceReport) {
    let p = bufs.len();
    let n = bufs.first().map(Vec::len).unwrap_or(0);
    let mut report = AllreduceReport { algo: "reduce_scatter", ..Default::default() };
    if p == 1 {
        let own = bufs.first().cloned().unwrap_or_default();
        return (vec![own], report);
    }
    ctx.register_ranks(p, (n * 4) as u64);
    // block ranges (nearly equal)
    let base = n / p;
    let rem = n % p;
    let range = |i: usize| {
        let lo = i * base + i.min(rem);
        let len = base + usize::from(i < rem);
        (lo, lo + len)
    };
    let max_block = 4 * (base + usize::from(rem > 0));
    for s in 0..p - 1 {
        let outgoing: Vec<Vec<f32>> = (0..p)
            .map(|r| {
                let b = (r + p - s) % p;
                let (lo, hi) = range(b);
                bufs[r][lo..hi].to_vec()
            })
            .collect();
        let mut red = Default::default();
        for r in 0..p {
            let left = (r + p - 1) % p;
            let b = (left + p - s) % p;
            let (lo, hi) = range(b);
            let mut acc = std::mem::take(&mut bufs[r]);
            red = ctx.reduce_into(&mut acc[lo..hi], &outgoing[left]);
            bufs[r] = acc;
        }
        let mut step = ctx.sendrecv_cost(max_block);
        step.driver_us = ctx.driver_cost_us(0);
        step.add(&red);
        report.cost.add(&step);
        report.steps += 1;
        report.wire_bytes_per_rank += max_block;
    }
    // rank r now owns fully-reduced block (r+1) mod p
    let owned: Vec<Vec<f32>> = (0..p)
        .map(|r| {
            let b = (r + 1) % p;
            let (lo, hi) = range(b);
            bufs[r][lo..hi].to_vec()
        })
        .collect();
    report.time = SimTime::from_us(report.cost.total_us());
    // return in block order (block i from the rank that owns it)
    let mut blocks = vec![Vec::new(); p];
    for (r, data) in owned.into_iter().enumerate() {
        blocks[(r + 1) % p] = data;
    }
    (blocks, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::allreduce::testutil::{ctx_gdr, make_bufs};

    #[test]
    fn bcast_replicates_root_any_root() {
        for p in [1usize, 2, 3, 5, 8, 13] {
            for root in [0, p / 2, p - 1] {
                let mut bufs = make_bufs(p, 100, (p * 7 + root) as u64);
                let want = bufs[root].clone();
                let mut ctx = ctx_gdr();
                let r = bcast(&mut bufs, root, &mut ctx);
                for b in &bufs {
                    assert_eq!(b, &want, "p={p} root={root}");
                }
                if p > 1 {
                    assert_eq!(r.steps, (p.next_power_of_two()).trailing_zeros() as usize);
                }
            }
        }
    }

    #[test]
    fn reduce_sums_to_root() {
        for p in [2usize, 3, 7, 16] {
            for root in [0, p - 1] {
                let mut bufs = make_bufs(p, 333, (p + root) as u64);
                let oracle = crate::comm::allreduce::serial_oracle(&bufs);
                let mut ctx = ctx_gdr();
                reduce(&mut bufs, root, &mut ctx);
                for (x, o) in bufs[root].iter().zip(&oracle) {
                    assert!((x - o).abs() < 1e-4, "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn allgather_concatenates_everywhere() {
        for p in [1usize, 2, 4, 7] {
            let contribs = make_bufs(p, 50, p as u64);
            let mut want = Vec::new();
            for c in &contribs {
                want.extend_from_slice(c);
            }
            let mut ctx = ctx_gdr();
            let (out, r) = allgather(&contribs, &mut ctx);
            for o in &out {
                assert_eq!(o, &want, "p={p}");
            }
            if p > 1 {
                assert_eq!(r.steps, p - 1);
            }
        }
    }

    #[test]
    fn reduce_scatter_blocks_match_oracle() {
        for p in [2usize, 3, 8] {
            for n in [16usize, 100, 101] {
                let mut bufs = make_bufs(p, n, (p * n) as u64);
                let oracle = crate::comm::allreduce::serial_oracle(&bufs);
                let mut ctx = ctx_gdr();
                let (blocks, _) = reduce_scatter(&mut bufs, &mut ctx);
                let flat: Vec<f32> = blocks.concat();
                assert_eq!(flat.len(), n);
                for (x, o) in flat.iter().zip(&oracle) {
                    assert!((x - o).abs() < 1e-4, "p={p} n={n}");
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_plus_allgather_equals_allreduce() {
        // the RSA identity the whole paper builds on
        let p = 8;
        let n = 240;
        let mut bufs = make_bufs(p, n, 99);
        let oracle = crate::comm::allreduce::serial_oracle(&bufs);
        let mut ctx = ctx_gdr();
        let (blocks, _) = reduce_scatter(&mut bufs, &mut ctx);
        let mut ctx2 = ctx_gdr();
        let (gathered, _) = allgather(&blocks, &mut ctx2);
        // blocks are unequal size when n % p != 0 → use concat of blocks
        let flat: Vec<f32> = blocks.concat();
        for (x, o) in flat.iter().zip(&oracle) {
            assert!((x - o).abs() < 1e-4);
        }
        drop(gathered);
    }

    #[test]
    fn broadcast_cost_log_steps_full_vector() {
        let mut bufs = make_bufs(16, 1 << 16, 5);
        let mut ctx = ctx_gdr();
        let r = bcast(&mut bufs, 0, &mut ctx);
        assert_eq!(r.steps, 4);
        assert_eq!(r.wire_bytes_per_rank, 4 * (1 << 16) * 4);
    }
}
