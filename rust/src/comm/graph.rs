//! The `CommGraph` layer: a collective as a DAG of **per-rank** `CommOp`
//! nodes with explicit cross-rank dependencies, executed dependency-aware
//! on the discrete-event engine.
//!
//! A serialized [`CommSchedule`](crate::comm::commop::CommSchedule) models
//! the critical-path rank: one op chain, so a straggler can only shift the
//! *whole* collective.  On real fabrics skew propagates *through* the
//! algorithm: ring step *s* on rank *r* cannot start before step *s−1* of
//! rank *r* **and** the matching send of rank *r−1* — one slow rank delays
//! its downstream neighbours one step later, the next neighbour two steps
//! later, a cone that widens by one rank per step.  That propagation (the
//! structure the paper's Allreduce characterization rides on) is exactly
//! what this graph expresses and the serialized form cannot.
//!
//! Contract:
//!  * **Nodes** — one per (rank, algorithm step): an ordered `CommOp` list
//!    (the same [`StepCost::ops`] decomposition the serialized schedule
//!    uses, so durations stay pinned to the validated α–β cost models).
//!  * **Edges** — `deps`: a node becomes *eligible* only when every
//!    predecessor has finished.  Builders wire ring / halving-doubling /
//!    tree / PS fan-in topologies.
//!  * **Eligibility vs queueing** — eligibility is an engine *join*
//!    ([`Engine::join`]); once eligible, a node's ops run as a typed
//!    engine program queueing FIFO on the **node-local** resources a
//!    [`Placement`] lays out for its rank ([`GraphResources`]: NIC ports
//!    per `(node, rail)`, PCIe per node, GPU per rank, …) instead of the
//!    one shared per-job proxy.  Dense placements colocate ranks on
//!    shared NIC/PCIe bundles, and the placed builders cost hops between
//!    co-located ranks over the node-local link instead of the wire.
//!
//! §Perf — build once, replay many: a [`GraphTemplate`] is an immutable
//! built graph plus its precomputed successor/in-degree plan, cached in a
//! [`TemplateCache`] keyed by `(algo, world, placement, step-cost
//! signature)` ([`crate::comm::commop::steps_sig`]).  Per-iteration variation — what
//! the old code expressed by cloning the node vector and mutating op
//! durations — is a [`GraphOverlay`]: multiplicative per-rank factors and
//! per-node jitter leads applied at *execute* time, in the same order the
//! mutators applied them, so replayed timings are bit-identical to a
//! freshly built perturbed graph (pinned by `tests` here and the
//! equivalence suites in `tests/des_regression.rs` / `proptest_lite.rs`).
//!
//! With uniform per-step durations (no scenario perturbation) the graph's
//! completion time provably equals the serialized schedule's total: every
//! rank's chain is the same op sequence, and cross-rank edges between
//! equal-length chains never extend the path.  `tests` and
//! `tests/des_regression.rs` pin this zero-skew equivalence, which is what
//! lets the strategies keep the fast serialized replay when nothing skews
//! ranks apart.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::{Arc, Mutex};

use crate::cluster::Placement;
use crate::comm::allreduce::Algo;
use crate::comm::commop::{CommOp, RelPin, ResKind, ResourceUse, StepCost};
use crate::sim::{Action, Engine, LaneSetId, OnDone, ProgStep, ResourceId, SimTime};

/// Handle to a node inside one [`CommGraph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NodeId(pub usize);

/// One unit of per-rank work: an ordered op list plus the nodes that must
/// finish before it may start.
#[derive(Debug, Clone)]
pub struct GraphNode {
    pub rank: usize,
    /// Builder step index (timeline display, deterministic jitter keys).
    pub step: u32,
    pub ops: Vec<CommOp>,
    pub deps: Vec<NodeId>,
}

impl GraphNode {
    pub fn dur_us(&self) -> f64 {
        self.ops.iter().map(|o| o.us).sum()
    }
}

/// A DAG of per-rank [`GraphNode`]s.  Nodes are created in topological
/// order (dependencies always point backwards), which keeps execution and
/// critical-path evaluation single-pass.  A built graph is immutable in
/// spirit: per-iteration perturbation goes through [`GraphOverlay`], not
/// mutation, so one build can be replayed many times.
#[derive(Debug, Clone, Default)]
pub struct CommGraph {
    pub nodes: Vec<GraphNode>,
}

impl CommGraph {
    pub fn push_node(
        &mut self,
        rank: usize,
        step: u32,
        ops: Vec<CommOp>,
        deps: Vec<NodeId>,
    ) -> NodeId {
        let id = self.nodes.len();
        debug_assert!(deps.iter().all(|d| d.0 < id), "deps must precede the node");
        self.nodes.push(GraphNode { rank, step, ops, deps });
        NodeId(id)
    }

    /// The trivial adapter for linear schedules (gRPC-family transfers):
    /// one node carrying the whole op chain on one rank.
    pub fn chain(rank: usize, ops: Vec<CommOp>) -> CommGraph {
        let mut g = CommGraph::default();
        g.push_node(rank, 0, ops, Vec::new());
        g
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Sum of every node's work — the per-rank ledger, *not* wall time
    /// (p ranks working in parallel each contribute their own ops).
    pub fn total_us(&self) -> f64 {
        self.nodes.iter().map(|n| n.dur_us()).sum()
    }

    /// Longest dependency path, assuming no resource queueing — the
    /// zero-contention wall time of the graph.
    pub fn critical_path_us(&self) -> f64 {
        let mut cp = vec![0.0f64; self.nodes.len()];
        let mut best = 0.0f64;
        for (i, node) in self.nodes.iter().enumerate() {
            let start = node.deps.iter().map(|d| cp[d.0]).fold(0.0, f64::max);
            cp[i] = start + node.dur_us();
            best = best.max(cp[i]);
        }
        best
    }

    /// Prepend a root node every current source depends on — Horovod's
    /// rank-0 coordination round before the buffer's Allreduce.  Existing
    /// step indices shift by one.  (A template-build step, not a
    /// per-iteration one.)
    pub fn prefix_root(&mut self, rank: usize, ops: Vec<CommOp>) {
        let mut nodes = Vec::with_capacity(self.nodes.len() + 1);
        nodes.push(GraphNode { rank, step: 0, ops, deps: Vec::new() });
        for n in self.nodes.drain(..) {
            let deps = if n.deps.is_empty() {
                vec![NodeId(0)]
            } else {
                n.deps.iter().map(|d| NodeId(d.0 + 1)).collect()
            };
            nodes.push(GraphNode { step: n.step + 1, deps, ..n });
        }
        self.nodes = nodes;
    }
}

fn dep2(a: Option<NodeId>, b: Option<NodeId>) -> Vec<NodeId> {
    let mut v = Vec::new();
    if let Some(x) = a {
        v.push(x);
    }
    if let Some(y) = b {
        if a != Some(y) {
            v.push(y);
        }
    }
    v
}

/// The ops of one algorithm step for rank `rank` exchanging with `peer`
/// under a placement: an inter-node hop keeps the step's decomposition;
/// an intra-node hop re-kinds the `Wire` component to `Pcie` — it rides
/// the node's PCIe/NVLink path (queueing on the node's local link, not
/// the NIC) scaled by `local` (inter-node β ÷ local β, see
/// [`crate::cluster::Fabric::local_hop_factor`]).  With one GPU per node
/// no hop is ever intra, so the output is bit-identical to
/// [`StepCost::ops`] — the placement-invariance guarantee.
fn step_ops(st: &StepCost, place: &Placement, local: f64, rank: usize, peer: usize) -> Vec<CommOp> {
    let mut ops = st.ops();
    if place.gpus_per_node > 1 && place.same_node(rank, peer) {
        for op in &mut ops {
            if op.kind == ResKind::Wire {
                op.kind = ResKind::Pcie;
                op.us *= local;
            }
        }
    }
    ops
}

/// Build the dependency graph of an allreduce from its validated per-step
/// costs (the same [`StepCost`] sequence the serialized schedule uses),
/// with every rank on its own node (the paper's layout).
pub fn allreduce_graph(algo: Algo, p: usize, steps: &[StepCost]) -> CommGraph {
    allreduce_graph_placed(algo, p, steps, Placement::one_per_node(), 1.0)
}

/// [`allreduce_graph`] under a [`Placement`]: hops between co-located
/// ranks are re-costed onto the node-local link (`local` = inter-node β
/// ÷ local β).  Trivial placements reproduce [`allreduce_graph`]
/// bit-for-bit regardless of `local`.
pub fn allreduce_graph_placed(
    algo: Algo,
    p: usize,
    steps: &[StepCost],
    place: Placement,
    local: f64,
) -> CommGraph {
    match algo {
        Algo::Ring => ring_graph_placed(p, steps, place, local),
        Algo::Rhd => rhd_graph_placed(p, steps, place, local),
        Algo::Tree => tree_graph_placed(p, steps, place, local),
    }
}

/// Ring: step *s* on rank *r* waits on its own step *s−1* and on the
/// matching send of rank *r−1* (the data it receives this step).
pub fn ring_graph(p: usize, steps: &[StepCost]) -> CommGraph {
    ring_graph_placed(p, steps, Placement::one_per_node(), 1.0)
}

/// Placed ring: under a block placement the hop from *r−1* into *r* is
/// intra-node whenever `r` is not its node's first rank — the classic
/// hierarchical-ring benefit (one wire crossing per node per step, the
/// rest rides PCIe/NVLink).
pub fn ring_graph_placed(
    p: usize,
    steps: &[StepCost],
    place: Placement,
    local: f64,
) -> CommGraph {
    let mut g = CommGraph::default();
    if p < 2 {
        return g;
    }
    let mut last: Vec<Option<NodeId>> = vec![None; p];
    for (s, st) in steps.iter().enumerate() {
        let prev = last.clone();
        for (r, slot) in last.iter_mut().enumerate() {
            let from = (r + p - 1) % p;
            let ops = step_ops(st, &place, local, r, from);
            *slot = Some(g.push_node(r, s as u32, ops, dep2(prev[r], prev[from])));
        }
    }
    g
}

/// Recursive halving-doubling: mask step exchanges pair rank *r* with
/// *r ^ mask*; a non-power-of-two world folds the extra ranks into their
/// base partner first (pre) and unfolds them last (post) — the same phase
/// sequence `shadow::rhd_shadow` charges.
pub fn rhd_graph(p: usize, steps: &[StepCost]) -> CommGraph {
    rhd_graph_placed(p, steps, Placement::one_per_node(), 1.0)
}

/// Placed RHD: small-mask exchanges pair near ranks — under a block
/// placement every mask < `gpus_per_node` stays on-node, the larger
/// masks always cross the wire.
pub fn rhd_graph_placed(
    p: usize,
    steps: &[StepCost],
    place: Placement,
    local: f64,
) -> CommGraph {
    let mut g = CommGraph::default();
    if p < 2 {
        return g;
    }
    let p2 = crate::comm::allreduce::flp2(p);
    let rem = p - p2;
    let mut last: Vec<Option<NodeId>> = vec![None; p];
    let mut si = 0usize;

    let mut fold_step = |g: &mut CommGraph, last: &mut Vec<Option<NodeId>>, si: &mut usize| {
        let st = &steps[*si];
        let stepi = *si as u32;
        *si += 1;
        let prev = last.clone();
        for r in p2..p {
            let base = r - p2;
            let ops_r = step_ops(st, &place, local, r, base);
            let ops_b = step_ops(st, &place, local, base, r);
            last[r] = Some(g.push_node(r, stepi, ops_r, dep2(prev[r], prev[base])));
            last[base] = Some(g.push_node(base, stepi, ops_b, dep2(prev[base], prev[r])));
        }
    };

    if rem > 0 {
        fold_step(&mut g, &mut last, &mut si);
    }
    let masks: Vec<usize> = {
        let mut v = Vec::new();
        let mut m = p2 >> 1;
        while m > 0 {
            v.push(m);
            m >>= 1;
        }
        v
    };
    for &mask in masks.iter().chain(masks.iter().rev()) {
        let st = &steps[si];
        let stepi = si as u32;
        si += 1;
        let prev = last.clone();
        for (r, slot) in last.iter_mut().enumerate().take(p2) {
            let q = r ^ mask;
            let ops = step_ops(st, &place, local, r, q);
            *slot = Some(g.push_node(r, stepi, ops, dep2(prev[r], prev[q])));
        }
    }
    if rem > 0 {
        fold_step(&mut g, &mut last, &mut si);
    }
    debug_assert_eq!(si, steps.len(), "rhd builder / shadow step count mismatch");
    g
}

/// Binomial tree: reduce up (receivers reduce), broadcast down.  Each
/// pair's work lives on the receiving rank; the node also becomes the
/// sender's latest node, which serializes a rank's consecutive sends
/// (rank 0 broadcasts one level at a time).
pub fn tree_graph(p: usize, steps: &[StepCost]) -> CommGraph {
    tree_graph_placed(p, steps, Placement::one_per_node(), 1.0)
}

/// Placed binomial tree: the lowest levels pair adjacent ranks, which a
/// block placement keeps on-node; the top levels always cross the wire.
pub fn tree_graph_placed(
    p: usize,
    steps: &[StepCost],
    place: Placement,
    local: f64,
) -> CommGraph {
    let mut g = CommGraph::default();
    if p < 2 {
        return g;
    }
    let mut last: Vec<Option<NodeId>> = vec![None; p];
    let mut si = 0usize;

    let mut level = |g: &mut CommGraph,
                     last: &mut Vec<Option<NodeId>>,
                     si: &mut usize,
                     pairs: &[(usize, usize)]| {
        let st = &steps[*si];
        let stepi = *si as u32;
        *si += 1;
        let prev = last.clone();
        for &(src, dst) in pairs {
            let ops = step_ops(st, &place, local, dst, src);
            let id = g.push_node(dst, stepi, ops, dep2(prev[dst], prev[src]));
            last[dst] = Some(id);
            last[src] = Some(id);
        }
    };

    let mut dist = 1;
    while dist < p {
        let pairs: Vec<(usize, usize)> = (0..p)
            .filter(|r| r % (2 * dist) == dist)
            .map(|src| (src, src - dist))
            .collect();
        if !pairs.is_empty() {
            level(&mut g, &mut last, &mut si, &pairs);
        }
        dist *= 2;
    }
    let mut dist = p.next_power_of_two() / 2;
    while dist >= 1 {
        let pairs: Vec<(usize, usize)> = (0..p)
            .step_by(2 * dist)
            .filter(|&src| src + dist < p)
            .map(|src| (src, src + dist))
            .collect();
        if !pairs.is_empty() {
            level(&mut g, &mut last, &mut si, &pairs);
        }
        dist /= 2;
    }
    debug_assert_eq!(si, steps.len(), "tree builder / shadow step count mismatch");
    g
}

/// The PS fan-in/fan-out DAG of ONE parameter shard: `workers` push
/// chains converge on the server's update node (the fan-in the PS NIC
/// queues feed), which fans back out into `workers` pull chains.  Returns
/// the graph and each worker's pull sink, whose finish time is that
/// worker's completion for the shard.
pub fn ps_fanin_graph(
    workers: usize,
    server_rank: usize,
    push_ops: impl Fn(usize) -> Vec<CommOp>,
    update_ops: Vec<CommOp>,
    pull_ops: impl Fn(usize) -> Vec<CommOp>,
) -> (CommGraph, Vec<NodeId>) {
    let mut g = CommGraph::default();
    let pushes: Vec<NodeId> =
        (0..workers).map(|w| g.push_node(w, 0, push_ops(w), Vec::new())).collect();
    let update = g.push_node(server_rank, 1, update_ops, pushes);
    let pulls: Vec<NodeId> =
        (0..workers).map(|w| g.push_node(w, 2, pull_ops(w), vec![update])).collect();
    debug_assert_eq!(pulls, ps_fanin_pulls(workers), "fan-in layout drifted from the helper");
    (g, pulls)
}

/// The pull-sink node ids of [`ps_fanin_graph`] for `workers` workers.
/// The builder's layout is fixed — pushes `0..w`, update `w`, pulls
/// `w+1..=2w` — so a cached fan-in template can recover its sinks
/// without storing them alongside (cross-call PS templating).
pub fn ps_fanin_pulls(workers: usize) -> Vec<NodeId> {
    (0..workers).map(|w| NodeId(workers + 1 + w)).collect()
}

/// Resolves an op to the engine resource backing it: by `(rank, kind)`
/// for per-rank work, or by the op's template-relative [`RelPin`] (PS
/// fan-in NIC queues, worker service threads) when one is present —
/// `None` elapses as an uncontended per-rank delay.  Rel pins are what
/// keep cached templates engine-independent: the graph names the
/// resource, each run's map resolves the name.
pub type GraphResMap = Rc<dyn Fn(usize, ResKind, Option<RelPin>) -> Option<ResourceId>>;

/// A map backing nothing: every op elapses as a pure per-rank delay
/// (engine-pinned ops still hit their resources).
pub fn unmapped() -> GraphResMap {
    Rc::new(|_, _, _| None)
}

/// Per-iteration execution overlay (§Perf): everything that may vary
/// between iterations of one cached [`GraphTemplate`].  The template is
/// immutable; the overlay carries multiplicative duration factors and a
/// per-node lead delay, applied at execute time in the exact order the
/// old clone-and-mutate path applied them —
///
///   1. `global` (Baidu's ring-pipeline amortization, ex-`scale`),
///   2. per-rank all-op factor (stragglers, ex-`scale_rank`),
///   3. per-rank GPU-side factor on `GpuReduce`/`Launch`/`Pcie` ops
///      (hetero GPU generations, ex-`scale_rank_gpu`),
///   4. a leading per-node stall resolved through the rank's `Sw`
///      resource (OS/sync jitter, ex-`jitter_nodes`) —
///
/// so an overlay replay is bit-identical to executing a freshly built,
/// mutated graph.  What is *baked into the template* instead: topology,
/// dep edges, step indices, op kinds/pins, and unperturbed durations.
#[derive(Clone)]
pub struct GraphOverlay {
    global: f64,
    rank_all: Vec<f64>,
    rank_gpu: Vec<f64>,
    lead: Option<Rc<dyn Fn(usize, u32) -> f64>>,
}

/// `Default` is the neutral overlay (identity factors, no lead).
impl Default for GraphOverlay {
    fn default() -> GraphOverlay {
        GraphOverlay::neutral()
    }
}

impl GraphOverlay {
    /// The identity overlay: replaying under it equals the bare template.
    pub fn neutral() -> GraphOverlay {
        GraphOverlay { global: 1.0, rank_all: Vec::new(), rank_gpu: Vec::new(), lead: None }
    }

    /// Multiply every op of every rank (pipeline amortization).
    pub fn scale_global(&mut self, f: f64) {
        self.global *= f;
    }

    /// Multiply every op of one rank — a straggler whose progress engine,
    /// host and links all run slow.  (Out-of-`world` ranks have no nodes,
    /// matching the old mutator's no-op; the factor table grows to cover
    /// the largest `world` seen, so composed calls never drop a factor.)
    pub fn scale_rank(&mut self, world: usize, rank: usize, f: f64) {
        if self.rank_all.len() < world {
            self.rank_all.resize(world, 1.0);
        }
        if let Some(s) = self.rank_all.get_mut(rank) {
            *s *= f;
        }
    }

    /// Multiply only the GPU-side ops (reduce kernel, launch, PCIe
    /// staging) of one rank — a rank placed on an older GPU generation.
    pub fn scale_rank_gpu(&mut self, world: usize, rank: usize, f: f64) {
        if self.rank_gpu.len() < world {
            self.rank_gpu.resize(world, 1.0);
        }
        if let Some(s) = self.rank_gpu.get_mut(rank) {
            *s *= f;
        }
    }

    /// Per-node extra lead delay from a deterministic `(rank, step)` draw
    /// — OS/sync jitter at step granularity.  The delay occupies the
    /// rank's own `Sw` resource (never a shared pinned one), so a jittery
    /// worker delays itself, not the NIC queue behind it.
    pub fn set_lead(&mut self, draw: impl Fn(usize, u32) -> f64 + 'static) {
        self.lead = Some(Rc::new(draw));
    }

    pub fn is_neutral(&self) -> bool {
        self.global == 1.0
            && self.rank_all.is_empty()
            && self.rank_gpu.is_empty()
            && self.lead.is_none()
    }

    fn all_factor(&self, rank: usize) -> f64 {
        self.rank_all.get(rank).copied().unwrap_or(1.0)
    }

    fn gpu_factor(&self, rank: usize) -> f64 {
        self.rank_gpu.get(rank).copied().unwrap_or(1.0)
    }

    fn lead_us(&self, rank: usize, step: u32) -> f64 {
        self.lead.as_ref().map_or(0.0, |f| f(rank, step))
    }
}

impl std::fmt::Debug for GraphOverlay {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GraphOverlay")
            .field("global", &self.global)
            .field("rank_all", &self.rank_all)
            .field("rank_gpu", &self.rank_gpu)
            .field("lead", &self.lead.is_some())
            .finish()
    }
}

/// Resolve one node against the resource map and overlay into a typed
/// engine program.  The multiplication order (global → rank → rank-GPU)
/// replicates the old sequential `op.us *= f` mutations bit-for-bit, and
/// `f * 1.0 == f` exactly, so a neutral overlay changes nothing.
fn resolve_node(node: &GraphNode, map: &GraphResMap, ov: &GraphOverlay) -> Rc<[ProgStep]> {
    let rank = node.rank;
    let lead = ov.lead_us(rank, node.step);
    let mut steps = Vec::with_capacity(node.ops.len() + usize::from(lead > 0.0));
    if lead > 0.0 {
        steps.push(ProgStep { us: lead, on: map(rank, ResKind::Sw, None) });
    }
    let all = ov.all_factor(rank);
    let gpu = ov.gpu_factor(rank);
    for op in &node.ops {
        let mut us = op.us;
        us *= ov.global;
        us *= all;
        if matches!(op.kind, ResKind::GpuReduce | ResKind::Launch | ResKind::Pcie) {
            us *= gpu;
        }
        steps.push(ProgStep { us, on: op.on.or_else(|| map(rank, op.kind, op.rel)) });
    }
    steps.into()
}

/// The precomputed execution plan of a graph: successor lists, in-degrees
/// and the sink count — everything `execute` used to rebuild per run.
#[derive(Debug)]
struct GraphPlan {
    succ: Vec<Vec<usize>>,
    indeg: Vec<usize>,
    sink_count: usize,
}

impl GraphPlan {
    fn of(g: &CommGraph) -> GraphPlan {
        let n = g.nodes.len();
        let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indeg: Vec<usize> = vec![0; n];
        for (i, node) in g.nodes.iter().enumerate() {
            for d in &node.deps {
                succ[d.0].push(i);
                indeg[i] += 1;
            }
        }
        let sink_count = succ.iter().filter(|s| s.is_empty()).count();
        GraphPlan { succ, indeg, sink_count }
    }
}

/// An immutable, build-once graph plus its execution plan — the unit the
/// strategies cache and replay (§Perf).  Executing a template never
/// mutates it; per-iteration variation goes through [`GraphOverlay`].
#[derive(Debug)]
pub struct GraphTemplate {
    graph: CommGraph,
    plan: GraphPlan,
}

impl GraphTemplate {
    pub fn new(graph: CommGraph) -> GraphTemplate {
        let plan = GraphPlan::of(&graph);
        GraphTemplate { graph, plan }
    }

    pub fn graph(&self) -> &CommGraph {
        &self.graph
    }

    /// Execute the template now (source nodes release at the current
    /// virtual time).  See [`GraphTemplate::execute_at`].
    pub fn execute(
        &self,
        e: &mut Engine,
        map: GraphResMap,
        ov: &GraphOverlay,
        done: Action,
    ) -> Rc<RefCell<GraphRun>> {
        let at = e.now();
        self.execute_at(e, map, ov, at, done)
    }

    /// Execute the template with sources released at virtual time `at`
    /// (>= now), under `ov`.  Each node becomes *eligible* when all its
    /// predecessors complete (an [`Engine::join`]), then its resolved op
    /// program queues FIFO on the resources `map` resolves for its rank.
    /// `done` fires when every node has finished.  Source nodes release
    /// in node order (deterministic FIFO ties).
    pub fn execute_at(
        &self,
        e: &mut Engine,
        map: GraphResMap,
        ov: &GraphOverlay,
        at: SimTime,
        done: Action,
    ) -> Rc<RefCell<GraphRun>> {
        execute_planned(e, &self.graph, &self.plan, &map, ov, at, OnDone::Call(done))
    }

    /// Execute the template as stream-lane job `job` of `set`: sources
    /// release now (this is the job's launch turn), and the terminal
    /// join's completion is the typed [`Engine::lane_done`] — no boxed
    /// `done` per collective, which is what keeps the fusion-overlap
    /// buffer loop allocation-free (§Overlap).
    pub fn execute_lane(
        &self,
        e: &mut Engine,
        map: GraphResMap,
        ov: &GraphOverlay,
        set: LaneSetId,
        job: u32,
    ) -> Rc<RefCell<GraphRun>> {
        let at = e.now();
        execute_planned(e, &self.graph, &self.plan, &map, ov, at, OnDone::Lane(set, job))
    }
}

/// A shared template-cache handle: clones of a strategy share one map,
/// and the parallel sweep drivers may probe it from several threads.
/// Keys are exact ([`TemplateKey`] embeds the full step-cost bit
/// signature), so a hit can never be stale.
#[derive(Debug, Clone, Default)]
pub struct TemplateCache {
    inner: Arc<Mutex<HashMap<TemplateKey, Arc<GraphTemplate>>>>,
}

/// Cache key of one built collective graph: algorithm tag, world size,
/// the placement signature, and the exact bit signature of the per-step
/// costs (plus any builder extras the caller appends, e.g. Horovod's
/// coordination-root cost or the intra-node hop factor).  The placement
/// is part of the key because a placed builder bakes intra-node hop
/// re-kinding *into the graph*: two layouts of the same collective must
/// never alias one template (and rails, though resource-side only, keep
/// the key honest about what layout a template was built for).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TemplateKey {
    pub algo: u8,
    pub world: usize,
    /// `(gpus_per_node, rails)` — [`Placement::key`].
    pub place: (usize, usize),
    pub sig: Vec<u64>,
}

impl TemplateKey {
    pub fn allreduce(algo: Algo, world: usize, sig: Vec<u64>) -> TemplateKey {
        TemplateKey::allreduce_placed(algo, world, Placement::one_per_node(), sig)
    }

    pub fn allreduce_placed(
        algo: Algo,
        world: usize,
        place: Placement,
        sig: Vec<u64>,
    ) -> TemplateKey {
        let algo = match algo {
            Algo::Tree => 0,
            Algo::Ring => 1,
            Algo::Rhd => 2,
        };
        TemplateKey { algo, world, place: place.key(), sig }
    }

    /// Key of a PS fan-in template: `world` is the worker count, and the
    /// caller's `sig` carries everything the shard's ops depend on
    /// (server index, transfer/update costs, single-thread flag, the
    /// intra-node factor) bit-exactly.  Tag 3 keeps fan-ins disjoint
    /// from every allreduce algorithm.
    pub fn ps_fanin(world: usize, place: Placement, sig: Vec<u64>) -> TemplateKey {
        TemplateKey { algo: 3, world, place: place.key(), sig }
    }
}

impl TemplateCache {
    /// Return the cached template for `key`, building (and caching) it
    /// with `build` on a miss.  The build runs *outside* the lock: cold
    /// parallel sweeps may build a key twice (first insert wins, the
    /// duplicate is dropped) rather than serializing every thread on one
    /// graph construction — and a panicking builder cannot poison the
    /// cache for the surviving threads.
    pub fn get_or_build(
        &self,
        key: TemplateKey,
        build: impl FnOnce() -> CommGraph,
    ) -> Arc<GraphTemplate> {
        if let Some(hit) = self.inner.lock().expect("template cache poisoned").get(&key) {
            return hit.clone();
        }
        let built = Arc::new(GraphTemplate::new(build()));
        let mut m = self.inner.lock().expect("template cache poisoned");
        m.entry(key).or_insert(built).clone()
    }

    /// Number of distinct templates built so far.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("template cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Node-local resources laid out over a [`Placement`]: co-located ranks
/// share their node's NIC ports (`wire`, one per `(node, rail)`) and
/// its PCIe/NVLink path (`pcie`, one per node) while keeping private
/// GPU/CPU/driver/launch/software resources.  With the paper's trivial
/// placement (1 GPU per node, 1 rail) every bundle is per-rank, exactly
/// the historical layout.  Cross-rank contention inside one collective
/// comes from co-located ranks queueing on shared node resources (plus
/// the dependency edges); co-tenant *jobs* sharing the fabric contend
/// per NIC port via [`GraphResources::sharing_wire`].
#[derive(Clone)]
pub struct GraphResources {
    /// NIC ports, node-major rail-minor: `wire[node * rails + rail]`.
    pub wire: Vec<ResourceId>,
    /// Host staging / intra-node transfer links, one per node.
    pub pcie: Vec<ResourceId>,
    pub gpu: Vec<ResourceId>,
    pub cpu: Vec<ResourceId>,
    pub driver: Vec<ResourceId>,
    pub launch: Vec<ResourceId>,
    pub sw: Vec<ResourceId>,
    place: Placement,
    ranks: usize,
}

impl GraphResources {
    pub fn install(e: &mut Engine, ranks: usize) -> GraphResources {
        GraphResources::install_placed(e, ranks, Placement::one_per_node())
    }

    /// Install a job's bundle under a placement.  Resource-creation
    /// order (wire ports, pcie, then the per-rank vectors) matches the
    /// historical per-rank install when the placement is trivial, so
    /// engine resource ids — and therefore FIFO tie-breaking — are
    /// unchanged on the paper's layouts.
    pub fn install_placed(e: &mut Engine, ranks: usize, place: Placement) -> GraphResources {
        let nodes = place.nodes_for(ranks);
        let wire = (0..nodes * place.rails).map(|_| e.unit_resource()).collect();
        let pcie = (0..nodes).map(|_| e.unit_resource()).collect();
        let per_rank = |e: &mut Engine| -> Vec<ResourceId> {
            (0..ranks).map(|_| e.unit_resource()).collect()
        };
        GraphResources {
            wire,
            pcie,
            gpu: per_rank(e),
            cpu: per_rank(e),
            driver: per_rank(e),
            launch: per_rank(e),
            sw: per_rank(e),
            place,
            ranks,
        }
    }

    /// A co-tenant job's bundle sharing another job's physical NIC ports
    /// (both jobs' wire steps queue FIFO on the same `(node, rail)`
    /// ports) while owning every other node-local resource.  The
    /// co-tenant lands on the same physical nodes, so it inherits
    /// `other`'s placement geometry; `ranks` is the co-tenant's own
    /// world size — when it spans more nodes than `other`, the extra
    /// nodes' ports stay private (nobody there to share with), and when
    /// it spans fewer, only the overlapping ports are shared.
    pub fn sharing_wire(e: &mut Engine, ranks: usize, other: &GraphResources) -> GraphResources {
        let mut mine = GraphResources::install_placed(e, ranks, other.place);
        let shared = mine.wire.len().min(other.wire.len());
        mine.wire[..shared].copy_from_slice(&other.wire[..shared]);
        mine
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    pub fn placement(&self) -> Placement {
        self.place
    }

    /// The engine resource backing `rank`'s ops of kind `k`.  Panics on
    /// an out-of-range rank — the old modulo indexing silently wrapped
    /// such ranks onto another rank's bundle, turning a caller bug into
    /// phantom contention.
    pub fn get(&self, rank: usize, k: ResKind) -> ResourceId {
        assert!(
            rank < self.ranks,
            "rank {rank} out of range: bundle installed for {} ranks",
            self.ranks
        );
        match k {
            ResKind::Wire => {
                self.wire[self.place.node_of(rank) * self.place.rails + self.place.rail_of(rank)]
            }
            ResKind::Pcie => self.pcie[self.place.node_of(rank)],
            ResKind::GpuReduce => self.gpu[rank],
            ResKind::CpuReduce => self.cpu[rank],
            ResKind::Driver => self.driver[rank],
            ResKind::Launch => self.launch[rank],
            ResKind::Sw => self.sw[rank],
        }
    }

    pub fn mapper(&self) -> GraphResMap {
        let me = self.clone();
        Rc::new(move |rank, k, _rel| Some(me.get(rank, k)))
    }

    /// Per-kind (served, busy) rows aggregated across the *distinct*
    /// underlying resources (shared node resources count once, not once
    /// per co-located rank) — same row names as the serialized path's
    /// `CommResources::utilization`.
    pub fn utilization(&self, e: &Engine) -> Vec<ResourceUse> {
        let rows: [(&str, &Vec<ResourceId>); 7] = [
            (ResKind::Wire.name(), &self.wire),
            (ResKind::Pcie.name(), &self.pcie),
            (ResKind::GpuReduce.name(), &self.gpu),
            (ResKind::CpuReduce.name(), &self.cpu),
            (ResKind::Driver.name(), &self.driver),
            (ResKind::Launch.name(), &self.launch),
            (ResKind::Sw.name(), &self.sw),
        ];
        rows.iter()
            .map(|(name, ids)| ResourceUse::aggregate(e, name, ids.iter().copied()))
            .filter(|u| u.served > 0)
            .collect()
    }
}

/// Per-node start/finish times of one executed graph.
#[derive(Debug, Clone)]
pub struct GraphRun {
    pub start: Vec<SimTime>,
    pub finish: Vec<SimTime>,
}

impl GraphRun {
    pub fn finish_of(&self, id: NodeId) -> SimTime {
        self.finish[id.0]
    }
}

/// Execute a graph on the engine under the neutral overlay — the
/// one-shot path (plan rebuilt per call).  Cached replay goes through
/// [`GraphTemplate::execute`].
pub fn execute(
    e: &mut Engine,
    g: &CommGraph,
    map: GraphResMap,
    done: Action,
) -> Rc<RefCell<GraphRun>> {
    let now = e.now();
    execute_at(e, g, map, now, done)
}

/// [`execute`] with the source release deferred to virtual time `at`
/// (>= now) — lets a caller wire up many graphs at setup time, each
/// releasing when its data is ready.
pub fn execute_at(
    e: &mut Engine,
    g: &CommGraph,
    map: GraphResMap,
    at: SimTime,
    done: Action,
) -> Rc<RefCell<GraphRun>> {
    let plan = GraphPlan::of(g);
    execute_planned(e, g, &plan, &map, &GraphOverlay::neutral(), at, OnDone::Call(done))
}

/// The shared executor: wire joins from the (pre)computed plan, resolve
/// each node against `map` + `ov` into a typed engine program, release
/// sources at `at`.
fn execute_planned(
    e: &mut Engine,
    g: &CommGraph,
    plan: &GraphPlan,
    map: &GraphResMap,
    ov: &GraphOverlay,
    at: SimTime,
    done: OnDone,
) -> Rc<RefCell<GraphRun>> {
    let n = g.nodes.len();
    let run = Rc::new(RefCell::new(GraphRun {
        start: vec![SimTime::ZERO; n],
        finish: vec![SimTime::ZERO; n],
    }));
    if n == 0 {
        match done {
            OnDone::Call(a) => e.at(at, a),
            // lane executions always release at `at == now` (the job's
            // launch turn), so the empty graph completes on the spot
            OnDone::Lane(set, job) => e.lane_done(set, job),
        }
        return run;
    }
    let terminal = e.join_with(plan.sink_count, done);

    // Joins must exist before the node actions that arrive at them are
    // built; nodes are created in topological order, so walking in
    // reverse guarantees every successor's join is already allocated.
    let mut joins = vec![None; n];
    let mut sources: Vec<(usize, Action)> = Vec::new();
    for i in (0..n).rev() {
        let node = &g.nodes[i];
        let steps = resolve_node(node, map, ov);
        let succ_joins: Vec<_> =
            plan.succ[i].iter().map(|&j| joins[j].expect("topological order")).collect();
        let run_i = run.clone();
        let action = move |e: &mut Engine| {
            run_i.borrow_mut().start[i] = e.now();
            let run_done = run_i.clone();
            e.run_program(
                steps,
                Box::new(move |e| {
                    run_done.borrow_mut().finish[i] = e.now();
                    if succ_joins.is_empty() {
                        e.arrive(terminal);
                    }
                    for j in succ_joins {
                        e.arrive(j);
                    }
                }),
            );
        };
        if plan.indeg[i] == 0 {
            sources.push((i, Box::new(action)));
        } else {
            joins[i] = Some(e.join(plan.indeg[i], action));
        }
    }
    sources.sort_by_key(|&(i, _)| i);
    for (_, a) in sources {
        e.at(at, a);
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::commop::CommSchedule;
    use crate::comm::CostBreakdown;

    fn wire_steps(count: usize, us: f64) -> Vec<StepCost> {
        vec![
            StepCost {
                cost: CostBreakdown { wire_us: us, ..Default::default() },
                gpu_reduce: false,
            };
            count
        ]
    }

    fn run_graph(g: &CommGraph, ranks: usize) -> (SimTime, GraphRun) {
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, ranks);
        let run = execute(&mut e, g, res.mapper(), Box::new(|_| {}));
        let end = e.run();
        let out = run.borrow().clone();
        (end, out)
    }

    fn run_template(t: &GraphTemplate, ranks: usize, ov: &GraphOverlay) -> (SimTime, GraphRun) {
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, ranks);
        let run = t.execute(&mut e, res.mapper(), ov, Box::new(|_| {}));
        let end = e.run();
        let out = run.borrow().clone();
        (end, out)
    }

    #[test]
    fn zero_skew_ring_matches_serialized_total() {
        for p in [2usize, 3, 4, 8] {
            let steps = wire_steps(2 * (p - 1), 10.0);
            let g = ring_graph(p, &steps);
            assert_eq!(g.len(), p * steps.len());
            let serial = CommSchedule::from_steps(&steps).total_us();
            let (end, _) = run_graph(&g, p);
            assert!(
                (end.as_us() - serial).abs() < 1e-9,
                "ring p={p}: graph {} vs serial {serial}",
                end.as_us()
            );
            assert!((g.critical_path_us() - serial).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_skew_rhd_and_tree_match_serialized_total() {
        for p in [2usize, 3, 5, 8, 13, 16] {
            let p2 = crate::comm::allreduce::flp2(p);
            let rem_steps = if p > p2 { 2 } else { 0 };
            let rhd_steps = wire_steps(rem_steps + 2 * p2.trailing_zeros() as usize, 7.0);
            let g = rhd_graph(p, &rhd_steps);
            let serial = CommSchedule::from_steps(&rhd_steps).total_us();
            let (end, _) = run_graph(&g, p);
            assert!(
                (end.as_us() - serial).abs() < 1e-9,
                "rhd p={p}: graph {} vs serial {serial}",
                end.as_us()
            );

            // tree level count: log2 up + log2 down (shadow skips no level
            // for p >= 2)
            let levels = {
                let mut c = 0;
                let mut dist = 1;
                while dist < p {
                    c += 1;
                    dist *= 2;
                }
                let mut dist = p.next_power_of_two() / 2;
                while dist >= 1 {
                    if (0..p).step_by(2 * dist).any(|s| s + dist < p) {
                        c += 1;
                    }
                    dist /= 2;
                }
                c
            };
            let tree_steps = wire_steps(levels, 5.0);
            let g = tree_graph(p, &tree_steps);
            let serial = CommSchedule::from_steps(&tree_steps).total_us();
            let (end, _) = run_graph(&g, p);
            assert!(
                (end.as_us() - serial).abs() < 1e-9,
                "tree p={p}: graph {} vs serial {serial}",
                end.as_us()
            );
        }
    }

    #[test]
    fn straggler_skew_propagates_one_rank_per_step() {
        // Ring p=4, 6 uniform 10us steps; rank 1 runs 2x slow (overlay).
        // The skew cone: a node (r, s) is delayed iff s >= ring-distance
        // (1 -> r); outside the cone finish times match the pristine run.
        let p = 4;
        let steps = wire_steps(2 * (p - 1), 10.0);
        let t = GraphTemplate::new(ring_graph(p, &steps));
        let (_, base) = run_template(&t, p, &GraphOverlay::neutral());
        let mut ov = GraphOverlay::neutral();
        ov.scale_rank(p, 1, 2.0);
        let (end, run) = run_template(&t, p, &ov);

        let at = |r: usize, s: usize| NodeId(s * p + r); // ring builder layout
        // unaffected: early steps of downstream ranks
        assert_eq!(run.finish_of(at(2, 0)), base.finish_of(at(2, 0)));
        assert_eq!(run.finish_of(at(3, 1)), base.finish_of(at(3, 1)));
        assert_eq!(run.finish_of(at(0, 2)), base.finish_of(at(0, 2)));
        // delayed: the dependent steps one hop later
        assert!(run.finish_of(at(2, 1)) > base.finish_of(at(2, 1)));
        assert!(run.finish_of(at(3, 2)) > base.finish_of(at(3, 2)));
        assert!(run.finish_of(at(0, 3)) > base.finish_of(at(0, 3)));
        // the straggler's own chain dominates completion: 6 steps × 20us
        assert_eq!(end, SimTime::from_us(120.0));
    }

    #[test]
    fn ps_fanin_updates_after_last_push_and_pulls_fifo() {
        let mut e = Engine::new();
        let nic_in = e.unit_resource();
        let nic_out = e.unit_resource();
        let (g, pulls) = ps_fanin_graph(
            3,
            0,
            |_| vec![CommOp::fixed(ResKind::Wire, 10.0).pinned(nic_in)],
            vec![CommOp::fixed(ResKind::CpuReduce, 5.0)],
            |_| vec![CommOp::fixed(ResKind::Wire, 10.0).pinned(nic_out)],
        );
        let done = Rc::new(RefCell::new(0.0));
        let d2 = done.clone();
        let run = execute(
            &mut e,
            &g,
            unmapped(),
            Box::new(move |e| *d2.borrow_mut() = e.now().as_us()),
        );
        e.run();
        // pushes serialize on the ingress NIC (10/20/30), update at 35,
        // pulls serialize on the egress NIC in worker order (45/55/65)
        let r = run.borrow();
        assert_eq!(
            pulls.iter().map(|&id| r.finish_of(id).as_us()).collect::<Vec<_>>(),
            vec![45.0, 55.0, 65.0]
        );
        assert_eq!(*done.borrow(), 65.0);
    }

    #[test]
    fn prefix_root_gates_every_source() {
        let p = 3;
        let steps = wire_steps(2 * (p - 1), 10.0);
        let mut g = ring_graph(p, &steps);
        g.prefix_root(0, vec![CommOp::fixed(ResKind::Sw, 4.0)]);
        assert_eq!(g.nodes[0].deps.len(), 0);
        assert!(g.nodes[1..].iter().all(|n| !n.deps.is_empty()));
        let (end, _) = run_graph(&g, p);
        assert!((end.as_us() - (4.0 + 40.0)).abs() < 1e-9);
    }

    #[test]
    fn jitter_lead_is_additive_and_keyed_by_rank_step() {
        let steps = wire_steps(2, 10.0);
        let t = GraphTemplate::new(ring_graph(2, &steps));
        let mut ov = GraphOverlay::neutral();
        ov.set_lead(|rank, step| if rank == 0 && step == 0 { 3.0 } else { 0.0 });
        let (end, run) = run_template(&t, 2, &ov);
        assert_eq!(run.finish_of(NodeId(0)), SimTime::from_us(13.0));
        // rank 1 step 1 depends on rank 0 step 0: jitter propagates
        assert_eq!(end, SimTime::from_us(23.0));
    }

    #[test]
    fn empty_graph_completes_immediately() {
        let mut e = Engine::new();
        let fired = Rc::new(RefCell::new(false));
        let f = fired.clone();
        execute(
            &mut e,
            &CommGraph::default(),
            unmapped(),
            Box::new(move |_| *f.borrow_mut() = true),
        );
        let end = e.run();
        assert!(*fired.borrow());
        assert_eq!(end, SimTime::ZERO);
    }

    #[test]
    fn template_replay_matches_one_shot_execute_bitwise() {
        // the §Perf pin at graph level: executing a cached template under
        // the neutral overlay reproduces the one-shot path exactly, and
        // replaying the SAME template again gives the same trace
        for p in [3usize, 8] {
            let steps = wire_steps(2 * (p - 1), 9.5);
            let g = ring_graph(p, &steps);
            let (end0, run0) = run_graph(&g, p);
            let t = GraphTemplate::new(g);
            let (end1, run1) = run_template(&t, p, &GraphOverlay::neutral());
            let (end2, run2) = run_template(&t, p, &GraphOverlay::neutral());
            assert_eq!(end0, end1);
            assert_eq!(run0.finish, run1.finish);
            assert_eq!(end1, end2);
            assert_eq!(run1.finish, run2.finish);
        }
    }

    /// Materialize an overlay into a mutated graph the way the old
    /// in-place perturbation API did — the oracle for overlay replay.
    fn materialize(g: &CommGraph, ov_global: f64, all: &[f64], gpu: &[f64],
                   lead: impl Fn(usize, u32) -> f64) -> CommGraph {
        let mut out = g.clone();
        for n in &mut out.nodes {
            for op in &mut n.ops {
                op.us *= ov_global;
                op.us *= all.get(n.rank).copied().unwrap_or(1.0);
                if matches!(op.kind, ResKind::GpuReduce | ResKind::Launch | ResKind::Pcie) {
                    op.us *= gpu.get(n.rank).copied().unwrap_or(1.0);
                }
            }
            let j = lead(n.rank, n.step);
            if j > 0.0 {
                n.ops.insert(0, CommOp::fixed(ResKind::Sw, j));
            }
        }
        out
    }

    #[test]
    fn overlay_replay_equals_materialized_mutation() {
        // straggler + hetero + jitter overlays on a mixed-kind ring must
        // reproduce a freshly built mutated graph bit-for-bit
        let p = 5;
        let steps: Vec<StepCost> = (0..2 * (p - 1))
            .map(|i| StepCost {
                cost: CostBreakdown {
                    wire_us: 7.0 + i as f64,
                    staging_us: 1.5,
                    reduce_us: 2.25,
                    launch_us: 0.5,
                    sw_us: 0.75,
                    ..Default::default()
                },
                gpu_reduce: true,
            })
            .collect();
        let g = ring_graph(p, &steps);
        let lead = |rank: usize, step: u32| {
            if (rank + step as usize) % 3 == 0 { 1.0 + rank as f64 * 0.37 } else { 0.0 }
        };
        let mut all = vec![1.0; p];
        all[1] = 1.7;
        let mut gpu = vec![1.0; p];
        gpu[4] = 2.5;
        gpu[1] = 1.3; // rank 1 is both straggler and on a slow GPU

        let oracle = materialize(&g, 1.25, &all, &gpu, lead);
        let (end_o, run_o) = run_graph(&oracle, p);

        let t = GraphTemplate::new(g);
        let mut ov = GraphOverlay::neutral();
        ov.scale_global(1.25);
        ov.scale_rank(p, 1, 1.7);
        ov.scale_rank_gpu(p, 4, 2.5);
        ov.scale_rank_gpu(p, 1, 1.3);
        ov.set_lead(lead);
        let (end_t, run_t) = run_template(&t, p, &ov);

        assert_eq!(end_o, end_t, "overlay end diverged from materialized graph");
        assert_eq!(run_o.finish, run_t.finish, "per-node finishes diverged");
        assert_eq!(run_o.start, run_t.start, "per-node starts diverged");
    }

    #[test]
    fn rel_pins_resolve_through_the_map() {
        // a named (template-relative) pin routes onto whatever resource
        // THIS engine's map resolves it to — two executions contend on
        // the named NIC exactly like the old engine-id pin
        let mut e = Engine::new();
        let nic = e.unit_resource();
        let g = CommGraph::chain(
            0,
            vec![CommOp::fixed(ResKind::Wire, 10.0).rel_pinned(RelPin::PsIn(3))],
        );
        let map: GraphResMap = Rc::new(move |_, _, rel| match rel {
            Some(RelPin::PsIn(3)) => Some(nic),
            _ => None,
        });
        for _ in 0..2 {
            execute(&mut e, &g, map.clone(), Box::new(|_| {}));
        }
        let end = e.run();
        assert_eq!(end, SimTime::from_us(20.0));
        let (served, busy) = e.resource_stats(nic);
        assert_eq!((served, busy), (2, SimTime::from_us(20.0)));
        // under a map that does not name it, the op elapses per-rank
        let mut e2 = Engine::new();
        execute(&mut e2, &g, unmapped(), Box::new(|_| {}));
        assert_eq!(e2.run(), SimTime::from_us(10.0));
    }

    #[test]
    fn execute_lane_completes_through_typed_join() {
        // the §Overlap execution shape: templates launched as lane jobs
        // finish through the typed terminal join, hand the lane back,
        // and a width-1 set reproduces back-to-back serialized rings
        use crate::sim::{LaneDriver, LaneSetId};
        struct D {
            t: Arc<GraphTemplate>,
            map: GraphResMap,
        }
        impl LaneDriver for D {
            fn launch(&self, e: &mut Engine, set: LaneSetId, job: u32) {
                self.t.execute_lane(e, self.map.clone(), &GraphOverlay::neutral(), set, job);
            }
        }
        let p = 3;
        let steps = wire_steps(2 * (p - 1), 10.0);
        let t = Arc::new(GraphTemplate::new(ring_graph(p, &steps)));
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, p);
        let set = e.lane_set(1, 1, Rc::new(D { t, map: res.mapper() }));
        e.lane_submit(set, SimTime::ZERO, 0);
        e.lane_submit(set, SimTime::ZERO, 1);
        let end = e.run();
        let serial = CommSchedule::from_steps(&steps).total_us();
        assert!((end.as_us() - 2.0 * serial).abs() < 1e-9);
        assert_eq!(e.lane_completed(set), 2);
        let (launches, busy) = e.lane_stats(set);
        assert_eq!(launches, 2);
        assert_eq!(busy, end);
    }

    #[test]
    fn ps_fanin_pulls_match_builder_layout() {
        let (g, pulls) = ps_fanin_graph(
            4,
            1,
            |_| vec![CommOp::fixed(ResKind::Sw, 1.0)],
            vec![CommOp::fixed(ResKind::CpuReduce, 1.0)],
            |_| vec![CommOp::fixed(ResKind::Sw, 1.0)],
        );
        assert_eq!(pulls, ps_fanin_pulls(4));
        assert_eq!(g.len(), 9);
    }

    #[test]
    fn template_cache_hits_on_equal_keys_only() {
        let cache = TemplateCache::default();
        let steps = wire_steps(4, 10.0);
        let sig = crate::comm::commop::steps_sig(&steps);
        let t1 = cache.get_or_build(TemplateKey::allreduce(Algo::Ring, 3, sig.clone()), || {
            ring_graph(3, &steps)
        });
        let t2 = cache.get_or_build(TemplateKey::allreduce(Algo::Ring, 3, sig.clone()), || {
            panic!("must hit the cache")
        });
        assert!(Arc::ptr_eq(&t1, &t2), "same key must be pointer-cached");
        assert_eq!(cache.len(), 1);
        // different world or perturbed costs miss
        cache.get_or_build(TemplateKey::allreduce(Algo::Ring, 4, sig), || ring_graph(4, &steps));
        let steps2 = wire_steps(4, 10.000001);
        let sig2 = crate::comm::commop::steps_sig(&steps2);
        cache.get_or_build(TemplateKey::allreduce(Algo::Ring, 3, sig2), || {
            ring_graph(3, &steps2)
        });
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn shared_wire_contends_across_jobs_private_rest_overlaps() {
        // Two single-node chains on the same rank-0 NIC: wire serializes,
        // the private gpu phases overlap — the two-job model at rank level.
        let mut e = Engine::new();
        let a = GraphResources::install(&mut e, 2);
        let b = GraphResources::sharing_wire(&mut e, 2, &a);
        let mut ends = Vec::new();
        for res in [&a, &b] {
            let g = CommGraph::chain(
                0,
                vec![CommOp::fixed(ResKind::Wire, 10.0), CommOp::fixed(ResKind::GpuReduce, 5.0)],
            );
            let done = Rc::new(RefCell::new(0.0));
            let d2 = done.clone();
            execute(
                &mut e,
                &g,
                res.mapper(),
                Box::new(move |e| *d2.borrow_mut() = e.now().as_us()),
            );
            ends.push(done);
        }
        e.run();
        assert_eq!(*ends[0].borrow(), 15.0);
        assert_eq!(*ends[1].borrow(), 25.0);
        let (_, busy) = e.resource_stats(a.wire[0]);
        assert_eq!(busy, SimTime::from_us(20.0));
    }

    #[test]
    fn placed_bundle_shares_node_nic_and_pcie_keeps_gpu_private() {
        // Two co-located ranks (2 GPUs/node, 1 rail): their wire ops
        // serialize on the node's one port, their gpu ops overlap.
        let mut e = Engine::new();
        let res = GraphResources::install_placed(&mut e, 2, Placement::new(2, 1));
        assert_eq!(res.ranks(), 2);
        assert_eq!(res.wire.len(), 1);
        assert_eq!(res.pcie.len(), 1);
        assert_eq!(res.gpu.len(), 2);
        assert_eq!(res.get(0, ResKind::Wire), res.get(1, ResKind::Wire));
        assert_eq!(res.get(0, ResKind::Pcie), res.get(1, ResKind::Pcie));
        assert_ne!(res.get(0, ResKind::GpuReduce), res.get(1, ResKind::GpuReduce));
        let mut g = CommGraph::default();
        for r in 0..2 {
            g.push_node(
                r,
                0,
                vec![CommOp::fixed(ResKind::Wire, 10.0), CommOp::fixed(ResKind::GpuReduce, 5.0)],
                Vec::new(),
            );
        }
        let (end, run) = {
            let run = execute(&mut e, &g, res.mapper(), Box::new(|_| {}));
            let end = e.run();
            let out = run.borrow().clone();
            (end, out)
        };
        // rank 0 wire 0-10, gpu 10-15; rank 1 wire queues 10-20, gpu 20-25
        assert_eq!(run.finish, vec![SimTime::from_us(15.0), SimTime::from_us(25.0)]);
        assert_eq!(end, SimTime::from_us(25.0));
    }

    #[test]
    fn second_rail_splits_the_node_nic() {
        // same two-rank node, 2 rails: each rank gets its own port, the
        // wire ops run in parallel again
        let mut e = Engine::new();
        let res = GraphResources::install_placed(&mut e, 2, Placement::new(2, 2));
        assert_eq!(res.wire.len(), 2);
        assert_ne!(res.get(0, ResKind::Wire), res.get(1, ResKind::Wire));
        let mut g = CommGraph::default();
        for r in 0..2 {
            g.push_node(r, 0, vec![CommOp::fixed(ResKind::Wire, 10.0)], Vec::new());
        }
        let run = execute(&mut e, &g, res.mapper(), Box::new(|_| {}));
        let end = e.run();
        assert_eq!(end, SimTime::from_us(10.0));
        assert_eq!(
            run.borrow().finish,
            vec![SimTime::from_us(10.0), SimTime::from_us(10.0)]
        );
    }

    #[test]
    fn placed_ring_rekind_intra_hops_onto_pcie() {
        // p=4 in 2-GPU nodes: odd ranks receive from their on-node
        // neighbour — those hops re-kind to Pcie and scale by `local`;
        // even ranks' hops stay on the wire, untouched.
        let steps = wire_steps(1, 10.0);
        let place = Placement::new(2, 1);
        let g = ring_graph_placed(4, &steps, place, 0.5);
        assert_eq!(g.len(), 4);
        for node in &g.nodes {
            let op = node.ops[0];
            if node.rank % 2 == 1 {
                assert_eq!(op.kind, ResKind::Pcie, "rank {} hop should be local", node.rank);
                assert!((op.us - 5.0).abs() < 1e-12);
            } else {
                assert_eq!(op.kind, ResKind::Wire, "rank {} hop should cross", node.rank);
                assert!((op.us - 10.0).abs() < 1e-12);
            }
        }
        // trivial placement reproduces the unplaced builder bit-for-bit,
        // whatever the local factor
        let a = ring_graph(4, &steps);
        let b = ring_graph_placed(4, &steps, Placement::one_per_node(), 0.25);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.nodes.iter().zip(&b.nodes) {
            assert_eq!(x.rank, y.rank);
            assert_eq!(x.deps, y.deps);
            assert_eq!(x.ops.len(), y.ops.len());
            for (ox, oy) in x.ops.iter().zip(&y.ops) {
                assert_eq!(ox.kind, oy.kind);
                assert_eq!(ox.us.to_bits(), oy.us.to_bits());
            }
        }
    }

    #[test]
    fn sharing_wire_handles_different_rank_counts() {
        // job A spans 2 nodes, job B spans 4: the first two nodes' ports
        // are shared, B's extra nodes get private ports (the old code
        // sized B's whole bundle off A and wrapped B's high ranks onto
        // A's ports)
        let mut e = Engine::new();
        let place = Placement::new(2, 1);
        let a = GraphResources::install_placed(&mut e, 4, place);
        let b = GraphResources::sharing_wire(&mut e, 8, &a);
        assert_eq!(b.ranks(), 8);
        assert_eq!(b.wire.len(), 4);
        assert_eq!(b.get(0, ResKind::Wire), a.get(0, ResKind::Wire));
        assert_eq!(b.get(3, ResKind::Wire), a.get(3, ResKind::Wire));
        // beyond A's span: private ports, and B's own non-wire resources
        assert!(a.wire.iter().all(|&w| w != b.get(6, ResKind::Wire)));
        assert_ne!(b.get(0, ResKind::GpuReduce), a.get(0, ResKind::GpuReduce));
        // the smaller-job direction shares only the overlap
        let c = GraphResources::sharing_wire(&mut e, 2, &a);
        assert_eq!(c.wire.len(), 1);
        assert_eq!(c.get(1, ResKind::Wire), a.get(0, ResKind::Wire));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn get_rejects_out_of_range_ranks() {
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, 4);
        let _ = res.get(4, ResKind::Wire);
    }

    #[test]
    fn template_cache_distinguishes_placements() {
        // same (algo, world, step costs), three layouts: three distinct
        // keys, three distinct templates — and the dense timelines
        // actually differ from the trivial one
        let cache = TemplateCache::default();
        let steps = wire_steps(6, 10.0);
        let sig = crate::comm::commop::steps_sig(&steps);
        let trivial = cache.get_or_build(TemplateKey::allreduce(Algo::Ring, 4, sig.clone()), || {
            ring_graph(4, &steps)
        });
        let dense = cache.get_or_build(
            TemplateKey::allreduce_placed(Algo::Ring, 4, Placement::new(2, 1), sig.clone()),
            || ring_graph_placed(4, &steps, Placement::new(2, 1), 3.0),
        );
        let railed = cache.get_or_build(
            TemplateKey::allreduce_placed(Algo::Ring, 4, Placement::new(2, 2), sig.clone()),
            || ring_graph_placed(4, &steps, Placement::new(2, 2), 3.0),
        );
        assert_eq!(cache.len(), 3, "placements must not alias in the cache");
        assert!(!Arc::ptr_eq(&trivial, &dense));
        assert!(!Arc::ptr_eq(&dense, &railed));
        // distinct timelines: intra hops at 3x make the dense chains
        // strictly longer than the trivial 6 × 10us serialization
        let run = |t: &GraphTemplate, place: Placement| {
            let mut e = Engine::new();
            let res = GraphResources::install_placed(&mut e, 4, place);
            t.execute(&mut e, res.mapper(), &GraphOverlay::neutral(), Box::new(|_| {}));
            e.run()
        };
        let end_trivial = run(&trivial, Placement::one_per_node());
        let end_dense = run(&dense, Placement::new(2, 1));
        assert_eq!(end_trivial, SimTime::from_us(60.0));
        assert!(end_dense > end_trivial, "{end_dense} vs {end_trivial}");
        // warm-vs-cold under placement: the same key replays the same
        // pointer and the same timeline
        let warm = cache.get_or_build(
            TemplateKey::allreduce_placed(Algo::Ring, 4, Placement::new(2, 1), sig),
            || panic!("placement key must hit the cache"),
        );
        assert!(Arc::ptr_eq(&dense, &warm));
        assert_eq!(run(&warm, Placement::new(2, 1)), end_dense);
    }
}
