//! The `CommGraph` layer: a collective as a DAG of **per-rank** `CommOp`
//! nodes with explicit cross-rank dependencies, executed dependency-aware
//! on the discrete-event engine.
//!
//! A serialized [`CommSchedule`](crate::comm::commop::CommSchedule) models
//! the critical-path rank: one op chain, so a straggler can only shift the
//! *whole* collective.  On real fabrics skew propagates *through* the
//! algorithm: ring step *s* on rank *r* cannot start before step *s−1* of
//! rank *r* **and** the matching send of rank *r−1* — one slow rank delays
//! its downstream neighbours one step later, the next neighbour two steps
//! later, a cone that widens by one rank per step.  That propagation (the
//! structure the paper's Allreduce characterization rides on) is exactly
//! what this graph expresses and the serialized form cannot.
//!
//! Contract:
//!  * **Nodes** — one per (rank, algorithm step): an ordered `CommOp` list
//!    (the same [`StepCost::ops`] decomposition the serialized schedule
//!    uses, so durations stay pinned to the validated α–β cost models).
//!  * **Edges** — `deps`: a node becomes *eligible* only when every
//!    predecessor has finished.  Builders wire ring / halving-doubling /
//!    tree / PS fan-in topologies.
//!  * **Eligibility vs queueing** — eligibility is an engine *join*
//!    ([`Engine::join`]); once eligible, a node's ops queue FIFO on its
//!    rank's **node-local** resources ([`GraphResources`]: per-rank NIC,
//!    PCIe link, GPU, …) instead of the one shared per-job proxy.
//!
//! With uniform per-step durations (no scenario perturbation) the graph's
//! completion time provably equals the serialized schedule's total: every
//! rank's chain is the same op sequence, and cross-rank edges between
//! equal-length chains never extend the path.  `tests` and
//! `tests/des_regression.rs` pin this zero-skew equivalence, which is what
//! lets the strategies keep the fast serialized replay when nothing skews
//! ranks apart.

use std::cell::RefCell;
use std::rc::Rc;

use crate::comm::allreduce::Algo;
use crate::comm::commop::{replay, CommOp, ResKind, ResMap, ResourceUse, StepCost};
use crate::sim::{Engine, ResourceId, SimTime};

/// Handle to a node inside one [`CommGraph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NodeId(pub usize);

/// One unit of per-rank work: an ordered op list plus the nodes that must
/// finish before it may start.
#[derive(Debug, Clone)]
pub struct GraphNode {
    pub rank: usize,
    /// Builder step index (timeline display, deterministic jitter keys).
    pub step: u32,
    pub ops: Vec<CommOp>,
    pub deps: Vec<NodeId>,
}

impl GraphNode {
    pub fn dur_us(&self) -> f64 {
        self.ops.iter().map(|o| o.us).sum()
    }
}

/// A DAG of per-rank [`GraphNode`]s.  Nodes are created in topological
/// order (dependencies always point backwards), which keeps execution and
/// critical-path evaluation single-pass.
#[derive(Debug, Clone, Default)]
pub struct CommGraph {
    pub nodes: Vec<GraphNode>,
}

impl CommGraph {
    pub fn push_node(
        &mut self,
        rank: usize,
        step: u32,
        ops: Vec<CommOp>,
        deps: Vec<NodeId>,
    ) -> NodeId {
        let id = self.nodes.len();
        debug_assert!(deps.iter().all(|d| d.0 < id), "deps must precede the node");
        self.nodes.push(GraphNode { rank, step, ops, deps });
        NodeId(id)
    }

    /// The trivial adapter for linear schedules (gRPC-family transfers):
    /// one node carrying the whole op chain on one rank.
    pub fn chain(rank: usize, ops: Vec<CommOp>) -> CommGraph {
        let mut g = CommGraph::default();
        g.push_node(rank, 0, ops, Vec::new());
        g
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Sum of every node's work — the per-rank ledger, *not* wall time
    /// (p ranks working in parallel each contribute their own ops).
    pub fn total_us(&self) -> f64 {
        self.nodes.iter().map(|n| n.dur_us()).sum()
    }

    /// Longest dependency path, assuming no resource queueing — the
    /// zero-contention wall time of the graph.
    pub fn critical_path_us(&self) -> f64 {
        let mut cp = vec![0.0f64; self.nodes.len()];
        let mut best = 0.0f64;
        for (i, node) in self.nodes.iter().enumerate() {
            let start = node.deps.iter().map(|d| cp[d.0]).fold(0.0, f64::max);
            cp[i] = start + node.dur_us();
            best = best.max(cp[i]);
        }
        best
    }

    /// Scale every op duration (Baidu's ring-pipeline amortization).
    pub fn scale(&mut self, s: f64) {
        for n in &mut self.nodes {
            for op in &mut n.ops {
                op.us *= s;
            }
        }
    }

    /// Scale every op of one rank's nodes — a straggler whose progress
    /// engine, host and links all run slow.
    pub fn scale_rank(&mut self, rank: usize, f: f64) {
        for n in &mut self.nodes {
            if n.rank == rank {
                for op in &mut n.ops {
                    op.us *= f;
                }
            }
        }
    }

    /// Scale only the GPU-side ops (reduce kernel, launch, PCIe staging)
    /// of one rank — a rank placed on an older GPU generation.
    pub fn scale_rank_gpu(&mut self, rank: usize, f: f64) {
        for n in &mut self.nodes {
            if n.rank == rank {
                for op in &mut n.ops {
                    if matches!(op.kind, ResKind::GpuReduce | ResKind::Launch | ResKind::Pcie) {
                        op.us *= f;
                    }
                }
            }
        }
    }

    /// Add per-node extra delay from a deterministic draw of
    /// `(rank, step)` — OS/sync jitter at step granularity.  The delay is
    /// prepended as an *unpinned* `Sw` op (per-rank pre-start stall), so
    /// it never inflates the occupancy of a shared pinned resource — a
    /// jittery worker delays itself, not the NIC queue behind it.
    pub fn jitter_nodes(&mut self, draw: impl Fn(usize, u32) -> f64) {
        for n in &mut self.nodes {
            let j = draw(n.rank, n.step);
            if j > 0.0 {
                n.ops.insert(0, CommOp::fixed(ResKind::Sw, j));
            }
        }
    }

    /// Prepend a root node every current source depends on — Horovod's
    /// rank-0 coordination round before the buffer's Allreduce.  Existing
    /// step indices shift by one.
    pub fn prefix_root(&mut self, rank: usize, ops: Vec<CommOp>) {
        let mut nodes = Vec::with_capacity(self.nodes.len() + 1);
        nodes.push(GraphNode { rank, step: 0, ops, deps: Vec::new() });
        for n in self.nodes.drain(..) {
            let deps = if n.deps.is_empty() {
                vec![NodeId(0)]
            } else {
                n.deps.iter().map(|d| NodeId(d.0 + 1)).collect()
            };
            nodes.push(GraphNode { step: n.step + 1, deps, ..n });
        }
        self.nodes = nodes;
    }
}

fn dep2(a: Option<NodeId>, b: Option<NodeId>) -> Vec<NodeId> {
    let mut v = Vec::new();
    if let Some(x) = a {
        v.push(x);
    }
    if let Some(y) = b {
        if a != Some(y) {
            v.push(y);
        }
    }
    v
}

/// Build the dependency graph of an allreduce from its validated per-step
/// costs (the same [`StepCost`] sequence the serialized schedule uses).
pub fn allreduce_graph(algo: Algo, p: usize, steps: &[StepCost]) -> CommGraph {
    match algo {
        Algo::Ring => ring_graph(p, steps),
        Algo::Rhd => rhd_graph(p, steps),
        Algo::Tree => tree_graph(p, steps),
    }
}

/// Ring: step *s* on rank *r* waits on its own step *s−1* and on the
/// matching send of rank *r−1* (the data it receives this step).
pub fn ring_graph(p: usize, steps: &[StepCost]) -> CommGraph {
    let mut g = CommGraph::default();
    if p < 2 {
        return g;
    }
    let mut last: Vec<Option<NodeId>> = vec![None; p];
    for (s, st) in steps.iter().enumerate() {
        let ops = st.ops();
        let prev = last.clone();
        for (r, slot) in last.iter_mut().enumerate() {
            let from = (r + p - 1) % p;
            *slot = Some(g.push_node(r, s as u32, ops.clone(), dep2(prev[r], prev[from])));
        }
    }
    g
}

/// Recursive halving-doubling: mask step exchanges pair rank *r* with
/// *r ^ mask*; a non-power-of-two world folds the extra ranks into their
/// base partner first (pre) and unfolds them last (post) — the same phase
/// sequence `shadow::rhd_shadow` charges.
pub fn rhd_graph(p: usize, steps: &[StepCost]) -> CommGraph {
    let mut g = CommGraph::default();
    if p < 2 {
        return g;
    }
    let p2 = crate::comm::allreduce::flp2(p);
    let rem = p - p2;
    let mut last: Vec<Option<NodeId>> = vec![None; p];
    let mut si = 0usize;

    let mut fold_step = |g: &mut CommGraph, last: &mut Vec<Option<NodeId>>, si: &mut usize| {
        let ops = steps[*si].ops();
        let stepi = *si as u32;
        *si += 1;
        let prev = last.clone();
        for r in p2..p {
            let base = r - p2;
            last[r] = Some(g.push_node(r, stepi, ops.clone(), dep2(prev[r], prev[base])));
            last[base] = Some(g.push_node(base, stepi, ops.clone(), dep2(prev[base], prev[r])));
        }
    };

    if rem > 0 {
        fold_step(&mut g, &mut last, &mut si);
    }
    let masks: Vec<usize> = {
        let mut v = Vec::new();
        let mut m = p2 >> 1;
        while m > 0 {
            v.push(m);
            m >>= 1;
        }
        v
    };
    for &mask in masks.iter().chain(masks.iter().rev()) {
        let ops = steps[si].ops();
        let stepi = si as u32;
        si += 1;
        let prev = last.clone();
        for (r, slot) in last.iter_mut().enumerate().take(p2) {
            let q = r ^ mask;
            *slot = Some(g.push_node(r, stepi, ops.clone(), dep2(prev[r], prev[q])));
        }
    }
    if rem > 0 {
        fold_step(&mut g, &mut last, &mut si);
    }
    debug_assert_eq!(si, steps.len(), "rhd builder / shadow step count mismatch");
    g
}

/// Binomial tree: reduce up (receivers reduce), broadcast down.  Each
/// pair's work lives on the receiving rank; the node also becomes the
/// sender's latest node, which serializes a rank's consecutive sends
/// (rank 0 broadcasts one level at a time).
pub fn tree_graph(p: usize, steps: &[StepCost]) -> CommGraph {
    let mut g = CommGraph::default();
    if p < 2 {
        return g;
    }
    let mut last: Vec<Option<NodeId>> = vec![None; p];
    let mut si = 0usize;

    let mut level = |g: &mut CommGraph,
                     last: &mut Vec<Option<NodeId>>,
                     si: &mut usize,
                     pairs: &[(usize, usize)]| {
        let ops = steps[*si].ops();
        let stepi = *si as u32;
        *si += 1;
        let prev = last.clone();
        for &(src, dst) in pairs {
            let id = g.push_node(dst, stepi, ops.clone(), dep2(prev[dst], prev[src]));
            last[dst] = Some(id);
            last[src] = Some(id);
        }
    };

    let mut dist = 1;
    while dist < p {
        let pairs: Vec<(usize, usize)> = (0..p)
            .filter(|r| r % (2 * dist) == dist)
            .map(|src| (src, src - dist))
            .collect();
        if !pairs.is_empty() {
            level(&mut g, &mut last, &mut si, &pairs);
        }
        dist *= 2;
    }
    let mut dist = p.next_power_of_two() / 2;
    while dist >= 1 {
        let pairs: Vec<(usize, usize)> = (0..p)
            .step_by(2 * dist)
            .filter(|&src| src + dist < p)
            .map(|src| (src, src + dist))
            .collect();
        if !pairs.is_empty() {
            level(&mut g, &mut last, &mut si, &pairs);
        }
        dist /= 2;
    }
    debug_assert_eq!(si, steps.len(), "tree builder / shadow step count mismatch");
    g
}

/// The PS fan-in/fan-out DAG of ONE parameter shard: `workers` push
/// chains converge on the server's update node (the fan-in the PS NIC
/// queues feed), which fans back out into `workers` pull chains.  Returns
/// the graph and each worker's pull sink, whose finish time is that
/// worker's completion for the shard.
pub fn ps_fanin_graph(
    workers: usize,
    server_rank: usize,
    push_ops: impl Fn(usize) -> Vec<CommOp>,
    update_ops: Vec<CommOp>,
    pull_ops: impl Fn(usize) -> Vec<CommOp>,
) -> (CommGraph, Vec<NodeId>) {
    let mut g = CommGraph::default();
    let pushes: Vec<NodeId> =
        (0..workers).map(|w| g.push_node(w, 0, push_ops(w), Vec::new())).collect();
    let update = g.push_node(server_rank, 1, update_ops, pushes);
    let pulls: Vec<NodeId> =
        (0..workers).map(|w| g.push_node(w, 2, pull_ops(w), vec![update])).collect();
    (g, pulls)
}

/// Resolves `(rank, kind)` to the engine resource backing that rank's op
/// (or `None` for uncontended per-rank work).
pub type GraphResMap = Rc<dyn Fn(usize, ResKind) -> Option<ResourceId>>;

/// A map backing nothing: every op elapses as a pure per-rank delay
/// (pinned ops still hit their resources).
pub fn unmapped() -> GraphResMap {
    Rc::new(|_, _| None)
}

/// Node-local resources, one full bundle per rank: the wire NIC and PCIe
/// link stop being one shared per-job proxy and become the rank's own
/// (every paper cluster places one GPU per node, so rank ≡ node here).
/// Cross-rank contention inside one collective disappears — replaced by
/// the dependency edges — while co-tenant jobs sharing the fabric contend
/// per NIC via [`GraphResources::sharing_wire`].
#[derive(Clone)]
pub struct GraphResources {
    pub wire: Vec<ResourceId>,
    pub pcie: Vec<ResourceId>,
    pub gpu: Vec<ResourceId>,
    pub cpu: Vec<ResourceId>,
    pub driver: Vec<ResourceId>,
    pub launch: Vec<ResourceId>,
    pub sw: Vec<ResourceId>,
}

impl GraphResources {
    pub fn install(e: &mut Engine, ranks: usize) -> GraphResources {
        let mk = |e: &mut Engine| -> Vec<ResourceId> {
            (0..ranks).map(|_| e.unit_resource()).collect()
        };
        GraphResources {
            wire: mk(e),
            pcie: mk(e),
            gpu: mk(e),
            cpu: mk(e),
            driver: mk(e),
            launch: mk(e),
            sw: mk(e),
        }
    }

    /// A co-tenant job's bundle sharing another job's per-node NICs
    /// (both jobs' wire steps queue FIFO on the same physical ports) but
    /// owning every other node-local resource.
    pub fn sharing_wire(e: &mut Engine, other: &GraphResources) -> GraphResources {
        let mut mine = GraphResources::install(e, other.wire.len());
        mine.wire = other.wire.clone();
        mine
    }

    pub fn ranks(&self) -> usize {
        self.wire.len()
    }

    pub fn get(&self, rank: usize, k: ResKind) -> ResourceId {
        let v = match k {
            ResKind::Wire => &self.wire,
            ResKind::Pcie => &self.pcie,
            ResKind::GpuReduce => &self.gpu,
            ResKind::CpuReduce => &self.cpu,
            ResKind::Driver => &self.driver,
            ResKind::Launch => &self.launch,
            ResKind::Sw => &self.sw,
        };
        v[rank % v.len()]
    }

    pub fn mapper(&self) -> GraphResMap {
        let me = self.clone();
        Rc::new(move |rank, k| Some(me.get(rank, k)))
    }

    /// Per-kind (served, busy) rows aggregated across ranks — same row
    /// names as the serialized path's `CommResources::utilization`.
    pub fn utilization(&self, e: &Engine) -> Vec<ResourceUse> {
        ResKind::ALL
            .iter()
            .map(|&k| {
                ResourceUse::aggregate(e, k.name(), (0..self.ranks()).map(|r| self.get(r, k)))
            })
            .filter(|u| u.served > 0)
            .collect()
    }
}

/// Per-node start/finish times of one executed graph.
#[derive(Debug, Clone)]
pub struct GraphRun {
    pub start: Vec<SimTime>,
    pub finish: Vec<SimTime>,
}

impl GraphRun {
    pub fn finish_of(&self, id: NodeId) -> SimTime {
        self.finish[id.0]
    }
}

/// Execute a graph on the engine: each node becomes *eligible* when all
/// its predecessors complete (an [`Engine::join`]), then its ops queue
/// FIFO on the resources `map` resolves for its rank.  `done` fires when
/// every node has finished.  Source nodes release at the current virtual
/// time, in node order (deterministic FIFO ties).
pub fn execute(
    e: &mut Engine,
    g: &CommGraph,
    map: GraphResMap,
    done: Box<dyn FnOnce(&mut Engine)>,
) -> Rc<RefCell<GraphRun>> {
    let now = e.now();
    execute_at(e, g, map, now, done)
}

/// [`execute`] with the source release deferred to virtual time `at`
/// (>= now) — lets a caller wire up many graphs at setup time, each
/// releasing when its data is ready (the PS strategy schedules one
/// fan-in graph per parameter shard this way).
pub fn execute_at(
    e: &mut Engine,
    g: &CommGraph,
    map: GraphResMap,
    at: SimTime,
    done: Box<dyn FnOnce(&mut Engine)>,
) -> Rc<RefCell<GraphRun>> {
    let n = g.nodes.len();
    let run = Rc::new(RefCell::new(GraphRun {
        start: vec![SimTime::ZERO; n],
        finish: vec![SimTime::ZERO; n],
    }));
    if n == 0 {
        e.at(at, done);
        return run;
    }
    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut indeg: Vec<usize> = vec![0; n];
    for (i, node) in g.nodes.iter().enumerate() {
        for d in &node.deps {
            succ[d.0].push(i);
            indeg[i] += 1;
        }
    }
    let sink_count = succ.iter().filter(|s| s.is_empty()).count();
    let terminal = e.join(sink_count, done);

    // Joins must exist before the node actions that arrive at them are
    // built; nodes are created in topological order, so walking in
    // reverse guarantees every successor's join is already allocated.
    let mut joins = vec![None; n];
    let mut sources: Vec<(usize, Box<dyn FnOnce(&mut Engine)>)> = Vec::new();
    for i in (0..n).rev() {
        let node = &g.nodes[i];
        let rank = node.rank;
        let ops = Rc::new(node.ops.clone());
        let succ_joins: Vec<_> =
            succ[i].iter().map(|&j| joins[j].expect("topological order")).collect();
        let map_i = map.clone();
        let run_i = run.clone();
        let action = move |e: &mut Engine| {
            run_i.borrow_mut().start[i] = e.now();
            let rank_map: ResMap = Rc::new(move |k| map_i(rank, k));
            let run_done = run_i.clone();
            replay(
                e,
                rank_map,
                ops,
                Box::new(move |e| {
                    run_done.borrow_mut().finish[i] = e.now();
                    if succ_joins.is_empty() {
                        e.arrive(terminal);
                    }
                    for j in succ_joins {
                        e.arrive(j);
                    }
                }),
            );
        };
        if indeg[i] == 0 {
            sources.push((i, Box::new(action)));
        } else {
            joins[i] = Some(e.join(indeg[i], action));
        }
    }
    sources.sort_by_key(|&(i, _)| i);
    for (_, a) in sources {
        e.at(at, a);
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::commop::CommSchedule;
    use crate::comm::CostBreakdown;

    fn wire_steps(count: usize, us: f64) -> Vec<StepCost> {
        vec![
            StepCost {
                cost: CostBreakdown { wire_us: us, ..Default::default() },
                gpu_reduce: false,
            };
            count
        ]
    }

    fn run_graph(g: &CommGraph, ranks: usize) -> (SimTime, GraphRun) {
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, ranks);
        let run = execute(&mut e, g, res.mapper(), Box::new(|_| {}));
        let end = e.run();
        let out = run.borrow().clone();
        (end, out)
    }

    #[test]
    fn zero_skew_ring_matches_serialized_total() {
        for p in [2usize, 3, 4, 8] {
            let steps = wire_steps(2 * (p - 1), 10.0);
            let g = ring_graph(p, &steps);
            assert_eq!(g.len(), p * steps.len());
            let serial = CommSchedule::from_steps(&steps).total_us();
            let (end, _) = run_graph(&g, p);
            assert!(
                (end.as_us() - serial).abs() < 1e-9,
                "ring p={p}: graph {} vs serial {serial}",
                end.as_us()
            );
            assert!((g.critical_path_us() - serial).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_skew_rhd_and_tree_match_serialized_total() {
        for p in [2usize, 3, 5, 8, 13, 16] {
            let p2 = crate::comm::allreduce::flp2(p);
            let rem_steps = if p > p2 { 2 } else { 0 };
            let rhd_steps = wire_steps(rem_steps + 2 * p2.trailing_zeros() as usize, 7.0);
            let g = rhd_graph(p, &rhd_steps);
            let serial = CommSchedule::from_steps(&rhd_steps).total_us();
            let (end, _) = run_graph(&g, p);
            assert!(
                (end.as_us() - serial).abs() < 1e-9,
                "rhd p={p}: graph {} vs serial {serial}",
                end.as_us()
            );

            // tree level count: log2 up + log2 down (shadow skips no level
            // for p >= 2)
            let levels = {
                let mut c = 0;
                let mut dist = 1;
                while dist < p {
                    c += 1;
                    dist *= 2;
                }
                let mut dist = p.next_power_of_two() / 2;
                while dist >= 1 {
                    if (0..p).step_by(2 * dist).any(|s| s + dist < p) {
                        c += 1;
                    }
                    dist /= 2;
                }
                c
            };
            let tree_steps = wire_steps(levels, 5.0);
            let g = tree_graph(p, &tree_steps);
            let serial = CommSchedule::from_steps(&tree_steps).total_us();
            let (end, _) = run_graph(&g, p);
            assert!(
                (end.as_us() - serial).abs() < 1e-9,
                "tree p={p}: graph {} vs serial {serial}",
                end.as_us()
            );
        }
    }

    #[test]
    fn straggler_skew_propagates_one_rank_per_step() {
        // Ring p=4, 6 uniform 10us steps; rank 1 runs 2x slow.  The skew
        // cone: a node (r, s) is delayed iff s >= ring-distance(1 -> r);
        // outside the cone finish times match the pristine run exactly.
        let p = 4;
        let steps = wire_steps(2 * (p - 1), 10.0);
        let g0 = ring_graph(p, &steps);
        let (_, base) = run_graph(&g0, p);
        let mut g = g0.clone();
        g.scale_rank(1, 2.0);
        let (end, run) = run_graph(&g, p);

        let at = |r: usize, s: usize| NodeId(s * p + r); // ring builder layout
        // unaffected: early steps of downstream ranks
        assert_eq!(run.finish_of(at(2, 0)), base.finish_of(at(2, 0)));
        assert_eq!(run.finish_of(at(3, 1)), base.finish_of(at(3, 1)));
        assert_eq!(run.finish_of(at(0, 2)), base.finish_of(at(0, 2)));
        // delayed: the dependent steps one hop later
        assert!(run.finish_of(at(2, 1)) > base.finish_of(at(2, 1)));
        assert!(run.finish_of(at(3, 2)) > base.finish_of(at(3, 2)));
        assert!(run.finish_of(at(0, 3)) > base.finish_of(at(0, 3)));
        // the straggler's own chain dominates completion: 6 steps × 20us
        assert_eq!(end, SimTime::from_us(120.0));
    }

    #[test]
    fn ps_fanin_updates_after_last_push_and_pulls_fifo() {
        let mut e = Engine::new();
        let nic_in = e.unit_resource();
        let nic_out = e.unit_resource();
        let (g, pulls) = ps_fanin_graph(
            3,
            0,
            |_| vec![CommOp::fixed(ResKind::Wire, 10.0).pinned(nic_in)],
            vec![CommOp::fixed(ResKind::CpuReduce, 5.0)],
            |_| vec![CommOp::fixed(ResKind::Wire, 10.0).pinned(nic_out)],
        );
        let done = Rc::new(RefCell::new(0.0));
        let d2 = done.clone();
        let run = execute(
            &mut e,
            &g,
            unmapped(),
            Box::new(move |e| *d2.borrow_mut() = e.now().as_us()),
        );
        e.run();
        // pushes serialize on the ingress NIC (10/20/30), update at 35,
        // pulls serialize on the egress NIC in worker order (45/55/65)
        let r = run.borrow();
        assert_eq!(
            pulls.iter().map(|&id| r.finish_of(id).as_us()).collect::<Vec<_>>(),
            vec![45.0, 55.0, 65.0]
        );
        assert_eq!(*done.borrow(), 65.0);
    }

    #[test]
    fn prefix_root_gates_every_source() {
        let p = 3;
        let steps = wire_steps(2 * (p - 1), 10.0);
        let mut g = ring_graph(p, &steps);
        g.prefix_root(0, vec![CommOp::fixed(ResKind::Sw, 4.0)]);
        assert_eq!(g.nodes[0].deps.len(), 0);
        assert!(g.nodes[1..].iter().all(|n| !n.deps.is_empty()));
        let (end, _) = run_graph(&g, p);
        assert!((end.as_us() - (4.0 + 40.0)).abs() < 1e-9);
    }

    #[test]
    fn jitter_is_additive_and_keyed_by_rank_step() {
        let steps = wire_steps(2, 10.0);
        let mut g = ring_graph(2, &steps);
        g.jitter_nodes(|rank, step| if rank == 0 && step == 0 { 3.0 } else { 0.0 });
        let (end, run) = run_graph(&g, 2);
        assert_eq!(run.finish_of(NodeId(0)), SimTime::from_us(13.0));
        // rank 1 step 1 depends on rank 0 step 0: jitter propagates
        assert_eq!(end, SimTime::from_us(23.0));
    }

    #[test]
    fn empty_graph_completes_immediately() {
        let mut e = Engine::new();
        let fired = Rc::new(RefCell::new(false));
        let f = fired.clone();
        execute(
            &mut e,
            &CommGraph::default(),
            unmapped(),
            Box::new(move |_| *f.borrow_mut() = true),
        );
        let end = e.run();
        assert!(*fired.borrow());
        assert_eq!(end, SimTime::ZERO);
    }

    #[test]
    fn shared_wire_contends_across_jobs_private_rest_overlaps() {
        // Two single-node chains on the same rank-0 NIC: wire serializes,
        // the private gpu phases overlap — the two-job model at rank level.
        let mut e = Engine::new();
        let a = GraphResources::install(&mut e, 2);
        let b = GraphResources::sharing_wire(&mut e, &a);
        let mut ends = Vec::new();
        for res in [&a, &b] {
            let g = CommGraph::chain(
                0,
                vec![CommOp::fixed(ResKind::Wire, 10.0), CommOp::fixed(ResKind::GpuReduce, 5.0)],
            );
            let done = Rc::new(RefCell::new(0.0));
            let d2 = done.clone();
            execute(
                &mut e,
                &g,
                res.mapper(),
                Box::new(move |e| *d2.borrow_mut() = e.now().as_us()),
            );
            ends.push(done);
        }
        e.run();
        assert_eq!(*ends[0].borrow(), 15.0);
        assert_eq!(*ends[1].borrow(), 25.0);
        let (_, busy) = e.resource_stats(a.wire[0]);
        assert_eq!(busy, SimTime::from_us(20.0));
    }
}
