//! The `CommGraph` layer: a collective as a DAG of **per-rank** `CommOp`
//! nodes with explicit cross-rank dependencies, executed dependency-aware
//! on the discrete-event engine.
//!
//! A serialized [`CommSchedule`](crate::comm::commop::CommSchedule) models
//! the critical-path rank: one op chain, so a straggler can only shift the
//! *whole* collective.  On real fabrics skew propagates *through* the
//! algorithm: ring step *s* on rank *r* cannot start before step *s−1* of
//! rank *r* **and** the matching send of rank *r−1* — one slow rank delays
//! its downstream neighbours one step later, the next neighbour two steps
//! later, a cone that widens by one rank per step.  That propagation (the
//! structure the paper's Allreduce characterization rides on) is exactly
//! what this graph expresses and the serialized form cannot.
//!
//! Contract:
//!  * **Nodes** — one per (rank, algorithm step): an ordered `CommOp` list
//!    (the same [`StepCost::ops`] decomposition the serialized schedule
//!    uses, so durations stay pinned to the validated α–β cost models).
//!  * **Edges** — `deps`: a node becomes *eligible* only when every
//!    predecessor has finished.  Builders wire ring / halving-doubling /
//!    tree / PS fan-in topologies.
//!  * **Eligibility vs queueing** — eligibility is an engine *join*
//!    ([`Engine::join`]); once eligible, a node's ops run as a typed
//!    engine program queueing FIFO on the **node-local** resources a
//!    [`Placement`] lays out for its rank ([`GraphResources`]: NIC ports
//!    per `(node, rail)`, PCIe per node, GPU per rank, …) instead of the
//!    one shared per-job proxy.  Dense placements colocate ranks on
//!    shared NIC/PCIe bundles, and the placed builders cost hops between
//!    co-located ranks over the node-local link instead of the wire.
//!
//! §Perf — build once, replay many: a [`GraphTemplate`] is an immutable
//! built graph plus its precomputed successor/in-degree plan, cached in a
//! [`TemplateCache`] keyed by `(algo, world, placement, step-cost
//! signature)` ([`crate::comm::commop::steps_sig`]).  Per-iteration variation — what
//! the old code expressed by cloning the node vector and mutating op
//! durations — is a [`GraphOverlay`]: multiplicative per-rank factors and
//! per-node jitter leads applied at *execute* time, in the same order the
//! mutators applied them, so replayed timings are bit-identical to a
//! freshly built perturbed graph (pinned by `tests` here and the
//! equivalence suites in `tests/des_regression.rs` / `proptest_lite.rs`).
//!
//! With uniform per-step durations (no scenario perturbation) the graph's
//! completion time provably equals the serialized schedule's total: every
//! rank's chain is the same op sequence, and cross-rank edges between
//! equal-length chains never extend the path.  `tests` and
//! `tests/des_regression.rs` pin this zero-skew equivalence, which is what
//! lets the strategies keep the fast serialized replay when nothing skews
//! ranks apart.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::{Arc, Mutex};

use crate::cluster::Placement;
use crate::comm::allreduce::Algo;
use crate::comm::commop::{CommOp, RelPin, ResKind, ResourceUse, StepCost};
use crate::sim::{
    Action, Engine, EngineHook, HookId, LaneSetId, OnDone, ProgStep, ResourceId, SimTime, SpanKind,
};

/// Builders whose node count reaches this materialize their node vectors
/// on scoped worker threads (§Scale) — below it the spawn overhead beats
/// the build.
const PAR_BUILD_MIN_NODES: usize = 1 << 16;

/// Handle to a node inside one [`CommGraph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NodeId(pub usize);

/// One unit of per-rank work: an ordered op list plus the nodes that must
/// finish before it may start.
#[derive(Debug, Clone)]
pub struct GraphNode {
    pub rank: usize,
    /// Builder step index (timeline display, deterministic jitter keys).
    pub step: u32,
    pub ops: Vec<CommOp>,
    pub deps: Vec<NodeId>,
}

impl GraphNode {
    pub fn dur_us(&self) -> f64 {
        self.ops.iter().map(|o| o.us).sum()
    }
}

/// A DAG of per-rank [`GraphNode`]s.  Nodes are created in topological
/// order (dependencies always point backwards), which keeps execution and
/// critical-path evaluation single-pass.  A built graph is immutable in
/// spirit: per-iteration perturbation goes through [`GraphOverlay`], not
/// mutation, so one build can be replayed many times.
#[derive(Debug, Clone, Default)]
pub struct CommGraph {
    pub nodes: Vec<GraphNode>,
}

impl CommGraph {
    pub fn push_node(
        &mut self,
        rank: usize,
        step: u32,
        ops: Vec<CommOp>,
        deps: Vec<NodeId>,
    ) -> NodeId {
        let id = self.nodes.len();
        debug_assert!(deps.iter().all(|d| d.0 < id), "deps must precede the node");
        self.nodes.push(GraphNode { rank, step, ops, deps });
        NodeId(id)
    }

    /// The trivial adapter for linear schedules (gRPC-family transfers):
    /// one node carrying the whole op chain on one rank.
    pub fn chain(rank: usize, ops: Vec<CommOp>) -> CommGraph {
        let mut g = CommGraph::default();
        g.push_node(rank, 0, ops, Vec::new());
        g
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Sum of every node's work — the per-rank ledger, *not* wall time
    /// (p ranks working in parallel each contribute their own ops).
    pub fn total_us(&self) -> f64 {
        self.nodes.iter().map(|n| n.dur_us()).sum()
    }

    /// Longest dependency path, assuming no resource queueing — the
    /// zero-contention wall time of the graph.
    pub fn critical_path_us(&self) -> f64 {
        let mut cp = vec![0.0f64; self.nodes.len()];
        let mut best = 0.0f64;
        for (i, node) in self.nodes.iter().enumerate() {
            let start = node.deps.iter().map(|d| cp[d.0]).fold(0.0, f64::max);
            cp[i] = start + node.dur_us();
            best = best.max(cp[i]);
        }
        best
    }

    /// Prepend a root node every current source depends on — Horovod's
    /// rank-0 coordination round before the buffer's Allreduce.  Existing
    /// step indices shift by one.  (A template-build step, not a
    /// per-iteration one.)
    pub fn prefix_root(&mut self, rank: usize, ops: Vec<CommOp>) {
        let mut nodes = Vec::with_capacity(self.nodes.len() + 1);
        nodes.push(GraphNode { rank, step: 0, ops, deps: Vec::new() });
        for n in self.nodes.drain(..) {
            let deps = if n.deps.is_empty() {
                vec![NodeId(0)]
            } else {
                n.deps.iter().map(|d| NodeId(d.0 + 1)).collect()
            };
            nodes.push(GraphNode { step: n.step + 1, deps, ..n });
        }
        self.nodes = nodes;
    }
}

fn dep2(a: Option<NodeId>, b: Option<NodeId>) -> Vec<NodeId> {
    let mut v = Vec::new();
    if let Some(x) = a {
        v.push(x);
    }
    if let Some(y) = b {
        if a != Some(y) {
            v.push(y);
        }
    }
    v
}

/// The ops of one algorithm step for rank `rank` exchanging with `peer`
/// under a placement: an inter-node hop keeps the step's decomposition;
/// an intra-node hop re-kinds the `Wire` component to `Pcie` — it rides
/// the node's PCIe/NVLink path (queueing on the node's local link, not
/// the NIC) scaled by `local` (inter-node β ÷ local β, see
/// [`crate::cluster::Fabric::local_hop_factor`]).  With one GPU per node
/// no hop is ever intra, so the output is bit-identical to
/// [`StepCost::ops`] — the placement-invariance guarantee.
fn step_ops(st: &StepCost, place: &Placement, local: f64, rank: usize, peer: usize) -> Vec<CommOp> {
    let mut ops = st.ops();
    if place.gpus_per_node > 1 && place.same_node(rank, peer) {
        for op in &mut ops {
            if op.kind == ResKind::Wire {
                op.kind = ResKind::Pcie;
                op.us *= local;
            }
        }
    }
    ops
}

/// Build the dependency graph of an allreduce from its validated per-step
/// costs (the same [`StepCost`] sequence the serialized schedule uses),
/// with every rank on its own node (the paper's layout).
pub fn allreduce_graph(algo: Algo, p: usize, steps: &[StepCost]) -> CommGraph {
    allreduce_graph_placed(algo, p, steps, Placement::one_per_node(), 1.0)
}

/// [`allreduce_graph`] under a [`Placement`]: hops between co-located
/// ranks are re-costed onto the node-local link (`local` = inter-node β
/// ÷ local β).  Trivial placements reproduce [`allreduce_graph`]
/// bit-for-bit regardless of `local`.
pub fn allreduce_graph_placed(
    algo: Algo,
    p: usize,
    steps: &[StepCost],
    place: Placement,
    local: f64,
) -> CommGraph {
    match algo {
        Algo::Ring => ring_graph_placed(p, steps, place, local),
        Algo::Rhd => rhd_graph_placed(p, steps, place, local),
        Algo::Tree => tree_graph_placed(p, steps, place, local),
    }
}

/// Ring: step *s* on rank *r* waits on its own step *s−1* and on the
/// matching send of rank *r−1* (the data it receives this step).
pub fn ring_graph(p: usize, steps: &[StepCost]) -> CommGraph {
    ring_graph_placed(p, steps, Placement::one_per_node(), 1.0)
}

/// Placed ring: under a block placement the hop from *r−1* into *r* is
/// intra-node whenever `r` is not its node's first rank — the classic
/// hierarchical-ring benefit (one wire crossing per node per step, the
/// rest rides PCIe/NVLink).
pub fn ring_graph_placed(
    p: usize,
    steps: &[StepCost],
    place: Placement,
    local: f64,
) -> CommGraph {
    let mut g = CommGraph::default();
    if p < 2 {
        return g;
    }
    if p * steps.len() >= PAR_BUILD_MIN_NODES {
        g.nodes = par_build_nodes(p * steps.len(), |lo, hi| {
            ring_nodes_range(p, steps, &place, local, lo, hi)
        });
        return g;
    }
    let mut last: Vec<Option<NodeId>> = vec![None; p];
    for (s, st) in steps.iter().enumerate() {
        let prev = last.clone();
        for (r, slot) in last.iter_mut().enumerate() {
            let from = (r + p - 1) % p;
            let ops = step_ops(st, &place, local, r, from);
            *slot = Some(g.push_node(r, s as u32, ops, dep2(prev[r], prev[from])));
        }
    }
    g
}

/// The ring builder's nodes for flat indices `lo..hi` (node `(s, r)` is
/// index `s * p + r`), derived from the closed-form edge rule instead of
/// the sequential `last` scan — bit-identical to the serial builder
/// (pinned by `parallel_ring_build_matches_serial`), which is what lets
/// large worlds build on worker threads.
fn ring_nodes_range(
    p: usize,
    steps: &[StepCost],
    place: &Placement,
    local: f64,
    lo: usize,
    hi: usize,
) -> Vec<GraphNode> {
    let mut out = Vec::with_capacity(hi - lo);
    for id in lo..hi {
        let (s, r) = (id / p, id % p);
        let from = (r + p - 1) % p;
        let ops = step_ops(&steps[s], place, local, r, from);
        let deps = if s == 0 {
            Vec::new()
        } else {
            // dep2(prev[r], prev[from]) with prev[x] = (s-1)*p + x
            vec![NodeId((s - 1) * p + r), NodeId((s - 1) * p + from)]
        };
        out.push(GraphNode { rank: r, step: s as u32, ops, deps });
    }
    out
}

/// Materialize `total` nodes by splitting the flat index range across
/// scoped threads and concatenating the chunks in thread order — a
/// deterministic merge, so the parallel build is bit-identical to the
/// serial one whatever the machine's core count.
fn par_build_nodes(
    total: usize,
    build: impl Fn(usize, usize) -> Vec<GraphNode> + Sync,
) -> Vec<GraphNode> {
    let threads = std::thread::available_parallelism().map_or(1, |n| n.get()).min(16);
    if threads < 2 {
        return build(0, total);
    }
    let chunk = total.div_ceil(threads);
    let mut out = Vec::with_capacity(total);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let lo = (t * chunk).min(total);
                let hi = ((t + 1) * chunk).min(total);
                let build = &build;
                scope.spawn(move || build(lo, hi))
            })
            .collect();
        for h in handles {
            out.append(&mut h.join().expect("graph build worker panicked"));
        }
    });
    out
}

/// Recursive halving-doubling: mask step exchanges pair rank *r* with
/// *r ^ mask*; a non-power-of-two world folds the extra ranks into their
/// base partner first (pre) and unfolds them last (post) — the same phase
/// sequence `shadow::rhd_shadow` charges.
pub fn rhd_graph(p: usize, steps: &[StepCost]) -> CommGraph {
    rhd_graph_placed(p, steps, Placement::one_per_node(), 1.0)
}

/// Placed RHD: small-mask exchanges pair near ranks — under a block
/// placement every mask < `gpus_per_node` stays on-node, the larger
/// masks always cross the wire.
pub fn rhd_graph_placed(
    p: usize,
    steps: &[StepCost],
    place: Placement,
    local: f64,
) -> CommGraph {
    let mut g = CommGraph::default();
    if p < 2 {
        return g;
    }
    if p.is_power_of_two() && p * steps.len() >= PAR_BUILD_MIN_NODES {
        let masks = rhd_mask_sequence(p);
        if masks.len() == steps.len() {
            g.nodes = par_build_nodes(p * steps.len(), |lo, hi| {
                rhd_nodes_range(p, steps, &place, local, &masks, lo, hi)
            });
            return g;
        }
    }
    let p2 = crate::comm::allreduce::flp2(p);
    let rem = p - p2;
    let mut last: Vec<Option<NodeId>> = vec![None; p];
    let mut si = 0usize;

    let mut fold_step = |g: &mut CommGraph, last: &mut Vec<Option<NodeId>>, si: &mut usize| {
        let st = &steps[*si];
        let stepi = *si as u32;
        *si += 1;
        let prev = last.clone();
        for r in p2..p {
            let base = r - p2;
            let ops_r = step_ops(st, &place, local, r, base);
            let ops_b = step_ops(st, &place, local, base, r);
            last[r] = Some(g.push_node(r, stepi, ops_r, dep2(prev[r], prev[base])));
            last[base] = Some(g.push_node(base, stepi, ops_b, dep2(prev[base], prev[r])));
        }
    };

    if rem > 0 {
        fold_step(&mut g, &mut last, &mut si);
    }
    let masks: Vec<usize> = {
        let mut v = Vec::new();
        let mut m = p2 >> 1;
        while m > 0 {
            v.push(m);
            m >>= 1;
        }
        v
    };
    for &mask in masks.iter().chain(masks.iter().rev()) {
        let st = &steps[si];
        let stepi = si as u32;
        si += 1;
        let prev = last.clone();
        for (r, slot) in last.iter_mut().enumerate().take(p2) {
            let q = r ^ mask;
            let ops = step_ops(st, &place, local, r, q);
            *slot = Some(g.push_node(r, stepi, ops, dep2(prev[r], prev[q])));
        }
    }
    if rem > 0 {
        fold_step(&mut g, &mut last, &mut si);
    }
    debug_assert_eq!(si, steps.len(), "rhd builder / shadow step count mismatch");
    g
}

/// The per-step XOR masks of a power-of-two halving-doubling world:
/// `p/2, p/4, …, 1` (reduce-scatter) then reversed (allgather) — the
/// exact order the serial builder iterates.
fn rhd_mask_sequence(p: usize) -> Vec<usize> {
    debug_assert!(p.is_power_of_two());
    let mut masks = Vec::new();
    let mut m = p >> 1;
    while m > 0 {
        masks.push(m);
        m >>= 1;
    }
    let down: Vec<usize> = masks.iter().rev().copied().collect();
    masks.extend(down);
    masks
}

/// Power-of-two RHD nodes for flat indices `lo..hi` (node `(s, r)` is
/// `s * p + r`; no fold steps, so the layout matches the serial builder
/// exactly).  Deps mirror `dep2(prev[r], prev[r ^ masks[s]])`.
fn rhd_nodes_range(
    p: usize,
    steps: &[StepCost],
    place: &Placement,
    local: f64,
    masks: &[usize],
    lo: usize,
    hi: usize,
) -> Vec<GraphNode> {
    let mut out = Vec::with_capacity(hi - lo);
    for id in lo..hi {
        let (s, r) = (id / p, id % p);
        let q = r ^ masks[s];
        let ops = step_ops(&steps[s], place, local, r, q);
        let deps = if s == 0 {
            Vec::new()
        } else {
            vec![NodeId((s - 1) * p + r), NodeId((s - 1) * p + q)]
        };
        out.push(GraphNode { rank: r, step: s as u32, ops, deps });
    }
    out
}

/// Binomial tree: reduce up (receivers reduce), broadcast down.  Each
/// pair's work lives on the receiving rank; the node also becomes the
/// sender's latest node, which serializes a rank's consecutive sends
/// (rank 0 broadcasts one level at a time).
pub fn tree_graph(p: usize, steps: &[StepCost]) -> CommGraph {
    tree_graph_placed(p, steps, Placement::one_per_node(), 1.0)
}

/// Placed binomial tree: the lowest levels pair adjacent ranks, which a
/// block placement keeps on-node; the top levels always cross the wire.
pub fn tree_graph_placed(
    p: usize,
    steps: &[StepCost],
    place: Placement,
    local: f64,
) -> CommGraph {
    let mut g = CommGraph::default();
    if p < 2 {
        return g;
    }
    let mut last: Vec<Option<NodeId>> = vec![None; p];
    let mut si = 0usize;

    let mut level = |g: &mut CommGraph,
                     last: &mut Vec<Option<NodeId>>,
                     si: &mut usize,
                     pairs: &[(usize, usize)]| {
        let st = &steps[*si];
        let stepi = *si as u32;
        *si += 1;
        let prev = last.clone();
        for &(src, dst) in pairs {
            let ops = step_ops(st, &place, local, dst, src);
            let id = g.push_node(dst, stepi, ops, dep2(prev[dst], prev[src]));
            last[dst] = Some(id);
            last[src] = Some(id);
        }
    };

    let mut dist = 1;
    while dist < p {
        let pairs: Vec<(usize, usize)> = (0..p)
            .filter(|r| r % (2 * dist) == dist)
            .map(|src| (src, src - dist))
            .collect();
        if !pairs.is_empty() {
            level(&mut g, &mut last, &mut si, &pairs);
        }
        dist *= 2;
    }
    let mut dist = p.next_power_of_two() / 2;
    while dist >= 1 {
        let pairs: Vec<(usize, usize)> = (0..p)
            .step_by(2 * dist)
            .filter(|&src| src + dist < p)
            .map(|src| (src, src + dist))
            .collect();
        if !pairs.is_empty() {
            level(&mut g, &mut last, &mut si, &pairs);
        }
        dist /= 2;
    }
    debug_assert_eq!(si, steps.len(), "tree builder / shadow step count mismatch");
    g
}

/// The PS fan-in/fan-out DAG of ONE parameter shard: `workers` push
/// chains converge on the server's update node (the fan-in the PS NIC
/// queues feed), which fans back out into `workers` pull chains.  Returns
/// the graph and each worker's pull sink, whose finish time is that
/// worker's completion for the shard.
pub fn ps_fanin_graph(
    workers: usize,
    server_rank: usize,
    push_ops: impl Fn(usize) -> Vec<CommOp>,
    update_ops: Vec<CommOp>,
    pull_ops: impl Fn(usize) -> Vec<CommOp>,
) -> (CommGraph, Vec<NodeId>) {
    let mut g = CommGraph::default();
    let pushes: Vec<NodeId> =
        (0..workers).map(|w| g.push_node(w, 0, push_ops(w), Vec::new())).collect();
    let update = g.push_node(server_rank, 1, update_ops, pushes);
    let pulls: Vec<NodeId> =
        (0..workers).map(|w| g.push_node(w, 2, pull_ops(w), vec![update])).collect();
    debug_assert_eq!(pulls, ps_fanin_pulls(workers), "fan-in layout drifted from the helper");
    (g, pulls)
}

/// The pull-sink node ids of [`ps_fanin_graph`] for `workers` workers.
/// The builder's layout is fixed — pushes `0..w`, update `w`, pulls
/// `w+1..=2w` — so a cached fan-in template can recover its sinks
/// without storing them alongside (cross-call PS templating).
pub fn ps_fanin_pulls(workers: usize) -> Vec<NodeId> {
    (0..workers).map(|w| NodeId(workers + 1 + w)).collect()
}

/// How rank `r`'s exchange partner at one symmetric step derives from
/// `r` alone (§Scale): `Shift(k)` receives from `(r + k) % world` (the
/// ring uses `k = world − 1`), `Xor(m)` pairs with `r ^ m` (the
/// halving-doubling masks).  Both are bijections of the rank set, so the
/// *successor* rule — which ranks' next-step nodes depend on `r` — is
/// the inverse permutation ([`PeerRule::inv`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerRule {
    Shift(usize),
    Xor(usize),
}

impl PeerRule {
    /// The rank whose *next-step* node depends on rank `r` — the inverse
    /// of the peer map (`Shift(k)⁻¹ = Shift(world − k)`, XOR is its own
    /// inverse).
    fn inv(self, world: usize, r: usize) -> usize {
        match self {
            PeerRule::Shift(k) => (r + world - k) % world,
            PeerRule::Xor(m) => r ^ m,
        }
    }
}

/// One step of a rank-symmetric collective: the op list *every* rank
/// runs this step (identical across ranks at a trivial placement — no
/// hop ever re-kinds) plus the peer rule its cross-rank edge follows.
#[derive(Debug, Clone)]
pub struct SymStep {
    pub ops: Vec<CommOp>,
    pub peer: PeerRule,
}

/// A rank-relative shared plan (§Scale): ONE step template for all
/// `world` ranks instead of `world × steps` materialized nodes.  Node
/// `(step s, rank r)` of the equivalent full graph is flat index
/// `s * world + r`; its dependencies are `(s−1, r)` and
/// `(s−1, peer_s(r))` — exactly the full builders' edges — so executing
/// the plan is bit-identical *in virtual time* to executing the full
/// [`GraphTemplate`] (pinned by
/// `prop_sym_plan_replays_full_template_bitwise`; event interleaving may
/// differ, times never do, because every rank's programs occupy only
/// that rank's private resources at a trivial placement).  Memory is
/// O(steps), not O(world × steps) — the fleet-scale win.
#[derive(Debug, Clone)]
pub struct SymTemplate {
    world: usize,
    steps: Vec<SymStep>,
}

/// Derive the shared symmetric plan of an allreduce, or `None` when the
/// collective is not rank-symmetric: dense placements re-kind intra-node
/// hops per rank, non-power-of-two RHD folds remainder ranks
/// asymmetrically, and the binomial tree puts each pair's work on one
/// rank.  Callers fall back to the full per-rank builder on `None`.
pub fn sym_allreduce_plan(
    algo: Algo,
    p: usize,
    steps: &[StepCost],
    place: Placement,
) -> Option<SymTemplate> {
    if !place.is_trivial() || p < 2 || steps.is_empty() {
        return None;
    }
    let sym_steps: Vec<SymStep> = match algo {
        Algo::Ring => steps
            .iter()
            .map(|st| SymStep { ops: st.ops(), peer: PeerRule::Shift(p - 1) })
            .collect(),
        Algo::Rhd => {
            if !p.is_power_of_two() {
                return None;
            }
            let masks = rhd_mask_sequence(p);
            if masks.len() != steps.len() {
                return None;
            }
            steps
                .iter()
                .zip(masks)
                .map(|(st, mask)| SymStep { ops: st.ops(), peer: PeerRule::Xor(mask) })
                .collect()
        }
        Algo::Tree => return None,
    };
    debug_assert!(
        sym_steps.iter().all(|s| s.ops.iter().all(|o| o.on.is_none() && o.rel.is_none())),
        "symmetric step ops must be unpinned"
    );
    Some(SymTemplate { world: p, steps: sym_steps })
}

impl SymTemplate {
    pub fn world(&self) -> usize {
        self.world
    }

    pub fn step_count(&self) -> usize {
        self.steps.len()
    }

    /// Node count of the equivalent full graph (what the plan *replaces*).
    pub fn node_count(&self) -> usize {
        self.world * self.steps.len()
    }

    /// Resident size of the plan itself — O(steps), the figure the scale
    /// bench reports as peak template memory (vs the full template's
    /// [`GraphTemplate::approx_bytes`]).
    pub fn approx_bytes(&self) -> usize {
        use std::mem::size_of;
        size_of::<SymTemplate>()
            + self.steps.len() * size_of::<SymStep>()
            + self.steps.iter().map(|s| s.ops.len() * size_of::<CommOp>()).sum::<usize>()
    }

    /// Execute now (sources release at the current virtual time).
    pub fn execute(
        &self,
        e: &mut Engine,
        res: &GraphResources,
        ov: &GraphOverlay,
        record: bool,
        done: Action,
    ) -> Option<Rc<RefCell<GraphRun>>> {
        let at = e.now();
        self.execute_at(e, res, ov, at, record, done)
    }

    /// Execute the shared plan with sources released at `at`.  Every
    /// node's program is resolved against **rank 0's** resource pins and
    /// launched through the engine's rank-offset view
    /// ([`Engine::run_program_shifted`]) with `offset = rank` — valid
    /// because [`GraphResources`] installs each kind as one contiguous
    /// per-rank run (asserted here).  Completions route through ONE
    /// registered [`EngineHook`], which counts the two arrivals of each
    /// successor node and launches it at its max arrival time — the same
    /// instant the full path's join would fire.  With `record` the
    /// per-node [`GraphRun`] is returned (O(nodes) memory — leave it off
    /// at fleet scale).
    pub fn execute_at(
        &self,
        e: &mut Engine,
        res: &GraphResources,
        ov: &GraphOverlay,
        at: SimTime,
        record: bool,
        done: Action,
    ) -> Option<Rc<RefCell<GraphRun>>> {
        let world = self.world;
        assert!(res.placement().is_trivial(), "shared plans need a trivial placement");
        assert!(res.ranks() >= world, "resource bundle smaller than the plan's world");
        for kind in ResKind::ALL {
            let base = res.get(0, kind).index();
            for r in 1..world {
                assert_eq!(
                    res.get(r, kind).index(),
                    base + r,
                    "rank-offset view needs contiguous per-kind resources ({})",
                    kind.name()
                );
            }
        }
        // per-rank overlay terms force per-rank resolution; a uniform
        // overlay (identity or global-only) shares one program per step
        let uniform = ov.rank_all.is_empty() && ov.rank_gpu.is_empty() && ov.lead.is_none();
        let progs = if uniform {
            SymProgs::Shared(
                self.steps.iter().map(|st| resolve_sym_rank(st, 0, res, ov, 0)).collect(),
            )
        } else {
            SymProgs::PerRank(
                self.steps
                    .iter()
                    .enumerate()
                    .map(|(s, st)| {
                        (0..world).map(|r| resolve_sym_rank(st, s as u32, res, ov, r)).collect()
                    })
                    .collect(),
            )
        };
        let run = record.then(|| {
            let n = self.node_count();
            Rc::new(RefCell::new(GraphRun {
                start: vec![SimTime::ZERO; n],
                finish: vec![SimTime::ZERO; n],
            }))
        });
        let exec = Rc::new(SymExec {
            world,
            peers: self.steps.iter().map(|s| s.peer).collect(),
            progs,
            hook: Cell::new(None),
            run: run.clone(),
            state: RefCell::new(SymExecState {
                arrivals: vec![0; self.node_count()],
                remaining: world,
                done: Some(done),
            }),
        });
        let id = e.hook(exec.clone());
        exec.hook.set(Some(id));
        let sources = exec.clone();
        e.at(at, move |e| {
            // step-0 nodes are flat indices 0..world: release in rank
            // order, like the full executor's sorted source release
            for r in 0..world {
                sources.launch(e, r as u32);
            }
        });
        run
    }
}

/// Resolve one symmetric step for `rank` against **rank 0's** pins —
/// the overlay application order (lead, then global → rank → rank-GPU
/// factors) replicates [`resolve_node`] bit-for-bit, and the rank-0 pins
/// are shifted to `rank`'s resources at launch time.
fn resolve_sym_rank(
    st: &SymStep,
    step: u32,
    res: &GraphResources,
    ov: &GraphOverlay,
    rank: usize,
) -> Rc<[ProgStep]> {
    let lead = ov.lead_us(rank, step);
    let mut steps = Vec::with_capacity(st.ops.len() + usize::from(lead > 0.0));
    if lead > 0.0 {
        steps.push(ProgStep { us: lead, on: Some(res.get(0, ResKind::Sw)) });
    }
    let all = ov.all_factor(rank);
    let gpu = ov.gpu_factor(rank);
    for op in &st.ops {
        let mut us = op.us;
        us *= ov.global;
        us *= all;
        if matches!(op.kind, ResKind::GpuReduce | ResKind::Launch | ResKind::Pcie) {
            us *= gpu;
        }
        steps.push(ProgStep { us, on: Some(res.get(0, op.kind)) });
    }
    steps.into()
}

/// Resolved programs of a running shared plan: one per step when the
/// overlay is rank-uniform, one per (step, rank) otherwise.
enum SymProgs {
    Shared(Vec<Rc<[ProgStep]>>),
    PerRank(Vec<Vec<Rc<[ProgStep]>>>),
}

struct SymExecState {
    /// Per-node arrival counters (flat `step * world + rank`); a node
    /// launches on its 2nd arrival (every non-source has exactly two
    /// predecessors — `peer ≠ self` for any world ≥ 2).
    arrivals: Vec<u8>,
    /// Last-step nodes still running; 0 fires `done`.
    remaining: usize,
    done: Option<Action>,
}

/// The shared-plan executor: one [`EngineHook`] registration serves every
/// node completion of the run, so steady-state execution allocates
/// nothing per node beyond its arrival counter.
struct SymExec {
    world: usize,
    peers: Vec<PeerRule>,
    progs: SymProgs,
    hook: Cell<Option<HookId>>,
    run: Option<Rc<RefCell<GraphRun>>>,
    state: RefCell<SymExecState>,
}

impl SymExec {
    fn prog(&self, s: usize, r: usize) -> Rc<[ProgStep]> {
        match &self.progs {
            SymProgs::Shared(v) => v[s].clone(),
            SymProgs::PerRank(v) => v[s][r].clone(),
        }
    }

    fn launch(&self, e: &mut Engine, node: u32) {
        let (s, r) = (node as usize / self.world, node as usize % self.world);
        if let Some(run) = &self.run {
            run.borrow_mut().start[node as usize] = e.now();
        }
        let hook = self.hook.get().expect("sym executor not registered");
        e.run_program_shifted(self.prog(s, r), r as u32, OnDone::Hook(hook, node));
    }
}

impl EngineHook for SymExec {
    fn done(&self, e: &mut Engine, node: u32) {
        if let Some(run) = &self.run {
            run.borrow_mut().finish[node as usize] = e.now();
        }
        let world = self.world;
        let (s, r) = (node as usize / world, node as usize % world);
        if s + 1 == self.peers.len() {
            let finished = {
                let mut st = self.state.borrow_mut();
                st.remaining -= 1;
                if st.remaining == 0 {
                    st.done.take()
                } else {
                    None
                }
            };
            if let Some(a) = finished {
                a(e);
            }
            return;
        }
        // this node feeds (s+1, r) and (s+1, inv(r)); whichever sees its
        // second arrival launches now — the join's max-arrival instant
        let succ = [r, self.peers[s + 1].inv(world, r)];
        let mut ready = [None, None];
        {
            let mut st = self.state.borrow_mut();
            for (slot, &q) in succ.iter().enumerate() {
                let idx = (s + 1) * world + q;
                st.arrivals[idx] += 1;
                if st.arrivals[idx] == 2 {
                    ready[slot] = Some(idx as u32);
                }
            }
        }
        // borrow dropped before launching: a zero-duration program can
        // complete synchronously and re-enter this hook
        for n in ready.into_iter().flatten() {
            self.launch(e, n);
        }
    }
}

/// Resolves an op to the engine resource backing it: by `(rank, kind)`
/// for per-rank work, or by the op's template-relative [`RelPin`] (PS
/// fan-in NIC queues, worker service threads) when one is present —
/// `None` elapses as an uncontended per-rank delay.  Rel pins are what
/// keep cached templates engine-independent: the graph names the
/// resource, each run's map resolves the name.
pub type GraphResMap = Rc<dyn Fn(usize, ResKind, Option<RelPin>) -> Option<ResourceId>>;

/// A map backing nothing: every op elapses as a pure per-rank delay
/// (engine-pinned ops still hit their resources).
pub fn unmapped() -> GraphResMap {
    Rc::new(|_, _, _| None)
}

/// Per-iteration execution overlay (§Perf): everything that may vary
/// between iterations of one cached [`GraphTemplate`].  The template is
/// immutable; the overlay carries multiplicative duration factors and a
/// per-node lead delay, applied at execute time in the exact order the
/// old clone-and-mutate path applied them —
///
///   1. `global` (Baidu's ring-pipeline amortization, ex-`scale`),
///   2. per-rank all-op factor (stragglers, ex-`scale_rank`),
///   3. per-rank GPU-side factor on `GpuReduce`/`Launch`/`Pcie` ops
///      (hetero GPU generations, ex-`scale_rank_gpu`),
///   4. a leading per-node stall resolved through the rank's `Sw`
///      resource (OS/sync jitter, ex-`jitter_nodes`) —
///
/// so an overlay replay is bit-identical to executing a freshly built,
/// mutated graph.  What is *baked into the template* instead: topology,
/// dep edges, step indices, op kinds/pins, and unperturbed durations.
#[derive(Clone)]
pub struct GraphOverlay {
    global: f64,
    rank_all: Vec<f64>,
    rank_gpu: Vec<f64>,
    lead: Option<Rc<dyn Fn(usize, u32) -> f64>>,
}

/// `Default` is the neutral overlay (identity factors, no lead).
impl Default for GraphOverlay {
    fn default() -> GraphOverlay {
        GraphOverlay::neutral()
    }
}

impl GraphOverlay {
    /// The identity overlay: replaying under it equals the bare template.
    pub fn neutral() -> GraphOverlay {
        GraphOverlay { global: 1.0, rank_all: Vec::new(), rank_gpu: Vec::new(), lead: None }
    }

    /// Multiply every op of every rank (pipeline amortization).
    pub fn scale_global(&mut self, f: f64) {
        self.global *= f;
    }

    /// Multiply every op of one rank — a straggler whose progress engine,
    /// host and links all run slow.  (Out-of-`world` ranks have no nodes,
    /// matching the old mutator's no-op; the factor table grows to cover
    /// the largest `world` seen, so composed calls never drop a factor.)
    pub fn scale_rank(&mut self, world: usize, rank: usize, f: f64) {
        if self.rank_all.len() < world {
            self.rank_all.resize(world, 1.0);
        }
        if let Some(s) = self.rank_all.get_mut(rank) {
            *s *= f;
        }
    }

    /// Multiply only the GPU-side ops (reduce kernel, launch, PCIe
    /// staging) of one rank — a rank placed on an older GPU generation.
    pub fn scale_rank_gpu(&mut self, world: usize, rank: usize, f: f64) {
        if self.rank_gpu.len() < world {
            self.rank_gpu.resize(world, 1.0);
        }
        if let Some(s) = self.rank_gpu.get_mut(rank) {
            *s *= f;
        }
    }

    /// Per-node extra lead delay from a deterministic `(rank, step)` draw
    /// — OS/sync jitter at step granularity.  The delay occupies the
    /// rank's own `Sw` resource (never a shared pinned one), so a jittery
    /// worker delays itself, not the NIC queue behind it.
    pub fn set_lead(&mut self, draw: impl Fn(usize, u32) -> f64 + 'static) {
        self.lead = Some(Rc::new(draw));
    }

    pub fn is_neutral(&self) -> bool {
        self.global == 1.0
            && self.rank_all.is_empty()
            && self.rank_gpu.is_empty()
            && self.lead.is_none()
    }

    fn all_factor(&self, rank: usize) -> f64 {
        self.rank_all.get(rank).copied().unwrap_or(1.0)
    }

    fn gpu_factor(&self, rank: usize) -> f64 {
        self.rank_gpu.get(rank).copied().unwrap_or(1.0)
    }

    fn lead_us(&self, rank: usize, step: u32) -> f64 {
        self.lead.as_ref().map_or(0.0, |f| f(rank, step))
    }
}

impl std::fmt::Debug for GraphOverlay {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GraphOverlay")
            .field("global", &self.global)
            .field("rank_all", &self.rank_all)
            .field("rank_gpu", &self.rank_gpu)
            .field("lead", &self.lead.is_some())
            .finish()
    }
}

/// Resolve one node against the resource map and overlay into a typed
/// engine program.  The multiplication order (global → rank → rank-GPU)
/// replicates the old sequential `op.us *= f` mutations bit-for-bit, and
/// `f * 1.0 == f` exactly, so a neutral overlay changes nothing.
fn resolve_node(node: &GraphNode, map: &GraphResMap, ov: &GraphOverlay) -> Rc<[ProgStep]> {
    let rank = node.rank;
    let lead = ov.lead_us(rank, node.step);
    let mut steps = Vec::with_capacity(node.ops.len() + usize::from(lead > 0.0));
    if lead > 0.0 {
        steps.push(ProgStep { us: lead, on: map(rank, ResKind::Sw, None) });
    }
    let all = ov.all_factor(rank);
    let gpu = ov.gpu_factor(rank);
    for op in &node.ops {
        let mut us = op.us;
        us *= ov.global;
        us *= all;
        if matches!(op.kind, ResKind::GpuReduce | ResKind::Launch | ResKind::Pcie) {
            us *= gpu;
        }
        steps.push(ProgStep { us, on: op.on.or_else(|| map(rank, op.kind, op.rel)) });
    }
    steps.into()
}

/// The precomputed execution plan of a graph: successor lists, in-degrees
/// and the sink count — everything `execute` used to rebuild per run.
#[derive(Debug)]
struct GraphPlan {
    succ: Vec<Vec<usize>>,
    indeg: Vec<usize>,
    sink_count: usize,
}

impl GraphPlan {
    fn of(g: &CommGraph) -> GraphPlan {
        let n = g.nodes.len();
        let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indeg: Vec<usize> = vec![0; n];
        for (i, node) in g.nodes.iter().enumerate() {
            for d in &node.deps {
                succ[d.0].push(i);
                indeg[i] += 1;
            }
        }
        let sink_count = succ.iter().filter(|s| s.is_empty()).count();
        GraphPlan { succ, indeg, sink_count }
    }
}

/// An immutable, build-once graph plus its execution plan — the unit the
/// strategies cache and replay (§Perf).  Executing a template never
/// mutates it; per-iteration variation goes through [`GraphOverlay`].
#[derive(Debug)]
pub struct GraphTemplate {
    graph: CommGraph,
    plan: GraphPlan,
}

impl GraphTemplate {
    pub fn new(graph: CommGraph) -> GraphTemplate {
        let plan = GraphPlan::of(&graph);
        GraphTemplate { graph, plan }
    }

    pub fn graph(&self) -> &CommGraph {
        &self.graph
    }

    /// Resident size of the materialized graph + plan — O(world × steps);
    /// the scale bench reports it against [`SymTemplate::approx_bytes`]
    /// to show the shared plan's O(1)-in-world footprint.
    pub fn approx_bytes(&self) -> usize {
        use std::mem::size_of;
        let mut bytes = self.graph.nodes.len() * size_of::<GraphNode>();
        for n in &self.graph.nodes {
            bytes += n.ops.len() * size_of::<CommOp>() + n.deps.len() * size_of::<NodeId>();
        }
        bytes += self.plan.indeg.len() * size_of::<usize>();
        bytes += self.plan.succ.len() * size_of::<Vec<usize>>();
        for s in &self.plan.succ {
            bytes += s.len() * size_of::<usize>();
        }
        bytes
    }

    /// Execute the template now (source nodes release at the current
    /// virtual time).  See [`GraphTemplate::execute_at`].
    pub fn execute(
        &self,
        e: &mut Engine,
        map: GraphResMap,
        ov: &GraphOverlay,
        done: Action,
    ) -> Rc<RefCell<GraphRun>> {
        let at = e.now();
        self.execute_at(e, map, ov, at, done)
    }

    /// Execute the template with sources released at virtual time `at`
    /// (>= now), under `ov`.  Each node becomes *eligible* when all its
    /// predecessors complete (an [`Engine::join`]), then its resolved op
    /// program queues FIFO on the resources `map` resolves for its rank.
    /// `done` fires when every node has finished.  Source nodes release
    /// in node order (deterministic FIFO ties).
    pub fn execute_at(
        &self,
        e: &mut Engine,
        map: GraphResMap,
        ov: &GraphOverlay,
        at: SimTime,
        done: Action,
    ) -> Rc<RefCell<GraphRun>> {
        execute_planned(e, &self.graph, &self.plan, &map, ov, at, OnDone::Call(done))
    }

    /// Execute the template as stream-lane job `job` of `set`: sources
    /// release now (this is the job's launch turn), and the terminal
    /// join's completion is the typed [`Engine::lane_done`] — no boxed
    /// `done` per collective, which is what keeps the fusion-overlap
    /// buffer loop allocation-free (§Overlap).
    pub fn execute_lane(
        &self,
        e: &mut Engine,
        map: GraphResMap,
        ov: &GraphOverlay,
        set: LaneSetId,
        job: u32,
    ) -> Rc<RefCell<GraphRun>> {
        let at = e.now();
        execute_planned(e, &self.graph, &self.plan, &map, ov, at, OnDone::Lane(set, job))
    }
}

/// A shared template-cache handle: clones of a strategy share one map,
/// and the parallel sweep drivers may probe it from several threads.
/// Keys are exact ([`TemplateKey`] embeds the full step-cost bit
/// signature), so a hit can never be stale.
#[derive(Debug, Clone, Default)]
pub struct TemplateCache {
    inner: Arc<Mutex<HashMap<TemplateKey, Arc<GraphTemplate>>>>,
    /// Shared symmetric plans (§Scale), keyed disjointly from the full
    /// templates ([`TemplateKey::sym`] sets the high algo bit).
    sym: Arc<Mutex<HashMap<TemplateKey, Arc<SymTemplate>>>>,
}

/// Cache key of one built collective graph: algorithm tag, world size,
/// the placement signature, and the exact bit signature of the per-step
/// costs (plus any builder extras the caller appends, e.g. Horovod's
/// coordination-root cost or the intra-node hop factor).  The placement
/// is part of the key because a placed builder bakes intra-node hop
/// re-kinding *into the graph*: two layouts of the same collective must
/// never alias one template (and rails, though resource-side only, keep
/// the key honest about what layout a template was built for).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TemplateKey {
    pub algo: u8,
    pub world: usize,
    /// `(gpus_per_node, rails)` — [`Placement::key`].
    pub place: (usize, usize),
    pub sig: Vec<u64>,
}

impl TemplateKey {
    pub fn allreduce(algo: Algo, world: usize, sig: Vec<u64>) -> TemplateKey {
        TemplateKey::allreduce_placed(algo, world, Placement::one_per_node(), sig)
    }

    pub fn allreduce_placed(
        algo: Algo,
        world: usize,
        place: Placement,
        sig: Vec<u64>,
    ) -> TemplateKey {
        let algo = match algo {
            Algo::Tree => 0,
            Algo::Ring => 1,
            Algo::Rhd => 2,
        };
        TemplateKey { algo, world, place: place.key(), sig }
    }

    /// Key of a PS fan-in template: `world` is the worker count, and the
    /// caller's `sig` carries everything the shard's ops depend on
    /// (server index, transfer/update costs, single-thread flag, the
    /// intra-node factor) bit-exactly.  Tag 3 keeps fan-ins disjoint
    /// from every allreduce algorithm.
    pub fn ps_fanin(world: usize, place: Placement, sig: Vec<u64>) -> TemplateKey {
        TemplateKey { algo: 3, world, place: place.key(), sig }
    }

    /// Tag this key as naming a *shared symmetric plan* (§Scale): the
    /// high algo bit keeps sym keys disjoint from full-template keys even
    /// though the cache stores the two in separate maps.
    pub fn sym(mut self) -> TemplateKey {
        self.algo |= 0x80;
        self
    }
}

impl TemplateCache {
    /// Return the cached template for `key`, building (and caching) it
    /// with `build` on a miss.  The build runs *outside* the lock: cold
    /// parallel sweeps may build a key twice (first insert wins, the
    /// duplicate is dropped) rather than serializing every thread on one
    /// graph construction — and a panicking builder cannot poison the
    /// cache for the surviving threads.
    pub fn get_or_build(
        &self,
        key: TemplateKey,
        build: impl FnOnce() -> CommGraph,
    ) -> Arc<GraphTemplate> {
        if let Some(hit) = self.inner.lock().expect("template cache poisoned").get(&key) {
            return hit.clone();
        }
        let built = Arc::new(GraphTemplate::new(build()));
        let mut m = self.inner.lock().expect("template cache poisoned");
        m.entry(key).or_insert(built).clone()
    }

    /// [`TemplateCache::get_or_build`] for shared symmetric plans: same
    /// first-insert-wins, build-outside-the-lock discipline, in a map of
    /// its own so a sym plan and the full template of one collective can
    /// coexist (the scale bench compares them head-to-head).
    pub fn get_or_build_sym(
        &self,
        key: TemplateKey,
        build: impl FnOnce() -> SymTemplate,
    ) -> Arc<SymTemplate> {
        let key = key.sym();
        if let Some(hit) = self.sym.lock().expect("template cache poisoned").get(&key) {
            return hit.clone();
        }
        let built = Arc::new(build());
        let mut m = self.sym.lock().expect("template cache poisoned");
        m.entry(key).or_insert(built).clone()
    }

    /// Number of distinct templates built so far (full + shared plans).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("template cache poisoned").len()
            + self.sym.lock().expect("template cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Node-local resources laid out over a [`Placement`]: co-located ranks
/// share their node's NIC ports (`wire`, one per `(node, rail)`) and
/// its PCIe/NVLink path (`pcie`, one per node) while keeping private
/// GPU/CPU/driver/launch/software resources.  With the paper's trivial
/// placement (1 GPU per node, 1 rail) every bundle is per-rank, exactly
/// the historical layout.  Cross-rank contention inside one collective
/// comes from co-located ranks queueing on shared node resources (plus
/// the dependency edges); co-tenant *jobs* sharing the fabric contend
/// per NIC port via [`GraphResources::sharing_wire`].
#[derive(Clone)]
pub struct GraphResources {
    /// NIC ports, node-major rail-minor: `wire[node * rails + rail]`.
    pub wire: Vec<ResourceId>,
    /// Host staging / intra-node transfer links, one per node.
    pub pcie: Vec<ResourceId>,
    pub gpu: Vec<ResourceId>,
    pub cpu: Vec<ResourceId>,
    pub driver: Vec<ResourceId>,
    pub launch: Vec<ResourceId>,
    pub sw: Vec<ResourceId>,
    place: Placement,
    ranks: usize,
}

impl GraphResources {
    pub fn install(e: &mut Engine, ranks: usize) -> GraphResources {
        GraphResources::install_placed(e, ranks, Placement::one_per_node())
    }

    /// Install a job's bundle under a placement.  Resource-creation
    /// order (wire ports, pcie, then the per-rank vectors) matches the
    /// historical per-rank install when the placement is trivial, so
    /// engine resource ids — and therefore FIFO tie-breaking — are
    /// unchanged on the paper's layouts.
    pub fn install_placed(e: &mut Engine, ranks: usize, place: Placement) -> GraphResources {
        let nodes = place.nodes_for(ranks);
        let wire = (0..nodes * place.rails).map(|_| e.unit_resource()).collect();
        let pcie = (0..nodes).map(|_| e.unit_resource()).collect();
        let per_rank = |e: &mut Engine| -> Vec<ResourceId> {
            (0..ranks).map(|_| e.unit_resource()).collect()
        };
        let res = GraphResources {
            wire,
            pcie,
            gpu: per_rank(e),
            cpu: per_rank(e),
            driver: per_rank(e),
            launch: per_rank(e),
            sw: per_rank(e),
            place,
            ranks,
        };
        // Naming happens after every id is handed out, so the creation
        // order above — and with it FIFO tie-breaking — is identical
        // whether or not a tracer is attached.
        if e.tracing() {
            use crate::sim::trace::{pid_node, pid_rank};
            for (i, &r) in res.wire.iter().enumerate() {
                let (node, rail) = (i / res.place.rails, i % res.place.rails);
                let name = format!("{} n{node} rail{rail}", ResKind::Wire.name());
                e.trace_resource(r, SpanKind::Wire, pid_node(node), node as u32, &name);
            }
            for (node, &r) in res.pcie.iter().enumerate() {
                let name = format!("{} n{node}", ResKind::Pcie.name());
                e.trace_resource(r, SpanKind::Pcie, pid_node(node), node as u32, &name);
            }
            let per_rank_rows: [(&Vec<ResourceId>, ResKind); 5] = [
                (&res.gpu, ResKind::GpuReduce),
                (&res.cpu, ResKind::CpuReduce),
                (&res.driver, ResKind::Driver),
                (&res.launch, ResKind::Launch),
                (&res.sw, ResKind::Sw),
            ];
            for (ids, k) in per_rank_rows {
                for (rank, &r) in ids.iter().enumerate() {
                    let name = format!("{} r{rank}", k.name());
                    e.trace_resource(r, k.span_kind(), pid_rank(rank), rank as u32, &name);
                }
            }
        }
        res
    }

    /// A co-tenant job's bundle sharing another job's physical NIC ports
    /// (both jobs' wire steps queue FIFO on the same `(node, rail)`
    /// ports) while owning every other node-local resource.  The
    /// co-tenant lands on the same physical nodes, so it inherits
    /// `other`'s placement geometry; `ranks` is the co-tenant's own
    /// world size — when it spans more nodes than `other`, the extra
    /// nodes' ports stay private (nobody there to share with), and when
    /// it spans fewer, only the overlapping ports are shared.
    pub fn sharing_wire(e: &mut Engine, ranks: usize, other: &GraphResources) -> GraphResources {
        let mut mine = GraphResources::install_placed(e, ranks, other.place);
        let shared = mine.wire.len().min(other.wire.len());
        mine.wire[..shared].copy_from_slice(&other.wire[..shared]);
        mine
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    pub fn placement(&self) -> Placement {
        self.place
    }

    /// The engine resource backing `rank`'s ops of kind `k`.  Panics on
    /// an out-of-range rank — the old modulo indexing silently wrapped
    /// such ranks onto another rank's bundle, turning a caller bug into
    /// phantom contention.
    pub fn get(&self, rank: usize, k: ResKind) -> ResourceId {
        assert!(
            rank < self.ranks,
            "rank {rank} out of range: bundle installed for {} ranks",
            self.ranks
        );
        match k {
            ResKind::Wire => {
                self.wire[self.place.node_of(rank) * self.place.rails + self.place.rail_of(rank)]
            }
            ResKind::Pcie => self.pcie[self.place.node_of(rank)],
            ResKind::GpuReduce => self.gpu[rank],
            ResKind::CpuReduce => self.cpu[rank],
            ResKind::Driver => self.driver[rank],
            ResKind::Launch => self.launch[rank],
            ResKind::Sw => self.sw[rank],
        }
    }

    pub fn mapper(&self) -> GraphResMap {
        let me = self.clone();
        Rc::new(move |rank, k, _rel| Some(me.get(rank, k)))
    }

    /// Per-kind (served, busy) rows aggregated across the *distinct*
    /// underlying resources (shared node resources count once, not once
    /// per co-located rank) — same row names as the serialized path's
    /// `CommResources::utilization`.
    pub fn utilization(&self, e: &Engine) -> Vec<ResourceUse> {
        let rows: [(&str, &Vec<ResourceId>); 7] = [
            (ResKind::Wire.name(), &self.wire),
            (ResKind::Pcie.name(), &self.pcie),
            (ResKind::GpuReduce.name(), &self.gpu),
            (ResKind::CpuReduce.name(), &self.cpu),
            (ResKind::Driver.name(), &self.driver),
            (ResKind::Launch.name(), &self.launch),
            (ResKind::Sw.name(), &self.sw),
        ];
        rows.iter()
            .map(|(name, ids)| ResourceUse::aggregate(e, name, ids.iter().copied()))
            .filter(|u| u.served > 0)
            .collect()
    }
}

/// Per-node start/finish times of one executed graph.
#[derive(Debug, Clone)]
pub struct GraphRun {
    pub start: Vec<SimTime>,
    pub finish: Vec<SimTime>,
}

impl GraphRun {
    pub fn finish_of(&self, id: NodeId) -> SimTime {
        self.finish[id.0]
    }
}

/// Execute a graph on the engine under the neutral overlay — the
/// one-shot path (plan rebuilt per call).  Cached replay goes through
/// [`GraphTemplate::execute`].
pub fn execute(
    e: &mut Engine,
    g: &CommGraph,
    map: GraphResMap,
    done: Action,
) -> Rc<RefCell<GraphRun>> {
    let now = e.now();
    execute_at(e, g, map, now, done)
}

/// [`execute`] with the source release deferred to virtual time `at`
/// (>= now) — lets a caller wire up many graphs at setup time, each
/// releasing when its data is ready.
pub fn execute_at(
    e: &mut Engine,
    g: &CommGraph,
    map: GraphResMap,
    at: SimTime,
    done: Action,
) -> Rc<RefCell<GraphRun>> {
    let plan = GraphPlan::of(g);
    execute_planned(e, g, &plan, &map, &GraphOverlay::neutral(), at, OnDone::Call(done))
}

/// The shared executor: wire joins from the (pre)computed plan, resolve
/// each node against `map` + `ov` into a typed engine program, release
/// sources at `at`.
fn execute_planned(
    e: &mut Engine,
    g: &CommGraph,
    plan: &GraphPlan,
    map: &GraphResMap,
    ov: &GraphOverlay,
    at: SimTime,
    done: OnDone,
) -> Rc<RefCell<GraphRun>> {
    let n = g.nodes.len();
    let run = Rc::new(RefCell::new(GraphRun {
        start: vec![SimTime::ZERO; n],
        finish: vec![SimTime::ZERO; n],
    }));
    if n == 0 {
        match done {
            OnDone::Call(a) => e.at(at, a),
            // lane executions always release at `at == now` (the job's
            // launch turn), so the empty graph completes on the spot
            OnDone::Lane(set, job) => e.lane_done(set, job),
            // hook completions are a SymExec-only path, and symmetric
            // plans refuse empty step lists before reaching here
            OnDone::Hook(..) => unreachable!("graph templates never complete through hooks"),
        }
        return run;
    }
    let terminal = e.join_with(plan.sink_count, done);

    // Joins must exist before the node actions that arrive at them are
    // built; nodes are created in topological order, so walking in
    // reverse guarantees every successor's join is already allocated.
    let mut joins = vec![None; n];
    let mut sources: Vec<(usize, Action)> = Vec::new();
    for i in (0..n).rev() {
        let node = &g.nodes[i];
        let steps = resolve_node(node, map, ov);
        let succ_joins: Vec<_> =
            plan.succ[i].iter().map(|&j| joins[j].expect("topological order")).collect();
        let run_i = run.clone();
        let action = move |e: &mut Engine| {
            run_i.borrow_mut().start[i] = e.now();
            let run_done = run_i.clone();
            e.run_program(
                steps,
                Box::new(move |e| {
                    run_done.borrow_mut().finish[i] = e.now();
                    if succ_joins.is_empty() {
                        e.arrive(terminal);
                    }
                    for j in succ_joins {
                        e.arrive(j);
                    }
                }),
            );
        };
        if plan.indeg[i] == 0 {
            sources.push((i, Box::new(action)));
        } else {
            joins[i] = Some(e.join(plan.indeg[i], action));
        }
    }
    sources.sort_by_key(|&(i, _)| i);
    for (_, a) in sources {
        e.at(at, a);
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::commop::CommSchedule;
    use crate::comm::CostBreakdown;

    fn wire_steps(count: usize, us: f64) -> Vec<StepCost> {
        vec![
            StepCost {
                cost: CostBreakdown { wire_us: us, ..Default::default() },
                gpu_reduce: false,
            };
            count
        ]
    }

    fn run_graph(g: &CommGraph, ranks: usize) -> (SimTime, GraphRun) {
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, ranks);
        let run = execute(&mut e, g, res.mapper(), Box::new(|_| {}));
        let end = e.run();
        let out = run.borrow().clone();
        (end, out)
    }

    fn run_template(t: &GraphTemplate, ranks: usize, ov: &GraphOverlay) -> (SimTime, GraphRun) {
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, ranks);
        let run = t.execute(&mut e, res.mapper(), ov, Box::new(|_| {}));
        let end = e.run();
        let out = run.borrow().clone();
        (end, out)
    }

    #[test]
    fn zero_skew_ring_matches_serialized_total() {
        for p in [2usize, 3, 4, 8] {
            let steps = wire_steps(2 * (p - 1), 10.0);
            let g = ring_graph(p, &steps);
            assert_eq!(g.len(), p * steps.len());
            let serial = CommSchedule::from_steps(&steps).total_us();
            let (end, _) = run_graph(&g, p);
            assert!(
                (end.as_us() - serial).abs() < 1e-9,
                "ring p={p}: graph {} vs serial {serial}",
                end.as_us()
            );
            assert!((g.critical_path_us() - serial).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_skew_rhd_and_tree_match_serialized_total() {
        for p in [2usize, 3, 5, 8, 13, 16] {
            let p2 = crate::comm::allreduce::flp2(p);
            let rem_steps = if p > p2 { 2 } else { 0 };
            let rhd_steps = wire_steps(rem_steps + 2 * p2.trailing_zeros() as usize, 7.0);
            let g = rhd_graph(p, &rhd_steps);
            let serial = CommSchedule::from_steps(&rhd_steps).total_us();
            let (end, _) = run_graph(&g, p);
            assert!(
                (end.as_us() - serial).abs() < 1e-9,
                "rhd p={p}: graph {} vs serial {serial}",
                end.as_us()
            );

            // tree level count: log2 up + log2 down (shadow skips no level
            // for p >= 2)
            let levels = {
                let mut c = 0;
                let mut dist = 1;
                while dist < p {
                    c += 1;
                    dist *= 2;
                }
                let mut dist = p.next_power_of_two() / 2;
                while dist >= 1 {
                    if (0..p).step_by(2 * dist).any(|s| s + dist < p) {
                        c += 1;
                    }
                    dist /= 2;
                }
                c
            };
            let tree_steps = wire_steps(levels, 5.0);
            let g = tree_graph(p, &tree_steps);
            let serial = CommSchedule::from_steps(&tree_steps).total_us();
            let (end, _) = run_graph(&g, p);
            assert!(
                (end.as_us() - serial).abs() < 1e-9,
                "tree p={p}: graph {} vs serial {serial}",
                end.as_us()
            );
        }
    }

    #[test]
    fn straggler_skew_propagates_one_rank_per_step() {
        // Ring p=4, 6 uniform 10us steps; rank 1 runs 2x slow (overlay).
        // The skew cone: a node (r, s) is delayed iff s >= ring-distance
        // (1 -> r); outside the cone finish times match the pristine run.
        let p = 4;
        let steps = wire_steps(2 * (p - 1), 10.0);
        let t = GraphTemplate::new(ring_graph(p, &steps));
        let (_, base) = run_template(&t, p, &GraphOverlay::neutral());
        let mut ov = GraphOverlay::neutral();
        ov.scale_rank(p, 1, 2.0);
        let (end, run) = run_template(&t, p, &ov);

        let at = |r: usize, s: usize| NodeId(s * p + r); // ring builder layout
        // unaffected: early steps of downstream ranks
        assert_eq!(run.finish_of(at(2, 0)), base.finish_of(at(2, 0)));
        assert_eq!(run.finish_of(at(3, 1)), base.finish_of(at(3, 1)));
        assert_eq!(run.finish_of(at(0, 2)), base.finish_of(at(0, 2)));
        // delayed: the dependent steps one hop later
        assert!(run.finish_of(at(2, 1)) > base.finish_of(at(2, 1)));
        assert!(run.finish_of(at(3, 2)) > base.finish_of(at(3, 2)));
        assert!(run.finish_of(at(0, 3)) > base.finish_of(at(0, 3)));
        // the straggler's own chain dominates completion: 6 steps × 20us
        assert_eq!(end, SimTime::from_us(120.0));
    }

    #[test]
    fn ps_fanin_updates_after_last_push_and_pulls_fifo() {
        let mut e = Engine::new();
        let nic_in = e.unit_resource();
        let nic_out = e.unit_resource();
        let (g, pulls) = ps_fanin_graph(
            3,
            0,
            |_| vec![CommOp::fixed(ResKind::Wire, 10.0).pinned(nic_in)],
            vec![CommOp::fixed(ResKind::CpuReduce, 5.0)],
            |_| vec![CommOp::fixed(ResKind::Wire, 10.0).pinned(nic_out)],
        );
        let done = Rc::new(RefCell::new(0.0));
        let d2 = done.clone();
        let run = execute(
            &mut e,
            &g,
            unmapped(),
            Box::new(move |e| *d2.borrow_mut() = e.now().as_us()),
        );
        e.run();
        // pushes serialize on the ingress NIC (10/20/30), update at 35,
        // pulls serialize on the egress NIC in worker order (45/55/65)
        let r = run.borrow();
        assert_eq!(
            pulls.iter().map(|&id| r.finish_of(id).as_us()).collect::<Vec<_>>(),
            vec![45.0, 55.0, 65.0]
        );
        assert_eq!(*done.borrow(), 65.0);
    }

    #[test]
    fn prefix_root_gates_every_source() {
        let p = 3;
        let steps = wire_steps(2 * (p - 1), 10.0);
        let mut g = ring_graph(p, &steps);
        g.prefix_root(0, vec![CommOp::fixed(ResKind::Sw, 4.0)]);
        assert_eq!(g.nodes[0].deps.len(), 0);
        assert!(g.nodes[1..].iter().all(|n| !n.deps.is_empty()));
        let (end, _) = run_graph(&g, p);
        assert!((end.as_us() - (4.0 + 40.0)).abs() < 1e-9);
    }

    #[test]
    fn jitter_lead_is_additive_and_keyed_by_rank_step() {
        let steps = wire_steps(2, 10.0);
        let t = GraphTemplate::new(ring_graph(2, &steps));
        let mut ov = GraphOverlay::neutral();
        ov.set_lead(|rank, step| if rank == 0 && step == 0 { 3.0 } else { 0.0 });
        let (end, run) = run_template(&t, 2, &ov);
        assert_eq!(run.finish_of(NodeId(0)), SimTime::from_us(13.0));
        // rank 1 step 1 depends on rank 0 step 0: jitter propagates
        assert_eq!(end, SimTime::from_us(23.0));
    }

    #[test]
    fn empty_graph_completes_immediately() {
        let mut e = Engine::new();
        let fired = Rc::new(RefCell::new(false));
        let f = fired.clone();
        execute(
            &mut e,
            &CommGraph::default(),
            unmapped(),
            Box::new(move |_| *f.borrow_mut() = true),
        );
        let end = e.run();
        assert!(*fired.borrow());
        assert_eq!(end, SimTime::ZERO);
    }

    #[test]
    fn template_replay_matches_one_shot_execute_bitwise() {
        // the §Perf pin at graph level: executing a cached template under
        // the neutral overlay reproduces the one-shot path exactly, and
        // replaying the SAME template again gives the same trace
        for p in [3usize, 8] {
            let steps = wire_steps(2 * (p - 1), 9.5);
            let g = ring_graph(p, &steps);
            let (end0, run0) = run_graph(&g, p);
            let t = GraphTemplate::new(g);
            let (end1, run1) = run_template(&t, p, &GraphOverlay::neutral());
            let (end2, run2) = run_template(&t, p, &GraphOverlay::neutral());
            assert_eq!(end0, end1);
            assert_eq!(run0.finish, run1.finish);
            assert_eq!(end1, end2);
            assert_eq!(run1.finish, run2.finish);
        }
    }

    /// Materialize an overlay into a mutated graph the way the old
    /// in-place perturbation API did — the oracle for overlay replay.
    fn materialize(g: &CommGraph, ov_global: f64, all: &[f64], gpu: &[f64],
                   lead: impl Fn(usize, u32) -> f64) -> CommGraph {
        let mut out = g.clone();
        for n in &mut out.nodes {
            for op in &mut n.ops {
                op.us *= ov_global;
                op.us *= all.get(n.rank).copied().unwrap_or(1.0);
                if matches!(op.kind, ResKind::GpuReduce | ResKind::Launch | ResKind::Pcie) {
                    op.us *= gpu.get(n.rank).copied().unwrap_or(1.0);
                }
            }
            let j = lead(n.rank, n.step);
            if j > 0.0 {
                n.ops.insert(0, CommOp::fixed(ResKind::Sw, j));
            }
        }
        out
    }

    #[test]
    fn overlay_replay_equals_materialized_mutation() {
        // straggler + hetero + jitter overlays on a mixed-kind ring must
        // reproduce a freshly built mutated graph bit-for-bit
        let p = 5;
        let steps: Vec<StepCost> = (0..2 * (p - 1))
            .map(|i| StepCost {
                cost: CostBreakdown {
                    wire_us: 7.0 + i as f64,
                    staging_us: 1.5,
                    reduce_us: 2.25,
                    launch_us: 0.5,
                    sw_us: 0.75,
                    ..Default::default()
                },
                gpu_reduce: true,
            })
            .collect();
        let g = ring_graph(p, &steps);
        let lead = |rank: usize, step: u32| {
            if (rank + step as usize) % 3 == 0 { 1.0 + rank as f64 * 0.37 } else { 0.0 }
        };
        let mut all = vec![1.0; p];
        all[1] = 1.7;
        let mut gpu = vec![1.0; p];
        gpu[4] = 2.5;
        gpu[1] = 1.3; // rank 1 is both straggler and on a slow GPU

        let oracle = materialize(&g, 1.25, &all, &gpu, lead);
        let (end_o, run_o) = run_graph(&oracle, p);

        let t = GraphTemplate::new(g);
        let mut ov = GraphOverlay::neutral();
        ov.scale_global(1.25);
        ov.scale_rank(p, 1, 1.7);
        ov.scale_rank_gpu(p, 4, 2.5);
        ov.scale_rank_gpu(p, 1, 1.3);
        ov.set_lead(lead);
        let (end_t, run_t) = run_template(&t, p, &ov);

        assert_eq!(end_o, end_t, "overlay end diverged from materialized graph");
        assert_eq!(run_o.finish, run_t.finish, "per-node finishes diverged");
        assert_eq!(run_o.start, run_t.start, "per-node starts diverged");
    }

    #[test]
    fn rel_pins_resolve_through_the_map() {
        // a named (template-relative) pin routes onto whatever resource
        // THIS engine's map resolves it to — two executions contend on
        // the named NIC exactly like the old engine-id pin
        let mut e = Engine::new();
        let nic = e.unit_resource();
        let g = CommGraph::chain(
            0,
            vec![CommOp::fixed(ResKind::Wire, 10.0).rel_pinned(RelPin::PsIn(3))],
        );
        let map: GraphResMap = Rc::new(move |_, _, rel| match rel {
            Some(RelPin::PsIn(3)) => Some(nic),
            _ => None,
        });
        for _ in 0..2 {
            execute(&mut e, &g, map.clone(), Box::new(|_| {}));
        }
        let end = e.run();
        assert_eq!(end, SimTime::from_us(20.0));
        let s = e.resource_stats(nic);
        assert_eq!((s.served, s.busy), (2, SimTime::from_us(20.0)));
        // under a map that does not name it, the op elapses per-rank
        let mut e2 = Engine::new();
        execute(&mut e2, &g, unmapped(), Box::new(|_| {}));
        assert_eq!(e2.run(), SimTime::from_us(10.0));
    }

    #[test]
    fn execute_lane_completes_through_typed_join() {
        // the §Overlap execution shape: templates launched as lane jobs
        // finish through the typed terminal join, hand the lane back,
        // and a width-1 set reproduces back-to-back serialized rings
        use crate::sim::{LaneDriver, LaneSetId};
        struct D {
            t: Arc<GraphTemplate>,
            map: GraphResMap,
        }
        impl LaneDriver for D {
            fn launch(&self, e: &mut Engine, set: LaneSetId, job: u32) {
                self.t.execute_lane(e, self.map.clone(), &GraphOverlay::neutral(), set, job);
            }
        }
        let p = 3;
        let steps = wire_steps(2 * (p - 1), 10.0);
        let t = Arc::new(GraphTemplate::new(ring_graph(p, &steps)));
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, p);
        let set = e.lane_set(1, 1, Rc::new(D { t, map: res.mapper() }));
        e.lane_submit(set, SimTime::ZERO, 0);
        e.lane_submit(set, SimTime::ZERO, 1);
        let end = e.run();
        let serial = CommSchedule::from_steps(&steps).total_us();
        assert!((end.as_us() - 2.0 * serial).abs() < 1e-9);
        assert_eq!(e.lane_completed(set), 2);
        let s = e.lane_stats(set);
        assert_eq!(s.served, 2);
        assert_eq!(s.busy, end);
    }

    #[test]
    fn ps_fanin_pulls_match_builder_layout() {
        let (g, pulls) = ps_fanin_graph(
            4,
            1,
            |_| vec![CommOp::fixed(ResKind::Sw, 1.0)],
            vec![CommOp::fixed(ResKind::CpuReduce, 1.0)],
            |_| vec![CommOp::fixed(ResKind::Sw, 1.0)],
        );
        assert_eq!(pulls, ps_fanin_pulls(4));
        assert_eq!(g.len(), 9);
    }

    #[test]
    fn template_cache_hits_on_equal_keys_only() {
        let cache = TemplateCache::default();
        let steps = wire_steps(4, 10.0);
        let sig = crate::comm::commop::steps_sig(&steps);
        let t1 = cache.get_or_build(TemplateKey::allreduce(Algo::Ring, 3, sig.clone()), || {
            ring_graph(3, &steps)
        });
        let t2 = cache.get_or_build(TemplateKey::allreduce(Algo::Ring, 3, sig.clone()), || {
            panic!("must hit the cache")
        });
        assert!(Arc::ptr_eq(&t1, &t2), "same key must be pointer-cached");
        assert_eq!(cache.len(), 1);
        // different world or perturbed costs miss
        cache.get_or_build(TemplateKey::allreduce(Algo::Ring, 4, sig), || ring_graph(4, &steps));
        let steps2 = wire_steps(4, 10.000001);
        let sig2 = crate::comm::commop::steps_sig(&steps2);
        cache.get_or_build(TemplateKey::allreduce(Algo::Ring, 3, sig2), || {
            ring_graph(3, &steps2)
        });
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn shared_wire_contends_across_jobs_private_rest_overlaps() {
        // Two single-node chains on the same rank-0 NIC: wire serializes,
        // the private gpu phases overlap — the two-job model at rank level.
        let mut e = Engine::new();
        let a = GraphResources::install(&mut e, 2);
        let b = GraphResources::sharing_wire(&mut e, 2, &a);
        let mut ends = Vec::new();
        for res in [&a, &b] {
            let g = CommGraph::chain(
                0,
                vec![CommOp::fixed(ResKind::Wire, 10.0), CommOp::fixed(ResKind::GpuReduce, 5.0)],
            );
            let done = Rc::new(RefCell::new(0.0));
            let d2 = done.clone();
            execute(
                &mut e,
                &g,
                res.mapper(),
                Box::new(move |e| *d2.borrow_mut() = e.now().as_us()),
            );
            ends.push(done);
        }
        e.run();
        assert_eq!(*ends[0].borrow(), 15.0);
        assert_eq!(*ends[1].borrow(), 25.0);
        assert_eq!(e.resource_stats(a.wire[0]).busy, SimTime::from_us(20.0));
    }

    #[test]
    fn placed_bundle_shares_node_nic_and_pcie_keeps_gpu_private() {
        // Two co-located ranks (2 GPUs/node, 1 rail): their wire ops
        // serialize on the node's one port, their gpu ops overlap.
        let mut e = Engine::new();
        let res = GraphResources::install_placed(&mut e, 2, Placement::new(2, 1));
        assert_eq!(res.ranks(), 2);
        assert_eq!(res.wire.len(), 1);
        assert_eq!(res.pcie.len(), 1);
        assert_eq!(res.gpu.len(), 2);
        assert_eq!(res.get(0, ResKind::Wire), res.get(1, ResKind::Wire));
        assert_eq!(res.get(0, ResKind::Pcie), res.get(1, ResKind::Pcie));
        assert_ne!(res.get(0, ResKind::GpuReduce), res.get(1, ResKind::GpuReduce));
        let mut g = CommGraph::default();
        for r in 0..2 {
            g.push_node(
                r,
                0,
                vec![CommOp::fixed(ResKind::Wire, 10.0), CommOp::fixed(ResKind::GpuReduce, 5.0)],
                Vec::new(),
            );
        }
        let (end, run) = {
            let run = execute(&mut e, &g, res.mapper(), Box::new(|_| {}));
            let end = e.run();
            let out = run.borrow().clone();
            (end, out)
        };
        // rank 0 wire 0-10, gpu 10-15; rank 1 wire queues 10-20, gpu 20-25
        assert_eq!(run.finish, vec![SimTime::from_us(15.0), SimTime::from_us(25.0)]);
        assert_eq!(end, SimTime::from_us(25.0));
    }

    #[test]
    fn second_rail_splits_the_node_nic() {
        // same two-rank node, 2 rails: each rank gets its own port, the
        // wire ops run in parallel again
        let mut e = Engine::new();
        let res = GraphResources::install_placed(&mut e, 2, Placement::new(2, 2));
        assert_eq!(res.wire.len(), 2);
        assert_ne!(res.get(0, ResKind::Wire), res.get(1, ResKind::Wire));
        let mut g = CommGraph::default();
        for r in 0..2 {
            g.push_node(r, 0, vec![CommOp::fixed(ResKind::Wire, 10.0)], Vec::new());
        }
        let run = execute(&mut e, &g, res.mapper(), Box::new(|_| {}));
        let end = e.run();
        assert_eq!(end, SimTime::from_us(10.0));
        assert_eq!(
            run.borrow().finish,
            vec![SimTime::from_us(10.0), SimTime::from_us(10.0)]
        );
    }

    #[test]
    fn placed_ring_rekind_intra_hops_onto_pcie() {
        // p=4 in 2-GPU nodes: odd ranks receive from their on-node
        // neighbour — those hops re-kind to Pcie and scale by `local`;
        // even ranks' hops stay on the wire, untouched.
        let steps = wire_steps(1, 10.0);
        let place = Placement::new(2, 1);
        let g = ring_graph_placed(4, &steps, place, 0.5);
        assert_eq!(g.len(), 4);
        for node in &g.nodes {
            let op = node.ops[0];
            if node.rank % 2 == 1 {
                assert_eq!(op.kind, ResKind::Pcie, "rank {} hop should be local", node.rank);
                assert!((op.us - 5.0).abs() < 1e-12);
            } else {
                assert_eq!(op.kind, ResKind::Wire, "rank {} hop should cross", node.rank);
                assert!((op.us - 10.0).abs() < 1e-12);
            }
        }
        // trivial placement reproduces the unplaced builder bit-for-bit,
        // whatever the local factor
        let a = ring_graph(4, &steps);
        let b = ring_graph_placed(4, &steps, Placement::one_per_node(), 0.25);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.nodes.iter().zip(&b.nodes) {
            assert_eq!(x.rank, y.rank);
            assert_eq!(x.deps, y.deps);
            assert_eq!(x.ops.len(), y.ops.len());
            for (ox, oy) in x.ops.iter().zip(&y.ops) {
                assert_eq!(ox.kind, oy.kind);
                assert_eq!(ox.us.to_bits(), oy.us.to_bits());
            }
        }
    }

    #[test]
    fn sharing_wire_handles_different_rank_counts() {
        // job A spans 2 nodes, job B spans 4: the first two nodes' ports
        // are shared, B's extra nodes get private ports (the old code
        // sized B's whole bundle off A and wrapped B's high ranks onto
        // A's ports)
        let mut e = Engine::new();
        let place = Placement::new(2, 1);
        let a = GraphResources::install_placed(&mut e, 4, place);
        let b = GraphResources::sharing_wire(&mut e, 8, &a);
        assert_eq!(b.ranks(), 8);
        assert_eq!(b.wire.len(), 4);
        assert_eq!(b.get(0, ResKind::Wire), a.get(0, ResKind::Wire));
        assert_eq!(b.get(3, ResKind::Wire), a.get(3, ResKind::Wire));
        // beyond A's span: private ports, and B's own non-wire resources
        assert!(a.wire.iter().all(|&w| w != b.get(6, ResKind::Wire)));
        assert_ne!(b.get(0, ResKind::GpuReduce), a.get(0, ResKind::GpuReduce));
        // the smaller-job direction shares only the overlap
        let c = GraphResources::sharing_wire(&mut e, 2, &a);
        assert_eq!(c.wire.len(), 1);
        assert_eq!(c.get(1, ResKind::Wire), a.get(0, ResKind::Wire));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn get_rejects_out_of_range_ranks() {
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, 4);
        let _ = res.get(4, ResKind::Wire);
    }

    #[test]
    fn template_cache_distinguishes_placements() {
        // same (algo, world, step costs), three layouts: three distinct
        // keys, three distinct templates — and the dense timelines
        // actually differ from the trivial one
        let cache = TemplateCache::default();
        let steps = wire_steps(6, 10.0);
        let sig = crate::comm::commop::steps_sig(&steps);
        let trivial = cache.get_or_build(TemplateKey::allreduce(Algo::Ring, 4, sig.clone()), || {
            ring_graph(4, &steps)
        });
        let dense = cache.get_or_build(
            TemplateKey::allreduce_placed(Algo::Ring, 4, Placement::new(2, 1), sig.clone()),
            || ring_graph_placed(4, &steps, Placement::new(2, 1), 3.0),
        );
        let railed = cache.get_or_build(
            TemplateKey::allreduce_placed(Algo::Ring, 4, Placement::new(2, 2), sig.clone()),
            || ring_graph_placed(4, &steps, Placement::new(2, 2), 3.0),
        );
        assert_eq!(cache.len(), 3, "placements must not alias in the cache");
        assert!(!Arc::ptr_eq(&trivial, &dense));
        assert!(!Arc::ptr_eq(&dense, &railed));
        // distinct timelines: intra hops at 3x make the dense chains
        // strictly longer than the trivial 6 × 10us serialization
        let run = |t: &GraphTemplate, place: Placement| {
            let mut e = Engine::new();
            let res = GraphResources::install_placed(&mut e, 4, place);
            t.execute(&mut e, res.mapper(), &GraphOverlay::neutral(), Box::new(|_| {}));
            e.run()
        };
        let end_trivial = run(&trivial, Placement::one_per_node());
        let end_dense = run(&dense, Placement::new(2, 1));
        assert_eq!(end_trivial, SimTime::from_us(60.0));
        assert!(end_dense > end_trivial, "{end_dense} vs {end_trivial}");
        // warm-vs-cold under placement: the same key replays the same
        // pointer and the same timeline
        let warm = cache.get_or_build(
            TemplateKey::allreduce_placed(Algo::Ring, 4, Placement::new(2, 1), sig),
            || panic!("placement key must hit the cache"),
        );
        assert!(Arc::ptr_eq(&dense, &warm));
        assert_eq!(run(&warm, Placement::new(2, 1)), end_dense);
    }

    fn mixed_steps(count: usize) -> Vec<StepCost> {
        (0..count)
            .map(|i| StepCost {
                cost: CostBreakdown {
                    wire_us: 6.0 + i as f64 * 0.5,
                    reduce_us: 1.25,
                    launch_us: 0.5,
                    sw_us: 0.75,
                    ..Default::default()
                },
                gpu_reduce: true,
            })
            .collect()
    }

    fn run_sym(t: &SymTemplate, ranks: usize, ov: &GraphOverlay) -> (SimTime, GraphRun) {
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, ranks);
        let run = t.execute(&mut e, &res, ov, true, Box::new(|_| {})).expect("recording run");
        let end = e.run();
        let out = run.borrow().clone();
        (end, out)
    }

    #[test]
    fn sym_ring_plan_replays_full_template_times() {
        // the §Scale pin at unit level: a shared rank-relative ring plan
        // executes bit-identically (per-node start/finish and end) to the
        // materialized per-rank template, neutral and perturbed alike
        let p = 5;
        let steps = mixed_steps(2 * (p - 1));
        let full = GraphTemplate::new(ring_graph(p, &steps));
        let plan = sym_allreduce_plan(Algo::Ring, p, &steps, Placement::one_per_node())
            .expect("trivial ring is symmetric");
        assert_eq!(plan.world(), p);
        assert_eq!(plan.node_count(), full.graph().len());
        assert!(plan.approx_bytes() < full.approx_bytes());

        let (end_f, run_f) = run_template(&full, p, &GraphOverlay::neutral());
        let (end_s, run_s) = run_sym(&plan, p, &GraphOverlay::neutral());
        assert_eq!(end_f, end_s);
        assert_eq!(run_f.start, run_s.start);
        assert_eq!(run_f.finish, run_s.finish);

        let mut ov = GraphOverlay::neutral();
        ov.scale_global(1.25);
        ov.scale_rank(p, 1, 1.7);
        ov.scale_rank_gpu(p, 3, 2.5);
        ov.set_lead(|rank, step| if (rank + step as usize) % 3 == 0 { 1.5 } else { 0.0 });
        let (end_f, run_f) = run_template(&full, p, &ov);
        let (end_s, run_s) = run_sym(&plan, p, &ov);
        assert_eq!(end_f, end_s, "perturbed sym replay diverged");
        assert_eq!(run_f.start, run_s.start);
        assert_eq!(run_f.finish, run_s.finish);
    }

    #[test]
    fn sym_rhd_plan_replays_full_template_times() {
        for p in [2usize, 4, 8, 16] {
            let steps = mixed_steps(2 * p.trailing_zeros() as usize);
            let full = GraphTemplate::new(rhd_graph(p, &steps));
            let plan = sym_allreduce_plan(Algo::Rhd, p, &steps, Placement::one_per_node())
                .expect("pow2 rhd is symmetric");
            let (end_f, run_f) = run_template(&full, p, &GraphOverlay::neutral());
            let (end_s, run_s) = run_sym(&plan, p, &GraphOverlay::neutral());
            assert_eq!(end_f, end_s, "rhd p={p}");
            assert_eq!(run_f.finish, run_s.finish, "rhd p={p}");
        }
    }

    #[test]
    fn sym_plan_refuses_asymmetric_shapes() {
        let steps = mixed_steps(6);
        // dense placements re-kind hops per rank
        assert!(sym_allreduce_plan(Algo::Ring, 4, &steps, Placement::new(2, 1)).is_none());
        // non-power-of-two rhd folds remainder ranks
        assert!(sym_allreduce_plan(Algo::Rhd, 6, &steps, Placement::one_per_node()).is_none());
        // the tree's pair work is one-sided
        assert!(sym_allreduce_plan(Algo::Tree, 4, &steps, Placement::one_per_node()).is_none());
        // degenerate worlds
        assert!(sym_allreduce_plan(Algo::Ring, 1, &steps, Placement::one_per_node()).is_none());
    }

    #[test]
    fn parallel_ring_build_matches_serial() {
        // the closed-form range builder (what the scoped threads run) must
        // reproduce the sequential scan node-for-node, and the threaded
        // merge must keep index order
        let p = 8;
        let steps = mixed_steps(2 * (p - 1));
        let place = Placement::one_per_node();
        let serial = ring_graph_placed(p, &steps, place, 1.0);
        let ranged = ring_nodes_range(p, &steps, &place, 1.0, 0, p * steps.len());
        let merged = par_build_nodes(p * steps.len(), |lo, hi| {
            ring_nodes_range(p, &steps, &place, 1.0, lo, hi)
        });
        for nodes in [&ranged, &merged] {
            assert_eq!(nodes.len(), serial.nodes.len());
            for (a, b) in serial.nodes.iter().zip(nodes.iter()) {
                assert_eq!(a.rank, b.rank);
                assert_eq!(a.step, b.step);
                assert_eq!(a.deps, b.deps);
                assert_eq!(a.ops.len(), b.ops.len());
                for (x, y) in a.ops.iter().zip(&b.ops) {
                    assert_eq!(x.kind, y.kind);
                    assert_eq!(x.us.to_bits(), y.us.to_bits());
                }
            }
        }
    }

    #[test]
    fn parallel_rhd_build_matches_serial() {
        let p = 16;
        let steps = mixed_steps(2 * p.trailing_zeros() as usize);
        let place = Placement::one_per_node();
        let serial = rhd_graph_placed(p, &steps, place, 1.0);
        let masks = rhd_mask_sequence(p);
        assert_eq!(masks.len(), steps.len());
        let ranged = rhd_nodes_range(p, &steps, &place, 1.0, &masks, 0, p * steps.len());
        assert_eq!(ranged.len(), serial.nodes.len());
        for (a, b) in serial.nodes.iter().zip(&ranged) {
            assert_eq!((a.rank, a.step, &a.deps), (b.rank, b.step, &b.deps));
            for (x, y) in a.ops.iter().zip(&b.ops) {
                assert_eq!(x.us.to_bits(), y.us.to_bits());
            }
        }
    }

    #[test]
    fn sym_cache_is_disjoint_from_full_templates() {
        let cache = TemplateCache::default();
        let steps = mixed_steps(6);
        let sig = crate::comm::commop::steps_sig(&steps);
        let key = TemplateKey::allreduce(Algo::Ring, 4, sig);
        let full = cache.get_or_build(key.clone(), || ring_graph(4, &steps));
        let plan = cache.get_or_build_sym(key.clone(), || {
            sym_allreduce_plan(Algo::Ring, 4, &steps, Placement::one_per_node()).unwrap()
        });
        assert_eq!(cache.len(), 2, "full and sym entries of one key coexist");
        let warm = cache.get_or_build_sym(key, || panic!("sym key must hit"));
        assert!(Arc::ptr_eq(&plan, &warm));
        assert!(plan.approx_bytes() < full.approx_bytes());
    }
}
