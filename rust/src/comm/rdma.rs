//! RDMA zero-copy tensor transport — the "RPC considered harmful"
//! competitor the PS family was missing: one-sided RDMA writes carry the
//! tensor payload directly between registered buffers, so there is **no
//! protobuf encode/decode**, no request/response RPC pair, and — when
//! the fabric has GPUDirect RDMA — **no host staging** either (the NIC
//! DMAs GPU memory).  Setup/administration stays on gRPC exactly like
//! the verbs contrib; only the tensor path changes.
//!
//! The registration (pin) cost is amortized the same way the paper's
//! pointer cache amortizes `cuPointerGetAttribute` (§V-B): buffers are
//! registered once at allocation (the `Intercept` discipline in
//! [`crate::comm::ptrcache`]), so the steady-state per-transfer cost is
//! a registration-cache probe, not a pin syscall.  [`RdmaTransport::
//! cold_cost`] exposes the unamortized first-touch path against the
//! simulated CUDA driver for contrast.

use crate::cluster::{Fabric, Link};
use crate::comm::ptrcache::{CacheMode, CudaDriverSim, PointerCache};
use crate::comm::CostBreakdown;
use crate::sim::SimTime;

#[derive(Debug, Clone)]
pub struct RdmaTransport {
    pub link: Link,
    pub pcie: Link,
    /// GPUDirect RDMA: the NIC reads/writes GPU memory directly, so the
    /// host-staging copies disappear from the tensor path entirely.
    pub gdr: bool,
    /// Posting one one-sided RDMA write work request, µs.  Cheaper than
    /// the verbs two-sided path (no receive matching at the target, no
    /// completion rendezvous on the critical path).
    pub post_us: f64,
    /// Steady-state registration-cache probe per transfer, µs — the
    /// warm `Intercept`-mode hit cost, i.e. pin/registration amortized
    /// across iterations rather than paid per message.
    pub reg_probe_us: f64,
}

impl RdmaTransport {
    pub fn new(fabric: &Fabric) -> Self {
        RdmaTransport {
            link: fabric.inter,
            pcie: fabric.pcie,
            gdr: fabric.gdr,
            post_us: 1.0,
            reg_probe_us: PointerCache::new(CacheMode::Intercept).hit_cost_us,
        }
    }

    /// One tensor moved GPU→GPU as a one-sided RDMA write: work-request
    /// post + warm registration probe, pinned-bounce-buffer staging only
    /// when the fabric lacks GDR, then the wire.  No encode, no request
    /// leg — zero-copy semantics.
    pub fn tensor_cost(&self, bytes: usize) -> CostBreakdown {
        let mut c = CostBreakdown { sw_us: self.post_us, ..Default::default() };
        c.driver_us = self.reg_probe_us;
        if !self.gdr {
            // pinned (pre-registered) bounce buffers: full PCIe
            // efficiency, same as the verbs pinned path
            c.staging_us = 2.0 * (self.pcie.alpha_us + self.pcie.wire_us(bytes));
        }
        c.wire_us = self.link.alpha_us + self.link.wire_us(bytes);
        c
    }

    pub fn tensor_time(&self, bytes: usize) -> SimTime {
        self.tensor_cost(bytes).total()
    }

    /// The unamortized first-touch transfer: the buffer is not in the
    /// registration cache yet, so the transport pays a driver attribute
    /// query plus a memory-registration pin (~µs per MB of pages)
    /// before the write posts.  The steady state [`Self::tensor_cost`]
    /// never pays this — that gap is what the ptrcache-style
    /// amortization buys.
    pub fn cold_cost(&self, bytes: usize, driver: &mut CudaDriverSim) -> CostBreakdown {
        let mut c = self.tensor_cost(bytes);
        let ptr = driver.cu_malloc(bytes as u64);
        let (_, query_us) = driver.query(ptr);
        // pinning walks page tables: ~1µs per MB of registered pages
        c.driver_us += query_us + bytes as f64 / (1 << 20) as f64;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fabric;
    use crate::comm::grpc::GrpcTransport;
    use crate::comm::verbs::VerbsTransport;

    #[test]
    fn rdma_beats_verbs_beats_grpc() {
        // the transport-level half of the Figure-3 extension: the
        // zero-copy one-sided path undercuts the two-sided verbs path,
        // which undercuts gRPC, on every message size
        for f in [Fabric::ib_edr_gdr(), Fabric::aries()] {
            let r = RdmaTransport::new(&f);
            let v = VerbsTransport::new(&f);
            let g = GrpcTransport::new(f.tcp, f.pcie);
            for bytes in [1 << 12, 1 << 20, 16 << 20] {
                let (rt, vt, gt) = (
                    r.tensor_time(bytes).as_us(),
                    v.tensor_time(bytes).as_us(),
                    g.tensor_pull_time(bytes).as_us(),
                );
                assert!(rt < vt, "rdma {rt} !< verbs {vt} at {bytes}B on {}", f.inter.name);
                assert!(vt < gt, "verbs {vt} !< grpc {gt} at {bytes}B on {}", f.inter.name);
            }
        }
    }

    #[test]
    fn gdr_removes_staging_entirely() {
        let f = Fabric::ib_edr_gdr();
        assert!(f.gdr);
        let r = RdmaTransport::new(&f);
        let c = r.tensor_cost(16 << 20);
        assert_eq!(c.staging_us, 0.0, "GDR path must not stage through the host");
        // a GDR-less fabric stages through pinned bounce buffers
        let a = Fabric::aries();
        assert!(!a.gdr);
        assert!(RdmaTransport::new(&a).tensor_cost(16 << 20).staging_us > 0.0);
    }

    #[test]
    fn no_encode_cost_and_flat_software_overhead() {
        // zero-copy means the software side does NOT scale with payload
        // (gRPC's protobuf encode does)
        let f = Fabric::ib_edr_gdr();
        let r = RdmaTransport::new(&f);
        let small = r.tensor_cost(1 << 10);
        let big = r.tensor_cost(64 << 20);
        assert_eq!(small.sw_us, big.sw_us, "one-sided post cost is size-independent");
        let g = GrpcTransport::new(f.tcp, f.pcie);
        assert!(g.tensor_rpc_cost(64 << 20).sw_us > g.tensor_rpc_cost(1 << 10).sw_us);
    }

    #[test]
    fn registration_amortization_matters() {
        // warm transfers pay the cache probe; the cold first touch pays
        // the driver query + pin, which dwarfs it
        let f = Fabric::ib_edr_gdr();
        let r = RdmaTransport::new(&f);
        let mut d = CudaDriverSim::new(10.0);
        let warm = r.tensor_cost(4 << 20).driver_us;
        let cold = r.cold_cost(4 << 20, &mut d).driver_us;
        assert!(warm < 0.1, "warm probe should be a hash lookup: {warm}us");
        assert!(cold > 10.0 * warm, "cold pin {cold}us should dwarf warm probe {warm}us");
        assert_eq!(d.queries, 1, "cold path queried the driver once");
    }
}
