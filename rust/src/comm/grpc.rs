//! gRPC transport + tensor-table semantics (§III-A).
//!
//! TensorFlow's parameter-server model moves tensors with a **pull**
//! protocol: the producer parks a computed tensor in a table; the consumer
//! sends a request RPC and the producer answers with the tensor payload.
//! gRPC offers no CUDA-aware path, so every GPU tensor is staged to host
//! memory and protobuf-encoded before it touches the wire (and decoded +
//! staged back up on the other side).
//!
//! Both halves are implemented here: the *cost model* (`rpc_time`,
//! `tensor_pull_time`) and the *semantics* (`TensorTable`, a real
//! pending-request table exercised by the PS strategy and its tests).

use std::collections::HashMap;

use crate::cluster::Link;
use crate::comm::CostBreakdown;
use crate::sim::SimTime;

/// gRPC channel characteristics over a given TCP-capable link.
#[derive(Debug, Clone)]
pub struct GrpcTransport {
    /// The TCP path (IPoIB on IB clusters — §III-A notes gRPC can ride
    /// IPoIB transparently).
    pub link: Link,
    /// Per-RPC software overhead, µs (HTTP/2 framing, dispatch, threads).
    pub rpc_overhead_us: f64,
    /// protobuf encode/decode throughput, GB/s.
    pub encode_gbs: f64,
    /// Host↔device staging link.
    pub pcie: Link,
}

impl GrpcTransport {
    pub fn new(link: Link, pcie: Link) -> Self {
        GrpcTransport { link, rpc_overhead_us: 90.0, encode_gbs: 1.0, pcie }
    }

    /// Cost of one one-way RPC carrying `bytes` of tensor payload where
    /// the payload originates in GPU memory and lands in GPU memory.
    pub fn tensor_rpc_cost(&self, bytes: usize) -> CostBreakdown {
        let mut c = CostBreakdown::default();
        c.sw_us = self.rpc_overhead_us
            // encode at the producer + decode at the consumer
            + 2.0 * bytes as f64 / (self.encode_gbs * 1e3);
        // D2H at producer, H2D at consumer
        c.staging_us = 2.0 * (self.pcie.alpha_us + self.pcie.wire_us(bytes));
        c.wire_us = self.link.alpha_us + self.link.wire_us(bytes);
        c
    }

    /// Full pull-model round trip: tiny request RPC + tensor response.
    pub fn tensor_pull_cost(&self, bytes: usize) -> CostBreakdown {
        let mut c = self.tensor_rpc_cost(bytes);
        // the request leg: no payload, no staging
        c.sw_us += self.rpc_overhead_us;
        c.wire_us += self.link.alpha_us;
        c
    }

    pub fn tensor_pull_time(&self, bytes: usize) -> SimTime {
        self.tensor_pull_cost(bytes).total()
    }
}

/// Key identifying one tensor in flight (step, producer, tensor id).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorKey {
    pub step: u64,
    pub producer: usize,
    pub tensor: usize,
}

/// The producer-side waiting table of TF's rendezvous protocol (§III-A):
/// tensors wait for requests, requests wait for tensors.
#[derive(Debug, Default)]
pub struct TensorTable {
    ready: HashMap<TensorKey, Vec<f32>>,
    pending: HashMap<TensorKey, Vec<usize>>, // consumers waiting
    pub served: u64,
}

/// What happened when a tensor or request arrived.
#[derive(Debug, PartialEq)]
pub enum TableEvent {
    /// Tensor parked; nobody asked yet.
    Parked,
    /// Tensor parked OVER an unconsumed tensor for the same key, whose
    /// payload is dropped — a producer re-publishing before any consumer
    /// pulled (a missed step or a duplicate send).  The old payload is
    /// gone either way; this event makes the loss observable instead of
    /// silent.
    Replaced,
    /// Request matched instantly; payload returned to these consumers.
    Served(Vec<usize>),
    /// Request queued; producer hasn't computed the tensor yet.
    Queued,
}

impl TensorTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Producer publishes a tensor.  If requests are pending they are all
    /// served immediately and the tensor is removed (TF step 3); otherwise
    /// it parks (TF steps 1–2).  Re-publishing a key whose tensor is
    /// still parked replaces the unconsumed payload and says so
    /// ([`TableEvent::Replaced`]) — the first payload used to vanish
    /// silently.
    pub fn publish(&mut self, key: TensorKey, data: Vec<f32>) -> TableEvent {
        if let Some(waiters) = self.pending.remove(&key) {
            self.served += waiters.len() as u64;
            TableEvent::Served(waiters)
        } else if self.ready.insert(key, data).is_some() {
            TableEvent::Replaced
        } else {
            TableEvent::Parked
        }
    }

    /// Consumer requests a tensor.  Served immediately if parked (and the
    /// entry is removed), queued otherwise.
    pub fn request(&mut self, key: TensorKey, consumer: usize) -> (TableEvent, Option<Vec<f32>>) {
        if let Some(data) = self.ready.remove(&key) {
            self.served += 1;
            (TableEvent::Served(vec![consumer]), Some(data))
        } else {
            self.pending.entry(key).or_default().push(consumer);
            (TableEvent::Queued, None)
        }
    }

    pub fn parked(&self) -> usize {
        self.ready.len()
    }

    pub fn waiting(&self) -> usize {
        self.pending.values().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fabric;

    fn transport() -> GrpcTransport {
        let f = Fabric::ib_edr_gdr();
        GrpcTransport::new(f.tcp, f.pcie)
    }

    #[test]
    fn pull_cost_components() {
        let t = transport();
        let c = t.tensor_pull_cost(1 << 20);
        assert!(c.sw_us > 2.0 * t.rpc_overhead_us - 1e-9, "two RPC dispatches");
        assert!(c.staging_us > 0.0, "gRPC always stages GPU tensors");
        assert!(c.wire_us > 0.0);
        // encode cost scales with size
        let c2 = t.tensor_pull_cost(2 << 20);
        assert!(c2.sw_us > c.sw_us);
    }

    #[test]
    fn grpc_slower_than_verbs_path() {
        // the whole reason for gRPC+X: IPoIB + protobuf + staging ≫ verbs
        let t = transport();
        let verbs = Link::ib_edr();
        let n = 4 << 20;
        assert!(t.tensor_pull_time(n).as_us() > 2.0 * verbs.transfer(n).as_us());
    }

    #[test]
    fn table_pull_model_tensor_first() {
        let mut tab = TensorTable::new();
        let k = TensorKey { step: 1, producer: 0, tensor: 7 };
        assert_eq!(tab.publish(k, vec![1.0, 2.0]), TableEvent::Parked);
        assert_eq!(tab.parked(), 1);
        let (ev, data) = tab.request(k, 3);
        assert_eq!(ev, TableEvent::Served(vec![3]));
        assert_eq!(data.unwrap(), vec![1.0, 2.0]);
        assert_eq!(tab.parked(), 0, "served tensors leave the table");
    }

    #[test]
    fn table_pull_model_request_first() {
        let mut tab = TensorTable::new();
        let k = TensorKey { step: 2, producer: 1, tensor: 0 };
        let (ev, data) = tab.request(k, 5);
        assert_eq!(ev, TableEvent::Queued);
        assert!(data.is_none());
        assert_eq!(tab.waiting(), 1);
        // multiple waiters accumulate
        tab.request(k, 6);
        assert_eq!(tab.waiting(), 2);
        match tab.publish(k, vec![9.0]) {
            TableEvent::Served(w) => assert_eq!(w, vec![5, 6]),
            other => panic!("expected Served, got {other:?}"),
        }
        assert_eq!(tab.waiting(), 0);
        assert_eq!(tab.served, 2);
    }

    #[test]
    fn double_publish_surfaces_the_replacement() {
        let mut tab = TensorTable::new();
        let k = TensorKey { step: 3, producer: 2, tensor: 1 };
        assert_eq!(tab.publish(k, vec![1.0]), TableEvent::Parked);
        // same key again before any consumer pulled: the first payload
        // is dropped, and the table now says so instead of parking again
        assert_eq!(tab.publish(k, vec![2.0]), TableEvent::Replaced);
        assert_eq!(tab.parked(), 1, "still exactly one parked tensor for the key");
        // the consumer gets the LATEST payload
        let (ev, data) = tab.request(k, 4);
        assert_eq!(ev, TableEvent::Served(vec![4]));
        assert_eq!(data.unwrap(), vec![2.0]);
        // once consumed, the next publish parks cleanly again
        assert_eq!(tab.publish(k, vec![3.0]), TableEvent::Parked);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let mut tab = TensorTable::new();
        let k1 = TensorKey { step: 1, producer: 0, tensor: 0 };
        let k2 = TensorKey { step: 1, producer: 0, tensor: 1 };
        tab.publish(k1, vec![1.0]);
        let (ev, _) = tab.request(k2, 0);
        assert_eq!(ev, TableEvent::Queued);
        assert_eq!(tab.parked(), 1);
        assert_eq!(tab.waiting(), 1);
    }
}
