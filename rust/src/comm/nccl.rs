//! NVIDIA NCCL 2.x model (§II-B): ring-RSA allreduce driven by CUDA
//! kernels, IB verbs inter-node.  Signature behaviour the model captures:
//!
//!  * excellent large-message bandwidth (GPU-kernel reductions, GDR),
//!    though the era's NCCL2 ring achieved somewhat lower effective wire
//!    bandwidth than MVAPICH2-GDR's pipelined RHD (the −29% headline);
//!  * poor small-message latency: 2(p−1) kernel-launch-paced ring steps
//!    (the 17× gap of Figure 6 at 8 bytes);
//!  * hard dependency on IB verbs — unavailable on Cray Aries, so
//!    Horovod-NCCL cannot run on Piz Daint (§VI-D).

use crate::cluster::{ClusterSpec, Link};
use crate::comm::allreduce::{ring_allreduce, AllreduceCtx, AllreduceReport, ReducePlace, TransportMode};
use crate::comm::ptrcache::CacheMode;

/// NCCL's effective inter-node link: verbs RC transport with the ring
/// protocol's chunking overhead folded into β.
pub const NCCL_LINK: Link = Link::new("NCCL-IB", 3.0, 7.5);

#[derive(Debug, Clone)]
pub struct NcclWorld {
    pub cluster: ClusterSpec,
}

#[derive(Debug)]
pub struct NcclUnsupported {
    pub cluster: &'static str,
}

impl std::fmt::Display for NcclUnsupported {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "NCCL2 requires IB verbs for inter-node communication; {} has none (Aries)",
            self.cluster
        )
    }
}

impl std::error::Error for NcclUnsupported {}

impl NcclWorld {
    /// Fails on fabrics without IB verbs — the paper could not run
    /// Horovod-NCCL on Piz Daint for exactly this reason.
    pub fn new(cluster: ClusterSpec) -> Result<Self, NcclUnsupported> {
        if !cluster.fabric.ib_verbs {
            return Err(NcclUnsupported { cluster: cluster.name });
        }
        Ok(NcclWorld { cluster })
    }

    fn ctx(&self) -> AllreduceCtx {
        let c = &self.cluster;
        let mut ctx = AllreduceCtx::new(
            c.fabric.clone(),
            c.gpu.clone(),
            TransportMode::Gdr,
            ReducePlace::Gpu,
            // NCCL owns its buffers; no per-call driver queries.
            CacheMode::Intercept,
            c.driver_query_us,
        );
        ctx.wire = NCCL_LINK;
        ctx.attrs_per_buffer = 0;
        // every ring step is a CUDA-kernel-paced copy
        ctx.p2p_sw_us = c.gpu.launch_us;
        ctx
    }

    /// ncclAllReduce over real per-rank buffers (always ring).
    pub fn allreduce(&self, bufs: &mut [Vec<f32>]) -> AllreduceReport {
        let mut ctx = self.ctx();
        let mut r = ring_allreduce(bufs, &mut ctx);
        r.algo = "nccl-ring";
        r
    }

    /// Latency microbench primitive (Figures 4 and 6) — shadow cost path.
    pub fn allreduce_latency(&self, p: usize, bytes: usize) -> AllreduceReport {
        self.allreduce_schedule(p, bytes, 1.0).0
    }

    /// The NCCL ring as a replayable `CommOp` schedule (plus its report);
    /// `wire_derate` models scenario-level fabric sharing (1.0 = pristine).
    pub fn allreduce_schedule(
        &self,
        p: usize,
        bytes: usize,
        wire_derate: f64,
    ) -> (AllreduceReport, crate::comm::commop::CommSchedule) {
        let (_, report, steps) = self.allreduce_steps(p, bytes, wire_derate);
        (report, crate::comm::commop::CommSchedule::from_steps(&steps))
    }

    /// Per-step cost sequence of the NCCL ring (always `Algo::Ring`) —
    /// the `CommGraph` builders' input.
    pub fn allreduce_steps(
        &self,
        p: usize,
        bytes: usize,
        wire_derate: f64,
    ) -> (
        crate::comm::allreduce::Algo,
        AllreduceReport,
        Vec<crate::comm::commop::StepCost>,
    ) {
        let n = (bytes / 4).max(1);
        let mut ctx = self.ctx();
        ctx.wire.beta_gbs /= self.cluster.fabric.contention_factor(p) * wire_derate;
        let (mut r, steps) = crate::comm::allreduce::shadow_steps(
            crate::comm::allreduce::Algo::Ring,
            p,
            n,
            &mut ctx,
        );
        r.algo = "nccl-ring";
        (crate::comm::allreduce::Algo::Ring, r, steps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::allreduce::{max_abs_err, serial_oracle};
    use crate::comm::mpi::{MpiFlavor, MpiWorld};

    #[test]
    fn unavailable_on_aries() {
        assert!(NcclWorld::new(presets::piz_daint()).is_err());
        assert!(NcclWorld::new(presets::ri2()).is_ok());
    }

    #[test]
    fn reduces_correctly() {
        let w = NcclWorld::new(presets::ri2()).unwrap();
        let mut rng = crate::util::prng::Rng::new(5);
        let mut bufs: Vec<Vec<f32>> = (0..8).map(|_| rng.f32_vec(5000)).collect();
        let oracle = serial_oracle(&bufs);
        w.allreduce(&mut bufs);
        assert!(max_abs_err(&bufs, &oracle) < 1e-3);
    }

    #[test]
    fn nccl_beats_stock_mpi_at_dl_message_sizes() {
        // Figure 4's claim is about DL-relevant (large) sizes; at 8 bytes
        // the paper's own ratios (17× vs 4.1× against MPI-Opt) imply stock
        // MVAPICH2 actually beats NCCL2.  Both regimes are asserted.
        let nccl = NcclWorld::new(presets::ri2()).unwrap();
        let mpi = MpiWorld::new(MpiFlavor::Mvapich2, presets::ri2());
        for bytes in [1 << 20, 16 << 20, 64 << 20] {
            let t_nccl = nccl.allreduce_latency(16, bytes).time.as_us();
            let t_mpi = mpi.allreduce_latency(16, bytes).time.as_us();
            assert!(
                t_nccl < t_mpi,
                "NCCL should beat stock MVAPICH2 at {bytes}B: {t_nccl} vs {t_mpi}"
            );
        }
        // tiny-message regime flips (launch-paced ring vs log-step tree)
        let t_nccl = nccl.allreduce_latency(16, 8).time.as_us();
        let t_mpi = mpi.allreduce_latency(16, 8).time.as_us();
        assert!(t_mpi < t_nccl, "stock MPI should win at 8B: {t_mpi} vs {t_nccl}");
    }

    #[test]
    fn small_message_latency_is_launch_paced() {
        // 16 ranks ⇒ 30 ring steps ⇒ hundreds of µs at 8 bytes.
        let w = NcclWorld::new(presets::ri2()).unwrap();
        let t = w.allreduce_latency(16, 8).time.as_us();
        assert!(t > 200.0, "NCCL 8B@16 should be launch-dominated, got {t}us");
    }

    #[test]
    fn opt_mpi_beats_nccl_small_and_matches_shape_large() {
        // The §V-C headline: 17× at 8B; ~1.4× (−29%) at 256MB on 16 GPUs.
        let nccl = NcclWorld::new(presets::ri2()).unwrap();
        let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());

        let r_small = nccl.allreduce_latency(16, 8).time.as_us()
            / opt.allreduce_latency(16, 8).time.as_us();
        assert!(r_small > 5.0, "expected ≥5× at 8B (paper: 17×), got {r_small:.1}×");

        let bytes = 256 << 20;
        let r_large = nccl.allreduce_latency(16, bytes).time.as_us()
            / opt.allreduce_latency(16, bytes).time.as_us();
        assert!(
            r_large > 1.15 && r_large < 1.9,
            "expected ~1.4× at 256MB (paper: 29% reduction), got {r_large:.2}×"
        );
    }
}
