//! MPI library personalities (§II-B, §III): algorithm selection +
//! transport/reduction configuration per flavor.  One `rhd`/`ring`/`tree`
//! code path serves every library; the flavor only changes the
//! `AllreduceCtx` — exactly the paper's framing that the *design choices*
//! (where to reduce, whether to cache pointers) explain the performance
//! gaps, not the algorithm skeleton.

use crate::cluster::ClusterSpec;
use crate::comm::allreduce::{
    rhd_allreduce, ring_allreduce, tree_allreduce, Algo, AllreduceCtx, AllreduceReport,
    ReducePlace, TransportMode,
};
use crate::comm::ptrcache::CacheMode;
use crate::comm::CostBreakdown;
use crate::sim::SimTime;

/// Which MPI implementation personality to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MpiFlavor {
    /// Stock MVAPICH2 2.x: CUDA-aware but host-staged transfers, CPU
    /// reductions, driver query on every call (the Figure 4 baseline).
    Mvapich2,
    /// MVAPICH2-GDR 2.3rc1 with the paper's optimizations: GDR transport,
    /// GPU-kernel reductions for large messages, intercept pointer cache.
    Mvapich2GdrOpt,
    /// Cray-MPICH 7.6 (Piz Daint): CUDA-aware over Aries, CPU reductions,
    /// no GDR, no IB verbs.
    CrayMpich,
    /// Plain MPICH: naive GPU support (always staged, CPU reduce).
    Mpich,
}

impl MpiFlavor {
    pub fn name(&self) -> &'static str {
        match self {
            MpiFlavor::Mvapich2 => "MVAPICH2",
            MpiFlavor::Mvapich2GdrOpt => "MVAPICH2-GDR-Opt",
            MpiFlavor::CrayMpich => "Cray-MPICH",
            MpiFlavor::Mpich => "MPICH",
        }
    }
}

/// Message-size threshold below which latency-optimal trees beat RSA.
pub const SMALL_MSG_BYTES: usize = 32 * 1024;

/// The optimized library switches to the GPU-kernel RSA path earlier: its
/// large-message path is cheap enough that the GDRCopy eager window only
/// pays off for truly tiny payloads.
pub const SMALL_MSG_BYTES_OPT: usize = 8 * 1024;

/// An MPI communicator over a cluster: the Allreduce entry point the
/// Horovod/Baidu strategies call.
#[derive(Debug, Clone)]
pub struct MpiWorld {
    pub flavor: MpiFlavor,
    pub cluster: ClusterSpec,
}

impl MpiWorld {
    pub fn new(flavor: MpiFlavor, cluster: ClusterSpec) -> Self {
        MpiWorld { flavor, cluster }
    }

    /// Build the execution context + algorithm for a message of `bytes`.
    pub fn plan(&self, bytes: usize) -> (Algo, AllreduceCtx) {
        let c = &self.cluster;
        let small = if self.flavor == MpiFlavor::Mvapich2GdrOpt {
            bytes <= SMALL_MSG_BYTES_OPT
        } else {
            bytes <= SMALL_MSG_BYTES
        };
        let (transport, reduce, cache) = match self.flavor {
            MpiFlavor::Mvapich2 => (
                TransportMode::Staged,
                ReducePlace::Cpu { gbs: 2.0 },
                CacheMode::None,
            ),
            MpiFlavor::Mvapich2GdrOpt => {
                if small {
                    // eager GDRCopy path + host reduce of tiny payloads
                    (TransportMode::Gdr, ReducePlace::Cpu { gbs: 6.0 }, CacheMode::Intercept)
                } else {
                    // §V-A: GPU-kernel reduction, GDR transport
                    (TransportMode::Gdr, ReducePlace::Gpu, CacheMode::Intercept)
                }
            }
            MpiFlavor::CrayMpich => (
                TransportMode::Staged,
                ReducePlace::Cpu { gbs: 2.5 },
                CacheMode::None,
            ),
            MpiFlavor::Mpich => (
                TransportMode::Staged,
                ReducePlace::Cpu { gbs: 2.0 },
                CacheMode::None,
            ),
        };
        let algo = if small { Algo::Tree } else { Algo::Rhd };
        let ctx = AllreduceCtx::new(
            c.fabric.clone(),
            c.gpu.clone(),
            transport,
            reduce,
            cache,
            c.driver_query_us,
        );
        (algo, ctx)
    }

    /// Allreduce over real per-rank buffers.
    pub fn allreduce(&self, bufs: &mut [Vec<f32>]) -> AllreduceReport {
        let bytes = bufs.first().map(|b| b.len() * 4).unwrap_or(0);
        let (algo, mut ctx) = self.plan(bytes);
        match algo {
            Algo::Tree => tree_allreduce(bufs, &mut ctx),
            Algo::Ring => ring_allreduce(bufs, &mut ctx),
            Algo::Rhd => rhd_allreduce(bufs, &mut ctx),
        }
    }

    /// Latency of an allreduce of `bytes` across `p` ranks — the
    /// micro-benchmark primitive behind Figures 4 and 6.  Uses the shadow
    /// cost path (pinned to the real-data implementations by
    /// `shadow::tests`) so 256MB × 128-rank points stay cheap.  Applies
    /// the fabric's at-scale contention factor to the wire.
    pub fn allreduce_latency(&self, p: usize, bytes: usize) -> AllreduceReport {
        self.allreduce_schedule(p, bytes, 1.0).0
    }

    /// The allreduce as a replayable `CommOp` schedule (plus its report).
    /// `wire_derate` further divides the wire bandwidth — scenario knob
    /// for co-running jobs / degraded fabrics (1.0 = pristine).
    pub fn allreduce_schedule(
        &self,
        p: usize,
        bytes: usize,
        wire_derate: f64,
    ) -> (AllreduceReport, crate::comm::commop::CommSchedule) {
        let (_, report, steps) = self.allreduce_steps(p, bytes, wire_derate);
        (report, crate::comm::commop::CommSchedule::from_steps(&steps))
    }

    /// The allreduce's per-step cost sequence plus the algorithm selected
    /// for this size — what the `CommGraph` builders consume (the
    /// serialized schedule above is the same steps concatenated).
    pub fn allreduce_steps(
        &self,
        p: usize,
        bytes: usize,
        wire_derate: f64,
    ) -> (Algo, AllreduceReport, Vec<crate::comm::commop::StepCost>) {
        let n = (bytes / 4).max(1);
        let (algo, mut ctx) = self.plan(bytes);
        ctx.wire.beta_gbs /= self.cluster.fabric.contention_factor(p) * wire_derate;
        let (report, steps) = crate::comm::allreduce::shadow_steps(algo, p, n, &mut ctx);
        (algo, report, steps)
    }

    /// CUDA-aware point-to-point send/recv cost (used by the Baidu ring
    /// built on MPI_Send/MPI_Irecv and the gRPC+MPI tensor offload).
    pub fn p2p_cost(&self, bytes: usize) -> CostBreakdown {
        let (_, mut ctx) = self.plan(bytes);
        ctx.register_ranks(2, bytes.max(4) as u64);
        let mut c = ctx.sendrecv_cost(bytes);
        c.driver_us = ctx.driver_cost_us(0);
        c
    }

    pub fn p2p_time(&self, bytes: usize) -> SimTime {
        self.p2p_cost(bytes).total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::allreduce::{max_abs_err, serial_oracle};

    fn bufs(p: usize, n: usize) -> Vec<Vec<f32>> {
        let mut rng = crate::util::prng::Rng::new(7);
        (0..p).map(|_| rng.f32_vec(n)).collect()
    }

    #[test]
    fn every_flavor_reduces_correctly() {
        for flavor in [
            MpiFlavor::Mvapich2,
            MpiFlavor::Mvapich2GdrOpt,
            MpiFlavor::CrayMpich,
            MpiFlavor::Mpich,
        ] {
            let w = MpiWorld::new(flavor, presets::ri2());
            for (p, n) in [(2, 4), (5, 1000), (16, 20000)] {
                let mut b = bufs(p, n);
                let oracle = serial_oracle(&b);
                w.allreduce(&mut b);
                let err = max_abs_err(&b, &oracle);
                assert!(err < 1e-3, "{}: err {err}", flavor.name());
            }
        }
    }

    #[test]
    fn algorithm_selection_by_size() {
        let w = MpiWorld::new(MpiFlavor::Mvapich2, presets::ri2());
        assert_eq!(w.plan(8).0, Algo::Tree);
        assert_eq!(w.plan(SMALL_MSG_BYTES).0, Algo::Tree);
        assert_eq!(w.plan(SMALL_MSG_BYTES + 1).0, Algo::Rhd);
        assert_eq!(w.plan(256 << 20).0, Algo::Rhd);
    }

    #[test]
    fn opt_beats_stock_small_messages() {
        // Fig 6 left panel: ~4× from the pointer cache + GDR eager path.
        let stock = MpiWorld::new(MpiFlavor::Mvapich2, presets::ri2());
        let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());
        let t_stock = stock.allreduce_latency(16, 8).time.as_us();
        let t_opt = opt.allreduce_latency(16, 8).time.as_us();
        let speedup = t_stock / t_opt;
        assert!(speedup > 2.5, "expected ≥2.5× at 8B, got {speedup:.2}× ({t_stock} vs {t_opt})");
    }

    #[test]
    fn opt_beats_stock_large_messages() {
        // Fig 6 right panel: GPU-kernel reduction vs CPU-staged, ~4–8×.
        let stock = MpiWorld::new(MpiFlavor::Mvapich2, presets::ri2());
        let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());
        let bytes = 64 << 20;
        let t_stock = stock.allreduce_latency(16, bytes).time.as_ms();
        let t_opt = opt.allreduce_latency(16, bytes).time.as_ms();
        let speedup = t_stock / t_opt;
        assert!(speedup > 3.0, "expected ≥3× at 64MB, got {speedup:.2}×");
    }

    #[test]
    fn p2p_cost_cuda_aware_vs_staged() {
        let stock = MpiWorld::new(MpiFlavor::Mvapich2, presets::ri2());
        let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());
        let n = 4 << 20;
        assert!(stock.p2p_time(n).as_us() > opt.p2p_time(n).as_us());
    }

    #[test]
    fn driver_queries_counted_only_without_cache() {
        let stock = MpiWorld::new(MpiFlavor::Mvapich2, presets::ri2());
        let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());
        let d_stock = stock.allreduce_latency(8, 8).cost.driver_us;
        let d_opt = opt.allreduce_latency(8, 8).cost.driver_us;
        assert!(d_stock > 10.0, "stock pays the driver per call, got {d_stock}us");
        assert!(
            d_opt < d_stock / 10.0,
            "cache should kill ≥90% of query time: {d_opt} vs {d_stock}"
        );
    }
}
