//! Communication substrates: the libraries the paper characterizes
//! (gRPC, MPI, Verbs, NCCL) and the one it contributes (the truly
//! CUDA-Aware `MPI_Allreduce` — allreduce/rhd.rs + ptrcache.rs).
//!
//! All collectives run over **real f32 buffers** (correctness is pinned to
//! a serial oracle by unit + property tests); the virtual clock rides
//! along with the data so the same call yields both the reduced tensor and
//! the modeled latency on the target fabric.

pub mod allreduce;
pub mod collectives;
pub mod commop;
pub mod fusion;
pub mod graph;
pub mod grpc;
pub mod mpi;
pub mod nccl;
pub mod ptrcache;
pub mod rdma;
pub mod verbs;

pub use commop::{
    replay, resolve_ops, steps_sig, CommOp, CommResources, CommSchedule, RelPin, ResKind, ResMap,
    ResourceUse, StepCost,
};
pub use graph::{
    allreduce_graph, ps_fanin_graph, ps_fanin_pulls, sym_allreduce_plan, CommGraph, GraphOverlay,
    GraphResources, GraphTemplate, NodeId, PeerRule, SymStep, SymTemplate, TemplateCache,
    TemplateKey,
};
pub use mpi::{MpiFlavor, MpiWorld};
pub use ptrcache::{BufKind, CacheMode, CudaDriverSim, PointerCache};

use crate::sim::SimTime;

/// Where the latency of a communication operation went — the breakdown the
/// paper's §V analysis reasons about.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CostBreakdown {
    /// Wire/link time (α + n/β terms).
    pub wire_us: f64,
    /// Host-staging copies (D2H/H2D over PCIe) for non-CUDA-aware paths.
    pub staging_us: f64,
    /// Reduction compute (CPU loop or GPU kernel).
    pub reduce_us: f64,
    /// CUDA driver pointer-attribute queries (what the pointer cache kills).
    pub driver_us: f64,
    /// Kernel-launch overheads (NCCL pays one per ring step).
    pub launch_us: f64,
    /// Software overhead (protobuf encode, RPC dispatch, negotiation).
    pub sw_us: f64,
}

impl CostBreakdown {
    pub fn total_us(&self) -> f64 {
        self.wire_us + self.staging_us + self.reduce_us + self.driver_us + self.launch_us + self.sw_us
    }

    pub fn total(&self) -> SimTime {
        SimTime::from_us(self.total_us())
    }

    pub fn add(&mut self, other: &CostBreakdown) {
        self.wire_us += other.wire_us;
        self.staging_us += other.staging_us;
        self.reduce_us += other.reduce_us;
        self.driver_us += other.driver_us;
        self.launch_us += other.launch_us;
        self.sw_us += other.sw_us;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_sums() {
        let mut a = CostBreakdown { wire_us: 1.0, staging_us: 2.0, ..Default::default() };
        let b = CostBreakdown { reduce_us: 3.0, driver_us: 4.0, launch_us: 5.0, sw_us: 6.0, ..Default::default() };
        a.add(&b);
        assert!((a.total_us() - 21.0).abs() < 1e-12);
        assert_eq!(a.total(), SimTime::from_us(21.0));
    }
}
