//! Horovod "Tensor Fusion" (§III-C2): pack many small gradient tensors
//! into one fusion buffer so the Allreduce pays one α instead of dozens.
//! The threshold is a runtime knob the paper tunes per platform; the
//! ablation bench sweeps it.
//!
//! Real packing: f32 payloads are copied into a contiguous buffer and
//! scattered back after the collective — pack/unpack is round-trip tested.

/// One packed buffer: which tensors it holds and where.
#[derive(Debug, Clone)]
pub struct FusionBuffer {
    /// (tensor id, offset, len) for each packed tensor.
    pub layout: Vec<(usize, usize, usize)>,
    pub data: Vec<f32>,
}

impl FusionBuffer {
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn tensor_ids(&self) -> Vec<usize> {
        self.layout.iter().map(|&(id, _, _)| id).collect()
    }
}

/// Greedily pack tensors (in arrival order, like Horovod's per-cycle
/// negotiation) into buffers of at most `threshold_bytes`.  A tensor
/// larger than the threshold gets a buffer of its own — fusion never
/// splits tensors.
pub fn fuse(tensors: &[(usize, &[f32])], threshold_bytes: usize) -> Vec<FusionBuffer> {
    let mut out = Vec::new();
    let mut cur = FusionBuffer { layout: Vec::new(), data: Vec::new() };
    for &(id, data) in tensors {
        let bytes = data.len() * 4;
        if !cur.data.is_empty() && cur.bytes() + bytes > threshold_bytes {
            out.push(std::mem::replace(&mut cur, FusionBuffer { layout: Vec::new(), data: Vec::new() }));
        }
        let off = cur.data.len();
        cur.layout.push((id, off, data.len()));
        cur.data.extend_from_slice(data);
    }
    if !cur.data.is_empty() || !cur.layout.is_empty() {
        out.push(cur);
    }
    out
}

/// Scatter a (reduced) fusion buffer back into per-tensor storage.
/// `sink(tensor_id, data)` receives each unpacked slice.
pub fn unfuse(buf: &FusionBuffer, mut sink: impl FnMut(usize, &[f32])) {
    for &(id, off, len) in &buf.layout {
        sink(id, &buf.data[off..off + len]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensors(sizes: &[usize]) -> Vec<Vec<f32>> {
        let mut rng = crate::util::prng::Rng::new(11);
        sizes.iter().map(|&n| rng.f32_vec(n)).collect()
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let data = tensors(&[10, 300, 1, 77, 2048]);
        let refs: Vec<(usize, &[f32])> =
            data.iter().enumerate().map(|(i, d)| (i, d.as_slice())).collect();
        let bufs = fuse(&refs, 1024); // 256 floats per buffer
        let mut seen = vec![None; data.len()];
        for b in &bufs {
            unfuse(b, |id, slice| seen[id] = Some(slice.to_vec()));
        }
        for (i, orig) in data.iter().enumerate() {
            assert_eq!(seen[i].as_ref().unwrap(), orig, "tensor {i} corrupted");
        }
    }

    #[test]
    fn respects_threshold() {
        let data = tensors(&[100; 20]);
        let refs: Vec<(usize, &[f32])> =
            data.iter().enumerate().map(|(i, d)| (i, d.as_slice())).collect();
        let threshold = 1600; // 400 floats = 4 tensors
        let bufs = fuse(&refs, threshold);
        assert_eq!(bufs.len(), 5);
        for b in &bufs {
            assert!(b.bytes() <= threshold);
        }
    }

    #[test]
    fn oversize_tensor_gets_own_buffer() {
        let data = tensors(&[10, 5000, 10]);
        let refs: Vec<(usize, &[f32])> =
            data.iter().enumerate().map(|(i, d)| (i, d.as_slice())).collect();
        let bufs = fuse(&refs, 400);
        // 10 | 5000 | 10 — the big one unsplit in its own buffer
        assert_eq!(bufs.len(), 3);
        assert_eq!(bufs[1].layout.len(), 1);
        assert_eq!(bufs[1].data.len(), 5000);
    }

    #[test]
    fn order_preserved_and_everything_packed() {
        let data = tensors(&[3, 3, 3, 3]);
        let refs: Vec<(usize, &[f32])> =
            data.iter().enumerate().map(|(i, d)| (i, d.as_slice())).collect();
        let bufs = fuse(&refs, usize::MAX);
        assert_eq!(bufs.len(), 1);
        assert_eq!(bufs[0].tensor_ids(), vec![0, 1, 2, 3]);
        assert_eq!(bufs[0].data.len(), 12);
    }

    #[test]
    fn huge_threshold_one_alpha_small_threshold_many() {
        let data = tensors(&[64; 32]);
        let refs: Vec<(usize, &[f32])> =
            data.iter().enumerate().map(|(i, d)| (i, d.as_slice())).collect();
        assert_eq!(fuse(&refs, usize::MAX).len(), 1);
        assert_eq!(fuse(&refs, 64 * 4).len(), 32);
    }
}
