//! Pointer cache for device buffers — the paper's §V-B contribution.
//!
//! CUDA unified addressing means a raw pointer value alone doesn't say
//! whether it refers to host or device memory; a CUDA-Aware MPI runtime
//! must know, because the answer selects the algorithm (staged vs GDR vs
//! kernel reduction).  The stock path asks the driver
//! (`cuPointerGetAttribute`) on *every* MPI call — several module hops per
//! query (paper Fig 5) — which dominates small-message latency.
//!
//! Two cache designs from the paper, both implemented:
//!  1. `MpiLevel`  — cache at first sight inside MPI.  Broken by design:
//!     the application can `cuFree` + re-`cuMalloc` without telling MPI,
//!     leaving a **stale entry** (test below demonstrates the bug — this
//!     is exactly why the paper rejects this approach).
//!  2. `Intercept` — MPI intercepts `cuMalloc`/`cuFree`, so the cache is
//!     maintained at (de)allocation time and lookups on the critical path
//!     are a pure hash probe.
//!
//! The driver below is a *simulated* CUDA driver (DESIGN.md §2): a real
//! allocator data structure with modeled per-query latency, so cache
//! correctness is testable for real while latency stays analytic.

use std::collections::{BTreeMap, HashMap};

/// What kind of memory a pointer refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufKind {
    Host,
    Device,
}

/// Simulated CUDA driver: bump allocator over two address ranges plus an
/// attribute-query interface with a modeled cost.
pub struct CudaDriverSim {
    /// live allocations: base → (len, kind)
    allocs: BTreeMap<u64, (u64, BufKind)>,
    next_device: u64,
    next_host: u64,
    /// Latency of one `cuPointerGetAttribute` round trip, µs.
    pub query_cost_us: f64,
    pub queries: u64,
}

/// Device allocations live at high addresses, host at low — mirrors real
/// unified-addressing layouts and makes accidental overlap impossible.
const DEVICE_BASE: u64 = 0x7000_0000_0000;
const HOST_BASE: u64 = 0x1000_0000_0000;

impl CudaDriverSim {
    pub fn new(query_cost_us: f64) -> Self {
        CudaDriverSim {
            allocs: BTreeMap::new(),
            next_device: DEVICE_BASE,
            next_host: HOST_BASE,
            query_cost_us,
            queries: 0,
        }
    }

    /// cuMemAlloc: returns the new device pointer.
    pub fn cu_malloc(&mut self, len: u64) -> u64 {
        let ptr = self.next_device;
        // 512-byte alignment like the real allocator
        self.next_device += (len + 511) & !511;
        self.allocs.insert(ptr, (len, BufKind::Device));
        ptr
    }

    /// cuMemAllocHost / malloc: returns a host pointer.
    pub fn host_malloc(&mut self, len: u64) -> u64 {
        let ptr = self.next_host;
        self.next_host += (len + 511) & !511;
        self.allocs.insert(ptr, (len, BufKind::Host));
        ptr
    }

    /// cuMemFree: releases; the address range may be reused by a later
    /// allocation (that reuse is what breaks the MpiLevel cache).
    pub fn cu_free(&mut self, ptr: u64) -> Result<(), String> {
        let (len, _) = self.allocs.remove(&ptr).ok_or_else(|| format!("double free {ptr:#x}"))?;
        // model allocator reuse: wind the bump pointer back when the freed
        // block was the most recent allocation
        let aligned = (len + 511) & !511;
        if ptr + aligned == self.next_device {
            self.next_device = ptr;
        }
        if ptr + aligned == self.next_host {
            self.next_host = ptr;
        }
        Ok(())
    }

    /// cuPointerGetAttribute: what kind of memory is this?  Walks the
    /// allocation map (range lookup) and charges `query_cost_us`.
    pub fn query(&mut self, ptr: u64) -> (Option<BufKind>, f64) {
        self.queries += 1;
        let kind = self
            .allocs
            .range(..=ptr)
            .next_back()
            .filter(|(base, (len, _))| ptr >= **base && ptr < **base + *len)
            .map(|(_, (_, kind))| *kind);
        (kind, self.query_cost_us)
    }

    pub fn live_allocations(&self) -> usize {
        self.allocs.len()
    }
}

/// Cache maintenance policy (paper §V-B's two designs + `None` baseline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheMode {
    /// Stock behaviour: query the driver on every resolve.
    None,
    /// One-time driver lookup at MPI level; never invalidated (UNSAFE —
    /// kept to demonstrate the stale-entry failure the paper describes).
    MpiLevel,
    /// Allocation-API interception: cache updated at cuMalloc/cuFree, so
    /// resolves never miss and never go stale.
    Intercept,
}

/// The pointer cache: hash map from pointer to kind.
pub struct PointerCache {
    mode: CacheMode,
    map: HashMap<u64, BufKind>,
    /// Cost of a cache probe, µs (a hash lookup: ~30ns, i.e. ~0.03µs).
    pub hit_cost_us: f64,
    pub hits: u64,
    pub misses: u64,
}

impl PointerCache {
    pub fn new(mode: CacheMode) -> Self {
        PointerCache { mode, map: HashMap::new(), hit_cost_us: 0.03, hits: 0, misses: 0 }
    }

    pub fn mode(&self) -> CacheMode {
        self.mode
    }

    /// Interception hooks — called by the (simulated) runtime when the
    /// application allocates/frees, in `Intercept` mode.
    pub fn on_malloc(&mut self, ptr: u64, kind: BufKind) {
        if self.mode == CacheMode::Intercept {
            self.map.insert(ptr, kind);
        }
    }

    pub fn on_free(&mut self, ptr: u64) {
        if self.mode == CacheMode::Intercept {
            self.map.remove(&ptr);
        }
    }

    /// Resolve a pointer's kind on the MPI critical path; returns the kind
    /// and the time charged (µs).  This is THE hot-path operation the
    /// paper optimizes: `None` pays the driver on every call, `Intercept`
    /// pays a hash probe.
    pub fn resolve(&mut self, ptr: u64, driver: &mut CudaDriverSim) -> (BufKind, f64) {
        match self.mode {
            CacheMode::None => {
                let (kind, cost) = driver.query(ptr);
                (kind.expect("dangling pointer on MPI path"), cost)
            }
            CacheMode::MpiLevel => {
                if let Some(&kind) = self.map.get(&ptr) {
                    self.hits += 1;
                    (kind, self.hit_cost_us)
                } else {
                    self.misses += 1;
                    let (kind, cost) = driver.query(ptr);
                    let kind = kind.expect("dangling pointer on MPI path");
                    self.map.insert(ptr, kind);
                    (kind, cost + self.hit_cost_us)
                }
            }
            CacheMode::Intercept => match self.map.get(&ptr) {
                Some(&kind) => {
                    self.hits += 1;
                    (kind, self.hit_cost_us)
                }
                None => {
                    // Not intercepted (e.g. stack/static host buffer):
                    // fall through to the driver once, do not cache —
                    // interception owns the cache contents.
                    self.misses += 1;
                    let (kind, cost) = driver.query(ptr);
                    (kind.unwrap_or(BufKind::Host), cost)
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn driver_allocates_and_queries() {
        let mut d = CudaDriverSim::new(1.0);
        let dev = d.cu_malloc(4096);
        let host = d.host_malloc(4096);
        assert_eq!(d.query(dev).0, Some(BufKind::Device));
        assert_eq!(d.query(host).0, Some(BufKind::Host));
        // interior pointer resolves to its allocation
        assert_eq!(d.query(dev + 100).0, Some(BufKind::Device));
        // out-of-range pointer is unknown
        assert_eq!(d.query(0xdead).0, None);
        assert_eq!(d.queries, 4);
    }

    #[test]
    fn driver_free_and_double_free() {
        let mut d = CudaDriverSim::new(1.0);
        let p = d.cu_malloc(100);
        assert!(d.cu_free(p).is_ok());
        assert!(d.cu_free(p).is_err());
        assert_eq!(d.query(p).0, None);
    }

    #[test]
    fn no_cache_pays_driver_every_call() {
        let mut d = CudaDriverSim::new(1.0);
        let mut c = PointerCache::new(CacheMode::None);
        let p = d.cu_malloc(64);
        let mut total = 0.0;
        for _ in 0..10 {
            total += c.resolve(p, &mut d).1;
        }
        assert!((total - 10.0).abs() < 1e-9);
        assert_eq!(d.queries, 10);
    }

    #[test]
    fn intercept_cache_is_a_hash_probe_after_malloc() {
        let mut d = CudaDriverSim::new(1.0);
        let mut c = PointerCache::new(CacheMode::Intercept);
        let p = d.cu_malloc(64);
        c.on_malloc(p, BufKind::Device);
        let mut total = 0.0;
        for _ in 0..10 {
            let (kind, cost) = c.resolve(p, &mut d);
            assert_eq!(kind, BufKind::Device);
            total += cost;
        }
        assert_eq!(d.queries, 0, "driver must never be hit");
        assert!(total < 1.0, "10 probes should cost ≪ one driver query, got {total}us");
        assert_eq!(c.hits, 10);
    }

    /// The stale-entry failure that motivates interception (§V-B): free a
    /// device buffer, allocate a *host* buffer that reuses the address —
    /// the MPI-level cache still claims Device.
    #[test]
    fn mpi_level_cache_goes_stale_after_free() {
        let mut d = CudaDriverSim::new(1.0);
        let mut c = PointerCache::new(CacheMode::MpiLevel);

        // Construct address reuse across kinds deterministically: query a
        // device pointer, free it, then hand the SAME address back as if
        // the allocator had recycled it for host-registered memory.
        let p = d.cu_malloc(256);
        assert_eq!(c.resolve(p, &mut d).0, BufKind::Device);
        d.cu_free(p).unwrap();
        d.allocs.insert(p, (256, BufKind::Host)); // allocator reuse

        let truth = d.query(p).0.unwrap();
        let cached = c.resolve(p, &mut d).0;
        assert_eq!(truth, BufKind::Host);
        assert_eq!(cached, BufKind::Device, "stale entry: cache must disagree with driver");
    }

    /// Interception keeps the cache coherent across the same reuse pattern.
    #[test]
    fn intercept_cache_survives_free_realloc() {
        let mut d = CudaDriverSim::new(1.0);
        let mut c = PointerCache::new(CacheMode::Intercept);
        let p = d.cu_malloc(256);
        c.on_malloc(p, BufKind::Device);
        assert_eq!(c.resolve(p, &mut d).0, BufKind::Device);

        d.cu_free(p).unwrap();
        c.on_free(p);
        d.allocs.insert(p, (256, BufKind::Host));
        c.on_malloc(p, BufKind::Host);

        assert_eq!(c.resolve(p, &mut d).0, BufKind::Host);
        assert_eq!(d.queries, 0);
    }

    #[test]
    fn mpi_level_caches_after_first_touch() {
        let mut d = CudaDriverSim::new(1.0);
        let mut c = PointerCache::new(CacheMode::MpiLevel);
        let p = d.cu_malloc(64);
        let first = c.resolve(p, &mut d).1;
        let second = c.resolve(p, &mut d).1;
        assert!(first > 1.0 - 1e-9);
        assert!(second < 0.1);
        assert_eq!(d.queries, 1);
    }
}
