//! Synthetic training data, sharded per worker.
//!
//! The paper's methodology (§IV): synthetic input data so the benchmark
//! measures exactly GPU compute + gradient communication — no file I/O,
//! no input pipeline.  We generate token sequences from a seeded PRNG;
//! each worker owns a disjoint stream (fork by rank), which is what makes
//! the data-parallel gradient averaging meaningful.

use crate::util::prng::Rng;

/// Per-worker synthetic token stream.
pub struct ShardedTokens {
    rngs: Vec<Rng>,
    vocab: u32,
    tokens_per_step: usize,
}

impl ShardedTokens {
    pub fn new(seed: u64, world: usize, vocab: usize, tokens_per_step: usize) -> Self {
        let mut root = Rng::new(seed);
        ShardedTokens {
            rngs: (0..world).map(|r| root.fork(r as u64)).collect(),
            vocab: vocab as u32,
            tokens_per_step,
        }
    }

    pub fn world(&self) -> usize {
        self.rngs.len()
    }

    /// Next batch for `rank` (i32 tokens, shape [batch, seq+1] flattened).
    pub fn next_batch(&mut self, rank: usize) -> Vec<i32> {
        self.rngs[rank].tokens(self.tokens_per_step, self.vocab)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_are_disjoint_streams() {
        let mut s = ShardedTokens::new(7, 2, 100, 64);
        let a = s.next_batch(0);
        let b = s.next_batch(1);
        assert_ne!(a, b, "ranks must see different data");
        assert_eq!(a.len(), 64);
        assert!(a.iter().all(|&t| (0..100).contains(&t)));
    }

    #[test]
    fn deterministic_per_seed() {
        let mut s1 = ShardedTokens::new(9, 4, 50, 32);
        let mut s2 = ShardedTokens::new(9, 4, 50, 32);
        for r in 0..4 {
            assert_eq!(s1.next_batch(r), s2.next_batch(r));
        }
    }

    #[test]
    fn stream_advances() {
        let mut s = ShardedTokens::new(3, 1, 100, 16);
        assert_ne!(s.next_batch(0), s.next_batch(0));
    }
}
