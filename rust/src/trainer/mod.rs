//! The real data-parallel trainer: every simulated worker executes the
//! AOT-compiled JAX/Pallas train_step through PJRT, gradients are
//! aggregated with the *actual* allreduce implementations from `comm`,
//! and the fused Pallas SGD kernel applies the update — the full
//! L1→L2→L3 composition, with the virtual clock estimating what the same
//! iteration would cost on the paper's clusters.

pub mod checkpoint;
pub mod data;
pub mod run;

pub use checkpoint::Checkpoint;
pub use data::ShardedTokens;
pub use run::{TrainConfig, TrainResult, Trainer};
