//! Training-state checkpointing (§III-A: TF's PS support classes exist
//! "for checkpointing (saving) the training state or for fault tolerance
//! in case a worker node crashes" — the trainer provides the same).
//!
//! Format: a small header (magic, version, step, param count) followed by
//! little-endian f32 params and velocity.  Self-validating on restore.

use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::{Context, Result};

const MAGIC: &[u8; 8] = b"MPIDNNv1";

/// A resumable training state snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<f32>,
    pub velocity: Vec<f32>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::ensure!(
            self.params.len() == self.velocity.len(),
            "params/velocity length mismatch"
        );
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp)
                    .with_context(|| format!("creating {}", tmp.display()))?,
            );
            f.write_all(MAGIC)?;
            f.write_all(&self.step.to_le_bytes())?;
            f.write_all(&(self.params.len() as u64).to_le_bytes())?;
            for v in self.params.iter().chain(self.velocity.iter()) {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        // atomic publish: a crash mid-save never corrupts the previous one
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        crate::ensure!(&magic == MAGIC, "not a checkpoint file: bad magic");
        let mut u64buf = [0u8; 8];
        f.read_exact(&mut u64buf)?;
        let step = u64::from_le_bytes(u64buf);
        f.read_exact(&mut u64buf)?;
        let n = u64::from_le_bytes(u64buf) as usize;
        crate::ensure!(n < (1 << 31), "implausible param count {n}");
        let mut read_vec = |len: usize| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes).context("truncated checkpoint")?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        let params = read_vec(n)?;
        let velocity = read_vec(n)?;
        Ok(Checkpoint { step, params, velocity })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mpidnn_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = crate::util::prng::Rng::new(3);
        let ck = Checkpoint { step: 123, params: rng.f32_vec(1000), velocity: rng.f32_vec(1000) };
        let p = tmp("rt.bin");
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ck, back);
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        // truncated: valid header, missing payload
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&7u64.to_le_bytes());
        bytes.extend_from_slice(&100u64.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let e = Checkpoint::load(&p).unwrap_err();
        assert!(format!("{e:#}").contains("truncated"), "{e:#}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mismatched_lengths_refused_on_save() {
        let ck = Checkpoint { step: 0, params: vec![1.0], velocity: vec![] };
        assert!(ck.save(&tmp("mm.bin")).is_err());
    }
}
