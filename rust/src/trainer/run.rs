//! The training loop.
//!
//! One process simulates W data-parallel workers: each executes the REAL
//! AOT train_step (PJRT CPU) on its own data shard; the W gradient
//! vectors are aggregated by the configured Allreduce implementation
//! (ring / RHD / tree over real buffers — the same code the
//! micro-benchmarks time); the fused Pallas SGD artifact applies the
//! update.  Parameters stay bit-identical across workers by construction
//! (one copy, updated once — exactly what a correct synchronous
//! data-parallel run guarantees), and the gradient averaging is the REAL
//! sum from the collective, so the loss curve is a genuine training
//! signal, not a simulation.

use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use crate::util::error::{Context, Result};

use super::data::ShardedTokens;
use crate::cluster::ClusterSpec;
use crate::comm::{MpiFlavor, MpiWorld};
use crate::models::transformer;
use crate::runtime::{self, ReduceKernel, RuntimeClient, SgdUpdate, TrainStep};
use crate::sim::SimTime;
use crate::strategies::WorldSpec;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact config name (tiny | small | medium | large).
    pub model_config: String,
    pub world: usize,
    pub steps: usize,
    pub seed: u64,
    /// MPI flavor backing the gradient allreduce.
    pub flavor: MpiFlavor,
    /// Cluster whose virtual clock we ride (for the simulated-time report).
    pub cluster: ClusterSpec,
    /// Run the reduction through the PJRT Pallas kernel (true) or the
    /// semantically-identical scalar path (false, faster wall-clock).
    pub pjrt_reduce: bool,
    /// Log every n steps (0 = silent).
    pub log_every: usize,
    /// Checkpoint every n steps to `checkpoint_path` (0 = disabled);
    /// when the file already exists, training RESUMES from it (§III-A's
    /// fault-tolerance story).
    pub checkpoint_every: usize,
    pub checkpoint_path: Option<PathBuf>,
    /// Write a Chrome trace of one simulated comm iteration here after
    /// training (§Observability); `None` keeps the tracer detached.
    pub trace_path: Option<PathBuf>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model_config: "small".into(),
            world: 4,
            steps: 50,
            seed: 0,
            flavor: MpiFlavor::Mvapich2GdrOpt,
            cluster: crate::cluster::presets::ri2(),
            pjrt_reduce: false,
            log_every: 10,
            checkpoint_every: 0,
            checkpoint_path: None,
            trace_path: None,
        }
    }
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub losses: Vec<f32>,
    /// Virtual (simulated-cluster) time for the whole run.
    pub sim_time: SimTime,
    /// Wall-clock seconds actually spent.
    pub wall_secs: f64,
    pub steps: usize,
    pub world: usize,
    pub param_count: usize,
}

impl TrainResult {
    /// Smoothed final loss (mean of last quarter of the curve).
    pub fn final_loss(&self) -> f32 {
        let tail = &self.losses[self.losses.len() - (self.losses.len() / 4).max(1)..];
        tail.iter().sum::<f32>() / tail.len() as f32
    }

    pub fn initial_loss(&self) -> f32 {
        self.losses[0]
    }
}

pub struct Trainer {
    cfg: TrainConfig,
    step: TrainStep,
    sgd: SgdUpdate,
    reduce_kernel: Option<Rc<ReduceKernel>>,
    mpi: MpiWorld,
    artifacts: PathBuf,
}

impl Trainer {
    pub fn new(client: &RuntimeClient, cfg: TrainConfig) -> Result<Trainer> {
        let artifacts = runtime::artifacts_dir()?;
        crate::ensure!(
            runtime::config_available(&artifacts, &cfg.model_config),
            "artifacts for `{}` not built (run `make artifacts`)",
            cfg.model_config
        );
        let step = TrainStep::load(client, &artifacts, &cfg.model_config)
            .context("loading train_step artifact")?;
        let sgd = SgdUpdate::load(client, &artifacts, &cfg.model_config, step.meta.param_count)?;
        let reduce_kernel = if cfg.pjrt_reduce {
            Some(Rc::new(ReduceKernel::load(client, &artifacts, &step.meta.reduce_chunks)?))
        } else {
            None
        };
        let mpi = MpiWorld::new(cfg.flavor, cfg.cluster.clone());
        Ok(Trainer { cfg, step, sgd, reduce_kernel, mpi, artifacts })
    }

    pub fn meta(&self) -> &crate::runtime::ModelMeta {
        &self.step.meta
    }

    /// Run the configured number of steps; returns the loss curve + times.
    pub fn train(&mut self) -> Result<TrainResult> {
        let meta = self.step.meta.clone();
        let wall0 = Instant::now();
        let mut params = meta.load_params(&self.artifacts)?;
        let mut velocity = vec![0.0f32; meta.param_count];
        let mut start_step = 0usize;
        // resume from a checkpoint if one is present
        if let Some(path) = &self.cfg.checkpoint_path {
            if path.is_file() {
                let ck = super::checkpoint::Checkpoint::load(path)?;
                crate::ensure!(
                    ck.params.len() == meta.param_count,
                    "checkpoint is for a different model ({} vs {} params)",
                    ck.params.len(),
                    meta.param_count
                );
                start_step = ck.step as usize;
                params = ck.params;
                velocity = ck.velocity;
                crate::log_info!("resumed from {} at step {start_step}", path.display());
            }
        }
        let mut data =
            ShardedTokens::new(self.cfg.seed, self.cfg.world, meta.vocab, meta.tokens_len());
        // replay the data stream up to the resume point (determinism)
        for _ in 0..start_step {
            for rank in 0..self.cfg.world {
                let _ = data.next_batch(rank);
            }
        }
        let mut losses = Vec::with_capacity(self.cfg.steps);
        let mut sim = SimTime::ZERO;
        // Horovod broadcasts initial parameters from rank 0 (§III-C2);
        // charge the binomial broadcast on the virtual clock.
        if self.cfg.world > 1 && start_step == 0 {
            let hops = (self.cfg.world as f64).log2().ceil();
            let (_, ctx) = self.mpi.plan(meta.grad_bytes());
            sim += SimTime::from_us(hops * ctx.sendrecv_cost(meta.grad_bytes()).total_us());
        }

        // virtual-clock cost of one worker's fwd/bwd on the target cluster
        let profile = transformer::profile(&meta);
        let ws = WorldSpec {
            cluster: self.cfg.cluster.clone(),
            model: profile,
            world: self.cfg.world,
            batch_per_gpu: meta.batch,
        };
        let compute_time = ws.compute_time();

        for step_i in start_step..self.cfg.steps {
            // --- L2: real fwd/bwd per worker (PJRT) ---
            let mut grads: Vec<Vec<f32>> = Vec::with_capacity(self.cfg.world);
            let mut mean_loss = 0.0f32;
            for rank in 0..self.cfg.world {
                let tokens = data.next_batch(rank);
                let (loss, g) = self.step.run(&params, &tokens)?;
                mean_loss += loss / self.cfg.world as f32;
                grads.push(g);
            }

            // --- L3: real allreduce over the gradient buffers ---
            let report = if let Some(kernel) = &self.reduce_kernel {
                // route the reductions through the Pallas artifact
                let bytes = meta.grad_bytes();
                let (algo, mut ctx) = self.mpi.plan(bytes);
                ctx.reduce = crate::comm::allreduce::ReducePlace::GpuPjrt(kernel.clone());
                crate::comm::allreduce::run_algo(algo, &mut grads, &mut ctx)
            } else {
                self.mpi.allreduce(&mut grads)
            };

            // --- L1: fused Pallas SGD update (scale averages the sum) ---
            let scale = 1.0 / self.cfg.world as f32;
            self.sgd.run(&mut params, &mut velocity, &grads[0], scale)?;

            sim += compute_time + report.time;
            losses.push(mean_loss);
            if self.cfg.log_every > 0 && step_i % self.cfg.log_every == 0 {
                crate::log_info!(
                    "step {step_i:>4}  loss {mean_loss:.4}  sim {sim}  wall {:.1}s",
                    wall0.elapsed().as_secs_f64()
                );
            }
            if self.cfg.checkpoint_every > 0 && (step_i + 1) % self.cfg.checkpoint_every == 0 {
                if let Some(path) = &self.cfg.checkpoint_path {
                    super::checkpoint::Checkpoint {
                        step: (step_i + 1) as u64,
                        params: params.clone(),
                        velocity: velocity.clone(),
                    }
                    .save(path)?;
                }
            }
        }

        // §Observability: re-run one simulated iteration of the comm
        // strategy this run modeled, tracer attached, and export the
        // Chrome timeline.  The traced engine is a fresh observer run —
        // it never touches the training state or the virtual clock above.
        if let Some(path) = &self.cfg.trace_path {
            crate::ensure!(
                self.cfg.world >= 2,
                "--trace needs --world >= 2 (a single rank runs no collective)"
            );
            use crate::strategies::Strategy as _;
            let strat = crate::strategies::Horovod::mpi(self.cfg.flavor);
            let report = {
                let _t = crate::sim::TraceGuard::new();
                strat.iteration_in(&ws, &crate::strategies::Scenario::default())?
            };
            let trace = report
                .trace
                .context("traced iteration attached no trace (tracer disabled?)")?;
            std::fs::write(path, &trace.chrome_json)
                .context(format!("writing trace to {}", path.display()))?;
            crate::log_info!("wrote comm-iteration trace to {}", path.display());
        }

        Ok(TrainResult {
            losses,
            sim_time: sim,
            wall_secs: wall0.elapsed().as_secs_f64(),
            steps: self.cfg.steps,
            world: self.cfg.world,
            param_count: meta.param_count,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::client::shared;

    fn have_tiny() -> bool {
        runtime::artifacts_dir()
            .map(|d| runtime::config_available(&d, "tiny"))
            .unwrap_or(false)
    }

    #[test]
    fn tiny_training_reduces_loss() {
        if !have_tiny() {
            return;
        }
        let client = shared().unwrap();
        let cfg = TrainConfig {
            model_config: "tiny".into(),
            world: 2,
            steps: 30,
            log_every: 0,
            ..Default::default()
        };
        let mut t = Trainer::new(&client, cfg).unwrap();
        let r = t.train().unwrap();
        assert_eq!(r.losses.len(), 30);
        assert!(
            r.final_loss() < r.initial_loss() - 0.05,
            "loss should decrease: {} -> {}",
            r.initial_loss(),
            r.final_loss()
        );
        assert!(r.sim_time > SimTime::ZERO);
    }

    #[test]
    fn world_sizes_agree_on_first_loss() {
        // With the same seed, the first-step mean loss is computed from
        // the same params; allreduce correctness is covered elsewhere.
        if !have_tiny() {
            return;
        }
        let client = shared().unwrap();
        let mk = |world| TrainConfig {
            model_config: "tiny".into(),
            world,
            steps: 1,
            log_every: 0,
            ..Default::default()
        };
        let l1 = Trainer::new(&client, mk(1)).unwrap().train().unwrap().losses[0];
        let l4 = Trainer::new(&client, mk(4)).unwrap().train().unwrap().losses[0];
        // same init, random-uniform data ⇒ losses near ln(vocab) for both
        assert!((l1 - l4).abs() < 0.5, "{l1} vs {l4}");
    }

    #[test]
    fn checkpoint_resume_matches_uninterrupted_run() {
        // Train 10 steps straight vs 5 + crash + resume 5: identical curve.
        if !have_tiny() {
            return;
        }
        let client = shared().unwrap();
        let ck = std::env::temp_dir()
            .join(format!("mpidnn_resume_{}.ckpt", std::process::id()));
        std::fs::remove_file(&ck).ok();
        let mk = |steps: usize, path: Option<std::path::PathBuf>| TrainConfig {
            model_config: "tiny".into(),
            world: 2,
            steps,
            seed: 5,
            log_every: 0,
            checkpoint_every: 5,
            checkpoint_path: path,
            ..Default::default()
        };
        let straight = Trainer::new(&client, mk(10, None)).unwrap().train().unwrap();
        // first half (checkpoints at step 5)
        let _half = Trainer::new(&client, mk(5, Some(ck.clone()))).unwrap().train().unwrap();
        // resume to step 10
        let resumed = Trainer::new(&client, mk(10, Some(ck.clone()))).unwrap().train().unwrap();
        std::fs::remove_file(&ck).ok();
        assert_eq!(resumed.losses.len(), 5, "resumed run covers steps 5..10");
        for (a, b) in straight.losses[5..].iter().zip(&resumed.losses) {
            assert!((a - b).abs() < 1e-5, "resume diverged: {a} vs {b}");
        }
    }

    #[test]
    fn pjrt_reduce_path_matches_scalar_path() {
        // The Pallas reduction kernel and the scalar path must yield the
        // same training trajectory (same sums ⇒ same updates ⇒ same loss).
        if !have_tiny() {
            return;
        }
        let client = shared().unwrap();
        let mk = |pjrt| TrainConfig {
            model_config: "tiny".into(),
            world: 2,
            steps: 5,
            pjrt_reduce: pjrt,
            log_every: 0,
            ..Default::default()
        };
        let a = Trainer::new(&client, mk(false)).unwrap().train().unwrap();
        let b = Trainer::new(&client, mk(true)).unwrap().train().unwrap();
        for (x, y) in a.losses.iter().zip(&b.losses) {
            assert!((x - y).abs() < 1e-3, "curves diverged: {x} vs {y}");
        }
    }
}
