//! MobileNet v1 (Howard et al.) — the paper's communication-bound extreme:
//! small parameter set (4.2M), tiny per-image compute (≈0.57 GMACs), and
//! depthwise convolutions that utilize dense-conv hardware poorly.  This
//! is the model whose gradients "cannot be hidden behind the relatively
//! smaller computation" (§VI-D), giving the worst scaling in Figure 9.

use super::layer::NetBuilder;
use super::ModelProfile;

pub fn mobilenet_v1() -> ModelProfile {
    let mut b = NetBuilder::new();
    // stem: 3×3/2 conv, 3→32, 224→112
    b.conv("conv1", 3, 3, 32, 112, true);
    // depthwise-separable stack: (cin, cout, out_hw after this layer)
    let layers: [(usize, usize, usize); 13] = [
        (32, 64, 112),
        (64, 128, 56),
        (128, 128, 56),
        (128, 256, 28),
        (256, 256, 28),
        (256, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
        (512, 1024, 7),
        (1024, 1024, 7),
    ];
    for (i, &(cin, cout, hw)) in layers.iter().enumerate() {
        b.dwconv(&format!("ds{i}.dw"), 3, cin, hw, true);
        b.conv(&format!("ds{i}.pw"), 1, cin, cout, hw, true);
    }
    b.fc("fc", 1024, 1000);

    let gflops_fwd = b.gflops_fwd();
    let kernel_launches = b.launches;
    ModelProfile {
        name: "MobileNet".to_string(),
        gflops_fwd,
        kernel_launches,
        eff_mult: 0.5, // depthwise convs run dense-conv pipelines poorly
        act_bytes_per_sample: 25e6,
        default_batch: 64,
        tensors: b.tensors_bwd_order(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_matches_published() {
        let m = mobilenet_v1();
        let p = m.param_count();
        // published: 4.24M (1.0 width, 224)
        assert!((4_000_000..=4_500_000).contains(&p), "MobileNet params {p} ≈ 4.2M");
    }

    #[test]
    fn gflops_matches_published() {
        let m = mobilenet_v1();
        // 569M MACs ⇒ ≈1.14 GFLOPs fwd
        assert!(m.gflops_fwd > 0.9 && m.gflops_fwd < 1.4, "got {}", m.gflops_fwd);
    }

    #[test]
    fn mostly_tiny_tensors() {
        // the communication pathology: many small gradient tensors
        let m = mobilenet_v1();
        assert_eq!(m.tensors.len(), 83); // stem(3) + 13·(dw 3 + pw 3) + fc(2)
        let tiny = m.tensors.iter().filter(|t| t.bytes() < 16 * 1024).count();
        assert!(tiny as f64 > 0.55 * m.tensors.len() as f64, "{tiny}/83 tiny");
    }

    #[test]
    fn much_faster_than_resnet_per_image() {
        let m = mobilenet_v1();
        let r = super::super::resnet::resnet50();
        assert!(r.gflops_fwd / m.gflops_fwd > 5.0);
    }
}
