//! ResNet-50 (He et al.) — the paper's primary workload.  Constructed
//! block-by-block; the derived totals are pinned to the published numbers
//! (25.56M parameters, ≈4.1 GMACs for 224×224).

use super::layer::NetBuilder;
use super::ModelProfile;

/// Bottleneck block: 1×1 reduce → 3×3 → 1×1 expand (+ optional projection
/// shortcut).  `hw` is the output spatial size of the block.
fn bottleneck(b: &mut NetBuilder, name: &str, cin: usize, w: usize, hw: usize, project: bool) {
    b.conv(&format!("{name}.a"), 1, cin, w, hw, true);
    b.conv(&format!("{name}.b"), 3, w, w, hw, true);
    b.conv(&format!("{name}.c"), 1, w, 4 * w, hw, true);
    if project {
        b.conv(&format!("{name}.proj"), 1, cin, 4 * w, hw, true);
    }
}

pub fn resnet50() -> ModelProfile {
    let mut b = NetBuilder::new();
    // stem: 7×7/2 conv, 64 ch, 224→112
    b.conv("conv1", 7, 3, 64, 112, true);
    // stage configs: (blocks, width, output hw)   — 112→56→28→14→7
    let stages = [(3usize, 64usize, 56usize), (4, 128, 28), (6, 256, 14), (3, 512, 7)];
    let mut cin = 64;
    for (s, &(blocks, w, hw)) in stages.iter().enumerate() {
        for i in 0..blocks {
            bottleneck(&mut b, &format!("s{s}b{i}"), cin, w, hw, i == 0);
            cin = 4 * w;
        }
    }
    b.fc("fc", 2048, 1000);

    let gflops_fwd = b.gflops_fwd();
    let kernel_launches = b.launches;
    ModelProfile {
        name: "ResNet-50".to_string(),
        gflops_fwd,
        kernel_launches,
        eff_mult: 1.0,
        act_bytes_per_sample: 62e6,
        default_batch: 64,
        tensors: b.tensors_bwd_order(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_matches_published() {
        let m = resnet50();
        let p = m.param_count();
        // torchvision resnet50: 25,557,032
        assert!(
            (24_500_000..=26_500_000).contains(&p),
            "ResNet-50 params {p} should be ≈25.56M"
        );
    }

    #[test]
    fn gflops_matches_published() {
        let m = resnet50();
        // ≈4.1 GMACs ⇒ ≈8.2 GFLOPs fwd (2·MACs)
        assert!(
            m.gflops_fwd > 7.0 && m.gflops_fwd < 9.5,
            "ResNet-50 fwd GFLOPs {} should be ≈8.2",
            m.gflops_fwd
        );
    }

    #[test]
    fn tensor_inventory_shape() {
        let m = resnet50();
        // 53 convs + 53·2 BN + fc w/b = 161 tensors
        assert_eq!(m.tensors.len(), 161);
        // backward order: fc bias first, stem conv last
        assert_eq!(m.tensors[0].name, "fc.b");
        assert_eq!(m.tensors.last().unwrap().name, "conv1.w");
        // largest single tensor is the s3 expand / fc region (~2M)
        let max = m.tensors.iter().map(|t| t.elems).max().unwrap();
        assert!(max >= 2_000_000 && max < 3_000_000);
    }

    #[test]
    fn throughput_calibration_batch64() {
        // Fig 2 era numbers (fp32, TF 1.10 synthetic): K80 ≈ 50, P100 ≈
        // 195, V100 ≈ 330 img/s.
        use crate::cluster::GpuModel;
        let m = resnet50();
        for (gpu, lo, hi) in [
            (GpuModel::k80(), 35.0, 70.0),
            (GpuModel::p100(), 150.0, 240.0),
            (GpuModel::v100(), 260.0, 400.0),
        ] {
            let t = m.throughput_1gpu(&gpu, 64);
            assert!(t > lo && t < hi, "{}: {t:.0} img/s not in [{lo}, {hi}]", gpu.name);
        }
    }
}
