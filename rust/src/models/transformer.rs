//! Transformer profile derived from the L2 artifact metadata — the
//! workload our *real* end-to-end runs train.  The tensor layout mirrors
//! python/compile/model.py's `param_specs` exactly; the cross-language
//! test pins the rust-side reconstruction to the python-side
//! `param_count` recorded in meta_<cfg>.json.

use super::layer::TensorSpec;
use super::ModelProfile;
use crate::runtime::ModelMeta;

/// Reconstruct the flat-vector layout of python/compile/model.py.
pub fn param_specs(m: &ModelMeta) -> Vec<TensorSpec> {
    let mut s = Vec::new();
    s.push(TensorSpec::new("tok_emb", m.vocab * m.d_model));
    s.push(TensorSpec::new("pos_emb", m.seq * m.d_model));
    for i in 0..m.n_layers {
        s.push(TensorSpec::new(format!("l{i}.ln1_g"), m.d_model));
        s.push(TensorSpec::new(format!("l{i}.ln1_b"), m.d_model));
        for w in ["wq", "wk", "wv", "wo"] {
            s.push(TensorSpec::new(format!("l{i}.{w}"), m.d_model * m.d_model));
        }
        s.push(TensorSpec::new(format!("l{i}.ln2_g"), m.d_model));
        s.push(TensorSpec::new(format!("l{i}.ln2_b"), m.d_model));
        s.push(TensorSpec::new(format!("l{i}.w1"), m.d_model * m.d_ff));
        s.push(TensorSpec::new(format!("l{i}.b1"), m.d_ff));
        s.push(TensorSpec::new(format!("l{i}.w2"), m.d_ff * m.d_model));
        s.push(TensorSpec::new(format!("l{i}.b2"), m.d_model));
    }
    s.push(TensorSpec::new("lnf_g", m.d_model));
    s.push(TensorSpec::new("lnf_b", m.d_model));
    s.push(TensorSpec::new("head", m.d_model * m.vocab));
    s
}

/// Workload profile for the strategies/simulator (a "sample" is one
/// sequence; fwd FLOPs ≈ 2·params·seq).
pub fn profile(meta: &ModelMeta) -> ModelProfile {
    let mut tensors = param_specs(meta);
    let n: usize = tensors.iter().map(|t| t.elems).sum();
    tensors.reverse(); // backward emission order
    ModelProfile {
        name: format!("Transformer-{}", meta.config),
        gflops_fwd: 2.0 * n as f64 * meta.seq as f64 / 1e9,
        kernel_launches: 12 * meta.n_layers + 8,
        eff_mult: 1.0,
        act_bytes_per_sample: (meta.seq * meta.d_model * (meta.n_layers + 2) * 4) as f64,
        default_batch: meta.batch,
        tensors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_dir, config_available};

    #[test]
    fn layout_matches_python_param_count() {
        // CROSS-LANGUAGE INVARIANT: rust reconstruction == python layout.
        let Ok(dir) = artifacts_dir() else { return };
        for cfg in ["tiny", "small", "medium"] {
            if !config_available(&dir, cfg) {
                continue;
            }
            let meta = ModelMeta::load(&dir, cfg).unwrap();
            let total: usize = param_specs(&meta).iter().map(|t| t.elems).sum();
            assert_eq!(
                total, meta.param_count,
                "{cfg}: rust layout {total} != python {}",
                meta.param_count
            );
        }
    }

    #[test]
    fn profile_tensor_order_is_backward() {
        let Ok(dir) = artifacts_dir() else { return };
        if !config_available(&dir, "tiny") {
            return;
        }
        let meta = ModelMeta::load(&dir, "tiny").unwrap();
        let p = profile(&meta);
        assert_eq!(p.tensors[0].name, "head");
        assert_eq!(p.tensors.last().unwrap().name, "tok_emb");
        assert_eq!(p.param_count(), meta.param_count);
    }
}
