//! DNN workload profiles: the gradient-tensor inventory and FLOP budget of
//! the networks the paper trains (ResNet-50, MobileNet, NASNet-large) plus
//! the transformer our real end-to-end runs use.
//!
//! Profiles are *constructed from the architectures* (conv/fc shape
//! arithmetic), not hard-coded totals — the tests pin the derived
//! parameter counts and FLOPs to the published numbers.

pub mod layer;
pub mod mobilenet;
pub mod nasnet;
pub mod resnet;
pub mod transformer;

pub use layer::TensorSpec;

/// Everything the strategies need to know about one DNN workload.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: String,
    /// Gradient tensors in *backward* emission order (last layer first) —
    /// the order Horovod sees them become ready.
    pub tensors: Vec<TensorSpec>,
    /// Forward-pass GFLOPs per sample (2·MACs convention).
    pub gflops_fwd: f64,
    /// Kernel launches per fwd+bwd iteration (pipelining overhead term).
    pub kernel_launches: usize,
    /// Utilization multiplier vs the GPU's dense-conv efficiency curve
    /// (depthwise convolutions and fragmented cells run the MXU/SM array
    /// poorly: MobileNet ≈ 0.5, NASNet ≈ 0.6).
    pub eff_mult: f64,
    /// Activation bytes per sample (for batch-feasibility checks).
    pub act_bytes_per_sample: f64,
    /// The batch size the paper's runs use for this model.
    pub default_batch: usize,
}

impl ModelProfile {
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.elems).sum()
    }

    pub fn grad_bytes(&self) -> usize {
        self.param_count() * 4
    }

    /// fwd+bwd GFLOPs per sample (backward ≈ 2× forward).
    pub fn gflops_fwd_bwd(&self) -> f64 {
        3.0 * self.gflops_fwd
    }

    /// Compute time for one iteration on `gpu` at `batch`.
    pub fn compute_time(&self, gpu: &crate::cluster::GpuModel, batch: usize) -> crate::sim::SimTime {
        let eff = gpu.efficiency(batch) * self.eff_mult;
        let compute_us =
            batch as f64 * self.gflops_fwd_bwd() / (gpu.peak_gflops * eff) * 1e6;
        crate::sim::SimTime::from_us(compute_us + gpu.launch_us * self.kernel_launches as f64)
    }

    /// Single-GPU throughput (samples/s) — the "ideal" scaling baseline.
    pub fn throughput_1gpu(&self, gpu: &crate::cluster::GpuModel, batch: usize) -> f64 {
        batch as f64 / self.compute_time(gpu, batch).as_secs()
    }
}

/// Look up a workload by name.
pub fn by_name(name: &str) -> crate::util::error::Result<ModelProfile> {
    match name.to_ascii_lowercase().as_str() {
        "resnet50" | "resnet-50" | "resnet" => Ok(resnet::resnet50()),
        "mobilenet" => Ok(mobilenet::mobilenet_v1()),
        "nasnet" | "nasnet-large" => Ok(nasnet::nasnet_large()),
        other => crate::bail!("unknown model `{other}` (resnet50 | mobilenet | nasnet)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuModel;

    #[test]
    fn lookup_works() {
        assert!(by_name("ResNet-50").is_ok());
        assert!(by_name("mobilenet").is_ok());
        assert!(by_name("nasnet").is_ok());
        assert!(by_name("vgg").is_err());
    }

    #[test]
    fn relative_speeds_sane() {
        // samples/s: MobileNet > ResNet-50 > NASNet on every GPU
        let gpu = GpuModel::p100();
        let m = mobilenet::mobilenet_v1();
        let r = resnet::resnet50();
        let n = nasnet::nasnet_large();
        let tm = m.throughput_1gpu(&gpu, m.default_batch);
        let tr = r.throughput_1gpu(&gpu, r.default_batch);
        let tn = n.throughput_1gpu(&gpu, n.default_batch);
        assert!(tm > tr && tr > tn, "mobilenet {tm} > resnet {tr} > nasnet {tn}");
    }

    #[test]
    fn grad_sizes_ordered_like_param_counts() {
        let m = mobilenet::mobilenet_v1().grad_bytes();
        let r = resnet::resnet50().grad_bytes();
        let n = nasnet::nasnet_large().grad_bytes();
        assert!(m < r && r < n);
    }
}
