//! Layer/tensor building blocks shared by the architecture constructors.

/// One trainable tensor (weight or bias/BN) — the unit of gradient
/// communication.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub elems: usize,
}

impl TensorSpec {
    pub fn new(name: impl Into<String>, elems: usize) -> TensorSpec {
        TensorSpec { name: name.into(), elems }
    }

    pub fn bytes(&self) -> usize {
        self.elems * 4
    }
}

/// Running tally while walking an architecture: tensors + MACs.
#[derive(Debug, Default)]
pub struct NetBuilder {
    pub tensors: Vec<TensorSpec>,
    pub macs: f64,
    pub launches: usize,
}

impl NetBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// 2-D convolution: k×k, cin→cout, producing out_hw² spatial outputs.
    /// Registers weight (+ BN scale/shift when `bn`) and counts MACs.
    pub fn conv(&mut self, name: &str, k: usize, cin: usize, cout: usize, out_hw: usize, bn: bool) {
        self.tensors.push(TensorSpec::new(format!("{name}.w"), k * k * cin * cout));
        if bn {
            self.tensors.push(TensorSpec::new(format!("{name}.bn_g"), cout));
            self.tensors.push(TensorSpec::new(format!("{name}.bn_b"), cout));
        }
        self.macs += (k * k * cin * cout * out_hw * out_hw) as f64;
        self.launches += if bn { 3 } else { 1 }; // conv + bn + relu
    }

    /// Depthwise convolution: k×k per-channel filter over c channels.
    pub fn dwconv(&mut self, name: &str, k: usize, c: usize, out_hw: usize, bn: bool) {
        self.tensors.push(TensorSpec::new(format!("{name}.dw"), k * k * c));
        if bn {
            self.tensors.push(TensorSpec::new(format!("{name}.bn_g"), c));
            self.tensors.push(TensorSpec::new(format!("{name}.bn_b"), c));
        }
        self.macs += (k * k * c * out_hw * out_hw) as f64;
        self.launches += if bn { 3 } else { 1 };
    }

    /// Fully connected layer with bias.
    pub fn fc(&mut self, name: &str, cin: usize, cout: usize) {
        self.tensors.push(TensorSpec::new(format!("{name}.w"), cin * cout));
        self.tensors.push(TensorSpec::new(format!("{name}.b"), cout));
        self.macs += (cin * cout) as f64;
        self.launches += 1;
    }

    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.elems).sum()
    }

    /// GFLOPs forward (2·MACs convention).
    pub fn gflops_fwd(&self) -> f64 {
        2.0 * self.macs / 1e9
    }

    /// Tensors in backward (reverse) emission order.
    pub fn tensors_bwd_order(mut self) -> Vec<TensorSpec> {
        self.tensors.reverse();
        self.tensors
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_arithmetic() {
        let mut b = NetBuilder::new();
        b.conv("c1", 3, 16, 32, 10, true);
        assert_eq!(b.param_count(), 3 * 3 * 16 * 32 + 32 + 32);
        assert!((b.macs - (3 * 3 * 16 * 32 * 100) as f64).abs() < 1.0);
    }

    #[test]
    fn dwconv_much_cheaper_than_conv() {
        let mut dense = NetBuilder::new();
        dense.conv("c", 3, 256, 256, 14, false);
        let mut dw = NetBuilder::new();
        dw.dwconv("d", 3, 256, 14, false);
        assert!(dense.macs > 100.0 * dw.macs);
    }

    #[test]
    fn bwd_order_reverses() {
        let mut b = NetBuilder::new();
        b.fc("a", 2, 2);
        b.fc("z", 2, 2);
        let t = b.tensors_bwd_order();
        assert_eq!(t[0].name, "z.b");
        assert_eq!(t.last().unwrap().name, "a.w");
    }
}
