//! NASNet-A-Large 6@4032 (Zoph et al.) — the paper's compute-bound
//! extreme: 88.9M parameters and ≈23.8 GFLOPs (≈11.9 GMACs) forward, with
//! a huge, fragmented tensor inventory.  Its long backward pass hides the
//! gradient communication almost completely (92% efficiency at 128 GPUs in
//! Figure 9).
//!
//! Substitution note: NASNet's cell graph is enormous; we reproduce the
//! published *aggregates* (params, FLOPs, tensor-count order of magnitude,
//! channel progression) with a faithful-in-structure approximation of the
//! separable-conv cells rather than the exact 1000+-edge cell DAG.  The
//! scaling experiments depend only on these aggregates (DESIGN.md §2).

use super::layer::NetBuilder;
use super::ModelProfile;

/// One NASNet separable-conv branch: depthwise k×k + pointwise, twice
/// (NASNet separables are applied twice back-to-back).
fn sep(b: &mut NetBuilder, name: &str, k: usize, cin: usize, cout: usize, hw: usize) {
    b.dwconv(&format!("{name}.dw1"), k, cin, hw, true);
    b.conv(&format!("{name}.pw1"), 1, cin, cout, hw, true);
    b.dwconv(&format!("{name}.dw2"), k, cout, hw, true);
    b.conv(&format!("{name}.pw2"), 1, cout, cout, hw, true);
}

/// A normal cell at filter count `f`: five separable branches (5×5 and
/// 3×3 mixes) plus two 1×1 adjust convs — NASNet-A's branch inventory.
fn normal_cell(b: &mut NetBuilder, name: &str, cin: usize, f: usize, hw: usize) {
    b.conv(&format!("{name}.adj0"), 1, cin, f, hw, true);
    b.conv(&format!("{name}.adj1"), 1, cin, f, hw, true);
    sep(b, &format!("{name}.sep5a"), 5, f, f, hw);
    sep(b, &format!("{name}.sep3a"), 3, f, f, hw);
    sep(b, &format!("{name}.sep5b"), 5, f, f, hw);
    sep(b, &format!("{name}.sep3b"), 3, f, f, hw);
    sep(b, &format!("{name}.sep3c"), 3, f, f, hw);
}

/// Reduction cell: same branch mix at stride 2 (halved hw), 7×7/5×5 heavy.
fn reduction_cell(b: &mut NetBuilder, name: &str, cin: usize, f: usize, hw: usize) {
    b.conv(&format!("{name}.adj"), 1, cin, f, hw, true);
    sep(b, &format!("{name}.sep7"), 7, f, f, hw);
    sep(b, &format!("{name}.sep5"), 5, f, f, hw);
    sep(b, &format!("{name}.sep3a"), 3, f, f, hw);
    sep(b, &format!("{name}.sep3b"), 3, f, f, hw);
}

pub fn nasnet_large() -> ModelProfile {
    let mut b = NetBuilder::new();
    // stem
    b.conv("stem", 3, 3, 96, 83, true);
    // NASNet-A (6 @ 4032): filters per normal-cell output concat ≈ 1008·k.
    // Branch filter widths: 168 → 336 → 672 across the three stacks.
    // 6 normal cells per stack; concat of 6 branches ⇒ cell output 6·f.
    // Spatial sizes trimmed so the aggregate FLOPs match the published
    // 23.8 GFLOPs (our cells over-count edges vs the exact NASNet DAG).
    let stacks = [(168usize, 33usize), (336, 17), (672, 9)];
    let mut cin = 96;
    for (s, &(f, hw)) in stacks.iter().enumerate() {
        if s > 0 {
            reduction_cell(&mut b, &format!("r{s}"), cin, f, hw);
            cin = 4 * f;
        }
        for i in 0..6 {
            normal_cell(&mut b, &format!("s{s}c{i}"), cin, f, hw);
            cin = 6 * f;
        }
    }
    b.fc("fc", 4032, 1000);

    let gflops_fwd = b.gflops_fwd();
    let kernel_launches = b.launches;
    ModelProfile {
        name: "NASNet-large".to_string(),
        gflops_fwd,
        kernel_launches,
        eff_mult: 0.6, // fragmented cells + separables underutilize
        act_bytes_per_sample: 280e6,
        default_batch: 32, // batch 64 does not fit a 16GB P100 for NASNet
        tensors: b.tensors_bwd_order(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_match_published() {
        let m = nasnet_large();
        let p = m.param_count();
        assert!(
            (80_000_000..=98_000_000).contains(&p),
            "NASNet-large params {p} should be ≈88.9M"
        );
        assert!(
            m.gflops_fwd > 18.0 && m.gflops_fwd < 30.0,
            "NASNet fwd GFLOPs {} should be ≈23.8",
            m.gflops_fwd
        );
    }

    #[test]
    fn huge_fragmented_tensor_inventory() {
        let m = nasnet_large();
        assert!(m.tensors.len() > 400, "got {}", m.tensors.len());
        assert!(m.tensors.len() > 2 * super::super::resnet::resnet50().tensors.len());
    }

    #[test]
    fn slowest_model_per_image() {
        let m = nasnet_large();
        let r = super::super::resnet::resnet50();
        assert!(m.gflops_fwd > 2.0 * r.gflops_fwd);
    }
}
