//! Hardware substrate models: GPUs, interconnects, node topology, and the
//! three testbeds of the paper (RI2, Owens, Piz Daint).
//!
//! Substitution note (DESIGN.md §2): these are analytic cost models
//! calibrated against the era-appropriate published numbers (tf_cnn_
//! benchmarks throughputs, IB EDR / Aries link specs).  The *figures* of
//! the paper depend only on the relative composition of compute and
//! communication, which these models reproduce; the *numerics* of training
//! are exercised for real through the PJRT runtime.

pub mod gpu;
pub mod interconnect;
pub mod presets;
pub mod topology;

pub use gpu::GpuModel;
pub use interconnect::{Fabric, Link};
pub use topology::{ClusterSpec, Placement};
