//! Cluster topology: nodes × GPUs-per-node over a fabric.

use super::gpu::GpuModel;
use super::interconnect::Fabric;

/// One testbed (all three of the paper's systems are 1 GPU per node, which
/// keeps rank == node; the struct still carries `gpus_per_node` so denser
/// systems like DGX boxes can be expressed).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub gpu: GpuModel,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub fabric: Fabric,
    /// CUDA driver pointer-attribute query cost, µs (the §V-B overhead the
    /// pointer cache removes; per-query, and MPI issues several per call).
    pub driver_query_us: f64,
}

impl ClusterSpec {
    pub fn max_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Rank → node placement (block distribution).
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    /// Are two ranks on the same node?
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Validate a requested world size against the machine.
    pub fn check_world(&self, world: usize) -> crate::util::error::Result<()> {
        crate::ensure!(world >= 1, "world size must be ≥ 1");
        crate::ensure!(
            world <= self.max_gpus(),
            "{} has only {} GPUs (requested {world})",
            self.name,
            self.max_gpus()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::presets;

    #[test]
    fn placement_block_distribution() {
        let mut c = presets::ri2();
        c.gpus_per_node = 2;
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(1), 0);
        assert_eq!(c.node_of(2), 1);
        assert!(c.same_node(0, 1));
        assert!(!c.same_node(1, 2));
    }

    #[test]
    fn world_bounds_enforced() {
        let c = presets::ri2();
        assert!(c.check_world(16).is_ok());
        assert!(c.check_world(0).is_err());
        assert!(c.check_world(10_000).is_err());
    }
}
