//! Cluster topology: nodes × GPUs-per-node over a fabric, plus the
//! [`Placement`] map (rank → node, rank → NIC rail) the resource layers
//! lay their per-node bundles out over.

use super::gpu::GpuModel;
use super::interconnect::Fabric;

/// Rank → node placement plus the node's NIC rail layout: the geometry
/// `GraphResources` (comm/graph.rs) lays resource bundles out over.
/// Ranks distribute over nodes in blocks (`node_of`), co-located ranks
/// round-robin over the node's `rails` independent NIC ports
/// (`rail_of`).  With `gpus_per_node == 1` and `rails == 1` — every
/// cluster in the paper — the placement is *trivial*: rank ≡ node,
/// one port per node, and the placed paths are bit-identical to the
/// historical per-rank bundles (pinned by `tests/proptest_lite.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Placement {
    pub gpus_per_node: usize,
    /// Independent NIC rails per node (dual-rail IB and the like).
    pub rails: usize,
}

impl Placement {
    pub fn new(gpus_per_node: usize, rails: usize) -> Placement {
        assert!(gpus_per_node >= 1, "placement needs >= 1 GPU per node");
        assert!(rails >= 1, "placement needs >= 1 NIC rail per node");
        Placement { gpus_per_node, rails }
    }

    /// The paper's layout: one GPU (rank) per node, single-rail NICs.
    pub fn one_per_node() -> Placement {
        Placement { gpus_per_node: 1, rails: 1 }
    }

    /// Trivial placements change nothing: rank ≡ node, port ≡ node.
    pub fn is_trivial(&self) -> bool {
        self.gpus_per_node == 1 && self.rails == 1
    }

    /// Rank → node (block distribution).
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    /// Rank → local index on its node.
    pub fn local_of(&self, rank: usize) -> usize {
        rank % self.gpus_per_node
    }

    /// Rank → NIC rail on its node (round-robin over local index).
    pub fn rail_of(&self, rank: usize) -> usize {
        self.local_of(rank) % self.rails
    }

    /// Are two ranks on the same node?
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Nodes a world of `ranks` ranks occupies.
    pub fn nodes_for(&self, ranks: usize) -> usize {
        ranks.div_ceil(self.gpus_per_node)
    }

    /// The cache-key signature: two placements with different layouts
    /// must never alias one graph template.
    pub fn key(&self) -> (usize, usize) {
        (self.gpus_per_node, self.rails)
    }
}

impl Default for Placement {
    fn default() -> Placement {
        Placement::one_per_node()
    }
}

/// One testbed (all three of the paper's systems are 1 GPU per node, which
/// keeps rank == node; the struct still carries `gpus_per_node` and
/// `nic_rails` so denser systems like DGX boxes can be expressed).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub gpu: GpuModel,
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Independent NIC rails per node (1 everywhere in the paper).
    pub nic_rails: usize,
    pub fabric: Fabric,
    /// CUDA driver pointer-attribute query cost, µs (the §V-B overhead the
    /// pointer cache removes; per-query, and MPI issues several per call).
    pub driver_query_us: f64,
}

impl ClusterSpec {
    pub fn max_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// The cluster's rank/rail layout as a [`Placement`].
    pub fn placement(&self) -> Placement {
        Placement::new(self.gpus_per_node, self.nic_rails)
    }

    /// Rank → node placement (block distribution).
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    /// Are two ranks on the same node?
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Validate a requested world size against the machine.
    pub fn check_world(&self, world: usize) -> crate::util::error::Result<()> {
        crate::ensure!(world >= 1, "world size must be ≥ 1");
        crate::ensure!(
            world <= self.max_gpus(),
            "{} has only {} GPUs (requested {world})",
            self.name,
            self.max_gpus()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::presets;

    #[test]
    fn placement_block_distribution() {
        let mut c = presets::ri2();
        c.gpus_per_node = 2;
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(1), 0);
        assert_eq!(c.node_of(2), 1);
        assert!(c.same_node(0, 1));
        assert!(!c.same_node(1, 2));
    }

    #[test]
    fn placement_rails_round_robin_and_triviality() {
        use super::Placement;
        let p = Placement::new(4, 2);
        assert!(!p.is_trivial());
        assert_eq!((0..4).map(|r| p.node_of(r)).collect::<Vec<_>>(), vec![0, 0, 0, 0]);
        assert_eq!(p.node_of(4), 1);
        // local ranks 0..3 round-robin over 2 rails
        assert_eq!((0..4).map(|r| p.rail_of(r)).collect::<Vec<_>>(), vec![0, 1, 0, 1]);
        assert_eq!(p.rail_of(5), 1); // rank 5 = node 1, local 1
        assert_eq!(p.nodes_for(5), 2);
        assert_eq!(p.nodes_for(8), 2);
        assert!(Placement::one_per_node().is_trivial());
        assert_eq!(Placement::default(), Placement::one_per_node());
        assert_ne!(Placement::new(2, 1).key(), Placement::new(2, 2).key());
        let mut c = presets::ri2();
        assert_eq!(c.placement(), Placement::one_per_node());
        c.gpus_per_node = 2;
        c.nic_rails = 2;
        assert_eq!(c.placement().key(), (2, 2));
    }

    #[test]
    fn world_bounds_enforced() {
        let c = presets::ri2();
        assert!(c.check_world(16).is_ok());
        assert!(c.check_world(0).is_err());
        assert!(c.check_world(10_000).is_err());
    }
}
