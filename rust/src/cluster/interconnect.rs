//! Interconnect models: α–β links plus the host-staging path.
//!
//! A point-to-point transfer of `n` bytes costs `α + n/β`.  CUDA-Aware
//! paths with GPUDirect RDMA (GDR) go NIC↔GPU directly; non-CUDA-aware
//! paths stage through host memory, adding PCIe copies each way — the
//! paper's §II-B motivation for CUDA-Aware MPI.

use crate::sim::SimTime;

/// One α–β link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    pub name: &'static str,
    /// One-way latency, µs.
    pub alpha_us: f64,
    /// Effective bandwidth, GB/s.
    pub beta_gbs: f64,
}

impl Link {
    pub const fn new(name: &'static str, alpha_us: f64, beta_gbs: f64) -> Link {
        Link { name, alpha_us, beta_gbs }
    }

    /// Time to move `bytes` over this link.
    pub fn transfer(&self, bytes: usize) -> SimTime {
        SimTime::from_us(self.alpha_us + bytes as f64 / (self.beta_gbs * 1e3))
    }

    /// Bandwidth-only component (µs), for overlap math.
    pub fn wire_us(&self, bytes: usize) -> f64 {
        bytes as f64 / (self.beta_gbs * 1e3)
    }

    // ---- presets (era-appropriate published characteristics) ----

    /// InfiniBand EDR (100 Gb/s): native verbs path.
    pub const fn ib_edr() -> Link {
        Link::new("IB-EDR", 2.5, 10.5)
    }

    /// IP-over-IB on the same EDR HCA: the TCP/IP stack costs both
    /// latency and bandwidth (single-stream IPoIB in the TF 1.x era
    /// delivered ~1.4 GB/s, far below the 12.5 GB/s wire rate).
    pub const fn ipoib_edr() -> Link {
        Link::new("IPoIB-EDR", 25.0, 1.4)
    }

    /// Cray Aries (Piz Daint dragonfly).
    pub const fn aries() -> Link {
        Link::new("Aries", 1.8, 9.0)
    }

    /// PCIe gen3 x16 host↔device staging copies.
    pub const fn pcie3() -> Link {
        Link::new("PCIe3x16", 5.0, 11.0)
    }
}

/// The communication fabric of one cluster: inter-node link, the host
/// staging link, and whether GPUDirect RDMA is available.
#[derive(Debug, Clone, PartialEq)]
pub struct Fabric {
    pub inter: Link,
    /// TCP/IP-style path on the same wires (gRPC rides this: IPoIB on IB
    /// machines, the TCP service on Aries).
    pub tcp: Link,
    pub pcie: Link,
    /// GPUDirect RDMA available (NIC reads/writes GPU memory directly).
    pub gdr: bool,
    /// IB verbs available (NCCL2 inter-node requires it — absent on Aries,
    /// which is why Horovod-NCCL cannot run on Piz Daint, §VI-D).
    pub ib_verbs: bool,
    /// Congestion coefficient for collective traffic at scale: effective
    /// β divides by `1 + contention·log₂(p/8)` for p > 8 ranks.  Zero on
    /// the non-blocking EDR fat-trees (RI2/Owens); positive on the Aries
    /// dragonfly, where the paper notes "placement ... is random and can
    /// influence the actual execution time" (§VI-D).
    pub contention: f64,
}

impl Fabric {
    pub const fn ib_edr_gdr() -> Fabric {
        Fabric {
            inter: Link::ib_edr(),
            tcp: Link::ipoib_edr(),
            pcie: Link::pcie3(),
            gdr: true,
            ib_verbs: true,
            contention: 0.0,
        }
    }

    pub const fn aries() -> Fabric {
        Fabric {
            inter: Link::aries(),
            tcp: Link::new("Aries-TCP", 18.0, 1.4),
            pcie: Link::pcie3(),
            gdr: false,
            ib_verbs: false,
            contention: 0.35,
        }
    }

    /// Effective β divisor for a `p`-rank collective on this fabric.
    pub fn contention_factor(&self, p: usize) -> f64 {
        if p > 8 && self.contention > 0.0 {
            1.0 + self.contention * (p as f64 / 8.0).log2()
        } else {
            1.0
        }
    }

    /// Intra-node hop cost multiplier for placed collective graphs: a
    /// hop between two ranks on one node rides the node's PCIe/NVLink
    /// path instead of the NIC, so its wire component scales by
    /// inter-node β ÷ local β (< 1 when the local link is faster).
    pub fn local_hop_factor(&self) -> f64 {
        self.inter.beta_gbs / self.pcie.beta_gbs
    }

    /// GPU-to-GPU p2p transfer time for `bytes`, CUDA-aware path.
    /// With GDR: straight over the NIC.  Without: staged D2H → wire → H2D.
    pub fn p2p_cuda_aware(&self, bytes: usize) -> SimTime {
        if self.gdr {
            self.inter.transfer(bytes)
        } else {
            self.staged(bytes)
        }
    }

    /// Host-staged GPU-to-GPU transfer: D2H copy, wire, H2D copy.
    pub fn staged(&self, bytes: usize) -> SimTime {
        self.pcie.transfer(bytes) + self.inter.transfer(bytes) + self.pcie.transfer(bytes)
    }

    /// Host-to-host transfer (CPU buffers, already staged).
    pub fn host_to_host(&self, bytes: usize) -> SimTime {
        self.inter.transfer(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_alpha_dominates_small() {
        let l = Link::ib_edr();
        let t = l.transfer(8);
        assert!((t.as_us() - l.alpha_us).abs() < 0.01, "{t}");
    }

    #[test]
    fn transfer_beta_dominates_large() {
        let l = Link::ib_edr();
        let bytes = 256 * 1024 * 1024;
        let t = l.transfer(bytes);
        let wire = bytes as f64 / (l.beta_gbs * 1e3);
        assert!((t.as_us() - wire) / wire < 0.001);
        // 256MB over 10.5 GB/s ≈ 25.6ms
        assert!(t.as_ms() > 20.0 && t.as_ms() < 30.0);
    }

    #[test]
    fn ipoib_slower_than_verbs() {
        let n = 1 << 20;
        assert!(Link::ipoib_edr().transfer(n) > Link::ib_edr().transfer(n));
        assert!(Link::ipoib_edr().alpha_us > 5.0 * Link::ib_edr().alpha_us);
    }

    #[test]
    fn gdr_beats_staging() {
        let f = Fabric::ib_edr_gdr();
        let n = 1 << 22;
        let direct = f.p2p_cuda_aware(n);
        let staged = f.staged(n);
        assert!(staged.as_us() > 2.5 * direct.as_us(), "staged {staged} vs direct {direct}");
    }

    #[test]
    fn local_hop_factor_is_beta_ratio() {
        for f in [Fabric::ib_edr_gdr(), Fabric::aries()] {
            let k = f.local_hop_factor();
            assert!((k - f.inter.beta_gbs / f.pcie.beta_gbs).abs() < 1e-12);
            assert!(k > 0.0 && k.is_finite());
        }
        // both era fabrics have PCIe3 at least as fast as the wire, so
        // intra-node hops never cost more than the NIC path
        assert!(Fabric::ib_edr_gdr().local_hop_factor() <= 1.0);
        assert!(Fabric::aries().local_hop_factor() <= 1.0);
    }

    #[test]
    fn aries_has_no_verbs_or_gdr() {
        let f = Fabric::aries();
        assert!(!f.ib_verbs && !f.gdr);
        // non-GDR fabric: CUDA-aware p2p falls back to staging
        assert_eq!(f.p2p_cuda_aware(1024), f.staged(1024));
    }
}
