//! The paper's three testbeds (§VI).

use super::gpu::GpuModel;
use super::interconnect::Fabric;
use super::topology::ClusterSpec;

/// RI2 @ OSU: 20 nodes, 1× K80 each, IB EDR, MVAPICH2-GDR capable.
/// The paper uses up to 16 GPUs here (Figures 3, 4, 6, 7).
pub fn ri2() -> ClusterSpec {
    ClusterSpec {
        name: "RI2",
        gpu: GpuModel::k80(),
        nodes: 20,
        gpus_per_node: 1,
        nic_rails: 1,
        fabric: Fabric::ib_edr_gdr(),
        driver_query_us: 1.0,
    }
}

/// Owens @ OSC: 160 GPU nodes, 1× P100 each, IB EDR (Figure 8, ≤64 GPUs).
pub fn owens() -> ClusterSpec {
    ClusterSpec {
        name: "Owens",
        gpu: GpuModel::p100(),
        nodes: 160,
        gpus_per_node: 1,
        nic_rails: 1,
        fabric: Fabric::ib_edr_gdr(),
        driver_query_us: 1.0,
    }
}

/// Piz Daint @ CSCS: 1× P100 per node, Cray Aries dragonfly — no IB verbs
/// (so no NCCL2) and no GDR for the stock MPI (Figure 9, ≤128 GPUs).
pub fn piz_daint() -> ClusterSpec {
    ClusterSpec {
        name: "PizDaint",
        gpu: GpuModel::p100(),
        nodes: 5704,
        gpus_per_node: 1,
        nic_rails: 1,
        fabric: Fabric::aries(),
        driver_query_us: 1.2,
    }
}

/// Look a preset up by (case-insensitive) name.
pub fn by_name(name: &str) -> crate::util::error::Result<ClusterSpec> {
    match name.to_ascii_lowercase().as_str() {
        "ri2" => Ok(ri2()),
        "owens" => Ok(owens()),
        "pizdaint" | "piz_daint" | "piz-daint" => Ok(piz_daint()),
        other => crate::bail!("unknown cluster `{other}` (ri2 | owens | pizdaint)"),
    }
}

pub fn all() -> Vec<ClusterSpec> {
    vec![ri2(), owens(), piz_daint()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_hardware() {
        let r = ri2();
        assert_eq!(r.gpu.name, "K80");
        assert!(r.max_gpus() >= 16);
        assert!(r.fabric.ib_verbs);

        let o = owens();
        assert_eq!(o.gpu.name, "P100");
        assert!(o.max_gpus() >= 64);

        let p = piz_daint();
        assert_eq!(p.gpu.name, "P100");
        assert!(p.max_gpus() >= 128);
        assert!(!p.fabric.ib_verbs, "NCCL2 must be unavailable on Piz Daint");
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("RI2").unwrap().name, "RI2");
        assert_eq!(by_name("piz-daint").unwrap().name, "PizDaint");
        assert!(by_name("summit").is_err());
        assert_eq!(all().len(), 3);
    }
}
