//! GPU compute model: saturating-efficiency roofline.
//!
//! Iteration time for a DNN on one GPU:
//!
//!   t_iter(b) = launch·L + (b · flops_per_sample) / (peak · eff(b))
//!   eff(b)    = eff_max · b / (b + b_half)
//!
//! The hyperbolic efficiency term captures what Figure 2 of the paper
//! shows: throughput rises with batch size and flattens past a sweet spot,
//! and *faster* GPUs need *larger* batches to saturate (bigger `b_half`).
//! Constants are calibrated so ResNet-50 at batch 64 lands on the
//! era-published tf_cnn_benchmarks throughputs (K80 ≈ 52, P100 ≈ 190,
//! V100 ≈ 330 img/s, fp32, TF 1.10).

use crate::sim::SimTime;

#[derive(Debug, Clone, PartialEq)]
pub struct GpuModel {
    pub name: &'static str,
    /// Peak fp32 throughput in GFLOP/s.
    pub peak_gflops: f64,
    /// HBM/GDDR bandwidth in GB/s (reduction kernels are BW-bound).
    pub mem_bw_gbs: f64,
    /// Device memory in GiB (bounds feasible batch size).
    pub mem_gib: f64,
    /// CUDA kernel launch overhead, µs (paid per launched kernel).
    pub launch_us: f64,
    /// Peak fraction achieved at b → ∞ for DNN workloads.
    pub eff_max: f64,
    /// Batch size at which efficiency reaches eff_max/2.
    pub b_half: f64,
}

impl GpuModel {
    pub const fn k80() -> GpuModel {
        // One GK210 die of the dual-die K80 board (what TF sees as a device).
        GpuModel {
            name: "K80",
            peak_gflops: 2800.0,
            mem_bw_gbs: 240.0,
            mem_gib: 12.0,
            launch_us: 8.0,
            eff_max: 0.50,
            b_half: 8.0,
        }
    }

    pub const fn p100() -> GpuModel {
        GpuModel {
            name: "P100",
            peak_gflops: 9300.0,
            mem_bw_gbs: 732.0,
            mem_gib: 16.0,
            launch_us: 6.0,
            eff_max: 0.56,
            b_half: 10.0,
        }
    }

    pub const fn v100() -> GpuModel {
        GpuModel {
            name: "V100",
            peak_gflops: 14000.0,
            mem_bw_gbs: 900.0,
            mem_gib: 16.0,
            launch_us: 5.0,
            eff_max: 0.66,
            b_half: 12.0,
        }
    }

    /// Achieved fraction of peak at batch size `b`.
    pub fn efficiency(&self, batch: usize) -> f64 {
        let b = batch as f64;
        self.eff_max * b / (b + self.b_half)
    }

    /// Forward+backward time for one iteration of a workload described by
    /// (flops per sample fwd+bwd, kernel launches per iteration).
    pub fn iter_time(&self, flops_per_sample: f64, kernel_launches: usize, batch: usize) -> SimTime {
        // flops_per_sample is in GFLOP; peak is GFLOP/s ⇒ seconds ⇒ µs.
        let compute_us =
            batch as f64 * flops_per_sample / (self.peak_gflops * self.efficiency(batch)) * 1e6;
        let launch_us = self.launch_us * kernel_launches as f64;
        SimTime::from_us(compute_us + launch_us)
    }

    /// Images (samples) per second at the given batch size.
    pub fn throughput(&self, flops_per_sample: f64, kernel_launches: usize, batch: usize) -> f64 {
        batch as f64 / self.iter_time(flops_per_sample, kernel_launches, batch).as_secs()
    }

    /// Time for the on-device reduction of `bytes` (the §V-A CUDA-kernel
    /// reduction): streams 2 reads + 1 write per element through HBM.
    pub fn reduce_time(&self, bytes: usize) -> SimTime {
        SimTime::from_us(self.launch_us + 3.0 * bytes as f64 / (self.mem_bw_gbs * 1e3))
    }

    /// Rough feasibility bound: does a `batch`-sized ResNet-50-class
    /// workload fit in device memory?  (~62 MB activations per sample +
    /// ~400 MB weights/optimizer state; coarse, per paper Fig 2's axis.)
    pub fn batch_fits(&self, bytes_per_sample: f64, batch: usize) -> bool {
        let need_gib = (400e6 + bytes_per_sample * batch as f64) / (1u64 << 30) as f64;
        need_gib <= self.mem_gib * 0.9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RESNET50_FLOPS_FB: f64 = 24.6; // GFLOP fwd+bwd per image (8.2 fwd × 3, 2·MACs)

    #[test]
    fn efficiency_monotone_saturating() {
        let g = GpuModel::p100();
        let e1 = g.efficiency(1);
        let e64 = g.efficiency(64);
        let e256 = g.efficiency(256);
        assert!(e1 < e64 && e64 < e256);
        assert!(e256 < g.eff_max);
    }

    #[test]
    fn resnet50_batch64_calibration() {
        // Paper Fig 2 era numbers: K80 ≈ 50, P100 ≈ 190, V100 ≈ 330 img/s.
        let cases = [
            (GpuModel::k80(), 40.0, 65.0),
            (GpuModel::p100(), 160.0, 230.0),
            (GpuModel::v100(), 280.0, 390.0),
        ];
        for (g, lo, hi) in cases {
            let t = g.throughput(RESNET50_FLOPS_FB, 250, 64);
            assert!(t > lo && t < hi, "{}: {t} img/s not in [{lo}, {hi}]", g.name);
        }
    }

    #[test]
    fn faster_gpus_keep_gaining_at_larger_batch() {
        // Fig 2 insight: V100 gains more than K80 when going 32 → 128.
        let gain = |g: &GpuModel| {
            g.throughput(RESNET50_FLOPS_FB, 250, 128) / g.throughput(RESNET50_FLOPS_FB, 250, 32)
        };
        assert!(gain(&GpuModel::v100()) > gain(&GpuModel::k80()));
    }

    #[test]
    fn diminishing_returns_past_sweet_spot() {
        let g = GpuModel::k80();
        let t64 = g.throughput(RESNET50_FLOPS_FB, 250, 64);
        let t128 = g.throughput(RESNET50_FLOPS_FB, 250, 128);
        assert!(t128 / t64 < 1.10, "gain past 64 should be <10%, got {}", t128 / t64);
    }

    #[test]
    fn reduce_time_bandwidth_bound() {
        let g = GpuModel::p100();
        let t_small = g.reduce_time(1024);
        let t_large = g.reduce_time(256 * 1024 * 1024);
        // small reductions are launch-dominated
        assert!((t_small.as_us() - g.launch_us).abs() < 1.0);
        // large: 3·256MB / 732GB/s ≈ 1.1ms
        assert!(t_large.as_ms() > 0.8 && t_large.as_ms() < 1.5, "{t_large}");
    }

    #[test]
    fn memory_bound_on_batch() {
        let g = GpuModel::k80();
        assert!(g.batch_fits(62e6, 64));
        assert!(!g.batch_fits(62e6, 256));
    }
}
