//! Horovod (§III-C2): allreduce-based data parallelism with tensor fusion
//! and a background communication thread.
//!
//! Model: gradient tensors become ready back-to-front during the backward
//! pass; ready tensors are greedily packed into fusion buffers (threshold
//! = `fusion_bytes`); each buffer costs one coordination round (the
//! rank-0 negotiation of §III-C2) plus one Allreduce on the configured
//! backend.  The Allreduce is a `CommOp` schedule (wire, staging, reduce
//! kernel, driver, launch steps) replayed onto the discrete-event engine;
//! the background thread is a *stream-lane set* (§Overlap): at the
//! default `streams = 1` buffers serialize exactly like the historical
//! comm-thread gate — buffer *i* starts at max(ready_i, done_{i−1}) —
//! while `streams > 1` launches ready buffers round-robin across lanes
//! so their graphs interleave on the per-rank wire/PCIe resources
//! (NCCL-stream semantics; `HOROVOD_NUM_NCCL_STREAMS`).  When another
//! job shares the fabric, every wire step queues behind the co-tenant's
//! traffic either way.  When the
//! scenario skews individual ranks (stragglers, hetero mixes, per-step
//! jitter) the Allreduce instead executes as a per-rank `CommGraph`
//! ([`Horovod::iteration_graph`]) so the skew propagates along ring/RHD
//! dependency edges rather than shifting the whole schedule.  Iteration
//! ends
//! when both compute and the last Allreduce finish — whatever
//! communication didn't fit under the backward pass is the "exposed" time
//! that erodes scaling efficiency (the Figure 9 story: MobileNet exposes
//! almost everything, NASNet almost nothing).

use std::collections::HashMap;
use std::rc::Rc;

use crate::util::error::Result;

use super::scenario::Scenario;
use super::{IterationReport, JobTrace, LaneJob, Strategy, WorldSpec};
use crate::cluster::ClusterSpec;
use crate::comm::allreduce::Algo;
use crate::comm::commop::{
    resolve_ops, steps_sig, CommOp, CommResources, CommSchedule, ResKind, StepCost,
};
use crate::comm::graph::{allreduce_graph_placed, GraphResources, TemplateCache, TemplateKey};
use crate::comm::nccl::NcclWorld;
use crate::comm::{MpiFlavor, MpiWorld};
use crate::sim::{Engine, ProgStep, SimTime};

/// Which collective library backs the Allreduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HorovodBackend {
    Mpi(MpiFlavor),
    Nccl,
}

#[derive(Debug, Clone)]
pub struct Horovod {
    pub backend: HorovodBackend,
    /// Tensor-fusion buffer threshold (Horovod default 64 MB; the paper
    /// tunes it per platform — the ablation bench sweeps it).
    pub fusion_bytes: usize,
    /// Fusion cycle period, µs (HOROVOD_CYCLE_TIME, 5 ms in this era):
    /// tensors becoming ready within one cycle window fuse together; a
    /// buffer launches no earlier than its cycle boundary.
    pub cycle_us: f64,
    /// Per-cycle coordination cost coefficients: the rank-0 coordinator
    /// gathers readiness bitmaps and broadcasts the fusion plan.
    pub coord_alpha_hops: f64,
    pub coord_per_rank_us: f64,
    /// TF-runtime dilation of distributed steps (graph-rewrite operators,
    /// stream synchronization): iter compute is stretched by
    /// `1 + tax·(1 − 1/p)`.  Calibrated against the paper's ≈98% RI2@16
    /// and ≈90% Owens@64 efficiencies.
    pub runtime_tax: f64,
    /// Per-iteration synchronization skew, µs per rank: every synchronous
    /// step ends with an implicit barrier, and the slowest of p ranks
    /// (stragglers, OS noise, placement) sets the pace.  This fixed cost
    /// is why *short-iteration* models (MobileNet) scale worst in Figure 9
    /// — the paper's "communication cannot be hidden behind the relatively
    /// smaller computation".
    pub skew_us_per_rank: f64,
    /// Build-once/replay-many graph templates (§Perf), keyed by
    /// `(algo, world, step-cost signature ⧺ coord cost)`.  Shared across
    /// clones; any knob that changes a buffer's per-step costs changes
    /// the key, so hits can never be stale.
    pub cache: TemplateCache,
}

impl Horovod {
    pub fn mpi(flavor: MpiFlavor) -> Horovod {
        Horovod {
            backend: HorovodBackend::Mpi(flavor),
            fusion_bytes: 64 << 20,
            cycle_us: 5_000.0,
            coord_alpha_hops: 2.0,
            coord_per_rank_us: 0.4,
            runtime_tax: 0.02,
            skew_us_per_rank: 470.0,
            cache: TemplateCache::default(),
        }
    }

    pub fn nccl() -> Horovod {
        Horovod { backend: HorovodBackend::Nccl, ..Horovod::mpi(MpiFlavor::Mvapich2) }
    }

    fn backend_name(&self) -> String {
        match self.backend {
            HorovodBackend::Mpi(MpiFlavor::Mvapich2) => "Horovod-MPI".into(),
            HorovodBackend::Mpi(MpiFlavor::Mvapich2GdrOpt) => "Horovod-MPI-Opt".into(),
            HorovodBackend::Mpi(MpiFlavor::CrayMpich) => "Horovod-MPI (Cray)".into(),
            HorovodBackend::Mpi(MpiFlavor::Mpich) => "Horovod-MPICH".into(),
            HorovodBackend::Nccl => "Horovod-NCCL".into(),
        }
    }

    /// The Allreduce of one fused buffer as its per-step cost sequence
    /// (plus the selected algorithm), and the share of its host staging
    /// that contends with the training stream on PCIe (only the bandwidth
    /// term — the per-copy DMA-setup α's pipeline away) and therefore
    /// rides the compute-side critical path even when the wire time hides
    /// under the backward pass.
    fn buffer_steps(
        &self,
        ws: &WorldSpec,
        sc: &Scenario,
        bytes: usize,
    ) -> Result<(Algo, Vec<StepCost>, f64)> {
        let derate = sc.wire_derate();
        let (algo, report, steps) = match self.backend {
            HorovodBackend::Mpi(flavor) => {
                let w = MpiWorld::new(flavor, ws.cluster.clone());
                w.allreduce_steps(ws.world, bytes, derate)
            }
            HorovodBackend::Nccl => {
                let w = NcclWorld::new(ws.cluster.clone())?;
                w.allreduce_steps(ws.world, bytes, derate)
            }
        };
        let pcie = ws.cluster.fabric.pcie.beta_gbs * 1e3;
        let staging_crit = (4.0 * bytes as f64 / pcie).min(report.cost.staging_us);
        Ok((algo, steps, staging_crit))
    }

    /// The buffer's serialized (critical-path) schedule — the fast replay
    /// the strategy uses whenever no scenario knob skews ranks apart.
    fn buffer_schedule(
        &self,
        ws: &WorldSpec,
        sc: &Scenario,
        bytes: usize,
    ) -> Result<(CommSchedule, f64)> {
        let (_, steps, staging_crit) = self.buffer_steps(ws, sc, bytes)?;
        Ok((CommSchedule::from_steps(&steps), staging_crit))
    }

    /// Coordination cost per fusion cycle at world size `p`.
    pub fn coord_us(&self, ws: &WorldSpec) -> f64 {
        let p = ws.world as f64;
        let hops = (ws.world.max(2) as f64).log2().ceil();
        self.coord_alpha_hops * hops * ws.cluster.fabric.inter.alpha_us
            + self.coord_per_rank_us * p
    }

    /// Pack ready tensors into fusion buffers: (ready_time, bytes).
    /// A buffer closes when it would exceed the threshold OR when the
    /// next tensor lands in a later fusion cycle.
    pub fn fusion_schedule(&self, ws: &WorldSpec) -> Vec<(SimTime, usize)> {
        self.fusion_schedule_in(ws, 1.0)
    }

    /// Fusion schedule with the slowest rank's compute stretched by
    /// `stretch` (scenario stragglers / heterogeneous nodes): a collective
    /// cannot start before its slowest producer.
    pub fn fusion_schedule_in(&self, ws: &WorldSpec, stretch: f64) -> Vec<(SimTime, usize)> {
        let cycle_of = |t: SimTime| (t.as_us() / self.cycle_us).floor() as i64;
        let compute = SimTime::from_us(ws.compute_time().as_us() * stretch);
        let launch_of = |ready: SimTime| {
            // the buffer launches at its cycle boundary (never past the
            // end of the backward pass)
            let boundary = SimTime::from_us((cycle_of(ready) + 1) as f64 * self.cycle_us);
            ready.max(boundary.min(compute))
        };
        let mut buffers = Vec::new();
        let mut cur_bytes = 0usize;
        let mut cur_ready = SimTime::ZERO;
        for (i, ready) in ws.tensor_readiness() {
            let ready = SimTime::from_us(ready.as_us() * stretch);
            let bytes = ws.model.tensors[i].bytes();
            let splits = cur_bytes > 0
                && (cur_bytes + bytes > self.fusion_bytes || cycle_of(ready) != cycle_of(cur_ready));
            if splits {
                buffers.push((launch_of(cur_ready), cur_bytes));
                cur_bytes = 0;
            }
            cur_bytes += bytes;
            cur_ready = ready; // buffer is ready when its LAST tensor is
        }
        if cur_bytes > 0 {
            // same cycle-boundary launch rule as every other buffer (the
            // final buffer used to skip it; for a full backward pass
            // cur_ready == compute end, so the value is unchanged — this
            // closes the inconsistency, not the number)
            buffers.push((launch_of(cur_ready), cur_bytes));
        }
        buffers
    }

    /// Schedule one training job's communication onto an engine: the
    /// fused buffers' [coordination + Allreduce] op programs release at
    /// their ready times onto the job's comm stream lanes (`streams = 1`
    /// = the classic background comm thread: FIFO, one buffer at a
    /// time).  §Perf: programs are resolved once per buffer-size bucket
    /// and shared across buffers, and the buffer loop schedules only
    /// typed lane events — no `Engine::at` closure, no boxed gate waiter
    /// per buffer (the retired "gate waiters box one closure per
    /// acquire" follow-up).
    pub(crate) fn schedule_job(
        &self,
        ws: &WorldSpec,
        sc: &Scenario,
        e: &mut Engine,
        res: CommResources,
    ) -> Result<LaneJob> {
        let coord = self.coord_us(ws);
        let map = res.mapper();
        let mut memo: HashMap<usize, (Rc<[ProgStep]>, f64)> = HashMap::new();
        let mut staging_total = 0.0;
        let mut items = Vec::new();
        for (ready, bytes) in self.fusion_schedule_in(ws, sc.compute_stretch()) {
            let (steps, staging) = match memo.get(&bytes) {
                Some(hit) => hit.clone(),
                None => {
                    let (sched, staging) = self.buffer_schedule(ws, sc, bytes)?;
                    let mut ops = Vec::with_capacity(sched.ops.len() + 1);
                    ops.push(CommOp::fixed(ResKind::Sw, coord));
                    ops.extend(sched.ops);
                    let built = (resolve_ops(&ops, &map), staging);
                    memo.insert(bytes, built.clone());
                    built
                }
            };
            staging_total += staging;
            items.push((ready, steps));
        }
        Ok(LaneJob::programs(e, sc.lanes(), items, staging_total, SimTime::ZERO))
    }

    /// Fold a finished job trace into an iteration time (see
    /// `strategies::close_iteration`).
    pub(crate) fn close_job(
        &self,
        ws: &WorldSpec,
        sc: &Scenario,
        trace: &JobTrace,
        offset: SimTime,
    ) -> SimTime {
        self.close_parts(ws, sc, trace, offset).iter
    }

    /// [`Horovod::close_job`] keeping the closing formula's terms for
    /// the trace attribution report (§Observability).
    pub(crate) fn close_parts(
        &self,
        ws: &WorldSpec,
        sc: &Scenario,
        trace: &JobTrace,
        offset: SimTime,
    ) -> crate::sim::IterationParts {
        super::close_iteration_parts(ws, sc, trace, offset, self.runtime_tax, self.skew_us_per_rank)
    }

    /// The iteration's fused buffers as cached graph templates plus
    /// their per-buffer overlays and release times — the unit both
    /// [`Horovod::iteration_graph`] and the two-job graph-path
    /// link-share runner schedule.  Templates are built under the
    /// cluster's [`Placement`](crate::cluster::Placement): hops between
    /// co-located ranks re-cost onto the node-local link, and the
    /// placement (plus the intra-hop factor) joins the cache key so
    /// layouts can never alias.
    pub(crate) fn graph_items(
        &self,
        ws: &WorldSpec,
        sc: &Scenario,
    ) -> Result<Vec<super::GraphWork>> {
        let place = ws.cluster.placement();
        let local = ws.cluster.fabric.local_hop_factor();
        let coord = self.coord_us(ws);
        let buffers = self.fusion_schedule_in(ws, sc.compute_stretch());
        let mut items = Vec::with_capacity(buffers.len());
        for (bi, (ready, bytes)) in buffers.into_iter().enumerate() {
            let (algo, steps, staging) = self.buffer_steps(ws, sc, bytes)?;
            // the coord cost and intra-hop factor are baked into the
            // template (root node / re-kinded hop durations), so they
            // are part of the cache key alongside the step costs
            let mut sig = steps_sig(&steps);
            sig.push(coord.to_bits());
            sig.push(local.to_bits());
            let template = self.cache.get_or_build(
                TemplateKey::allreduce_placed(algo, ws.world, place, sig),
                || {
                    let mut g = allreduce_graph_placed(algo, ws.world, &steps, place, local);
                    // the rank-0 negotiation round gates every rank's
                    // first step
                    g.prefix_root(0, vec![CommOp::fixed(ResKind::Sw, coord)]);
                    g
                },
            );
            items.push(super::GraphWork {
                ready,
                template,
                overlay: sc.overlay(ws.world, bi as u64),
                staging_us: staging,
            });
        }
        Ok(items)
    }

    /// One iteration with every fused buffer executed as a **per-rank
    /// dependency graph** on placement-aware node-local resources:
    /// ring/RHD/tree step *s* of rank *r* becomes eligible when its
    /// predecessors (own step *s−1* and the partner's matching send)
    /// finish, so a perturbed rank's delay propagates step-by-step
    /// instead of shifting the whole collective, and co-located ranks
    /// queue on their shared NIC/PCIe bundle.  `iteration_in` routes
    /// here whenever the scenario skews individual ranks OR the cluster
    /// places more than one GPU per node; with a neutral scenario and
    /// the paper's 1-GPU-per-node layout this path is provably
    /// equivalent to the serialized replay (pinned by
    /// `tests/des_regression.rs`), just ~`world`× more engine events.
    /// §Perf: each buffer's graph is an immutable cached template
    /// (buffers bucket by size, so a ResNet iteration builds a handful of
    /// graphs instead of one per buffer) replayed under the scenario's
    /// per-buffer overlay.
    pub fn iteration_graph(&self, ws: &WorldSpec, sc: &Scenario) -> Result<IterationReport> {
        crate::ensure!(
            self.available(&ws.cluster),
            "{} unavailable on {}",
            self.name(),
            ws.cluster.name
        );
        if ws.world == 1 {
            let iter = SimTime::from_us(ws.compute_time().as_us() * sc.compute_stretch());
            return Ok(IterationReport::from_times(self.name(), ws, iter));
        }
        let mut e = Engine::new();
        let res = GraphResources::install_placed(&mut e, ws.world, ws.cluster.placement());
        let items = self.graph_items(ws, sc)?;
        let job = LaneJob::graphs(&mut e, &res, sc.lanes(), items, SimTime::ZERO);
        e.run();
        let parts = self.close_parts(ws, sc, &job.trace(&e)?, SimTime::ZERO);
        let util = res.utilization(&e);
        Ok(super::report_with_comm_thread(self.name(), ws, parts, util, &mut e, job.set()))
    }
}

impl Strategy for Horovod {
    fn name(&self) -> String {
        self.backend_name()
    }

    fn available(&self, cluster: &ClusterSpec) -> bool {
        match self.backend {
            HorovodBackend::Nccl => cluster.fabric.ib_verbs,
            HorovodBackend::Mpi(_) => true,
        }
    }

    fn iteration_in(&self, ws: &WorldSpec, sc: &Scenario) -> Result<IterationReport> {
        crate::ensure!(
            self.available(&ws.cluster),
            "{} unavailable on {}",
            self.name(),
            ws.cluster.name
        );
        if !sc.fault.is_empty() {
            // fault injection routes through the shared recovery runner
            // (§Robustness); an empty plan never reaches this branch, so
            // the fault-free paths below stay bit-identical
            return super::recovery::run_faulted_collective(
                self.name(),
                ws,
                sc,
                self.runtime_tax,
                self.skew_us_per_rank,
                &|ws, sc| self.graph_items(ws, sc),
            );
        }
        if sc.rejoin_rebuild_us > 0.0 {
            // elastic rejoin (§Robustness campaign): the grown world's
            // templates re-form before any collective launches; zero
            // rebuild never reaches this branch
            return super::recovery::run_rejoin_collective(
                self.name(),
                ws,
                sc,
                self.runtime_tax,
                self.skew_us_per_rank,
                &|ws, sc| self.graph_items(ws, sc),
            );
        }
        if ws.world == 1 {
            let iter = SimTime::from_us(ws.compute_time().as_us() * sc.compute_stretch());
            return Ok(IterationReport::from_times(self.name(), ws, iter));
        }
        if sc.per_rank_skew() || !ws.cluster.placement().is_trivial() || sc.overlapped() {
            // per-rank skew needs per-rank schedules, a dense placement
            // needs per-node resource sharing, and overlapped streams
            // need per-rank resources for the interleaved buffer graphs
            // to contend on: execute the dependency graphs (equivalent
            // to the replay below when the scenario is neutral, every
            // rank owns its node and streams = 1 — des_regression pins
            // it)
            return self.iteration_graph(ws, sc);
        }
        let mut e = Engine::new();
        let res = CommResources::install(&mut e);
        let job = self.schedule_job(ws, sc, &mut e, res)?;
        e.run();
        let parts = self.close_parts(ws, sc, &job.trace(&e)?, SimTime::ZERO);
        let util = res.utilization(&e);
        Ok(super::report_with_comm_thread(self.name(), ws, parts, util, &mut e, job.set()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::models::{mobilenet, nasnet, resnet};

    #[test]
    fn single_gpu_is_ideal() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 1);
        let r = Horovod::mpi(MpiFlavor::Mvapich2).iteration(&ws).unwrap();
        assert!((r.scaling_efficiency - 1.0).abs() < 1e-6);
    }

    #[test]
    fn nccl_rejected_on_piz_daint() {
        let ws = WorldSpec::new(presets::piz_daint(), resnet::resnet50(), 8);
        assert!(Horovod::nccl().iteration(&ws).is_err());
        assert!(!Horovod::nccl().available(&presets::piz_daint()));
    }

    #[test]
    fn opt_beats_stock_mpi_resnet_ri2() {
        // Figure 7's key comparison (on the slow K80s the difference is
        // small — most comm hides under the 1.2s iteration).
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 16);
        let stock = Horovod::mpi(MpiFlavor::Mvapich2).iteration(&ws).unwrap();
        let opt = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt).iteration(&ws).unwrap();
        assert!(
            opt.imgs_per_sec >= stock.imgs_per_sec,
            "opt {} < stock {}",
            opt.imgs_per_sec,
            stock.imgs_per_sec
        );
        assert!(opt.scaling_efficiency > 0.85, "RI2@16 opt eff {}", opt.scaling_efficiency);
    }

    #[test]
    fn opt_beats_stock_mpi_resnet_owens64() {
        // Figure 8: on the fast P100s at 64 GPUs the comm difference
        // surfaces — MPI-Opt must win strictly and land ≈90% efficiency.
        let ws = WorldSpec::new(presets::owens(), resnet::resnet50(), 64);
        let stock = Horovod::mpi(MpiFlavor::Mvapich2).iteration(&ws).unwrap();
        let opt = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt).iteration(&ws).unwrap();
        assert!(
            opt.imgs_per_sec > stock.imgs_per_sec,
            "opt {} <= stock {}",
            opt.imgs_per_sec,
            stock.imgs_per_sec
        );
        assert!(
            opt.scaling_efficiency > 0.80 && opt.scaling_efficiency <= 1.0,
            "Owens@64 opt eff {} (paper ≈0.90)",
            opt.scaling_efficiency
        );
    }

    #[test]
    fn efficiency_ordering_nasnet_resnet_mobilenet() {
        // Figure 9: NASNet ≈ 92% > ResNet-50 ≈ 71% > MobileNet ≈ 16%.
        let eff = |m: crate::models::ModelProfile| {
            let ws = WorldSpec::new(presets::piz_daint(), m, 128);
            Horovod::mpi(MpiFlavor::CrayMpich).iteration(&ws).unwrap().scaling_efficiency
        };
        let n = eff(nasnet::nasnet_large());
        let r = eff(resnet::resnet50());
        let m = eff(mobilenet::mobilenet_v1());
        assert!(n > r && r > m, "ordering broken: nasnet {n:.2}, resnet {r:.2}, mobilenet {m:.2}");
        // paper: 92% / 71% / 16%.  Our simulator reproduces the ordering
        // and the near-ideal NASNet; the MobileNet magnitude is compressed
        // (EXPERIMENTS.md discusses the residual).
        assert!(n > 0.80, "NASNet should scale near-ideally, got {n:.2}");
        assert!(m < 0.68, "MobileNet should scale poorly, got {m:.2}");
    }

    #[test]
    fn fusion_reduces_buffer_count() {
        let ws = WorldSpec::new(presets::ri2(), mobilenet::mobilenet_v1(), 8);
        let mut h = Horovod::mpi(MpiFlavor::Mvapich2);
        let fused = h.fusion_schedule(&ws).len();
        h.fusion_bytes = 1; // effectively per-tensor
        let unfused = h.fusion_schedule(&ws).len();
        assert!(fused < unfused / 4, "fusion {fused} vs per-tensor {unfused}");
        assert_eq!(unfused, ws.model.tensors.len());
    }

    #[test]
    fn fused_bytes_conserved() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 8);
        let h = Horovod::mpi(MpiFlavor::Mvapich2);
        let total: usize = h.fusion_schedule(&ws).iter().map(|&(_, b)| b).sum();
        assert_eq!(total, ws.model.grad_bytes());
    }

    #[test]
    fn final_buffer_obeys_cycle_launch_rule() {
        // The last buffer's launch time must never precede its readiness
        // and never exceed the (stretched) compute end — same rule as
        // every other buffer, bytes conserved under stretch too.
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 8);
        let h = Horovod::mpi(MpiFlavor::Mvapich2);
        for stretch in [1.0, 1.7] {
            let buffers = h.fusion_schedule_in(&ws, stretch);
            let compute = SimTime::from_us(ws.compute_time().as_us() * stretch);
            let last = buffers.last().unwrap();
            assert!(last.0 <= compute, "last buffer {} past compute {compute}", last.0);
            let total: usize = buffers.iter().map(|&(_, b)| b).sum();
            assert_eq!(total, ws.model.grad_bytes(), "bytes conserved under stretch");
        }
    }

    #[test]
    fn graph_and_serialized_paths_agree_when_neutral() {
        // the zero-skew equivalence at strategy level: forcing the
        // per-rank graph executor under a neutral scenario reproduces the
        // serialized critical-path iteration within per-op ns rounding
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 8);
        for h in [Horovod::mpi(MpiFlavor::Mvapich2GdrOpt), Horovod::nccl()] {
            let serial = h.iteration(&ws).unwrap().iter;
            let graph = h.iteration_graph(&ws, &Scenario::default()).unwrap().iter;
            let rel = (graph.as_us() - serial.as_us()).abs() / serial.as_us();
            assert!(rel < 2e-3, "{}: graph {graph} vs serialized {serial}", h.name());
        }
    }

    #[test]
    fn graph_templates_are_cached_and_replays_are_stable() {
        // §Perf: one straggler iteration builds ≤ one template per buffer
        // size bucket; a second identical call replays from cache and
        // reproduces the exact same iteration time
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 8);
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let sc = Scenario::straggler(1, 1.5);
        let a = h.iteration_in(&ws, &sc).unwrap().iter;
        let built = h.cache.len();
        let buffers = h.fusion_schedule(&ws).len();
        assert!(built >= 1 && built <= buffers, "{built} templates for {buffers} buffers");
        let b = h.iteration_in(&ws, &sc).unwrap().iter;
        assert_eq!(a, b, "cached replay must be bit-identical");
        assert_eq!(h.cache.len(), built, "second run must not rebuild templates");
    }

    #[test]
    fn overlapped_streams_strictly_reduce_commbound_iterations() {
        // §Overlap: on a comm-bound point (MobileNet at scale, Fig 9's
        // worst case) two streams hide buffer k+1's coordination and
        // staging under buffer k's wire time — the iteration must get
        // strictly faster, and more streams never hurt.
        use crate::models::mobilenet;
        let ws = WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 64);
        let h = Horovod::mpi(MpiFlavor::CrayMpich);
        let base = h.iteration(&ws).unwrap().iter;
        let s2 = h
            .iteration_in(&ws, &Scenario { streams: 2, ..Scenario::default() })
            .unwrap()
            .iter;
        let s4 = h
            .iteration_in(&ws, &Scenario { streams: 4, ..Scenario::default() })
            .unwrap()
            .iter;
        assert!(s2 < base, "2 streams must beat the serialized thread: {s2} vs {base}");
        assert!(s4 <= s2, "4 streams must not lose to 2: {s4} vs {s2}");
    }

    #[test]
    fn utilization_ledger_has_wire_traffic() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 8);
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let r = h.iteration(&ws).unwrap();
        let wire = r.resource_util.iter().find(|u| u.name == "wire").expect("wire row");
        assert!(wire.busy > SimTime::ZERO && wire.served > 0);
        let thread = r.resource_util.iter().find(|u| u.name == "comm-thread").unwrap();
        assert_eq!(thread.served as usize, h.fusion_schedule(&ws).len());
    }
}
