//! Scenario knobs: perturbations layered over any strategy, plus the
//! two-job link-sharing runs the `CommOp`→`Engine` refactor unlocks.
//!
//! The paper measures pristine, dedicated clusters; production clusters
//! are not.  A [`Scenario`] injects the deviations operators actually
//! see — stragglers (one slow rank paces every synchronous collective),
//! heterogeneous node mixes (part of the allocation on an older GPU),
//! per-step OS/sync jitter, and a fabric shared with other traffic —
//! without touching the calibrated cost models.  Knobs that skew
//! *individual ranks* apart ([`Scenario::per_rank_skew`]) route the
//! strategies onto per-rank `CommGraph` execution — cached templates
//! replayed under the knobs' [`Scenario::overlay`], so a slow rank's
//! delay propagates along the algorithm's dependency edges; whole-job
//! knobs keep the provably equivalent serialized replay.  Two *whole
//! jobs* can also share one fabric and contend transfer-by-transfer on
//! the graph path's physical per-`(node, rail)` NIC ports
//! ([`GraphResources::sharing_wire`]: [`link_share`] for the Horovod
//! family, [`link_share_baidu`] for Baidu, [`link_share_ps`] for the PS
//! family's shared server NICs).

use super::baidu::Baidu;
use super::horovod::Horovod;
use super::ps::{PsFabric, PsJob, PsStrategy};
use super::{GraphWork, JobTrace, LaneJob, Strategy, WorldSpec};
use crate::comm::commop::ResourceUse;
use crate::comm::graph::{GraphOverlay, GraphResources};
use crate::ensure;
use crate::sim::{CampaignSpec, Engine, FaultPlan, SimTime};
use crate::util::error::Result;
use crate::util::prng::Rng;

/// Highest background-traffic fraction the link-load knob accepts; the
/// CLI and `[scenario]` config validate against this, and
/// [`Scenario::wire_derate`] clamps to it (a 20× derate ceiling).
pub const MAX_LINK_LOAD: f64 = 0.95;

/// A perturbation of the pristine-cluster assumptions.  `Default` is
/// neutral: every strategy produces identical results under it.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Ranks whose compute runs `straggler_factor` × slower (thermal
    /// throttling, a busy co-tenant, a failing DIMM).
    pub straggler_ranks: usize,
    pub straggler_factor: f64,
    /// Ranks placed on a slower GPU generation; their compute is scaled
    /// by `hetero_factor` (e.g. K80-vs-P100 ≈ 2.5×).
    pub hetero_ranks: usize,
    pub hetero_factor: f64,
    /// Per-rank, per-step synchronization jitter bound, µs.  The slowest
    /// of `p` deterministic draws is added to the step's barrier skew.
    pub jitter_us: f64,
    /// Seed for the jitter draws (bit-reproducible scenarios).
    pub seed: u64,
    /// Fraction of inter-node wire bandwidth consumed by unrelated
    /// traffic (0.0 = dedicated fabric, 0.5 = half the wire is gone).
    pub link_load: f64,
    /// Run a second identical job sharing the fabric (the experiment
    /// launcher's `[scenario] second_job = true` emits a link-share table
    /// per supported strategy; `iteration_in` itself ignores it).
    pub second_job: bool,
    /// Start offset of the second job, µs.
    pub second_job_offset_us: f64,
    /// Logical comm streams (§Overlap, `HOROVOD_NUM_NCCL_STREAMS`): `1`
    /// = the classic serialized background comm thread; `n > 1` launches
    /// ready fusion buffers / per-tensor rings round-robin across `n`
    /// lanes, so their graphs interleave on the shared per-rank
    /// resources and wire/PCIe contention does the arbitration.
    pub streams: usize,
    /// Queue-depth cap: at most this many collectives in flight across
    /// the lanes (`0` = the stream count, i.e. uncapped).
    pub depth: usize,
    /// Per-worker RPC window of the PS family (§Transports): at most
    /// this many push/pull shard exchanges in flight per worker.  `0` =
    /// unbounded (the historical behaviour — every shard's RPCs issue at
    /// tensor readiness), `n >= 1` bounds in-flight RPCs on an engine
    /// lane set, opening the contended fan-in regime the gRPC
    /// micro-benchmarks show.  Inert for the allreduce family.
    pub rpc_window: usize,
    /// Injected failures + detection/recovery knobs (§Robustness).  An
    /// empty plan routes every strategy through the exact pre-fault code
    /// path — bit-identical to the plan not existing.
    pub fault: FaultPlan,
    /// Sustained-failure training campaign (§Robustness campaign):
    /// `iters > 0` runs N iterations under a seeded MTBF crash stream
    /// with checkpoint policies and elastic rejoin.  The default (off)
    /// is inert; `iteration_in` never reads it — the campaign runner
    /// (`sim::campaign::run_campaign`) is the only consumer.
    pub campaign: CampaignSpec,
    /// Grow-back rebuild cost of an elastic-rejoin iteration, µs
    /// (§Robustness campaign): `> 0` re-forms the collective templates /
    /// shard plan over the grown world before any comm launches.  Set by
    /// the campaign runner; `0` routes the exact plain path.
    pub rejoin_rebuild_us: f64,
}

impl Default for Scenario {
    fn default() -> Scenario {
        Scenario {
            straggler_ranks: 0,
            straggler_factor: 1.0,
            hetero_ranks: 0,
            hetero_factor: 1.0,
            jitter_us: 0.0,
            seed: 0,
            link_load: 0.0,
            second_job: false,
            second_job_offset_us: 0.0,
            streams: 1,
            depth: 0,
            rpc_window: 0,
            fault: FaultPlan::default(),
            campaign: CampaignSpec::default(),
            rejoin_rebuild_us: 0.0,
        }
    }
}

impl Scenario {
    pub fn straggler(ranks: usize, factor: f64) -> Scenario {
        Scenario { straggler_ranks: ranks, straggler_factor: factor, ..Scenario::default() }
    }

    pub fn hetero(ranks: usize, factor: f64) -> Scenario {
        Scenario { hetero_ranks: ranks, hetero_factor: factor, ..Scenario::default() }
    }

    pub fn link_loaded(load: f64) -> Scenario {
        Scenario { link_load: load, ..Scenario::default() }
    }

    pub fn overlap(streams: usize) -> Scenario {
        Scenario { streams, ..Scenario::default() }
    }

    pub fn windowed(rpc_window: usize) -> Scenario {
        Scenario { rpc_window, ..Scenario::default() }
    }

    pub fn with_fault(fault: FaultPlan) -> Scenario {
        Scenario { fault, ..Scenario::default() }
    }

    pub fn is_neutral(&self) -> bool {
        self == &Scenario::default()
    }

    /// The single range/consistency check every surface (CLI flags,
    /// `[scenario]` config table, bench sweeps) funnels through, so the
    /// accepted knob space cannot drift between surfaces.  Surface-
    /// specific concerns (flag spelling, raw negative integers before
    /// the usize cast, placement reshaping) stay at the surface.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.streams >= 1, "streams must be >= 1 (got {})", self.streams);
        if self.depth > 0 {
            ensure!(
                self.streams > 1,
                "a queue-depth cap needs streams > 1 (got streams {})",
                self.streams
            );
            ensure!(
                self.depth <= self.streams,
                "depth {} exceeds the stream count {} (each lane holds one collective)",
                self.depth,
                self.streams
            );
        }
        ensure!(
            self.link_load.is_finite() && (0.0..=MAX_LINK_LOAD).contains(&self.link_load),
            "link load must be in [0, {MAX_LINK_LOAD}] (got {})",
            self.link_load
        );
        for (what, ranks, factor) in [
            ("straggler", self.straggler_ranks, self.straggler_factor),
            ("hetero", self.hetero_ranks, self.hetero_factor),
        ] {
            ensure!(
                factor.is_finite() && factor > 0.0,
                "{what} factor must be finite and > 0 (got {factor})"
            );
            if ranks > 0 {
                ensure!(
                    factor > 1.0,
                    "{what} factor must be > 1.0 to slow ranks down (got {factor})"
                );
            } else {
                ensure!(
                    factor == 1.0,
                    "{what} factor {factor} without {what} ranks is inert — set ranks too"
                );
            }
        }
        ensure!(
            self.jitter_us.is_finite() && self.jitter_us >= 0.0,
            "jitter must be finite and >= 0 us (got {})",
            self.jitter_us
        );
        if self.second_job {
            ensure!(
                self.streams == 1 && self.depth == 0,
                "second_job and streams/depth overlap cannot combine (streams {}, depth {})",
                self.streams,
                self.depth
            );
            // the two-job runner schedules both jobs unbounded — a window
            // it never reads would silently report unwindowed numbers
            ensure!(
                self.rpc_window == 0,
                "second_job does not consume rpc_window ({}) — the link-share runner \
                 schedules both jobs unbounded",
                self.rpc_window
            );
            ensure!(
                self.second_job_offset_us.is_finite() && self.second_job_offset_us >= 0.0,
                "second job offset must be finite and >= 0 us (got {})",
                self.second_job_offset_us
            );
        } else {
            ensure!(
                self.second_job_offset_us == 0.0,
                "second_job_offset_us without second_job is inert — enable second_job too"
            );
        }
        // §Robustness campaign knobs: the same inert-combination policy.
        // Campaign and rejoin surfaces only compose with scenarios that
        // carry a fault surface — a two-job run or an explicit fault
        // plan would silently race the campaign's own clock/stream.
        self.campaign.validate()?;
        if !self.campaign.is_off() {
            ensure!(
                self.fault.is_empty(),
                "a campaign draws its own seeded fault stream — an explicit fault plan \
                 would race it (drop the --fault events; the plan's recovery knobs still \
                 apply to drawn crashes)"
            );
            ensure!(
                !self.second_job,
                "campaign + second_job cannot combine: the campaign clock owns the fabric"
            );
        }
        ensure!(
            self.rejoin_rebuild_us.is_finite() && self.rejoin_rebuild_us >= 0.0,
            "rejoin rebuild cost must be finite and >= 0 us (got {})",
            self.rejoin_rebuild_us
        );
        if self.rejoin_rebuild_us > 0.0 {
            ensure!(
                self.fault.is_empty(),
                "rejoin rebuild and an injected fault plan cannot share an iteration — \
                 the grow-back happens at a clean step boundary"
            );
            ensure!(
                !self.second_job,
                "rejoin rebuild + second_job cannot combine: the two-job runner never \
                 reads the rejoin surface"
            );
        }
        self.fault.validate_knobs()
    }

    /// Slowest-rank compute multiplier.  Synchronous data parallelism is
    /// paced by the slowest rank: tensor readiness and the compute-side
    /// critical path both stretch by this.  Factors below 1.0 cannot
    /// *speed up* the collective (the unperturbed ranks still exist).
    pub fn compute_stretch(&self) -> f64 {
        let mut stretch = 1.0f64;
        if self.straggler_ranks > 0 {
            stretch = stretch.max(self.straggler_factor);
        }
        if self.hetero_ranks > 0 {
            stretch = stretch.max(self.hetero_factor);
        }
        stretch
    }

    /// Wire-bandwidth divisor from background fabric load.  Clamped to
    /// [`MAX_LINK_LOAD`] — the same bound the CLI/config validation
    /// enforces, so the effective knob always equals the requested one.
    pub fn wire_derate(&self) -> f64 {
        let load = self.link_load.clamp(0.0, MAX_LINK_LOAD);
        1.0 / (1.0 - load)
    }

    /// Max-of-`world` deterministic jitter draws, µs — the barrier waits
    /// for the unluckiest rank.
    pub fn sync_jitter_us(&self, world: usize) -> f64 {
        if self.jitter_us <= 0.0 || world == 0 {
            return 0.0;
        }
        let mut rng = Rng::new(self.seed ^ 0x5CEA_A210);
        (0..world)
            .map(|_| rng.next_below(1 << 20) as f64 / (1u64 << 20) as f64 * self.jitter_us)
            .fold(0.0, f64::max)
    }

    /// The comm stream-lane layout as `(streams, depth)`: `streams`
    /// logical lanes with at most `depth` collectives in flight.  A
    /// `depth` of 0 means "as deep as the stream count"; a configured
    /// depth is clamped to the stream count (a deeper queue than there
    /// are lanes would be inert — each lane holds one collective).
    pub fn lanes(&self) -> (usize, usize) {
        let streams = self.streams.max(1);
        let depth = if self.depth == 0 { streams } else { self.depth.min(streams) };
        (streams, depth)
    }

    /// Does the scenario open the overlapped regime (§Overlap — more
    /// than one comm stream)?  When true, the allreduce-family
    /// strategies execute per-rank `CommGraph`s even under neutral skew
    /// and trivial placement, because interleaved buffer graphs need
    /// per-rank resources to contend on; the serialized replay cannot
    /// express two collectives in flight.
    pub fn overlapped(&self) -> bool {
        self.lanes().0 > 1
    }

    /// Do the knobs skew *individual ranks* apart (rather than shifting
    /// the whole job)?  When true, the allreduce-family strategies execute
    /// per-rank `CommGraph`s so the skew propagates along dependency
    /// edges; when false they keep the serialized critical-path replay,
    /// which is provably identical under uniform per-rank timing (and
    /// orders of magnitude fewer engine events at p=128).
    pub fn per_rank_skew(&self) -> bool {
        (self.straggler_ranks > 0 && self.straggler_factor > 1.0)
            || (self.hetero_ranks > 0 && self.hetero_factor > 1.0)
            || self.jitter_us > 0.0
    }

    /// Deterministic per-node jitter draw, µs, keyed by `(seed, salt,
    /// rank, step)` — independent of execution order, so perturbed runs
    /// stay bit-reproducible.  `salt` distinguishes collectives within an
    /// iteration (fusion-buffer / tensor / shard ordinal); without it
    /// every collective would replay one identical jitter pattern instead
    /// of drawing independently.  (The iteration-level barrier draw
    /// [`Scenario::sync_jitter_us`] is separate and unchanged.)
    pub fn node_jitter_us(&self, salt: u64, rank: usize, step: u32) -> f64 {
        if self.jitter_us <= 0.0 {
            return 0.0;
        }
        let key = ((rank as u64) << 32) | step as u64;
        let mut rng = Rng::new(
            self.seed
                ^ 0x6A09_E667_F3BC_C908
                ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ salt.wrapping_mul(0xBF58_476D_1CE4_E5B9),
        );
        rng.next_f64() * self.jitter_us
    }

    /// The per-rank knobs as a [`GraphOverlay`] for one collective
    /// (§Perf: the overlay replaces the old clone-and-mutate
    /// `perturb_graph`, so a cached template can be replayed under it):
    /// straggler ranks (the first `straggler_ranks` of `world`) run every
    /// op `straggler_factor`× slower, heterogeneous ranks (the last
    /// `hetero_ranks`) pay `hetero_factor`× on GPU-side ops, and each
    /// node draws its `(salt, rank, step)` jitter lead (`salt` = the
    /// collective's ordinal within the iteration).  The skew then
    /// *propagates* through the graph's edges instead of shifting the
    /// whole schedule.
    pub fn overlay(&self, world: usize, salt: u64) -> GraphOverlay {
        let mut ov = GraphOverlay::neutral();
        if self.straggler_ranks > 0 && self.straggler_factor > 1.0 {
            for r in 0..self.straggler_ranks.min(world) {
                ov.scale_rank(world, r, self.straggler_factor);
            }
        }
        if self.hetero_ranks > 0 && self.hetero_factor > 1.0 {
            for r in world.saturating_sub(self.hetero_ranks)..world {
                ov.scale_rank_gpu(world, r, self.hetero_factor);
            }
        }
        if self.jitter_us > 0.0 {
            let sc = self.clone();
            ov.set_lead(move |rank, step| sc.node_jitter_us(salt, rank, step));
        }
        ov
    }
}

/// Outcome of two identical Horovod jobs contending on one fabric.
#[derive(Debug, Clone)]
pub struct LinkShareReport {
    /// Iteration time of the job alone on the fabric.
    pub solo_iter: SimTime,
    /// Iteration times of job A (starts at 0) and job B (starts at
    /// `offset`), each measured from its own start.
    pub job_iters: [SimTime; 2],
    /// Total wire occupancy across both jobs.
    pub wire_busy: SimTime,
    pub wire_served: u64,
}

impl LinkShareReport {
    /// Per-job slowdown vs the solo run.
    pub fn slowdowns(&self) -> [f64; 2] {
        let solo = self.solo_iter.as_us();
        [self.job_iters[0].as_us() / solo, self.job_iters[1].as_us() / solo]
    }
}

/// The shared graph-path two-job engine run behind [`link_share`] and
/// [`link_share_baidu`]: each job's collectives execute as per-rank
/// dependency graphs on its own placement-aware [`GraphResources`]
/// bundle, with job B's bundle [`GraphResources::sharing_wire`] — both
/// jobs' wire steps queue FIFO on the same physical `(node, rail)` NIC
/// ports while PCIe/GPU/host resources stay private per job.  Returns
/// both job traces plus the shared-port wire ledger.
fn run_shared_wire_jobs(
    ws: &WorldSpec,
    lanes: (usize, usize),
    items_a: Vec<GraphWork>,
    items_b: Vec<GraphWork>,
    offset: SimTime,
) -> Result<(JobTrace, JobTrace, u64, SimTime)> {
    let mut e = Engine::new();
    let place = ws.cluster.placement();
    let res_a = GraphResources::install_placed(&mut e, ws.world, place);
    let res_b = GraphResources::sharing_wire(&mut e, ws.world, &res_a);
    let job_a = LaneJob::graphs(&mut e, &res_a, lanes, items_a, SimTime::ZERO);
    let job_b = LaneJob::graphs(&mut e, &res_b, lanes, items_b, offset);
    e.run();
    let wire = ResourceUse::aggregate(&e, "wire", res_a.wire.iter().copied());
    Ok((job_a.trace(&e)?, job_b.trace(&e)?, wire.served, wire.busy))
}

/// Run two identical Horovod jobs on one engine, sharing the physical
/// per-node NIC ports (private PCIe/GPU/host resources).  Job B's
/// schedule starts `offset` after job A's.  Both jobs — and the solo
/// baseline — run on the per-rank graph path, so the co-tenant's
/// transfers interleave between individual ring/RHD steps instead of
/// between whole serialized collectives (the old serialized-chain
/// runner), and dense placements share ports within each job too.
pub fn link_share(h: &Horovod, ws: &WorldSpec, offset: SimTime) -> Result<LinkShareReport> {
    let sc = Scenario::default();
    let solo = h.iteration_graph(ws, &sc)?;
    let (trace_a, trace_b, wire_served, wire_busy) = run_shared_wire_jobs(
        ws,
        sc.lanes(),
        h.graph_items(ws, &sc)?,
        h.graph_items(ws, &sc)?,
        offset,
    )?;
    let iter_a = h.close_job(ws, &sc, &trace_a, SimTime::ZERO);
    let iter_b = h.close_job(ws, &sc, &trace_b, offset);
    Ok(LinkShareReport {
        solo_iter: solo.iter,
        job_iters: [iter_a, iter_b],
        wire_busy,
        wire_served,
    })
}

/// Two identical Baidu jobs on one engine, sharing the physical NIC
/// ports, job B offset by `offset`.  The Baidu counterpart of
/// [`link_share`]: per-tensor rings (no fusion) contend
/// transfer-by-transfer on the graph path, so the co-tenant's traffic
/// interleaves between every ring step's sends.
pub fn link_share_baidu(b: &Baidu, ws: &WorldSpec, offset: SimTime) -> Result<LinkShareReport> {
    let sc = Scenario::default();
    let solo = b.iteration_graph(ws, &sc)?;
    let (trace_a, trace_b, wire_served, wire_busy) = run_shared_wire_jobs(
        ws,
        sc.lanes(),
        b.graph_items(ws, &sc)?,
        b.graph_items(ws, &sc)?,
        offset,
    )?;
    let close = |trace: &JobTrace, off: SimTime| {
        super::close_iteration(ws, &sc, trace, off, b.runtime_tax, b.skew_us_per_rank)
    };
    let iter_a = close(&trace_a, SimTime::ZERO);
    let iter_b = close(&trace_b, offset);
    Ok(LinkShareReport {
        solo_iter: solo.iter,
        job_iters: [iter_a, iter_b],
        wire_busy,
        wire_served,
    })
}

/// Two identical PS jobs on one engine, sharing every parameter server's
/// ingress/egress NIC (the co-tenant lands on the same hosts) while each
/// job keeps its own worker-side resources.  Job B starts at `offset`.
/// This is the PS-family counterpart of [`link_share`]: fan-in congestion
/// now comes from *both* jobs' pushes queueing on the shared NICs.
pub fn link_share_ps(ps: &PsStrategy, ws: &WorldSpec, offset: SimTime) -> Result<LinkShareReport> {
    let sc = Scenario::default();
    let solo = ps.iteration(ws)?;

    let mut e = Engine::new();
    let fabric = PsFabric::install_placed(&mut e, ws.world, ws.cluster.placement());
    let job_a = ps.schedule_job(ws, &sc, &mut e, &fabric, SimTime::ZERO)?;
    let job_b = ps.schedule_job(ws, &sc, &mut e, &fabric, offset)?;
    e.run();

    let close = |job: &PsJob, off: SimTime| -> Result<SimTime> {
        let trace = JobTrace { comm_end: job.comm_end(&e)?, staging_us: 0.0 };
        Ok(super::close_iteration(ws, &sc, &trace, off, ps.runtime_tax, ps.skew_us_per_rank))
    };
    let iter_a = close(&job_a, SimTime::ZERO)?;
    let iter_b = close(&job_b, offset)?;
    let (wire_served, wire_busy) = fabric.wire_stats(&e);
    Ok(LinkShareReport {
        solo_iter: solo.iter,
        job_iters: [iter_a, iter_b],
        wire_busy,
        wire_served,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::MpiFlavor;
    use crate::models::resnet;
    use crate::strategies::Strategy;

    fn ws16() -> WorldSpec {
        WorldSpec::new(presets::ri2(), resnet::resnet50(), 16)
    }

    #[test]
    fn neutral_scenario_matches_baseline() {
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let ws = ws16();
        let base = h.iteration(&ws).unwrap();
        let neutral = h.iteration_in(&ws, &Scenario::default()).unwrap();
        assert_eq!(base.iter, neutral.iter);
    }

    #[test]
    fn straggler_slows_iteration_monotonically() {
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let ws = ws16();
        let base = h.iteration(&ws).unwrap().iter;
        let mild = h.iteration_in(&ws, &Scenario::straggler(1, 1.3)).unwrap().iter;
        let bad = h.iteration_in(&ws, &Scenario::straggler(1, 2.0)).unwrap().iter;
        assert!(mild > base, "1.3x straggler must slow the step: {mild} vs {base}");
        assert!(bad > mild, "2.0x straggler must be worse: {bad} vs {mild}");
        // a sub-1.0 "straggler" cannot speed the job up
        let fast = h.iteration_in(&ws, &Scenario::straggler(1, 0.5)).unwrap().iter;
        assert_eq!(fast, base);
    }

    #[test]
    fn link_load_slows_comm_bound_models() {
        use crate::models::mobilenet;
        let h = Horovod::mpi(MpiFlavor::CrayMpich);
        let ws = WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 64);
        let base = h.iteration(&ws).unwrap().iter;
        let loaded = h.iteration_in(&ws, &Scenario::link_loaded(0.5)).unwrap().iter;
        assert!(loaded > base, "half the wire must hurt MobileNet: {loaded} vs {base}");
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let sc = Scenario { jitter_us: 100.0, seed: 7, ..Scenario::default() };
        let a = sc.sync_jitter_us(64);
        let b = sc.sync_jitter_us(64);
        assert_eq!(a, b);
        assert!(a > 0.0 && a < 100.0);
        // more ranks ⇒ the max draw can only grow
        assert!(sc.sync_jitter_us(64) >= sc.sync_jitter_us(4));
        assert_eq!(Scenario::default().sync_jitter_us(64), 0.0);
    }

    #[test]
    fn shared_link_slows_both_jobs() {
        // Comm-bound point (Fig 9's worst case) so wire contention cannot
        // hide behind compute.
        use crate::models::mobilenet;
        let h = Horovod::mpi(MpiFlavor::CrayMpich);
        let ws = WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 64);
        let r = link_share(&h, &ws, SimTime::ZERO).unwrap();
        let [a, b] = r.slowdowns();
        assert!(a >= 1.0 && b >= 1.0, "sharing cannot speed anyone up: {a} {b}");
        assert!(
            a > 1.0 || b > 1.0,
            "two jobs on one wire must contend somewhere: {a} {b}"
        );
        assert!(r.wire_busy > SimTime::ZERO);
    }

    #[test]
    fn lanes_default_and_clamp() {
        assert_eq!(Scenario::default().lanes(), (1, 1));
        assert!(!Scenario::default().overlapped());
        assert_eq!(Scenario::overlap(4).lanes(), (4, 4));
        assert!(Scenario::overlap(2).overlapped());
        // a configured depth caps in-flight; deeper than the stream
        // count clamps (each lane holds one collective)
        let sc = Scenario { streams: 4, depth: 2, ..Scenario::default() };
        assert_eq!(sc.lanes(), (4, 2));
        let sc = Scenario { streams: 2, depth: 9, ..Scenario::default() };
        assert_eq!(sc.lanes(), (2, 2));
        // streams alone is not per-rank skew — it is an execution-model
        // knob, not a perturbation
        assert!(!Scenario::overlap(4).per_rank_skew());
    }

    #[test]
    fn overlap_keeps_baseline_at_one_stream_and_helps_beyond() {
        use crate::models::mobilenet;
        let h = Horovod::mpi(MpiFlavor::CrayMpich);
        let ws = WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 32);
        let base = h.iteration(&ws).unwrap().iter;
        let one = h.iteration_in(&ws, &Scenario::overlap(1)).unwrap().iter;
        assert_eq!(one, base, "streams = 1 must be the serialized baseline");
        let two = h.iteration_in(&ws, &Scenario::overlap(2)).unwrap().iter;
        assert!(two < base, "overlap must hide comm on a comm-bound point: {two} vs {base}");
    }

    #[test]
    fn per_rank_skew_classifies_knobs() {
        assert!(!Scenario::default().per_rank_skew());
        assert!(!Scenario::link_loaded(0.5).per_rank_skew());
        assert!(Scenario::straggler(1, 1.5).per_rank_skew());
        assert!(!Scenario::straggler(1, 0.5).per_rank_skew(), "sub-1.0 factor is inert");
        assert!(Scenario::hetero(2, 2.0).per_rank_skew());
        let j = Scenario { jitter_us: 50.0, ..Scenario::default() };
        assert!(j.per_rank_skew());
    }

    #[test]
    fn node_jitter_deterministic_bounded_and_keyed() {
        let sc = Scenario { jitter_us: 100.0, seed: 3, ..Scenario::default() };
        let a = sc.node_jitter_us(0, 2, 7);
        assert_eq!(a, sc.node_jitter_us(0, 2, 7), "same key, same draw");
        assert!((0.0..100.0).contains(&a));
        assert_ne!(a, sc.node_jitter_us(0, 3, 7), "rank changes the draw");
        assert_ne!(a, sc.node_jitter_us(0, 2, 8), "step changes the draw");
        assert_ne!(a, sc.node_jitter_us(1, 2, 7), "collective ordinal changes the draw");
        assert_eq!(Scenario::default().node_jitter_us(0, 2, 7), 0.0);
    }

    #[test]
    fn overlay_scales_only_the_straggler() {
        use crate::comm::commop::{CommOp, ResKind};
        use crate::comm::graph::{unmapped, CommGraph, GraphTemplate};
        let mut g = CommGraph::default();
        for r in 0..4 {
            g.push_node(r, 0, vec![CommOp::fixed(ResKind::Wire, 10.0)], Vec::new());
        }
        let t = GraphTemplate::new(g);
        let ov = Scenario::straggler(1, 2.0).overlay(4, 0);
        let mut e = Engine::new();
        let run = t.execute(&mut e, unmapped(), &ov, Box::new(|_| {}));
        e.run();
        // four independent nodes all release at t=0: finish time == dur
        let durs: Vec<f64> = run.borrow().finish.iter().map(|f| f.as_us()).collect();
        assert_eq!(durs, vec![20.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn neutral_scenario_overlay_is_neutral() {
        assert!(Scenario::default().overlay(8, 0).is_neutral());
        assert!(!Scenario::straggler(1, 2.0).overlay(8, 0).is_neutral());
    }

    #[test]
    fn straggler_skews_individual_ranks_not_just_the_job() {
        // With the graph path, one straggler must cost *more* than the
        // pure compute stretch the serialized model charged (its slow
        // comm steps push the dependent ring steps outward too).
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let ws = ws16();
        let neutral = h.iteration(&ws).unwrap().iter;
        let skewed = h.iteration_in(&ws, &Scenario::straggler(2, 1.5)).unwrap().iter;
        assert!(skewed > neutral);
    }

    #[test]
    fn two_baidu_jobs_sharing_the_wire_contend() {
        use crate::models::mobilenet;
        let b = Baidu::with_flavor(MpiFlavor::CrayMpich);
        let ws = WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 32);
        let r = link_share_baidu(&b, &ws, SimTime::ZERO).unwrap();
        let [a, bb] = r.slowdowns();
        assert!(a >= 1.0 && bb >= 1.0, "sharing cannot speed anyone up: {a} {bb}");
        assert!(a > 1.0 || bb > 1.0, "two rings on one wire must contend: {a} {bb}");
        assert!(r.wire_busy > SimTime::ZERO && r.wire_served > 0);
    }

    #[test]
    fn two_ps_jobs_sharing_nics_contend() {
        use crate::models::mobilenet;
        let ps = crate::strategies::PsStrategy::grpc();
        let ws = WorldSpec::new(presets::ri2(), mobilenet::mobilenet_v1(), 8);
        let r = link_share_ps(&ps, &ws, SimTime::ZERO).unwrap();
        let [a, b] = r.slowdowns();
        assert!(a >= 1.0 && b >= 1.0, "sharing cannot speed anyone up: {a} {b}");
        assert!(a > 1.0 || b > 1.0, "shared PS NICs must contend: {a} {b}");
        assert!(r.wire_busy > SimTime::ZERO && r.wire_served > 0);
    }

    #[test]
    fn validate_accepts_real_scenarios_and_rejects_degenerate_knobs() {
        Scenario::default().validate().unwrap();
        Scenario::straggler(1, 1.5).validate().unwrap();
        Scenario::overlap(4).validate().unwrap();
        Scenario { streams: 4, depth: 2, ..Scenario::default() }.validate().unwrap();
        Scenario::windowed(2).validate().unwrap();
        Scenario { rpc_window: 4, ..Scenario::straggler(1, 1.5) }.validate().unwrap();
        Scenario { second_job: true, second_job_offset_us: 250.0, ..Scenario::default() }
            .validate()
            .unwrap();
        Scenario::with_fault(crate::sim::FaultPlan::crash(1, 500.0)).validate().unwrap();

        let bad: Vec<Scenario> = vec![
            Scenario { streams: 0, ..Scenario::default() },
            Scenario { depth: 2, ..Scenario::default() },
            Scenario { streams: 2, depth: 3, ..Scenario::default() },
            Scenario { link_load: 0.99, ..Scenario::default() },
            Scenario { link_load: -0.1, ..Scenario::default() },
            Scenario::straggler(1, 1.0),
            Scenario::straggler(0, 1.5),
            Scenario::hetero(2, 0.0),
            Scenario { jitter_us: -1.0, ..Scenario::default() },
            Scenario { second_job: true, streams: 2, ..Scenario::default() },
            Scenario { second_job: true, rpc_window: 2, ..Scenario::default() },
            Scenario { second_job: true, second_job_offset_us: -5.0, ..Scenario::default() },
            Scenario { second_job_offset_us: 10.0, ..Scenario::default() },
            Scenario {
                fault: crate::sim::FaultPlan {
                    backoff_factor: 0.0,
                    ..crate::sim::FaultPlan::default()
                },
                ..Scenario::default()
            },
        ];
        for (i, sc) in bad.iter().enumerate() {
            assert!(sc.validate().is_err(), "degenerate scenario #{i} must be rejected");
        }
    }

    #[test]
    fn hetero_mix_degrades_efficiency() {
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let ws = ws16();
        let base = h.iteration(&ws).unwrap().scaling_efficiency;
        let mixed = h
            .iteration_in(&ws, &Scenario::hetero(4, 2.5))
            .unwrap()
            .scaling_efficiency;
        assert!(mixed < base, "hetero mix must cost efficiency: {mixed} vs {base}");
    }
}
