//! Scenario knobs: perturbations layered over any strategy, plus the
//! two-job link-sharing run the `CommOp`→`Engine` refactor unlocks.
//!
//! The paper measures pristine, dedicated clusters; production clusters
//! are not.  A [`Scenario`] injects the deviations operators actually
//! see — stragglers (one slow rank paces every synchronous collective),
//! heterogeneous node mixes (part of the allocation on an older GPU),
//! per-step OS/sync jitter, and a fabric shared with other traffic —
//! without touching the calibrated cost models.  Since every strategy now
//! schedules `CommOp`s onto engine resources, two *whole jobs* can also
//! share one wire resource and contend step-by-step ([`link_share`]).

use std::cell::RefCell;
use std::rc::Rc;

use super::horovod::Horovod;
use super::{JobTrace, Strategy, WorldSpec};
use crate::comm::commop::CommResources;
use crate::sim::{Engine, SimTime};
use crate::util::error::Result;
use crate::util::prng::Rng;

/// Highest background-traffic fraction the link-load knob accepts; the
/// CLI and `[scenario]` config validate against this, and
/// [`Scenario::wire_derate`] clamps to it (a 20× derate ceiling).
pub const MAX_LINK_LOAD: f64 = 0.95;

/// A perturbation of the pristine-cluster assumptions.  `Default` is
/// neutral: every strategy produces identical results under it.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Ranks whose compute runs `straggler_factor` × slower (thermal
    /// throttling, a busy co-tenant, a failing DIMM).
    pub straggler_ranks: usize,
    pub straggler_factor: f64,
    /// Ranks placed on a slower GPU generation; their compute is scaled
    /// by `hetero_factor` (e.g. K80-vs-P100 ≈ 2.5×).
    pub hetero_ranks: usize,
    pub hetero_factor: f64,
    /// Per-rank, per-step synchronization jitter bound, µs.  The slowest
    /// of `p` deterministic draws is added to the step's barrier skew.
    pub jitter_us: f64,
    /// Seed for the jitter draws (bit-reproducible scenarios).
    pub seed: u64,
    /// Fraction of inter-node wire bandwidth consumed by unrelated
    /// traffic (0.0 = dedicated fabric, 0.5 = half the wire is gone).
    pub link_load: f64,
}

impl Default for Scenario {
    fn default() -> Scenario {
        Scenario {
            straggler_ranks: 0,
            straggler_factor: 1.0,
            hetero_ranks: 0,
            hetero_factor: 1.0,
            jitter_us: 0.0,
            seed: 0,
            link_load: 0.0,
        }
    }
}

impl Scenario {
    pub fn straggler(ranks: usize, factor: f64) -> Scenario {
        Scenario { straggler_ranks: ranks, straggler_factor: factor, ..Scenario::default() }
    }

    pub fn hetero(ranks: usize, factor: f64) -> Scenario {
        Scenario { hetero_ranks: ranks, hetero_factor: factor, ..Scenario::default() }
    }

    pub fn link_loaded(load: f64) -> Scenario {
        Scenario { link_load: load, ..Scenario::default() }
    }

    pub fn is_neutral(&self) -> bool {
        self == &Scenario::default()
    }

    /// Slowest-rank compute multiplier.  Synchronous data parallelism is
    /// paced by the slowest rank: tensor readiness and the compute-side
    /// critical path both stretch by this.  Factors below 1.0 cannot
    /// *speed up* the collective (the unperturbed ranks still exist).
    pub fn compute_stretch(&self) -> f64 {
        let mut stretch = 1.0f64;
        if self.straggler_ranks > 0 {
            stretch = stretch.max(self.straggler_factor);
        }
        if self.hetero_ranks > 0 {
            stretch = stretch.max(self.hetero_factor);
        }
        stretch
    }

    /// Wire-bandwidth divisor from background fabric load.  Clamped to
    /// [`MAX_LINK_LOAD`] — the same bound the CLI/config validation
    /// enforces, so the effective knob always equals the requested one.
    pub fn wire_derate(&self) -> f64 {
        let load = self.link_load.clamp(0.0, MAX_LINK_LOAD);
        1.0 / (1.0 - load)
    }

    /// Max-of-`world` deterministic jitter draws, µs — the barrier waits
    /// for the unluckiest rank.
    pub fn sync_jitter_us(&self, world: usize) -> f64 {
        if self.jitter_us <= 0.0 || world == 0 {
            return 0.0;
        }
        let mut rng = Rng::new(self.seed ^ 0x5CEA_A210);
        (0..world)
            .map(|_| rng.next_below(1 << 20) as f64 / (1u64 << 20) as f64 * self.jitter_us)
            .fold(0.0, f64::max)
    }
}

/// Outcome of two identical Horovod jobs contending on one fabric.
#[derive(Debug, Clone)]
pub struct LinkShareReport {
    /// Iteration time of the job alone on the fabric.
    pub solo_iter: SimTime,
    /// Iteration times of job A (starts at 0) and job B (starts at
    /// `offset`), each measured from its own start.
    pub job_iters: [SimTime; 2],
    /// Total wire occupancy across both jobs.
    pub wire_busy: SimTime,
    pub wire_served: u64,
}

impl LinkShareReport {
    /// Per-job slowdown vs the solo run.
    pub fn slowdowns(&self) -> [f64; 2] {
        let solo = self.solo_iter.as_us();
        [self.job_iters[0].as_us() / solo, self.job_iters[1].as_us() / solo]
    }
}

/// Run two identical Horovod jobs on one engine, sharing the inter-node
/// wire resource (private PCIe/GPU/host resources — different nodes).
/// Job B's schedule starts `offset` after job A's.
pub fn link_share(h: &Horovod, ws: &WorldSpec, offset: SimTime) -> Result<LinkShareReport> {
    let sc = Scenario::default();
    let solo = h.iteration(ws)?;

    let mut e = Engine::new();
    let res_a = CommResources::install(&mut e);
    let res_b = CommResources::sharing_wire(&mut e, res_a.wire);
    let gate_a = e.gate();
    let gate_b = e.gate();
    let trace_a: Rc<RefCell<JobTrace>> =
        h.schedule_job(ws, &sc, &mut e, res_a, gate_a, SimTime::ZERO)?;
    let trace_b: Rc<RefCell<JobTrace>> = h.schedule_job(ws, &sc, &mut e, res_b, gate_b, offset)?;
    e.run();

    let iter_a = h.close_job(ws, &sc, &trace_a.borrow(), SimTime::ZERO);
    let iter_b = h.close_job(ws, &sc, &trace_b.borrow(), offset);
    let (wire_served, wire_busy) = e.resource_stats(res_a.wire);
    Ok(LinkShareReport {
        solo_iter: solo.iter,
        job_iters: [iter_a, iter_b],
        wire_busy,
        wire_served,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::MpiFlavor;
    use crate::models::resnet;
    use crate::strategies::Strategy;

    fn ws16() -> WorldSpec {
        WorldSpec::new(presets::ri2(), resnet::resnet50(), 16)
    }

    #[test]
    fn neutral_scenario_matches_baseline() {
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let ws = ws16();
        let base = h.iteration(&ws).unwrap();
        let neutral = h.iteration_in(&ws, &Scenario::default()).unwrap();
        assert_eq!(base.iter, neutral.iter);
    }

    #[test]
    fn straggler_slows_iteration_monotonically() {
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let ws = ws16();
        let base = h.iteration(&ws).unwrap().iter;
        let mild = h.iteration_in(&ws, &Scenario::straggler(1, 1.3)).unwrap().iter;
        let bad = h.iteration_in(&ws, &Scenario::straggler(1, 2.0)).unwrap().iter;
        assert!(mild > base, "1.3x straggler must slow the step: {mild} vs {base}");
        assert!(bad > mild, "2.0x straggler must be worse: {bad} vs {mild}");
        // a sub-1.0 "straggler" cannot speed the job up
        let fast = h.iteration_in(&ws, &Scenario::straggler(1, 0.5)).unwrap().iter;
        assert_eq!(fast, base);
    }

    #[test]
    fn link_load_slows_comm_bound_models() {
        use crate::models::mobilenet;
        let h = Horovod::mpi(MpiFlavor::CrayMpich);
        let ws = WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 64);
        let base = h.iteration(&ws).unwrap().iter;
        let loaded = h.iteration_in(&ws, &Scenario::link_loaded(0.5)).unwrap().iter;
        assert!(loaded > base, "half the wire must hurt MobileNet: {loaded} vs {base}");
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let sc = Scenario { jitter_us: 100.0, seed: 7, ..Scenario::default() };
        let a = sc.sync_jitter_us(64);
        let b = sc.sync_jitter_us(64);
        assert_eq!(a, b);
        assert!(a > 0.0 && a < 100.0);
        // more ranks ⇒ the max draw can only grow
        assert!(sc.sync_jitter_us(64) >= sc.sync_jitter_us(4));
        assert_eq!(Scenario::default().sync_jitter_us(64), 0.0);
    }

    #[test]
    fn shared_link_slows_both_jobs() {
        // Comm-bound point (Fig 9's worst case) so wire contention cannot
        // hide behind compute.
        use crate::models::mobilenet;
        let h = Horovod::mpi(MpiFlavor::CrayMpich);
        let ws = WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 64);
        let r = link_share(&h, &ws, SimTime::ZERO).unwrap();
        let [a, b] = r.slowdowns();
        assert!(a >= 1.0 && b >= 1.0, "sharing cannot speed anyone up: {a} {b}");
        assert!(
            a > 1.0 || b > 1.0,
            "two jobs on one wire must contend somewhere: {a} {b}"
        );
        assert!(r.wire_busy > SimTime::ZERO);
    }

    #[test]
    fn hetero_mix_degrades_efficiency() {
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let ws = ws16();
        let base = h.iteration(&ws).unwrap().scaling_efficiency;
        let mixed = h
            .iteration_in(&ws, &Scenario::hetero(4, 2.5))
            .unwrap()
            .scaling_efficiency;
        assert!(mixed < base, "hetero mix must cost efficiency: {mixed} vs {base}");
    }
}
