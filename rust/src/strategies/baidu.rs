//! Baidu tf.contrib.mpi_collectives (§III-C1): the original ring-allreduce
//! contribution — per-tensor ring allreduce built on MPI_Send/MPI_Irecv.
//! Two handicaps vs Horovod that Figure 3 shows: no tensor fusion (every
//! tensor pays the full 2(p−1)-step ring latency) and p2p-level MPI usage
//! (driver queries + per-message software overhead on every hop).

use anyhow::Result;

use super::{IterationReport, Strategy, WorldSpec};
use crate::comm::{MpiFlavor, MpiWorld};
use crate::sim::SimTime;

#[derive(Debug, Clone)]
pub struct Baidu {
    pub flavor: MpiFlavor,
    /// TF-runtime dilation (see horovod.rs); Baidu's graph-rewrite
    /// operators are coarser than Horovod's, hence the larger tax.
    pub runtime_tax: f64,
    /// Per-iteration synchronization skew, µs per rank (see horovod.rs).
    pub skew_us_per_rank: f64,
}

impl Baidu {
    pub fn new() -> Baidu {
        Baidu { flavor: MpiFlavor::Mvapich2, runtime_tax: 0.05, skew_us_per_rank: 550.0 }
    }

    pub fn with_flavor(flavor: MpiFlavor) -> Baidu {
        Baidu { flavor, ..Baidu::new() }
    }

    /// Ring allreduce latency on the flavor's transport (Baidu always
    /// rings, regardless of size — no algorithm selection).  Returns
    /// (total µs, host-staging µs); shadow cost path.
    ///
    /// Successive per-tensor rings pipeline: while one tensor's ring step
    /// waits on the wire, the next tensor's sends are already posted
    /// (MPI_Irecv-based implementation), so the per-step *fixed* costs
    /// (α, sw, launch, driver) amortize by `RING_PIPELINE` across the
    /// tensor stream — without this, a 1000-tensor model at p=128 would
    /// pay 2(p−1)·α serially per tensor, which the paper's "Baidu ≈
    /// Horovod" Figure 9 result rules out.
    fn ring_us(&self, ws: &WorldSpec, bytes: usize) -> (f64, f64) {
        let w = MpiWorld::new(self.flavor, ws.cluster.clone());
        let (_, mut ctx) = w.plan(bytes.max(SMALL_OVERRIDE)); // transport from flavor
        ctx.wire.beta_gbs /= ws.cluster.fabric.contention_factor(ws.world);
        let n = (bytes / 4).max(1);
        let full = crate::comm::allreduce::shadow_cost(
            crate::comm::allreduce::Algo::Ring,
            ws.world,
            n,
            &mut ctx,
        );
        // fixed (size-independent) share ≈ the cost of a 1-element ring
        let fixed = crate::comm::allreduce::shadow_cost(
            crate::comm::allreduce::Algo::Ring,
            ws.world,
            1,
            &mut ctx,
        )
        .time
        .as_us();
        let total = (full.time.as_us() - fixed).max(0.0) + fixed / RING_PIPELINE;
        // bandwidth share of staging only (see horovod.rs)
        let pcie = ws.cluster.fabric.pcie.beta_gbs * 1e3;
        let staging_crit = (4.0 * bytes as f64 / pcie).min(full.cost.staging_us);
        (total, staging_crit)
    }
}

/// Force the large-message (RSA-capable) context even for small tensors:
/// Baidu's implementation has a single code path.
const SMALL_OVERRIDE: usize = crate::comm::mpi::SMALL_MSG_BYTES + 1;

/// Overlap depth of fixed costs across back-to-back per-tensor rings.
const RING_PIPELINE: f64 = 8.0;

impl Default for Baidu {
    fn default() -> Self {
        Baidu::new()
    }
}

impl Strategy for Baidu {
    fn name(&self) -> String {
        "Baidu-MPI".into()
    }

    fn iteration(&self, ws: &WorldSpec) -> Result<IterationReport> {
        if ws.world == 1 {
            return Ok(IterationReport::from_times(self.name(), ws, ws.compute_time()));
        }
        // serialize per-tensor allreduces on the comm thread
        let mut thread_free = 0.0f64;
        let mut staging_total = 0.0f64;
        for (i, ready) in ws.tensor_readiness() {
            let bytes = ws.model.tensors[i].bytes();
            let start = thread_free.max(ready.as_us());
            let (total, staging) = self.ring_us(ws, bytes);
            thread_free = start + total;
            staging_total += staging;
        }
        let dilated = ws.compute_time().as_us()
            * (1.0 + self.runtime_tax * (1.0 - 1.0 / ws.world as f64));
        let skew = self.skew_us_per_rank * ws.world as f64;
        // staged copies contend with the training stream (see horovod.rs)
        let iter = SimTime::from_us(thread_free.max(dilated + staging_total) + skew);
        Ok(IterationReport::from_times(self.name(), ws, iter))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::MpiFlavor;
    use crate::models::resnet;
    use crate::strategies::Horovod;

    #[test]
    fn baidu_slower_than_horovod_same_mpi() {
        // Figure 3: Baidu lags Horovod despite the same ring idea —
        // fusion + algorithm selection matter.
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 16);
        let b = Baidu::new().iteration(&ws).unwrap();
        let h = Horovod::mpi(MpiFlavor::Mvapich2).iteration(&ws).unwrap();
        assert!(
            b.imgs_per_sec <= h.imgs_per_sec * 1.001,
            "baidu {} should not beat horovod {}",
            b.imgs_per_sec,
            h.imgs_per_sec
        );
    }

    #[test]
    fn scales_but_below_ideal() {
        let ws1 = WorldSpec::new(presets::ri2(), resnet::resnet50(), 2);
        let ws16 = WorldSpec::new(presets::ri2(), resnet::resnet50(), 16);
        let r2 = Baidu::new().iteration(&ws1).unwrap();
        let r16 = Baidu::new().iteration(&ws16).unwrap();
        assert!(r16.imgs_per_sec > 4.0 * r2.imgs_per_sec / 2.0 * 0.9);
        assert!(r16.scaling_efficiency < 1.0);
        assert!(r16.scaling_efficiency > 0.3);
    }
}
