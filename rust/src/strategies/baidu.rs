//! Baidu tf.contrib.mpi_collectives (§III-C1): the original ring-allreduce
//! contribution — per-tensor ring allreduce built on MPI_Send/MPI_Irecv.
//! Two handicaps vs Horovod that Figure 3 shows: no tensor fusion (every
//! tensor pays the full 2(p−1)-step ring latency) and p2p-level MPI usage
//! (driver queries + per-message software overhead on every hop).
//!
//! Each per-tensor ring is a `CommOp` schedule replayed onto the engine
//! (or, when the scenario skews individual ranks, a per-rank ring
//! `CommGraph` whose dependency edges propagate the skew); the
//! graph-rewrite comm thread is a stream-lane set serializing tensors at
//! the default `streams = 1` the way Horovod's fusion buffers serialize,
//! and interleaving per-tensor rings across lanes when the scenario
//! opens the overlapped regime (§Overlap).

use std::collections::HashMap;
use std::rc::Rc;

use crate::util::error::Result;

use super::scenario::Scenario;
use super::{IterationReport, LaneJob, Strategy, WorldSpec};
use crate::comm::allreduce::Algo;
use crate::comm::commop::{resolve_ops, steps_sig, CommResources, CommSchedule, StepCost};
use crate::comm::graph::{ring_graph_placed, GraphResources, TemplateCache, TemplateKey};
use crate::comm::{MpiFlavor, MpiWorld};
use crate::sim::{Engine, ProgStep, SimTime};

#[derive(Debug, Clone)]
pub struct Baidu {
    pub flavor: MpiFlavor,
    /// TF-runtime dilation (see horovod.rs); Baidu's graph-rewrite
    /// operators are coarser than Horovod's, hence the larger tax.
    pub runtime_tax: f64,
    /// Per-iteration synchronization skew, µs per rank (see horovod.rs).
    pub skew_us_per_rank: f64,
    /// Build-once/replay-many ring templates (§Perf), keyed by
    /// `(ring, world, step-cost signature)`; tensors bucket by size, so
    /// a per-tensor iteration builds one graph per distinct tensor size.
    /// The pipeline-amortization scale is per-iteration overlay state,
    /// not part of the template.
    pub cache: TemplateCache,
}

impl Baidu {
    pub fn new() -> Baidu {
        Baidu {
            flavor: MpiFlavor::Mvapich2,
            runtime_tax: 0.05,
            skew_us_per_rank: 550.0,
            cache: TemplateCache::default(),
        }
    }

    pub fn with_flavor(flavor: MpiFlavor) -> Baidu {
        Baidu { flavor, ..Baidu::new() }
    }

    /// Ring allreduce of one tensor as a `CommOp` schedule (Baidu always
    /// rings, regardless of size — no algorithm selection), plus the
    /// critical host-staging share (see horovod.rs).
    ///
    /// Successive per-tensor rings pipeline: while one tensor's ring step
    /// waits on the wire, the next tensor's sends are already posted
    /// (MPI_Irecv-based implementation), so the per-step *fixed* costs
    /// (α, sw, launch, driver) amortize by `RING_PIPELINE` across the
    /// tensor stream — without this, a 1000-tensor model at p=128 would
    /// pay 2(p−1)·α serially per tensor, which the paper's "Baidu ≈
    /// Horovod" Figure 9 result rules out.  The amortization scales the
    /// schedule uniformly so the replayed total equals the pipelined cost.
    fn ring_schedule(&self, ws: &WorldSpec, sc: &Scenario, bytes: usize) -> (CommSchedule, f64) {
        let (steps, scale, staging_crit) = self.ring_steps(ws, sc, bytes);
        let mut sched = CommSchedule::from_steps(&steps);
        sched.scale(scale);
        (sched, staging_crit)
    }

    /// The ring's per-step cost sequence, the pipeline-amortization scale
    /// factor, and the critical host-staging share — the common input of
    /// the serialized schedule above and the per-rank ring graph.
    fn ring_steps(&self, ws: &WorldSpec, sc: &Scenario, bytes: usize) -> (Vec<StepCost>, f64, f64) {
        let w = MpiWorld::new(self.flavor, ws.cluster.clone());
        let (_, mut ctx) = w.plan(bytes.max(SMALL_OVERRIDE)); // transport from flavor
        ctx.wire.beta_gbs /=
            ws.cluster.fabric.contention_factor(ws.world) * sc.wire_derate();
        let n = (bytes / 4).max(1);
        let (full, steps) =
            crate::comm::allreduce::shadow_steps(Algo::Ring, ws.world, n, &mut ctx);
        // fixed (size-independent) share ≈ the cost of a 1-element ring
        let fixed = crate::comm::allreduce::shadow_cost(Algo::Ring, ws.world, 1, &mut ctx)
            .time
            .as_us();
        let full_us = full.time.as_us();
        let total = (full_us - fixed).max(0.0) + fixed / RING_PIPELINE;
        let scale = if full_us > 0.0 { total / full_us } else { 1.0 };
        // bandwidth share of staging only (see horovod.rs)
        let pcie = ws.cluster.fabric.pcie.beta_gbs * 1e3;
        let staging_crit = (4.0 * bytes as f64 / pcie).min(full.cost.staging_us);
        (steps, scale, staging_crit)
    }

    /// The iteration's per-tensor rings as cached graph templates plus
    /// per-tensor overlays and release times — shared by
    /// [`Baidu::iteration_graph`] and the two-job graph-path link-share
    /// runner.  Templates build under the cluster's placement (hops
    /// between co-located ranks re-cost onto the node-local link; the
    /// layout and intra-hop factor join the cache key).
    pub(crate) fn graph_items(
        &self,
        ws: &WorldSpec,
        sc: &Scenario,
    ) -> Result<Vec<super::GraphWork>> {
        let place = ws.cluster.placement();
        let local = ws.cluster.fabric.local_hop_factor();
        let stretch = sc.compute_stretch();
        let readiness = ws.tensor_readiness();
        let mut items = Vec::with_capacity(readiness.len());
        let mut per_bytes: HashMap<usize, (Vec<StepCost>, f64, f64)> = HashMap::new();
        for (i, ready) in readiness {
            let ready = SimTime::from_us(ready.as_us() * stretch);
            let bytes = ws.model.tensors[i].bytes();
            let (steps, scale, staging) = per_bytes
                .entry(bytes)
                .or_insert_with(|| self.ring_steps(ws, sc, bytes));
            let mut sig = steps_sig(steps);
            sig.push(local.to_bits());
            let template = self.cache.get_or_build(
                TemplateKey::allreduce_placed(Algo::Ring, ws.world, place, sig),
                || ring_graph_placed(ws.world, steps, place, local),
            );
            let mut overlay = sc.overlay(ws.world, i as u64);
            overlay.scale_global(*scale);
            items.push(super::GraphWork { ready, template, overlay, staging_us: *staging });
        }
        Ok(items)
    }

    /// One iteration with every per-tensor ring executed as a per-rank
    /// dependency graph on placement-aware resources (see
    /// `Horovod::iteration_graph`); `iteration_in` routes here when the
    /// scenario skews individual ranks or the cluster places more than
    /// one GPU per node, and the neutral-scenario 1-GPU-per-node
    /// equivalence with the serialized replay is pinned by
    /// `tests/des_regression.rs`.  §Perf: rings are cached templates
    /// per tensor-size bucket; the pipeline amortization is the overlay's
    /// global scale, applied at replay time.
    pub fn iteration_graph(&self, ws: &WorldSpec, sc: &Scenario) -> Result<IterationReport> {
        if ws.world == 1 {
            let iter = SimTime::from_us(ws.compute_time().as_us() * sc.compute_stretch());
            return Ok(IterationReport::from_times(self.name(), ws, iter));
        }
        let mut e = Engine::new();
        let res = GraphResources::install_placed(&mut e, ws.world, ws.cluster.placement());
        let items = self.graph_items(ws, sc)?;
        let job = LaneJob::graphs(&mut e, &res, sc.lanes(), items, SimTime::ZERO);
        e.run();
        let parts = super::close_iteration_parts(
            ws,
            sc,
            &job.trace(&e)?,
            SimTime::ZERO,
            self.runtime_tax,
            self.skew_us_per_rank,
        );
        let util = res.utilization(&e);
        Ok(super::report_with_comm_thread(self.name(), ws, parts, util, &mut e, job.set()))
    }

    /// Schedule one Baidu job's communication onto an engine: the
    /// per-tensor pipelined ring programs release at their (stretched)
    /// ready times onto the job's comm stream lanes (`streams = 1` = the
    /// classic graph-rewrite comm thread, serializing tensors FIFO).
    /// Programs bucket by tensor size (§Perf) and are shared across
    /// equal-size tensors; the tensor loop schedules only typed lane
    /// events — no boxed closure per tensor.
    pub(crate) fn schedule_job(
        &self,
        ws: &WorldSpec,
        sc: &Scenario,
        e: &mut Engine,
        res: CommResources,
    ) -> Result<LaneJob> {
        let stretch = sc.compute_stretch();
        let map = res.mapper();
        let mut memo: HashMap<usize, (Rc<[ProgStep]>, f64)> = HashMap::new();
        let mut staging_total = 0.0;
        let mut items = Vec::new();
        for (i, ready) in ws.tensor_readiness() {
            let ready = SimTime::from_us(ready.as_us() * stretch);
            let bytes = ws.model.tensors[i].bytes();
            let (steps, staging) = memo
                .entry(bytes)
                .or_insert_with(|| {
                    let (sched, staging) = self.ring_schedule(ws, sc, bytes);
                    (resolve_ops(&sched.ops, &map), staging)
                })
                .clone();
            staging_total += staging;
            items.push((ready, steps));
        }
        Ok(LaneJob::programs(e, sc.lanes(), items, staging_total, SimTime::ZERO))
    }
}

/// Force the large-message (RSA-capable) context even for small tensors:
/// Baidu's implementation has a single code path.
const SMALL_OVERRIDE: usize = crate::comm::mpi::SMALL_MSG_BYTES + 1;

/// Overlap depth of fixed costs across back-to-back per-tensor rings.
const RING_PIPELINE: f64 = 8.0;

impl Default for Baidu {
    fn default() -> Self {
        Baidu::new()
    }
}

impl Strategy for Baidu {
    fn name(&self) -> String {
        "Baidu-MPI".into()
    }

    fn iteration_in(&self, ws: &WorldSpec, sc: &Scenario) -> Result<IterationReport> {
        if !sc.fault.is_empty() {
            // fault injection routes through the shared recovery runner
            // (§Robustness); an empty plan never reaches this branch, so
            // the fault-free paths below stay bit-identical
            return super::recovery::run_faulted_collective(
                self.name(),
                ws,
                sc,
                self.runtime_tax,
                self.skew_us_per_rank,
                &|ws, sc| self.graph_items(ws, sc),
            );
        }
        if sc.rejoin_rebuild_us > 0.0 {
            // elastic rejoin (§Robustness campaign): the grown world's
            // templates re-form before any ring launches; zero rebuild
            // never reaches this branch
            return super::recovery::run_rejoin_collective(
                self.name(),
                ws,
                sc,
                self.runtime_tax,
                self.skew_us_per_rank,
                &|ws, sc| self.graph_items(ws, sc),
            );
        }
        if ws.world == 1 {
            let iter = SimTime::from_us(ws.compute_time().as_us() * sc.compute_stretch());
            return Ok(IterationReport::from_times(self.name(), ws, iter));
        }
        if sc.per_rank_skew() || !ws.cluster.placement().is_trivial() || sc.overlapped() {
            return self.iteration_graph(ws, sc);
        }
        // per-tensor rings serialize on the comm stream lane (streams =
        // 1: the graph-rewrite comm thread); each ring runs its resolved
        // program on the job's resources
        let mut e = Engine::new();
        let res = CommResources::install(&mut e);
        let job = self.schedule_job(ws, sc, &mut e, res)?;
        e.run();
        let parts = super::close_iteration_parts(
            ws,
            sc,
            &job.trace(&e)?,
            SimTime::ZERO,
            self.runtime_tax,
            self.skew_us_per_rank,
        );
        let util = res.utilization(&e);
        Ok(super::report_with_comm_thread(self.name(), ws, parts, util, &mut e, job.set()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::MpiFlavor;
    use crate::models::resnet;
    use crate::strategies::Horovod;

    #[test]
    fn baidu_slower_than_horovod_same_mpi() {
        // Figure 3: Baidu lags Horovod despite the same ring idea —
        // fusion + algorithm selection matter.
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 16);
        let b = Baidu::new().iteration(&ws).unwrap();
        let h = Horovod::mpi(MpiFlavor::Mvapich2).iteration(&ws).unwrap();
        assert!(
            b.imgs_per_sec <= h.imgs_per_sec * 1.001,
            "baidu {} should not beat horovod {}",
            b.imgs_per_sec,
            h.imgs_per_sec
        );
    }

    #[test]
    fn scales_but_below_ideal() {
        let ws1 = WorldSpec::new(presets::ri2(), resnet::resnet50(), 2);
        let ws16 = WorldSpec::new(presets::ri2(), resnet::resnet50(), 16);
        let r2 = Baidu::new().iteration(&ws1).unwrap();
        let r16 = Baidu::new().iteration(&ws16).unwrap();
        assert!(r16.imgs_per_sec > 4.0 * r2.imgs_per_sec / 2.0 * 0.9);
        assert!(r16.scaling_efficiency < 1.0);
        assert!(r16.scaling_efficiency > 0.3);
    }

    #[test]
    fn per_tensor_rings_fill_the_ledger() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 8);
        let r = Baidu::new().iteration(&ws).unwrap();
        let thread = r.resource_util.iter().find(|u| u.name == "comm-thread").unwrap();
        // one gate grant per tensor (no fusion)
        assert_eq!(thread.served as usize, ws.model.tensors.len());
    }
}
