//! Failure recovery for the allreduce families (§Robustness).
//!
//! One runner serves both Horovod and Baidu — they differ only in how
//! the iteration's collectives are built (`items_for`), not in how they
//! fail.  The recovery model is abort-and-restart with elastic shrink:
//!
//! ```text
//! phase 1 (world p)      run until the crash instant, count the k
//!                        collectives that completed, abort the rest
//! detect                 the runtime declares the peer suspect after
//!                        the plan's detection timeout
//! backoff                bounded exponential retries (all exhausted —
//!                        the peer is dead, not slow)
//! rebuild                collective templates re-formed over the
//!                        surviving world (elastic shrink to p−1)
//! phase 2 (world p−1)    the remaining collectives replay from the
//!                        last completed fusion buffer — valid because
//!                        the fusion schedule depends on model/cluster/
//!                        batch, not world size, so phase 2 has the
//!                        same buffer list
//! ```
//!
//! Transient faults (link flaps, rail failures) never shrink the world:
//! a flap FIFO-holds its NIC port for the window (in-flight retries
//! queue behind it and drain when it lifts) and a rail failure derates
//! the node's ranks for the whole iteration (failover onto the
//! surviving rails).
//!
//! The detect/backoff/rebuild intervals are recorded as trace marks on
//! the recovery track, chained back-to-back so the critical-path
//! retro-walk attributes the recovery gap instead of charging it to
//! compute (§Observability).
//!
//! This module is only entered when `!sc.fault.is_empty()` — the
//! empty-plan bit-identity guarantee lives in the callers' routing.

use super::scenario::Scenario;
use super::{FaultReport, GraphWork, IterationReport, JobTrace, LaneJob, WorldSpec};
use crate::comm::graph::GraphResources;
use crate::sim::{Engine, FaultKind, FaultPlan, SimTime, SpanKind};
use crate::util::error::Result;

/// Build-the-items callback: the strategy's `graph_items` under a given
/// (possibly shrunk) world.
pub(crate) type ItemsFor<'a> = &'a dyn Fn(&WorldSpec, &Scenario) -> Result<Vec<GraphWork>>;

/// Event budget for the recovery/rejoin engine runs: far above any
/// legitimate single-iteration count (a world-128 fault run executes
/// ~10M events), so tripping it means a scheduling livelock, not a big
/// run (§Robustness chaos invariant: the queue must drain).
pub(crate) const DRAIN_BUDGET: u64 = 100_000_000;

/// Run one fault-injected iteration of an allreduce-family strategy.
pub(crate) fn run_faulted_collective(
    name: String,
    ws: &WorldSpec,
    sc: &Scenario,
    runtime_tax: f64,
    skew_us_per_rank: f64,
    items_for: ItemsFor,
) -> Result<IterationReport> {
    let plan = sc.fault.clone();
    let place = ws.cluster.placement();
    plan.validate(ws.world, &place)?;
    crate::ensure!(
        ws.world >= 2,
        "fault injection needs a distributed run (world {} < 2)",
        ws.world
    );

    // The runner is the only consumer of the plan: everything below the
    // items callback runs under a fault-free scenario so no inner path
    // re-enters the fault machinery.
    let mut sc_run = sc.clone();
    sc_run.fault = FaultPlan::default();

    let mut e = Engine::new();
    let rails = place.rails;
    let res = GraphResources::install_placed(&mut e, ws.world, place);
    let mut items = items_for(ws, &sc_run)?;
    let n_items = items.len();

    let crash = plan.first_crash();
    if let Some((_, rank, Some(factor))) = crash {
        // straggler-escalates-to-dead: the dying rank limps until the
        // crash instant
        for it in &mut items {
            it.overlay.scale_rank(ws.world, rank, factor);
        }
    }
    apply_rail_failover(&plan, ws.world, &place, &mut items);

    let job = LaneJob::graphs(&mut e, &res, sc_run.lanes(), items, SimTime::ZERO);
    for (at, node, rail, dur) in plan.flaps() {
        // the port goes dark: FIFO-hold it for the window, stalling
        // queued and in-flight transfers behind the outage
        let port = res.wire[node * rails + rail];
        e.at(at, move |e| e.hold(port, dur));
    }

    if let Some((t_fail, _dead, _)) = crash {
        // --- abort: freeze the world at the crash instant ---
        e.run_until(t_fail);
        let done = e.lane_completed(job.set());
        e.lane_abort(job.set());
        e.clear_pending();
        e.trace_truncate(t_fail);

        // --- detect -> backoff -> rebuild, back-to-back on the clock ---
        let detect = SimTime::from_us(plan.detect_timeout_us);
        let detect_end = t_fail + detect;
        let backoff_end = detect_end + SimTime::from_us(plan.backoff_total_us());
        let rebuild_end = backoff_end + SimTime::from_us(plan.rebuild_us);
        e.trace_mark(SpanKind::Fault, t_fail, detect_end);
        e.trace_mark(SpanKind::Backoff, detect_end, backoff_end);
        e.trace_mark(SpanKind::Rebuild, backoff_end, rebuild_end);

        // --- elastic shrink: restart over the surviving world ---
        let mut ws2 = ws.clone();
        ws2.world = ws.world - 1;
        let place2 = ws2.cluster.placement();
        let res2 = GraphResources::install_placed(&mut e, ws2.world, place2);
        let mut items2 = items_for(&ws2, &sc_run)?;
        crate::ensure!(
            items2.len() == n_items,
            "fusion schedule changed across the elastic shrink: {} vs {} collectives",
            items2.len(),
            n_items
        );
        apply_rail_failover(&plan, ws2.world, &place2, &mut items2);
        let tail: Vec<GraphWork> = items2
            .drain(done.min(n_items)..)
            .map(|mut w| {
                // gradients were already produced — every surviving
                // collective is ready the moment the rebuild lands
                w.ready = SimTime::ZERO;
                w
            })
            .collect();
        let job2 = LaneJob::graphs(&mut e, &res2, sc_run.lanes(), tail, rebuild_end);
        e.run_budgeted(DRAIN_BUDGET)?;

        // recovery extends the timeline even when no collective was
        // left to replay (crash after the comm phase finished)
        let comm_end = job2.trace(&e)?.comm_end.max(rebuild_end);
        let trace = JobTrace { comm_end, staging_us: job.staging_us };
        let parts = super::close_iteration_parts(
            &ws2,
            &sc_run,
            &trace,
            SimTime::ZERO,
            runtime_tax,
            skew_us_per_rank,
        );
        let iter = parts.iter;
        let util = res2.utilization(&e);
        let mut report =
            super::report_with_comm_thread(name, &ws2, parts, util, &mut e, job2.set());
        let lost = plan.lost_work(t_fail);
        report.fault = Some(FaultReport {
            failed_at: t_fail,
            detect,
            recover: rebuild_end.saturating_sub(t_fail),
            lost_work: lost,
            // a dead peer exhausts the retry budget before the runtime
            // gives up on it
            retries: plan.max_retries,
            surviving_world: ws2.world,
            goodput_imgs_per_sec: ws2.world as f64 * ws2.batch_per_gpu as f64
                / (iter.as_secs() + lost.as_secs()),
        });
        Ok(report)
    } else {
        // --- transient faults only: the full world survives ---
        e.run_budgeted(DRAIN_BUDGET)?;
        let detect = SimTime::from_us(plan.detect_timeout_us);
        for ev in &plan.events {
            let t0 = SimTime::from_us(ev.at_us);
            match ev.kind {
                FaultKind::LinkFlap { for_us, .. } => {
                    e.trace_mark(SpanKind::Fault, t0, t0 + SimTime::from_us(for_us));
                }
                FaultKind::RailDown { .. } => {
                    e.trace_mark(SpanKind::Fault, t0, t0 + detect);
                }
                _ => {}
            }
        }
        let parts = super::close_iteration_parts(
            ws,
            &sc_run,
            &job.trace(&e)?,
            SimTime::ZERO,
            runtime_tax,
            skew_us_per_rank,
        );
        let iter = parts.iter;
        let util = res.utilization(&e);
        let mut report =
            super::report_with_comm_thread(name, ws, parts, util, &mut e, job.set());
        let failed_at = plan
            .events
            .iter()
            .map(|ev| SimTime::from_us(ev.at_us))
            .min()
            .unwrap_or(SimTime::ZERO);
        let flap_end = plan
            .flaps()
            .iter()
            .map(|&(at, _, _, dur)| at + dur)
            .max()
            .unwrap_or(failed_at);
        let longest_flap = plan
            .flaps()
            .iter()
            .map(|&(_, _, _, dur)| dur)
            .max()
            .unwrap_or(SimTime::ZERO);
        report.fault = Some(FaultReport {
            failed_at,
            detect,
            // healthy again when the last flap lifts, never before one
            // detection window has passed
            recover: (flap_end.max(failed_at + detect)).saturating_sub(failed_at),
            lost_work: SimTime::ZERO,
            retries: retries_to_bridge(&plan, longest_flap.as_us()),
            surviving_world: ws.world,
            goodput_imgs_per_sec: ws.world as f64 * ws.batch_per_gpu as f64 / iter.as_secs(),
        });
        Ok(report)
    }
}

/// Run one elastic-rejoin iteration of an allreduce-family strategy
/// (§Robustness campaign): the repaired rank rejoins at the iteration
/// boundary, so the collective templates are re-formed over the grown
/// (full) world before any collective can launch.  The grow-back
/// rebuild overlaps compute — survivors keep producing gradients while
/// the communicator re-forms — so every collective's release is offset
/// by the rebuild window and the compute side is untouched.  The
/// rebuild interval rides the recovery track as a `Rebuild` mark, same
/// as the shrink path's.
///
/// Only entered with `sc.rejoin_rebuild_us > 0` and an empty fault plan
/// — the zero-rebuild guarantee mirrors the empty-plan one and lives in
/// the callers' routing.
pub(crate) fn run_rejoin_collective(
    name: String,
    ws: &WorldSpec,
    sc: &Scenario,
    runtime_tax: f64,
    skew_us_per_rank: f64,
    items_for: ItemsFor,
) -> Result<IterationReport> {
    crate::ensure!(
        ws.world >= 2,
        "elastic rejoin needs a distributed run (world {} < 2)",
        ws.world
    );
    let rebuild = SimTime::from_us(sc.rejoin_rebuild_us);
    let mut sc_run = sc.clone();
    sc_run.rejoin_rebuild_us = 0.0;

    let mut e = Engine::new();
    let res = GraphResources::install_placed(&mut e, ws.world, ws.cluster.placement());
    let items = items_for(ws, &sc_run)?;
    e.trace_mark(SpanKind::Rebuild, SimTime::ZERO, rebuild);
    let job = LaneJob::graphs(&mut e, &res, sc_run.lanes(), items, rebuild);
    e.run_budgeted(DRAIN_BUDGET)?;

    // the rebuild extends the comm timeline even if no collective runs
    let comm_end = job.trace(&e)?.comm_end.max(rebuild);
    let trace = JobTrace { comm_end, staging_us: job.staging_us };
    let parts =
        super::close_iteration_parts(ws, &sc_run, &trace, SimTime::ZERO, runtime_tax, skew_us_per_rank);
    let util = res.utilization(&e);
    Ok(super::report_with_comm_thread(name, ws, parts, util, &mut e, job.set()))
}

/// A failed rail's traffic fails over onto the node's surviving rails:
/// every rank on the node drives its collective `rails/(rails−1)` slower
/// for the whole iteration (the conservative whole-rank derate — the
/// engine has no per-kind overlay, and wire time dominates the derated
/// ranks' steps).
fn apply_rail_failover(
    plan: &FaultPlan,
    world: usize,
    place: &crate::cluster::Placement,
    items: &mut [GraphWork],
) {
    for (node, _rail) in plan.rail_downs() {
        let f = place.rails as f64 / (place.rails - 1) as f64;
        for r in 0..world {
            if place.node_of(r) == node {
                for it in items.iter_mut() {
                    it.overlay.scale_rank(world, r, f);
                }
            }
        }
    }
}

/// How many bounded retries it takes until the cumulative backoff wait
/// covers a transient outage of `dur_us` (all of them if it never does).
pub(crate) fn retries_to_bridge(plan: &FaultPlan, dur_us: f64) -> u32 {
    if dur_us <= 0.0 {
        return 0;
    }
    let mut acc = 0.0;
    for i in 0..plan.max_retries {
        acc += plan.backoff_base_us * plan.backoff_factor.powi(i as i32);
        if acc >= dur_us {
            return i + 1;
        }
    }
    plan.max_retries
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retries_to_bridge_walks_the_backoff_ladder() {
        let plan = FaultPlan {
            backoff_base_us: 100.0,
            backoff_factor: 2.0,
            max_retries: 3,
            ..FaultPlan::default()
        };
        assert_eq!(retries_to_bridge(&plan, 0.0), 0);
        assert_eq!(retries_to_bridge(&plan, 50.0), 1); // 100 covers it
        assert_eq!(retries_to_bridge(&plan, 250.0), 2); // 100+200
        assert_eq!(retries_to_bridge(&plan, 699.0), 3);
        assert_eq!(retries_to_bridge(&plan, 10_000.0), 3, "budget exhausted");
    }
}
