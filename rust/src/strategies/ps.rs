//! Parameter-server strategies (§III-A/B): TF's default gRPC PS plus the
//! gRPC+MPI and gRPC+Verbs tensor-offload contribs.
//!
//! Simulated on the discrete-event engine because the PS pathologies are
//! *queueing* effects: every worker pushes its gradients to the parameter
//! shards and pulls updated parameters back, so each PS NIC serializes
//! W transfers per tensor per direction (fan-in), and the gRPC+MPI
//! contrib additionally serializes *everything* through one MPI service
//! thread per process (§III-B1, "single thread for all MPI related
//! operations" — the Figure 9 worst case).
//!
//! Since the `CommGraph` port, each parameter shard is one explicit
//! **fan-in/fan-out DAG** (`comm::graph::ps_fanin_graph`): W push chains
//! → the owning server's update node → W pull chains.  The fan-in barrier
//! that used to be a hand-rolled countdown is now a dependency join; the
//! NIC FIFO resources still produce the congestion, the op durations
//! still come from the gRPC/Verbs/MPI transport cost models, and scenario
//! knobs perturb individual workers' nodes.  `iteration_reference` keeps
//! the pre-graph serialized-replay implementation as the regression
//! oracle (`tests/des_regression.rs` pins the two within tolerance).
//!
//! PS placement follows the paper's tf_cnn_benchmarks setup: one PS task
//! colocated per worker node (`ps_count == world`), parameters sharded
//! round-robin across them.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use crate::util::error::Result;

use super::scenario::Scenario;
use super::{FaultReport, GraphLaneDriver, IterationReport, JobTrace, Strategy, WorldSpec};
use crate::cluster::ClusterSpec;
use crate::comm::commop::{replay, CommOp, RelPin, ResKind, ResMap, ResourceUse};
use crate::comm::graph::{
    ps_fanin_graph, ps_fanin_pulls, GraphOverlay, GraphResMap, GraphRun, GraphTemplate, NodeId,
    TemplateCache, TemplateKey,
};
use crate::comm::grpc::GrpcTransport;
use crate::comm::rdma::RdmaTransport;
use crate::comm::verbs::VerbsTransport;
use crate::comm::{MpiFlavor, MpiWorld};
use crate::sim::{Engine, FaultKind, FaultPlan, LaneSetId, ResourceId, SimTime, SpanKind};

/// Which library carries the tensor payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PsTransport {
    Grpc,
    Mpi,
    Verbs,
    /// One-sided RDMA writes, zero-copy: no protobuf encode, no request
    /// RPC leg, no host staging when the fabric has GDR
    /// ([`RdmaTransport`]).
    Rdma,
}

#[derive(Debug, Clone)]
pub struct PsStrategy {
    pub transport: PsTransport,
    /// gRPC+MPI's single service thread: all of a worker's transfers
    /// (pushes and pull receptions) serialize through one queue.
    pub single_thread_worker: bool,
    /// Per-message dispatch overhead of that single thread, µs (progress
    /// polling + request matching) — why gRPC+MPI is worst for the
    /// many-tensor NASNet in Figure 9 despite the faster link.
    pub thread_dispatch_us: f64,
    /// TF PS-machinery dilation of distributed steps (variable-update
    /// graph ops, session-run overheads) — larger than the Horovod tax.
    pub runtime_tax: f64,
    /// Per-iteration synchronization skew, µs per rank (see horovod.rs).
    pub skew_us_per_rank: f64,
    /// Build-once/replay-many fan-in templates (§Perf follow-up,
    /// cross-call PS templating): shard DAGs carry *named* resource pins
    /// ([`RelPin`]) instead of engine ids, so one template serves every
    /// call and engine; keyed per `(world, placement, server ⧺ cost
    /// signature)`.  Shared across clones.
    pub cache: TemplateCache,
}

impl PsStrategy {
    pub fn grpc() -> PsStrategy {
        PsStrategy {
            transport: PsTransport::Grpc,
            single_thread_worker: false,
            thread_dispatch_us: 0.0,
            runtime_tax: 0.10,
            skew_us_per_rank: 470.0,
            cache: TemplateCache::default(),
        }
    }

    pub fn grpc_mpi() -> PsStrategy {
        PsStrategy {
            transport: PsTransport::Mpi,
            single_thread_worker: true,
            thread_dispatch_us: 700.0,
            runtime_tax: 0.10,
            skew_us_per_rank: 470.0,
            cache: TemplateCache::default(),
        }
    }

    pub fn grpc_verbs() -> PsStrategy {
        PsStrategy {
            transport: PsTransport::Verbs,
            single_thread_worker: false,
            thread_dispatch_us: 0.0,
            runtime_tax: 0.10,
            skew_us_per_rank: 470.0,
            cache: TemplateCache::default(),
        }
    }

    /// The RDMA zero-copy variant: same PS machinery (runtime tax, skew,
    /// fan-in topology) as the rest of the family, so the figure sweeps
    /// isolate the transport — one-sided writes with no encode and no
    /// staging under GDR.
    pub fn rdma() -> PsStrategy {
        PsStrategy {
            transport: PsTransport::Rdma,
            single_thread_worker: false,
            thread_dispatch_us: 0.0,
            runtime_tax: 0.10,
            skew_us_per_rank: 470.0,
            cache: TemplateCache::default(),
        }
    }

    /// (fixed per-transfer overhead µs, payload link bandwidth GB/s) for
    /// one tensor of `bytes` — the β part is modeled by the NIC resources.
    fn transfer_params(&self, cluster: &ClusterSpec, bytes: usize, pull: bool) -> (f64, f64) {
        match self.transport {
            PsTransport::Grpc => {
                let t = GrpcTransport::new(cluster.fabric.tcp, cluster.fabric.pcie);
                let c = if pull { t.tensor_pull_cost(bytes) } else { t.tensor_rpc_cost(bytes) };
                (c.total_us() - t.link.wire_us(bytes), t.link.beta_gbs)
            }
            PsTransport::Verbs => {
                let t = VerbsTransport::new(&cluster.fabric);
                let c = t.tensor_cost(bytes);
                (c.total_us() - t.link.wire_us(bytes), t.link.beta_gbs)
            }
            // one-sided transfers: pushes and pulls cost the same (no
            // request leg either way), so `pull` does not matter here
            PsTransport::Rdma => {
                let t = RdmaTransport::new(&cluster.fabric);
                let c = t.tensor_cost(bytes);
                (c.total_us() - t.link.wire_us(bytes), t.link.beta_gbs)
            }
            PsTransport::Mpi => {
                let w = MpiWorld::new(MpiFlavor::Mvapich2, cluster.clone());
                let c = w.p2p_cost(bytes);
                (c.total_us() - cluster.fabric.inter.wire_us(bytes), cluster.fabric.inter.beta_gbs)
            }
        }
    }

    /// Shard the variables across PS tasks the way TF's greedy
    /// load-balancing placer does, with readiness stretched by the
    /// scenario's slowest rank.  Returns per-shard
    /// `(bytes, push_fixed_us, pull_fixed_us, server, ready)`.
    fn shard_plan(&self, ws: &WorldSpec, sc: &Scenario) -> Vec<(usize, f64, f64, usize, SimTime)> {
        let ps_count = ws.world;
        let stretch = sc.compute_stretch();
        // Variables above min_slice_size (TF's partitioner default, ~4MB)
        // split into PartitionedVariable pieces; everything else stays
        // whole — so the PS holding a popular mid-size variable still
        // serves W pulls of it per step, which is the fan-in hot-spot
        // that throttles gRPC for the small-compute models (H4's 3.2×
        // MobileNet gap).
        const MIN_SLICE: usize = 4 << 20;
        let mut shards: Vec<(usize, SimTime)> = Vec::new(); // (bytes, ready)
        for &(t, ready) in &ws.tensor_readiness() {
            let ready = SimTime::from_us(ready.as_us() * stretch);
            let bytes = ws.model.tensors[t].bytes();
            let pieces = bytes.div_ceil(MIN_SLICE).max(1);
            let piece = bytes / pieces;
            // the remainder folds into the last piece — the split must
            // conserve the variable's bytes exactly (padding pieces, as
            // the old `.max(4)` floor did, silently inflated the plan)
            for i in 0..pieces {
                let b = if i + 1 == pieces { piece + bytes % pieces } else { piece };
                shards.push((b, ready));
            }
        }
        let model_total: usize = ws.model.tensors.iter().map(|t| t.bytes()).sum();
        let shard_total: usize = shards.iter().map(|&(b, _)| b).sum();
        assert_eq!(
            shard_total, model_total,
            "shard plan lost bytes: shards carry {shard_total}, model holds {model_total}"
        );
        // greedy least-loaded assignment, largest shards first (the
        // standard LPT heuristic TF's GreedyLoadBalancingStrategy applies)
        let mut order: Vec<usize> = (0..shards.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(shards[i].0));
        let mut load = vec![0usize; ps_count];
        let mut assigned = vec![0usize; shards.len()];
        for &i in &order {
            let ps = (0..ps_count).min_by_key(|&s| load[s]).unwrap();
            load[ps] += shards[i].0;
            assigned[i] = ps;
        }
        shards
            .iter()
            .enumerate()
            .map(|(i, &(bytes, ready))| {
                let (push_fixed, _) = self.transfer_params(&ws.cluster, bytes, false);
                let (pull_fixed, _) = self.transfer_params(&ws.cluster, bytes, true);
                (bytes, push_fixed, pull_fixed, assigned[i], ready)
            })
            .collect()
    }

    /// Schedule one PS job onto the engine: per parameter shard, one
    /// [`ps_fanin_graph`] — W push chains converging on the owning
    /// server's update node, fanning back out into W pull chains —
    /// released at the shard's readiness plus `offset`.  Wire ops pin to
    /// the (shareable) fabric's NIC queues — except transfers between a
    /// worker and a PS task co-located on one dense node, which ride the
    /// node's PCIe/NVLink path off the port; the gRPC+MPI single service
    /// thread is a per-worker pinned resource private to this job.
    /// §Perf: shards bucket by `(bytes, server)` — the fan-in DAG is
    /// built once per bucket as a `GraphTemplate` in the **strategy-level
    /// [`TemplateCache`]** and replayed per shard under the scenario's
    /// overlay.  Templates carry *named* resource pins ([`RelPin`]:
    /// server ingress/egress, worker MPI thread) that this call's map
    /// resolves onto the engine's physical fabric ports, so one build
    /// serves every call, job and engine (cross-call PS templating; the
    /// old engine-id pins made fan-ins call-local).
    pub(crate) fn schedule_job(
        &self,
        ws: &WorldSpec,
        sc: &Scenario,
        e: &mut Engine,
        fabric: &PsFabric,
        offset: SimTime,
    ) -> Result<PsJob> {
        let w_count = ws.world;
        crate::ensure!(
            fabric.ingress.len() == w_count,
            "PS fabric sized for {} servers, world is {w_count}",
            fabric.ingress.len()
        );
        let per_shard = self.shard_plan(ws, sc);
        // payload link rate, bytes/µs (scenario load eats into it)
        let link_gbs = self.transfer_params(&ws.cluster, 1 << 20, false).1;
        let rate = link_gbs * 1e3 / sc.wire_derate();
        let wire_us = move |bytes: usize| bytes as f64 / rate;
        // per-worker MPI service thread (gRPC+MPI only): serialized AND
        // paying a fixed dispatch cost per message
        let dispatch_us = self.thread_dispatch_us;
        let single = self.single_thread_worker;
        let worker_tx: Option<Vec<ResourceId>> =
            single.then(|| (0..w_count).map(|_| e.unit_resource()).collect());
        if e.tracing() {
            if let Some(tx) = &worker_tx {
                use crate::sim::trace::pid_rank;
                for (w, &r) in tx.iter().enumerate() {
                    let name = format!("worker-tx r{w}");
                    e.trace_resource(r, crate::sim::SpanKind::Sw, pid_rank(w), w as u32, &name);
                }
            }
        }
        // µs it takes a PS CPU to aggregate W gradients and apply the
        // update (TF variable ops run single-threaded per variable, but
        // vectorized — ~8 GB/s of aggregated gradient data).
        let update_us = move |bytes: usize| 2.0 + w_count as f64 * bytes as f64 / 8e3;
        // Dense placements: a worker exchanging with a PS task on its own
        // node moves the payload over PCIe/NVLink, off the shared NIC
        // port (mirrors the placed builders' intra-node hop re-costing).
        // Inert at 1 GPU per node — there worker w ≡ server w is the
        // historical full-wire loopback, which keeps the PR-1 reference
        // oracle and every trivial-placement pin bit-identical.
        let place = ws.cluster.placement();
        let local = ws.cluster.fabric.local_hop_factor();
        let node_local = move |w: usize, s: usize| place.gpus_per_node > 1 && place.same_node(w, s);

        // this call's resolution of the templates' named pins: per-rank
        // kinds stay uncontended (None, the historical unmapped()), rel
        // pins land on the engine's fabric ports / worker threads
        let map: GraphResMap = {
            let ingress = fabric.ingress.clone();
            let egress = fabric.egress.clone();
            let tx = worker_tx.clone();
            Rc::new(move |_rank, _kind, rel| match rel {
                Some(RelPin::PsIn(s)) => Some(ingress[s as usize]),
                Some(RelPin::PsOut(s)) => Some(egress[s as usize]),
                Some(RelPin::WorkerTx(w)) => tx.as_ref().map(|t| t[w as usize]),
                None => None,
            })
        };

        let done = Rc::new(RefCell::new(0usize));
        let pulls = ps_fanin_pulls(w_count);
        let window = sc.rpc_window;
        let mut runs = Vec::with_capacity(if window == 0 { per_shard.len() } else { 0 });
        let mut lane_items: Vec<(Arc<GraphTemplate>, GraphOverlay)> = Vec::new();
        let mut lane_release: Vec<SimTime> = Vec::new();
        for (si, &(bytes, push_fixed, pull_fixed, ps, ready)) in per_shard.iter().enumerate() {
            // everything the shard's op durations and routing depend on,
            // bit-exact (world and placement live in the key proper)
            let sig = vec![
                ps as u64,
                single as u64,
                bytes as u64,
                push_fixed.to_bits(),
                pull_fixed.to_bits(),
                wire_us(bytes).to_bits(),
                update_us(bytes).to_bits(),
                dispatch_us.to_bits(),
                local.to_bits(),
            ];
            let template = self.cache.get_or_build(
                TemplateKey::ps_fanin(w_count, place, sig),
                || {
                    let push_ops = |w: usize| {
                        let mut ops = Vec::new();
                        if single {
                            ops.push(
                                CommOp::fixed(ResKind::Sw, wire_us(bytes) + dispatch_us)
                                    .rel_pinned(RelPin::WorkerTx(w as u32)),
                            );
                        }
                        ops.push(CommOp::fixed(ResKind::Sw, push_fixed));
                        if node_local(w, ps) {
                            // co-located pair: payload rides the node's
                            // local link, not the shared NIC port
                            ops.push(CommOp::fixed(ResKind::Pcie, wire_us(bytes) * local));
                        } else {
                            ops.push(
                                CommOp::fixed(ResKind::Wire, wire_us(bytes))
                                    .rel_pinned(RelPin::PsIn(ps as u32)),
                            );
                        }
                        ops
                    };
                    let update = vec![CommOp::fixed(ResKind::CpuReduce, update_us(bytes))];
                    let pull_ops = |w: usize| {
                        let mut ops = vec![if node_local(w, ps) {
                            CommOp::fixed(ResKind::Pcie, wire_us(bytes) * local)
                        } else {
                            CommOp::fixed(ResKind::Wire, wire_us(bytes))
                                .rel_pinned(RelPin::PsOut(ps as u32))
                        }];
                        ops.push(CommOp::fixed(ResKind::Sw, pull_fixed));
                        if single {
                            ops.push(
                                CommOp::fixed(ResKind::Sw, wire_us(bytes) + dispatch_us)
                                    .rel_pinned(RelPin::WorkerTx(w as u32)),
                            );
                        }
                        ops
                    };
                    ps_fanin_graph(w_count, ps, push_ops, update, pull_ops).0
                },
            );
            let overlay = sc.overlay(w_count, si as u64);
            if window > 0 {
                // bounded RPC window: the shard exchange launches on a
                // stream lane instead of firing at its readiness
                lane_items.push((template, overlay));
                lane_release.push(offset + ready);
            } else {
                let shard_done = done.clone();
                let run = template.execute_at(
                    e,
                    map.clone(),
                    &overlay,
                    offset + ready,
                    Box::new(move |_| *shard_done.borrow_mut() += 1),
                );
                runs.push(run);
            }
        }
        // The per-worker window IS the set-level in-flight cap: every
        // worker takes part in every shard's fan-in/fan-out, so "at most
        // `window` push/pull exchanges in flight per worker" and "at most
        // `window` shard DAGs live on the engine" are the same
        // constraint.  Each shard gets its own lane (no artificial
        // serialization between fixed shard pairs) and `depth = window`
        // is the sliding cap — shards issue smallest-released-index
        // first, the FIFO RPC issue order of a real windowed stub.
        let lane = (window > 0).then(|| {
            let scheduled = lane_items.len();
            let driver = GraphLaneDriver::new(map.clone(), std::mem::take(&mut lane_items));
            let set = e.lane_set(scheduled.max(1), window, Rc::new(driver));
            for (job, &at) in lane_release.iter().enumerate() {
                e.lane_submit(set, at, job as u32);
            }
            (set, scheduled)
        });
        Ok(PsJob { runs, pulls, done, worker_tx, lane })
    }
}

/// Per-PS NIC resources of one fabric, laid out over a
/// [`Placement`](crate::cluster::Placement): the *physical* ports are
/// per `(node, rail)` (ingress queues serialize gradient pushes, egress
/// queues serialize pull payloads), and `ingress[s]` / `egress[s]` alias
/// server `s` onto its node's rail — PS tasks colocated on one dense
/// node contend on the same physical port.  With the paper's trivial
/// placement every server owns its ports, the historical layout.
/// Link-share runs hand the *same* fabric to two jobs (the co-tenant's
/// PS tasks land on the same hosts), so both jobs' transfers queue FIFO
/// on shared ports.
pub struct PsFabric {
    /// Physical ingress ports, node-major rail-minor (distinct
    /// resources — aggregate these, not the per-server aliases).
    in_ports: Vec<ResourceId>,
    out_ports: Vec<ResourceId>,
    /// Per-server aliases into the physical ports.
    pub ingress: Vec<ResourceId>,
    pub egress: Vec<ResourceId>,
}

impl PsFabric {
    pub fn install(e: &mut Engine, ps_count: usize) -> PsFabric {
        PsFabric::install_placed(e, ps_count, crate::cluster::Placement::one_per_node())
    }

    pub fn install_placed(
        e: &mut Engine,
        ps_count: usize,
        place: crate::cluster::Placement,
    ) -> PsFabric {
        let nodes = place.nodes_for(ps_count);
        let in_ports: Vec<ResourceId> =
            (0..nodes * place.rails).map(|_| e.unit_resource()).collect();
        let out_ports: Vec<ResourceId> =
            (0..nodes * place.rails).map(|_| e.unit_resource()).collect();
        if e.tracing() {
            use crate::sim::trace::pid_node;
            use crate::sim::SpanKind;
            for (dir, ports) in [("ps-in", &in_ports), ("ps-out", &out_ports)] {
                for (i, &r) in ports.iter().enumerate() {
                    let (node, rail) = (i / place.rails, i % place.rails);
                    let name = format!("{dir} n{node} rail{rail}");
                    e.trace_resource(r, SpanKind::Wire, pid_node(node), node as u32, &name);
                }
            }
        }
        let port = |s: usize| place.node_of(s) * place.rails + place.rail_of(s);
        PsFabric {
            ingress: (0..ps_count).map(|s| in_ports[port(s)]).collect(),
            egress: (0..ps_count).map(|s| out_ports[port(s)]).collect(),
            in_ports,
            out_ports,
        }
    }

    /// The distinct physical ingress ports (for utilization ledgers —
    /// summing the per-server aliases would double-count shared ports).
    pub fn in_ports(&self) -> &[ResourceId] {
        &self.in_ports
    }

    pub fn out_ports(&self) -> &[ResourceId] {
        &self.out_ports
    }

    /// Aggregate (served, busy) over every physical NIC port — the
    /// fabric-level wire ledger the link-share report exposes.
    pub fn wire_stats(&self, e: &Engine) -> (u64, SimTime) {
        let u =
            ResourceUse::aggregate(e, "wire", self.in_ports.iter().chain(&self.out_ports).copied());
        (u.served, u.busy)
    }
}

/// One scheduled PS job: the per-shard fan-in runs plus the (shared)
/// pull-sink layout, read back after the engine run.
pub struct PsJob {
    runs: Vec<Rc<RefCell<GraphRun>>>,
    /// Pull sinks of every shard's fan-in template (the builder layout
    /// is fixed per worker count, so one list serves all shards).
    pulls: Vec<NodeId>,
    done: Rc<RefCell<usize>>,
    worker_tx: Option<Vec<ResourceId>>,
    /// The bounded-RPC-window lane set and the number of shards it was
    /// handed (`rpc_window > 0` schedules through lanes; `None` is the
    /// historical release-at-readiness path, kept bit-identical).
    lane: Option<(LaneSetId, usize)>,
}

impl PsJob {
    /// The lane set carrying this job's windowed shard exchanges, if
    /// the scenario bounded the RPC window.
    pub(crate) fn lane_set(&self) -> Option<LaneSetId> {
        self.lane.map(|(set, _)| set)
    }

    /// When the job's last worker received its last shard.
    pub(crate) fn comm_end(&self, e: &Engine) -> Result<SimTime> {
        if let Some((set, scheduled)) = self.lane {
            crate::ensure!(
                e.lane_completed(set) == scheduled,
                "PS simulation did not converge: {} of {scheduled} windowed shards",
                e.lane_completed(set)
            );
            // the pull deliveries are the fan-in DAG's terminal ops, so
            // the set's last lane completion is the last pull delivery
            return Ok(e.lane_last_done(set));
        }
        crate::ensure!(
            *self.done.borrow() == self.runs.len(),
            "PS simulation did not converge: {} of {} shards",
            *self.done.borrow(),
            self.runs.len()
        );
        let mut end = SimTime::ZERO;
        for run in &self.runs {
            let r = run.borrow();
            for &id in &self.pulls {
                end = end.max(r.finish_of(id));
            }
        }
        Ok(end)
    }
}

impl Strategy for PsStrategy {
    fn name(&self) -> String {
        match self.transport {
            PsTransport::Grpc => "gRPC".into(),
            PsTransport::Mpi => "gRPC+MPI".into(),
            PsTransport::Verbs => "gRPC+Verbs".into(),
            PsTransport::Rdma => "RDMA".into(),
        }
    }

    fn iteration_in(&self, ws: &WorldSpec, sc: &Scenario) -> Result<IterationReport> {
        if !sc.fault.is_empty() {
            // fault injection routes through the RPC retry / shard
            // reassignment model (§Robustness); an empty plan never
            // reaches this branch, so the path below stays bit-identical
            return self.iteration_faulted(ws, sc);
        }
        if sc.rejoin_rebuild_us > 0.0 {
            // elastic rejoin (§Robustness campaign): the repaired rank's
            // worker + parameter server rejoin at the step boundary, so
            // the shard plan re-spreads over the full world before any
            // push/pull RPC can issue; zero rebuild never reaches this
            // branch
            return self.iteration_rejoin(ws, sc);
        }
        if ws.world == 1 {
            let iter = SimTime::from_us(ws.compute_time().as_us() * sc.compute_stretch());
            return Ok(IterationReport::from_times(self.name(), ws, iter));
        }
        let mut engine = Engine::new();
        // one PS task per worker, laid out over the cluster's placement
        // (dense nodes colocate PS tasks on shared NIC ports)
        let fabric = PsFabric::install_placed(&mut engine, ws.world, ws.cluster.placement());
        let job = self.schedule_job(ws, sc, &mut engine, &fabric, SimTime::ZERO)?;
        engine.run();
        let trace = JobTrace { comm_end: job.comm_end(&engine)?, staging_us: 0.0 };
        let parts = super::close_iteration_parts(
            ws,
            sc,
            &trace,
            SimTime::ZERO,
            self.runtime_tax,
            self.skew_us_per_rank,
        );
        let mut report = IterationReport::from_times(self.name(), ws, parts.iter);
        report.engine_events = engine.executed();
        report.resource_util.push(agg_util(&engine, fabric.in_ports(), "ps-nic-in"));
        report.resource_util.push(agg_util(&engine, fabric.out_ports(), "ps-nic-out"));
        if let Some(tx) = &job.worker_tx {
            report.resource_util.push(agg_util(&engine, tx, "worker-mpi-thread"));
        }
        report.attach_trace(&mut engine, parts);
        Ok(report)
    }
}

impl PsStrategy {
    /// One elastic-rejoin PS iteration (§Robustness campaign): the
    /// repaired rank's tasks re-register and the shard plan re-spreads
    /// over the grown world before any RPC issues, so every shard
    /// exchange's release is offset by the rebuild window.  Workers keep
    /// computing while the registry settles — the compute side is
    /// untouched, mirroring the allreduce families' grow-back model.
    fn iteration_rejoin(&self, ws: &WorldSpec, sc: &Scenario) -> Result<IterationReport> {
        crate::ensure!(
            ws.world >= 2,
            "elastic rejoin needs a distributed run (world {} < 2)",
            ws.world
        );
        let rebuild = SimTime::from_us(sc.rejoin_rebuild_us);
        let mut sc_run = sc.clone();
        sc_run.rejoin_rebuild_us = 0.0;
        let mut engine = Engine::new();
        let fabric = PsFabric::install_placed(&mut engine, ws.world, ws.cluster.placement());
        engine.trace_mark(crate::sim::SpanKind::Rebuild, SimTime::ZERO, rebuild);
        let job = self.schedule_job(ws, &sc_run, &mut engine, &fabric, rebuild)?;
        engine.run_budgeted(super::recovery::DRAIN_BUDGET)?;
        let comm_end = job.comm_end(&engine)?.max(rebuild);
        let trace = JobTrace { comm_end, staging_us: 0.0 };
        let parts = super::close_iteration_parts(
            ws,
            &sc_run,
            &trace,
            SimTime::ZERO,
            self.runtime_tax,
            self.skew_us_per_rank,
        );
        let mut report = IterationReport::from_times(self.name(), ws, parts.iter);
        report.engine_events = engine.executed();
        report.resource_util.push(agg_util(&engine, fabric.in_ports(), "ps-nic-in"));
        report.resource_util.push(agg_util(&engine, fabric.out_ports(), "ps-nic-out"));
        if let Some(tx) = &job.worker_tx {
            report.resource_util.push(agg_util(&engine, tx, "worker-mpi-thread"));
        }
        report.attach_trace(&mut engine, parts);
        Ok(report)
    }

    /// One fault-injected PS iteration (§Robustness).  The RPC view of
    /// the shared fault model: a transient link flap FIFO-holds the
    /// port's NIC queues for the window, so in-flight pushes/pulls look
    /// like timed-out RPCs whose bounded-backoff retries drain when the
    /// port recovers; a rail failure holds its port for one detection
    /// window (the failover hand-off).  A crashed rank kills its
    /// colocated worker *and* parameter server: after detect → backoff
    /// (the retry budget is exhausted against a dead peer) → shard
    /// reassignment (the rebuild cost), the synchronous step restarts
    /// over the surviving world with the dead server's shards LPT-spread
    /// across the p−1 survivors — each now more loaded, the degraded
    /// regime.  Only entered with a non-empty plan.
    fn iteration_faulted(&self, ws: &WorldSpec, sc: &Scenario) -> Result<IterationReport> {
        let plan = sc.fault.clone();
        let place = ws.cluster.placement();
        plan.validate(ws.world, &place)?;
        crate::ensure!(
            ws.world >= 2,
            "fault injection needs a distributed run (world {} < 2)",
            ws.world
        );
        let mut sc_run = sc.clone();
        sc_run.fault = FaultPlan::default();

        let mut engine = Engine::new();
        let fabric = PsFabric::install_placed(&mut engine, ws.world, place);
        let job = self.schedule_job(ws, &sc_run, &mut engine, &fabric, SimTime::ZERO)?;
        let detect = SimTime::from_us(plan.detect_timeout_us);
        for ev in &plan.events {
            let at = SimTime::from_us(ev.at_us);
            let hold_port = |node: usize, rail: usize, dur: SimTime, e: &mut Engine| {
                let i = node * place.rails + rail;
                let (inp, outp) = (fabric.in_ports()[i], fabric.out_ports()[i]);
                e.at(at, move |e| {
                    e.hold(inp, dur);
                    e.hold(outp, dur);
                });
            };
            match ev.kind {
                FaultKind::LinkFlap { node, rail, for_us } => {
                    hold_port(node, rail, SimTime::from_us(for_us), &mut engine);
                }
                // the port is dark until the failover completes
                FaultKind::RailDown { node, rail } => hold_port(node, rail, detect, &mut engine),
                _ => {}
            }
        }

        if let Some((t_fail, _dead, _)) = plan.first_crash() {
            // --- the dead rank takes its worker and server with it ---
            engine.run_until(t_fail);
            if let Some(set) = job.lane_set() {
                // windowed shards queued behind the crash never launch
                // (same discipline as recovery.rs: abort, then clear)
                engine.lane_abort(set);
            }
            engine.clear_pending();
            engine.trace_truncate(t_fail);
            let detect_end = t_fail + detect;
            let backoff_end = detect_end + SimTime::from_us(plan.backoff_total_us());
            let rebuild_end = backoff_end + SimTime::from_us(plan.rebuild_us);
            engine.trace_mark(SpanKind::Fault, t_fail, detect_end);
            engine.trace_mark(SpanKind::Backoff, detect_end, backoff_end);
            engine.trace_mark(SpanKind::Rebuild, backoff_end, rebuild_end);

            // --- restart the synchronous step over the survivors ---
            let mut ws2 = ws.clone();
            ws2.world = ws.world - 1;
            let place2 = ws2.cluster.placement();
            let fabric2 = PsFabric::install_placed(&mut engine, ws2.world, place2);
            let job2 = self.schedule_job(&ws2, &sc_run, &mut engine, &fabric2, rebuild_end)?;
            engine.run();
            let comm_end = job2.comm_end(&engine)?.max(rebuild_end);
            let trace = JobTrace { comm_end, staging_us: 0.0 };
            let parts = super::close_iteration_parts(
                &ws2,
                &sc_run,
                &trace,
                SimTime::ZERO,
                self.runtime_tax,
                self.skew_us_per_rank,
            );
            let mut report = IterationReport::from_times(self.name(), &ws2, parts.iter);
            report.engine_events = engine.executed();
            report.resource_util.push(agg_util(&engine, fabric2.in_ports(), "ps-nic-in"));
            report.resource_util.push(agg_util(&engine, fabric2.out_ports(), "ps-nic-out"));
            if let Some(tx) = &job2.worker_tx {
                report.resource_util.push(agg_util(&engine, tx, "worker-mpi-thread"));
            }
            report.attach_trace(&mut engine, parts);
            let lost = plan.lost_work(t_fail);
            report.fault = Some(FaultReport {
                failed_at: t_fail,
                detect,
                recover: rebuild_end.saturating_sub(t_fail),
                lost_work: lost,
                retries: plan.max_retries,
                surviving_world: ws2.world,
                goodput_imgs_per_sec: ws2.world as f64 * ws2.batch_per_gpu as f64
                    / (parts.iter.as_secs() + lost.as_secs()),
            });
            Ok(report)
        } else {
            // --- transient faults only: retries bridge the outage ---
            engine.run();
            for ev in &plan.events {
                let t0 = SimTime::from_us(ev.at_us);
                match ev.kind {
                    FaultKind::LinkFlap { for_us, .. } => {
                        engine.trace_mark(SpanKind::Fault, t0, t0 + SimTime::from_us(for_us));
                    }
                    FaultKind::RailDown { .. } => {
                        engine.trace_mark(SpanKind::Fault, t0, t0 + detect);
                    }
                    _ => {}
                }
            }
            let trace = JobTrace { comm_end: job.comm_end(&engine)?, staging_us: 0.0 };
            let parts = super::close_iteration_parts(
                ws,
                &sc_run,
                &trace,
                SimTime::ZERO,
                self.runtime_tax,
                self.skew_us_per_rank,
            );
            let mut report = IterationReport::from_times(self.name(), ws, parts.iter);
            report.engine_events = engine.executed();
            report.resource_util.push(agg_util(&engine, fabric.in_ports(), "ps-nic-in"));
            report.resource_util.push(agg_util(&engine, fabric.out_ports(), "ps-nic-out"));
            if let Some(tx) = &job.worker_tx {
                report.resource_util.push(agg_util(&engine, tx, "worker-mpi-thread"));
            }
            report.attach_trace(&mut engine, parts);
            let failed_at = plan
                .events
                .iter()
                .map(|ev| SimTime::from_us(ev.at_us))
                .min()
                .unwrap_or(SimTime::ZERO);
            let flap_end = plan
                .flaps()
                .iter()
                .map(|&(at, _, _, dur)| at + dur)
                .max()
                .unwrap_or(failed_at);
            let longest_flap = plan
                .flaps()
                .iter()
                .map(|&(_, _, _, dur)| dur)
                .max()
                .unwrap_or(SimTime::ZERO);
            report.fault = Some(FaultReport {
                failed_at,
                detect,
                recover: (flap_end.max(failed_at + detect)).saturating_sub(failed_at),
                lost_work: SimTime::ZERO,
                retries: super::recovery::retries_to_bridge(&plan, longest_flap.as_us()),
                surviving_world: ws.world,
                goodput_imgs_per_sec: ws.world as f64 * ws.batch_per_gpu as f64
                    / parts.iter.as_secs(),
            });
            Ok(report)
        }
    }
}

fn agg_util(e: &Engine, ids: &[ResourceId], name: &str) -> ResourceUse {
    ResourceUse::aggregate(e, name, ids.iter().copied())
}

/// Shared mutable state of the reference implementation.
struct PsState {
    /// pushes still missing per tensor (counts down from W).
    pending_pushes: Vec<usize>,
    /// tensors received back per worker.
    received: Vec<usize>,
    /// last event time per worker.
    done_at: Vec<SimTime>,
}

impl PsStrategy {
    /// The pre-`CommGraph` implementation (PR 1): hand-rolled push
    /// countdowns and serialized pull replays on the same NIC resources.
    /// Kept verbatim as the regression oracle — `tests/des_regression.rs`
    /// pins the graph-scheduled `iteration_in` to this within tolerance,
    /// which is what "the port preserved the timings" means.
    pub fn iteration_reference(&self, ws: &WorldSpec, sc: &Scenario) -> Result<IterationReport> {
        if ws.world == 1 {
            let iter = SimTime::from_us(ws.compute_time().as_us() * sc.compute_stretch());
            return Ok(IterationReport::from_times(self.name(), ws, iter));
        }
        let w_count = ws.world;
        let ps_count = ws.world;
        let per_shard = self.shard_plan(ws, sc);
        let t_count = per_shard.len(); // shards are the unit of transfer

        let mut engine = Engine::new();
        let link_gbs = self.transfer_params(&ws.cluster, 1 << 20, false).1;
        let rate = link_gbs * 1e3 / sc.wire_derate();
        let wire_us = move |bytes: usize| bytes as f64 / rate;
        let ingress: Vec<ResourceId> =
            (0..ps_count).map(|_| engine.unit_resource()).collect();
        let egress: Vec<ResourceId> =
            (0..ps_count).map(|_| engine.unit_resource()).collect();
        let dispatch_us = self.thread_dispatch_us;
        let worker_tx: Option<Rc<Vec<ResourceId>>> = self.single_thread_worker.then(|| {
            Rc::new((0..w_count).map(|_| engine.unit_resource()).collect::<Vec<_>>())
        });
        // everything not pinned to a NIC/thread is per-rank private work
        let unmapped_ref: ResMap = Rc::new(|_| None);

        let state = Rc::new(RefCell::new(PsState {
            pending_pushes: vec![w_count; t_count],
            received: vec![0; w_count],
            done_at: vec![SimTime::ZERO; w_count],
        }));

        let update_us = move |bytes: usize| 2.0 + w_count as f64 * bytes as f64 / 8e3;

        for w in 0..w_count {
            for (t, &(bytes, push_fixed, pull_fixed, ps, ready)) in per_shard.iter().enumerate() {
                // push: ready → (worker thread) → fixed overhead → PS NIC
                let mut push_ops = Vec::new();
                if let Some(tx) = &worker_tx {
                    push_ops.push(
                        CommOp::fixed(ResKind::Sw, wire_us(bytes) + dispatch_us).pinned(tx[w]),
                    );
                }
                push_ops.push(CommOp::fixed(ResKind::Sw, push_fixed));
                push_ops.push(CommOp::fixed(ResKind::Wire, wire_us(bytes)).pinned(ingress[ps]));
                let push_ops = Rc::new(push_ops);

                let egress_r = egress[ps];
                let state = state.clone();
                let worker_tx = worker_tx.clone();
                let unmapped_ref = unmapped_ref.clone();
                engine.at(ready, move |e| {
                    let map = unmapped_ref.clone();
                    let done = Box::new(move |e: &mut Engine| {
                        let mut st = state.borrow_mut();
                        st.pending_pushes[t] -= 1;
                        if st.pending_pushes[t] != 0 {
                            return;
                        }
                        drop(st);
                        // parameters updated; answer every worker's
                        // (pipelined) pull
                        let state2 = state.clone();
                        let worker_tx2 = worker_tx.clone();
                        let unmapped2 = unmapped_ref.clone();
                        e.after(SimTime::from_us(update_us(bytes)), move |e| {
                            for w2 in 0..w_count {
                                let mut pull_ops = vec![
                                    CommOp::fixed(ResKind::Wire, wire_us(bytes)).pinned(egress_r),
                                    CommOp::fixed(ResKind::Sw, pull_fixed),
                                ];
                                if let Some(tx) = &worker_tx2 {
                                    pull_ops.push(
                                        CommOp::fixed(ResKind::Sw, wire_us(bytes) + dispatch_us)
                                            .pinned(tx[w2]),
                                    );
                                }
                                let state3 = state2.clone();
                                replay(
                                    e,
                                    unmapped2.clone(),
                                    Rc::new(pull_ops),
                                    Box::new(move |e| {
                                        let mut st = state3.borrow_mut();
                                        st.received[w2] += 1;
                                        if st.received[w2] == t_count {
                                            st.done_at[w2] = e.now();
                                        }
                                    }),
                                );
                            }
                        });
                    });
                    replay(e, map, push_ops, done);
                });
            }
        }
        engine.run();
        let st = state.borrow();
        crate::ensure!(
            st.received.iter().all(|&r| r == t_count),
            "PS simulation did not converge: {:?} of {t_count}",
            st.received
        );
        let comm_end = st.done_at.iter().copied().max().unwrap();
        let trace = JobTrace { comm_end, staging_us: 0.0 };
        let iter = super::close_iteration(
            ws,
            sc,
            &trace,
            SimTime::ZERO,
            self.runtime_tax,
            self.skew_us_per_rank,
        );
        let mut report = IterationReport::from_times(self.name(), ws, iter);
        report.engine_events = engine.executed();
        report.resource_util.push(agg_util(&engine, &ingress, "ps-nic-in"));
        report.resource_util.push(agg_util(&engine, &egress, "ps-nic-out"));
        if let Some(tx) = &worker_tx {
            report.resource_util.push(agg_util(&engine, tx, "worker-mpi-thread"));
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::MpiFlavor;
    use crate::models::{mobilenet, nasnet, resnet};
    use crate::strategies::Horovod;

    #[test]
    fn ps_variants_complete_and_scale_somewhat() {
        for s in [PsStrategy::grpc(), PsStrategy::grpc_mpi(), PsStrategy::grpc_verbs()] {
            let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
            let r = s.iteration(&ws).unwrap();
            assert!(r.scaling_efficiency > 0.1 && r.scaling_efficiency <= 1.0,
                "{}: eff {}", s.name(), r.scaling_efficiency);
        }
    }

    #[test]
    fn graph_port_matches_reference_implementation() {
        // the zero-skew pin at module level: the fan-in DAG execution
        // reproduces the PR-1 countdown implementation (same shards,
        // same NIC queues, same durations — only the scheduling substrate
        // changed; residual divergence is same-timestamp FIFO tie order)
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        for s in [PsStrategy::grpc(), PsStrategy::grpc_mpi(), PsStrategy::grpc_verbs()] {
            let graph = s.iteration(&ws).unwrap().iter.as_us();
            let reference =
                s.iteration_reference(&ws, &Scenario::default()).unwrap().iter.as_us();
            let rel = (graph - reference).abs() / reference;
            assert!(
                rel < 2e-3,
                "{}: graph {graph}us vs reference {reference}us (rel {rel:.2e})",
                s.name()
            );
        }
    }

    #[test]
    fn verbs_beats_grpc_beats_nothing() {
        // Figure 3 ordering within the PS family: verbs ≥ grpc.
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 16);
        let g = PsStrategy::grpc().iteration(&ws).unwrap();
        let v = PsStrategy::grpc_verbs().iteration(&ws).unwrap();
        assert!(v.imgs_per_sec >= g.imgs_per_sec, "verbs {} < grpc {}", v.imgs_per_sec, g.imgs_per_sec);
    }

    #[test]
    fn horovod_beats_all_ps_variants() {
        // The paper's first key insight: No-gRPC > gRPC family.
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 16);
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt).iteration(&ws).unwrap();
        for s in [PsStrategy::grpc(), PsStrategy::grpc_mpi(), PsStrategy::grpc_verbs()] {
            let r = s.iteration(&ws).unwrap();
            assert!(
                h.imgs_per_sec > r.imgs_per_sec,
                "horovod {} should beat {} {}",
                h.imgs_per_sec,
                s.name(),
                r.imgs_per_sec
            );
        }
    }

    #[test]
    fn grpc_mpi_single_thread_worst_for_big_models() {
        // Figure 9: gRPC+MPI shows the worst scaling, especially NASNet.
        let ws = WorldSpec::new(presets::piz_daint(), nasnet::nasnet_large(), 32);
        let mpi = PsStrategy::grpc_mpi().iteration(&ws).unwrap();
        let grpc = PsStrategy::grpc().iteration(&ws).unwrap();
        assert!(
            mpi.imgs_per_sec < grpc.imgs_per_sec,
            "gRPC+MPI {} should be worst, gRPC {}",
            mpi.imgs_per_sec,
            grpc.imgs_per_sec
        );
    }

    #[test]
    fn horovod_advantage_larger_for_mobilenet_than_resnet() {
        // H4 (Figure 9): Horovod-MPI beats gRPC by 3.2× for MobileNet but
        // only 1.8× for ResNet-50 — the gRPC penalty hits the
        // communication-bound model hardest.
        let ratio = |m: crate::models::ModelProfile| {
            let ws = WorldSpec::new(presets::piz_daint(), m, 64);
            let h = Horovod::mpi(MpiFlavor::CrayMpich).iteration(&ws).unwrap();
            let g = PsStrategy::grpc().iteration(&ws).unwrap();
            h.imgs_per_sec / g.imgs_per_sec
        };
        let r_mob = ratio(mobilenet::mobilenet_v1());
        let r_res = ratio(resnet::resnet50());
        assert!(
            r_mob > r_res,
            "MobileNet ratio {r_mob:.2} should exceed ResNet ratio {r_res:.2}"
        );
        assert!(r_res > 1.2, "Horovod should clearly beat gRPC, got {r_res:.2}");
    }

    #[test]
    fn nic_fan_in_shows_up_in_the_ledger() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        let r = PsStrategy::grpc().iteration(&ws).unwrap();
        let nic_in = r.resource_util.iter().find(|u| u.name == "ps-nic-in").unwrap();
        let nic_out = r.resource_util.iter().find(|u| u.name == "ps-nic-out").unwrap();
        // every shard is pushed by W workers and pulled back W times
        assert_eq!(nic_in.served, nic_out.served);
        assert!(nic_in.busy > SimTime::ZERO);
        // gRPC has no single worker thread
        assert!(r.resource_util.iter().all(|u| u.name != "worker-mpi-thread"));
        let m = PsStrategy::grpc_mpi().iteration(&ws).unwrap();
        assert!(m.resource_util.iter().any(|u| u.name == "worker-mpi-thread"));
    }

    #[test]
    fn placed_fabric_aliases_colocated_servers() {
        use crate::cluster::Placement;
        let mut e = Engine::new();
        let f = PsFabric::install_placed(&mut e, 4, Placement::new(2, 1));
        assert_eq!(f.in_ports().len(), 2, "one physical port per 2-GPU node");
        assert_eq!(f.ingress.len(), 4, "one alias per server");
        assert_eq!(f.ingress[0], f.ingress[1], "colocated servers share the port");
        assert_ne!(f.ingress[1], f.ingress[2], "different nodes keep distinct ports");
        // a second rail splits the colocated pair again
        let f2 = PsFabric::install_placed(&mut e, 4, Placement::new(2, 2));
        assert_eq!(f2.in_ports().len(), 4);
        assert_ne!(f2.ingress[0], f2.ingress[1]);
        // trivial placement: the alias is the identity (historical layout)
        let f3 = PsFabric::install(&mut e, 3);
        assert_eq!(f3.ingress, f3.in_ports().to_vec());
        assert_eq!(f3.egress, f3.out_ports().to_vec());
    }

    #[test]
    fn fanin_templates_are_cached_across_calls_and_replays_are_stable() {
        // the cross-call templating pin: the first iteration builds one
        // template per (bytes, server) bucket into the STRATEGY cache;
        // a second iteration — a fresh engine — replays them warm,
        // builds nothing new, and reproduces the exact same time
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        for s in [PsStrategy::grpc(), PsStrategy::grpc_mpi(), PsStrategy::grpc_verbs()] {
            let a = s.iteration(&ws).unwrap();
            let built = s.cache.len();
            assert!(built >= 1, "{}: no fan-in templates cached", s.name());
            let b = s.iteration(&ws).unwrap();
            assert_eq!(a.iter, b.iter, "{}: warm replay diverged", s.name());
            assert_eq!(a.engine_events, b.engine_events, "{}: event count diverged", s.name());
            assert_eq!(s.cache.len(), built, "{}: warm call rebuilt templates", s.name());
        }
        // the scenario derate perturbs wire costs → new keys, no stale hit
        let s = PsStrategy::grpc();
        s.iteration(&ws).unwrap();
        let cold = s.cache.len();
        s.iteration_in(&ws, &Scenario::link_loaded(0.5)).unwrap();
        assert!(s.cache.len() > cold, "derated wire must not alias the pristine templates");
    }

    #[test]
    fn straggler_worker_delays_ps_iteration() {
        // the per-rank knob flows into the fan-in DAG: a slow worker's
        // push/pull nodes stretch, which delays every shard's update
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        let s = PsStrategy::grpc();
        let base = s.iteration(&ws).unwrap().iter;
        let skewed = s.iteration_in(&ws, &Scenario::straggler(1, 2.0)).unwrap().iter;
        assert!(skewed > base, "straggler must slow PS: {skewed} vs {base}");
    }

    #[test]
    fn shard_plan_conserves_every_byte() {
        // the byte-loss bugfix: partitioning variables into ~4MB pieces
        // must conserve the model size exactly (the old plan floored the
        // per-piece size and padded tiny pieces, so totals drifted)
        for model in [resnet::resnet50(), mobilenet::mobilenet_v1(), nasnet::nasnet_large()] {
            let model_bytes: usize = model.tensors.iter().map(|t| t.bytes()).sum();
            for world in [2usize, 4, 7] {
                let ws = WorldSpec::new(presets::ri2(), model.clone(), world);
                let plan = PsStrategy::grpc().shard_plan(&ws, &Scenario::default());
                let total: usize = plan.iter().map(|&(b, _, _, _, _)| b).sum();
                assert_eq!(total, model_bytes, "world {world}: shard plan lost bytes");
            }
        }
    }

    #[test]
    fn rdma_fastest_of_the_ps_family() {
        // the Figure-3 ordering extended end-to-end: the zero-copy
        // one-sided transport beats verbs, which beats plain gRPC
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 16);
        let g = PsStrategy::grpc().iteration(&ws).unwrap();
        let v = PsStrategy::grpc_verbs().iteration(&ws).unwrap();
        let r = PsStrategy::rdma().iteration(&ws).unwrap();
        assert!(r.imgs_per_sec >= v.imgs_per_sec, "rdma {} < verbs {}", r.imgs_per_sec, v.imgs_per_sec);
        assert!(v.imgs_per_sec >= g.imgs_per_sec, "verbs {} < grpc {}", v.imgs_per_sec, g.imgs_per_sec);
    }

    #[test]
    fn unbounded_window_on_lanes_matches_release_at_readiness() {
        // window=∞ at zero skew is the regression pin for the lane port:
        // a window wider than the shard count never blocks a launch, so
        // the lane path must reproduce the historical release-at-readiness
        // path exactly — same launch times, same FIFO claim order on the
        // NIC queues, same iteration time
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        for s in [
            PsStrategy::grpc(),
            PsStrategy::grpc_mpi(),
            PsStrategy::grpc_verbs(),
            PsStrategy::rdma(),
        ] {
            let base = s.iteration(&ws).unwrap().iter;
            let lane = s.iteration_in(&ws, &Scenario::windowed(1 << 20)).unwrap().iter;
            assert_eq!(lane, base, "{}: infinite-window lane path diverged", s.name());
        }
    }

    #[test]
    fn tighter_windows_never_speed_up_the_exchange() {
        // closing the window can only delay launches: iteration time is
        // non-increasing in the window, and window=1 (fully serialized
        // shard exchanges) is strictly slower than unbounded
        let ws = WorldSpec::new(presets::ri2(), mobilenet::mobilenet_v1(), 4);
        let s = PsStrategy::grpc();
        let unbounded = s.iteration(&ws).unwrap().iter;
        let mut prev = unbounded;
        for w in [8usize, 2, 1] {
            let t = s.iteration_in(&ws, &Scenario::windowed(w)).unwrap().iter;
            assert!(t >= prev, "window {w}: {t} beat the looser window {prev}");
            prev = t;
        }
        assert!(prev > unbounded, "window=1 must open the contended regime");
    }

    #[test]
    fn windowed_fan_in_survives_a_crash() {
        // a crash mid-iteration aborts the windowed lane set cleanly and
        // the restarted job (fresh set) converges over the survivors
        use crate::sim::FaultPlan;
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        let mut sc = Scenario::windowed(2);
        sc.fault = FaultPlan::crash(1, 5_000.0);
        let r = PsStrategy::rdma().iteration_in(&ws, &sc).unwrap();
        let f = r.fault.expect("fault report");
        assert_eq!(f.surviving_world, 3);
        assert!(r.iter > SimTime::ZERO);
    }
}
