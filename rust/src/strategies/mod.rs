//! Distributed-training strategies (§III's taxonomy, Figure 1):
//!
//! ```text
//! gRPC-based   : ps.rs        — parameter server over gRPC (TF default)
//! gRPC+X       : ps.rs        — PS with tensor transfers offloaded to
//!                               MPI (single-threaded!) or RDMA verbs
//! No-gRPC      : baidu.rs     — per-tensor ring allreduce over MPI p2p
//!                horovod.rs   — fused allreduce over MPI or NCCL,
//!                               including the paper's MPI-Opt variant
//! ```
//!
//! A strategy maps a `WorldSpec` (cluster × model × world size × batch) to
//! an `IterationReport` (iteration time, exposed communication, scaling
//! efficiency, per-resource utilization) by scheduling one training step's
//! compute + communication on the discrete-event engine.  **Every**
//! strategy runs through the shared `CommOp` → `Engine` path: collectives
//! emit resource-occupancy schedules (comm/commop.rs) that are replayed
//! onto FIFO engine resources — PS fan-in congestion, Horovod's background
//! comm-thread serialization (a FIFO gate), and the gRPC+MPI
//! single-service-thread bottleneck are all queueing effects of the same
//! substrate.  [`Scenario`] injects stragglers, heterogeneous node mixes,
//! sync jitter and fabric sharing on top of any strategy.

pub mod baidu;
pub mod horovod;
pub mod ps;
pub mod scenario;

pub use baidu::Baidu;
pub use horovod::{Horovod, HorovodBackend};
pub use ps::{PsStrategy, PsTransport};
pub use scenario::Scenario;

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use crate::cluster::ClusterSpec;
use crate::comm::graph::{GraphOverlay, GraphResources, GraphTemplate};
use crate::comm::ResourceUse;
use crate::models::ModelProfile;
use crate::sim::{Engine, GateId, SimTime};
use crate::util::error::Result;

/// One experiment point.
#[derive(Debug, Clone)]
pub struct WorldSpec {
    pub cluster: ClusterSpec,
    pub model: ModelProfile,
    pub world: usize,
    pub batch_per_gpu: usize,
}

impl WorldSpec {
    pub fn new(cluster: ClusterSpec, model: ModelProfile, world: usize) -> Self {
        let batch = model.default_batch;
        WorldSpec { cluster, model, world, batch_per_gpu: batch }
    }

    /// Per-worker fwd+bwd time (data parallelism keeps local batch fixed).
    pub fn compute_time(&self) -> SimTime {
        self.model.compute_time(&self.cluster.gpu, self.batch_per_gpu)
    }

    /// Single-GPU throughput — the paper's "Ideal = 1-GPU × N" baseline.
    pub fn throughput_1gpu(&self) -> f64 {
        self.model.throughput_1gpu(&self.cluster.gpu, self.batch_per_gpu)
    }

    /// When each gradient tensor becomes ready during the backward pass,
    /// in emission (bwd) order: fwd takes ⅓ of compute, bwd ⅔, and tensor
    /// readiness advances with the cumulative parameter volume.
    pub fn tensor_readiness(&self) -> Vec<(usize, SimTime)> {
        let compute = self.compute_time().as_us();
        let fwd = compute / 3.0;
        let bwd = compute - fwd;
        let total: usize = self.model.tensors.iter().map(|t| t.elems).sum();
        let mut cum = 0usize;
        self.model
            .tensors
            .iter()
            .enumerate()
            .map(|(i, t)| {
                cum += t.elems;
                (i, SimTime::from_us(fwd + bwd * cum as f64 / total as f64))
            })
            .collect()
    }
}

/// The outcome of simulating one training iteration at steady state.
#[derive(Debug, Clone)]
pub struct IterationReport {
    pub strategy: String,
    pub compute: SimTime,
    pub iter: SimTime,
    /// Communication time not hidden behind compute.
    pub exposed_comm: SimTime,
    /// Aggregate images (samples) per second across the world.
    pub imgs_per_sec: f64,
    /// imgs_per_sec / (world × single-GPU imgs_per_sec).
    pub scaling_efficiency: f64,
    /// Per-resource (served, busy) ledger of the engine run that produced
    /// `iter` — derived from `Engine::resource_stats`, not hand-kept.
    pub resource_util: Vec<ResourceUse>,
    /// Events the engine executed to produce `iter` (0 for analytic
    /// shortcuts like world=1) — the §Perf events/s numerator.
    pub engine_events: u64,
}

impl IterationReport {
    pub fn from_times(strategy: String, ws: &WorldSpec, iter: SimTime) -> IterationReport {
        let compute = ws.compute_time();
        let imgs = ws.world as f64 * ws.batch_per_gpu as f64 / iter.as_secs();
        let ideal = ws.world as f64 * ws.throughput_1gpu();
        IterationReport {
            strategy,
            compute,
            exposed_comm: iter.saturating_sub(compute),
            iter,
            imgs_per_sec: imgs,
            scaling_efficiency: imgs / ideal,
            resource_util: Vec::new(),
            engine_events: 0,
        }
    }
}

/// What one job's engine run leaves behind: when its last collective
/// finished on the virtual clock, and how much host-staging time rode the
/// PCIe links the training stream needs (the share that cannot hide
/// behind compute).
#[derive(Debug, Clone, Copy, Default)]
pub struct JobTrace {
    pub comm_end: SimTime,
    pub staging_us: f64,
}

/// One collective of a [`GraphJob`]: a cached immutable template, the
/// per-iteration overlay to replay it under, its release time, and the
/// critical host-staging share it charges the compute path.
pub(crate) struct GraphWork {
    pub ready: SimTime,
    pub template: Arc<GraphTemplate>,
    pub overlay: GraphOverlay,
    pub staging_us: f64,
}

/// One allreduce-family job's per-collective dependency graphs scheduled
/// onto an engine: each template replays at its ready time and runs under
/// the strategy's background comm-thread gate (FIFO, one collective at a
/// time — the same serialization the serialized-replay path uses), on the
/// job's per-rank [`GraphResources`].  Shared by `Horovod` and `Baidu`'s
/// `iteration_graph`.
pub(crate) struct GraphJob {
    trace: Rc<RefCell<JobTrace>>,
    completed: Rc<RefCell<usize>>,
    scheduled: usize,
}

impl GraphJob {
    /// Schedule the job's collectives, each releasing at `offset` plus
    /// its own ready time (two-job link-share runs stagger job B by an
    /// offset); read the result back with [`GraphJob::trace`] after
    /// `Engine::run`.
    pub(crate) fn schedule(
        e: &mut Engine,
        res: &GraphResources,
        thread: GateId,
        items: Vec<GraphWork>,
        offset: SimTime,
    ) -> GraphJob {
        let trace = Rc::new(RefCell::new(JobTrace::default()));
        let completed = Rc::new(RefCell::new(0usize));
        let scheduled = items.len();
        let map = res.mapper();
        for w in items {
            trace.borrow_mut().staging_us += w.staging_us;
            let map = map.clone();
            let trace = trace.clone();
            let completed = completed.clone();
            e.at(offset + w.ready, move |e| {
                let GraphWork { template, overlay, .. } = w;
                e.acquire(thread, move |e| {
                    template.execute(
                        e,
                        map,
                        &overlay,
                        Box::new(move |e| {
                            trace.borrow_mut().comm_end = e.now();
                            *completed.borrow_mut() += 1;
                            e.release(thread);
                        }),
                    );
                });
            });
        }
        GraphJob { trace, completed, scheduled }
    }

    /// The finished job trace — errors if any collective's graph never
    /// completed (a wiring bug would otherwise silently report a too-fast
    /// iteration; the PS path has the same guard in `PsJob::comm_end`).
    pub(crate) fn trace(&self) -> Result<JobTrace> {
        crate::ensure!(
            *self.completed.borrow() == self.scheduled,
            "graph job did not converge: {} of {} collectives completed",
            *self.completed.borrow(),
            self.scheduled
        );
        Ok(*self.trace.borrow())
    }
}

/// Fold an engine run into the allreduce-family iteration report: the
/// per-resource utilization rows plus the background comm-thread gate row
/// (shared by the serialized and graph paths of Horovod and Baidu).
pub(crate) fn report_with_comm_thread(
    name: String,
    ws: &WorldSpec,
    iter: SimTime,
    util: Vec<ResourceUse>,
    e: &Engine,
    thread: GateId,
) -> IterationReport {
    let mut report = IterationReport::from_times(name, ws, iter);
    report.resource_util = util;
    report.engine_events = e.executed();
    let (grants, busy) = e.gate_stats(thread);
    report.resource_util.push(ResourceUse {
        name: "comm-thread".to_string(),
        served: grants,
        busy,
    });
    report
}

/// Shared closing formula of the allreduce-family strategies: the
/// iteration ends when both the (runtime-dilated, scenario-stretched)
/// compute + critical staging and the communication pipeline are done,
/// plus the synchronization skew of `p` ranks.
pub(crate) fn close_iteration(
    ws: &WorldSpec,
    sc: &Scenario,
    trace: &JobTrace,
    offset: SimTime,
    runtime_tax: f64,
    skew_us_per_rank: f64,
) -> SimTime {
    let p = ws.world as f64;
    let dilated = ws.compute_time().as_us()
        * sc.compute_stretch()
        * (1.0 + runtime_tax * (1.0 - 1.0 / p));
    let skew = skew_us_per_rank * p + sc.sync_jitter_us(ws.world);
    let comm = trace.comm_end.saturating_sub(offset).as_us();
    SimTime::from_us(comm.max(dilated + trace.staging_us) + skew)
}

/// Object-safe strategy interface — what the bench harness iterates over.
/// `Send + Sync` so the sweep drivers can fan points out across threads
/// (each `iteration` call owns its private engine).
pub trait Strategy: Send + Sync {
    fn name(&self) -> String;
    /// Some designs are hardware-gated (NCCL2 needs IB verbs — §VI-D).
    fn available(&self, cluster: &ClusterSpec) -> bool {
        let _ = cluster;
        true
    }
    /// One steady-state iteration under pristine conditions.
    fn iteration(&self, ws: &WorldSpec) -> Result<IterationReport> {
        self.iteration_in(ws, &Scenario::default())
    }
    /// One steady-state iteration under a [`Scenario`] (stragglers,
    /// heterogeneous nodes, jitter, shared fabric).
    fn iteration_in(&self, ws: &WorldSpec, sc: &Scenario) -> Result<IterationReport>;
}

/// All approaches the paper compares, in Figure-3 order.
pub fn all_strategies() -> Vec<Box<dyn Strategy>> {
    vec![
        Box::new(PsStrategy::grpc()),
        Box::new(PsStrategy::grpc_mpi()),
        Box::new(PsStrategy::grpc_verbs()),
        Box::new(Baidu::new()),
        Box::new(Horovod::mpi(crate::comm::MpiFlavor::Mvapich2)),
        Box::new(Horovod::nccl()),
        Box::new(Horovod::mpi(crate::comm::MpiFlavor::Mvapich2GdrOpt)),
    ]
}

/// Strategy lookup for the CLI (`--strategy horovod-mpi-opt` etc.).
pub fn by_name(name: &str) -> Result<Box<dyn Strategy>> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "grpc" => Box::new(PsStrategy::grpc()),
        "grpc+mpi" | "grpc-mpi" => Box::new(PsStrategy::grpc_mpi()),
        "grpc+verbs" | "grpc-verbs" => Box::new(PsStrategy::grpc_verbs()),
        "baidu" | "baidu-mpi" => Box::new(Baidu::new()),
        "horovod-mpi" => Box::new(Horovod::mpi(crate::comm::MpiFlavor::Mvapich2)),
        "horovod-nccl" => Box::new(Horovod::nccl()),
        "horovod-mpi-opt" => Box::new(Horovod::mpi(crate::comm::MpiFlavor::Mvapich2GdrOpt)),
        "horovod-cray" => Box::new(Horovod::mpi(crate::comm::MpiFlavor::CrayMpich)),
        other => crate::bail!(
            "unknown strategy `{other}` (grpc | grpc+mpi | grpc+verbs | baidu | \
             horovod-mpi | horovod-nccl | horovod-mpi-opt | horovod-cray)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::models::resnet;

    #[test]
    fn readiness_monotone_and_spans_compute() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        let r = ws.tensor_readiness();
        assert_eq!(r.len(), ws.model.tensors.len());
        for w in r.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        let compute = ws.compute_time();
        assert!(r.first().unwrap().1 > SimTime::from_us(compute.as_us() / 3.0 - 1.0));
        assert_eq!(r.last().unwrap().1, compute);
    }

    #[test]
    fn report_efficiency_is_compute_over_iter() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        let compute = ws.compute_time();
        let iter = SimTime::from_us(compute.as_us() * 1.25);
        let rep = IterationReport::from_times("x".into(), &ws, iter);
        assert!((rep.scaling_efficiency - 0.8).abs() < 0.01);
        assert_eq!(rep.exposed_comm, iter - compute);
    }

    #[test]
    fn lookup_and_inventory() {
        assert_eq!(all_strategies().len(), 7);
        assert!(by_name("horovod-mpi-opt").is_ok());
        assert!(by_name("gloo").is_err());
    }

    #[test]
    fn every_strategy_reports_utilization() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        for s in all_strategies() {
            if !s.available(&ws.cluster) {
                continue;
            }
            let r = s.iteration(&ws).unwrap();
            assert!(
                !r.resource_util.is_empty(),
                "{} reports no resource utilization",
                s.name()
            );
            assert!(
                r.resource_util.iter().any(|u| u.busy > SimTime::ZERO),
                "{} utilization all-zero",
                s.name()
            );
        }
    }
}
