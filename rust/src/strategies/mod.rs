//! Distributed-training strategies (§III's taxonomy, Figure 1):
//!
//! ```text
//! gRPC-based   : ps.rs        — parameter server over gRPC (TF default)
//! gRPC+X       : ps.rs        — PS with tensor transfers offloaded to
//!                               MPI (single-threaded!) or RDMA verbs
//! No-gRPC      : baidu.rs     — per-tensor ring allreduce over MPI p2p
//!                horovod.rs   — fused allreduce over MPI or NCCL,
//!                               including the paper's MPI-Opt variant
//! ```
//!
//! A strategy maps a `WorldSpec` (cluster × model × world size × batch) to
//! an `IterationReport` (iteration time, exposed communication, scaling
//! efficiency, per-resource utilization) by scheduling one training step's
//! compute + communication on the discrete-event engine.  **Every**
//! strategy runs through the shared `CommOp` → `Engine` path: collectives
//! emit resource-occupancy schedules (comm/commop.rs) that are replayed
//! onto FIFO engine resources — PS fan-in congestion, Horovod's background
//! comm-thread serialization (a stream-lane set: `streams = 1` is the
//! classic serialized comm thread, `streams > 1` opens NCCL-stream-style
//! fusion overlap, §Overlap), and the gRPC+MPI single-service-thread
//! bottleneck are all queueing effects of the same substrate.
//! [`Scenario`] injects stragglers, heterogeneous node mixes, sync
//! jitter, fabric sharing and the overlap knobs on top of any strategy.

pub mod baidu;
pub mod horovod;
pub mod ps;
pub(crate) mod recovery;
pub mod scenario;

pub use baidu::Baidu;
pub use horovod::{Horovod, HorovodBackend};
pub use ps::{PsStrategy, PsTransport};
pub use scenario::Scenario;

use std::rc::Rc;
use std::sync::Arc;

use crate::cluster::ClusterSpec;
use crate::comm::graph::{GraphOverlay, GraphResMap, GraphResources, GraphTemplate};
use crate::comm::ResourceUse;
use crate::models::ModelProfile;
use crate::sim::{
    Engine, IterationParts, LaneDriver, LaneSetId, ProgStep, ProgramLanes, SimTime, TraceReport,
};
use crate::util::error::Result;

/// One experiment point.
#[derive(Debug, Clone)]
pub struct WorldSpec {
    pub cluster: ClusterSpec,
    pub model: ModelProfile,
    pub world: usize,
    pub batch_per_gpu: usize,
}

impl WorldSpec {
    pub fn new(cluster: ClusterSpec, model: ModelProfile, world: usize) -> Self {
        let batch = model.default_batch;
        WorldSpec { cluster, model, world, batch_per_gpu: batch }
    }

    /// Per-worker fwd+bwd time (data parallelism keeps local batch fixed).
    pub fn compute_time(&self) -> SimTime {
        self.model.compute_time(&self.cluster.gpu, self.batch_per_gpu)
    }

    /// Single-GPU throughput — the paper's "Ideal = 1-GPU × N" baseline.
    pub fn throughput_1gpu(&self) -> f64 {
        self.model.throughput_1gpu(&self.cluster.gpu, self.batch_per_gpu)
    }

    /// When each gradient tensor becomes ready during the backward pass,
    /// in emission (bwd) order: fwd takes ⅓ of compute, bwd ⅔, and tensor
    /// readiness advances with the cumulative parameter volume.
    pub fn tensor_readiness(&self) -> Vec<(usize, SimTime)> {
        let compute = self.compute_time().as_us();
        let fwd = compute / 3.0;
        let bwd = compute - fwd;
        let total: usize = self.model.tensors.iter().map(|t| t.elems).sum();
        let mut cum = 0usize;
        self.model
            .tensors
            .iter()
            .enumerate()
            .map(|(i, t)| {
                cum += t.elems;
                (i, SimTime::from_us(fwd + bwd * cum as f64 / total as f64))
            })
            .collect()
    }
}

/// The outcome of simulating one training iteration at steady state.
#[derive(Debug, Clone)]
pub struct IterationReport {
    pub strategy: String,
    pub compute: SimTime,
    pub iter: SimTime,
    /// Communication time not hidden behind compute.
    pub exposed_comm: SimTime,
    /// Aggregate images (samples) per second across the world.
    pub imgs_per_sec: f64,
    /// imgs_per_sec / (world × single-GPU imgs_per_sec).
    pub scaling_efficiency: f64,
    /// Per-resource (served, busy) ledger of the engine run that produced
    /// `iter` — derived from `Engine::resource_stats`, not hand-kept.
    pub resource_util: Vec<ResourceUse>,
    /// Events the engine executed to produce `iter` (0 for analytic
    /// shortcuts like world=1) — the §Perf events/s numerator.
    pub engine_events: u64,
    /// The attribution report of a traced run (§Observability) — `None`
    /// unless tracing was enabled around the engine run.  `Arc` keeps the
    /// report `Clone`/`Send` for the threaded sweep drivers.
    pub trace: Option<Arc<TraceReport>>,
    /// Failure-recovery ledger (§Faults) — `None` for fault-free runs.
    pub fault: Option<FaultReport>,
}

/// What a fault-injected iteration cost beyond its fault-free twin: the
/// detection/recovery latencies on the virtual clock, the work thrown
/// away, and the goodput that remains once lost work is amortized in.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultReport {
    /// Virtual time of the first injected failure.
    pub failed_at: SimTime,
    /// Failure onset → detection (the watchdog timeout that fired).
    pub detect: SimTime,
    /// Failure onset → training resumed (detect + backoff + rebuild, or
    /// the flap window for transient faults).
    pub recover: SimTime,
    /// Progress discarded by abort-and-restart (time since the last
    /// checkpoint, or since iteration start without checkpointing).
    pub lost_work: SimTime,
    /// Retry attempts spent before the failure was declared permanent.
    pub retries: u32,
    /// World size after recovery (`world - 1` after an elastic shrink).
    pub surviving_world: usize,
    /// Throughput counting only surviving, non-discarded samples —
    /// `imgs_per_sec` is raw pipe speed, this is useful training speed.
    pub goodput_imgs_per_sec: f64,
}

impl IterationReport {
    pub fn from_times(strategy: String, ws: &WorldSpec, iter: SimTime) -> IterationReport {
        let compute = ws.compute_time();
        let imgs = ws.world as f64 * ws.batch_per_gpu as f64 / iter.as_secs();
        let ideal = ws.world as f64 * ws.throughput_1gpu();
        IterationReport {
            strategy,
            compute,
            exposed_comm: iter.saturating_sub(compute),
            iter,
            imgs_per_sec: imgs,
            scaling_efficiency: imgs / ideal,
            resource_util: Vec::new(),
            engine_events: 0,
            trace: None,
            fault: None,
        }
    }

    /// Detach a traced engine's recorder and fold it into the report.
    /// No-op (and allocation-free) when the engine was not tracing.
    pub(crate) fn attach_trace(&mut self, e: &mut Engine, parts: IterationParts) {
        if let Some(t) = e.take_trace() {
            self.trace = Some(Arc::new(t.into_report(e, parts)));
        }
    }
}

/// What one job's engine run leaves behind: when its last collective
/// finished on the virtual clock, and how much host-staging time rode the
/// PCIe links the training stream needs (the share that cannot hide
/// behind compute).
#[derive(Debug, Clone, Copy, Default)]
pub struct JobTrace {
    pub comm_end: SimTime,
    pub staging_us: f64,
}

/// One collective of a [`LaneJob`]: a cached immutable template, the
/// per-iteration overlay to replay it under, its release time, and the
/// critical host-staging share it charges the compute path.
pub(crate) struct GraphWork {
    pub ready: SimTime,
    pub template: Arc<GraphTemplate>,
    pub overlay: GraphOverlay,
    pub staging_us: f64,
}

/// The driver behind a graph-path [`LaneJob`]: launching job `i`
/// executes template `i` under its overlay with a typed lane
/// completion.  One allocation per job set (per iteration) — the buffer
/// loop itself schedules only typed lane events, never an `Engine::at`
/// closure or boxed gate waiter per buffer.  Also the substrate of the
/// PS family's bounded RPC window (ps.rs): there each item is one shard
/// fan-in DAG and the lane width is the per-worker window.
pub(crate) struct GraphLaneDriver {
    map: GraphResMap,
    items: Vec<(Arc<GraphTemplate>, GraphOverlay)>,
}

impl GraphLaneDriver {
    pub(crate) fn new(map: GraphResMap, items: Vec<(Arc<GraphTemplate>, GraphOverlay)>) -> Self {
        GraphLaneDriver { map, items }
    }
}

impl LaneDriver for GraphLaneDriver {
    fn launch(&self, e: &mut Engine, set: LaneSetId, job: u32) {
        let (template, overlay) = &self.items[job as usize];
        template.execute_lane(e, self.map.clone(), overlay, set, job);
    }
}

/// One allreduce-family job's collectives scheduled onto the engine's
/// stream lanes (§Overlap): each collective releases at `offset` plus
/// its ready time, round-robins across the scenario's `streams` lanes
/// with at most `depth` in flight, and — once launched — interleaves
/// with its co-resident collectives on the job's shared resources, where
/// wire/PCIe/NIC FIFO contention does the arbitration (NCCL-stream
/// semantics).  `streams = 1` reproduces the retired background
/// comm-thread gate bit-for-bit: FIFO hand-off at max(ready, previous
/// completion), same event count, same grant times.  Shared by `Horovod`
/// and `Baidu` on both the graph path ([`LaneJob::graphs`]) and the
/// serialized replay ([`LaneJob::programs`]).
pub(crate) struct LaneJob {
    set: LaneSetId,
    scheduled: usize,
    staging_us: f64,
}

impl LaneJob {
    /// Graph-path job: collective `i` is a cached template replayed
    /// under its overlay on the job's placement-aware resources.
    pub(crate) fn graphs(
        e: &mut Engine,
        res: &GraphResources,
        lanes: (usize, usize),
        items: Vec<GraphWork>,
        offset: SimTime,
    ) -> LaneJob {
        let mut staging_us = 0.0;
        let mut release = Vec::with_capacity(items.len());
        let mut payload = Vec::with_capacity(items.len());
        for w in items {
            staging_us += w.staging_us;
            release.push(w.ready);
            payload.push((w.template, w.overlay));
        }
        let driver = GraphLaneDriver::new(res.mapper(), payload);
        LaneJob::submit(e, lanes, Rc::new(driver), release, staging_us, offset)
    }

    /// Serialized-path job: collective `i` is one pre-resolved op
    /// program — the typed gate-holder form of the old boxed `acquire`
    /// waiters (§Perf follow-up, retired here).
    pub(crate) fn programs(
        e: &mut Engine,
        lanes: (usize, usize),
        items: Vec<(SimTime, Rc<[ProgStep]>)>,
        staging_us: f64,
        offset: SimTime,
    ) -> LaneJob {
        let mut release = Vec::with_capacity(items.len());
        let mut progs = Vec::with_capacity(items.len());
        for (ready, steps) in items {
            release.push(ready);
            progs.push(steps);
        }
        LaneJob::submit(e, lanes, Rc::new(ProgramLanes::new(progs)), release, staging_us, offset)
    }

    fn submit(
        e: &mut Engine,
        lanes: (usize, usize),
        driver: Rc<dyn LaneDriver>,
        release: Vec<SimTime>,
        staging_us: f64,
        offset: SimTime,
    ) -> LaneJob {
        let scheduled = release.len();
        let set = e.lane_set(lanes.0, lanes.1, driver);
        for (i, r) in release.into_iter().enumerate() {
            e.lane_submit(set, offset + r, i as u32);
        }
        LaneJob { set, scheduled, staging_us }
    }

    /// The job's lane set — the comm-thread ledger the report reads.
    pub(crate) fn set(&self) -> LaneSetId {
        self.set
    }

    /// The finished job trace — errors if any collective never completed
    /// (a wiring bug would otherwise silently report a too-fast
    /// iteration; the PS path has the same guard in `PsJob::comm_end`).
    pub(crate) fn trace(&self, e: &Engine) -> Result<JobTrace> {
        crate::ensure!(
            e.lane_completed(self.set) == self.scheduled,
            "lane job did not converge: {} of {} collectives completed",
            e.lane_completed(self.set),
            self.scheduled
        );
        Ok(JobTrace { comm_end: e.lane_last_done(self.set), staging_us: self.staging_us })
    }
}

/// Fold an engine run into the allreduce-family iteration report: the
/// per-resource utilization rows plus the comm stream-lane row (kept
/// under the historical "comm-thread" name — at `streams = 1` it IS the
/// old background comm thread; shared by the serialized and graph paths
/// of Horovod and Baidu).
pub(crate) fn report_with_comm_thread(
    name: String,
    ws: &WorldSpec,
    parts: IterationParts,
    util: Vec<ResourceUse>,
    e: &mut Engine,
    set: LaneSetId,
) -> IterationReport {
    let mut report = IterationReport::from_times(name, ws, parts.iter);
    report.resource_util = util;
    report.engine_events = e.executed();
    let stats = e.lane_stats(set);
    report.resource_util.push(ResourceUse {
        name: "comm-thread".to_string(),
        served: stats.served,
        busy: stats.busy,
    });
    report.attach_trace(e, parts);
    report
}

/// Shared closing formula of the allreduce-family strategies: the
/// iteration ends when both the (runtime-dilated, scenario-stretched)
/// compute + critical staging and the communication pipeline are done,
/// plus the synchronization skew of `p` ranks.
pub(crate) fn close_iteration(
    ws: &WorldSpec,
    sc: &Scenario,
    trace: &JobTrace,
    offset: SimTime,
    runtime_tax: f64,
    skew_us_per_rank: f64,
) -> SimTime {
    close_iteration_parts(ws, sc, trace, offset, runtime_tax, skew_us_per_rank).iter
}

/// [`close_iteration`], keeping the formula's terms: the trace
/// attribution report (§Observability) composes the critical path from
/// exactly the quantities the closing formula combined, so the path
/// buckets sum to the iteration time instead of to an approximation.
pub(crate) fn close_iteration_parts(
    ws: &WorldSpec,
    sc: &Scenario,
    trace: &JobTrace,
    offset: SimTime,
    runtime_tax: f64,
    skew_us_per_rank: f64,
) -> IterationParts {
    let p = ws.world as f64;
    let dilated = ws.compute_time().as_us()
        * sc.compute_stretch()
        * (1.0 + runtime_tax * (1.0 - 1.0 / p));
    let skew = skew_us_per_rank * p + sc.sync_jitter_us(ws.world);
    let comm = trace.comm_end.saturating_sub(offset);
    let iter = SimTime::from_us(comm.as_us().max(dilated + trace.staging_us) + skew);
    IterationParts {
        iter,
        comm,
        compute_us: dilated,
        staging_us: trace.staging_us,
        skew_us: skew,
    }
}

/// Object-safe strategy interface — what the bench harness iterates over.
/// `Send + Sync` so the sweep drivers can fan points out across threads
/// (each `iteration` call owns its private engine).
pub trait Strategy: Send + Sync {
    fn name(&self) -> String;
    /// Some designs are hardware-gated (NCCL2 needs IB verbs — §VI-D).
    fn available(&self, cluster: &ClusterSpec) -> bool {
        let _ = cluster;
        true
    }
    /// One steady-state iteration under pristine conditions.
    fn iteration(&self, ws: &WorldSpec) -> Result<IterationReport> {
        self.iteration_in(ws, &Scenario::default())
    }
    /// One steady-state iteration under a [`Scenario`] (stragglers,
    /// heterogeneous nodes, jitter, shared fabric).
    fn iteration_in(&self, ws: &WorldSpec, sc: &Scenario) -> Result<IterationReport>;
}

/// All approaches the paper compares, in Figure-3 order (the RDMA
/// zero-copy transport extends the PS family past gRPC+Verbs — the
/// "RPC considered harmful" competitor — so the gRPC-vs-No-gRPC
/// contrast brackets the whole design space).
pub fn all_strategies() -> Vec<Box<dyn Strategy>> {
    vec![
        Box::new(PsStrategy::grpc()),
        Box::new(PsStrategy::grpc_mpi()),
        Box::new(PsStrategy::grpc_verbs()),
        Box::new(PsStrategy::rdma()),
        Box::new(Baidu::new()),
        Box::new(Horovod::mpi(crate::comm::MpiFlavor::Mvapich2)),
        Box::new(Horovod::nccl()),
        Box::new(Horovod::mpi(crate::comm::MpiFlavor::Mvapich2GdrOpt)),
    ]
}

/// Strategy lookup for the CLI (`--strategy horovod-mpi-opt` etc.).
pub fn by_name(name: &str) -> Result<Box<dyn Strategy>> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "grpc" => Box::new(PsStrategy::grpc()),
        "grpc+mpi" | "grpc-mpi" => Box::new(PsStrategy::grpc_mpi()),
        "grpc+verbs" | "grpc-verbs" => Box::new(PsStrategy::grpc_verbs()),
        "rdma" | "grpc+rdma" | "grpc-rdma" => Box::new(PsStrategy::rdma()),
        "baidu" | "baidu-mpi" => Box::new(Baidu::new()),
        "horovod-mpi" => Box::new(Horovod::mpi(crate::comm::MpiFlavor::Mvapich2)),
        "horovod-nccl" => Box::new(Horovod::nccl()),
        "horovod-mpi-opt" => Box::new(Horovod::mpi(crate::comm::MpiFlavor::Mvapich2GdrOpt)),
        "horovod-cray" => Box::new(Horovod::mpi(crate::comm::MpiFlavor::CrayMpich)),
        other => crate::bail!(
            "unknown strategy `{other}` (grpc | grpc+mpi | grpc+verbs | rdma | baidu | \
             horovod-mpi | horovod-nccl | horovod-mpi-opt | horovod-cray)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::models::resnet;

    #[test]
    fn readiness_monotone_and_spans_compute() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        let r = ws.tensor_readiness();
        assert_eq!(r.len(), ws.model.tensors.len());
        for w in r.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        let compute = ws.compute_time();
        assert!(r.first().unwrap().1 > SimTime::from_us(compute.as_us() / 3.0 - 1.0));
        assert_eq!(r.last().unwrap().1, compute);
    }

    #[test]
    fn report_efficiency_is_compute_over_iter() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        let compute = ws.compute_time();
        let iter = SimTime::from_us(compute.as_us() * 1.25);
        let rep = IterationReport::from_times("x".into(), &ws, iter);
        assert!((rep.scaling_efficiency - 0.8).abs() < 0.01);
        assert_eq!(rep.exposed_comm, iter - compute);
    }

    #[test]
    fn lookup_and_inventory() {
        assert_eq!(all_strategies().len(), 8);
        assert!(by_name("horovod-mpi-opt").is_ok());
        assert!(by_name("rdma").is_ok());
        assert!(by_name("grpc+rdma").is_ok());
        assert!(by_name("gloo").is_err());
    }

    #[test]
    fn every_strategy_reports_utilization() {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 4);
        for s in all_strategies() {
            if !s.available(&ws.cluster) {
                continue;
            }
            let r = s.iteration(&ws).unwrap();
            assert!(
                !r.resource_util.is_empty(),
                "{} reports no resource utilization",
                s.name()
            );
            assert!(
                r.resource_util.iter().any(|u| u.busy > SimTime::ZERO),
                "{} utilization all-zero",
                s.name()
            );
        }
    }
}
