//! Training campaigns under sustained failures (§Robustness campaign).
//!
//! PR 8's fault subsystem models *one* injected fault inside *one*
//! iteration, always shrinking the world.  A campaign is the steady
//! state the fleet-scale north star actually lives in: N committed
//! iterations of any strategy under a sustained, seeded, rate-driven
//! crash stream ([`crate::sim::fault::FaultStream`] — per-rank MTBF,
//! Poisson arrivals on the campaign clock), with
//!
//! - **checkpoint policies** ([`CheckpointPolicy`]): `off`, fixed
//!   period, or the Young–Daly optimal interval τ\* = √(2·C·M) computed
//!   from the measured per-iteration cost and the *system* MTBF
//!   (M = mtbf_per_rank / world), driving rollback-and-replay of the
//!   iterations committed since the last checkpoint;
//! - **elastic rejoin**: a crashed rank is repaired after a seeded
//!   repair-time draw and rejoins at the next iteration boundary,
//!   triggering a grow-back template rebuild to the full world (the
//!   shrink path's twin — `strategies::recovery::run_rejoin_collective`
//!   and the PS family's `iteration_rejoin`);
//! - a [`CampaignReport`] whose time buckets *conserve the clock
//!   exactly*: productive + rollback + recovery + rejoin-rebuild +
//!   checkpoint overhead == makespan on the integer-nanosecond clock.
//!
//! Campaign semantics, iteration by iteration:
//!
//! ```text
//! while committed < N:
//!   if a repaired rank is waiting        rejoin iteration at the full
//!                                        world, rebuild offset on comm
//!   else if a drawn crash lands here     rollback to the last checkpoint
//!                                        (uncheckpointed commits move to
//!                                        the rollback bucket), then the
//!                                        crashed iteration runs the PR 8
//!                                        shrink recovery and commits as
//!                                        the first recomputed step
//!   else                                 a plain iteration at the
//!                                        current world (cached — steady
//!                                        state is world-determined)
//!   then: every `interval` commits, pay the checkpoint cost
//! ```
//!
//! At most one rank is down at a time: arrivals drawn while degraded
//! (or during a rejoin barrier) are *suppressed* and counted — a second
//! concurrent failure would shrink past the recovery model's floor.
//! Crashes therefore always fire from the full world, and the world
//! timeline oscillates between `world` and `world − 1`.
//!
//! **Empty-campaign guarantee:** with `mtbf_us = 0` and checkpointing
//! off, every iteration takes the exact plain `iteration_in` path, so
//! the campaign makespan is bit-identical to N plain iterations on the
//! integer clock (`makespan.0 == N × iter.0`) — the campaign-level twin
//! of the empty-fault-plan guarantee, pinned by the chaos harness.
//!
//! **Goodput bound:** every committed step at world w contributes
//! w×batch images and costs at least the fault-free iteration at w, so
//! goodput ≤ max over visited worlds of the fault-free rate.  (The
//! bound is the *max* over {world, world−1}, not the full-world rate
//! alone: PS fan-in congestion makes throughput non-monotone in world,
//! so the shrunken world can be the faster one.)

use std::sync::Arc;

use super::fault::{FaultPlan, FaultStream};
use super::time::SimTime;
use super::trace::TraceReport;
use crate::strategies::{IterationReport, Scenario, Strategy, WorldSpec};
use crate::util::error::Result;
use crate::util::json::{self, Json};
use crate::util::prng::Rng;
use crate::{anyhow, ensure};

/// When (and how often) the campaign pays the checkpoint cost.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum CheckpointPolicy {
    /// Never checkpoint: a crash rolls back to campaign start.
    #[default]
    Off,
    /// Checkpoint every `period_us` of productive time (resolved to a
    /// whole number of iterations against the measured iteration cost).
    Fixed { period_us: f64 },
    /// Young–Daly optimal interval τ\* = √(2 · cost · MTBF_system),
    /// MTBF_system = mtbf_per_rank / world — computed from the measured
    /// per-iteration cost at campaign start.
    YoungDaly,
}

impl CheckpointPolicy {
    /// Parse the CLI/config spelling.  `period_us` feeds `fixed`.
    pub fn parse(name: &str, period_us: f64) -> Result<CheckpointPolicy> {
        match name {
            "off" => Ok(CheckpointPolicy::Off),
            "fixed" => Ok(CheckpointPolicy::Fixed { period_us }),
            "young-daly" | "yd" => Ok(CheckpointPolicy::YoungDaly),
            other => Err(anyhow!(
                "unknown checkpoint policy `{other}` (expected off | fixed | young-daly)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CheckpointPolicy::Off => "off",
            CheckpointPolicy::Fixed { .. } => "fixed",
            CheckpointPolicy::YoungDaly => "young-daly",
        }
    }
}

/// The campaign knobs a [`Scenario`] carries (`iters = 0` = no
/// campaign — the default is inert, keeping `Scenario::default()`
/// neutral).  Validation follows the repo's inert-combination policy:
/// knobs that nothing would read are rejected, not ignored.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CampaignSpec {
    /// Committed iterations the campaign must reach (0 = campaign off).
    pub iters: usize,
    /// Per-rank mean time between failures, µs (0 = fault-free).
    pub mtbf_us: f64,
    /// Seed of the crash stream and the repair-time draws.
    pub seed: u64,
    pub policy: CheckpointPolicy,
    /// Cost of writing one checkpoint, µs.
    pub ckpt_cost_us: f64,
    /// Mean repair time of a crashed rank, µs (the actual draw is
    /// uniform in [0.5, 1.5) × mean, seeded).
    pub repair_us: f64,
}

impl CampaignSpec {
    pub fn is_off(&self) -> bool {
        self.iters == 0
    }

    /// Surface-independent range/consistency checks (part of
    /// `Scenario::validate`, same funnel as the fault knobs).
    pub fn validate(&self) -> Result<()> {
        if self.iters == 0 {
            ensure!(
                self == &CampaignSpec::default(),
                "campaign knobs without campaign iterations are inert — set iters too"
            );
            return Ok(());
        }
        ensure!(
            self.mtbf_us.is_finite() && self.mtbf_us >= 0.0,
            "campaign mtbf must be finite and >= 0 us (got {})",
            self.mtbf_us
        );
        ensure!(
            self.ckpt_cost_us.is_finite() && self.ckpt_cost_us >= 0.0,
            "checkpoint cost must be finite and >= 0 us (got {})",
            self.ckpt_cost_us
        );
        ensure!(
            self.repair_us.is_finite() && self.repair_us >= 0.0,
            "repair time must be finite and >= 0 us (got {})",
            self.repair_us
        );
        if self.mtbf_us > 0.0 {
            ensure!(
                self.repair_us > 0.0,
                "a fault-driven campaign needs repair_us > 0 (the crashed rank must \
                 eventually rejoin)"
            );
        } else {
            ensure!(
                self.repair_us == 0.0,
                "repair time without an MTBF is inert — set mtbf_us too"
            );
        }
        match self.policy {
            CheckpointPolicy::Off => ensure!(
                self.ckpt_cost_us == 0.0,
                "checkpoint cost without a checkpoint policy is inert — pick fixed or \
                 young-daly"
            ),
            CheckpointPolicy::Fixed { period_us } => {
                ensure!(
                    period_us.is_finite() && period_us > 0.0,
                    "fixed checkpoint period must be finite and > 0 us (got {period_us})"
                );
                ensure!(self.ckpt_cost_us > 0.0, "a checkpoint policy needs a cost > 0 us");
            }
            CheckpointPolicy::YoungDaly => {
                ensure!(self.ckpt_cost_us > 0.0, "a checkpoint policy needs a cost > 0 us");
                ensure!(
                    self.mtbf_us > 0.0,
                    "young-daly needs an MTBF to optimize against (mtbf_us > 0)"
                );
            }
        }
        Ok(())
    }
}

/// The outcome of a whole training campaign.  All `SimTime` buckets
/// conserve the clock exactly: `productive + rollback_lost + recovery +
/// rejoin_rebuild + checkpoint_overhead == makespan`.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub strategy: String,
    pub world: usize,
    /// Committed iterations (== the spec's target on success).
    pub committed: usize,
    /// Iterations actually run, including replays of rolled-back work.
    pub attempted: usize,
    /// Commits discarded by rollbacks.
    pub discarded: usize,
    pub crashes: usize,
    pub rejoins: usize,
    /// Arrivals suppressed because a rank was already down (or a rejoin
    /// barrier was in progress) — at most one concurrent failure.
    pub suppressed: usize,
    pub checkpoints: usize,
    /// Resolved checkpoint interval, µs (0 = checkpointing off).
    pub checkpoint_interval_us: f64,
    pub makespan: SimTime,
    pub productive: SimTime,
    pub rollback_lost: SimTime,
    /// Detect + backoff + shrink-rebuild time inside crashed iterations.
    pub recovery: SimTime,
    pub rejoin_rebuild: SimTime,
    pub checkpoint_overhead: SimTime,
    /// Images of committed steps (world-at-commit × per-GPU batch each).
    pub images: f64,
    pub goodput_imgs_per_sec: f64,
    pub effective_iters_per_sec: f64,
    /// Fault-free throughput at the full world.
    pub fault_free_imgs_per_sec: f64,
    /// Fault-free throughput at world − 1 (0.0 if never visited).
    pub degraded_imgs_per_sec: f64,
    pub min_world: usize,
    /// `(time, world)` at every world-size change, starting `(0, world)`.
    pub world_timeline: Vec<(SimTime, usize)>,
    /// Engine events actually executed (cache misses, crashes, rejoins).
    pub engine_events: u64,
    /// Representative trace when a `TraceGuard` was active: the first
    /// crashed iteration's, or the first plain iteration's.
    pub trace: Option<Arc<TraceReport>>,
}

/// Byte-level equality for the determinism harness: every scalar field
/// plus the trace compared by its Chrome JSON bytes.
impl PartialEq for CampaignReport {
    fn eq(&self, o: &Self) -> bool {
        let trace_eq = match (&self.trace, &o.trace) {
            (None, None) => true,
            (Some(a), Some(b)) => a.chrome_json == b.chrome_json,
            _ => false,
        };
        self.strategy == o.strategy
            && self.world == o.world
            && self.committed == o.committed
            && self.attempted == o.attempted
            && self.discarded == o.discarded
            && self.crashes == o.crashes
            && self.rejoins == o.rejoins
            && self.suppressed == o.suppressed
            && self.checkpoints == o.checkpoints
            && self.checkpoint_interval_us == o.checkpoint_interval_us
            && self.makespan == o.makespan
            && self.productive == o.productive
            && self.rollback_lost == o.rollback_lost
            && self.recovery == o.recovery
            && self.rejoin_rebuild == o.rejoin_rebuild
            && self.checkpoint_overhead == o.checkpoint_overhead
            && self.images == o.images
            && self.goodput_imgs_per_sec == o.goodput_imgs_per_sec
            && self.effective_iters_per_sec == o.effective_iters_per_sec
            && self.fault_free_imgs_per_sec == o.fault_free_imgs_per_sec
            && self.degraded_imgs_per_sec == o.degraded_imgs_per_sec
            && self.min_world == o.min_world
            && self.world_timeline == o.world_timeline
            && self.engine_events == o.engine_events
            && trace_eq
    }
}

impl CampaignReport {
    /// The non-productive clock: everything a fault-free campaign would
    /// not have paid.
    pub fn overhead(&self) -> SimTime {
        self.rollback_lost + self.recovery + self.rejoin_rebuild + self.checkpoint_overhead
    }

    /// Deterministic JSON document (the CI `--report` artifact).
    pub fn to_json(&self) -> Json {
        let timeline = Json::Arr(
            self.world_timeline
                .iter()
                .map(|&(t, w)| Json::Arr(vec![json::num(t.as_us()), json::num(w as f64)]))
                .collect(),
        );
        json::obj(vec![
            ("schema", json::s("mpi-dnn-train/campaign/v1")),
            ("strategy", json::s(&self.strategy)),
            ("world", json::num(self.world as f64)),
            ("committed", json::num(self.committed as f64)),
            ("attempted", json::num(self.attempted as f64)),
            ("discarded", json::num(self.discarded as f64)),
            ("crashes", json::num(self.crashes as f64)),
            ("rejoins", json::num(self.rejoins as f64)),
            ("suppressed", json::num(self.suppressed as f64)),
            ("checkpoints", json::num(self.checkpoints as f64)),
            ("checkpoint_interval_us", json::num(self.checkpoint_interval_us)),
            ("makespan_us", json::num(self.makespan.as_us())),
            ("productive_us", json::num(self.productive.as_us())),
            ("rollback_lost_us", json::num(self.rollback_lost.as_us())),
            ("recovery_us", json::num(self.recovery.as_us())),
            ("rejoin_rebuild_us", json::num(self.rejoin_rebuild.as_us())),
            ("checkpoint_overhead_us", json::num(self.checkpoint_overhead.as_us())),
            ("images", json::num(self.images)),
            ("goodput_imgs_per_sec", json::num(self.goodput_imgs_per_sec)),
            ("effective_iters_per_sec", json::num(self.effective_iters_per_sec)),
            ("fault_free_imgs_per_sec", json::num(self.fault_free_imgs_per_sec)),
            ("degraded_imgs_per_sec", json::num(self.degraded_imgs_per_sec)),
            ("min_world", json::num(self.min_world as f64)),
            ("engine_events", json::num(self.engine_events as f64)),
            ("world_timeline", timeline),
        ])
    }
}

/// The fault-free steady state is world-determined, so the campaign
/// caches one plain iteration per visited world size ({full, full−1})
/// instead of re-simulating N identical iterations.
struct CachedIter {
    iter: SimTime,
    imgs_per_sec: f64,
    trace: Option<Arc<TraceReport>>,
}

/// Hard ceiling on replayed work: a campaign that attempts 64× its
/// target plus slack is thrashing (rollback outpacing progress — e.g.
/// MTBF far below the iteration time), which is a configuration error,
/// not a result.
fn attempt_ceiling(iters: usize) -> usize {
    iters.saturating_mul(64).saturating_add(1024)
}

/// Run a full training campaign of `sc.campaign` over `strategy`.
///
/// The per-iteration scenario is `sc` with the campaign surface
/// stripped (campaign spec, fault plan, rejoin knob) — drawn crashes
/// re-enter through per-iteration `FaultPlan`s carrying `sc.fault`'s
/// detection/recovery knobs, exactly like a hand-written plan would.
pub fn run_campaign(
    strategy: &dyn Strategy,
    ws: &WorldSpec,
    sc: &Scenario,
) -> Result<CampaignReport> {
    let spec = sc.campaign.clone();
    spec.validate()?;
    ensure!(spec.iters > 0, "a campaign needs iters > 0 (set --campaign-iters)");
    ensure!(ws.world >= 2, "a campaign needs a distributed run (world {} < 2)", ws.world);
    if spec.mtbf_us > 0.0 {
        ensure!(
            ws.world >= 3,
            "a fault-driven campaign needs world >= 3 (crash recovery rebuilds over \
             the survivors)"
        );
    }
    let full = ws.world;
    let knobs = sc.fault.clone();
    let mut sc_iter = sc.clone();
    sc_iter.campaign = CampaignSpec::default();
    sc_iter.fault = FaultPlan::default();
    sc_iter.rejoin_rebuild_us = 0.0;

    // the fault-free steady state is world-determined: one plain run
    // per visited world size ({full, full−1}) serves the whole campaign
    fn run_plain<'a>(
        cache: &'a mut [Option<CachedIter>; 2],
        events: &mut u64,
        strategy: &dyn Strategy,
        ws: &WorldSpec,
        sc_iter: &Scenario,
        full: usize,
        w: usize,
    ) -> Result<&'a CachedIter> {
        let slot = if w == full { 0 } else { 1 };
        if cache[slot].is_none() {
            let mut ws_w = ws.clone();
            ws_w.world = w;
            let r = strategy.iteration_in(&ws_w, sc_iter)?;
            *events += r.engine_events;
            cache[slot] =
                Some(CachedIter { iter: r.iter, imgs_per_sec: r.imgs_per_sec, trace: r.trace });
        }
        Ok(cache[slot].as_ref().expect("just filled"))
    }

    let mut engine_events: u64 = 0;
    let mut cache: [Option<CachedIter>; 2] = [None, None]; // [full, full−1]

    // measured per-iteration cost at the full world: the checkpoint
    // policies' input and the empty-campaign identity's unit
    let base = run_plain(&mut cache, &mut engine_events, strategy, ws, &sc_iter, full, full)?;
    let base_iter = base.iter;
    let fault_free_rate = base.imgs_per_sec;
    let plain_trace = base.trace.clone();
    ensure!(base_iter > SimTime::ZERO, "degenerate iteration: zero duration");

    // resolve the checkpoint policy to a whole number of iterations
    let tau_us = match spec.policy {
        CheckpointPolicy::Off => f64::INFINITY,
        CheckpointPolicy::Fixed { period_us } => period_us,
        CheckpointPolicy::YoungDaly => {
            (2.0 * spec.ckpt_cost_us * (spec.mtbf_us / full as f64)).sqrt()
        }
    };
    let (interval_iters, interval_us) = if tau_us.is_finite() {
        let n = (tau_us / base_iter.as_us()).round().max(1.0) as usize;
        (n, n as f64 * base_iter.as_us())
    } else {
        (usize::MAX, 0.0)
    };

    let mut stream = FaultStream::new(full, spec.mtbf_us, spec.seed);
    let mut repair_rng = Rng::new(spec.seed ^ 0x4E4A_0123);

    let mut t = SimTime::ZERO;
    let (mut committed, mut attempted, mut discarded) = (0usize, 0usize, 0usize);
    let (mut crashes, mut rejoins, mut suppressed, mut checkpoints) = (0usize, 0usize, 0usize, 0);
    let mut productive = SimTime::ZERO;
    let mut rollback_lost = SimTime::ZERO;
    let mut recovery = SimTime::ZERO;
    let mut rejoin_rebuild = SimTime::ZERO;
    let mut checkpoint_overhead = SimTime::ZERO;
    let mut images = 0.0f64;
    // productive span + images of each commit since the last checkpoint
    // — exactly what a crash rolls back
    let mut since_ckpt: Vec<(SimTime, f64)> = Vec::new();
    let mut down: Option<SimTime> = None; // repair-completion time
    let mut min_world = full;
    let mut world_timeline = vec![(SimTime::ZERO, full)];
    let mut crash_trace: Option<Arc<TraceReport>> = None;

    while committed < spec.iters {
        attempted += 1;
        ensure!(
            attempted <= attempt_ceiling(spec.iters),
            "campaign is thrashing: {attempted} attempts for {} targets (MTBF {} us \
             below the iteration time?)",
            spec.iters,
            spec.mtbf_us
        );
        let rejoining = matches!(down, Some(at) if t >= at);
        let w = if down.is_some() && !rejoining { full - 1 } else { full };

        // drawn arrivals landing in this iteration's fault-free window;
        // only one fires, and none while degraded or during a rejoin
        // barrier (at most one concurrent failure)
        let window =
            run_plain(&mut cache, &mut engine_events, strategy, ws, &sc_iter, full, w)?.iter;
        let mut crash: Option<(usize, f64)> = None;
        if let Some(st) = stream.as_mut() {
            while st.peek_us() < (t + window).as_us() {
                let (rank, at_us) = st.pop();
                if down.is_some() || rejoining || crash.is_some() {
                    suppressed += 1;
                    continue;
                }
                // arrivals that fell into non-iteration spans (checkpoint
                // writes, recovery tails) fire at the next iteration start
                crash = Some((rank, (at_us - t.as_us()).max(1.0)));
            }
        }

        let t_start = t;
        if rejoining {
            // --- elastic rejoin: grow-back rebuild to the full world ---
            let mut sc_r = sc_iter.clone();
            sc_r.rejoin_rebuild_us = knobs.rebuild_us.max(1e-3);
            let r = strategy.iteration_in(ws, &sc_r)?;
            engine_events += r.engine_events;
            let span = r.iter;
            t += span;
            // the rebuild overlaps compute, so only the excess over the
            // fault-free full-world iteration is grow-back cost
            let extra = span.saturating_sub(base_iter);
            rejoin_rebuild += extra;
            let prod = span - extra;
            productive += prod;
            let imgs = full as f64 * ws.batch_per_gpu as f64;
            images += imgs;
            since_ckpt.push((prod, imgs));
            committed += 1;
            rejoins += 1;
            down = None;
            world_timeline.push((t_start, full));
        } else if let Some((rank, at_us)) = crash {
            // --- rollback to the last checkpoint ---
            for (span, imgs) in since_ckpt.drain(..) {
                productive = productive - span;
                rollback_lost += span;
                images -= imgs;
                committed -= 1;
                discarded += 1;
            }
            // --- the crashed iteration: PR 8 shrink recovery; its
            // replayed collectives complete over world−1 and the step
            // commits as the first recomputed one ---
            let mut sc_f = sc_iter.clone();
            sc_f.fault = FaultPlan::crash_with_knobs_of(&knobs, rank, at_us);
            let r = strategy.iteration_in(ws, &sc_f)?;
            engine_events += r.engine_events;
            let f = r.fault.ok_or_else(|| anyhow!("crashed iteration returned no FaultReport"))?;
            if crash_trace.is_none() {
                crash_trace = r.trace.clone();
            }
            let span = r.iter;
            t += span;
            let rec = f.recover.min(span);
            recovery += rec;
            let prod = span - rec;
            productive += prod;
            let imgs = (full - 1) as f64 * ws.batch_per_gpu as f64;
            images += imgs;
            since_ckpt.push((prod, imgs));
            committed += 1;
            crashes += 1;
            min_world = min_world.min(full - 1);
            world_timeline.push((t_start + f.failed_at, full - 1));
            // seeded repair draw: uniform in [0.5, 1.5) × mean
            let repair = SimTime::from_us(spec.repair_us * (0.5 + repair_rng.next_f64()));
            down = Some(t + repair);
        } else {
            // --- plain iteration at the current world ---
            let c = run_plain(&mut cache, &mut engine_events, strategy, ws, &sc_iter, full, w)?;
            let span = c.iter;
            t += span;
            productive += span;
            let imgs = w as f64 * ws.batch_per_gpu as f64;
            images += imgs;
            since_ckpt.push((span, imgs));
            committed += 1;
        }

        // --- checkpoint policy: pay the cost every `interval` commits ---
        if since_ckpt.len() >= interval_iters {
            let cost = SimTime::from_us(spec.ckpt_cost_us);
            t += cost;
            checkpoint_overhead += cost;
            checkpoints += 1;
            since_ckpt.clear();
        }
    }

    let makespan = t;
    // clock conservation: every nanosecond is attributed exactly once
    ensure!(
        productive + rollback_lost + recovery + rejoin_rebuild + checkpoint_overhead == makespan,
        "campaign clock leak: buckets do not sum to the makespan"
    );
    ensure!(committed == spec.iters, "campaign ended at {committed}/{} commits", spec.iters);

    let degraded_rate = match &cache[1] {
        Some(c) => c.imgs_per_sec,
        None => 0.0,
    };
    Ok(CampaignReport {
        strategy: strategy.name(),
        world: full,
        committed,
        attempted,
        discarded,
        crashes,
        rejoins,
        suppressed,
        checkpoints,
        checkpoint_interval_us: interval_us,
        makespan,
        productive,
        rollback_lost,
        recovery,
        rejoin_rebuild,
        checkpoint_overhead,
        images,
        goodput_imgs_per_sec: images / makespan.as_secs(),
        effective_iters_per_sec: committed as f64 / makespan.as_secs(),
        fault_free_imgs_per_sec: fault_free_rate,
        degraded_imgs_per_sec: degraded_rate,
        min_world,
        world_timeline,
        engine_events,
        trace: crash_trace.or(plain_trace),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::MpiFlavor;
    use crate::models::mobilenet::mobilenet_v1;
    use crate::strategies::Horovod;

    fn ws(world: usize) -> WorldSpec {
        WorldSpec::new(presets::ri2(), mobilenet_v1(), world)
    }

    fn campaign_sc(spec: CampaignSpec) -> Scenario {
        Scenario { campaign: spec, ..Scenario::default() }
    }

    #[test]
    fn empty_campaign_makespan_is_n_plain_iterations_exactly() {
        let s = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let n = 37;
        let sc = campaign_sc(CampaignSpec { iters: n, ..CampaignSpec::default() });
        let r = run_campaign(&s, &ws(8), &sc).expect("campaign runs");
        let one = s.iteration_in(&ws(8), &Scenario::default()).expect("plain");
        assert_eq!(r.makespan.0, one.iter.0 * n as u64, "bit-identical to N plain iterations");
        assert_eq!(r.productive, r.makespan);
        assert_eq!(r.overhead(), SimTime::ZERO);
        assert_eq!((r.crashes, r.rejoins, r.checkpoints), (0, 0, 0));
    }

    #[test]
    fn young_daly_interval_follows_the_square_root_law() {
        // τ* = √(2·C·M), M = mtbf/world: C = 500 µs, mtbf = 8e5 µs,
        // world 8 ⇒ M = 1e5 ⇒ τ* = 10_000 µs
        let s = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let spec = CampaignSpec {
            iters: 20,
            mtbf_us: 800_000.0,
            seed: 11,
            policy: CheckpointPolicy::YoungDaly,
            ckpt_cost_us: 500.0,
            repair_us: 10_000.0,
        };
        let r = run_campaign(&s, &ws(8), &campaign_sc(spec)).expect("campaign runs");
        let one = s.iteration_in(&ws(8), &Scenario::default()).unwrap().iter.as_us();
        let expect = (10_000.0f64 / one).round().max(1.0) * one;
        assert!(
            (r.checkpoint_interval_us - expect).abs() < 1e-6,
            "interval {} != √(2CM) rounded to iterations {expect}",
            r.checkpoint_interval_us
        );
    }

    #[test]
    fn crashes_shrink_then_rejoin_grows_back() {
        let s = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let spec = CampaignSpec {
            iters: 40,
            mtbf_us: 40_000.0, // aggressive: several crashes over the run
            seed: 3,
            policy: CheckpointPolicy::Fixed { period_us: 5_000.0 },
            ckpt_cost_us: 200.0,
            repair_us: 4_000.0,
        };
        let r = run_campaign(&s, &ws(8), &campaign_sc(spec)).expect("campaign runs");
        assert!(r.crashes >= 1, "MTBF regime must produce crashes (got {})", r.crashes);
        assert!(r.rejoins >= 1, "repaired ranks must rejoin (got {})", r.rejoins);
        assert_eq!(r.min_world, 7, "one concurrent failure: world oscillates 8 ↔ 7");
        assert_eq!(r.committed, 40);
        assert_eq!(
            r.productive + r.rollback_lost + r.recovery + r.rejoin_rebuild
                + r.checkpoint_overhead,
            r.makespan,
            "clock conservation"
        );
        // the timeline records every shrink and grow-back
        let shrinks = r.world_timeline.iter().filter(|&&(_, w)| w == 7).count();
        let grows = r.world_timeline.iter().filter(|&&(_, w)| w == 8).count();
        assert_eq!(shrinks, r.crashes);
        assert_eq!(grows, 1 + r.rejoins);
        // goodput bound: no campaign outruns the best fault-free rate
        let bound = r.fault_free_imgs_per_sec.max(r.degraded_imgs_per_sec);
        assert!(r.goodput_imgs_per_sec <= bound * (1.0 + 1e-9));
    }

    #[test]
    fn campaign_spec_validation_rejects_inert_combinations() {
        assert!(CampaignSpec::default().validate().is_ok());
        // knobs without iters are inert
        let s = CampaignSpec { mtbf_us: 1e5, ..CampaignSpec::default() };
        assert!(s.validate().is_err());
        // faults without a repair path
        let s = CampaignSpec { iters: 10, mtbf_us: 1e5, ..CampaignSpec::default() };
        assert!(s.validate().is_err());
        // checkpoint cost without a policy
        let s = CampaignSpec { iters: 10, ckpt_cost_us: 100.0, ..CampaignSpec::default() };
        assert!(s.validate().is_err());
        // young-daly needs an MTBF
        let s = CampaignSpec {
            iters: 10,
            policy: CheckpointPolicy::YoungDaly,
            ckpt_cost_us: 100.0,
            ..CampaignSpec::default()
        };
        assert!(s.validate().is_err());
        // a fully specified campaign validates
        let s = CampaignSpec {
            iters: 10,
            mtbf_us: 1e5,
            seed: 1,
            policy: CheckpointPolicy::YoungDaly,
            ckpt_cost_us: 100.0,
            repair_us: 1e4,
        };
        assert!(s.validate().is_ok());
    }
}
