//! Virtual time: integer nanoseconds (total order, no float drift in the
//! event heap).  Cost models compute in f64 microseconds and convert at
//! the boundary.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Nanoseconds since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_us(us: f64) -> SimTime {
        debug_assert!(us >= 0.0 && us.is_finite(), "bad duration: {us}us");
        SimTime((us * 1e3).round() as u64)
    }

    pub fn from_ms(ms: f64) -> SimTime {
        SimTime::from_us(ms * 1e3)
    }

    pub fn from_secs(s: f64) -> SimTime {
        SimTime::from_us(s * 1e6)
    }

    pub fn as_us(self) -> f64 {
        self.0 as f64 / 1e3
    }

    pub fn as_ms(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn max(self, other: SimTime) -> SimTime {
        SimTime(self.0.max(other.0))
    }

    pub fn min(self, other: SimTime) -> SimTime {
        SimTime(self.0.min(other.0))
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_sub(rhs.0).expect("SimTime underflow"))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", crate::util::bytes::fmt_us(self.as_us()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        let t = SimTime::from_us(123.456);
        assert!((t.as_us() - 123.456).abs() < 1e-3);
        assert_eq!(SimTime::from_ms(1.0), SimTime::from_us(1000.0));
        assert_eq!(SimTime::from_secs(1.0), SimTime::from_ms(1000.0));
    }

    #[test]
    fn arithmetic() {
        let a = SimTime::from_us(10.0);
        let b = SimTime::from_us(4.0);
        assert_eq!((a + b).as_us(), 14.0);
        assert_eq!((a - b).as_us(), 6.0);
        assert_eq!(a.max(b), a);
        assert_eq!(b.saturating_sub(a), SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        let _ = SimTime::from_us(1.0) - SimTime::from_us(2.0);
    }
}
