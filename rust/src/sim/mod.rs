//! Deterministic discrete-event simulator.
//!
//! The paper's measurements come from real clusters (RI2 / Owens /
//! Piz Daint); repro band 0 means we substitute a simulated substrate
//! (DESIGN.md §2).  Everything time-related in the repo flows through this
//! engine: strategies schedule compute and communication activities as
//! events, FIFO resources model NIC/PCIe serialization (parameter-server
//! fan-in!), and the virtual clock yields the iteration times the figures
//! plot.  Runs are bit-deterministic: ties break on sequence number, no
//! wall-clock anywhere.

pub mod calq;
pub mod campaign;
pub mod engine;
pub mod fault;
pub mod time;
pub mod trace;

pub use calq::CalendarQueue;
pub use campaign::{run_campaign, CampaignReport, CampaignSpec, CheckpointPolicy};
pub use engine::{
    Action, Engine, EngineHook, GateId, HookId, JoinId, LaneDriver, LaneSetId, OnDone, ProgId,
    ProgStep, ProgramLanes, ResourceId, ServiceStats, TimerId,
};
pub use fault::{FaultEvent, FaultKind, FaultPlan, FaultStream};
pub use time::SimTime;
pub use trace::{
    IterationParts, PathBucket, SpanKind, TraceGuard, TraceReport, TraceSpan, Tracer,
};
