//! Calendar (radix-bucket) event queue — the fleet-scale replacement for
//! the `BinaryHeap` event heap (§Scale).
//!
//! A DES over a cluster produces *dense, near-monotone* timestamps: the
//! clock only moves forward, and at any instant the outstanding events
//! cluster within a few link/kernel service times of `now`.  A binary
//! heap pays O(log n) per event with n ~ world × ops; this queue pays
//! O(1) amortized by hashing each event into a bucket of its time *tick*
//! (`at >> shift`) on a power-of-two ring, and only ever sorting the one
//! bucket that is currently draining.
//!
//! ## Ordering contract
//!
//! Pops come out in strictly ascending `(at, seq)` order — the exact
//! total order of the old heap.  `seq` is globally unique (the engine's
//! scheduling counter), so *any* correct min-queue over `(at, seq)`
//! yields the same pop sequence; this is what keeps every Figure-pin and
//! placement/overlap regression bit-for-bit across the swap.  The
//! property test `prop_calendar_queue_matches_heap_oracle` pins this
//! against a `BinaryHeap` oracle over randomized streams.
//!
//! ## Structure and invariants
//!
//! - `active` holds the entries of the *front* tick run, sorted
//!   descending by `(at, seq)` so `pop` is a `Vec::pop` from the end.
//!   Every entry in `active` orders before every bucketed/overflow entry.
//! - `buckets[tick & mask]` holds entries with
//!   `active_tick < tick < active_tick + buckets.len()` — inside the
//!   window each in-use slot holds exactly one tick's entries, so a
//!   refill takes a whole slot without splitting.
//! - `overflow` holds everything beyond the window; when the window
//!   drains, the queue *rebases* at the overflow's minimum tick.
//!
//! ## Resize policy (hysteresis, never on the pop fast path)
//!
//! Tuning only runs at refill boundaries, and only after `STRIKES`
//! consecutive bad refills, so a single odd burst never thrashes:
//! - refills that scan more than `SCAN_HI` empty slots → *coarsen*
//!   (`shift += 2`, fewer finer-grained empty slots to walk);
//! - refilled runs larger than `DENSE_HI` entries → *refine*
//!   (`shift -= 2`, cheaper per-run sorts);
//! - a rebase that bounces most of the overflow back → *grow* the
//!   window (double the bucket count, up to `MAX_BUCKETS`).
//!
//! Every resize is a full rebuild to a consistent state, so the ordering
//! contract is unconditional — resizes change speed, never results.

use super::time::SimTime;

/// One queued event: the `(at, seq)` sort key plus an opaque payload.
struct Entry<T> {
    at: u64,
    seq: u64,
    item: T,
}

const INIT_SHIFT: u32 = 10; // 1.024us ticks — a PCIe/NVLink service quantum
const MAX_SHIFT: u32 = 40; // ~1100s ticks; beyond this everything is one tick
const INIT_BUCKETS: usize = 1 << 10;
const MAX_BUCKETS: usize = 1 << 16;
const SCAN_HI: u32 = 64; // refill scan longer than this is "too sparse"
const DENSE_HI: usize = 4096; // active run larger than this is "too dense"
const STRIKES: u32 = 8; // consecutive bad refills before a resize

/// Monotone priority queue over `(SimTime, seq)` with O(1) amortized
/// push/pop for the dense near-monotone streams a cluster DES emits.
/// See the module docs for the ordering contract and invariants.
pub struct CalendarQueue<T> {
    /// Bucket granularity: events map to tick `at >> shift`.
    shift: u32,
    /// `buckets.len() - 1`; bucket count is always a power of two.
    mask: u64,
    buckets: Vec<Vec<Entry<T>>>,
    /// Entries currently held across `buckets` (not `active`/`overflow`).
    in_buckets: usize,
    /// Front run: tick `active_tick`, sorted descending, popped from the end.
    active: Vec<Entry<T>>,
    active_tick: u64,
    /// Entries at ticks beyond the bucket window.
    overflow: Vec<Entry<T>>,
    len: usize,
    peak_len: usize,
    sparse_strikes: u32,
    dense_strikes: u32,
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        CalendarQueue::new()
    }
}

impl<T> CalendarQueue<T> {
    pub fn new() -> Self {
        CalendarQueue {
            shift: INIT_SHIFT,
            mask: (INIT_BUCKETS - 1) as u64,
            buckets: (0..INIT_BUCKETS).map(|_| Vec::new()).collect(),
            in_buckets: 0,
            active: Vec::new(),
            active_tick: 0,
            overflow: Vec::new(),
            len: 0,
            peak_len: 0,
            sparse_strikes: 0,
            dense_strikes: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// High-water mark of queued entries over the queue's lifetime.
    pub fn peak_len(&self) -> usize {
        self.peak_len
    }

    /// Is the queue currently *at* its high-water mark?  The tracer's
    /// depth counter (§Observability) samples exactly when this turns
    /// true after a push — monotone samples, so a traced run records a
    /// bounded, deterministic depth series off the pop path.
    pub fn at_peak(&self) -> bool {
        self.len > 0 && self.len == self.peak_len
    }

    /// Approximate peak memory footprint: the peak entry population plus
    /// the bucket ring itself.  A reporting figure (§Scale bench), not an
    /// allocator measurement.
    pub fn approx_peak_bytes(&self) -> usize {
        self.peak_len * std::mem::size_of::<Entry<T>>()
            + self.buckets.len() * std::mem::size_of::<Vec<Entry<T>>>()
    }

    /// Insert an entry.  `seq` must be unique across all live entries
    /// (the engine's global scheduling counter guarantees this); ties on
    /// `at` resolve by `seq`, i.e. scheduling order.
    pub fn push(&mut self, at: SimTime, seq: u64, item: T) {
        let e = Entry { at: at.0, seq, item };
        let tick = e.at >> self.shift;
        if self.len == 0 {
            // empty queue: re-anchor the window at this entry's tick so a
            // long idle jump never lands in overflow
            self.active_tick = tick;
            self.active.push(e);
        } else if tick <= self.active_tick {
            // joins the front run: sorted insert keeps `active` a
            // descending run (all bucketed entries have strictly larger
            // ticks, so ordering against them is already correct)
            let key = (e.at, e.seq);
            let pos = self.active.partition_point(|x| (x.at, x.seq) > key);
            self.active.insert(pos, e);
        } else if tick - self.active_tick < self.buckets.len() as u64 {
            self.buckets[(tick & self.mask) as usize].push(e);
            self.in_buckets += 1;
        } else {
            self.overflow.push(e);
        }
        self.len += 1;
        self.peak_len = self.peak_len.max(self.len);
    }

    /// Drop every queued entry (the fault-injection cut: abandoned
    /// events of an aborted timeline).  The window, granularity and
    /// peak-length ledger survive — only the entries go, so the queue
    /// keeps its tuned shape for the recovery phase that follows.
    pub fn clear(&mut self) {
        self.active.clear();
        if self.in_buckets > 0 {
            for b in &mut self.buckets {
                b.clear();
            }
            self.in_buckets = 0;
        }
        self.overflow.clear();
        self.len = 0;
    }

    /// Remove and return the minimum entry by `(at, seq)`.
    pub fn pop(&mut self) -> Option<(SimTime, u64, T)> {
        if self.active.is_empty() && !self.refill() {
            return None;
        }
        let e = self.active.pop().expect("refill left active empty");
        self.len -= 1;
        Some((SimTime(e.at), e.seq, e.item))
    }

    /// Advance to the next non-empty tick run.  Returns false iff the
    /// queue is empty.
    fn refill(&mut self) -> bool {
        if self.in_buckets > 0 {
            // invariant: some bucketed entry lies within the window, so
            // this scan terminates within buckets.len() - 1 probes
            let mut t = self.active_tick + 1;
            let mut scanned = 0u32;
            loop {
                let slot = (t & self.mask) as usize;
                if !self.buckets[slot].is_empty() {
                    self.active = std::mem::take(&mut self.buckets[slot]);
                    self.in_buckets -= self.active.len();
                    self.active_tick = t;
                    self.active.sort_unstable_by(|a, b| (b.at, b.seq).cmp(&(a.at, a.seq)));
                    break;
                }
                t += 1;
                scanned += 1;
                debug_assert!(
                    (scanned as u64) < self.buckets.len() as u64,
                    "bucket window lost an entry"
                );
            }
            self.tune(scanned);
            true
        } else if !self.overflow.is_empty() {
            self.rebase();
            true
        } else {
            false
        }
    }

    /// Window drained: restart it at the overflow's minimum tick, pulling
    /// newly in-window entries into the buckets.  If most of the spill
    /// bounces straight back to overflow the window is too narrow for the
    /// current event spread — grow it.
    fn rebase(&mut self) {
        let min_tick = self
            .overflow
            .iter()
            .map(|e| e.at >> self.shift)
            .min()
            .expect("rebase on empty overflow");
        self.active_tick = min_tick;
        let spill = std::mem::take(&mut self.overflow);
        let total = spill.len();
        let window = self.buckets.len() as u64;
        let mut bounced = 0usize;
        for e in spill {
            let tick = e.at >> self.shift;
            if tick == min_tick {
                self.active.push(e);
            } else if tick - min_tick < window {
                self.buckets[(tick & self.mask) as usize].push(e);
                self.in_buckets += 1;
            } else {
                self.overflow.push(e);
                bounced += 1;
            }
        }
        self.active.sort_unstable_by(|a, b| (b.at, b.seq).cmp(&(a.at, a.seq)));
        if bounced * 2 > total && self.buckets.len() < MAX_BUCKETS {
            self.rebuild(self.shift, self.buckets.len() * 2);
        }
    }

    /// Strike-counted tuning, run once per refill: coarsen after
    /// consistently sparse scans, refine after consistently dense runs.
    fn tune(&mut self, scanned: u32) {
        if scanned > SCAN_HI {
            self.sparse_strikes += 1;
        } else {
            self.sparse_strikes = 0;
        }
        if self.active.len() > DENSE_HI {
            self.dense_strikes += 1;
        } else {
            self.dense_strikes = 0;
        }
        if self.sparse_strikes >= STRIKES && self.shift < MAX_SHIFT {
            self.rebuild((self.shift + 2).min(MAX_SHIFT), self.buckets.len());
        } else if self.dense_strikes >= STRIKES && self.shift >= 2 {
            self.rebuild(self.shift - 2, self.buckets.len());
        }
    }

    /// Redistribute every entry under a new (shift, bucket count):
    /// re-anchor the window at the minimum tick, refill `active` with the
    /// minimum run.  Restores all invariants from scratch, so it is safe
    /// at any refill boundary.
    fn rebuild(&mut self, shift: u32, nbuckets: usize) {
        debug_assert!(nbuckets.is_power_of_two());
        let mut all: Vec<Entry<T>> = Vec::with_capacity(self.len);
        all.append(&mut self.active);
        for b in &mut self.buckets {
            all.append(b);
        }
        all.append(&mut self.overflow);
        self.shift = shift;
        if nbuckets != self.buckets.len() {
            self.buckets = (0..nbuckets).map(|_| Vec::new()).collect();
        }
        self.mask = (nbuckets - 1) as u64;
        self.in_buckets = 0;
        self.sparse_strikes = 0;
        self.dense_strikes = 0;
        let Some(min_tick) = all.iter().map(|e| e.at >> shift).min() else {
            return;
        };
        self.active_tick = min_tick;
        let window = nbuckets as u64;
        for e in all {
            let tick = e.at >> shift;
            if tick == min_tick {
                self.active.push(e);
            } else if tick - min_tick < window {
                self.buckets[(tick & self.mask) as usize].push(e);
                self.in_buckets += 1;
            } else {
                self.overflow.push(e);
            }
        }
        self.active.sort_unstable_by(|a, b| (b.at, b.seq).cmp(&(a.at, a.seq)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut CalendarQueue<u32>) -> Vec<(u64, u64, u32)> {
        let mut out = Vec::new();
        while let Some((at, seq, item)) = q.pop() {
            out.push((at.0, seq, item));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = CalendarQueue::new();
        q.push(SimTime(3000), 0, 0);
        q.push(SimTime(1000), 1, 1);
        q.push(SimTime(2000), 2, 2);
        q.push(SimTime(1000), 3, 3); // tie with seq 1: seq breaks it
        let order: Vec<u32> = drain(&mut q).into_iter().map(|(_, _, i)| i).collect();
        assert_eq!(order, vec![1, 3, 2, 0]);
        assert!(q.is_empty());
        assert_eq!(q.peak_len(), 4);
    }

    #[test]
    fn same_tick_ties_resolve_by_seq() {
        // all within one 1.024us tick
        let mut q = CalendarQueue::new();
        for seq in [5u64, 2, 9, 0] {
            q.push(SimTime(100), seq, seq as u32);
        }
        let order: Vec<u64> = drain(&mut q).into_iter().map(|(_, s, _)| s).collect();
        assert_eq!(order, vec![0, 2, 5, 9]);
    }

    #[test]
    fn clear_empties_queue_but_keeps_peak_and_stays_usable() {
        let mut q = CalendarQueue::new();
        q.push(SimTime(1000), 0, 0);
        q.push(SimTime(5000), 1, 1);
        // overflow population too
        q.push(SimTime(10u64 << (INIT_SHIFT + 14)), 2, 2);
        assert_eq!(q.pop().map(|(t, ..)| t.0), Some(1000));
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.pop().map(|(t, ..)| t.0), None);
        assert_eq!(q.peak_len(), 3, "the high-water ledger survives a clear");
        // the queue stays fully usable after the cut
        q.push(SimTime(7000), 3, 3);
        q.push(SimTime(6000), 4, 4);
        assert_eq!(drain(&mut q), vec![(6000, 4, 4), (7000, 3, 3)]);
    }

    #[test]
    fn far_future_entries_route_through_overflow_and_rebase() {
        let mut q = CalendarQueue::new();
        q.push(SimTime(0), 0, 0);
        // way beyond the initial window of 1024 ticks × 1.024us
        let far = 10u64 << (INIT_SHIFT + 14);
        q.push(SimTime(far), 1, 1);
        q.push(SimTime(far + 1), 2, 2);
        assert_eq!(drain(&mut q), vec![(0, 0, 0), (far, 1, 1), (far + 1, 2, 2)]);
    }

    #[test]
    fn push_at_or_before_active_tick_joins_front_run() {
        let mut q = CalendarQueue::new();
        q.push(SimTime(5000), 0, 0);
        q.push(SimTime(9000), 1, 1);
        assert_eq!(q.pop().map(|(t, ..)| t.0), Some(5000));
        // now active_tick is 9000's tick; an equal-time later-seq entry
        // must still order after it, an earlier-time entry before it
        q.push(SimTime(9000), 2, 2);
        q.push(SimTime(8000), 3, 3);
        let rest: Vec<u64> = drain(&mut q).into_iter().map(|(t, _, _)| t).collect();
        assert_eq!(rest, vec![8000, 9000, 9000]);
    }

    #[test]
    fn matches_heap_oracle_on_lcg_stream() {
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let mut q = CalendarQueue::new();
        let mut oracle: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
        let mut x: u64 = 0x2545F4914F6CDD1D;
        let mut now = 0u64;
        let mut seq = 0u64;
        for round in 0..5000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // mixed deltas: ties, dense near-future, occasional far jumps
            let delta = match x % 10 {
                0 => 0,
                1..=6 => x % 2_000,
                7 | 8 => x % 2_000_000,
                _ => x % 40_000_000_000,
            };
            let at = now + delta;
            q.push(SimTime(at), seq, seq as u32);
            oracle.push(Reverse((at, seq)));
            seq += 1;
            if round % 3 == 0 {
                let got = q.pop().map(|(t, s, _)| (t.0, s));
                let want = oracle.pop().map(|Reverse(k)| k);
                assert_eq!(got, want);
                if let Some((t, _)) = got {
                    now = t; // engine-style: never schedule into the past
                }
            }
        }
        loop {
            let got = q.pop().map(|(t, s, _)| (t.0, s));
            let want = oracle.pop().map(|Reverse(k)| k);
            assert_eq!(got, want);
            if got.is_none() {
                break;
            }
        }
    }

    #[test]
    fn dense_bursts_trigger_refine_without_reordering() {
        let mut q = CalendarQueue::new();
        let mut seq = 0u64;
        // many refills whose runs exceed DENSE_HI: forces the refine path
        for burst in 0..(STRIKES + 4) as u64 {
            let base = burst << (INIT_SHIFT + 1);
            for i in 0..(DENSE_HI + 64) as u64 {
                q.push(SimTime(base + (i % 7)), seq, seq as u32);
                seq += 1;
            }
        }
        let out = drain(&mut q);
        assert_eq!(out.len(), seq as usize);
        assert!(out.windows(2).all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1)));
    }

    #[test]
    fn sparse_streams_trigger_coarsen_without_reordering() {
        let mut q = CalendarQueue::new();
        // entries ~SCAN_HI*4 ticks apart: every refill over-scans, the
        // queue coarsens after STRIKES, order is unchanged
        let stride = (SCAN_HI as u64 * 4) << INIT_SHIFT;
        let n = (STRIKES + 6) as u64;
        for i in 0..n {
            q.push(SimTime(i * stride), i, i as u32);
        }
        let out = drain(&mut q);
        let times: Vec<u64> = out.iter().map(|e| e.0).collect();
        assert_eq!(times, (0..n).map(|i| i * stride).collect::<Vec<_>>());
    }
}
