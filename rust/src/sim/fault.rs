//! Deterministic fault injection (§Robustness).
//!
//! A [`FaultPlan`] is a *seeded, validated schedule* of injected
//! failures — rank crashes, transient link flaps on a `(node, rail)`
//! port, whole-rail failures with failover onto the surviving rails,
//! stragglers that escalate to dead — plus the detection/recovery cost
//! knobs every family shares: the failure-detection timeout, the
//! exponential retry backoff (base, factor, bounded attempts), the
//! template-rebuild cost of an elastic shrink, and the checkpoint
//! period of the lost-work model.
//!
//! The plan is *data*, not behavior: the per-family recovery models
//! live in `strategies::recovery` (collectives) and `strategies::ps`
//! (RPC retry).  What lives here is the schema, its CLI/`[scenario.fault]`
//! spec grammar, validation against a world/placement, and the seeded
//! generator backing the `scenario faults` sweep.
//!
//! **Empty-plan guarantee:** a plan with no events routes every strategy
//! through the exact pre-fault code path — zero extra events, zero extra
//! state — so an empty `FaultPlan` is bit-identical to the plan never
//! existing (pinned by `prop_empty_fault_plan_is_bit_identical`).

use crate::cluster::Placement;
use crate::util::error::Result;
use crate::util::prng::Rng;
use crate::{anyhow, ensure};

use super::time::SimTime;

/// One injected failure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Rank `rank` dies: its in-flight work is aborted, the collective
    /// detects the failure after the plan's timeout and rebuilds over
    /// the surviving world (elastic shrink to p−1); the PS family treats
    /// it as a dead parameter server and reassigns its shards.
    RankCrash { rank: usize },
    /// The `(node, rail)` NIC port goes dark for `for_us`: the port is
    /// FIFO-held for the window, stalling queued and in-flight transfers
    /// behind it (transient — no topology change).
    LinkFlap { node: usize, rail: usize, for_us: f64 },
    /// The `(node, rail)` NIC port fails for the iteration: the node's
    /// ranks fail over onto the surviving rails at degraded bandwidth
    /// (`rails / (rails − 1)` wire-time derate — the whole-iteration
    /// conservative model).
    RailDown { node: usize, rail: usize },
    /// Rank `rank` first slows by `factor` (a straggler), then dies at
    /// the event time — the straggler-escalates-to-dead scenario.
    StragglerDeath { rank: usize, factor: f64 },
}

/// One scheduled fault: what happens and when.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Injection time, µs of virtual iteration time.
    pub at_us: f64,
    pub kind: FaultKind,
}

/// A deterministic schedule of injected faults plus the shared
/// detection/recovery cost knobs.  See the module docs; the defaults
/// are deliberately round numbers in the RPC-stack regime (1 ms
/// detection timeout, 200 µs → ×2 exponential backoff over 3 retries,
/// 2 ms template rebuild, checkpointing off).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    /// Failure-detection window: time from the fault instant until the
    /// runtime declares the peer suspect, µs.
    pub detect_timeout_us: f64,
    /// First retry backoff wait, µs.
    pub backoff_base_us: f64,
    /// Multiplier between consecutive backoff waits.
    pub backoff_factor: f64,
    /// Bounded retry attempts before the peer is declared dead.
    pub max_retries: u32,
    /// Cost of rebuilding the collective template over the surviving
    /// world (or reassigning a dead server's shards), µs.
    pub rebuild_us: f64,
    /// Checkpoint period, µs; 0 disables checkpointing, making the
    /// lost work on a crash the full time since iteration start.
    pub checkpoint_period_us: f64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            events: Vec::new(),
            detect_timeout_us: 1_000.0,
            backoff_base_us: 200.0,
            backoff_factor: 2.0,
            max_retries: 3,
            rebuild_us: 2_000.0,
            checkpoint_period_us: 0.0,
        }
    }
}

impl FaultPlan {
    /// No injected faults?  The strategies branch on this *before*
    /// touching any fault machinery — the empty-plan bit-identity
    /// guarantee rests on it.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// A plan with a single rank crash at `at_us` (the canonical
    /// documented scenario).
    pub fn crash(rank: usize, at_us: f64) -> FaultPlan {
        FaultPlan {
            events: vec![FaultEvent { at_us, kind: FaultKind::RankCrash { rank } }],
            ..FaultPlan::default()
        }
    }

    /// The first crash-class event (rank crash or straggler death):
    /// `(time, dead rank, straggler factor)`.  At most one exists in a
    /// validated plan.
    pub fn first_crash(&self) -> Option<(SimTime, usize, Option<f64>)> {
        self.events.iter().find_map(|e| match e.kind {
            FaultKind::RankCrash { rank } => {
                Some((SimTime::from_us(e.at_us), rank, None))
            }
            FaultKind::StragglerDeath { rank, factor } => {
                Some((SimTime::from_us(e.at_us), rank, Some(factor)))
            }
            _ => None,
        })
    }

    /// All link-flap windows: `(start, node, rail, duration)`.
    pub fn flaps(&self) -> Vec<(SimTime, usize, usize, SimTime)> {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                FaultKind::LinkFlap { node, rail, for_us } => {
                    Some((SimTime::from_us(e.at_us), node, rail, SimTime::from_us(for_us)))
                }
                _ => None,
            })
            .collect()
    }

    /// All failed rails: `(node, rail)` (the failure time only gates
    /// detection accounting — the failover derate is modeled for the
    /// whole iteration, see [`FaultKind::RailDown`]).
    pub fn rail_downs(&self) -> Vec<(usize, usize)> {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                FaultKind::RailDown { node, rail } => Some((node, rail)),
                _ => None,
            })
            .collect()
    }

    /// Total backoff wait over the bounded retries:
    /// `Σ base·factor^i, i ∈ [0, max_retries)`, µs.
    pub fn backoff_total_us(&self) -> f64 {
        (0..self.max_retries)
            .map(|i| self.backoff_base_us * self.backoff_factor.powi(i as i32))
            .sum()
    }

    /// Lost work at a crash under the checkpoint model: time since the
    /// last completed checkpoint (the full elapsed time when the period
    /// is 0, i.e. checkpointing off).  A crash landing exactly on a
    /// checkpoint tick loses nothing — the residue is computed in the
    /// µs domain (the period's unit) and snapped within one clock tick
    /// of the boundary, so a period whose nanosecond conversion rounds
    /// cannot turn an on-tick crash into a full lost period.
    pub fn lost_work(&self, at: SimTime) -> SimTime {
        if self.checkpoint_period_us > 0.0 {
            let period = self.checkpoint_period_us;
            let at_us = at.as_us();
            let r = at_us - (at_us / period).floor() * period;
            // 1e-3 µs = one nanosecond, the clock's resolution
            let r = if r < 1e-3 || period - r < 1e-3 { 0.0 } else { r };
            SimTime::from_us(r)
        } else {
            at
        }
    }

    /// Validate the recovery knobs alone (surface-independent; part of
    /// `Scenario::validate`).
    pub fn validate_knobs(&self) -> Result<()> {
        ensure!(
            self.detect_timeout_us.is_finite() && self.detect_timeout_us >= 0.0,
            "fault detect timeout must be finite and >= 0 (got {})",
            self.detect_timeout_us
        );
        ensure!(
            self.backoff_base_us.is_finite() && self.backoff_base_us >= 0.0,
            "fault backoff base must be finite and >= 0 (got {})",
            self.backoff_base_us
        );
        ensure!(
            self.backoff_factor.is_finite() && self.backoff_factor >= 1.0,
            "fault backoff factor must be finite and >= 1 (got {})",
            self.backoff_factor
        );
        ensure!(self.max_retries <= 16, "at most 16 fault retries (got {})", self.max_retries);
        ensure!(
            self.rebuild_us.is_finite() && self.rebuild_us >= 0.0,
            "fault rebuild cost must be finite and >= 0 (got {})",
            self.rebuild_us
        );
        ensure!(
            self.checkpoint_period_us.is_finite() && self.checkpoint_period_us >= 0.0,
            "checkpoint period must be finite and >= 0 (got {})",
            self.checkpoint_period_us
        );
        for e in &self.events {
            ensure!(
                e.at_us.is_finite() && e.at_us >= 0.0,
                "fault event time must be finite and >= 0 (got {})",
                e.at_us
            );
            match e.kind {
                FaultKind::LinkFlap { for_us, .. } => {
                    ensure!(
                        for_us.is_finite() && for_us > 0.0,
                        "link flap duration must be finite and > 0 (got {for_us})"
                    );
                }
                FaultKind::StragglerDeath { factor, .. } => {
                    ensure!(
                        factor.is_finite() && factor > 1.0,
                        "straggler-death factor must be > 1 (got {factor})"
                    );
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Validate the plan against a concrete world and placement: ranks
    /// and `(node, rail)` ports must exist, a crash needs at least two
    /// survivors to rebuild a collective over, a rail failure needs a
    /// surviving rail to fail over to, and at most one crash-class event
    /// fits in one iteration.
    pub fn validate(&self, world: usize, place: &Placement) -> Result<()> {
        self.validate_knobs()?;
        let nodes = place.nodes_for(world);
        let mut crashes = 0usize;
        for e in &self.events {
            match e.kind {
                FaultKind::RankCrash { rank } | FaultKind::StragglerDeath { rank, .. } => {
                    ensure!(rank < world, "fault rank {rank} out of world {world}");
                    ensure!(
                        world >= 3,
                        "a rank crash needs world >= 3 (elastic rebuild over {} survivors)",
                        world.saturating_sub(1)
                    );
                    crashes += 1;
                }
                FaultKind::LinkFlap { node, rail, .. } => {
                    ensure!(node < nodes, "fault node {node} out of {nodes} nodes");
                    ensure!(rail < place.rails, "fault rail {rail} out of {} rails", place.rails);
                }
                FaultKind::RailDown { node, rail } => {
                    ensure!(node < nodes, "fault node {node} out of {nodes} nodes");
                    ensure!(rail < place.rails, "fault rail {rail} out of {} rails", place.rails);
                    ensure!(
                        place.rails >= 2,
                        "a rail failure needs >= 2 rails to fail over (got {})",
                        place.rails
                    );
                }
            }
        }
        ensure!(crashes <= 1, "at most one rank crash per iteration (got {crashes})");
        Ok(())
    }

    /// Seeded crash draw for the failure-rate × world sweep: with
    /// probability `rate` the plan contains one rank crash, uniformly
    /// placed in the middle 80% of `horizon_us` on a uniformly drawn
    /// rank.  Same `(world, rate, seed)` ⇒ same plan, bit-for-bit.
    pub fn seeded_crash(world: usize, rate: f64, horizon_us: f64, seed: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA17_0000 ^ (world as u64).wrapping_mul(0x9E37_79B9));
        let mut plan = FaultPlan::default();
        if world >= 3 && rng.next_f64() < rate {
            let rank = rng.next_below(world as u64) as usize;
            let at_us = horizon_us * (0.1 + 0.8 * rng.next_f64());
            plan.events.push(FaultEvent { at_us, kind: FaultKind::RankCrash { rank } });
        }
        plan
    }

    /// A single-crash plan carrying another plan's detection/recovery
    /// knobs — how the campaign layer turns one drawn arrival into the
    /// per-iteration plan the family recovery runners consume.  The
    /// per-iteration checkpoint model stays off: the campaign owns the
    /// checkpoint clock (§Robustness campaign).
    pub fn crash_with_knobs_of(knobs: &FaultPlan, rank: usize, at_us: f64) -> FaultPlan {
        FaultPlan {
            events: vec![FaultEvent { at_us, kind: FaultKind::RankCrash { rank } }],
            checkpoint_period_us: 0.0,
            ..knobs.clone()
        }
    }

    /// Parse a `;`-separated CLI fault spec.  Grammar (times in µs):
    ///
    /// ```text
    ///   crash@T:rN            rank N dies at T
    ///   die@T:rNxF            straggler (×F) rank N dies at T
    ///   flap@T:nN.lR+D        port (node N, rail R) dark for D from T
    ///   raildown@T:nN.lR      port (node N, rail R) failed (failover)
    /// ```
    pub fn parse_spec(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            plan.events.push(parse_event(part)?);
        }
        ensure!(!plan.is_empty(), "empty fault spec `{spec}`");
        Ok(plan)
    }
}

fn parse_event(part: &str) -> Result<FaultEvent> {
    let (head, rest) = part
        .split_once('@')
        .ok_or_else(|| anyhow!("fault event `{part}`: expected kind@time:target"))?;
    let (at, target) = rest
        .split_once(':')
        .ok_or_else(|| anyhow!("fault event `{part}`: expected kind@time:target"))?;
    let at_us: f64 =
        at.parse().map_err(|_| anyhow!("fault event `{part}`: bad time `{at}`"))?;
    let kind = match head {
        "crash" => FaultKind::RankCrash { rank: parse_rank(part, target)? },
        "die" => {
            let (r, f) = target
                .split_once('x')
                .ok_or_else(|| anyhow!("fault event `{part}`: expected rNxF"))?;
            let factor: f64 =
                f.parse().map_err(|_| anyhow!("fault event `{part}`: bad factor `{f}`"))?;
            FaultKind::StragglerDeath { rank: parse_rank(part, r)?, factor }
        }
        "flap" => {
            let (port, dur) = target
                .split_once('+')
                .ok_or_else(|| anyhow!("fault event `{part}`: expected nN.lR+D"))?;
            let (node, rail) = parse_port(part, port)?;
            let for_us: f64 =
                dur.parse().map_err(|_| anyhow!("fault event `{part}`: bad duration `{dur}`"))?;
            FaultKind::LinkFlap { node, rail, for_us }
        }
        "raildown" => {
            let (node, rail) = parse_port(part, target)?;
            FaultKind::RailDown { node, rail }
        }
        _ => {
            return Err(anyhow!(
                "fault event `{part}`: unknown kind `{head}` (want crash/die/flap/raildown)"
            ))
        }
    };
    Ok(FaultEvent { at_us, kind })
}

fn parse_rank(part: &str, s: &str) -> Result<usize> {
    s.strip_prefix('r')
        .and_then(|n| n.parse().ok())
        .ok_or_else(|| anyhow!("fault event `{part}`: expected rank `rN`, got `{s}`"))
}

fn parse_port(part: &str, s: &str) -> Result<(usize, usize)> {
    let parse = || {
        let (n, l) = s.split_once('.')?;
        let node = n.strip_prefix('n')?.parse().ok()?;
        let rail = l.strip_prefix('l')?.parse().ok()?;
        Some((node, rail))
    };
    parse().ok_or_else(|| anyhow!("fault event `{part}`: expected port `nN.lR`, got `{s}`"))
}

/// A sustained, seeded, rate-driven crash stream (§Robustness campaign):
/// the `seeded_crash` draw generalized from one iteration to a whole
/// training campaign.  Arrivals are a Poisson process on the campaign
/// clock at the *system* rate `world / mtbf_us` (per-rank exponential
/// lifetimes, memoryless, so the superposition is exponential too),
/// each arrival carrying a uniformly drawn victim rank.
///
/// Determinism contract: the k-th `pop` returns the same `(rank, time)`
/// for the same `(world, mtbf_us, seed)` regardless of *when* the
/// caller consumes it — arrival times are cumulative sums over a
/// private RNG, never functions of simulation state.  That is what
/// makes checkpoint policies comparable: every policy faces the same
/// crash schedule.
#[derive(Debug)]
pub struct FaultStream {
    rng: Rng,
    world: usize,
    /// Mean inter-arrival gap at the system level, µs.
    mean_gap_us: f64,
    /// Absolute campaign time of the next arrival, µs.
    next_us: f64,
}

impl FaultStream {
    /// `None` when `mtbf_us <= 0` — a fault-free campaign draws nothing
    /// (the empty-stream twin of the empty-plan guarantee).
    pub fn new(world: usize, mtbf_us: f64, seed: u64) -> Option<FaultStream> {
        if mtbf_us <= 0.0 || world == 0 {
            return None;
        }
        let mut s = FaultStream {
            rng: Rng::new(seed ^ 0xFA17_CA4E ^ (world as u64).wrapping_mul(0x9E37_79B9)),
            world,
            mean_gap_us: mtbf_us / world as f64,
            next_us: 0.0,
        };
        s.next_us = s.draw_gap();
        Some(s)
    }

    fn draw_gap(&mut self) -> f64 {
        // inverse-CDF exponential; next_f64 ∈ [0, 1) keeps ln finite
        -(1.0 - self.rng.next_f64()).ln() * self.mean_gap_us
    }

    /// Absolute time of the next arrival, µs (not yet consumed).
    pub fn peek_us(&self) -> f64 {
        self.next_us
    }

    /// Consume the next arrival: `(victim rank, absolute time µs)`.
    pub fn pop(&mut self) -> (usize, f64) {
        let at = self.next_us;
        let rank = self.rng.next_below(self.world as u64) as usize;
        self.next_us = at + self.draw_gap();
        (rank, at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_empty_and_valid() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        plan.validate(8, &Placement::new(2, 2)).expect("empty plan validates anywhere");
    }

    #[test]
    fn spec_grammar_round_trips_all_kinds() {
        let plan = FaultPlan::parse_spec(
            "crash@1500:r3; flap@200:n0.l1+350.5; raildown@0:n1.l0; die@900:r2x1.8",
        )
        .expect("spec parses");
        assert_eq!(plan.events.len(), 4);
        assert_eq!(
            plan.events[0],
            FaultEvent { at_us: 1500.0, kind: FaultKind::RankCrash { rank: 3 } }
        );
        assert_eq!(
            plan.events[1],
            FaultEvent {
                at_us: 200.0,
                kind: FaultKind::LinkFlap { node: 0, rail: 1, for_us: 350.5 }
            }
        );
        assert_eq!(
            plan.events[2],
            FaultEvent { at_us: 0.0, kind: FaultKind::RailDown { node: 1, rail: 0 } }
        );
        assert_eq!(
            plan.events[3],
            FaultEvent { at_us: 900.0, kind: FaultKind::StragglerDeath { rank: 2, factor: 1.8 } }
        );
    }

    #[test]
    fn spec_rejects_garbage() {
        for bad in [
            "",
            "crash",
            "crash@x:r0",
            "crash@100:3",
            "die@100:r3",
            "flap@100:n0.l1",
            "reboot@100:r0",
        ] {
            assert!(FaultPlan::parse_spec(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn validation_enforces_world_and_placement_bounds() {
        let place = Placement::new(2, 2);
        // one flap per (node, rail) of a 4-rank / 2-node world is fine
        FaultPlan::parse_spec("flap@10:n1.l1+5").unwrap().validate(4, &place).unwrap();
        // out-of-range rank / node / rail
        assert!(FaultPlan::crash(4, 10.0).validate(4, &place).is_err());
        assert!(FaultPlan::parse_spec("flap@10:n2.l0+5").unwrap().validate(4, &place).is_err());
        assert!(FaultPlan::parse_spec("flap@10:n0.l2+5").unwrap().validate(4, &place).is_err());
        // crash needs >= 3 ranks; raildown needs >= 2 rails
        assert!(FaultPlan::crash(0, 10.0).validate(2, &place).is_err());
        assert!(FaultPlan::crash(0, 10.0).validate(4, &place).is_ok());
        let one_rail = Placement::new(2, 1);
        assert!(FaultPlan::parse_spec("raildown@0:n0.l0")
            .unwrap()
            .validate(4, &one_rail)
            .is_err());
        // at most one crash-class event
        assert!(FaultPlan::parse_spec("crash@10:r0; die@20:r1x1.5")
            .unwrap()
            .validate(8, &place)
            .is_err());
    }

    #[test]
    fn knob_validation_rejects_degenerate_values() {
        let mut p = FaultPlan::crash(0, 10.0);
        p.backoff_factor = 0.5;
        assert!(p.validate_knobs().is_err());
        let mut p = FaultPlan::crash(0, 10.0);
        p.detect_timeout_us = f64::NAN;
        assert!(p.validate_knobs().is_err());
        let mut p = FaultPlan::crash(0, 10.0);
        p.max_retries = 99;
        assert!(p.validate_knobs().is_err());
        let mut p = FaultPlan::crash(0, 10.0);
        p.events[0].at_us = -5.0;
        assert!(p.validate_knobs().is_err());
    }

    #[test]
    fn backoff_and_lost_work_models() {
        let plan = FaultPlan {
            backoff_base_us: 100.0,
            backoff_factor: 2.0,
            max_retries: 3,
            ..FaultPlan::default()
        };
        assert!((plan.backoff_total_us() - 700.0).abs() < 1e-9); // 100+200+400
        // checkpointing off: everything since start is lost
        assert_eq!(plan.lost_work(SimTime::from_us(1234.0)), SimTime::from_us(1234.0));
        let ck = FaultPlan { checkpoint_period_us: 500.0, ..plan };
        assert_eq!(ck.lost_work(SimTime::from_us(1234.0)), SimTime::from_us(234.0));
    }

    #[test]
    fn seeded_crash_is_deterministic_and_rate_gated() {
        let a = FaultPlan::seeded_crash(16, 1.0, 50_000.0, 42);
        let b = FaultPlan::seeded_crash(16, 1.0, 50_000.0, 42);
        assert_eq!(a, b, "same (world, rate, seed) must yield the same plan");
        assert_eq!(a.events.len(), 1, "rate 1.0 always injects");
        assert!(FaultPlan::seeded_crash(16, 0.0, 50_000.0, 42).is_empty(), "rate 0 never does");
        assert!(FaultPlan::seeded_crash(2, 1.0, 50_000.0, 42).is_empty(), "tiny worlds skip");
        let c = FaultPlan::seeded_crash(16, 1.0, 50_000.0, 43);
        assert!(a != c || a.events == c.events, "plans are seed-dependent");
    }

    #[test]
    fn lost_work_is_zero_exactly_on_a_checkpoint_tick() {
        // a period whose nanosecond conversion rounds (444.5 µs → 445 ns
        // per 0.4445 µs scale model: here 444.5 µs → 444_500 ns exact,
        // so use a sub-ns fractional period to exercise the rounding)
        let ck = FaultPlan { checkpoint_period_us: 0.4445, ..FaultPlan::default() };
        // exactly on the 2nd tick (0.889 µs): zero lost work, not a
        // full period — the integer-ns modulo of the rounded period
        // (889 % 445 = 444 ns) used to report ~a whole period lost
        assert_eq!(ck.lost_work(SimTime::from_us(0.889)), SimTime::ZERO);
        // and a round-number period behaves classically at its tick
        let ck = FaultPlan { checkpoint_period_us: 500.0, ..FaultPlan::default() };
        assert_eq!(ck.lost_work(SimTime::from_us(1000.0)), SimTime::ZERO, "on the tick");
        assert_eq!(
            ck.lost_work(SimTime::from_us(999.0)),
            SimTime::from_us(499.0),
            "just before the tick: almost a full period since the previous checkpoint"
        );
        assert_eq!(
            ck.lost_work(SimTime::from_us(1001.0)),
            SimTime::from_us(1.0),
            "just after the tick: only the overhang"
        );
    }

    #[test]
    fn fault_stream_is_seed_deterministic_and_strictly_increasing() {
        let mut a = FaultStream::new(8, 100_000.0, 7).expect("stream");
        let mut b = FaultStream::new(8, 100_000.0, 7).expect("stream");
        let da: Vec<(usize, f64)> = (0..16).map(|_| a.pop()).collect();
        let db: Vec<(usize, f64)> = (0..16).map(|_| b.pop()).collect();
        assert_eq!(da, db, "same (world, mtbf, seed) ⇒ same arrival schedule");
        let mut last = 0.0;
        for &(rank, at) in &da {
            assert!(rank < 8);
            assert!(at > last, "arrivals strictly increase");
            last = at;
        }
        // mean gap sanity: system rate is world/mtbf
        let mean = da.last().unwrap().1 / 16.0;
        assert!(mean > 2_000.0 && mean < 60_000.0, "mean gap {mean} out of regime");
        assert!(FaultStream::new(8, 0.0, 7).is_none(), "mtbf 0 = fault-free");
        let mut c = FaultStream::new(8, 100_000.0, 8).expect("stream");
        assert!(c.pop() != da[0] || c.pop() != da[1], "seed-dependent");
    }
}
