//! Event queue + FIFO resources — the core of the cluster simulator.
//!
//! Events are *typed* (§Perf): the queue entry carries an [`EventKind`]
//! ordered by (time, sequence) — the sequence number makes simultaneous
//! events fire in scheduling order, which is what makes whole-cluster
//! runs bit-reproducible.  The queue itself is a calendar bucket queue
//! ([`CalendarQueue`], §Scale): O(1) amortized per event instead of the
//! old `BinaryHeap`'s O(log n), with the identical (time, seq) pop order.
//!
//! A **stream-lane set** ([`Engine::lane_set`]) is the typed overlap
//! scheduler (§Overlap): jobs release at known times, round-robin across
//! `streams` lanes with an in-flight depth cap, and every hand-off —
//! release, launch, completion — is a typed event or [`OnDone`]
//! completion, so the fusion-buffer loop schedules zero boxed closures.
//! `streams = 1` is exactly the old comm-thread gate discipline.
//!
//! The hot-path primitives (op-program steps,
//! gate grants, join firings, lane releases/launches) schedule `Copy`
//! variants, so steady-state
//! event traffic allocates nothing on the heap; `Call` is the rare
//! fallback for arbitrary closures (setup events, strategy callbacks).
//! One-shot state (op programs) lives in a slab with a generational
//! free-list, so slots recycle instead of growing per collective.
//!
//! `Resource` models a serialized server (a NIC, a PCIe link, a single
//! gRPC service thread): `serve()` requests are queued FIFO and each
//! occupies the resource for `bytes / rate` — this is how parameter-server
//! fan-in congestion and the single-threaded gRPC+MPI bottleneck (paper
//! §VI-D) arise in the model.

use std::collections::VecDeque;
use std::rc::Rc;

use super::calq::CalendarQueue;
use super::time::SimTime;
use super::trace::{SpanKind, Tracer};

/// A boxed engine callback — the *fallback* event payload (and the
/// storage form of gate waiters, join actions and program completions,
/// which are allocated once per collective/node, not once per event).
pub type Action = Box<dyn FnOnce(&mut Engine)>;

/// One resolved step of an event program (see [`Engine::run_program`]):
/// occupy `on` FIFO — or elapse as uncontended delay when `None` — for
/// `us` microseconds.  Durations stay in f64 µs so callers can apply
/// overlay scale factors *before* the ns conversion, bit-identically to
/// scaling the source op list.
#[derive(Debug, Clone, Copy)]
pub struct ProgStep {
    pub us: f64,
    pub on: Option<ResourceId>,
}

/// Typed event payload.  Hot-path variants are `Copy`; only `Call`
/// carries an allocation (made by the caller, once, for an arbitrary
/// closure).  The ordering of the heap ignores the payload entirely.
enum EventKind {
    /// Rare fallback: an arbitrary boxed closure ([`Engine::at`]).
    Call(Action),
    /// A join whose final `arrive` happened: fire its stored action.
    FireJoin(JoinId),
    /// A gate grant: run the front waiter of the gate.
    Grant(GateId),
    /// Advance program `slot` (stale generations are a wiring bug).
    Prog { slot: u32, gen: u32 },
    /// A released stream-lane job joining its lane's queue.
    LaneArrive { set: u32, job: u32 },
    /// A stream-lane job's launch turn: dispatch into the set's driver.
    LaneLaunch { set: u32, job: u32 },
    /// A cancellable timer firing.  Unlike `Prog`, a stale generation is
    /// *not* a bug: it is the tombstone of an O(1) [`Engine::cancel_timer`]
    /// (the calendar queue has no removal, so cancelled timers are
    /// discarded at pop time instead).
    Timer { slot: u32, gen: u32 },
}

/// Handle to a FIFO-serialized resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ResourceId(usize);

impl ResourceId {
    /// Slab index of this resource — stable for the engine's lifetime.
    /// Resources created consecutively have consecutive indices, which is
    /// the contiguity the graph layer's rank-offset program views
    /// ([`Engine::run_program_shifted`]) rely on (§Scale).
    pub fn index(self) -> usize {
        self.0
    }

    /// The inverse of [`ResourceId::index`], for the trace report builder
    /// walking the engine's service ledgers by slab index.
    pub(crate) fn from_index(i: usize) -> ResourceId {
        ResourceId(i)
    }
}

/// The unified service ledger of a FIFO resource, gate, or lane set
/// (§Observability): one struct consumed by both the utilization rows in
/// `IterationReport` and the trace attribution report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceStats {
    /// Requests served (resource), grants (gate), or launches (lane set).
    pub served: u64,
    /// Cumulative busy / held time.
    pub busy: SimTime,
}

struct ResourceState {
    /// Bytes per microsecond (i.e. MB/s / 1e... we keep it as bytes/us).
    rate_bytes_per_us: f64,
    /// Per-service fixed overhead.
    overhead: SimTime,
    busy_until: SimTime,
    served: u64,
    busy_time: SimTime,
}

/// Handle to a FIFO gate (see [`Engine::gate`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GateId(usize);

/// Handle to a dependency join (see [`Engine::join`]).  Generational:
/// join slots recycle once fired, and a stale handle is a detected bug
/// rather than silent corruption.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct JoinId {
    slot: u32,
    gen: u32,
}

/// A join is the *eligibility* primitive of dependency-graph scheduling:
/// its action fires once all `count` predecessors have called `arrive`.
/// Unlike resources/gates it carries no occupancy — eligibility and FIFO
/// queueing are deliberately separate (a `CommGraph` node first becomes
/// eligible here, then its ops queue on per-rank resources).
struct JoinState {
    gen: u32,
    remaining: usize,
    action: Option<OnDone>,
}

/// Handle to a cancellable timer (see [`Engine::timer_at`]).  Generational
/// like [`JoinId`]: slots recycle after firing or cancellation, and a
/// stale handle cancels nothing (returns `false`) instead of corrupting
/// an unrelated timer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TimerId {
    slot: u32,
    gen: u32,
}

/// A pending timer: the action to run at the deadline (or `None` for a
/// pure occupancy completion, see [`Engine::hold`]).
struct TimerState {
    gen: u32,
    action: Option<OnDone>,
}

/// Handle to an in-flight op program (see [`Engine::run_program_shifted`]).
/// Generational: once the program completes (or its abort drains), the
/// slot recycles and the handle goes stale — [`Engine::abort_program`] on
/// a stale handle is a no-op returning `false`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ProgId {
    slot: u32,
    gen: u32,
}

/// A typed completion: either a boxed callback (the general case) or a
/// stream-lane job completion routed to [`Engine::lane_done`].  Programs
/// and joins store one of these, so the fusion-overlap hot path — where
/// every completion is a lane hand-off — finishes collectives without a
/// boxed `done` per buffer.
pub enum OnDone {
    Call(Action),
    Lane(LaneSetId, u32),
    /// A registered [`EngineHook`] invoked with an argument — the
    /// shared-plan executors (§Scale) complete thousands of node
    /// programs through one hook registration instead of one boxed
    /// closure per node.
    Hook(HookId, u32),
}

impl OnDone {
    fn run(self, e: &mut Engine) {
        match self {
            OnDone::Call(a) => a(e),
            OnDone::Lane(set, job) => e.lane_done(set, job),
            OnDone::Hook(h, arg) => {
                let hook = e.hooks[h.0].clone();
                hook.done(e, arg);
            }
        }
    }
}

/// Handle to a registered completion hook (see [`Engine::hook`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HookId(usize);

/// A reusable typed completion target: `done` is called with the `u32`
/// argument carried by the [`OnDone::Hook`] that completed.  One
/// registration serves any number of completions, so graph executors can
/// route every node finish through a single shared-state object.
pub trait EngineHook {
    fn done(&self, e: &mut Engine, arg: u32);
}

/// Handle to a stream-lane set (see [`Engine::lane_set`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LaneSetId(pub(crate) usize);

/// What a lane set launches when a job's turn comes.  The engine
/// dispatches typed [`EventKind::LaneLaunch`] events into this, so
/// per-job scheduling allocates nothing — the driver itself is one
/// allocation per set (per iteration), not per job.
pub trait LaneDriver {
    /// Launch job `job` of `set` on the engine.  The work this starts
    /// must eventually call [`Engine::lane_done`] (directly or through a
    /// typed [`OnDone::Lane`] completion) exactly once for `job`.
    fn launch(&self, e: &mut Engine, set: LaneSetId, job: u32);
}

/// The canonical typed gate-holder driver: each lane job is one resolved
/// op program, launched with a typed lane completion.  This is what
/// replaced the boxed gate waiters of the serialized comm-thread path —
/// the "typed gate-holder programs" §Perf follow-up.
pub struct ProgramLanes {
    progs: Vec<Rc<[ProgStep]>>,
}

impl ProgramLanes {
    pub fn new(progs: Vec<Rc<[ProgStep]>>) -> ProgramLanes {
        ProgramLanes { progs }
    }
}

impl LaneDriver for ProgramLanes {
    fn launch(&self, e: &mut Engine, set: LaneSetId, job: u32) {
        e.run_program_lane(self.progs[job as usize].clone(), set, job);
    }
}

/// One stream-lane set: `width` logical comm streams (lanes) over one
/// FIFO discipline.  Jobs release onto their lane (`job % width`,
/// round-robin — NCCL-stream assignment), each lane serializes its own
/// jobs, different lanes interleave freely on whatever resources the
/// launched work occupies, and at most `depth` jobs are in flight across
/// the set (the queue-depth cap).  `width = 1` is exactly the comm-thread
/// gate: one job at a time, FIFO hand-off at max(release, previous
/// completion).
struct LaneSetState {
    width: usize,
    depth: usize,
    driver: Rc<dyn LaneDriver>,
    lane_busy: Vec<bool>,
    lane_acquired: Vec<SimTime>,
    /// Released-but-not-launched jobs, one FIFO per lane (arrival order).
    pending: Vec<VecDeque<u32>>,
    in_flight: usize,
    launches: u64,
    busy_time: SimTime,
    completed: usize,
    last_done: SimTime,
}

/// A gate is a FIFO mutex with a virtual-clock ledger: `acquire` runs the
/// action once the gate is free (waiters queue in arrival order), and the
/// holder must `release` explicitly.  Unlike `Resource`, the hold time is
/// *open-ended* — it spans whatever chain of events the holder schedules
/// before releasing.  This models Horovod's background communication
/// thread: the thread is occupied for the full coordination + Allreduce
/// of one fusion buffer, however long the buffer's schedule takes on the
/// contended links.
struct GateState {
    busy: bool,
    waiters: VecDeque<Action>,
    acquired_at: SimTime,
    grants: u64,
    busy_time: SimTime,
}

/// One in-flight op program: a shared immutable step list (a template
/// resolution — the `Rc` is a clone, not a rebuild), a cursor, and the
/// completion to run after the last step.  Slots recycle through
/// `prog_free` with a generation bump.
struct ProgState {
    gen: u32,
    next: u32,
    /// Added to every pinned step's resource index (§Scale): rank-relative
    /// shared plans store rank-0 pins and shift per rank at launch.
    offset: u32,
    steps: Rc<[ProgStep]>,
    done: Option<OnDone>,
    /// Abort tombstone ([`Engine::abort_program`]): the next program
    /// event drains the slot without firing `done` or occupying anything
    /// further.  The in-flight step finishes service first — occupancy
    /// is non-preemptive FIFO.
    cancelled: bool,
}

/// Discrete-event engine with a virtual clock.
#[derive(Default)]
pub struct Engine {
    now: SimTime,
    seq: u64,
    queue: CalendarQueue<EventKind>,
    resources: Vec<ResourceState>,
    gates: Vec<GateState>,
    joins: Vec<JoinState>,
    join_free: Vec<u32>,
    progs: Vec<ProgState>,
    prog_free: Vec<u32>,
    lanes: Vec<LaneSetState>,
    hooks: Vec<Rc<dyn EngineHook>>,
    timers: Vec<TimerState>,
    timer_free: Vec<u32>,
    /// One event popped past a [`Engine::run_until`] deadline, replayed
    /// by the next run call (the calendar queue has no peek).
    stashed: Option<(SimTime, u64, EventKind)>,
    executed: u64,
    /// The optional span recorder (§Observability).  `None` in normal
    /// runs: every instrumentation point is one branch on this option,
    /// records pure observations only (no events, no sequence numbers),
    /// and therefore cannot perturb a pin.
    tracer: Option<Box<Tracer>>,
}

impl Engine {
    pub fn new() -> Self {
        let mut e = Engine::default();
        if super::trace::enabled() {
            e.tracer = Some(Box::new(Tracer::new()));
        }
        e
    }

    /// Is this engine recording trace spans?
    pub fn tracing(&self) -> bool {
        self.tracer.is_some()
    }

    /// Register a resource's trace identity (track name, span kind,
    /// Chrome pid, owning rank/node).  No-op when tracing is off, so
    /// installers call it behind `if e.tracing()` purely to skip the
    /// name formatting.
    pub fn trace_resource(
        &mut self,
        r: ResourceId,
        kind: SpanKind,
        pid: u32,
        rank: u32,
        name: &str,
    ) {
        if let Some(t) = self.tracer.as_deref_mut() {
            crate::log_trace!("trace: resource {} is `{name}` ({})", r.0, kind.name());
            t.name_resource(r.0, kind, pid, rank, name);
        }
    }

    /// Detach the tracer (for report building after a run).
    pub fn take_trace(&mut self) -> Option<Box<Tracer>> {
        let t = self.tracer.take();
        if let Some(t) = &t {
            crate::log_trace!("trace: detached recorder with {} spans", t.spans().len());
        }
        t
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far (per-run metric; also the §Perf
    /// events/s denominator).
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Register a reusable completion hook; the returned handle is valid
    /// for the engine's lifetime and can back any number of
    /// [`OnDone::Hook`] completions.
    pub fn hook(&mut self, hook: Rc<dyn EngineHook>) -> HookId {
        self.hooks.push(hook);
        HookId(self.hooks.len() - 1)
    }

    /// High-water mark of outstanding events in the calendar queue.
    pub fn queue_peak(&self) -> usize {
        self.queue.peak_len()
    }

    /// Approximate peak engine memory (§Scale bench reporting): the
    /// calendar queue at its high-water mark plus the live state slabs.
    pub fn approx_slab_bytes(&self) -> usize {
        use std::mem::size_of;
        self.queue.approx_peak_bytes()
            + self.resources.capacity() * size_of::<ResourceState>()
            + self.joins.capacity() * size_of::<JoinState>()
            + self.progs.capacity() * size_of::<ProgState>()
            + self.gates.capacity() * size_of::<GateState>()
            + self.lanes.capacity() * size_of::<LaneSetState>()
            + self.timers.capacity() * size_of::<TimerState>()
    }

    /// The allocation-free scheduling primitive every typed path uses.
    fn push_event(&mut self, at: SimTime, kind: EventKind) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(at, seq, kind);
        if self.tracer.is_some() {
            self.trace_depth();
        }
    }

    /// Out-of-line tracer hookup for [`Engine::push_event`]: sample the
    /// calendar queue when it reaches a new high-water mark.
    #[cold]
    fn trace_depth(&mut self) {
        let (len, grew) = (self.queue.len(), self.queue.at_peak());
        let now = self.now;
        if let Some(t) = self.tracer.as_deref_mut() {
            if grew {
                t.sample_depth(now, len);
            }
        }
    }

    /// Schedule `action` at absolute time `at` (>= now).
    pub fn at(&mut self, at: SimTime, action: impl FnOnce(&mut Engine) + 'static) {
        self.push_event(at, EventKind::Call(Box::new(action)));
    }

    /// Schedule `action` after a delay.
    pub fn after(&mut self, dt: SimTime, action: impl FnOnce(&mut Engine) + 'static) {
        self.at(self.now + dt, action);
    }

    /// The next event to execute: the [`Engine::run_until`] stash first,
    /// then the calendar queue.
    fn next_event(&mut self) -> Option<(SimTime, u64, EventKind)> {
        self.stashed.take().or_else(|| self.queue.pop())
    }

    fn dispatch(&mut self, kind: EventKind) {
        match kind {
            EventKind::Call(action) => action(self),
            EventKind::FireJoin(j) => self.fire_join(j),
            EventKind::Grant(g) => self.fire_grant(g),
            EventKind::Prog { slot, gen } => {
                // a real assert (one u32 compare): a stale handle must
                // be a detected bug in release builds too, never a
                // silently-advanced recycled program
                assert_eq!(self.progs[slot as usize].gen, gen, "stale program event");
                self.advance_program(slot);
            }
            EventKind::LaneArrive { set, job } => self.lane_arrive(set as usize, job),
            EventKind::LaneLaunch { set, job } => {
                let driver = self.lanes[set as usize].driver.clone();
                driver.launch(self, LaneSetId(set as usize), job);
            }
            EventKind::Timer { slot, gen } => {
                let st = &mut self.timers[slot as usize];
                // stale generation = a cancelled timer's tombstone: skip
                if st.gen == gen {
                    let action = st.action.take();
                    st.gen = st.gen.wrapping_add(1);
                    self.timer_free.push(slot);
                    if let Some(a) = action {
                        a.run(self);
                    }
                }
            }
        }
    }

    /// Run until the event queue drains; returns the final clock.
    pub fn run(&mut self) -> SimTime {
        while let Some((at, _seq, kind)) = self.next_event() {
            self.now = at;
            self.executed += 1;
            self.dispatch(kind);
        }
        self.now
    }

    /// [`Engine::run`] with an event-budget watchdog (§Robustness chaos
    /// invariant: the queue must drain).  Executes identically to `run`
    /// — same order, same clock — but errors out once more than
    /// `budget` events execute in this call, turning a scheduling
    /// livelock (events re-arming events forever) into a diagnosable
    /// failure instead of a hang.
    pub fn run_budgeted(&mut self, budget: u64) -> crate::util::error::Result<SimTime> {
        let start = self.executed;
        while let Some((at, _seq, kind)) = self.next_event() {
            self.now = at;
            self.executed += 1;
            crate::ensure!(
                self.executed - start <= budget,
                "event-queue watchdog tripped: {budget} events executed without draining \
                 (clock {}) — scheduling livelock",
                self.now
            );
            self.dispatch(kind);
        }
        Ok(self.now)
    }

    /// Run until the event queue drains *or* the next event lies past
    /// `deadline` — that event is stashed and replayed by the next
    /// run call, so pausing is exact and order-preserving.  The clock
    /// advances to `deadline` (the fault-injection cut point) even when
    /// the queue drains early.  Returns the clock.
    pub fn run_until(&mut self, deadline: SimTime) -> SimTime {
        while let Some((at, seq, kind)) = self.next_event() {
            if at > deadline {
                self.stashed = Some((at, seq, kind));
                break;
            }
            self.now = at;
            self.executed += 1;
            self.dispatch(kind);
        }
        self.now = self.now.max(deadline);
        self.now
    }

    /// Drop every pending event (including the [`Engine::run_until`]
    /// stash).  This is the fault cut: in-flight programs, joins and
    /// timers whose events are dropped simply never advance — their
    /// slots are abandoned, which is fine for the remainder of one
    /// iteration.  Ledgers (busy time, served counts) keep what already
    /// happened.
    pub fn clear_pending(&mut self) {
        self.stashed = None;
        self.queue.clear();
    }

    /// Define a FIFO resource with service rate `bytes_per_us` and fixed
    /// per-request `overhead`.
    pub fn resource(&mut self, bytes_per_us: f64, overhead: SimTime) -> ResourceId {
        assert!(bytes_per_us > 0.0);
        self.resources.push(ResourceState {
            rate_bytes_per_us: bytes_per_us,
            overhead,
            busy_until: SimTime::ZERO,
            served: 0,
            busy_time: SimTime::ZERO,
        });
        ResourceId(self.resources.len() - 1)
    }

    /// Rate-derived transfer time of `bytes` on `r`, *excluding* the
    /// fixed overhead — the single formula [`Engine::serve`] and
    /// [`Engine::peek_completion`] both consult, so the analytic shortcut
    /// cannot drift from the served path.
    fn transfer_time(&self, r: ResourceId, bytes: f64) -> SimTime {
        SimTime::from_us(bytes / self.resources[r.0].rate_bytes_per_us)
    }

    /// Shared enqueue accounting of every FIFO request (`serve`,
    /// `serve_for`, program steps): start at max(busy_until, now), occupy
    /// for `dur` plus the resource's fixed overhead, schedule `kind` at
    /// completion.
    fn occupy(&mut self, r: ResourceId, dur: SimTime, bytes: f64, kind: EventKind) {
        let (start, end) = {
            let state = &mut self.resources[r.0];
            let service = dur + state.overhead;
            let start = state.busy_until.max(self.now);
            let end = start + service;
            state.busy_until = end;
            state.served += 1;
            state.busy_time += service;
            (start, end)
        };
        if self.tracer.is_some() {
            self.trace_serve(r, start, end, bytes);
        }
        self.push_event(end, kind);
    }

    /// Out-of-line tracer hookup for [`Engine::occupy`]: the request
    /// arrived *now* and is served `[start, end]` — `start - now` is the
    /// queue wait, the split the attribution report is built on.
    #[cold]
    fn trace_serve(&mut self, r: ResourceId, start: SimTime, end: SimTime, bytes: f64) {
        let arrival = self.now;
        if let Some(t) = self.tracer.as_deref_mut() {
            t.record_serve(r.0, arrival, start, end, bytes);
        }
    }

    /// Enqueue a `bytes`-sized request on resource `r`; `done` fires when
    /// the request finishes service (FIFO order, serialized).
    pub fn serve(&mut self, r: ResourceId, bytes: f64, done: impl FnOnce(&mut Engine) + 'static) {
        let dur = self.transfer_time(r, bytes);
        self.occupy(r, dur, bytes, EventKind::Call(Box::new(done)));
    }

    /// A serialized resource with no rate semantics: requests occupy it
    /// for an explicit duration (see [`Engine::serve_for`]).  The `CommOp`
    /// replay layer uses these — durations come from the validated cost
    /// models, queueing/contention from the FIFO here.
    pub fn unit_resource(&mut self) -> ResourceId {
        self.resource(1.0, SimTime::ZERO)
    }

    /// Enqueue a request occupying resource `r` for exactly `dur` (plus
    /// the resource's fixed overhead); `done` fires at completion.  FIFO
    /// with respect to `serve` requests on the same resource.
    pub fn serve_for(&mut self, r: ResourceId, dur: SimTime, done: impl FnOnce(&mut Engine) + 'static) {
        self.occupy(r, dur, 0.0, EventKind::Call(Box::new(done)));
    }

    /// Run an op program: step *i+1* starts when step *i* finishes
    /// service (each step queues FIFO on its resource, or elapses as a
    /// pure delay), and `done` runs synchronously after the last step —
    /// exactly the old closure-chain `replay` semantics, with one typed
    /// `Copy` event per step instead of one boxed closure per step.  An
    /// empty program runs `done` immediately.
    pub fn run_program(&mut self, steps: Rc<[ProgStep]>, done: Action) -> ProgId {
        self.run_program_with(steps, OnDone::Call(done))
    }

    /// [`Engine::run_program`] with a typed lane completion: the program
    /// IS lane job `job` of `set`, and finishing it hands the lane back
    /// ([`Engine::lane_done`]) without a boxed closure.
    pub fn run_program_lane(&mut self, steps: Rc<[ProgStep]>, set: LaneSetId, job: u32) -> ProgId {
        self.run_program_with(steps, OnDone::Lane(set, job))
    }

    /// Run an op program with an arbitrary typed completion.
    pub fn run_program_with(&mut self, steps: Rc<[ProgStep]>, done: OnDone) -> ProgId {
        self.run_program_shifted(steps, 0, done)
    }

    /// [`Engine::run_program_with`] through a *rank-offset view* (§Scale):
    /// every pinned step occupies the resource at index
    /// `step.on.index() + offset` instead of `step.on` itself.  A shared
    /// rank-relative plan resolves its programs once against rank 0's
    /// resources and replays them for rank `r` with `offset = r` — valid
    /// because [`GraphResources`](crate::comm::GraphResources) installs
    /// each resource kind as one contiguous per-rank run.
    /// Returns a [`ProgId`] usable with [`Engine::abort_program`]; for a
    /// program that completes synchronously (empty step list) the handle
    /// is already stale by the time it is returned.
    pub fn run_program_shifted(&mut self, steps: Rc<[ProgStep]>, offset: u32, done: OnDone) -> ProgId {
        let slot = match self.prog_free.pop() {
            Some(s) => {
                let st = &mut self.progs[s as usize];
                st.steps = steps;
                st.next = 0;
                st.offset = offset;
                st.done = Some(done);
                st.cancelled = false;
                s
            }
            None => {
                self.progs.push(ProgState {
                    gen: 0,
                    next: 0,
                    offset,
                    steps,
                    done: Some(done),
                    cancelled: false,
                });
                (self.progs.len() - 1) as u32
            }
        };
        let id = ProgId { slot, gen: self.progs[slot as usize].gen };
        self.advance_program(slot);
        id
    }

    /// Abort an in-flight program: its current step finishes service
    /// (occupancy is non-preemptive FIFO), then the slot drains and
    /// recycles *without* firing `done` and without occupying anything
    /// further — the refund of the not-yet-enqueued remainder.  Stale
    /// handles (program already completed) return `false`.
    pub fn abort_program(&mut self, p: ProgId) -> bool {
        let st = &mut self.progs[p.slot as usize];
        if st.gen != p.gen {
            return false;
        }
        st.cancelled = true;
        true
    }

    fn advance_program(&mut self, slot: u32) {
        if self.progs[slot as usize].cancelled {
            // abort drain: recycle the slot, never fire `done`
            let st = &mut self.progs[slot as usize];
            st.cancelled = false;
            st.done = None;
            st.steps = Vec::new().into();
            st.gen = st.gen.wrapping_add(1);
            self.prog_free.push(slot);
            return;
        }
        let next = {
            let st = &mut self.progs[slot as usize];
            let i = st.next as usize;
            if i < st.steps.len() {
                st.next += 1;
                Some((st.steps[i], st.gen, st.offset))
            } else {
                None
            }
        };
        match next {
            Some((step, gen, offset)) => {
                let kind = EventKind::Prog { slot, gen };
                match step.on {
                    Some(r) => {
                        let r = ResourceId(r.0 + offset as usize);
                        self.occupy(r, SimTime::from_us(step.us), 0.0, kind)
                    }
                    None => {
                        let at = self.now + SimTime::from_us(step.us);
                        if self.tracer.is_some() {
                            self.trace_delay(slot, at);
                        }
                        self.push_event(at, kind)
                    }
                }
            }
            None => {
                let done = {
                    let st = &mut self.progs[slot as usize];
                    let done = st.done.take().expect("program finished twice");
                    st.gen = st.gen.wrapping_add(1);
                    done
                };
                self.prog_free.push(slot);
                done.run(self);
            }
        }
    }

    /// Out-of-line tracer hookup for unpinned program steps: the delay
    /// elapses `[now, until]` on program slot `slot`'s track.
    #[cold]
    fn trace_delay(&mut self, slot: u32, until: SimTime) {
        let now = self.now;
        if let Some(t) = self.tracer.as_deref_mut() {
            t.record_delay(slot, now, until);
        }
    }

    /// Create a FIFO gate (open, no waiters).
    pub fn gate(&mut self) -> GateId {
        self.gates.push(GateState {
            busy: false,
            waiters: VecDeque::new(),
            acquired_at: SimTime::ZERO,
            grants: 0,
            busy_time: SimTime::ZERO,
        });
        GateId(self.gates.len() - 1)
    }

    /// Run `action` once gate `g` is free, holding it until `release`.
    /// Waiters are granted in arrival order; a grant fires through the
    /// event heap so ties stay deterministic.
    pub fn acquire(&mut self, g: GateId, action: impl FnOnce(&mut Engine) + 'static) {
        let now = self.now;
        let granted = {
            let st = &mut self.gates[g.0];
            st.waiters.push_back(Box::new(action));
            if st.busy {
                false
            } else {
                st.busy = true;
                st.acquired_at = now;
                st.grants += 1;
                true
            }
        };
        if granted {
            self.push_event(now, EventKind::Grant(g));
        }
    }

    /// Release gate `g`, granting the next waiter (if any) at the current
    /// virtual time.
    pub fn release(&mut self, g: GateId) {
        let now = self.now;
        let (grant, acquired_at) = {
            let st = &mut self.gates[g.0];
            debug_assert!(st.busy, "release of a free gate");
            let acquired_at = st.acquired_at;
            st.busy_time += now.saturating_sub(acquired_at);
            let grant = if st.waiters.is_empty() {
                st.busy = false;
                false
            } else {
                st.acquired_at = now;
                st.grants += 1;
                true
            };
            (grant, acquired_at)
        };
        if let Some(t) = self.tracer.as_deref_mut() {
            t.record_gate(g.0 as u32, acquired_at, now);
        }
        if grant {
            self.push_event(now, EventKind::Grant(g));
        }
    }

    fn fire_grant(&mut self, g: GateId) {
        // waiters only leave the queue here, and at most one grant event
        // is in flight per gate, so the front waiter at grant-schedule
        // time is still the front now.
        let action = self.gates[g.0].waiters.pop_front().expect("grant with no waiter");
        action(self);
    }

    /// Gate utilization: grants so far + cumulative held time.
    pub fn gate_stats(&self, g: GateId) -> ServiceStats {
        let st = &self.gates[g.0];
        ServiceStats { served: st.grants, busy: st.busy_time }
    }

    /// Create a stream-lane set: `streams` logical lanes, at most `depth`
    /// jobs in flight across them, launching through `driver`.  Jobs are
    /// assigned to lanes round-robin by index (`job % streams`); each
    /// lane serializes its own jobs in release order, distinct lanes
    /// interleave.  `streams = 1, depth = 1` reproduces a FIFO gate
    /// bit-for-bit: same grant times, same hand-off order, same event
    /// count — which is what keeps every serialized-era pin standing.
    pub fn lane_set(
        &mut self,
        streams: usize,
        depth: usize,
        driver: Rc<dyn LaneDriver>,
    ) -> LaneSetId {
        assert!(streams >= 1, "a lane set needs at least one stream");
        assert!(depth >= 1, "a lane set needs an in-flight depth of at least one");
        self.lanes.push(LaneSetState {
            width: streams,
            depth,
            driver,
            lane_busy: vec![false; streams],
            lane_acquired: vec![SimTime::ZERO; streams],
            pending: vec![VecDeque::new(); streams],
            in_flight: 0,
            launches: 0,
            busy_time: SimTime::ZERO,
            completed: 0,
            last_done: SimTime::ZERO,
        });
        LaneSetId(self.lanes.len() - 1)
    }

    /// Release lane job `job` of `set` at virtual time `at` (>= now):
    /// the job joins its lane's queue then and launches as soon as the
    /// lane is free and the set is under its depth cap.  One typed event
    /// per release — the overlap hot path's replacement for the old
    /// boxed ready-time closure + gate waiter pair.
    pub fn lane_submit(&mut self, set: LaneSetId, at: SimTime, job: u32) {
        debug_assert!(set.0 < self.lanes.len(), "submit to an unknown lane set");
        self.push_event(at, EventKind::LaneArrive { set: set.0 as u32, job });
    }

    fn lane_arrive(&mut self, set: usize, job: u32) {
        let now = self.now;
        if let Some(t) = self.tracer.as_deref_mut() {
            t.lane_arrived(set as u32, job, now);
        }
        let lane = job as usize % self.lanes[set].width;
        self.lanes[set].pending[lane].push_back(job);
        self.lane_try_launch(set);
    }

    /// Launch every currently launchable job of `set`: smallest released
    /// job index whose lane is free, while the depth cap allows.  The
    /// launch itself fires through the event heap (like a gate grant),
    /// so simultaneous launches keep deterministic FIFO tie order.
    fn lane_try_launch(&mut self, set: usize) {
        loop {
            let now = self.now;
            let job = {
                let st = &mut self.lanes[set];
                if st.in_flight >= st.depth {
                    break;
                }
                let mut pick: Option<(usize, u32)> = None;
                for (lane, q) in st.pending.iter().enumerate() {
                    if st.lane_busy[lane] {
                        continue;
                    }
                    if let Some(&j) = q.front() {
                        // (map_or, not is_none_or: the crate's MSRV is 1.79)
                        if pick.map_or(true, |(_, pj)| j < pj) {
                            pick = Some((lane, j));
                        }
                    }
                }
                let Some((lane, job)) = pick else { break };
                st.pending[lane].pop_front();
                st.lane_busy[lane] = true;
                st.lane_acquired[lane] = now;
                st.in_flight += 1;
                st.launches += 1;
                job
            };
            self.push_event(now, EventKind::LaneLaunch { set: set as u32, job });
        }
    }

    /// Record lane job `job` of `set` as finished: frees its lane,
    /// updates the occupancy ledger, and launches whatever became
    /// eligible.  Typed completions ([`OnDone::Lane`]) land here.
    pub fn lane_done(&mut self, set: LaneSetId, job: u32) {
        let now = self.now;
        let (lane, acquired) = {
            let st = &mut self.lanes[set.0];
            let lane = job as usize % st.width;
            assert!(st.lane_busy[lane], "lane_done on a free lane");
            st.lane_busy[lane] = false;
            let acquired = st.lane_acquired[lane];
            st.busy_time += now.saturating_sub(acquired);
            st.in_flight -= 1;
            st.completed += 1;
            st.last_done = now;
            (lane, acquired)
        };
        if let Some(t) = self.tracer.as_deref_mut() {
            t.record_lane(set.0 as u32, lane as u32, job, acquired, now);
        }
        self.lane_try_launch(set.0);
    }

    /// The comm-thread utilization ledger of a lane set: launches so far
    /// + cumulative lane-held time (grants/busy of the old gate).
    pub fn lane_stats(&self, set: LaneSetId) -> ServiceStats {
        let st = &self.lanes[set.0];
        ServiceStats { served: st.launches, busy: st.busy_time }
    }

    /// How many jobs of `set` have completed.
    pub fn lane_completed(&self, set: LaneSetId) -> usize {
        self.lanes[set.0].completed
    }

    /// Virtual time of the set's most recent job completion.
    pub fn lane_last_done(&self, set: LaneSetId) -> SimTime {
        self.lanes[set.0].last_done
    }

    /// Create a dependency join: `action` becomes eligible — scheduled at
    /// the virtual time of the final arrival — once [`Engine::arrive`] has
    /// been called `count` times.  The firing goes through the event heap,
    /// so simultaneous joins resolve in arrival order (deterministic).
    /// Join slots recycle after firing (generational free-list).
    pub fn join(&mut self, count: usize, action: impl FnOnce(&mut Engine) + 'static) -> JoinId {
        self.join_with(count, OnDone::Call(Box::new(action)))
    }

    /// [`Engine::join`] with an arbitrary typed completion — a lane
    /// completion makes a graph's terminal join hand its stream lane
    /// back with no boxed action.
    pub fn join_with(&mut self, count: usize, action: OnDone) -> JoinId {
        assert!(count > 0, "a join needs at least one dependency");
        match self.join_free.pop() {
            Some(slot) => {
                let st = &mut self.joins[slot as usize];
                st.remaining = count;
                st.action = Some(action);
                JoinId { slot, gen: st.gen }
            }
            None => {
                self.joins.push(JoinState { gen: 0, remaining: count, action: Some(action) });
                JoinId { slot: (self.joins.len() - 1) as u32, gen: 0 }
            }
        }
    }

    /// Record one predecessor completion on join `j`.
    pub fn arrive(&mut self, j: JoinId) {
        let fire = {
            let st = &mut self.joins[j.slot as usize];
            // real assert: with slot recycling, a stale arrival would
            // otherwise corrupt an unrelated join's countdown in release
            assert_eq!(st.gen, j.gen, "arrive on a recycled join");
            debug_assert!(st.remaining > 0, "arrive on an already-fired join");
            st.remaining -= 1;
            st.remaining == 0
        };
        if fire {
            let now = self.now;
            self.push_event(now, EventKind::FireJoin(j));
        }
    }

    fn fire_join(&mut self, j: JoinId) {
        let action = {
            let st = &mut self.joins[j.slot as usize];
            assert_eq!(st.gen, j.gen, "stale join firing");
            let action = st.action.take().expect("join fired twice");
            st.gen = st.gen.wrapping_add(1);
            action
        };
        self.join_free.push(j.slot);
        let now = self.now;
        if let Some(t) = self.tracer.as_deref_mut() {
            t.record_join(now);
        }
        action.run(self);
    }

    /// Arm a cancellable timer: `action` runs at absolute time `at`
    /// unless [`Engine::cancel_timer`] is called first.  This is the
    /// deadline-watchdog primitive: arm one next to a `serve`/join, run
    /// the failure handler when it fires, cancel it from the completion
    /// path when the guarded work finishes in time.
    pub fn timer_at(&mut self, at: SimTime, action: OnDone) -> TimerId {
        let id = self.timer_slot(Some(action));
        self.push_event(at, EventKind::Timer { slot: id.slot, gen: id.gen });
        id
    }

    /// [`Engine::timer_at`] with a plain closure.
    pub fn watchdog(
        &mut self,
        at: SimTime,
        action: impl FnOnce(&mut Engine) + 'static,
    ) -> TimerId {
        self.timer_at(at, OnDone::Call(Box::new(action)))
    }

    fn timer_slot(&mut self, action: Option<OnDone>) -> TimerId {
        match self.timer_free.pop() {
            Some(slot) => {
                let st = &mut self.timers[slot as usize];
                st.action = action;
                TimerId { slot, gen: st.gen }
            }
            None => {
                self.timers.push(TimerState { gen: 0, action });
                TimerId { slot: (self.timers.len() - 1) as u32, gen: 0 }
            }
        }
    }

    /// Cancel a pending timer in O(1): the action is dropped now and the
    /// already-queued event becomes a tombstone, discarded at pop time
    /// (the calendar queue has no removal).  Returns `false` if the
    /// timer already fired or was already cancelled.
    pub fn cancel_timer(&mut self, t: TimerId) -> bool {
        let st = &mut self.timers[t.slot as usize];
        if st.gen != t.gen {
            return false;
        }
        st.action = None;
        st.gen = st.gen.wrapping_add(1);
        self.timer_free.push(t.slot);
        true
    }

    /// Occupy resource `r` for `dur` with no completion action — an
    /// exogenous outage window (a link flap): the port is FIFO-busy for
    /// the window, so in-flight and queued transfers stall behind it.
    pub fn hold(&mut self, r: ResourceId, dur: SimTime) {
        let id = self.timer_slot(None);
        self.occupy(r, dur, 0.0, EventKind::Timer { slot: id.slot, gen: id.gen });
    }

    /// Abort a lane set: drop every released-but-unlaunched job, close
    /// the busy ledger of lanes whose in-flight job is being abandoned,
    /// and zero the in-flight count so a later submission wave (the
    /// post-recovery restart) launches cleanly.  Completed counts keep
    /// what actually finished — the restart-from-last-completed-buffer
    /// cursor reads [`Engine::lane_completed`] after this.
    pub fn lane_abort(&mut self, set: LaneSetId) {
        let now = self.now;
        let st = &mut self.lanes[set.0];
        for q in &mut st.pending {
            q.clear();
        }
        for lane in 0..st.width {
            if st.lane_busy[lane] {
                st.lane_busy[lane] = false;
                st.busy_time += now.saturating_sub(st.lane_acquired[lane]);
            }
        }
        st.in_flight = 0;
    }

    /// Drop recorded trace spans ending after `at` (no-op when tracing
    /// is off).  The fault cut's trace counterpart: activity that the
    /// aborted timeline would have completed after the failure instant
    /// never happened.
    pub fn trace_truncate(&mut self, at: SimTime) {
        if let Some(t) = self.tracer.as_deref_mut() {
            t.truncate(at);
        }
    }

    /// Record a recovery interval `[t0, t1]` of `kind` (fault detection,
    /// backoff wait, template rebuild) on the engine's recovery track.
    /// No-op when tracing is off.
    pub fn trace_mark(&mut self, kind: SpanKind, t0: SimTime, t1: SimTime) {
        if let Some(t) = self.tracer.as_deref_mut() {
            t.record_mark(kind, t0, t1);
        }
    }

    /// When would a `bytes` request complete if enqueued now (without
    /// actually enqueuing)?  Used by analytic shortcuts in the strategies.
    /// Shares [`Engine::transfer_time`] (and the overhead term) with the
    /// served path, so the two cannot drift.
    pub fn peek_completion(&self, r: ResourceId, bytes: f64) -> SimTime {
        let state = &self.resources[r.0];
        let start = state.busy_until.max(self.now);
        start + self.transfer_time(r, bytes) + state.overhead
    }

    /// Utilization metrics: requests served + cumulative busy time.
    pub fn resource_stats(&self, r: ResourceId) -> ServiceStats {
        let s = &self.resources[r.0];
        ServiceStats { served: s.served, busy: s.busy_time }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn events_fire_in_time_order() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for (t, tag) in [(30.0, "c"), (10.0, "a"), (20.0, "b")] {
            let log = log.clone();
            e.at(SimTime::from_us(t), move |_| log.borrow_mut().push(tag));
        }
        e.run();
        assert_eq!(*log.borrow(), vec!["a", "b", "c"]);
        assert_eq!(e.executed(), 3);
    }

    #[test]
    fn simultaneous_events_fire_in_scheduling_order() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for tag in ["first", "second", "third"] {
            let log = log.clone();
            e.at(SimTime::from_us(5.0), move |_| log.borrow_mut().push(tag));
        }
        e.run();
        assert_eq!(*log.borrow(), vec!["first", "second", "third"]);
    }

    #[test]
    fn chained_events_advance_clock() {
        let mut e = Engine::new();
        let seen = Rc::new(RefCell::new(SimTime::ZERO));
        let seen2 = seen.clone();
        e.after(SimTime::from_us(10.0), move |e| {
            let seen3 = seen2.clone();
            e.after(SimTime::from_us(5.0), move |e| {
                *seen3.borrow_mut() = e.now();
            });
        });
        let end = e.run();
        assert_eq!(end, SimTime::from_us(15.0));
        assert_eq!(*seen.borrow(), SimTime::from_us(15.0));
    }

    #[test]
    fn resource_serializes_fifo() {
        // Two 100-byte requests at rate 10 bytes/us, no overhead: the
        // second must wait for the first → completions at 10us and 20us.
        let mut e = Engine::new();
        let r = e.resource(10.0, SimTime::ZERO);
        let done = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..2 {
            let done = done.clone();
            e.serve(r, 100.0, move |e| done.borrow_mut().push(e.now().as_us()));
        }
        e.run();
        assert_eq!(*done.borrow(), vec![10.0, 20.0]);
        let ServiceStats { served, busy } = e.resource_stats(r);
        assert_eq!(served, 2);
        assert_eq!(busy, SimTime::from_us(20.0));
    }

    #[test]
    fn resource_overhead_applies_per_request() {
        let mut e = Engine::new();
        let r = e.resource(100.0, SimTime::from_us(3.0));
        let done = Rc::new(RefCell::new(0.0));
        let d2 = done.clone();
        e.serve(r, 100.0, move |e| *d2.borrow_mut() = e.now().as_us());
        e.run();
        assert!((*done.borrow() - 4.0).abs() < 1e-9); // 1us transfer + 3us overhead
    }

    #[test]
    fn resource_idle_gap_not_counted_busy() {
        let mut e = Engine::new();
        let r = e.resource(10.0, SimTime::ZERO);
        e.serve(r, 50.0, |_| {}); // completes at 5us
        e.at(SimTime::from_us(100.0), move |e| {
            e.serve(r, 50.0, |_| {}); // completes at 105us
        });
        let end = e.run();
        assert_eq!(end, SimTime::from_us(105.0));
        assert_eq!(e.resource_stats(r).busy, SimTime::from_us(10.0));
    }

    #[test]
    fn peek_does_not_mutate() {
        let mut e = Engine::new();
        let r = e.resource(10.0, SimTime::ZERO);
        let t1 = e.peek_completion(r, 100.0);
        let t2 = e.peek_completion(r, 100.0);
        assert_eq!(t1, t2);
        assert_eq!(t1, SimTime::from_us(10.0));
    }

    #[test]
    fn peek_matches_served_completion_including_overhead() {
        // the shared service formula: what peek predicts is exactly when
        // the served request completes, overhead included
        let mut e = Engine::new();
        let r = e.resource(10.0, SimTime::from_us(2.5));
        let predicted = e.peek_completion(r, 100.0);
        let done = Rc::new(RefCell::new(SimTime::ZERO));
        let d2 = done.clone();
        e.serve(r, 100.0, move |e| *d2.borrow_mut() = e.now());
        e.run();
        assert_eq!(*done.borrow(), predicted);
    }

    #[test]
    fn serve_for_occupies_exact_duration() {
        let mut e = Engine::new();
        let r = e.unit_resource();
        let done = Rc::new(RefCell::new(Vec::new()));
        for dur in [4.0, 6.0] {
            let done = done.clone();
            e.serve_for(r, SimTime::from_us(dur), move |e| {
                done.borrow_mut().push(e.now().as_us());
            });
        }
        e.run();
        assert_eq!(*done.borrow(), vec![4.0, 10.0]);
        let ServiceStats { served, busy } = e.resource_stats(r);
        assert_eq!(served, 2);
        assert_eq!(busy, SimTime::from_us(10.0));
    }

    #[test]
    fn program_runs_steps_in_order_with_fifo_queueing() {
        // a program's pinned steps queue FIFO behind other traffic; the
        // unpinned step elapses in parallel with nothing blocking it
        let mut e = Engine::new();
        let r = e.unit_resource();
        e.serve_for(r, SimTime::from_us(5.0), |_| {}); // background occupancy
        let end = Rc::new(RefCell::new(0.0));
        let e2 = end.clone();
        let steps: Rc<[ProgStep]> = vec![
            ProgStep { us: 3.0, on: Some(r) }, // starts at 5 (FIFO), ends 8
            ProgStep { us: 2.0, on: None },    // pure delay → 10
            ProgStep { us: 1.0, on: Some(r) }, // resource free → 11
        ]
        .into();
        e.run_program(steps, Box::new(move |e| *e2.borrow_mut() = e.now().as_us()));
        e.run();
        assert!((*end.borrow() - 11.0).abs() < 1e-9);
        let ServiceStats { served, busy } = e.resource_stats(r);
        assert_eq!(served, 3);
        assert_eq!(busy, SimTime::from_us(9.0));
    }

    #[test]
    fn empty_program_completes_synchronously() {
        let mut e = Engine::new();
        let fired = Rc::new(RefCell::new(false));
        let f = fired.clone();
        let steps: Rc<[ProgStep]> = Vec::new().into();
        e.run_program(steps, Box::new(move |_| *f.borrow_mut() = true));
        assert!(*fired.borrow(), "empty program must complete without events");
        assert_eq!(e.run(), SimTime::ZERO);
        assert_eq!(e.executed(), 0);
    }

    #[test]
    fn program_slots_recycle() {
        // sequential programs reuse one slab slot (generational free-list)
        let mut e = Engine::new();
        let steps: Rc<[ProgStep]> = vec![ProgStep { us: 1.0, on: None }].into();
        for _ in 0..3 {
            e.run_program(steps.clone(), Box::new(|_| {}));
            e.run();
        }
        assert_eq!(e.progs.len(), 1, "sequential programs must share a slot");
        assert_eq!(e.progs[0].gen, 3);
        // two concurrent programs need two slots
        e.run_program(steps.clone(), Box::new(|_| {}));
        e.run_program(steps, Box::new(|_| {}));
        e.run();
        assert_eq!(e.progs.len(), 2);
    }

    #[test]
    fn concurrent_programs_events_count_one_per_step() {
        let mut e = Engine::new();
        let steps: Rc<[ProgStep]> = vec![
            ProgStep { us: 1.0, on: None },
            ProgStep { us: 1.0, on: None },
        ]
        .into();
        for _ in 0..5 {
            e.run_program(steps.clone(), Box::new(|_| {}));
        }
        e.run();
        assert_eq!(e.executed(), 10, "one event per program step");
    }

    #[test]
    fn gate_serializes_fifo_and_tracks_busy() {
        // Three holders, each keeping the gate for 10us of chained events:
        // grants at 0/10/20, releases at 10/20/30.
        let mut e = Engine::new();
        let g = e.gate();
        let log = Rc::new(RefCell::new(Vec::new()));
        for tag in ["a", "b", "c"] {
            let log = log.clone();
            e.acquire(g, move |e| {
                log.borrow_mut().push((tag, e.now().as_us()));
                e.after(SimTime::from_us(10.0), move |e| e.release(g));
            });
        }
        let end = e.run();
        assert_eq!(end, SimTime::from_us(30.0));
        assert_eq!(*log.borrow(), vec![("a", 0.0), ("b", 10.0), ("c", 20.0)]);
        let ServiceStats { served: grants, busy } = e.gate_stats(g);
        assert_eq!(grants, 3);
        assert_eq!(busy, SimTime::from_us(30.0));
    }

    #[test]
    fn gate_idle_time_not_counted() {
        let mut e = Engine::new();
        let g = e.gate();
        e.acquire(g, move |e| e.after(SimTime::from_us(5.0), move |e| e.release(g)));
        e.at(SimTime::from_us(100.0), move |e| {
            e.acquire(g, move |e| e.after(SimTime::from_us(5.0), move |e| e.release(g)));
        });
        e.run();
        assert_eq!(e.gate_stats(g).busy, SimTime::from_us(10.0));
    }

    #[test]
    fn join_fires_at_last_arrival() {
        // Two predecessors completing at 5us and 12us: the join's action
        // must fire exactly once, at 12us.
        let mut e = Engine::new();
        let fired = Rc::new(RefCell::new(Vec::new()));
        let f2 = fired.clone();
        let j = e.join(2, move |e| f2.borrow_mut().push(e.now().as_us()));
        e.after(SimTime::from_us(5.0), move |e| e.arrive(j));
        e.after(SimTime::from_us(12.0), move |e| e.arrive(j));
        e.run();
        assert_eq!(*fired.borrow(), vec![12.0]);
    }

    #[test]
    fn join_slots_recycle_with_fresh_generation() {
        let mut e = Engine::new();
        let j1 = e.join(1, |_| {});
        e.arrive(j1);
        e.run();
        let j2 = e.join(1, |_| {});
        // the slot is reused, the generation is not
        assert_eq!(e.joins.len(), 1);
        assert_ne!(j1, j2);
        e.arrive(j2);
        e.run();
    }

    #[test]
    fn join_chains_into_resources() {
        // Diamond: two 10us serve_for legs arrive at a join whose action
        // occupies the resource again — classic eligibility-then-FIFO.
        let mut e = Engine::new();
        let r = e.unit_resource();
        let end = Rc::new(RefCell::new(0.0));
        let e2 = end.clone();
        let j = e.join(2, move |e| {
            e.serve_for(r, SimTime::from_us(3.0), move |e| {
                *e2.borrow_mut() = e.now().as_us();
            });
        });
        for _ in 0..2 {
            // both legs queue FIFO on the same resource: done at 10, 20
            e.serve_for(r, SimTime::from_us(10.0), move |e| e.arrive(j));
        }
        e.run();
        assert!((*end.borrow() - 23.0).abs() < 1e-9);
    }

    #[test]
    fn simultaneous_join_firings_resolve_in_arrival_order() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        let (l1, l2) = (log.clone(), log.clone());
        let a = e.join(1, move |_| l1.borrow_mut().push("a"));
        let b = e.join(1, move |_| l2.borrow_mut().push("b"));
        e.after(SimTime::from_us(1.0), move |e| {
            // arrive b first: it must also fire first
            e.arrive(b);
            e.arrive(a);
        });
        e.run();
        assert_eq!(*log.borrow(), vec!["b", "a"]);
    }

    /// Lane driver for the tests: each job is one `serve_for`-style
    /// occupancy on a shared resource, completed through the typed path.
    struct TestLanes {
        durs: Vec<f64>,
        on: ResourceId,
    }

    impl LaneDriver for TestLanes {
        fn launch(&self, e: &mut Engine, set: LaneSetId, job: u32) {
            let steps: Rc<[ProgStep]> =
                vec![ProgStep { us: self.durs[job as usize], on: Some(self.on) }].into();
            e.run_program_lane(steps, set, job);
        }
    }

    /// Unpinned variant: jobs elapse as pure delays (no shared resource),
    /// so lane concurrency is directly visible in the completion times.
    struct DelayLanes {
        durs: Vec<f64>,
    }

    impl LaneDriver for DelayLanes {
        fn launch(&self, e: &mut Engine, set: LaneSetId, job: u32) {
            let steps: Rc<[ProgStep]> =
                vec![ProgStep { us: self.durs[job as usize], on: None }].into();
            e.run_program_lane(steps, set, job);
        }
    }

    #[test]
    fn single_lane_matches_gate_semantics() {
        // Three 10us holders released at 0/0/5: the gate serializes them
        // 0-10/10-20/20-30; a width-1 depth-1 lane set must reproduce the
        // same completions, launch count and busy ledger.
        let mut e = Engine::new();
        let set = e.lane_set(1, 1, Rc::new(DelayLanes { durs: vec![10.0; 3] }));
        e.lane_submit(set, SimTime::ZERO, 0);
        e.lane_submit(set, SimTime::ZERO, 1);
        e.lane_submit(set, SimTime::from_us(5.0), 2);
        let end = e.run();
        assert_eq!(end, SimTime::from_us(30.0));
        let ServiceStats { served: launches, busy } = e.lane_stats(set);
        assert_eq!(launches, 3);
        assert_eq!(busy, SimTime::from_us(30.0));
        assert_eq!(e.lane_completed(set), 3);
        assert_eq!(e.lane_last_done(set), SimTime::from_us(30.0));
    }

    #[test]
    fn two_lanes_interleave_uncontended_jobs() {
        // Two 10us jobs released together: one lane serializes (20us),
        // two lanes overlap them fully (10us).
        for (streams, expect) in [(1usize, 20.0), (2, 10.0)] {
            let mut e = Engine::new();
            let set = e.lane_set(streams, streams, Rc::new(DelayLanes { durs: vec![10.0; 2] }));
            e.lane_submit(set, SimTime::ZERO, 0);
            e.lane_submit(set, SimTime::ZERO, 1);
            assert_eq!(e.run(), SimTime::from_us(expect), "streams={streams}");
        }
    }

    #[test]
    fn lanes_share_resources_fifo() {
        // Two lanes, both jobs pinned to one FIFO resource: the launches
        // overlap but the occupancy serializes — contention arbitrates,
        // not the lane order.
        let mut e = Engine::new();
        let r = e.unit_resource();
        let set = e.lane_set(2, 2, Rc::new(TestLanes { durs: vec![10.0, 4.0], on: r }));
        e.lane_submit(set, SimTime::ZERO, 0);
        e.lane_submit(set, SimTime::ZERO, 1);
        let end = e.run();
        assert_eq!(end, SimTime::from_us(14.0));
        assert_eq!(e.resource_stats(r).busy, SimTime::from_us(14.0));
        // both lanes were held until their job's occupancy drained
        let ServiceStats { served: launches, busy: lane_busy } = e.lane_stats(set);
        assert_eq!(launches, 2);
        assert_eq!(lane_busy, SimTime::from_us(24.0));
    }

    #[test]
    fn depth_cap_limits_in_flight() {
        // Four 10us delay jobs on 4 lanes: depth 1 serializes (40us),
        // depth 2 pairs them (20us), depth 4 runs all at once (10us).
        for (depth, expect) in [(1usize, 40.0), (2, 20.0), (4, 10.0)] {
            let mut e = Engine::new();
            let set = e.lane_set(4, depth, Rc::new(DelayLanes { durs: vec![10.0; 4] }));
            for j in 0..4 {
                e.lane_submit(set, SimTime::ZERO, j);
            }
            assert_eq!(e.run(), SimTime::from_us(expect), "depth={depth}");
        }
    }

    #[test]
    fn lane_round_robin_serializes_same_lane_jobs() {
        // Jobs 0 and 2 share lane 0 of a 2-lane set: 2 waits for 0 even
        // though lane 1 (job 1) finished long ago.
        let mut e = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        struct Log {
            durs: Vec<f64>,
            done: Rc<RefCell<Vec<(u32, f64)>>>,
        }
        impl LaneDriver for Log {
            fn launch(&self, e: &mut Engine, set: LaneSetId, job: u32) {
                let steps: Rc<[ProgStep]> =
                    vec![ProgStep { us: self.durs[job as usize], on: None }].into();
                let d = self.done.clone();
                e.run_program(
                    steps,
                    Box::new(move |e| {
                        d.borrow_mut().push((job, e.now().as_us()));
                        e.lane_done(set, job);
                    }),
                );
            }
        }
        let set = e.lane_set(2, 2, Rc::new(Log { durs: vec![10.0, 1.0, 2.0], done: done.clone() }));
        for j in 0..3 {
            e.lane_submit(set, SimTime::ZERO, j);
        }
        e.run();
        assert_eq!(*done.borrow(), vec![(1, 1.0), (0, 10.0), (2, 12.0)]);
    }

    #[test]
    fn typed_join_completion_hands_lane_back() {
        // A lane job completed through join_with(OnDone::Lane) frees the
        // lane for the next job — the graph-path terminal join shape.
        struct JoinLanes;
        impl LaneDriver for JoinLanes {
            fn launch(&self, e: &mut Engine, set: LaneSetId, job: u32) {
                let j = e.join_with(2, OnDone::Lane(set, job));
                e.after(SimTime::from_us(3.0), move |e| e.arrive(j));
                e.after(SimTime::from_us(7.0), move |e| e.arrive(j));
            }
        }
        let mut e = Engine::new();
        let set = e.lane_set(1, 1, Rc::new(JoinLanes));
        e.lane_submit(set, SimTime::ZERO, 0);
        e.lane_submit(set, SimTime::ZERO, 1);
        let end = e.run();
        assert_eq!(end, SimTime::from_us(14.0));
        assert_eq!(e.lane_completed(set), 2);
    }

    #[test]
    fn program_lanes_driver_runs_resolved_programs() {
        let mut e = Engine::new();
        let r = e.unit_resource();
        let progs: Vec<Rc<[ProgStep]>> = vec![
            vec![ProgStep { us: 5.0, on: Some(r) }].into(),
            vec![ProgStep { us: 2.0, on: Some(r) }].into(),
        ];
        let set = e.lane_set(1, 1, Rc::new(ProgramLanes::new(progs)));
        e.lane_submit(set, SimTime::ZERO, 0);
        e.lane_submit(set, SimTime::ZERO, 1);
        let end = e.run();
        assert_eq!(end, SimTime::from_us(7.0));
        assert_eq!(e.resource_stats(r), ServiceStats { served: 2, busy: SimTime::from_us(7.0) });
    }

    #[test]
    fn hook_completions_route_through_registration() {
        struct Sink(Rc<RefCell<Vec<(u32, f64)>>>);
        impl EngineHook for Sink {
            fn done(&self, e: &mut Engine, arg: u32) {
                self.0.borrow_mut().push((arg, e.now().as_us()));
            }
        }
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        let h = e.hook(Rc::new(Sink(log.clone())));
        let steps: Rc<[ProgStep]> = vec![ProgStep { us: 4.0, on: None }].into();
        e.run_program_with(steps.clone(), OnDone::Hook(h, 7));
        e.run_program_with(steps, OnDone::Hook(h, 9));
        e.run();
        // simultaneous completions fire in scheduling order
        assert_eq!(*log.borrow(), vec![(7, 4.0), (9, 4.0)]);
    }

    #[test]
    fn shifted_program_occupies_offset_resource() {
        let mut e = Engine::new();
        let r0 = e.unit_resource();
        let r1 = e.unit_resource();
        assert_eq!(r1.index(), r0.index() + 1, "consecutive ids are contiguous");
        let steps: Rc<[ProgStep]> = vec![ProgStep { us: 5.0, on: Some(r0) }].into();
        e.run_program_shifted(steps, 1, OnDone::Call(Box::new(|_| {})));
        e.run();
        assert_eq!(e.resource_stats(r0), ServiceStats { served: 0, busy: SimTime::ZERO });
        assert_eq!(e.resource_stats(r1), ServiceStats { served: 1, busy: SimTime::from_us(5.0) });
    }

    #[test]
    fn queue_peak_tracks_outstanding_events() {
        let mut e = Engine::new();
        for i in 0..5 {
            e.at(SimTime::from_us(i as f64), |_| {});
        }
        e.run();
        assert_eq!(e.queue_peak(), 5);
        assert!(e.approx_slab_bytes() > 0);
    }

    #[test]
    fn timer_fires_at_deadline() {
        let mut e = Engine::new();
        let fired = Rc::new(RefCell::new(Vec::new()));
        let f = fired.clone();
        e.watchdog(SimTime::from_us(7.0), move |e| f.borrow_mut().push(e.now().as_us()));
        e.run();
        assert_eq!(*fired.borrow(), vec![7.0]);
    }

    #[test]
    fn cancelled_timer_never_fires() {
        let mut e = Engine::new();
        let fired = Rc::new(RefCell::new(false));
        let f = fired.clone();
        let t = e.watchdog(SimTime::from_us(7.0), move |_| *f.borrow_mut() = true);
        assert!(e.cancel_timer(t), "pending timer cancels");
        assert!(!e.cancel_timer(t), "second cancel is a stale no-op");
        let end = e.run();
        assert!(!*fired.borrow(), "cancelled watchdog must not fire");
        // the tombstone event still pops (the queue has no removal)
        assert_eq!(end, SimTime::from_us(7.0));
    }

    #[test]
    fn timer_slots_recycle_with_fresh_generation() {
        let mut e = Engine::new();
        let t1 = e.watchdog(SimTime::from_us(1.0), |_| {});
        e.run();
        assert!(!e.cancel_timer(t1), "fired timer is stale");
        let t2 = e.watchdog(SimTime::from_us(2.0), |_| {});
        assert_eq!(e.timers.len(), 1, "the fired slot must be reused");
        assert_ne!(t1, t2);
        e.run();
    }

    #[test]
    fn watchdog_cancelled_by_guarded_completion() {
        // the deadline-watchdog idiom: a serve that finishes before the
        // deadline cancels the watchdog from its completion path
        let mut e = Engine::new();
        let r = e.resource(10.0, SimTime::ZERO);
        let timed_out = Rc::new(RefCell::new(false));
        let to = timed_out.clone();
        let wd = e.watchdog(SimTime::from_us(50.0), move |_| *to.borrow_mut() = true);
        e.serve(r, 100.0, move |e| {
            e.cancel_timer(wd);
        });
        e.run();
        assert!(!*timed_out.borrow(), "completion at 10us beats the 50us deadline");
    }

    #[test]
    fn hold_blocks_fifo_service() {
        // a 20us outage window queued ahead of a 10us transfer: the
        // transfer completes at 30us instead of 10us
        let mut e = Engine::new();
        let r = e.resource(10.0, SimTime::ZERO);
        e.hold(r, SimTime::from_us(20.0));
        let done = Rc::new(RefCell::new(0.0));
        let d = done.clone();
        e.serve(r, 100.0, move |e| *d.borrow_mut() = e.now().as_us());
        e.run();
        assert!((*done.borrow() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn aborted_program_drains_without_done() {
        let mut e = Engine::new();
        let r = e.unit_resource();
        let fired = Rc::new(RefCell::new(false));
        let f = fired.clone();
        let steps: Rc<[ProgStep]> = vec![
            ProgStep { us: 5.0, on: Some(r) },
            ProgStep { us: 50.0, on: Some(r) },
        ]
        .into();
        let p = e.run_program(steps, Box::new(move |_| *f.borrow_mut() = true));
        assert!(e.abort_program(p), "in-flight program aborts");
        let end = e.run();
        assert!(!*fired.borrow(), "aborted program must not fire done");
        // the in-flight 5us step finishes service; the 50us remainder
        // never occupies the resource (the refund)
        assert_eq!(end, SimTime::from_us(5.0));
        assert_eq!(e.resource_stats(r).busy, SimTime::from_us(5.0));
        // the slot recycled: a fresh program reuses it, abort is stale
        assert!(!e.abort_program(p));
        let steps2: Rc<[ProgStep]> = vec![ProgStep { us: 1.0, on: None }].into();
        e.run_program(steps2, Box::new(|_| {}));
        e.run();
        assert_eq!(e.progs.len(), 1, "aborted slot must be reusable");
    }

    #[test]
    fn abort_after_completion_is_stale() {
        let mut e = Engine::new();
        let steps: Rc<[ProgStep]> = vec![ProgStep { us: 1.0, on: None }].into();
        let p = e.run_program(steps, Box::new(|_| {}));
        e.run();
        assert!(!e.abort_program(p), "completed program is a stale handle");
    }

    #[test]
    fn run_until_pauses_and_resumes_exactly() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for t in [5.0, 10.0, 15.0] {
            let log = log.clone();
            e.at(SimTime::from_us(t), move |e| log.borrow_mut().push(e.now().as_us()));
        }
        // pause between events: the 15us event is stashed, not lost
        let paused = e.run_until(SimTime::from_us(12.0));
        assert_eq!(paused, SimTime::from_us(12.0));
        assert_eq!(*log.borrow(), vec![5.0, 10.0]);
        let end = e.run();
        assert_eq!(end, SimTime::from_us(15.0));
        assert_eq!(*log.borrow(), vec![5.0, 10.0, 15.0]);
        assert_eq!(e.executed(), 3);
    }

    #[test]
    fn run_until_advances_clock_past_drained_queue() {
        let mut e = Engine::new();
        e.at(SimTime::from_us(3.0), |_| {});
        assert_eq!(e.run_until(SimTime::from_us(20.0)), SimTime::from_us(20.0));
    }

    #[test]
    fn clear_pending_drops_stash_and_queue() {
        let mut e = Engine::new();
        let fired = Rc::new(RefCell::new(0));
        for t in [5.0, 10.0, 15.0] {
            let f = fired.clone();
            e.at(SimTime::from_us(t), move |_| *f.borrow_mut() += 1);
        }
        e.run_until(SimTime::from_us(7.0));
        e.clear_pending();
        let end = e.run();
        assert_eq!(*fired.borrow(), 1, "only the pre-cut event ran");
        assert_eq!(end, SimTime::from_us(7.0));
        // the engine stays usable after the cut
        let f = fired.clone();
        e.at(SimTime::from_us(30.0), move |_| *f.borrow_mut() += 1);
        assert_eq!(e.run(), SimTime::from_us(30.0));
        assert_eq!(*fired.borrow(), 2);
    }

    #[test]
    fn lane_abort_frees_lanes_and_allows_restart() {
        let mut e = Engine::new();
        let set = e.lane_set(2, 2, Rc::new(DelayLanes { durs: vec![10.0; 6] }));
        for j in 0..4 {
            e.lane_submit(set, SimTime::ZERO, j);
        }
        e.run_until(SimTime::from_us(5.0));
        e.lane_abort(set);
        e.clear_pending();
        assert_eq!(e.lane_completed(set), 0);
        // restart: the remaining jobs launch on the freed lanes
        e.lane_submit(set, SimTime::from_us(5.0), 4);
        e.lane_submit(set, SimTime::from_us(5.0), 5);
        let end = e.run();
        assert_eq!(end, SimTime::from_us(15.0));
        assert_eq!(e.lane_completed(set), 2);
    }

    #[test]
    fn determinism_same_program_same_trace() {
        fn run_once() -> Vec<f64> {
            let mut e = Engine::new();
            let r = e.resource(7.0, SimTime::from_us(0.5));
            let out = Rc::new(RefCell::new(Vec::new()));
            for i in 0..20 {
                let out = out.clone();
                e.after(SimTime::from_us(i as f64 * 0.3), move |e| {
                    let out2 = out.clone();
                    e.serve(r, 64.0 * (i % 5 + 1) as f64, move |e| {
                        out2.borrow_mut().push(e.now().as_us());
                    });
                });
            }
            e.run();
            let v = out.borrow().clone();
            v
        }
        assert_eq!(run_once(), run_once());
    }
}
