//! Event heap + FIFO resources — the core of the cluster simulator.
//!
//! Events are `FnOnce(&mut Engine)` closures ordered by (time, sequence);
//! the sequence number makes simultaneous events fire in scheduling order,
//! which is what makes whole-cluster runs bit-reproducible.
//!
//! `Resource` models a serialized server (a NIC, a PCIe link, a single
//! gRPC service thread): `serve()` requests are queued FIFO and each
//! occupies the resource for `bytes / rate` — this is how parameter-server
//! fan-in congestion and the single-threaded gRPC+MPI bottleneck (paper
//! §VI-D) arise in the model.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use super::time::SimTime;

type Action = Box<dyn FnOnce(&mut Engine)>;

/// Heap entry carrying its action inline (§Perf: the original design
/// parked actions in a HashMap side table keyed by seq — one hash insert
/// + one hash remove per event; inlining them into the heap entry with an
/// order that ignores the closure removed both).
struct Event {
    at: SimTime,
    seq: u64,
    action: Action,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        (self.at, self.seq) == (other.at, other.seq)
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Handle to a FIFO-serialized resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ResourceId(usize);

struct ResourceState {
    /// Bytes per microsecond (i.e. MB/s / 1e... we keep it as bytes/us).
    rate_bytes_per_us: f64,
    /// Per-service fixed overhead.
    overhead: SimTime,
    busy_until: SimTime,
    served: u64,
    busy_time: SimTime,
}

/// Handle to a FIFO gate (see [`Engine::gate`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GateId(usize);

/// Handle to a dependency join (see [`Engine::join`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct JoinId(usize);

/// A join is the *eligibility* primitive of dependency-graph scheduling:
/// its action fires once all `count` predecessors have called `arrive`.
/// Unlike resources/gates it carries no occupancy — eligibility and FIFO
/// queueing are deliberately separate (a `CommGraph` node first becomes
/// eligible here, then its ops queue on per-rank resources).
struct JoinState {
    remaining: usize,
    action: Option<Action>,
}

/// A gate is a FIFO mutex with a virtual-clock ledger: `acquire` runs the
/// action once the gate is free (waiters queue in arrival order), and the
/// holder must `release` explicitly.  Unlike `Resource`, the hold time is
/// *open-ended* — it spans whatever chain of events the holder schedules
/// before releasing.  This models Horovod's background communication
/// thread: the thread is occupied for the full coordination + Allreduce
/// of one fusion buffer, however long the buffer's schedule takes on the
/// contended links.
struct GateState {
    busy: bool,
    waiters: VecDeque<Action>,
    acquired_at: SimTime,
    grants: u64,
    busy_time: SimTime,
}

/// Discrete-event engine with a virtual clock.
#[derive(Default)]
pub struct Engine {
    now: SimTime,
    seq: u64,
    heap: BinaryHeap<Reverse<Event>>,
    resources: Vec<ResourceState>,
    gates: Vec<GateState>,
    joins: Vec<JoinState>,
    executed: u64,
}

impl Engine {
    pub fn new() -> Self {
        Engine::default()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far (per-run metric; also the §Perf
    /// events/s denominator).
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Schedule `action` at absolute time `at` (>= now).
    pub fn at(&mut self, at: SimTime, action: impl FnOnce(&mut Engine) + 'static) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Event { at, seq, action: Box::new(action) }));
    }

    /// Schedule `action` after a delay.
    pub fn after(&mut self, dt: SimTime, action: impl FnOnce(&mut Engine) + 'static) {
        self.at(self.now + dt, action);
    }

    /// Run until the event queue drains; returns the final clock.
    pub fn run(&mut self) -> SimTime {
        while let Some(Reverse(ev)) = self.heap.pop() {
            self.now = ev.at;
            self.executed += 1;
            (ev.action)(self);
        }
        self.now
    }

    /// Define a FIFO resource with service rate `bytes_per_us` and fixed
    /// per-request `overhead`.
    pub fn resource(&mut self, bytes_per_us: f64, overhead: SimTime) -> ResourceId {
        assert!(bytes_per_us > 0.0);
        self.resources.push(ResourceState {
            rate_bytes_per_us: bytes_per_us,
            overhead,
            busy_until: SimTime::ZERO,
            served: 0,
            busy_time: SimTime::ZERO,
        });
        ResourceId(self.resources.len() - 1)
    }

    /// Enqueue a `bytes`-sized request on resource `r`; `done` fires when
    /// the request finishes service (FIFO order, serialized).
    pub fn serve(&mut self, r: ResourceId, bytes: f64, done: impl FnOnce(&mut Engine) + 'static) {
        let state = &mut self.resources[r.0];
        let start = state.busy_until.max(self.now);
        let service = SimTime::from_us(bytes / state.rate_bytes_per_us) + state.overhead;
        let end = start + service;
        state.busy_until = end;
        state.served += 1;
        state.busy_time += service;
        self.at(end, done);
    }

    /// A serialized resource with no rate semantics: requests occupy it
    /// for an explicit duration (see [`Engine::serve_for`]).  The `CommOp`
    /// replay layer uses these — durations come from the validated cost
    /// models, queueing/contention from the FIFO here.
    pub fn unit_resource(&mut self) -> ResourceId {
        self.resource(1.0, SimTime::ZERO)
    }

    /// Enqueue a request occupying resource `r` for exactly `dur` (plus
    /// the resource's fixed overhead); `done` fires at completion.  FIFO
    /// with respect to `serve` requests on the same resource.
    pub fn serve_for(&mut self, r: ResourceId, dur: SimTime, done: impl FnOnce(&mut Engine) + 'static) {
        let state = &mut self.resources[r.0];
        let start = state.busy_until.max(self.now);
        let service = dur + state.overhead;
        let end = start + service;
        state.busy_until = end;
        state.served += 1;
        state.busy_time += service;
        self.at(end, done);
    }

    /// Create a FIFO gate (open, no waiters).
    pub fn gate(&mut self) -> GateId {
        self.gates.push(GateState {
            busy: false,
            waiters: VecDeque::new(),
            acquired_at: SimTime::ZERO,
            grants: 0,
            busy_time: SimTime::ZERO,
        });
        GateId(self.gates.len() - 1)
    }

    /// Run `action` once gate `g` is free, holding it until `release`.
    /// Waiters are granted in arrival order; a grant fires through the
    /// event heap so ties stay deterministic.
    pub fn acquire(&mut self, g: GateId, action: impl FnOnce(&mut Engine) + 'static) {
        if self.gates[g.0].busy {
            self.gates[g.0].waiters.push_back(Box::new(action));
            return;
        }
        let now = self.now;
        {
            let st = &mut self.gates[g.0];
            st.busy = true;
            st.acquired_at = now;
            st.grants += 1;
        }
        self.at(now, action);
    }

    /// Release gate `g`, granting the next waiter (if any) at the current
    /// virtual time.
    pub fn release(&mut self, g: GateId) {
        let now = self.now;
        let next = {
            let st = &mut self.gates[g.0];
            debug_assert!(st.busy, "release of a free gate");
            st.busy_time += now.saturating_sub(st.acquired_at);
            match st.waiters.pop_front() {
                Some(next) => {
                    st.acquired_at = now;
                    st.grants += 1;
                    Some(next)
                }
                None => {
                    st.busy = false;
                    None
                }
            }
        };
        if let Some(next) = next {
            self.at(now, next);
        }
    }

    /// (grants so far, cumulative held time) — gate utilization.
    pub fn gate_stats(&self, g: GateId) -> (u64, SimTime) {
        let st = &self.gates[g.0];
        (st.grants, st.busy_time)
    }

    /// Create a dependency join: `action` becomes eligible — scheduled at
    /// the virtual time of the final arrival — once [`Engine::arrive`] has
    /// been called `count` times.  The firing goes through the event heap,
    /// so simultaneous joins resolve in arrival order (deterministic).
    pub fn join(&mut self, count: usize, action: impl FnOnce(&mut Engine) + 'static) -> JoinId {
        assert!(count > 0, "a join needs at least one dependency");
        self.joins.push(JoinState { remaining: count, action: Some(Box::new(action)) });
        JoinId(self.joins.len() - 1)
    }

    /// Record one predecessor completion on join `j`.
    pub fn arrive(&mut self, j: JoinId) {
        let st = &mut self.joins[j.0];
        debug_assert!(st.remaining > 0, "arrive on an already-fired join");
        st.remaining -= 1;
        if st.remaining == 0 {
            let action = st.action.take().expect("join fired twice");
            let now = self.now;
            self.at(now, action);
        }
    }

    /// When would a `bytes` request complete if enqueued now (without
    /// actually enqueuing)?  Used by analytic shortcuts in the strategies.
    pub fn peek_completion(&self, r: ResourceId, bytes: f64) -> SimTime {
        let state = &self.resources[r.0];
        let start = state.busy_until.max(self.now);
        start + SimTime::from_us(bytes / state.rate_bytes_per_us) + state.overhead
    }

    /// (requests served, cumulative busy time) — utilization metrics.
    pub fn resource_stats(&self, r: ResourceId) -> (u64, SimTime) {
        let s = &self.resources[r.0];
        (s.served, s.busy_time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn events_fire_in_time_order() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for (t, tag) in [(30.0, "c"), (10.0, "a"), (20.0, "b")] {
            let log = log.clone();
            e.at(SimTime::from_us(t), move |_| log.borrow_mut().push(tag));
        }
        e.run();
        assert_eq!(*log.borrow(), vec!["a", "b", "c"]);
        assert_eq!(e.executed(), 3);
    }

    #[test]
    fn simultaneous_events_fire_in_scheduling_order() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for tag in ["first", "second", "third"] {
            let log = log.clone();
            e.at(SimTime::from_us(5.0), move |_| log.borrow_mut().push(tag));
        }
        e.run();
        assert_eq!(*log.borrow(), vec!["first", "second", "third"]);
    }

    #[test]
    fn chained_events_advance_clock() {
        let mut e = Engine::new();
        let seen = Rc::new(RefCell::new(SimTime::ZERO));
        let seen2 = seen.clone();
        e.after(SimTime::from_us(10.0), move |e| {
            let seen3 = seen2.clone();
            e.after(SimTime::from_us(5.0), move |e| {
                *seen3.borrow_mut() = e.now();
            });
        });
        let end = e.run();
        assert_eq!(end, SimTime::from_us(15.0));
        assert_eq!(*seen.borrow(), SimTime::from_us(15.0));
    }

    #[test]
    fn resource_serializes_fifo() {
        // Two 100-byte requests at rate 10 bytes/us, no overhead: the
        // second must wait for the first → completions at 10us and 20us.
        let mut e = Engine::new();
        let r = e.resource(10.0, SimTime::ZERO);
        let done = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..2 {
            let done = done.clone();
            e.serve(r, 100.0, move |e| done.borrow_mut().push(e.now().as_us()));
        }
        e.run();
        assert_eq!(*done.borrow(), vec![10.0, 20.0]);
        let (served, busy) = e.resource_stats(r);
        assert_eq!(served, 2);
        assert_eq!(busy, SimTime::from_us(20.0));
    }

    #[test]
    fn resource_overhead_applies_per_request() {
        let mut e = Engine::new();
        let r = e.resource(100.0, SimTime::from_us(3.0));
        let done = Rc::new(RefCell::new(0.0));
        let d2 = done.clone();
        e.serve(r, 100.0, move |e| *d2.borrow_mut() = e.now().as_us());
        e.run();
        assert!((*done.borrow() - 4.0).abs() < 1e-9); // 1us transfer + 3us overhead
    }

    #[test]
    fn resource_idle_gap_not_counted_busy() {
        let mut e = Engine::new();
        let r = e.resource(10.0, SimTime::ZERO);
        e.serve(r, 50.0, |_| {}); // completes at 5us
        e.at(SimTime::from_us(100.0), move |e| {
            e.serve(r, 50.0, |_| {}); // completes at 105us
        });
        let end = e.run();
        assert_eq!(end, SimTime::from_us(105.0));
        let (_, busy) = e.resource_stats(r);
        assert_eq!(busy, SimTime::from_us(10.0));
    }

    #[test]
    fn peek_does_not_mutate() {
        let mut e = Engine::new();
        let r = e.resource(10.0, SimTime::ZERO);
        let t1 = e.peek_completion(r, 100.0);
        let t2 = e.peek_completion(r, 100.0);
        assert_eq!(t1, t2);
        assert_eq!(t1, SimTime::from_us(10.0));
    }

    #[test]
    fn serve_for_occupies_exact_duration() {
        let mut e = Engine::new();
        let r = e.unit_resource();
        let done = Rc::new(RefCell::new(Vec::new()));
        for dur in [4.0, 6.0] {
            let done = done.clone();
            e.serve_for(r, SimTime::from_us(dur), move |e| {
                done.borrow_mut().push(e.now().as_us());
            });
        }
        e.run();
        assert_eq!(*done.borrow(), vec![4.0, 10.0]);
        let (served, busy) = e.resource_stats(r);
        assert_eq!(served, 2);
        assert_eq!(busy, SimTime::from_us(10.0));
    }

    #[test]
    fn gate_serializes_fifo_and_tracks_busy() {
        // Three holders, each keeping the gate for 10us of chained events:
        // grants at 0/10/20, releases at 10/20/30.
        let mut e = Engine::new();
        let g = e.gate();
        let log = Rc::new(RefCell::new(Vec::new()));
        for tag in ["a", "b", "c"] {
            let log = log.clone();
            e.acquire(g, move |e| {
                log.borrow_mut().push((tag, e.now().as_us()));
                e.after(SimTime::from_us(10.0), move |e| e.release(g));
            });
        }
        let end = e.run();
        assert_eq!(end, SimTime::from_us(30.0));
        assert_eq!(*log.borrow(), vec![("a", 0.0), ("b", 10.0), ("c", 20.0)]);
        let (grants, busy) = e.gate_stats(g);
        assert_eq!(grants, 3);
        assert_eq!(busy, SimTime::from_us(30.0));
    }

    #[test]
    fn gate_idle_time_not_counted() {
        let mut e = Engine::new();
        let g = e.gate();
        e.acquire(g, move |e| e.after(SimTime::from_us(5.0), move |e| e.release(g)));
        e.at(SimTime::from_us(100.0), move |e| {
            e.acquire(g, move |e| e.after(SimTime::from_us(5.0), move |e| e.release(g)));
        });
        e.run();
        let (_, busy) = e.gate_stats(g);
        assert_eq!(busy, SimTime::from_us(10.0));
    }

    #[test]
    fn join_fires_at_last_arrival() {
        // Two predecessors completing at 5us and 12us: the join's action
        // must fire exactly once, at 12us.
        let mut e = Engine::new();
        let fired = Rc::new(RefCell::new(Vec::new()));
        let f2 = fired.clone();
        let j = e.join(2, move |e| f2.borrow_mut().push(e.now().as_us()));
        e.after(SimTime::from_us(5.0), move |e| e.arrive(j));
        e.after(SimTime::from_us(12.0), move |e| e.arrive(j));
        e.run();
        assert_eq!(*fired.borrow(), vec![12.0]);
    }

    #[test]
    fn join_chains_into_resources() {
        // Diamond: two 10us serve_for legs arrive at a join whose action
        // occupies the resource again — classic eligibility-then-FIFO.
        let mut e = Engine::new();
        let r = e.unit_resource();
        let end = Rc::new(RefCell::new(0.0));
        let e2 = end.clone();
        let j = e.join(2, move |e| {
            e.serve_for(r, SimTime::from_us(3.0), move |e| {
                *e2.borrow_mut() = e.now().as_us();
            });
        });
        for _ in 0..2 {
            // both legs queue FIFO on the same resource: done at 10, 20
            e.serve_for(r, SimTime::from_us(10.0), move |e| e.arrive(j));
        }
        e.run();
        assert!((*end.borrow() - 23.0).abs() < 1e-9);
    }

    #[test]
    fn simultaneous_join_firings_resolve_in_arrival_order() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        let (l1, l2) = (log.clone(), log.clone());
        let a = e.join(1, move |_| l1.borrow_mut().push("a"));
        let b = e.join(1, move |_| l2.borrow_mut().push("b"));
        e.after(SimTime::from_us(1.0), move |e| {
            // arrive b first: it must also fire first
            e.arrive(b);
            e.arrive(a);
        });
        e.run();
        assert_eq!(*log.borrow(), vec!["b", "a"]);
    }

    #[test]
    fn determinism_same_program_same_trace() {
        fn run_once() -> Vec<f64> {
            let mut e = Engine::new();
            let r = e.resource(7.0, SimTime::from_us(0.5));
            let out = Rc::new(RefCell::new(Vec::new()));
            for i in 0..20 {
                let out = out.clone();
                e.after(SimTime::from_us(i as f64 * 0.3), move |e| {
                    let out2 = out.clone();
                    e.serve(r, 64.0 * (i % 5 + 1) as f64, move |e| {
                        out2.borrow_mut().push(e.now().as_us());
                    });
                });
            }
            e.run();
            let v = out.borrow().clone();
            v
        }
        assert_eq!(run_once(), run_once());
    }
}
