//! Deterministic span tracing (§Observability).
//!
//! A [`Tracer`] is an optional observer attached to an [`Engine`] at
//! construction when tracing is enabled for the current thread
//! ([`set_enabled`] / [`TraceGuard`]).  It records one [`TraceSpan`] per
//! unit of engine activity — FIFO service intervals (with the queue-wait
//! / service-time split), pure program delays, stream-lane holds, gate
//! holds, join firings — plus calendar-queue peak-depth samples, all
//! from the engine's existing chokepoints.  **No strategy contains
//! tracing code**: every path (graph or serialized, any family) runs
//! through `occupy`/programs/lanes/joins, so instrumenting those five
//! points covers the whole simulator.
//!
//! The observer is pure: it never schedules events, never touches the
//! sequence counter, and the disabled path is a single `Option` branch —
//! tracing off is bit-identical to the tracer never having existed
//! (pinned by `prop_tracing_is_observationally_free`).
//!
//! Two artifacts come out of a traced run:
//! - **Chrome trace-event JSON** ([`TraceReport::chrome_json`], schema
//!   [`TRACE_SCHEMA`]): `ph:"X"` complete events on (pid, tid) tracks —
//!   pid groups by rank / node / engine, tid is one resource, lane, or
//!   program slot — loadable in Perfetto / `chrome://tracing`.  Fully
//!   deterministic: interned names, stable sort, integer-derived
//!   timestamp formatting (no float printing).
//! - **An attribution report** ([`TraceReport`]): per-resource
//!   busy/idle/queue-wait totals with log2 wait histograms,
//!   exposed-vs-overlapped wire time, and the **critical path** — a
//!   retro-walk from the communication end backwards through the span
//!   that produced each arrival, bucketed by span kind so the report
//!   answers "where did the iteration go".
//!
//! ## The retro-walk contract
//!
//! A span's *arrival* (`t0 - queue_wait`) is the engine clock at enqueue
//! time, which is exactly the finish time of its causal predecessor (the
//! previous program step, the join that released the node, the lane
//! launch).  So the critical path needs no recorded edges: starting at
//! the last completion, repeatedly pick the latest-recorded span ending
//! at the current time that advances (nonzero service or wait), charge
//! its service to its kind bucket and its wait to the `queue` bucket,
//! and jump to its arrival.  When no span ends at the current time, the
//! chain starts at a release (tensor readiness) — the remaining prefix
//! is charged to `compute`.  The walk buckets sum to the walk end
//! *exactly* (integer [`SimTime`] arithmetic), and the iteration-level
//! path adds the closing formula's remainder (`skew`, or the
//! compute/staging split when compute-bound) so the full path sums to
//! the iteration time.

use std::cell::Cell;
use std::collections::HashMap;

use super::engine::Engine;
use super::time::SimTime;

/// Schema tag embedded in every exported trace document.
pub const TRACE_SCHEMA: &str = "mpi-dnn-train/trace/v1";

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
}

/// Enable/disable tracing for engines subsequently created **on this
/// thread** (sweep workers spawned elsewhere stay untraced).
pub fn set_enabled(on: bool) {
    ENABLED.with(|f| f.set(on));
}

/// Is tracing enabled for engines created on this thread?
pub fn enabled() -> bool {
    ENABLED.with(|f| f.get())
}

/// RAII scope: tracing on while the guard lives, off when dropped.
pub struct TraceGuard(());

impl TraceGuard {
    #[allow(clippy::new_without_default)]
    pub fn new() -> TraceGuard {
        set_enabled(true);
        TraceGuard(())
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        set_enabled(false);
    }
}

/// Span category — the critical-path attribution buckets.  The first
/// seven mirror [`ResKind`](crate::comm::ResKind); the rest are engine
/// activities with no backing resource kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpanKind {
    Wire,
    Pcie,
    GpuReduce,
    CpuReduce,
    Driver,
    Launch,
    Sw,
    /// Unpinned program step: elapses without contention.
    Delay,
    /// A stream-lane hold (launch → done) — encloses the member spans.
    Lane,
    /// A gate hold (acquire → release).
    Gate,
    /// A join firing (instant).
    Join,
    /// A failure-detection window (fault instant → timeout expiry).
    Fault,
    /// A retry backoff wait (exponential, bounded attempts).
    Backoff,
    /// A recovery rebuild: re-templating the collective over the
    /// surviving world, or reassigning a dead server's shards.
    Rebuild,
    /// Service on a resource nobody registered a name/kind for.
    Other,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Wire => "wire",
            SpanKind::Pcie => "pcie",
            SpanKind::GpuReduce => "gpu-reduce",
            SpanKind::CpuReduce => "cpu-reduce",
            SpanKind::Driver => "driver",
            SpanKind::Launch => "launch",
            SpanKind::Sw => "sw",
            SpanKind::Delay => "delay",
            SpanKind::Lane => "lane",
            SpanKind::Gate => "gate",
            SpanKind::Join => "join",
            SpanKind::Fault => "fault-detect",
            SpanKind::Backoff => "backoff",
            SpanKind::Rebuild => "rebuild",
            SpanKind::Other => "other",
        }
    }

    /// Does the retro-walk step through this span?  Lane/gate holds
    /// *enclose* the serve/delay spans that actually advance time (the
    /// walk would skip over the detail), and joins are instants.
    fn walkable(self) -> bool {
        !matches!(self, SpanKind::Lane | SpanKind::Gate | SpanKind::Join)
    }
}

/// Interned string handle (index into the tracer's string table).
pub type Istr = u32;

/// One recorded activity interval.  `t0` is service start; the span's
/// *arrival* (enqueue time) is `t0 - queue_wait` — the queue-wait /
/// service-time split of the FIFO `occupy` rule.
#[derive(Debug, Clone, Copy)]
pub struct TraceSpan {
    /// Track (Chrome tid) the span renders on.
    pub track: u32,
    /// Interned slice name.
    pub name: Istr,
    pub t0: SimTime,
    pub t1: SimTime,
    pub kind: SpanKind,
    pub bytes: u64,
    /// Owning rank (per-rank resources) or node (shared node resources).
    pub rank: u32,
    pub queue_wait: SimTime,
}

/// A Chrome (pid, tid) lane.
struct Track {
    name: Istr,
    pid: u32,
}

/// pid of engine-global tracks (lanes, program delays, counters).
pub const PID_ENGINE: u32 = 0;

/// pid grouping a rank's private resources.
pub fn pid_rank(rank: usize) -> u32 {
    1 + rank as u32
}

/// pid grouping a node's shared resources (NIC ports, PCIe).
pub fn pid_node(node: usize) -> u32 {
    100_000 + node as u32
}

const HIST_BUCKETS: usize = 16;

/// log2 histogram bucket of a queue wait: bucket 0 is `< 1us`, bucket k
/// covers `[2^(k-1), 2^k) us`, the last bucket absorbs the tail.
fn hist_bucket(wait: SimTime) -> usize {
    let us = wait.0 / 1_000;
    if us == 0 {
        0
    } else {
        ((us.ilog2() + 1) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Per-resource registration + accumulators (see
/// [`Engine::trace_resource`]).
struct ResMeta {
    track: u32,
    label: Istr,
    kind: SpanKind,
    rank: u32,
    wait: SimTime,
    hist: [u64; HIST_BUCKETS],
}

/// The span recorder an enabled engine carries.  All methods are called
/// from inside a `tracer.is_some()` branch in the engine — the recorder
/// observes, it never schedules.
#[derive(Default)]
pub struct Tracer {
    strings: Vec<String>,
    lookup: HashMap<String, Istr>,
    tracks: Vec<Track>,
    spans: Vec<TraceSpan>,
    /// Resource-index → registration (lazy default for unnamed ones).
    res: Vec<Option<ResMeta>>,
    lane_tracks: HashMap<(u32, u32), u32>,
    gate_tracks: HashMap<u32, u32>,
    slot_tracks: Vec<Option<u32>>,
    join_track: Option<u32>,
    recovery_track: Option<u32>,
    /// Stream-lane job arrival times, for the lane-hold queue-wait split.
    lane_arrivals: HashMap<(u32, u32), SimTime>,
    /// Calendar-queue peak-depth samples (time, new peak).
    depth: Vec<(SimTime, usize)>,
    depth_peak: usize,
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer::default()
    }

    pub fn spans(&self) -> &[TraceSpan] {
        &self.spans
    }

    pub fn string(&self, i: Istr) -> &str {
        &self.strings[i as usize]
    }

    fn intern(&mut self, s: &str) -> Istr {
        if let Some(&i) = self.lookup.get(s) {
            return i;
        }
        let i = self.strings.len() as Istr;
        self.strings.push(s.to_string());
        self.lookup.insert(s.to_string(), i);
        i
    }

    fn track(&mut self, name: &str, pid: u32) -> u32 {
        let name = self.intern(name);
        self.tracks.push(Track { name, pid });
        (self.tracks.len() - 1) as u32
    }

    /// Register a resource's identity: track name, span kind, Chrome
    /// pid, owning rank/node.  Unregistered resources fall back to an
    /// anonymous `res{i}` track of kind `Other`.
    pub(crate) fn name_resource(
        &mut self,
        idx: usize,
        kind: SpanKind,
        pid: u32,
        rank: u32,
        name: &str,
    ) {
        if self.res.len() <= idx {
            self.res.resize_with(idx + 1, || None);
        }
        let track = self.track(name, pid);
        let label = self.intern(kind.name());
        self.res[idx] = Some(ResMeta {
            track,
            label,
            kind,
            rank,
            wait: SimTime::ZERO,
            hist: [0; HIST_BUCKETS],
        });
    }

    fn ensure_res(&mut self, idx: usize) {
        if self.res.len() <= idx {
            self.res.resize_with(idx + 1, || None);
        }
        if self.res[idx].is_none() {
            let track = self.track(&format!("res{idx}"), PID_ENGINE);
            let label = self.intern(SpanKind::Other.name());
            self.res[idx] = Some(ResMeta {
                track,
                label,
                kind: SpanKind::Other,
                rank: 0,
                wait: SimTime::ZERO,
                hist: [0; HIST_BUCKETS],
            });
        }
    }

    /// One FIFO service interval on resource `idx`: arrived at
    /// `arrival`, served `[t0, t1]` (the queue-wait split point).
    pub(crate) fn record_serve(
        &mut self,
        idx: usize,
        arrival: SimTime,
        t0: SimTime,
        t1: SimTime,
        bytes: f64,
    ) {
        self.ensure_res(idx);
        let wait = t0.saturating_sub(arrival);
        let (track, name, kind, rank) = {
            let m = self.res[idx].as_mut().expect("ensure_res populated the slot");
            m.wait += wait;
            m.hist[hist_bucket(wait)] += 1;
            (m.track, m.label, m.kind, m.rank)
        };
        self.spans.push(TraceSpan {
            track,
            name,
            t0,
            t1,
            kind,
            bytes: bytes as u64,
            rank,
            queue_wait: wait,
        });
    }

    /// An unpinned program step elapsing `[t0, t1]` on slot `slot`
    /// (slots are exclusive, so per-slot tracks never self-overlap).
    pub(crate) fn record_delay(&mut self, slot: u32, t0: SimTime, t1: SimTime) {
        let s = slot as usize;
        if self.slot_tracks.len() <= s {
            self.slot_tracks.resize(s + 1, None);
        }
        let track = match self.slot_tracks[s] {
            Some(t) => t,
            None => {
                let t = self.track(&format!("prog p{slot}"), PID_ENGINE);
                self.slot_tracks[s] = Some(t);
                t
            }
        };
        let name = self.intern(SpanKind::Delay.name());
        self.spans.push(TraceSpan {
            track,
            name,
            t0,
            t1,
            kind: SpanKind::Delay,
            bytes: 0,
            rank: 0,
            queue_wait: SimTime::ZERO,
        });
    }

    /// A lane job joined its queue (arrival side of the lane-hold wait).
    pub(crate) fn lane_arrived(&mut self, set: u32, job: u32, at: SimTime) {
        self.lane_arrivals.insert((set, job), at);
    }

    /// A lane job finished: held `(set, lane)` over `[t0, t1]`.
    pub(crate) fn record_lane(&mut self, set: u32, lane: u32, job: u32, t0: SimTime, t1: SimTime) {
        let track = match self.lane_tracks.get(&(set, lane)) {
            Some(&t) => t,
            None => {
                let t = self.track(&format!("lanes s{set} l{lane}"), PID_ENGINE);
                self.lane_tracks.insert((set, lane), t);
                t
            }
        };
        let arrival = self.lane_arrivals.remove(&(set, job)).unwrap_or(t0);
        let name = self.intern(&format!("job{job}"));
        self.spans.push(TraceSpan {
            track,
            name,
            t0,
            t1,
            kind: SpanKind::Lane,
            bytes: 0,
            rank: 0,
            queue_wait: t0.saturating_sub(arrival),
        });
    }

    /// A gate hold `[t0, t1]` (acquire → release).
    pub(crate) fn record_gate(&mut self, gate: u32, t0: SimTime, t1: SimTime) {
        let track = match self.gate_tracks.get(&gate) {
            Some(&t) => t,
            None => {
                let t = self.track(&format!("gate g{gate}"), PID_ENGINE);
                self.gate_tracks.insert(gate, t);
                t
            }
        };
        let name = self.intern(SpanKind::Gate.name());
        self.spans.push(TraceSpan {
            track,
            name,
            t0,
            t1,
            kind: SpanKind::Gate,
            bytes: 0,
            rank: 0,
            queue_wait: SimTime::ZERO,
        });
    }

    /// A join fired (instant event).
    pub(crate) fn record_join(&mut self, at: SimTime) {
        let track = match self.join_track {
            Some(t) => t,
            None => {
                let t = self.track("joins", PID_ENGINE);
                self.join_track = Some(t);
                t
            }
        };
        let name = self.intern(SpanKind::Join.name());
        self.spans.push(TraceSpan {
            track,
            name,
            t0: at,
            t1: at,
            kind: SpanKind::Join,
            bytes: 0,
            rank: 0,
            queue_wait: SimTime::ZERO,
        });
    }

    /// A recovery interval `[t0, t1]` (fault detection, backoff wait,
    /// rebuild) on the engine's recovery track.  Recovery intervals are
    /// recorded back-to-back by the fault runners (`queue_wait` 0, each
    /// `t0` the predecessor's `t1`), so the retro-walk chains straight
    /// through them from the restarted communication to the failure
    /// instant.
    pub(crate) fn record_mark(&mut self, kind: SpanKind, t0: SimTime, t1: SimTime) {
        let track = match self.recovery_track {
            Some(t) => t,
            None => {
                let t = self.track("recovery", PID_ENGINE);
                self.recovery_track = Some(t);
                t
            }
        };
        let name = self.intern(kind.name());
        self.spans.push(TraceSpan {
            track,
            name,
            t0,
            t1,
            kind,
            bytes: 0,
            rank: 0,
            queue_wait: SimTime::ZERO,
        });
    }

    /// Drop spans ending after `at` — the trace side of a fault cut:
    /// whatever the aborted timeline would have finished after the
    /// failure instant never happened.  (A span spanning the cut is
    /// dropped whole rather than clipped; the truncated timeline stays
    /// internally consistent because the engine also discards the
    /// events that would have produced successors.)
    pub(crate) fn truncate(&mut self, at: SimTime) {
        self.spans.retain(|s| s.t1 <= at);
    }

    /// Sample the calendar queue when its depth reaches a new high-water
    /// mark (monotone samples ⇒ bounded, deterministic counter track).
    pub(crate) fn sample_depth(&mut self, at: SimTime, len: usize) {
        if len > self.depth_peak {
            self.depth_peak = len;
            self.depth.push((at, len));
        }
    }

    /// Fold the recorded spans plus the engine's service ledgers into
    /// the attribution report + Chrome JSON.  `parts` carries the
    /// iteration closing formula's terms so the critical path can be
    /// composed to sum to the full iteration time.
    pub fn into_report(self, e: &Engine, parts: IterationParts) -> TraceReport {
        let chrome_json = self.chrome_json(&parts);
        let (walk_end, comm_path) = self.retro_walk();

        // iteration-level composition (exact by remainder construction)
        let comm_bound = parts.comm.as_us() >= parts.compute_us + parts.staging_us;
        let mut critical_path = Vec::new();
        if comm_bound {
            critical_path.clone_from(&comm_path);
            let skew = parts.iter.saturating_sub(walk_end);
            if skew > SimTime::ZERO {
                critical_path.push(PathBucket { label: "skew", time: skew });
            }
        } else {
            let staging = SimTime::from_us(parts.staging_us).min(parts.iter);
            let skew = SimTime::from_us(parts.skew_us).min(parts.iter.saturating_sub(staging));
            let compute = parts.iter.saturating_sub(staging).saturating_sub(skew);
            for (label, time) in
                [("compute", compute), ("staging", staging), ("skew", skew)]
            {
                if time > SimTime::ZERO {
                    critical_path.push(PathBucket { label, time });
                }
            }
        }

        // exposed vs overlapped wire time against the compute window
        let window = SimTime::from_us(parts.compute_us);
        let (mut overlapped, mut exposed) = (SimTime::ZERO, SimTime::ZERO);
        for s in &self.spans {
            if s.kind != SpanKind::Wire {
                continue;
            }
            let inside = s.t1.min(window).saturating_sub(s.t0.min(window));
            overlapped += inside;
            exposed += (s.t1 - s.t0).saturating_sub(inside);
        }

        let mut resources = Vec::new();
        for (idx, meta) in self.res.iter().enumerate() {
            let Some(m) = meta else { continue };
            let stats = e.resource_stats(super::engine::ResourceId::from_index(idx));
            if stats.served == 0 {
                continue;
            }
            resources.push(ResourceRow {
                name: self.strings[self.tracks[m.track as usize].name as usize].clone(),
                kind: m.kind,
                served: stats.served,
                busy: stats.busy,
                idle: parts.iter.saturating_sub(stats.busy),
                queue_wait: m.wait,
                wait_hist: m.hist,
            });
        }

        TraceReport {
            iter: parts.iter,
            comm_end: walk_end,
            spans: self.spans.len(),
            engine_events: e.executed(),
            queue_peak: e.queue_peak(),
            critical_path,
            comm_path,
            exposed_wire: exposed,
            overlapped_wire: overlapped,
            resources,
            chrome_json,
        }
    }

    /// The critical-path retro-walk (module docs): returns the walk end
    /// (last walkable completion) and the kind buckets, which sum to the
    /// walk end exactly.
    fn retro_walk(&self) -> (SimTime, Vec<PathBucket>) {
        let mut by_end: Vec<(u64, u32)> = self
            .spans
            .iter()
            .enumerate()
            .filter(|(_, s)| s.kind.walkable())
            .map(|(i, s)| (s.t1.0, i as u32))
            .collect();
        by_end.sort_unstable();
        let end = by_end.last().map(|&(t, _)| SimTime(t)).unwrap_or(SimTime::ZERO);

        let mut buckets: Vec<PathBucket> = Vec::new();
        let mut add = |label: &'static str, dt: SimTime| {
            if dt == SimTime::ZERO {
                return;
            }
            match buckets.iter_mut().find(|b| b.label == label) {
                Some(b) => b.time += dt,
                None => buckets.push(PathBucket { label, time: dt }),
            }
        };

        let mut t = end;
        while t > SimTime::ZERO {
            let hi = by_end.partition_point(|&(at, _)| at <= t.0);
            let lo = by_end.partition_point(|&(at, _)| at < t.0);
            // latest-recorded span ending exactly at `t` that advances
            let step = by_end[lo..hi]
                .iter()
                .rev()
                .map(|&(_, i)| &self.spans[i as usize])
                .find(|s| s.t1 > s.t0 || s.queue_wait > SimTime::ZERO);
            match step {
                Some(s) => {
                    add(s.kind.name(), s.t1 - s.t0);
                    add("queue", s.queue_wait);
                    t = s.t0.saturating_sub(s.queue_wait);
                }
                None => {
                    // chain start: a timed release (tensor readiness) —
                    // the prefix is the producing compute
                    add("compute", t);
                    break;
                }
            }
        }
        (end, buckets)
    }

    /// Serialize to Chrome trace-event JSON (deterministic: stable span
    /// sort, interned names, integer-derived timestamps).
    fn chrome_json(&self, parts: &IterationParts) -> String {
        use std::fmt::Write as _;

        // ts/dur in microseconds with ns precision, no float formatting
        fn us(t: SimTime) -> String {
            format!("{}.{:03}", t.0 / 1_000, t.0 % 1_000)
        }
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        fn pid_name(pid: u32) -> String {
            if pid == PID_ENGINE {
                "engine".to_string()
            } else if pid < 100_000 {
                format!("rank {}", pid - 1)
            } else {
                format!("node {}", pid - 100_000)
            }
        }

        let mut out = String::with_capacity(128 + self.spans.len() * 96);
        let _ = write!(out, "{{\"schema\":\"{TRACE_SCHEMA}\",\"displayTimeUnit\":\"ms\",");
        out.push_str("\"traceEvents\":[");
        let mut first = true;
        let mut push = |out: &mut String, ev: String| {
            if !std::mem::take(&mut first) {
                out.push(',');
            }
            out.push_str(&ev);
        };

        // process names, first-seen order over the track table
        let mut seen_pids: Vec<u32> = Vec::new();
        for t in &self.tracks {
            if !seen_pids.contains(&t.pid) {
                seen_pids.push(t.pid);
            }
        }
        for &pid in &seen_pids {
            push(
                &mut out,
                format!(
                    "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    esc(&pid_name(pid))
                ),
            );
        }
        for (tid, t) in self.tracks.iter().enumerate() {
            push(
                &mut out,
                format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{},\"tid\":{tid},\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    t.pid,
                    esc(&self.strings[t.name as usize])
                ),
            );
        }

        // synthetic compute span so the overlap is visible next to comm
        let compute = SimTime::from_us(parts.compute_us);
        if compute > SimTime::ZERO {
            let tid = self.tracks.len();
            push(
                &mut out,
                format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{PID_ENGINE},\"tid\":{tid},\
                     \"args\":{{\"name\":\"iteration\"}}}}"
                ),
            );
            push(
                &mut out,
                format!(
                    "{{\"name\":\"compute\",\"ph\":\"X\",\"pid\":{PID_ENGINE},\"tid\":{tid},\
                     \"ts\":0.000,\"dur\":{},\"args\":{{\"kind\":\"compute\"}}}}",
                    us(compute)
                ),
            );
        }

        // spans, stable-sorted by (pid, tid, t0, recording order)
        let mut order: Vec<u32> = (0..self.spans.len() as u32).collect();
        order.sort_by_key(|&i| {
            let s = &self.spans[i as usize];
            (self.tracks[s.track as usize].pid, s.track, s.t0.0, i)
        });
        for &i in &order {
            let s = &self.spans[i as usize];
            let pid = self.tracks[s.track as usize].pid;
            let name = esc(&self.strings[s.name as usize]);
            if s.kind == SpanKind::Join {
                push(
                    &mut out,
                    format!(
                        "{{\"name\":\"{name}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":{pid},\
                         \"tid\":{},\"ts\":{}}}",
                        s.track,
                        us(s.t0)
                    ),
                );
                continue;
            }
            let mut args = format!("\"kind\":\"{}\",\"rank\":{}", s.kind.name(), s.rank);
            if s.bytes > 0 {
                let _ = write!(args, ",\"bytes\":{}", s.bytes);
            }
            if s.queue_wait > SimTime::ZERO {
                let _ = write!(args, ",\"queue_wait_us\":{}", us(s.queue_wait));
            }
            push(
                &mut out,
                format!(
                    "{{\"name\":\"{name}\",\"ph\":\"X\",\"pid\":{pid},\"tid\":{},\
                     \"ts\":{},\"dur\":{},\"args\":{{{args}}}}}",
                    s.track,
                    us(s.t0),
                    us(s.t1.saturating_sub(s.t0))
                ),
            );
        }

        // calendar-queue peak-depth counter samples
        for &(at, len) in &self.depth {
            push(
                &mut out,
                format!(
                    "{{\"name\":\"event-queue-depth\",\"ph\":\"C\",\"pid\":{PID_ENGINE},\
                     \"tid\":0,\"ts\":{},\"args\":{{\"depth\":{len}}}}}",
                    us(at)
                ),
            );
        }

        out.push_str("]}");
        out
    }
}

/// The iteration closing formula's terms, handed to the report builder
/// so the critical path composes to the full iteration time (see
/// `strategies::close_iteration`).
#[derive(Debug, Clone, Copy)]
pub struct IterationParts {
    pub iter: SimTime,
    /// Communication completion relative to the job's offset.
    pub comm: SimTime,
    /// Dilated compute (stretch + runtime tax applied), µs.
    pub compute_us: f64,
    /// Critical host-staging share charged to the compute path, µs.
    pub staging_us: f64,
    /// Synchronization skew + jitter, µs.
    pub skew_us: f64,
}

impl IterationParts {
    /// A bare engine run with no closing formula (e.g. the `graph`
    /// subcommand): the "iteration" is the communication itself.
    pub fn comm_only(end: SimTime) -> IterationParts {
        IterationParts { iter: end, comm: end, compute_us: 0.0, staging_us: 0.0, skew_us: 0.0 }
    }
}

/// One critical-path bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PathBucket {
    pub label: &'static str,
    pub time: SimTime,
}

/// Per-resource attribution row: service ledger ([`ServiceStats`]
/// (super::engine::ServiceStats) via the engine) + span-derived waits.
#[derive(Debug, Clone)]
pub struct ResourceRow {
    pub name: String,
    pub kind: SpanKind,
    pub served: u64,
    pub busy: SimTime,
    pub idle: SimTime,
    pub queue_wait: SimTime,
    pub wait_hist: [u64; HIST_BUCKETS],
}

/// The attribution report of one traced run (attached to
/// `IterationReport::trace`).
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub iter: SimTime,
    /// Last walkable span completion (the communication end).
    pub comm_end: SimTime,
    pub spans: usize,
    pub engine_events: u64,
    pub queue_peak: usize,
    /// Buckets summing to `iter` exactly.
    pub critical_path: Vec<PathBucket>,
    /// The raw retro-walk buckets, summing to `comm_end` exactly.
    pub comm_path: Vec<PathBucket>,
    pub exposed_wire: SimTime,
    pub overlapped_wire: SimTime,
    pub resources: Vec<ResourceRow>,
    /// Chrome trace-event document ([`TRACE_SCHEMA`]).
    pub chrome_json: String,
}

impl TraceReport {
    /// Human-readable attribution tables.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace: {} spans, {} engine events, queue peak {} (iter {}, comm end {})",
            self.spans, self.engine_events, self.queue_peak, self.iter, self.comm_end
        );
        let _ = writeln!(out, "critical path (sums to iteration):");
        for b in &self.critical_path {
            let pct = 100.0 * b.time.as_us() / self.iter.as_us().max(1e-9);
            let _ = writeln!(out, "  {:<12} {:>12}  {pct:5.1}%", b.label, b.time.to_string());
        }
        let _ = writeln!(
            out,
            "wire time: {} exposed past compute, {} overlapped",
            self.exposed_wire, self.overlapped_wire
        );
        let _ = writeln!(
            out,
            "{:<20} {:>8} {:>12} {:>12} {:>12}  wait histogram (log2 us)",
            "resource", "served", "busy", "idle", "queue-wait"
        );
        for r in &self.resources {
            let hist: Vec<String> = r
                .wait_hist
                .iter()
                .enumerate()
                .filter(|&(_, &n)| n > 0)
                .map(|(k, &n)| format!("<{}us:{n}", 1u64 << k))
                .collect();
            let _ = writeln!(
                out,
                "{:<20} {:>8} {:>12} {:>12} {:>12}  {}",
                r.name,
                r.served,
                r.busy.to_string(),
                r.idle.to_string(),
                r.queue_wait.to_string(),
                hist.join(" ")
            );
        }
        out
    }
}

/// Validate a Chrome trace document produced by this module: it parses,
/// carries the schema tag, every complete event has sane fields, tracks
/// are time-sorted, and resource-kind tracks never self-overlap.
/// Returns the event count.
pub fn validate_chrome_json(text: &str) -> crate::util::error::Result<usize> {
    use crate::util::json::Json;
    let doc = Json::parse(text).map_err(|e| crate::anyhow!("trace JSON: {e}"))?;
    crate::ensure!(
        doc.get("schema").and_then(Json::as_str) == Some(TRACE_SCHEMA),
        "missing/unknown schema tag (want {TRACE_SCHEMA})"
    );
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| crate::anyhow!("traceEvents missing"))?;
    // per-(pid, tid): last seen ts, and last end of a non-overlapping
    // resource-kind span
    let mut last_ts: HashMap<(u64, u64), f64> = HashMap::new();
    let mut last_end: HashMap<(u64, u64), f64> = HashMap::new();
    let serialized_kinds =
        ["wire", "pcie", "gpu-reduce", "cpu-reduce", "driver", "launch", "sw", "other"];
    for (i, ev) in events.iter().enumerate() {
        let ph = ev.get("ph").and_then(Json::as_str).unwrap_or("");
        crate::ensure!(
            matches!(ph, "X" | "M" | "C" | "i"),
            "event {i}: unexpected ph `{ph}`"
        );
        if ph == "M" {
            continue;
        }
        let pid = ev.req_usize("pid")? as u64;
        let tid = ev.req_usize("tid").unwrap_or(0) as u64;
        let ts = ev.req_f64("ts")?;
        crate::ensure!(ts >= 0.0, "event {i}: negative ts");
        let prev = last_ts.insert((pid, tid), ts).unwrap_or(0.0);
        crate::ensure!(
            ts >= prev || ph == "C" || ph == "i",
            "event {i}: track (pid {pid}, tid {tid}) not time-sorted ({ts} < {prev})"
        );
        if ph != "X" {
            continue;
        }
        let dur = ev.req_f64("dur")?;
        crate::ensure!(dur >= 0.0, "event {i}: negative dur");
        let kind = ev
            .get("args")
            .and_then(|a| a.get("kind"))
            .and_then(Json::as_str)
            .unwrap_or("");
        if serialized_kinds.contains(&kind) {
            let end = last_end.get(&(pid, tid)).copied().unwrap_or(0.0);
            // FIFO resources serialize: spans on one track never overlap
            // (1ns slack for the µs decimal formatting)
            crate::ensure!(
                ts >= end - 0.001,
                "event {i}: `{kind}` spans overlap on (pid {pid}, tid {tid}): {ts} < {end}"
            );
            last_end.insert((pid, tid), ts + dur);
        }
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_are_log2_us() {
        assert_eq!(hist_bucket(SimTime::ZERO), 0);
        assert_eq!(hist_bucket(SimTime::from_us(0.5)), 0);
        assert_eq!(hist_bucket(SimTime::from_us(1.0)), 1);
        assert_eq!(hist_bucket(SimTime::from_us(1.9)), 1);
        assert_eq!(hist_bucket(SimTime::from_us(2.0)), 2);
        assert_eq!(hist_bucket(SimTime::from_us(1e9)), HIST_BUCKETS - 1);
    }

    #[test]
    fn guard_scopes_enablement() {
        assert!(!enabled());
        {
            let _g = TraceGuard::new();
            assert!(enabled());
        }
        assert!(!enabled());
    }

    #[test]
    fn retro_walk_sums_to_end_and_splits_queue() {
        // two serves back-to-back on one FIFO: [0,10] then wait 5 +
        // serve [10,18] (arrived at 5), ending at 18
        let mut t = Tracer::new();
        t.name_resource(0, SpanKind::Wire, PID_ENGINE, 0, "wire");
        t.record_serve(0, SimTime::ZERO, SimTime::ZERO, SimTime::from_us(10.0), 0.0);
        let us = SimTime::from_us;
        t.record_serve(0, us(5.0), us(10.0), us(18.0), 0.0);
        let (end, buckets) = t.retro_walk();
        assert_eq!(end, SimTime::from_us(18.0));
        let total: u64 = buckets.iter().map(|b| b.time.0).sum();
        assert_eq!(SimTime(total), end);
        let wire = buckets.iter().find(|b| b.label == "wire").unwrap().time;
        let queue = buckets.iter().find(|b| b.label == "queue").unwrap().time;
        // walk: [10,18] wire 8 + wait 5 → arrival 5 → compute [0,5]
        assert_eq!(wire, SimTime::from_us(8.0));
        assert_eq!(queue, SimTime::from_us(5.0));
        assert_eq!(
            buckets.iter().find(|b| b.label == "compute").unwrap().time,
            SimTime::from_us(5.0)
        );
    }

    #[test]
    fn retro_walk_chains_through_recovery_marks() {
        // pre-fault serve [0,10], cut at 12, recovery marks 12→15→19→25,
        // restarted serve [25,30]: the walk must charge wire 5, rebuild 6,
        // backoff 4, fault-detect 3, compute 12 — summing to 30 exactly.
        let us = SimTime::from_us;
        let mut t = Tracer::new();
        t.name_resource(0, SpanKind::Wire, PID_ENGINE, 0, "wire");
        t.record_serve(0, SimTime::ZERO, SimTime::ZERO, us(10.0), 0.0);
        t.record_serve(0, us(12.5), us(12.5), us(40.0), 0.0); // post-cut span
        t.truncate(us(12.0));
        t.record_mark(SpanKind::Fault, us(12.0), us(15.0));
        t.record_mark(SpanKind::Backoff, us(15.0), us(19.0));
        t.record_mark(SpanKind::Rebuild, us(19.0), us(25.0));
        t.record_serve(0, us(25.0), us(25.0), us(30.0), 0.0);
        let (end, buckets) = t.retro_walk();
        assert_eq!(end, us(30.0));
        let total: u64 = buckets.iter().map(|b| b.time.0).sum();
        assert_eq!(SimTime(total), end, "buckets must sum to the walk end");
        let get = |label: &str| {
            buckets.iter().find(|b| b.label == label).map(|b| b.time).unwrap_or(SimTime::ZERO)
        };
        assert_eq!(get("wire"), us(5.0), "the truncated 40us span must be gone");
        assert_eq!(get("rebuild"), us(6.0));
        assert_eq!(get("backoff"), us(4.0));
        assert_eq!(get("fault-detect"), us(3.0));
        assert_eq!(get("compute"), us(12.0));
    }

    #[test]
    fn recovery_marks_export_valid_chrome_json() {
        let us = SimTime::from_us;
        let mut t = Tracer::new();
        t.record_mark(SpanKind::Fault, SimTime::ZERO, us(3.0));
        t.record_mark(SpanKind::Backoff, us(3.0), us(5.0));
        t.record_mark(SpanKind::Rebuild, us(5.0), us(9.0));
        let doc = t.chrome_json(&IterationParts::comm_only(us(9.0)));
        validate_chrome_json(&doc).expect("recovery spans must validate");
        assert!(doc.contains("fault-detect") && doc.contains("backoff") && doc.contains("rebuild"));
    }

    #[test]
    fn chrome_json_is_valid_and_deterministic() {
        let build = || {
            let mut t = Tracer::new();
            t.name_resource(0, SpanKind::Wire, pid_node(0), 0, "wire n0");
            t.name_resource(1, SpanKind::GpuReduce, pid_rank(1), 1, "gpu-reduce r1");
            t.record_serve(0, SimTime::ZERO, SimTime::ZERO, SimTime::from_us(3.5), 1024.0);
            t.record_serve(
                1,
                SimTime::from_us(1.0),
                SimTime::from_us(3.5),
                SimTime::from_us(4.0),
                0.0,
            );
            t.record_join(SimTime::from_us(4.0));
            t.record_delay(0, SimTime::from_us(4.0), SimTime::from_us(6.0));
            t.sample_depth(SimTime::ZERO, 3);
            t.chrome_json(&IterationParts::comm_only(SimTime::from_us(6.0)))
        };
        let a = build();
        let b = build();
        assert_eq!(a, b, "same recording must serialize byte-identically");
        let n = validate_chrome_json(&a).expect("valid trace");
        assert!(n >= 6, "expected metadata + spans, got {n} events");
    }

    #[test]
    fn validator_rejects_garbage_and_overlaps() {
        assert!(validate_chrome_json("{").is_err());
        assert!(validate_chrome_json("{\"traceEvents\":[]}").is_err(), "schema tag required");
        let overlap = format!(
            "{{\"schema\":\"{TRACE_SCHEMA}\",\"traceEvents\":[\
             {{\"name\":\"a\",\"ph\":\"X\",\"pid\":1,\"tid\":1,\"ts\":0.0,\"dur\":10.0,\
              \"args\":{{\"kind\":\"wire\"}}}},\
             {{\"name\":\"b\",\"ph\":\"X\",\"pid\":1,\"tid\":1,\"ts\":5.0,\"dur\":10.0,\
              \"args\":{{\"kind\":\"wire\"}}}}]}}"
        );
        assert!(validate_chrome_json(&overlap).is_err(), "overlapping wire spans must fail");
    }
}
