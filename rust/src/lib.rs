//! # mpi-dnn-train
//!
//! A production-shaped reproduction of **"Scalable Distributed DNN Training
//! using TensorFlow and CUDA-Aware MPI: Characterization, Designs, and
//! Performance Evaluation"** (Awan et al., CCGrid 2019) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: a deterministic discrete-event
//!   cluster simulator, MPI/gRPC/Verbs/NCCL communication substrates, the
//!   paper's optimized Allreduce (recursive halving/doubling RSA with
//!   GPU-kernel reductions + pointer cache), all seven distributed-training
//!   strategies, DNN workload profiles, a real data-parallel trainer, and a
//!   figure-regeneration bench harness.
//! * **L2** — a JAX transformer (python/compile/model.py) AOT-lowered to
//!   HLO text, executed here via the PJRT CPU client.
//! * **L1** — Pallas kernels (python/compile/kernels/) for the reduction
//!   and the fused optimizer, lowered into the same artifacts.
//!
//! See DESIGN.md for the experiment index and the substitution ledger
//! (real GPU clusters → simulated substrates).

pub mod bench;
pub mod cluster;
pub mod xla;
pub mod comm;
pub mod config;
pub mod models;
pub mod runtime;
pub mod sim;
pub mod strategies;
pub mod trainer;
pub mod util;
