//! §Perf harness — `mpi-dnn-train perf`.
//!
//! Times representative simulator workloads and reports events/s + wall
//! milliseconds, seeding the repo's engine-throughput trajectory
//! (`BENCH_engine.json`).  Event *counts* are deterministic (the engine
//! is bit-reproducible); wall times vary with the host, which is why the
//! CI job that runs this is non-gating.
//!
//! Workloads:
//!  * `engine-churn` — pure event-core throughput: schedule-and-serve
//!    churn through the typed event heap, no strategy logic.
//!  * `graph-replay` — one cached ring [`GraphTemplate`] replayed many
//!    times under the neutral overlay: the build-once/replay-many path
//!    every per-rank-skew iteration rides.
//!  * `sweep-serialized` — fig9-style Horovod iterations (neutral
//!    scenario → serialized `CommOp` replay), the path every figure
//!    sweep point takes.
//!  * `sweep-graph` — the same points under a straggler scenario, which
//!    routes onto per-rank `CommGraph` execution (~`world`× the events).
//!  * `sweep-dense` — the same model on a dense-node cluster (4 GPUs per
//!    node, 2 NIC rails): the placement-aware graph path, where
//!    co-located ranks queue on shared node ports and intra-node hops
//!    ride PCIe — tracks the placed `GraphResources` layout across PRs.
//!  * `overlap-sweep` — a streams × fusion-cycle grid (§Overlap): the
//!    stream-lane execution model where fusion buffers' graphs
//!    interleave instead of serializing on the comm thread — tracks the
//!    overlapped hot path across PRs.
//!
//! `check_against` diffs a fresh run's deterministic event counts
//! against the committed `BENCH_engine.json` baseline (the CI
//! `perf-smoke` job runs it), so the bench trajectory accumulates
//! instead of each PR's numbers vanishing into artifacts.

use std::time::Instant;

use super::table::Table;
use crate::cluster::presets;
use crate::comm::allreduce::{shadow_steps, Algo};
use crate::comm::graph::{ring_graph, GraphOverlay, GraphResources, GraphTemplate};
use crate::comm::{MpiFlavor, MpiWorld};
use crate::models::mobilenet;
use crate::sim::{Engine, SimTime};
use crate::strategies::{Horovod, Scenario, Strategy, WorldSpec};
use crate::util::error::Result;
use crate::util::json::{arr, num, obj, s, Json};

/// One timed workload: `events` is deterministic, `wall_ms` is not.
#[derive(Debug, Clone)]
pub struct PerfWorkload {
    pub name: String,
    pub detail: String,
    pub runs: usize,
    pub events: u64,
    pub wall_ms: f64,
}

impl PerfWorkload {
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / (self.wall_ms / 1e3).max(1e-9)
    }
}

fn timed(name: &str, detail: String, runs: usize, body: impl FnOnce() -> u64) -> PerfWorkload {
    let t0 = Instant::now();
    let events = body();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    PerfWorkload { name: name.to_string(), detail, runs, events, wall_ms }
}

/// Run every workload.  `quick` shrinks sizes for CI smoke runs.
pub fn run_perf(quick: bool) -> Result<Vec<PerfWorkload>> {
    let mut out = Vec::new();

    // --- 1. pure event-core churn --------------------------------------
    let n: u64 = if quick { 50_000 } else { 200_000 };
    let reps = if quick { 2 } else { 5 };
    out.push(timed(
        "engine-churn",
        format!("{n} timers + {n} FIFO serves per run"),
        reps,
        || {
            let mut events = 0u64;
            for _ in 0..reps {
                let mut e = Engine::new();
                let r = e.resource(10.0, SimTime::ZERO);
                for i in 0..n {
                    e.at(SimTime(i * 10), move |e| {
                        e.serve(r, 64.0, |_| {});
                    });
                }
                e.run();
                events += e.executed();
            }
            events
        },
    ));

    // --- 2. cached-template graph replay -------------------------------
    let p = if quick { 16 } else { 32 };
    let replays = if quick { 20 } else { 100 };
    let bytes = 4usize << 20;
    let w = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());
    let (_, mut ctx) = w.plan(bytes);
    let (_, steps) = shadow_steps(Algo::Ring, p, bytes / 4, &mut ctx);
    let template = GraphTemplate::new(ring_graph(p, &steps));
    let nodes = template.graph().len();
    let neutral = GraphOverlay::neutral();
    out.push(timed(
        "graph-replay",
        format!("ring p={p} ({nodes} nodes) × {replays} replays of one template"),
        replays,
        || {
            let mut events = 0u64;
            for _ in 0..replays {
                let mut e = Engine::new();
                let res = GraphResources::install(&mut e, p);
                template.execute(&mut e, res.mapper(), &neutral, Box::new(|_| {}));
                e.run();
                events += e.executed();
            }
            events
        },
    ));

    // --- 3/4. fig9-style strategy sweeps --------------------------------
    let worlds: &[usize] = if quick { &[16] } else { &[32, 64, 128] };
    let passes = if quick { 1 } else { 3 };
    let cluster = presets::piz_daint();
    let model = mobilenet::mobilenet_v1();
    let h = Horovod::mpi(MpiFlavor::CrayMpich);
    let sweep = |sc: &Scenario| -> Result<u64> {
        let mut events = 0u64;
        for _ in 0..passes {
            for &world in worlds {
                let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
                events += h.iteration_in(&ws, sc)?.engine_events;
            }
        }
        Ok(events)
    };

    let neutral_sc = Scenario::default();
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "sweep-serialized",
        format!("Horovod-MPI MobileNet pizdaint@{worlds:?} × {passes} passes, neutral"),
        passes * worlds.len(),
        || match sweep(&neutral_sc) {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    let straggler = Scenario::straggler(1, 1.5);
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "sweep-graph",
        format!(
            "Horovod-MPI MobileNet pizdaint@{worlds:?} × {passes} passes, straggler 1×1.5 \
             (per-rank CommGraph path)"
        ),
        passes * worlds.len(),
        || match sweep(&straggler) {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    // --- 5. dense-node placement sweep ----------------------------------
    let mut dense = cluster.clone();
    dense.gpus_per_node = 4;
    dense.nic_rails = 2;
    let dense_sweep = || -> Result<u64> {
        let mut events = 0u64;
        for _ in 0..passes {
            for &world in worlds {
                let ws = WorldSpec::new(dense.clone(), model.clone(), world);
                // neutral scenario + dense placement routes onto the
                // placed graph path
                events += h.iteration_in(&ws, &Scenario::default())?.engine_events;
            }
        }
        Ok(events)
    };
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "sweep-dense",
        format!(
            "Horovod-MPI MobileNet pizdaint(4 GPUs/node, 2 rails)@{worlds:?} × {passes} \
             passes, neutral (placed CommGraph path)"
        ),
        passes * worlds.len(),
        || match dense_sweep() {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    // --- 6. overlap sweep: streams × fusion-cycle grid ------------------
    let overlap_worlds: &[usize] = if quick { &[16] } else { &[32, 64] };
    let stream_counts = [1usize, 2, 4];
    let cycle_grid = [2_500.0f64, 5_000.0];
    let overlap_sweep = || -> Result<u64> {
        let mut events = 0u64;
        for _ in 0..passes {
            for &world in overlap_worlds {
                for &cycle_us in &cycle_grid {
                    let mut hv = h.clone();
                    hv.cycle_us = cycle_us;
                    for &s in &stream_counts {
                        let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
                        events += hv.iteration_in(&ws, &Scenario::overlap(s))?.engine_events;
                    }
                }
            }
        }
        Ok(events)
    };
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "overlap-sweep",
        format!(
            "Horovod-MPI MobileNet pizdaint@{overlap_worlds:?} × streams {stream_counts:?} × \
             cycle {cycle_grid:?}us × {passes} passes (stream-lane interleaving; streams = 1 \
             is the serialized baseline)"
        ),
        passes * overlap_worlds.len() * stream_counts.len() * cycle_grid.len(),
        || match overlap_sweep() {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    Ok(out)
}

/// Diff a fresh run's workloads against a committed baseline file.
/// Event counts are deterministic, so a count delta is a real
/// execution-model change worth a look (the report is informational —
/// the CI job that prints it is non-gating); wall times are
/// host-dependent and only summarized.  A missing or empty baseline
/// seeds the trajectory instead of failing.
pub fn check_against(
    fresh: &[PerfWorkload],
    quick: bool,
    path: &std::path::Path,
) -> Result<String> {
    use std::fmt::Write as _;
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => {
            return Ok(format!(
                "perf-check: no baseline at {} — this run seeds the trajectory",
                path.display()
            ))
        }
    };
    let json = Json::parse(&text)
        .map_err(|e| crate::anyhow!("perf-check: {} is not valid JSON: {e}", path.display()))?;
    let base: &[Json] = json.get("workloads").and_then(|w| w.as_arr()).unwrap_or(&[]);
    if base.is_empty() {
        return Ok(format!(
            "perf-check: baseline {} has no workloads yet — this run seeds the trajectory",
            path.display()
        ));
    }
    // quick and full runs size their workloads differently, so their
    // event counts are incomparable by design — flag the mode mismatch
    // instead of reporting every row as drift
    if let Some(base_quick) = json.get("quick").and_then(|v| v.as_bool()) {
        if base_quick != quick {
            return Ok(format!(
                "perf-check: mode mismatch — this run is {} but baseline {} is {}; \
                 regenerate the baseline in the same mode before comparing",
                if quick { "--quick" } else { "full" },
                path.display(),
                if base_quick { "--quick" } else { "full" },
            ));
        }
    }
    let base_of = |name: &str| {
        base.iter()
            .find(|w| w.get("name").and_then(|n| n.as_str()) == Some(name))
    };
    let mut out = format!("perf-check vs {}:\n", path.display());
    for w in fresh {
        match base_of(&w.name) {
            None => {
                let _ = writeln!(out, "  {:<16} NEW workload ({} events)", w.name, w.events);
            }
            Some(b) => {
                let b_events = b.get("events").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                let b_wall = b.get("wall_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
                if b_events == w.events {
                    let _ = writeln!(
                        out,
                        "  {:<16} events unchanged ({}); wall {:.1}ms (baseline {:.1}ms)",
                        w.name, w.events, w.wall_ms, b_wall
                    );
                } else {
                    let delta =
                        100.0 * (w.events as f64 - b_events as f64) / (b_events as f64).max(1.0);
                    let _ = writeln!(
                        out,
                        "  {:<16} events {} vs baseline {} ({delta:+.1}%) — deterministic \
                         drift, review the execution-model change",
                        w.name, w.events, b_events
                    );
                }
            }
        }
    }
    for b in base {
        if let Some(name) = b.get("name").and_then(|n| n.as_str()) {
            if !fresh.iter().any(|w| w.name == name) {
                let _ = writeln!(out, "  {name:<16} REMOVED (present only in the baseline)");
            }
        }
    }
    Ok(out)
}

/// Render the workloads as the CLI table.
pub fn perf_table(workloads: &[PerfWorkload], quick: bool) -> Table {
    let title = if quick {
        "Perf harness (quick): simulator throughput"
    } else {
        "Perf harness: simulator throughput"
    };
    let mut t = Table::new(title, &["workload", "runs", "events", "wall ms", "events/s"]);
    for w in workloads {
        t.row([
            w.name.clone(),
            w.runs.to_string(),
            w.events.to_string(),
            format!("{:.1}", w.wall_ms),
            format!("{:.0}", w.events_per_sec()),
        ]);
    }
    for w in workloads {
        t.note(format!("{}: {}", w.name, w.detail));
    }
    t.note("event counts are deterministic; wall times vary with the host (non-gating in CI)");
    t
}

/// The `BENCH_engine.json` payload.
pub fn perf_json(workloads: &[PerfWorkload], quick: bool) -> Json {
    obj(vec![
        ("schema", s("mpi-dnn-train/bench-engine/v1")),
        ("quick", Json::Bool(quick)),
        (
            "workloads",
            arr(workloads.iter().map(|w| {
                obj(vec![
                    ("name", s(&w.name)),
                    ("detail", s(&w.detail)),
                    ("runs", num(w.runs as f64)),
                    ("events", num(w.events as f64)),
                    ("wall_ms", num(w.wall_ms)),
                    ("events_per_sec", num(w.events_per_sec())),
                ])
            })),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_perf_produces_all_workloads_with_events() {
        let ws = run_perf(true).unwrap();
        assert_eq!(ws.len(), 6);
        for w in &ws {
            assert!(w.events > 0, "{}: no events", w.name);
            assert!(w.events_per_sec() > 0.0, "{}: zero rate", w.name);
        }
        // the graph path must schedule far more events than the
        // serialized path on the same sweep points
        let serialized = ws.iter().find(|w| w.name == "sweep-serialized").unwrap();
        let graph = ws.iter().find(|w| w.name == "sweep-graph").unwrap();
        assert!(
            graph.events > 2 * serialized.events,
            "graph sweep {} should dwarf serialized {}",
            graph.events,
            serialized.events
        );
        // the dense point rides the per-rank graph path too
        let dense = ws.iter().find(|w| w.name == "sweep-dense").unwrap();
        assert!(
            dense.events > 2 * serialized.events,
            "dense sweep {} should dwarf serialized {}",
            dense.events,
            serialized.events
        );
        // the overlap grid mixes serialized (streams = 1) and graph-path
        // (streams > 1) points, so it must out-event the serialized sweep
        let overlap = ws.iter().find(|w| w.name == "overlap-sweep").unwrap();
        assert!(
            overlap.events > serialized.events,
            "overlap sweep {} should exceed serialized {}",
            overlap.events,
            serialized.events
        );
        let t = perf_table(&ws, true);
        assert_eq!(t.rows.len(), 6);
        let j = perf_json(&ws, true);
        assert_eq!(
            j.get("schema").and_then(|v| v.as_str()),
            Some("mpi-dnn-train/bench-engine/v1")
        );
        assert_eq!(j.get("workloads").and_then(|v| v.as_arr()).map(|a| a.len()), Some(6));
    }

    #[test]
    fn check_against_reports_seed_match_and_drift() {
        let mk = |name: &str, events: u64| PerfWorkload {
            name: name.into(),
            detail: String::new(),
            runs: 1,
            events,
            wall_ms: 1.0,
        };
        let dir = std::env::temp_dir().join("mpi-dnn-train-perf-check-test");
        std::fs::create_dir_all(&dir).unwrap();

        // missing baseline seeds the trajectory
        let missing = dir.join("does-not-exist.json");
        let r = check_against(&[mk("a", 10)], true, &missing).unwrap();
        assert!(r.contains("seeds the trajectory"), "{r}");

        // empty-workloads baseline (the committed seed file) also seeds
        let empty = dir.join("empty.json");
        std::fs::write(&empty, perf_json(&[], true).to_string()).unwrap();
        let r = check_against(&[mk("a", 10)], true, &empty).unwrap();
        assert!(r.contains("no workloads yet"), "{r}");

        // populated baseline: unchanged, drifted, new and removed rows
        let base = dir.join("base.json");
        let baseline = perf_json(&[mk("same", 100), mk("drift", 100), mk("gone", 5)], true);
        std::fs::write(&base, baseline.to_string()).unwrap();
        let r =
            check_against(&[mk("same", 100), mk("drift", 110), mk("new", 7)], true, &base).unwrap();
        assert!(r.contains("same") && r.contains("unchanged"), "{r}");
        assert!(r.contains("drift") && r.contains("+10.0%"), "{r}");
        assert!(r.contains("NEW workload"), "{r}");
        assert!(r.contains("REMOVED"), "{r}");

        // quick vs full event counts are incomparable by design: the
        // mode mismatch is reported instead of per-row drift noise
        let r = check_against(&[mk("same", 999)], false, &base).unwrap();
        assert!(r.contains("mode mismatch"), "{r}");
        assert!(!r.contains("drift,"), "{r}");
    }

    #[test]
    fn event_counts_are_deterministic() {
        let a = run_perf(true).unwrap();
        let b = run_perf(true).unwrap();
        let ev = |v: &[PerfWorkload]| v.iter().map(|w| w.events).collect::<Vec<_>>();
        assert_eq!(ev(&a), ev(&b));
    }
}
